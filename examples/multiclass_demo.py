"""Section 6 / Theorem 3: K-class decision regions of a calibrated model
(ASCII rendering of the paper's Fig. 5 for K = 3).

    PYTHONPATH=src python examples/multiclass_demo.py [--beta 0.4]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import multiclass_regions, multiclass_rule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--beta", type=float, default=0.4)
    ap.add_argument("--res", type=int, default=30)
    args = ap.parse_args()

    # A representative asymmetric cost matrix (rows: true, cols: predicted).
    c = jnp.asarray([[0.0, 0.7, 0.9],
                     [1.0, 0.0, 0.6],
                     [0.8, 0.5, 0.0]])
    res = args.res
    marks = "012·"  # class regions + offload
    print(f"K=3 calibrated decision regions, β={args.beta} "
          f"(rows: f₀ → 1 top to bottom; cols: f₁ → 1 left to right; '·' = offload)")
    for i in range(res, -1, -1):
        f0 = i / res
        row = []
        for j in range(res + 1):
            f1 = j / res * (1 - f0)
            f2 = 1.0 - f0 - f1
            if f2 < -1e-9:
                row.append(" ")
                continue
            f = jnp.asarray([f0, f1, max(f2, 0.0)])
            lab = int(multiclass_regions(f[None], c, args.beta)[0])
            row.append(marks[lab])
        print("".join(row))
    # Expected-cost sanity on a few points.
    for f in ([1, 0, 0], [0.34, 0.33, 0.33], [0.1, 0.6, 0.3]):
        d = multiclass_rule(jnp.asarray(f, jnp.float32), c, jnp.asarray(args.beta))
        print(f"f={f} → {'offload' if bool(d.offload) else f'class {int(d.pred)}'}"
              f" (E[cost]={float(d.expected_cost):.3f})")


if __name__ == "__main__":
    main()
