"""Quickstart: run H2T2 on a calibrated BreakHis-like stream and compare with
every baseline from the paper's §5.

    PYTHONPATH=src python examples/quickstart.py [--dataset breakhis] [--beta 0.3]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import HIConfig, baselines, offline, run_stream
from repro.data import dataset_trace, empirical_confusion


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="breakhis")
    ap.add_argument("--beta", type=float, default=0.3)
    ap.add_argument("--horizon", type=int, default=10_000)
    ap.add_argument("--bits", type=int, default=4)
    args = ap.parse_args()

    cfg = HIConfig(bits=args.bits, delta_fp=0.7, delta_fn=1.0, eps=0.05, eta=1.0)
    tr = dataset_trace(args.dataset, args.horizon, jax.random.PRNGKey(0),
                       beta=args.beta)
    acc, fp, fn = empirical_confusion(tr)
    print(f"dataset={args.dataset}  LDL argmax: acc={acc:.2%} fp={fp:.2%} fn={fn:.2%}")
    print(f"experts |Θ| = {cfg.n_experts} (b={args.bits})\n")

    _, out = run_stream(cfg, tr.fs, tr.hrs, tr.betas, jax.random.PRNGKey(1))
    t = args.horizon
    results = {
        "No-offload": float(jnp.sum(baselines.no_offload_losses(
            cfg, tr.fs, tr.hrs, tr.betas))) / t,
        "Full-offload": float(jnp.sum(baselines.full_offload_losses(
            cfg, tr.fs, tr.hrs, tr.betas))) / t,
        "HI single-threshold (online)": float(jnp.sum(
            baselines.run_single_threshold(cfg, tr.fs, tr.hrs, tr.betas,
                                           jax.random.PRNGKey(2))[1].loss)) / t,
        "offline θ† (single)": float(offline.best_single_threshold(
            cfg, tr.fs, tr.hrs, tr.betas).best_loss) / t,
        "offline θ⃗* (two)": float(offline.best_two_threshold(
            cfg, tr.fs, tr.hrs, tr.betas).best_loss) / t,
        "H2T2 (ours)": float(jnp.sum(out.loss)) / t,
    }
    width = max(len(k) for k in results)
    for k, v in sorted(results.items(), key=lambda kv: kv[1]):
        print(f"  {k:<{width}}  avg cost = {v:.4f}")
    print(f"\noffload rate = {float(jnp.mean(out.offload)):.2%}, "
          f"explore rate = {float(jnp.mean(out.explored)):.2%}")


if __name__ == "__main__":
    main()
