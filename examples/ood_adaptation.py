"""OOD robustness + distribution-shift adaptation (paper Fig. 4e, extended).

Runs H2T2 on the OOD BreaCh stream, then on a BreakHis→BreaCh mid-stream
domain shift, comparing the paper's policy with the beyond-paper discounted
variant (decay < 1).

    PYTHONPATH=src python examples/ood_adaptation.py
"""
import jax
import jax.numpy as jnp

from repro.core import HIConfig, baselines, offline, run_stream
from repro.data import dataset_trace, drift_trace


def window_costs(losses, n=10):
    t = losses.shape[0]
    w = t // n
    return [float(jnp.mean(losses[i * w:(i + 1) * w])) for i in range(n)]


def main():
    beta, horizon = 0.3, 20_000
    key = jax.random.PRNGKey(0)

    print("== Stationary OOD (BreaCh: Chest model on BreakHis data, 38% FN) ==")
    tr = dataset_trace("breach", horizon, key, beta=beta)
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    _, out = run_stream(cfg, tr.fs, tr.hrs, tr.betas, jax.random.PRNGKey(1))
    no = float(jnp.mean(baselines.no_offload_losses(cfg, tr.fs, tr.hrs, tr.betas)))
    two = float(offline.best_two_threshold(cfg, tr.fs, tr.hrs, tr.betas).best_loss) / horizon
    print(f"  no-offload {no:.4f}  H2T2 {float(jnp.mean(out.loss)):.4f}  "
          f"offline-two {two:.4f}")

    print("\n== Mid-stream shift (BreakHis → BreaCh at T/2) ==")
    tr = drift_trace("breakhis", "breach", horizon, jax.random.PRNGKey(2), beta=beta)
    half = horizon // 2
    for decay in (1.0, 0.999):
        cfg = HIConfig(bits=4, eps=0.05, eta=1.0, decay=decay)
        _, out = run_stream(cfg, tr.fs, tr.hrs, tr.betas, jax.random.PRNGKey(3))
        label = "paper H2T2        " if decay == 1.0 else f"discounted γ={decay}"
        print(f"  {label}: pre-shift {float(jnp.mean(out.loss[:half])):.4f}  "
              f"post-shift {float(jnp.mean(out.loss[half:])):.4f}")
        print("    cost trajectory: "
              + " ".join(f"{c:.3f}" for c in window_costs(out.loss)))


if __name__ == "__main__":
    main()
