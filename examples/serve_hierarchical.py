"""End-to-end hierarchical-inference serving driver (paper Fig. 1).

A fleet of edge streams feeds samples through a REAL local transformer
backbone (paper-ldl config, binary head), H2T2 routes per stream, and ONLY
the offloaded samples are compacted into a fixed-capacity batch for the
remote backbone — the RDL is never paid for a locally-predicted sample, and
its labels feed back into the policy one slot later (double-buffered).

    PYTHONPATH=src python examples/serve_hierarchical.py [--streams 8] [--slots 100]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import LDL_CONFIG
from repro.core import HIConfig
from repro.models import init_params
from repro.models.heads import binary_head_init
from repro.serving import HIServer, HIServerConfig, available_engines, classifier_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--slots", type=int, default=100)
    ap.add_argument("--seq", type=int, default=24)
    ap.add_argument("--beta", type=float, default=0.2)
    ap.add_argument("--engine", default="fused", choices=available_engines(),
                    help="H2T2 PolicyEngine (see serving.policy_engine)")
    ap.add_argument("--capacity", type=int, default=0,
                    help="RDL offload-batch capacity (0 → n_streams)")
    args = ap.parse_args()

    vocab = 64
    ldl_cfg = LDL_CONFIG.reduced(vocab=vocab)
    key = jax.random.PRNGKey(0)
    ldl_params = init_params(key, ldl_cfg)
    ldl_head = binary_head_init(key, ldl_cfg)
    ldl = classifier_fn(ldl_cfg, ldl_params, ldl_head)

    def rdl(tokens):
        # Remote oracle: the event is 'odd number of token-7 occurrences'.
        return (jnp.sum(tokens == 7, axis=-1) % 2).astype(jnp.int32)

    hi = HIConfig(bits=4, delta_fp=0.7, delta_fn=1.0, eps=0.1, eta=1.0)
    server = HIServer(
        HIServerConfig(n_streams=args.streams, hi=hi, engine=args.engine,
                       offload_capacity=args.capacity or None), ldl, rdl)

    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (args.slots, args.streams, args.seq), 0, vocab,
        jnp.int32)
    betas = jnp.full((args.slots, args.streams), args.beta)

    t0 = time.perf_counter()
    state, summary = server.run(tokens, betas, jax.random.PRNGKey(2))
    wall = time.perf_counter() - t0
    n = args.slots * args.streams
    print(f"served {n} samples over {args.streams} streams "
          f"in {wall:.1f}s ({n/wall:.0f} samples/s on CPU)")
    print(f"avg offload cost = {summary['avg_offload_cost']:.4f}")
    print(f"offload rate     = {summary['offload_rate']:.2%}  (β = {args.beta})")
    print(f"RDL savings      = {summary['rdl_savings']:.2%} of samples never "
          f"hit the remote model ({summary['rdl_evals']:.0f} evals, "
          f"{summary['rdl_batches']:.0f} batches)")
    print("Each stream learned its own two-threshold policy online — "
          "no retraining of either backbone.")


if __name__ == "__main__":
    main()
