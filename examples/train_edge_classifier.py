"""Train a small LM backbone (~15M params by default) for a few hundred steps
on the synthetic token pipeline, then attach a binary head — producing an LDL
for the hierarchical-inference examples.

    PYTHONPATH=src python examples/train_edge_classifier.py --steps 300
"""
import argparse
import time

import jax

from repro.configs import RDL_CONFIG
from repro.data import synthetic_batch
from repro.models import init_params, param_count
from repro.training import AdamWConfig, TrainState, build_train_step, checkpoint, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="/tmp/edge_classifier.npz")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = RDL_CONFIG.reduced(vocab=512, n_layers=4, d_model=256, d_ff=1024)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    print(f"model: {cfg.name}  params={param_count(params):,}")
    state = TrainState(params=params, opt=init_opt_state(params))
    step = jax.jit(build_train_step(
        cfg, AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps),
        microbatches=args.microbatches))

    t0 = time.perf_counter()
    for i in range(args.steps):
        key, sub = jax.random.split(key)
        batch = synthetic_batch(sub, args.batch, args.seq, cfg.vocab)
        state, metrics = step(state, batch._asdict())
        if i % 25 == 0 or i == args.steps - 1:
            toks_s = args.batch * args.seq * (i + 1) / (time.perf_counter() - t0)
            print(f"step {i:4d}  loss={float(metrics['loss']):.4f}  "
                  f"lr={float(metrics['lr']):.2e}  "
                  f"grad_norm={float(metrics['grad_norm']):.2f}  "
                  f"tok/s={toks_s:.0f}")
    checkpoint.save(args.ckpt, state.params)
    print(f"saved checkpoint → {args.ckpt}")


if __name__ == "__main__":
    main()
