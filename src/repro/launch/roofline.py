"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds (per step):

    compute    = HLO_FLOPs_per_device / 197 TFLOP/s (bf16)
    memory     = HLO_bytes_per_device / 819 GB/s
    collective = collective_payload_bytes_per_device / 50 GB/s (one ICI link)

Sources: `compiled.cost_analysis()` (flops / bytes accessed, per device) and
the optimized HLO text for collectives. Two corrections:

  * XLA does NOT multiply costs through `while` loops (verified: a 62-layer
    scan reports one body's FLOPs). We therefore lower depth-1 and depth-2
    UNROLLED variants of the model and extrapolate:
        body = c(2) − c(1);  total = c(1) + (G − 1) · body
    which is exact for a homogeneous scanned stack.
  * Collective payloads use the largest shape printed on each collective op
    line (shard-local shapes post-SPMD); all-reduce is weighted 2× (ring
    sends reduce + broadcast passes).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional

import jax

from repro.configs import ModelConfig, InputShape
from repro.launch import builders
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8, "u64": 8, "c64": 8,
}
_SHAPE_RE = re.compile(r"(pred|s8|u8|bf16|f16|s16|u16|f32|s32|u32|f64|s64|u64)\[([0-9,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-kind payload bytes summed over collective ops in the HLO."""
    out = {k: 0.0 for k in _COLL_KINDS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # Match result-assignment lines: "%x = TYPE[...] kind(...)".
        m = re.match(r"%?[\w.\-]+\s*=\s*(?:\()?\s*(?:pred|s8|u8|bf16|f16|s16|u16|f32|s32|u32|f64|s64|u64|tuple)", stripped)
        if m is None:
            continue
        kind = None
        for k in _COLL_KINDS:
            if f" {k}(" in stripped or f"= {k}(" in stripped or f"{k}-start(" in stripped:
                kind = k
                break
        if kind is None:
            continue
        shapes = _SHAPE_RE.findall(stripped)
        if not shapes:
            continue
        payload = max(_shape_bytes(d, s) for d, s in shapes)
        weight = 2.0 if kind == "all-reduce" else 1.0
        out[kind] += weight * payload
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLL_KINDS)
    return out


def compile_and_measure(
    cfg: ModelConfig, shape: InputShape, mesh, strategy: str = "2d",
    unroll: bool = False, microbatches=None,
) -> Dict[str, Any]:
    fn, args, shard = builders.build_dryrun_step(
        cfg, shape, mesh, strategy=strategy, unroll=unroll,
        microbatches=microbatches)
    # Decode donates the cache state (arg 1): in-place ring updates instead of
    # a double-buffered copy of the whole KV cache per step.
    donate = (1,) if shape.kind == "decode" else ()
    with mesh:
        lowered = jax.jit(fn, in_shardings=shard,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):                    # jax ≤ 0.4.x wraps in a list
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "collective": coll,
        "memory": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "output_bytes": getattr(ma, "output_size_in_bytes", 0),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(ma, "argument_size_in_bytes", 0)
                           + getattr(ma, "output_size_in_bytes", 0)
                           + getattr(ma, "temp_size_in_bytes", 0)),
        },
    }


def _combine(c1: Dict, c2: Dict, groups: int) -> Dict[str, Any]:
    """total = c1 + (G−1)·(c2 − c1), elementwise on cost fields."""
    def extrap(a, b):
        return a + (groups - 1) * (b - a)

    coll = {}
    for k in list(c1["collective"].keys()):
        coll[k] = extrap(c1["collective"][k], c2["collective"][k])
    return {
        "flops": extrap(c1["flops"], c2["flops"]),
        "bytes": extrap(c1["bytes"], c2["bytes"]),
        "collective": coll,
    }


def roofline(
    cfg: ModelConfig, shape: InputShape, mesh, strategy: str = "2d",
    full_depth_memory: Optional[Dict] = None,
) -> Dict[str, Any]:
    """Delta-method roofline: exact per-layer costs from unrolled depth-1/2
    lowers, extrapolated to the full depth."""
    from repro.models.model import active_param_count

    # microbatches=1 so the grad-accum scan (another while loop XLA would
    # count once) doesn't hide FLOPs: one full-batch pass ≡ the summed
    # microbatch passes. Collective bytes consequently count the gradient
    # all-reduce once per step (the accumulate-then-reduce schedule).
    plan_groups = (cfg.n_layers - cfg.n_dense_layers) // len(cfg.pattern)
    c1 = compile_and_measure(builders.override_groups(cfg, 1), shape, mesh,
                             strategy, unroll=True, microbatches=1)
    c2 = compile_and_measure(builders.override_groups(cfg, 2), shape, mesh,
                             strategy, unroll=True, microbatches=1)
    total = _combine(c1, c2, plan_groups)

    compute_s = total["flops"] / PEAK_FLOPS_BF16
    memory_s = total["bytes"] / HBM_BW
    collective_s = total["collective"]["total"] / ICI_BW_PER_LINK
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)

    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens
    n_dev = mesh.size
    hlo_flops_global = total["flops"] * n_dev
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0

    return {
        "terms_seconds": terms,
        "dominant": dominant,
        "flops_per_device": total["flops"],
        "bytes_per_device": total["bytes"],
        "collective_bytes_per_device": total["collective"]["total"],
        "collective_breakdown": total["collective"],
        "model_flops": model_flops,
        "useful_flops_ratio": useful,
        "memory": full_depth_memory,
        "groups": plan_groups,
    }
