"""input_specs(): ShapeDtypeStruct stand-ins for every (arch × shape) input —
weak-type-correct, shardable, never allocating device memory."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, InputShape


def decode_capacity(cfg: ModelConfig, shape: InputShape) -> int:
    """KV-cache capacity a decode shape implies for this architecture.

    decode_32k keeps the full declared context. long_500k MUST be
    sub-quadratic: attention layers fall back to the sliding-window variant
    (cfg.long_context_window; the family's persistent window if smaller),
    recurrent/SSM layers carry O(1) state anyway. See DESIGN.md §4.
    """
    if shape.seq_len > 65536:
        win = cfg.long_context_window
        if cfg.sliding_window:
            win = min(win, cfg.sliding_window)
        return win
    return shape.seq_len


def decode_window(cfg: ModelConfig, shape: InputShape) -> Optional[int]:
    if shape.seq_len > 65536:
        return decode_capacity(cfg, shape)
    return None


def train_input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    text = s - cfg.n_patches if cfg.family == "vlm" else s
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((b, text), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, text), jnp.int32),
        "mask": jax.ShapeDtypeStruct((b, text), jnp.float32),
    }
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model),
                                                cfg.dtype)
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model),
                                               cfg.dtype)
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    specs = train_input_specs(cfg, shape)
    specs.pop("labels")
    specs.pop("mask")
    return specs


def decode_token_spec(cfg: ModelConfig, shape: InputShape):
    return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
