"""Hierarchical-inference serving driver: reduced LDL backbone + H2T2 fleet
router + remote oracle, over any assigned architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --streams 8 --slots 50 [--beta 0.25]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config
from repro.core import HIConfig
from repro.models import init_params
from repro.models.heads import binary_head_init
from repro.serving import HIServer, HIServerConfig, available_engines, classifier_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", help=f"one of {ASSIGNED}")
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--slots", type=int, default=50)
    ap.add_argument("--seq", type=int, default=24)
    ap.add_argument("--beta", type=float, default=0.25)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--decay", type=float, default=1.0)
    ap.add_argument("--engine", default="fused", choices=available_engines(),
                    help="H2T2 PolicyEngine (see serving.policy_engine)")
    ap.add_argument("--capacity", type=int, default=0,
                    help="RDL offload-batch capacity (0 → n_streams)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(vocab=64)
    if cfg.family in ("vlm", "encdec"):
        print(f"note: {args.arch} uses the decoder stack with token inputs "
              "for the serving demo (frontends are stubs)")
        import dataclasses
        cfg = dataclasses.replace(cfg, family="dense", pattern=("attn",),
                                  n_layers=2, n_dense_layers=0)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    head = binary_head_init(key, cfg)
    ldl = classifier_fn(cfg, params, head)

    def rdl(tokens):
        return (jnp.sum(tokens == 7, axis=-1) % 2).astype(jnp.int32)

    hi = HIConfig(bits=args.bits, eps=0.1, eta=1.0, decay=args.decay)
    server = HIServer(
        HIServerConfig(n_streams=args.streams, hi=hi, engine=args.engine,
                       offload_capacity=args.capacity or None), ldl, rdl)
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (args.slots, args.streams, args.seq), 0, 64,
        jnp.int32)
    betas = jnp.full((args.slots, args.streams), args.beta)
    t0 = time.perf_counter()
    _, summary = server.run(tokens, betas, jax.random.PRNGKey(2))
    n = args.slots * args.streams
    print(f"arch={args.arch} served {n} samples in "
          f"{time.perf_counter()-t0:.1f}s: "
          f"avg_offload_cost={summary['avg_offload_cost']:.4f} "
          f"offload_rate={summary['offload_rate']:.2%} "
          f"rdl_savings={summary['rdl_savings']:.2%}")


if __name__ == "__main__":
    main()
