"""Production meshes for TPU v5e pods.

Defined as FUNCTIONS so importing this module never touches jax device state
(jax locks the device count on first backend init — dryrun.py must set
XLA_FLAGS before any jax call).
"""
from __future__ import annotations

import jax


def _axis_types_kw(n_axes: int) -> dict:
    # jax.sharding.AxisType landed after 0.4.x; Auto is the default there
    # anyway, so older jax just omits the kwarg.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests / examples)."""
    n = jax.device_count()
    model = min(model, n)
    return jax.make_mesh(
        (n // model, model), ("data", "model"), **_axis_types_kw(2))


# TPU v5e hardware constants (per chip) for the roofline model.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s per link (~4 links usable per chip)
HBM_BYTES = 16 * 2**30            # 16 GiB
