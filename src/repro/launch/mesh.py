"""Production meshes for TPU v5e pods.

Defined as FUNCTIONS so importing this module never touches jax device state
(jax locks the device count on first backend init — dryrun.py must set
XLA_FLAGS before any jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests / examples)."""
    n = jax.device_count()
    model = min(model, n)
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants (per chip) for the roofline model.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s per link (~4 links usable per chip)
HBM_BYTES = 16 * 2**30            # 16 GiB
