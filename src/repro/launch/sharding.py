"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs per mesh.

Baseline strategy ("2d"): every weight matrix is sharded on its two largest
dims — row dim over 'data' (ZeRO/FSDP-flavored), column dim over 'model'
(tensor-parallel-flavored) — whenever divisible, else that dim is replicated.
Stacked scan params (leading group axis) and expert weights (leading expert
axis) shard their leading axis over 'model' when divisible (expert
parallelism), falling back to the 2D rule on the trailing dims.

Alternative strategies (used in §Perf hillclimbing):
  "tp"    — model-axis only on columns (pure tensor parallel, params
            replicated over 'data'),
  "fsdp"  — data-axis only on rows (pure ZeRO-3, no tensor parallel).

All rules are divisibility-safe: jit in_shardings reject uneven shards
(verified), so any non-divisible dim degrades to replication.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P



def _sizes(mesh: Mesh) -> Tuple[int, int]:
    return mesh.shape.get("data", 1), mesh.shape.get("model", 1)


def _fits(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0 and dim >= size


def _matrix_spec(shape, dsize, msize, strategy, leading_stack=0):
    """Spec for a (possibly stacked) weight tensor."""
    spec = [None] * len(shape)
    dims = list(range(leading_stack, len(shape)))
    if not dims:
        return P(*spec)
    if len(dims) == 1:
        d = dims[0]
        if strategy != "fsdp" and _fits(shape[d], msize):
            spec[d] = "model"
        return P(*spec)
    # Experts / stacked leading axis beyond the scan stack: shard over model.
    if len(dims) >= 3 and strategy != "fsdp" and _fits(shape[dims[0]], msize):
        spec[dims[0]] = "model"
        if strategy != "tp" and _fits(shape[dims[1]], dsize):
            spec[dims[1]] = "data"
        return P(*spec)
    row, col = dims[-2], dims[-1]
    if strategy != "tp" and _fits(shape[row], dsize):
        spec[row] = "data"
    if strategy != "fsdp" and _fits(shape[col], msize):
        spec[col] = "model"
    return P(*spec)


def param_specs(
    params_shape: Any, mesh: Mesh, strategy: str = "2d"
) -> Any:
    """Map a param shape-pytree (from jax.eval_shape) to PartitionSpecs."""
    dsize, msize = _sizes(mesh)

    def spec_for(path: str, shape) -> P:
        ndim = len(shape)
        if ndim <= 1:
            return P()
        stacked = "/body/" in path            # scan-stacked: skip group axis
        lead = 1 if stacked else 0
        if ndim - lead < 1:
            return P()
        # Vocab-sized weights: Megatron-style vocab-over-'model' ONLY.
        # 2D-sharding these makes GSPMD materialize full-vocab logits
        # (observed: 37 GB/device f32 logits on qwen2 train_4k).
        if "/embed/" in path:                 # (V, D)
            return P("model" if _fits(shape[0], msize) else None, None)
        if "/lm_head/" in path:               # (D, V)
            return P(None, "model" if _fits(shape[-1], msize) else None)
        # Depthwise conv (W, C): shard channels over model.
        if re.search(r"conv_w$", path):
            spec = [None] * ndim
            if _fits(shape[-1], msize) and strategy != "fsdp":
                spec[-1] = "model"
            return P(*spec)
        # Expert weights with E not divisible by the model axis (mixtral 8/16):
        # Megatron-style TP *within* each expert, matching moe_forward's
        # activation constraints — gate/up column-parallel (f@model), down
        # row-parallel (f@model on the contraction dim). The generic 2D rule
        # put 'data' on the contraction, which GSPMD resolved by all-gathering
        # the full-d_ff hidden (measured 17.5 GiB/step on mixtral prefill).
        if "/moe/" in path and ndim - lead == 3 and not _fits(
                shape[lead], msize):
            spec = [None] * ndim
            fdim = lead + 1 if path.endswith("/down") else lead + 2
            # Train ("2d"): hybrid TP+ZeRO — d_ff over ('model','data'),
            # 256-way storage; the 'data' part is re-gathered at use
            # (ZeRO-3), the 'model' part is the TP shard matching the
            # activation constraint. Inference ("tp"): model-only.
            if strategy == "2d" and _fits(shape[fdim], msize * dsize):
                spec[fdim] = ("model", "data")
            elif _fits(shape[fdim], msize):
                spec[fdim] = "model"
            return P(*spec)
        if ndim - lead == 1:
            return P()
        # Megatron pairing for second ("row-parallel") projections: their
        # contraction dim is the previous op's model-sharded output (d_ff,
        # attn heads, ssm inner), so shard the ROW over 'model' — otherwise
        # GSPMD all-gathers the full weight at every use (measured 1 GiB/layer
        # on deepseek-coder decode with tp).
        if re.search(r"/(down|o|out|out_proj)/w$", path) and ndim - lead == 2:
            spec = [None] * ndim
            if _fits(shape[lead], msize) and strategy != "fsdp":
                spec[lead] = "model"
            if strategy == "2d" and _fits(shape[lead + 1], dsize):
                spec[lead + 1] = "data"
            return P(*spec)
        return _matrix_spec(shape, dsize, msize, strategy, leading_stack=lead)

    def walk(tree, path=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
            t = type(tree)(walk(v, f"{path}/{i}") for i, v in enumerate(tree))
            return list(t) if isinstance(tree, list) else t
        if hasattr(tree, "_fields"):
            return type(tree)(*[walk(getattr(tree, k), f"{path}/{k}")
                                for k in tree._fields])
        return spec_for(path, tree.shape)

    return walk(params_shape)


def opt_state_specs(param_spec_tree: Any, params_shape: Any, mesh: Mesh) -> Any:
    """ZeRO-2 optimizer-state specs: m/v inherit the param spec PLUS 'data'
    on the largest still-unsharded divisible dim. The update is elementwise,
    so grads reshard in (a reduce-scatter-shaped move) and updated params
    gather back — param-sized traffic once per step, while the fp32 m/v
    (8 bytes/param) shard 256-way instead of 16-way."""
    dsize, _ = _sizes(mesh)

    def augment(spec: P, shape) -> P:
        used = set()
        for entry in spec:
            if isinstance(entry, (tuple, list)):
                used.update(entry)
            elif entry is not None:
                used.add(entry)
        if len(shape) < 2 or "data" in used:
            return spec
        parts = list(spec) + [None] * (len(shape) - len(spec))
        best, best_dim = 0, -1
        for i, (ax, dim) in enumerate(zip(parts, shape)):
            if ax is None and _fits(dim, dsize) and dim > best:
                best, best_dim = dim, i
        if best_dim >= 0:
            parts[best_dim] = "data"
        return P(*parts)

    flat_spec, treedef = jax.tree.flatten(
        param_spec_tree, is_leaf=lambda x: isinstance(x, P))
    flat_shape = jax.tree.leaves(params_shape)
    return jax.tree.unflatten(
        treedef, [augment(s, sh.shape) for s, sh in zip(flat_spec, flat_shape)])


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_spec(mesh: Mesh, global_batch: int, extra_dims: int = 1) -> P:
    """Spec for (B, ...) inputs; shards batch over (pod, data) if divisible."""
    axes = batch_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if global_batch % n == 0:
        return P(axes if len(axes) > 1 else axes[0], *([None] * extra_dims))
    d = mesh.shape.get("data", 1)
    if global_batch % d == 0:
        return P("data", *([None] * extra_dims))
    return P(*([None] * (extra_dims + 1)))


def cache_specs(caches_shape: Any, mesh: Mesh, global_batch: int) -> Any:
    """Decode-cache specs: batch over data when divisible; one trailing dim
    over 'model' preferring heads > feature > latent; seq dim replicated
    (ring writes land on one shard)."""
    dsize, msize = _sizes(mesh)
    baxes = batch_axes(mesh)
    nb = 1
    for a in baxes:
        nb *= mesh.shape[a]
    if global_batch % nb == 0:
        b_ax: Any = baxes if len(baxes) > 1 else baxes[0]
    elif global_batch % dsize == 0:
        b_ax = "data"
    else:
        b_ax = None

    def spec_for(path: str, shape) -> P:
        ndim = len(shape)
        if ndim == 0:
            return P()                        # cache index scalar
        stacked = "/body/" in path
        lead = 1 if stacked else 0
        spec = [None] * ndim
        if ndim - lead <= 1:                  # (stacked) scalar indices
            return P(*spec)
        bdim = lead
        if shape[bdim] and b_ax is not None and (
                shape[bdim] % nb == 0 if b_ax == baxes else shape[bdim] % dsize == 0):
            spec[bdim] = b_ax
        # One dim over 'model': prefer heads/latent dims (index bdim+2..) over
        # the ring/seq dim (bdim+1), which ring writes keep on one shard.
        candidates = list(range(bdim + 2, ndim)) + [bdim + 1]
        for d in candidates:
            if _fits(shape[d], msize):
                spec[d] = "model"
                break
        return P(*spec)

    def walk(tree, path=""):
        if tree is None:
            return None
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
            t = [walk(v, f"{path}/{i}") for i, v in enumerate(tree)]
            return t if isinstance(tree, list) else type(tree)(t)
        if hasattr(tree, "_fields"):
            return type(tree)(*[walk(getattr(tree, k), f"{path}/{k}")
                                for k in tree._fields])
        return spec_for(path, tree.shape)

    return walk(caches_shape)


def logical_axis_map(mesh: Mesh) -> Dict[str, Any]:
    """Mapping for repro.utils.constrain logical names."""
    baxes = batch_axes(mesh)
    return {
        "batch": baxes if len(baxes) > 1 else baxes[0],
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "expert": "model",
        "vocab": "model",
        "qseq": "model",
        "head_dim": "model",
        "seq": "data",
    }


def to_named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
