"""Training driver (CPU-scale runs of the reduced configs; the production
mesh path is exercised by dryrun.py).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --steps 50 \
        [--full] [--batch 8] [--seq 64] [--microbatches 1] [--ckpt out.npz]
"""
import argparse
import time

import jax

from repro.configs import ASSIGNED, get_config
from repro.data import synthetic_batch
from repro.models import init_params, param_count
from repro.training import (
    AdamWConfig,
    TrainState,
    build_train_step,
    checkpoint,
    init_opt_state,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, help=f"one of {ASSIGNED}")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="use the FULL config (needs accelerators; default is "
                         "the reduced smoke variant)")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    print(f"arch={cfg.name} params={param_count(params):,}")
    state = TrainState(params=params, opt=init_opt_state(params))
    step = jax.jit(build_train_step(
        cfg, AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps),
        microbatches=args.microbatches))
    t0 = time.perf_counter()
    for i in range(args.steps):
        key, sub = jax.random.split(key)
        batch = synthetic_batch(sub, args.batch, args.seq, cfg.vocab)._asdict()
        if cfg.family == "vlm":
            import jax.numpy as jnp
            batch["patches"] = 0.1 * jax.random.normal(
                sub, (args.batch, cfg.n_patches, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            import jax.numpy as jnp
            batch["frames"] = 0.1 * jax.random.normal(
                sub, (args.batch, cfg.n_frames, cfg.d_model), cfg.dtype)
        state, m = step(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"grad_norm={float(m['grad_norm']):.2f} "
                  f"({(i+1)/(time.perf_counter()-t0):.1f} it/s)")
    if args.ckpt:
        checkpoint.save(args.ckpt, state.params)
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
