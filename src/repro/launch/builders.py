"""Step builders shared by dryrun / train / serve: assemble (fn, arg specs,
in_shardings) for a given (arch × shape × mesh × strategy)."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig, InputShape
from repro.launch import sharding, specs as spec_lib
from repro.models import (
    decode_step,
    init_decode_state,
    init_params,
    prefill,
)
from repro.models.transformer import RunFlags
from repro.training import AdamWConfig, TrainState, build_train_step, init_opt_state
from repro.utils import activation_sharding


def model_flags(cfg: ModelConfig, shape: InputShape, mode: str,
                unroll_chunks: bool = False) -> RunFlags:
    attn_impl = "chunked" if shape.seq_len * shape.global_batch >= 2**20 else "naive"
    return RunFlags(
        mode=mode,
        window=spec_lib.decode_window(cfg, shape) if mode == "decode" else None,
        attn_impl=attn_impl if mode != "decode" else "naive",
        attn_chunk=2048,
        unroll_chunks=unroll_chunks,
        remat=(mode == "train"),
    )


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def default_microbatches(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> int:
    """Grad-accum factor for train shapes: target ≤ 4 sequences per device per
    microbatch for small models, ≤ 1 for d_model ≥ 4096 (33B-class activation
    slabs are ~4× larger per sequence)."""
    dsize = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    per_dev = max(1, shape.global_batch // dsize)
    target = 1 if cfg.d_model >= 4096 else 4
    m = max(1, per_dev // target)
    while shape.global_batch % m:
        m -= 1
    return m


def default_strategy(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> str:
    """Inference shapes use pure tensor-parallel params when a 1/msize shard
    of the TOTAL (stored, all-experts) weights fits comfortably in HBM: 2D
    (ZeRO-flavored) storage only buys memory that inference doesn't need,
    while paying a per-layer weight all-gather over 'data' (measured
    ~1 GiB/layer on deepseek-coder decode_32k)."""
    from repro.models.model import total_param_count

    if shape.kind == "train":
        return "2d"
    msize = mesh.shape.get("model", 1)
    per_dev = 2 * total_param_count(cfg) / msize           # bf16 bytes
    return "tp" if per_dev < 6 * 2**30 else "2d"


def build_dryrun_step(
    cfg: ModelConfig,
    shape: InputShape,
    mesh: Mesh,
    strategy: str = "auto",
    unroll: bool = False,
    microbatches: Optional[int] = None,
) -> Tuple[Any, Tuple, Any]:
    """Returns (fn, abstract args, in_shardings) for lower()."""
    if strategy == "auto":
        strategy = default_strategy(cfg, shape, mesh)
    mode = shape.kind
    flags = model_flags(cfg, shape, "prefill" if mode == "prefill" else mode,
                        unroll_chunks=unroll)
    p_shapes = abstract_params(cfg)
    p_specs = sharding.param_specs(p_shapes, mesh, strategy)
    p_shard = sharding.to_named(p_specs, mesh)
    logical = sharding.logical_axis_map(mesh)

    if mode == "train":
        batch_specs = spec_lib.train_input_specs(cfg, shape)
        opt_shapes = jax.eval_shape(init_opt_state, p_shapes)
        state_shapes = TrainState(params=p_shapes, opt=opt_shapes)
        mv_specs = sharding.opt_state_specs(p_specs, p_shapes, mesh)
        mv_shard = sharding.to_named(mv_specs, mesh)
        opt_shard = type(opt_shapes)(
            m=mv_shard, v=mv_shard,
            step=NamedSharding(mesh, P()))
        state_shard = TrainState(params=p_shard, opt=opt_shard)
        b_shard = {
            k: NamedSharding(
                mesh, sharding.batch_spec(mesh, shape.global_batch,
                                          extra_dims=len(v.shape) - 1))
            for k, v in batch_specs.items()
        }
        mb = (default_microbatches(cfg, shape, mesh)
              if microbatches is None else microbatches)
        step = build_train_step(cfg, AdamWConfig(), flags=flags, unroll=unroll,
                                microbatches=mb)

        def fn(state, batch):
            with activation_sharding(mesh, logical):
                return step(state, batch)

        return fn, (state_shapes, batch_specs), (state_shard, b_shard)

    if mode == "prefill":
        batch_specs = spec_lib.prefill_input_specs(cfg, shape)
        b_shard = {
            k: NamedSharding(
                mesh, sharding.batch_spec(mesh, shape.global_batch,
                                          extra_dims=len(v.shape) - 1))
            for k, v in batch_specs.items()
        }

        def fn(params, batch):
            with activation_sharding(mesh, logical):
                return prefill(params, cfg, batch, flags=flags, unroll=unroll)

        return fn, (p_shapes, batch_specs), (p_shard, b_shard)

    # decode
    capacity = spec_lib.decode_capacity(cfg, shape)
    state_shapes = jax.eval_shape(
        lambda: init_decode_state(cfg, shape.global_batch, capacity))
    c_specs = sharding.cache_specs(state_shapes, mesh, shape.global_batch)
    c_shard = sharding.to_named(c_specs, mesh)
    tok = spec_lib.decode_token_spec(cfg, shape)
    t_shard = NamedSharding(mesh, sharding.batch_spec(mesh, shape.global_batch))
    dflags = model_flags(cfg, shape, "decode")

    def fn(params, state, token):
        with activation_sharding(mesh, logical):
            return decode_step(params, cfg, state, token, flags=dflags,
                               unroll=unroll)

    return fn, (p_shapes, state_shapes, tok), (p_shard, c_shard, t_shard)


def override_groups(cfg: ModelConfig, k: int) -> ModelConfig:
    """Depth-reduced config with exactly k scanned groups (lead/tail kept) —
    used by the roofline delta method."""
    p = len(cfg.pattern)
    tail = (cfg.n_layers - cfg.n_dense_layers) % p
    n_layers = cfg.n_dense_layers + k * p + tail
    return dataclasses.replace(cfg, n_layers=n_layers)
