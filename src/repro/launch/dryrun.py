import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY for this dry-run entry point.

"""Multi-pod dry-run: prove every (arch × shape × mesh) lowers and compiles.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape train_4k --mesh single [--roofline] [--strategy 2d]

Emits one JSON object: memory analysis (bytes/device), cost analysis
(FLOPs/bytes), collective schedule summary, and — with --roofline — the
three-term roofline via the delta method (see launch/roofline.py).
"""
import argparse
import json
import sys
import time

import jax

from repro.configs import ASSIGNED, SHAPES, get_config, get_shape
from repro.launch import roofline as roofline_lib
from repro.launch.mesh import HBM_BYTES, make_production_mesh


def run_one(arch: str, shape_name: str, mesh_kind: str, strategy: str,
            do_roofline: bool, unroll: bool) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "strategy": strategy, "mode": shape.kind,
        "n_devices": int(mesh.size),
    }
    try:
        meas = roofline_lib.compile_and_measure(
            cfg, shape, mesh, strategy=strategy, unroll=unroll)
        result["ok"] = True
        result["compile_seconds"] = round(time.time() - t0, 1)
        result["memory"] = meas["memory"]
        result["fits_hbm"] = meas["memory"]["peak_bytes"] <= HBM_BYTES
        result["cost_analysis"] = {"flops": meas["flops"], "bytes": meas["bytes"]}
        result["collectives_fulldepth"] = meas["collective"]
        if do_roofline:
            t1 = time.time()
            result["roofline"] = roofline_lib.roofline(
                cfg, shape, mesh, strategy=strategy,
                full_depth_memory=meas["memory"])
            result["roofline_seconds"] = round(time.time() - t1, 1)
    except Exception as e:  # noqa: BLE001 — a dry-run failure IS the finding
        result["ok"] = False
        result["error"] = f"{type(e).__name__}: {e}"[:500]
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help=f"one of {ASSIGNED} (or 'all')")
    ap.add_argument("--shape", default="all", choices=[*SHAPES, "all"])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--strategy", default="auto", choices=["auto", "2d", "tp", "fsdp"])
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer scan in the full-depth compile")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    ok = True
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                res = run_one(arch, shape, mesh_kind, args.strategy,
                              args.roofline, args.unroll)
                print(json.dumps(res))
                sys.stdout.flush()
                ok &= res["ok"]
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
