"""Activation-sharding hook.

Model code calls `constrain(x, 'batch', None, 'model')` with *logical* axis
names; outside a mesh context this is the identity, inside it maps logical
names to mesh axes and applies `with_sharding_constraint`. Divisibility is
checked so constraints never break lowering (GSPMD rejects uneven shards for
named shardings) — a non-divisible axis silently degrades to replicated.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules() -> Optional[Dict[str, object]]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, logical_to_mesh: Dict[str, object]):
    """Enable constrain() with the given logical→mesh-axis mapping.

    logical_to_mesh values may be a mesh-axis name, a tuple of names, or None.
    """
    prev = getattr(_state, "ctx", None)
    _state.ctx = {"mesh": mesh, "map": dict(logical_to_mesh)}
    try:
        yield
    finally:
        _state.ctx = prev


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def axis_size(logical_name: str) -> int:
    """Mesh size of the axis a logical name maps to (1 outside a context)."""
    ctx = _rules()
    if ctx is None:
        return 1
    return _axis_size(ctx["mesh"], ctx["map"].get(logical_name))


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    ctx = _rules()
    if ctx is None:
        return x
    mesh: Mesh = ctx["mesh"]
    mapping = ctx["map"]
    spec = []
    for dim, name in enumerate(logical_axes):
        axis = mapping.get(name) if name is not None else None
        # GSPMD pads uneven *internal* shardings (verified: uneven
        # with_sharding_constraint lowers fine), but degenerate cases where
        # the dim is smaller than the axis would waste most devices.
        if axis is not None and x.shape[dim] < _axis_size(mesh, axis):
            axis = None
        spec.append(axis)
    # Trailing unnamed dims are replicated.
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec))
    )
