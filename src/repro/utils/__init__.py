from repro.utils.pjit import activation_sharding, constrain

__all__ = ["activation_sharding", "constrain"]
