"""repro — H2T2 hierarchical-inference serving framework (JAX / TPU).

Reproduction + extension of "Inference Offloading for Cost-Sensitive Binary
Classification at the Edge" (AAAI 2026). See README.md / DESIGN.md.
"""
__version__ = "1.0.0"
