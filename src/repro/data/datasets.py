"""Dataset/model-pair specs matched to the paper's Table 2/3 statistics.

The paper's evaluation consumes only the stream of (f_t, h_r_t) pairs — the LDL
confidence and the remote label (used as ground-truth proxy). We therefore model
each dataset/model pair as a generative confidence model:

    h_r ~ Bernoulli(p1)
    f | h_r = 1 ~ TruncNorm(mu1, sigma1; (0, 1))   (class-of-interest samples)
    f | h_r = 0 ~ TruncNorm(mu0, sigma0; (0, 1))

and solve (mu1, mu0) by bisection so that the *argmax* confusion statistics
match the paper's Table 2/3 exactly:

    FN = P(h_r = 1) · P(f < 0.5 | h_r = 1)      (fraction of ALL samples)
    FP = P(h_r = 0) · P(f ≥ 0.5 | h_r = 0)

This mirrors the paper's own Synthetic dataset construction ("softmax-like
values using Gaussian mixtures truncated to (0, 1)") and is exactly the
information the policies observe.
"""
from __future__ import annotations

import math
from typing import Dict

from repro.core.types import StreamSpec

_SQRT2 = math.sqrt(2.0)


def _norm_cdf(x: float) -> float:
    return 0.5 * (1.0 + math.erf(x / _SQRT2))


def trunc_norm_cdf(x: float, mu: float, sigma: float, lo: float = 0.0, hi: float = 1.0) -> float:
    """CDF of N(mu, sigma) truncated to (lo, hi), evaluated at x."""
    a = _norm_cdf((lo - mu) / sigma)
    b = _norm_cdf((hi - mu) / sigma)
    if b - a < 1e-300:
        return 0.0 if x < mu else 1.0
    x = min(max(x, lo), hi)
    return (_norm_cdf((x - mu) / sigma) - a) / (b - a)


def solve_mu(target_cdf_at_half: float, sigma: float) -> float:
    """Find mu with TruncNormCDF(0.5; mu, sigma) = target, by bisection."""
    lo, hi = -5.0, 6.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        # CDF at 0.5 decreases as mu increases.
        if trunc_norm_cdf(0.5, mid, sigma) > target_cdf_at_half:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def calibrate(spec: StreamSpec) -> Dict[str, float]:
    """Solve the generative parameters matching the spec's accuracy/FP/FN."""
    p1 = spec.p1
    if not 0.0 < p1 < 1.0:
        raise ValueError(f"{spec.name}: p1 must be in (0,1)")
    fn_cond = spec.fn / p1                # P(f < 0.5 | h_r = 1)
    fp_cond = spec.fp / (1.0 - p1)        # P(f ≥ 0.5 | h_r = 0)
    if not 0.0 <= fn_cond <= 1.0 or not 0.0 <= fp_cond <= 1.0:
        raise ValueError(
            f"{spec.name}: infeasible (p1={p1}, fn_cond={fn_cond}, fp_cond={fp_cond})"
        )
    mu1 = solve_mu(fn_cond, spec.sigma1)
    mu0 = solve_mu(1.0 - fp_cond, spec.sigma0)
    return {"p1": p1, "mu1": mu1, "sigma1": spec.sigma1, "mu0": mu0, "sigma0": spec.sigma0}


# --- Paper Table 2 (manuscript) and Table 3 (appendix) dataset/model pairs ----
# accuracy/fp/fn are fractions of all samples; priors follow the described
# class balances (BreakHis 5429/7909 malignant; Chest 4:1 cancerous; CIFAR
# cats/dogs balanced; ChestXRay 390/624 pneumonia; OOD pairs inherit sources).
DATASETS: Dict[str, StreamSpec] = {
    s.name: s
    for s in [
        StreamSpec("breakhis", accuracy=0.72, fp=0.10, fn=0.18, p1=0.558,
                   note="BreakHis × MobileNet LDL [Spanhol et al. 2015]"),
        StreamSpec("chest", accuracy=0.64, fp=0.16, fn=0.20, p1=0.80,
                   sigma1=0.35, sigma0=0.35,
                   note="Chest CT × MobileNet LDL [Mohamed 2025], 4:1 imbalance"),
        StreamSpec("phishing", accuracy=0.75, fp=0.12, fn=0.13, p1=0.50,
                   note="Phishing × 56-byte logistic regression [Tan 2018]"),
        StreamSpec("synthetic", accuracy=0.66, fp=0.15, fn=0.19, p1=0.50,
                   sigma1=0.40, sigma0=0.60,
                   note="Paper's truncated-GMM synthetic"),
        StreamSpec("breach", accuracy=0.45, fp=0.17, fn=0.38, p1=0.558,
                   sigma1=0.45, sigma0=0.45,
                   note="OOD: BreakHis data on Chest model (38% FN)"),
        # Appendix (Table 3) pairs:
        StreamSpec("chestxray", accuracy=0.78, fp=0.18, fn=0.04, p1=0.625,
                   note="ChestXRay pneumonia × small CNN [Kermany 2018]"),
        StreamSpec("resnetdogs", accuracy=0.73, fp=0.15, fn=0.11, p1=0.50,
                   note="CIFAR cats/dogs × ResNet-8"),
        StreamSpec("logisticdogs", accuracy=0.56, fp=0.22, fn=0.22, p1=0.50,
                   sigma1=0.50, sigma0=0.50,
                   note="CIFAR cats/dogs × logistic regression (97 KB)"),
        StreamSpec("xract", accuracy=0.35, fp=0.01, fn=0.64, p1=0.66,
                   sigma1=0.40, sigma0=0.30,
                   note="OOD: ChestXRay data on Chest-CT model"),
    ]
}


def get_spec(name: str) -> StreamSpec:
    try:
        return DATASETS[name]
    except KeyError:
        raise KeyError(f"unknown dataset {name!r}; choose from {sorted(DATASETS)}")
