"""Simulated HI streams: draw (f_t, h_r_t, β_t) traces from calibrated specs."""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import StreamSpec
from repro.data.datasets import calibrate, get_spec


class Trace(NamedTuple):
    fs: jnp.ndarray      # (T,) or (S, T) LDL confidences in [0, 1)
    hrs: jnp.ndarray     # remote labels (ground-truth proxy), int32
    betas: jnp.ndarray   # offloading costs


def _trunc_normal(key: jax.Array, mu, sigma, shape) -> jnp.ndarray:
    """Truncated N(mu, sigma) on (0, 1) via inverse-CDF on the base normal."""
    lo = (0.0 - mu) / sigma
    hi = (1.0 - mu) / sigma
    u = jax.random.uniform(key, shape, minval=1e-6, maxval=1.0 - 1e-6)
    from jax.scipy.stats import norm

    a, b = norm.cdf(lo), norm.cdf(hi)
    x = norm.ppf(a + u * (b - a))
    return jnp.clip(mu + sigma * x, 1e-6, 1.0 - 1e-6)


def sample_trace(
    spec: StreamSpec,
    horizon: int,
    key: jax.Array,
    beta: float = 0.3,
    beta_mode: str = "fixed",
    n_streams: Optional[int] = None,
) -> Trace:
    """Draw a trace of length `horizon` (optionally (n_streams, horizon)).

    beta_mode: 'fixed' — constant β (paper's comparison study);
               'uniform' — β_t ~ U(0, β) oblivious adversary.
    """
    params = calibrate(spec)
    shape = (horizon,) if n_streams is None else (n_streams, horizon)
    k_y, k_f1, k_f0, k_b = jax.random.split(key, 4)
    hrs = jax.random.bernoulli(k_y, params["p1"], shape).astype(jnp.int32)
    f1 = _trunc_normal(k_f1, params["mu1"], params["sigma1"], shape)
    f0 = _trunc_normal(k_f0, params["mu0"], params["sigma0"], shape)
    fs = jnp.where(hrs == 1, f1, f0)
    if beta_mode == "fixed":
        betas = jnp.full(shape, beta, jnp.float32)
    elif beta_mode == "uniform":
        betas = jax.random.uniform(k_b, shape, maxval=beta)
    else:
        raise ValueError(f"unknown beta_mode {beta_mode!r}")
    return Trace(fs=fs.astype(jnp.float32), hrs=hrs, betas=betas)


def dataset_trace(
    name: str, horizon: int, key: jax.Array, beta: float = 0.3, **kw
) -> Trace:
    return sample_trace(get_spec(name), horizon, key, beta=beta, **kw)


def empirical_confusion(trace: Trace) -> Tuple[float, float, float]:
    """(accuracy, fp, fn) of the argmax rule on a trace — sanity vs Table 2."""
    pred1 = trace.fs >= 0.5
    fp = float(jnp.mean(pred1 & (trace.hrs == 0)))
    fn = float(jnp.mean(~pred1 & (trace.hrs == 1)))
    return 1.0 - fp - fn, fp, fn


def drift_trace(
    name_a: str,
    name_b: str,
    horizon: int,
    key: jax.Array,
    beta: float = 0.3,
    switch_at: Optional[int] = None,
) -> Trace:
    """Concatenate two dataset regimes — distribution-shift robustness runs."""
    switch_at = horizon // 2 if switch_at is None else switch_at
    k_a, k_b = jax.random.split(key)
    a = dataset_trace(name_a, switch_at, k_a, beta=beta)
    b = dataset_trace(name_b, horizon - switch_at, k_b, beta=beta)
    return Trace(
        fs=jnp.concatenate([a.fs, b.fs]),
        hrs=jnp.concatenate([a.hrs, b.hrs]),
        betas=jnp.concatenate([a.betas, b.betas]),
    )
