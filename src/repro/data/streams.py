"""DEPRECATED shim — everything here lives in `repro.data.scenarios`.

`sample_trace` / `dataset_trace` / `drift_trace` predate the ScenarioSource
registry; they are now plain re-exports of the materialized-trace helpers in
`repro.data.scenarios` (which run the registered `stationary` / `piecewise`
sources to completion, so the chunked per-slot-keyed draws are the single
generation path).

Importing this module emits a `DeprecationWarning`. Import the same names
from `repro.data` (or `repro.data.scenarios`) instead.

Removal horizon: this shim is kept for two more stacked PRs after the
learner-registry/ExecSpec consolidation (PR 9) and will then be deleted;
no in-repo code imports it anymore.
"""
from __future__ import annotations

import warnings

from repro.data.scenarios import (  # noqa: F401
    Trace,
    _to_trace,
    dataset_trace,
    drift_trace,
    empirical_confusion,
    sample_trace,
)

warnings.warn(
    "repro.data.streams is deprecated and will be removed; import "
    "Trace/sample_trace/dataset_trace/drift_trace/empirical_confusion "
    "from repro.data (or repro.data.scenarios) instead",
    DeprecationWarning,
    stacklevel=2,
)

__all__ = ["Trace", "dataset_trace", "drift_trace", "empirical_confusion",
           "sample_trace"]
