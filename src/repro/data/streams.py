"""Simulated HI streams — thin compatibility shims over the ScenarioSource
registry (`repro.data.scenarios`).

`sample_trace` / `dataset_trace` / `drift_trace` predate the registry and
materialized (S, T) traces on the host in one shot. They now materialize
the matching scenario sources (`stationary`, `piecewise`), so there is a
single generation path: the chunked per-slot-keyed draws. Chunked emission
and these materialized traces are bit-identical by construction — prefer a
`ScenarioSource` (and `run_fleet_source` / `HIServer.run_source`) for
anything long-horizon or nonstationary; these shims exist for the paper
figures and tests that genuinely need the whole trace at once.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.types import StreamSpec
from repro.data.datasets import get_spec
from repro.data.scenarios import PiecewiseSource, SlotBatch, StationarySource


class Trace(NamedTuple):
    fs: jnp.ndarray      # (T,) or (S, T) LDL confidences in [0, 1)
    hrs: jnp.ndarray     # remote labels (ground-truth proxy), int32
    betas: jnp.ndarray   # offloading costs


def _to_trace(batch: SlotBatch, squeeze: bool) -> Trace:
    fs, hrs, betas = batch.fs, batch.hrs, batch.betas
    if squeeze:
        fs, hrs, betas = fs[0], hrs[0], betas[0]
    return Trace(fs=fs, hrs=hrs, betas=betas)


def sample_trace(
    spec: Union[StreamSpec, str],
    horizon: int,
    key: jax.Array,
    beta: float = 0.3,
    beta_mode: str = "fixed",
    n_streams: Optional[int] = None,
) -> Trace:
    """Materialized stationary trace of length `horizon` (optionally
    (n_streams, horizon)) — `StationarySource` run to completion.

    beta_mode: 'fixed' — constant β (paper's comparison study);
               'uniform' — β_t ~ U(0, β) oblivious adversary.
    """
    src = StationarySource(spec=spec, n_streams=n_streams or 1,
                           horizon=horizon, key=key, beta=beta,
                           beta_mode=beta_mode)
    return _to_trace(src.materialize(), squeeze=n_streams is None)


def dataset_trace(
    name: str, horizon: int, key: jax.Array, beta: float = 0.3, **kw
) -> Trace:
    return sample_trace(get_spec(name), horizon, key, beta=beta, **kw)


def empirical_confusion(trace) -> Tuple[float, float, float]:
    """(accuracy, fp, fn) of the argmax rule on a trace — sanity vs Table 2.

    Accepts a `Trace` or any (fs, hrs)-carrying batch (e.g. `SlotBatch`)."""
    pred1 = trace.fs >= 0.5
    fp = float(jnp.mean(pred1 & (trace.hrs == 0)))
    fn = float(jnp.mean(~pred1 & (trace.hrs == 1)))
    return 1.0 - fp - fn, fp, fn


def drift_trace(
    name_a: str,
    name_b: str,
    horizon: int,
    key: jax.Array,
    beta: float = 0.3,
    switch_at: Optional[int] = None,
) -> Trace:
    """Two-regime shift trace — the `piecewise` scenario's simplest schedule,
    kept for the distribution-shift robustness runs."""
    switch_at = horizon // 2 if switch_at is None else switch_at
    src = PiecewiseSource(segments=((0, name_a), (switch_at, name_b)),
                          horizon=horizon, key=key, beta=beta)
    return _to_trace(src.materialize(), squeeze=True)
