"""ScenarioSource registry: streamed, nonstationary workload generation.

The paper's headline claims beyond the regret bound are robustness to
distribution shift and to mismatched classifiers; this module turns every
such workload into a registered generator, the same way
`serving/policy_engine.py` turned execution backends into registered
engines (`register_scenario` / `get_scenario`).

A `ScenarioSource` never materializes the full (S, T) trace on the host.
It emits the horizon in **slot blocks** through the jit-able hook

    emit(state, key, slot) -> (state, SlotBatch)     # leaves (S, block)

pulled by `lax.scan` drivers (`materialize`, `core.policy.run_fleet_source`,
`HIServer.run_source`), so peak trace residency is one block however long
the horizon is.

Chunk-invariance contract: every random draw for absolute slot t is made
from `fold_in(domain-separated key, t)` (purpose-tagged sub-keys via a
further fold), never from a block-shaped one-shot draw. The emitted trace is therefore
bit-identical for ANY block size, stateful scenarios included, and
`materialize()` is exactly the concatenation of the chunks.

Registered scenarios:

  "stationary"   — the calibrated Table 2/3 specs (old `sample_trace`).
  "piecewise"    — arbitrary drift schedules: (start_slot, spec) segments;
                   generalizes and absorbs the old two-regime `drift_trace`.
  "beta_process" — network-cost dynamics over a stationary confidence
                   stream: fixed | uniform | bursty (two-state Markov
                   congestion, state carried across blocks) | sinusoidal.
  "noisy_rdl"    — mismatched remote classifier: the feedback labels `hrs`
                   are drawn from the RDL's own confusion spec while the
                   true labels stay in `ys` for simulation-grade accounting.
  "hetero_fleet" — per-stream dataset/model specs stacked into one fleet.
  "replay"       — playback of an explicit recorded (S, T) trace, e.g. the
                   request plane's per-round log, so online serving runs
                   can be replayed through the offline drivers exactly.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.registry import Registry
from repro.core.types import StreamSpec
from repro.data.datasets import calibrate, get_spec

SpecLike = Union[str, StreamSpec]

# Purpose tags folded into the per-slot key: one tag per draw, so scenarios
# can consume any subset without perturbing each other's streams.
_K_Y, _K_F1, _K_F0, _K_BETA, _K_RDL, _K_REGIME = range(6)
# Domain separator folded in before the slot index: scenario draws and the
# policy's `source_slot_keys` tree (fold_in(fold_in(key, t), stream)) stay
# disjoint even when a caller reuses one base key for both.
_K_DOMAIN = 0x5CE11A21


class SlotBatch(NamedTuple):
    """One emitted slot block; every leaf is (n_streams, block)."""

    fs: jnp.ndarray      # LDL confidences in (0, 1), float32
    hrs: jnp.ndarray     # remote labels the policy's feedback sees, int32
    ys: jnp.ndarray      # ground truth, int32 (== hrs unless the RDL is noisy)
    betas: jnp.ndarray   # offloading costs, float32


SCENARIOS: Registry = Registry("scenario")
# Compatibility alias: this IS the registry's backing dict (tests add and
# delete entries through it directly), not a copy.
_SCENARIOS = SCENARIOS._entries


def register_scenario(name: str):
    """Class decorator: add a ScenarioSource implementation to the registry."""

    def deco(cls):
        cls.name = name
        SCENARIOS.add(name, cls)
        return cls

    return deco


def available_scenarios(synthetic_only: bool = False) -> Tuple[str, ...]:
    """Registered scenario names; `synthetic_only=True` keeps only sources
    constructible from (n_streams, horizon, key) alone — generic sweeps use
    this to skip data-backed sources like `replay`."""
    return tuple(n for n in SCENARIOS.names()
                 if not synthetic_only or SCENARIOS.get(n).synthetic)


def list_scenarios() -> Tuple[Tuple[str, str], ...]:
    """(name, one-line description) pairs for every registered scenario."""
    return SCENARIOS.describe()


def get_scenario(name: str, **opts) -> "ScenarioSource":
    """Resolve a registered scenario name to a constructed source."""
    return SCENARIOS.lookup(name)(**opts)


def _trunc_normal(key: jax.Array, mu, sigma, shape) -> jnp.ndarray:
    """Truncated N(mu, sigma) on (0, 1) via inverse-CDF on the base normal."""
    from jax.scipy.stats import norm

    lo = (0.0 - mu) / sigma
    hi = (1.0 - mu) / sigma
    u = jax.random.uniform(key, shape, minval=1e-6, maxval=1.0 - 1e-6)
    a, b = norm.cdf(lo), norm.cdf(hi)
    x = norm.ppf(a + u * (b - a))
    return jnp.clip(mu + sigma * x, 1e-6, 1.0 - 1e-6)


def _as_params(spec: SpecLike) -> Dict[str, jnp.ndarray]:
    spec = get_spec(spec) if isinstance(spec, str) else spec
    return {k: jnp.float32(v) for k, v in calibrate(spec).items()}


def _confidence_slot(kt: jax.Array, params, s: int):
    """One slot's (y, f) draws for S streams.

    `params` values may be scalars or (S,) arrays (heterogeneous fleets);
    both broadcast through the truncated-normal inverse CDF.
    """
    y = jax.random.bernoulli(
        jax.random.fold_in(kt, _K_Y), params["p1"], (s,)).astype(jnp.int32)
    f1 = _trunc_normal(jax.random.fold_in(kt, _K_F1),
                       params["mu1"], params["sigma1"], (s,))
    f0 = _trunc_normal(jax.random.fold_in(kt, _K_F0),
                       params["mu0"], params["sigma0"], (s,))
    return y, jnp.where(y == 1, f1, f0).astype(jnp.float32)


class ScenarioSource:
    """Base class: block bookkeeping + the stateless per-slot emit loop.

    Subclasses implement `_slot(kt, t) -> (f, hr, y, beta)` (all (S,)) for
    stateless generation, or override `emit` entirely when the scenario
    carries state across slots (see the bursty β process). `horizon` must
    divide into `block`-sized chunks; `block=None` means one block — the
    materialized shape, still bit-identical to any other chunking.
    """

    name = "abstract"
    BETA_MODES = ("fixed", "uniform")
    #: True when the source can be built from (n_streams, horizon, key)
    #: alone — what generic sweeps (bench_scenarios) require. Data-backed
    #: sources (replay) set this False and need explicit arrays.
    synthetic = True

    def __init__(self, n_streams: int = 1, horizon: int = 10_000,
                 block: Optional[int] = None, key: Optional[jax.Array] = None,
                 beta: float = 0.3, beta_mode: str = "fixed"):
        block = horizon if block is None else block
        if n_streams < 1:
            raise ValueError(f"n_streams must be ≥ 1 (got {n_streams})")
        if horizon < 1 or block < 1 or horizon % block:
            raise ValueError(
                f"horizon {horizon} must be a positive multiple of the "
                f"block size {block}")
        if beta_mode not in self.BETA_MODES:
            raise ValueError(
                f"unknown beta_mode {beta_mode!r}; expected one of "
                f"{self.BETA_MODES}")
        self.n_streams = int(n_streams)
        self.horizon = int(horizon)
        self.block = int(block)
        self.key = jax.random.PRNGKey(0) if key is None else key
        self.beta = float(beta)
        self.beta_mode = beta_mode

    @property
    def n_blocks(self) -> int:
        return self.horizon // self.block

    def init_state(self):
        """Generator carry threaded through emit; () for stateless sources."""
        return ()

    def _draw_betas(self, kt: jax.Array, t) -> jnp.ndarray:
        if self.beta_mode == "uniform":
            return jax.random.uniform(
                jax.random.fold_in(kt, _K_BETA), (self.n_streams,),
                maxval=self.beta)
        return jnp.full((self.n_streams,), self.beta, jnp.float32)

    def _slot(self, kt: jax.Array, t):
        raise NotImplementedError

    def emit(self, state, key: jax.Array, slot) -> Tuple[object, SlotBatch]:
        """Emit slot block `slot` (block index): leaves (S, block)."""
        key = jax.random.fold_in(key, _K_DOMAIN)
        ts = slot * self.block + jnp.arange(self.block, dtype=jnp.int32)
        f, hr, y, b = jax.vmap(
            lambda t: self._slot(jax.random.fold_in(key, t), t))(ts)
        tp = lambda a: jnp.swapaxes(a, 0, 1)
        return state, SlotBatch(fs=tp(f), hrs=tp(hr), ys=tp(y), betas=tp(b))

    def materialize(self, key: Optional[jax.Array] = None) -> SlotBatch:
        """Concatenate all blocks into one (S, T) SlotBatch (tests/offline
        comparators only — the chunked drivers never call this)."""
        key = self.key if key is None else key

        def step(st, b):
            return self.emit(st, key, b)

        _, batches = jax.lax.scan(step, self.init_state(),
                                  jnp.arange(self.n_blocks))
        # leaves (n_blocks, S, block) → (S, T)
        return jax.tree_util.tree_map(
            lambda a: jnp.swapaxes(a, 0, 1).reshape(
                self.n_streams, self.horizon), batches)


@register_scenario("stationary")
class StationarySource(ScenarioSource):
    """The calibrated Table 2/3 workloads — old `sample_trace`, chunked."""

    def __init__(self, spec: SpecLike = "synthetic", **kw):
        super().__init__(**kw)
        self.params = _as_params(spec)

    def _slot(self, kt, t):
        y, f = _confidence_slot(kt, self.params, self.n_streams)
        return f, y, y, self._draw_betas(kt, t)


@register_scenario("hetero_fleet")
class HeteroFleetSource(StationarySource):
    """Per-stream specs stacked into one fleet: stream i runs specs[i].

    Defaults cycle the manuscript datasets up to `n_streams`; passing
    `specs` pins the fleet mix (and its length wins over `n_streams`).
    """

    DEFAULT_SPECS = ("breakhis", "chest", "phishing", "synthetic")

    def __init__(self, specs: Optional[Sequence[SpecLike]] = None,
                 n_streams: Optional[int] = None, **kw):
        if specs is None:
            n_streams = len(self.DEFAULT_SPECS) if n_streams is None else n_streams
            specs = tuple(self.DEFAULT_SPECS[i % len(self.DEFAULT_SPECS)]
                          for i in range(n_streams))
        elif n_streams is not None and n_streams != len(specs):
            raise ValueError(
                f"n_streams={n_streams} contradicts len(specs)={len(specs)}")
        ScenarioSource.__init__(self, n_streams=len(specs), **kw)
        per = [_as_params(sp) for sp in specs]
        self.specs = tuple(specs)
        self.params = {k: jnp.stack([p[k] for p in per]) for k in per[0]}


@register_scenario("piecewise")
class PiecewiseSource(ScenarioSource):
    """Arbitrary drift schedules: `segments` = ((start_slot, spec), ...).

    Slot t draws from the last segment whose start ≤ t (searchsorted on
    device, so emit stays one jit-able function across the whole schedule).
    The default reproduces the old `drift_trace` BreakHis→BreaCh switch at
    T/2; any number of regimes works.
    """

    def __init__(self, segments: Optional[Sequence[Tuple[int, SpecLike]]] = None,
                 **kw):
        super().__init__(**kw)
        if segments is None:
            segments = ((0, "breakhis"), (self.horizon // 2, "breach"))
        starts = [int(s) for s, _ in segments]
        if not starts or starts[0] != 0:
            raise ValueError("segments must start at slot 0")
        if any(b <= a for a, b in zip(starts, starts[1:])):
            raise ValueError(f"segment starts must strictly increase: {starts}")
        if starts[-1] >= self.horizon:
            raise ValueError(
                f"segment start {starts[-1]} is past the horizon {self.horizon}")
        per = [_as_params(sp) for _, sp in segments]
        self.segments = tuple((int(s), sp) for s, sp in segments)
        self.starts = jnp.asarray(starts, jnp.int32)
        self.params = {k: jnp.stack([p[k] for p in per]) for k in per[0]}

    def _slot(self, kt, t):
        idx = jnp.searchsorted(self.starts, t, side="right") - 1
        params_t = {k: v[idx] for k, v in self.params.items()}
        y, f = _confidence_slot(kt, params_t, self.n_streams)
        return f, y, y, self._draw_betas(kt, t)


@register_scenario("noisy_rdl")
class NoisyRDLSource(ScenarioSource):
    """Mismatched remote classifier: feedback labels from the RDL's own
    confusion spec instead of ground truth.

    `hrs` flips the true label with the RDL's conditional error rates
    (P(hr=0|y=1) = rdl_fn, P(hr=1|y=0) = rdl_fp) — either given directly or
    derived from a Table 2/3 `rdl_spec` (fn/p1, fp/(1−p1)). `ys` keeps the
    true label so simulation-grade accounting can separate what the policy
    believes (observed loss) from what it actually costs (true loss).
    """

    def __init__(self, spec: SpecLike = "synthetic",
                 rdl_spec: Optional[SpecLike] = None,
                 rdl_fn: float = 0.05, rdl_fp: float = 0.05, **kw):
        super().__init__(**kw)
        self.params = _as_params(spec)
        if rdl_spec is not None:
            rs = get_spec(rdl_spec) if isinstance(rdl_spec, str) else rdl_spec
            rdl_fn, rdl_fp = rs.fn / rs.p1, rs.fp / (1.0 - rs.p1)
        if not (0.0 <= rdl_fn < 1.0 and 0.0 <= rdl_fp < 1.0):
            raise ValueError(
                f"RDL error rates must lie in [0, 1): fn={rdl_fn}, fp={rdl_fp}")
        self.rdl_fn, self.rdl_fp = float(rdl_fn), float(rdl_fp)

    def _slot(self, kt, t):
        y, f = _confidence_slot(kt, self.params, self.n_streams)
        u = jax.random.uniform(jax.random.fold_in(kt, _K_RDL),
                               (self.n_streams,))
        flip = jnp.where(y == 1, u < self.rdl_fn, u < self.rdl_fp)
        hr = jnp.where(flip, 1 - y, y).astype(jnp.int32)
        return f, hr, y, self._draw_betas(kt, t)


@register_scenario("replay")
class ReplaySource(ScenarioSource):
    """Playback of an explicit recorded trace.

    Wraps given (S, T) arrays as a chunked source so a trace captured
    elsewhere — the request plane's per-round record, a saved materialized
    batch, real measurements — runs through every source-driven driver
    (`HIServer.run_source`, `engine.run_source`) unchanged. Emission is a
    `dynamic_slice` of the held arrays: trivially chunk-invariant, and the
    `key` only matters to the *driver*'s policy draws, not the data.
    """

    synthetic = False

    def __init__(self, fs, hrs, ys, betas, block: Optional[int] = None,
                 key: Optional[jax.Array] = None):
        fs = jnp.asarray(fs, jnp.float32)
        hrs = jnp.asarray(hrs, jnp.int32)
        ys = jnp.asarray(ys, jnp.int32)
        betas = jnp.asarray(betas, jnp.float32)
        if fs.ndim != 2 or not all(
                a.shape == fs.shape for a in (hrs, ys, betas)):
            raise ValueError(
                "replay arrays must share one (n_streams, horizon) shape; "
                f"got fs={fs.shape}, hrs={hrs.shape}, ys={ys.shape}, "
                f"betas={betas.shape}")
        super().__init__(n_streams=fs.shape[0], horizon=fs.shape[1],
                         block=block, key=key)
        self.trace = SlotBatch(fs=fs, hrs=hrs, ys=ys, betas=betas)

    def emit(self, state, key, slot):
        cut = lambda a: jax.lax.dynamic_slice_in_dim(
            a, slot * self.block, self.block, axis=1)
        return state, SlotBatch(*(cut(a) for a in self.trace))


@register_scenario("beta_process")
class BetaProcessSource(ScenarioSource):
    """Network-cost dynamics over a stationary confidence stream.

    beta_mode:
      "fixed"      — constant β (degenerate case, for sweeps).
      "uniform"    — β_t ~ U(0, β), the oblivious adversary.
      "sinusoidal" — β_t sweeps [beta_lo, beta] with period `period` slots
                     (diurnal congestion), identical across streams.
      "bursty"     — per-stream two-state Markov congestion: β jumps
                     beta_lo ↔ beta with transition probs p_up / p_down.
                     The regime vector is the carried generator state —
                     the reason `emit` threads `state` at all — and the
                     per-slot keying keeps even this stateful trace
                     bit-identical across block sizes.
    """

    BETA_MODES = ("fixed", "uniform", "sinusoidal", "bursty")

    def __init__(self, spec: SpecLike = "synthetic", beta_mode: str = "bursty",
                 beta_lo: float = 0.05, period: int = 512,
                 p_up: float = 0.05, p_down: float = 0.25, **kw):
        super().__init__(beta_mode=beta_mode, **kw)
        self.params = _as_params(spec)
        self.beta_lo = float(beta_lo)
        self.period = int(period)
        self.p_up, self.p_down = float(p_up), float(p_down)

    def init_state(self):
        if self.beta_mode == "bursty":
            return jnp.zeros((self.n_streams,), jnp.int32)   # all uncongested
        return ()

    def _slot(self, kt, t):
        y, f = _confidence_slot(kt, self.params, self.n_streams)
        if self.beta_mode == "sinusoidal":
            phase = 2.0 * jnp.pi * t / self.period
            val = self.beta_lo + 0.5 * (self.beta - self.beta_lo) * (
                1.0 + jnp.sin(phase))
            b = jnp.full((self.n_streams,), 1.0, jnp.float32) * val
        else:
            b = self._draw_betas(kt, t)
        return f, y, y, b

    def emit(self, state, key, slot):
        if self.beta_mode != "bursty":
            return super().emit(state, key, slot)
        key = jax.random.fold_in(key, _K_DOMAIN)
        ts = slot * self.block + jnp.arange(self.block, dtype=jnp.int32)

        def one(regime, t):
            kt = jax.random.fold_in(key, t)
            y, f = _confidence_slot(kt, self.params, self.n_streams)
            u = jax.random.uniform(jax.random.fold_in(kt, _K_REGIME),
                                   (self.n_streams,))
            regime = jnp.where(regime == 1,
                               (u >= self.p_down).astype(jnp.int32),
                               (u < self.p_up).astype(jnp.int32))
            b = jnp.where(regime == 1, self.beta, self.beta_lo
                          ).astype(jnp.float32)
            return regime, (f, y, b)

        state, (f, y, b) = jax.lax.scan(one, state, ts)
        tp = lambda a: jnp.swapaxes(a, 0, 1)
        return state, SlotBatch(fs=tp(f), hrs=tp(y), ys=tp(y), betas=tp(b))


# --------------------------------------------------------------------------
# Materialized-trace helpers (formerly `repro.data.streams`, which is now a
# deprecation shim over these). They run the matching scenario sources to
# completion, so there is a single generation path: the chunked
# per-slot-keyed draws. Prefer a ScenarioSource (and `run_fleet_source` /
# `HIServer.run_source`) for anything long-horizon or nonstationary; these
# exist for the paper figures and tests that need the whole trace at once.
# --------------------------------------------------------------------------


class Trace(NamedTuple):
    fs: jnp.ndarray      # (T,) or (S, T) LDL confidences in [0, 1)
    hrs: jnp.ndarray     # remote labels (ground-truth proxy), int32
    betas: jnp.ndarray   # offloading costs


def _to_trace(batch: SlotBatch, squeeze: bool) -> Trace:
    fs, hrs, betas = batch.fs, batch.hrs, batch.betas
    if squeeze:
        fs, hrs, betas = fs[0], hrs[0], betas[0]
    return Trace(fs=fs, hrs=hrs, betas=betas)


def sample_trace(
    spec: SpecLike,
    horizon: int,
    key: jax.Array,
    beta: float = 0.3,
    beta_mode: str = "fixed",
    n_streams: Optional[int] = None,
) -> Trace:
    """Materialized stationary trace of length `horizon` (optionally
    (n_streams, horizon)) — `StationarySource` run to completion.

    beta_mode: 'fixed' — constant β (paper's comparison study);
               'uniform' — β_t ~ U(0, β) oblivious adversary.
    """
    src = StationarySource(spec=spec, n_streams=n_streams or 1,
                           horizon=horizon, key=key, beta=beta,
                           beta_mode=beta_mode)
    return _to_trace(src.materialize(), squeeze=n_streams is None)


def dataset_trace(
    name: str, horizon: int, key: jax.Array, beta: float = 0.3, **kw
) -> Trace:
    return sample_trace(get_spec(name), horizon, key, beta=beta, **kw)


def empirical_confusion(trace) -> Tuple[float, float, float]:
    """(accuracy, fp, fn) of the argmax rule on a trace — sanity vs Table 2.

    Accepts a `Trace` or any (fs, hrs)-carrying batch (e.g. `SlotBatch`)."""
    pred1 = trace.fs >= 0.5
    fp = float(jnp.mean(pred1 & (trace.hrs == 0)))
    fn = float(jnp.mean(~pred1 & (trace.hrs == 1)))
    return 1.0 - fp - fn, fp, fn


def drift_trace(
    name_a: str,
    name_b: str,
    horizon: int,
    key: jax.Array,
    beta: float = 0.3,
    switch_at: Optional[int] = None,
) -> Trace:
    """Two-regime shift trace — the `piecewise` scenario's simplest schedule,
    kept for the distribution-shift robustness runs."""
    switch_at = horizon // 2 if switch_at is None else switch_at
    src = PiecewiseSource(segments=((0, name_a), (switch_at, name_b)),
                          horizon=horizon, key=key, beta=beta)
    return _to_trace(src.materialize(), squeeze=True)
