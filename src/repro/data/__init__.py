from repro.data.datasets import DATASETS, calibrate, get_spec
from repro.data.streams import (
    Trace,
    dataset_trace,
    drift_trace,
    empirical_confusion,
    sample_trace,
)
from repro.data.tokens import Batch, batch_iterator, classification_batch, synthetic_batch

__all__ = [
    "DATASETS", "calibrate", "get_spec",
    "Trace", "dataset_trace", "drift_trace", "empirical_confusion", "sample_trace",
    "Batch", "batch_iterator", "classification_batch", "synthetic_batch",
]
