"""Synthetic token pipeline for LM-backbone training/serving.

Deterministic, seedable, infinite stream of (tokens, labels) batches with a
Zipfian unigram marginal and a short-range Markov flavor — enough structure for
loss to decrease during the example training runs without external data.
"""
from __future__ import annotations

from typing import Iterator, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Batch(NamedTuple):
    tokens: jnp.ndarray       # (B, S) int32 inputs
    labels: jnp.ndarray       # (B, S) int32 next-token targets
    mask: jnp.ndarray         # (B, S) float32 loss mask (handles padded vocab)


def zipf_logits(vocab: int, alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / ranks**alpha
    return np.log(p / p.sum()).astype(np.float32)


def synthetic_batch(
    key: jax.Array,
    batch: int,
    seq: int,
    vocab: int,
    alpha: float = 1.1,
    markov_strength: float = 0.6,
) -> Batch:
    """One batch: Zipf draws, then each position copies its predecessor +1 (mod
    small window) with prob `markov_strength` — a learnable bigram pattern."""
    k1, k2 = jax.random.split(key)
    logits = jnp.asarray(zipf_logits(vocab, alpha))
    iid = jax.random.categorical(k1, logits, shape=(batch, seq + 1))
    keep = jax.random.bernoulli(k2, 1.0 - markov_strength, (batch, seq + 1))
    rolled = jnp.roll(iid, 1, axis=1)
    successor = (rolled + 1) % vocab
    toks = jnp.where(keep, iid, successor).astype(jnp.int32)
    return Batch(
        tokens=toks[:, :-1],
        labels=toks[:, 1:],
        mask=jnp.ones((batch, seq), jnp.float32),
    )


def batch_iterator(
    seed: int, batch: int, seq: int, vocab: int, **kw
) -> Iterator[Batch]:
    key = jax.random.PRNGKey(seed)
    while True:
        key, sub = jax.random.split(key)
        yield synthetic_batch(sub, batch, seq, vocab, **kw)


def classification_batch(
    key: jax.Array, batch: int, seq: int, vocab: int, sep_token: int = 7
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Binary-classification pretext: label = parity of sep_token count.

    Used to train the binary HI heads on top of LM backbones in the examples.
    """
    toks = jax.random.randint(key, (batch, seq), 0, vocab, jnp.int32)
    labels = (jnp.sum(toks == sep_token, axis=-1) % 2).astype(jnp.int32)
    return toks, labels
