"""Seeded open-loop traffic: arrival processes for the request plane.

Where `scenarios.py` generates *slot-synchronous* workloads ((S, block)
per emit), this module generates *asynchronous* ones: a single timeline of
per-request arrivals — interarrival gap, session id, LDL confidence, remote
label, ground truth, payload size — that the request-plane ingress replays
on the virtual clock (`serving.request_plane.serve_traffic`).

Two processes:

  "poisson" — memoryless arrivals at `rate` req/s, the open-loop baseline.
  "mmpp"    — Markov-modulated Poisson: a two-state chain (calm at `rate`,
              bursty at `burst_rate`, stepped once per arrival with
              p_burst/p_calm) — the arrival-side analogue of the
              `beta_process` bursty regime, for testing admission under
              load spikes.

Chunk-invariance contract (the `ScenarioSource` bit-identity contract,
restated for arrivals): every draw for absolute arrival i comes from
`fold_in(domain-separated key, i)` with a purpose tag per draw, and the
only carried state (the MMPP regime) threads through `emit`. The trace is
bit-identical for ANY chunk size, and `materialize()` is exactly the
concatenation of the chunks — so a load sweep is reproducible no matter
how the driver batches generation.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.data.scenarios import SpecLike, _as_params, _trunc_normal

# Purpose tags for the per-arrival key (one per draw; disjoint streams).
_A_GAP, _A_SESSION, _A_Y, _A_F1, _A_F0, _A_RDL, _A_PAYLOAD, _A_REGIME = \
    range(8)
# Domain separator: traffic draws stay disjoint from scenario draws and the
# policy's `source_slot_keys` tree even under one shared base key.
_T_DOMAIN = 0xA77A1F

PROCESSES = ("poisson", "mmpp")


class ArrivalBatch(NamedTuple):
    """One emitted chunk of the arrival timeline; every leaf is (chunk,)."""

    gaps: jnp.ndarray      # interarrival seconds (float32)
    sessions: jnp.ndarray  # session ids in [0, n_sessions) (int32)
    fs: jnp.ndarray        # LDL confidences in (0, 1) (float32)
    hrs: jnp.ndarray       # labels the remote model would return (int32)
    ys: jnp.ndarray        # ground truth (int32)
    payloads: jnp.ndarray  # request payload bytes (float32)


class TrafficProcess:
    """Seed-threaded arrival-process generator (chunked, chunk-invariant).

    Confidences/labels come from the same calibrated Table 2/3 `spec`
    machinery the scenarios use; `rdl_fn`/`rdl_fp` optionally decouple the
    remote label from ground truth (the `noisy_rdl` mismatch, per-request).
    Payloads jitter uniformly within ±`payload_jitter` of `payload_bytes`.
    """

    def __init__(self, process: str = "poisson", rate: float = 100.0,
                 n_arrivals: int = 1024, n_sessions: int = 16,
                 chunk: Optional[int] = None,
                 key: Optional[jax.Array] = None,
                 spec: SpecLike = "synthetic",
                 burst_rate: Optional[float] = None,
                 p_burst: float = 0.05, p_calm: float = 0.2,
                 payload_bytes: float = 4096.0, payload_jitter: float = 0.5,
                 rdl_fn: float = 0.0, rdl_fp: float = 0.0):
        chunk = n_arrivals if chunk is None else chunk
        if process not in PROCESSES:
            raise ValueError(f"unknown process {process!r}; expected one of "
                             f"{PROCESSES}")
        if rate <= 0:
            raise ValueError(f"rate must be positive (got {rate})")
        if n_arrivals < 1 or chunk < 1 or n_arrivals % chunk:
            raise ValueError(
                f"n_arrivals {n_arrivals} must be a positive multiple of "
                f"the chunk size {chunk}")
        if n_sessions < 1:
            raise ValueError(f"n_sessions must be ≥ 1 (got {n_sessions})")
        if not 0.0 <= payload_jitter <= 1.0:
            raise ValueError(
                f"payload_jitter must lie in [0, 1] (got {payload_jitter})")
        if not (0.0 <= rdl_fn < 1.0 and 0.0 <= rdl_fp < 1.0):
            raise ValueError(
                f"RDL error rates must lie in [0, 1): fn={rdl_fn}, fp={rdl_fp}")
        self.process = process
        self.rate = float(rate)
        self.burst_rate = float(4.0 * rate if burst_rate is None
                                else burst_rate)
        if self.burst_rate <= 0:
            raise ValueError(
                f"burst_rate must be positive (got {self.burst_rate})")
        self.p_burst, self.p_calm = float(p_burst), float(p_calm)
        self.n_arrivals = int(n_arrivals)
        self.chunk = int(chunk)
        self.n_sessions = int(n_sessions)
        self.key = jax.random.PRNGKey(0) if key is None else key
        self.params = _as_params(spec)
        self.payload_bytes = float(payload_bytes)
        self.payload_jitter = float(payload_jitter)
        self.rdl_fn, self.rdl_fp = float(rdl_fn), float(rdl_fp)

    @property
    def n_chunks(self) -> int:
        return self.n_arrivals // self.chunk

    def init_state(self):
        """Generator carry; the MMPP regime (0 calm / 1 burst), else ()."""
        if self.process == "mmpp":
            return jnp.zeros((), jnp.int32)
        return ()

    def _request(self, ki: jax.Array):
        """Everything about one arrival except its timing."""
        p = self.params
        session = jax.random.randint(
            jax.random.fold_in(ki, _A_SESSION), (), 0, self.n_sessions,
            jnp.int32)
        y = jax.random.bernoulli(
            jax.random.fold_in(ki, _A_Y), p["p1"], ()).astype(jnp.int32)
        f1 = _trunc_normal(jax.random.fold_in(ki, _A_F1),
                           p["mu1"], p["sigma1"], ())
        f0 = _trunc_normal(jax.random.fold_in(ki, _A_F0),
                           p["mu0"], p["sigma0"], ())
        f = jnp.where(y == 1, f1, f0).astype(jnp.float32)
        u = jax.random.uniform(jax.random.fold_in(ki, _A_RDL), ())
        flip = jnp.where(y == 1, u < self.rdl_fn, u < self.rdl_fp)
        hr = jnp.where(flip, 1 - y, y).astype(jnp.int32)
        uj = jax.random.uniform(jax.random.fold_in(ki, _A_PAYLOAD), (),
                                minval=-1.0, maxval=1.0)
        payload = (self.payload_bytes
                   * (1.0 + self.payload_jitter * uj)).astype(jnp.float32)
        return session, f, hr, y, payload

    def _gap(self, ki: jax.Array, rate) -> jnp.ndarray:
        u = jax.random.uniform(jax.random.fold_in(ki, _A_GAP), (),
                               minval=1e-12, maxval=1.0)
        return (-jnp.log(u) / rate).astype(jnp.float32)

    def emit(self, state, key: jax.Array, chunk_idx) -> Tuple[object,
                                                              ArrivalBatch]:
        """Emit chunk `chunk_idx` of the timeline; leaves (chunk,)."""
        key = jax.random.fold_in(key, _T_DOMAIN)
        idx = (chunk_idx * self.chunk
               + jnp.arange(self.chunk, dtype=jnp.int32))
        if self.process == "poisson":
            def one(i):
                ki = jax.random.fold_in(key, i)
                return (self._gap(ki, self.rate),) + self._request(ki)

            gap, session, f, hr, y, payload = jax.vmap(one)(idx)
            return state, ArrivalBatch(gap, session, f, hr, y, payload)

        def one(regime, i):
            ki = jax.random.fold_in(key, i)
            u = jax.random.uniform(jax.random.fold_in(ki, _A_REGIME), ())
            regime = jnp.where(regime == 1,
                               (u >= self.p_calm).astype(jnp.int32),
                               (u < self.p_burst).astype(jnp.int32))
            rate = jnp.where(regime == 1, self.burst_rate, self.rate)
            return regime, (self._gap(ki, rate),) + self._request(ki)

        state, (gap, session, f, hr, y, payload) = jax.lax.scan(
            one, state, idx)
        return state, ArrivalBatch(gap, session, f, hr, y, payload)

    def materialize(self, key: Optional[jax.Array] = None) -> ArrivalBatch:
        """All chunks concatenated into one (n_arrivals,) ArrivalBatch."""
        key = self.key if key is None else key

        def step(st, c):
            return self.emit(st, key, c)

        _, batches = jax.lax.scan(step, self.init_state(),
                                  jnp.arange(self.n_chunks))
        return jax.tree_util.tree_map(
            lambda a: a.reshape(self.n_arrivals), batches)
