"""AdamW + cosine schedule, implemented directly on parameter pytrees.

Optimizer state shares the parameter sharding (ZeRO-ish when params are
2D-sharded), m/v in float32 regardless of param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray


def init_opt_state(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(
    cfg: AdamWConfig, params: Any, grads: Any, state: OptState
) -> Tuple[Any, OptState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m / bc1, v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(m=new_m, v=new_v, step=step), metrics
