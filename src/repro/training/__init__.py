from repro.training.optimizer import AdamWConfig, OptState, apply_updates, init_opt_state
from repro.training.train_loop import (
    TrainState,
    build_eval_step,
    build_train_step,
    cross_entropy,
    loss_fn,
)
from repro.training import checkpoint

__all__ = [
    "AdamWConfig", "OptState", "TrainState", "apply_updates", "build_eval_step",
    "build_train_step", "checkpoint", "cross_entropy", "init_opt_state", "loss_fn",
]
