"""Train-step builder: LM cross-entropy (+ MoE aux loss) with optional remat."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward
from repro.models.transformer import RunFlags
from repro.training.optimizer import AdamWConfig, OptState, apply_updates


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray, vocab: int
) -> jnp.ndarray:
    """Mean masked CE. The gold logit is selected with an iota-compare
    select-reduce (fuses under GSPMD) instead of take_along_axis, which
    all-gathers the vocab-sharded logits."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), axis=-1)
    nll = logz - gold
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(
    params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
    flags: RunFlags, aux_weight: float = 0.01, unroll: bool = False,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = forward(params, cfg, batch, flags=flags, unroll=unroll)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:
        # VLM: logits cover [patches | text]; loss is on the text positions.
        logits = logits[:, -labels.shape[1]:, :]
    ce = cross_entropy(logits, labels, batch["mask"], cfg.vocab)
    total = ce + aux_weight * aux
    return total, {"ce": ce, "aux": aux}


def build_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    flags: RunFlags = RunFlags(mode="train"),
    unroll: bool = False,
    microbatches: int = 1,
) -> Callable:
    """Returns train_step(state, batch) → (state, metrics). jit/pjit-ready.

    microbatches > 1 runs gradient accumulation: the global batch is split on
    the leading axis and scanned, with a float32 grad accumulator — the
    standard production lever for activation memory (per-microbatch
    activations shrink by the factor; params/optimizer unchanged).
    """
    f = functools.partial(loss_fn, cfg=cfg, flags=flags, unroll=unroll)

    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: f(p, batch=batch), has_aux=True)(params)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        if microbatches == 1:
            (loss, parts), grads = grads_of(state.params, batch)
        else:
            split = {k: v.reshape(microbatches, v.shape[0] // microbatches,
                                  *v.shape[1:]) for k, v in batch.items()}

            def accum(carry, micro):
                gacc, lacc = carry
                (l, _), g = jax.checkpoint(grads_of)(state.params, micro)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l), None

            # Accumulate in the optimizer-m layout (ZeRO-2: 2D-sharded f32)
            # rather than the param layout — params may be model-only sharded
            # (11 GB/device fp32 accumulator on mixtral train otherwise).
            zeros = jax.tree.map(jnp.zeros_like, state.opt.m)
            (grads, loss), _ = jax.lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)), split)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            parts = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        params, opt, opt_metrics = apply_updates(opt_cfg, state.params, grads, state.opt)
        metrics = {"loss": loss, **parts, **opt_metrics}
        return TrainState(params=params, opt=opt), metrics

    return train_step


def build_eval_step(cfg: ModelConfig, flags: RunFlags = RunFlags(mode="train")):
    def eval_step(params, batch):
        loss, parts = loss_fn(params, cfg, batch, flags)
        return {"loss": loss, **parts}

    return eval_step
