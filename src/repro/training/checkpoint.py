"""Flat .npz checkpointing for parameter/optimizer pytrees (no orbax dep).

Pytree structure is encoded in the key names ('a/b/0/c'), restoring requires
a template pytree with matching structure (shapes/dtypes are validated).
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        arr = np.asarray(tree)
        if arr.dtype == jnp.bfloat16:
            out[prefix[:-1] + "@bf16"] = arr.view(np.uint16)
        else:
            out[prefix[:-1]] = arr
    return out


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore(path: str, template: Any) -> Any:
    data = np.load(path)
    flat: Dict[str, np.ndarray] = {}
    for k in data.files:
        if k.endswith("@bf16"):
            flat[k[:-5]] = data[k].view(jnp.bfloat16)
        else:
            flat[k] = data[k]

    def rebuild(tree: Any, prefix: str = ""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if hasattr(tree, "_fields"):
            return type(tree)(*[rebuild(getattr(tree, k), f"{prefix}{k}/")
                                for k in tree._fields])
        if isinstance(tree, (list, tuple)):
            return type(tree)(rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tree))
        key = prefix[:-1]
        arr = flat[key]
        tmpl = np.asarray(tree)
        if arr.shape != tmpl.shape:
            raise ValueError(f"{key}: shape {arr.shape} != template {tmpl.shape}")
        return jnp.asarray(arr)

    return rebuild(template)
