"""Offload-aware hierarchical-inference server: the paper's system (Fig. 1)
with the remote model paid only for offloaded samples.

Per time slot, for a fleet of edge streams, `serve_slot` runs a two-phase
decide/feedback flow on a `PolicyEngine`:

  1. apply the *previous* slot's RDL results as delayed feedback
     (`engine.feedback`) — the double-buffer: slot t's remote results update
     the expert weights at slot t+1, so edge rounds never block on remote
     inference,
  2. every sample runs the LDL classifier → confidence f_t,
  3. the policy decides offload / local-predict (`engine.decide`) — no label
     is consumed here,
  4. ONLY the offloaded samples are compacted (`compact_offloads`) into one
     fixed-capacity RDL batch; the RDL never sees a non-offloaded sample,
  5. RDL labels scatter back to their source streams (`scatter_results`) and
     are buffered as the next slot's feedback; offloads dropped by capacity
     overflow revert to their local prediction and pay nothing.

The slot's observable cost is β_t per sample actually offloaded; local
misclassification cost is unobservable online (no ground truth at the edge —
use `PolicyEngine.run` for simulation-grade accounting). The run summary
reports the RDL savings versus the old evaluate-everything server two ways:
`rdl_eval_rate` (samples whose labels the remote model produced) and
`rdl_row_savings` (actual compute rows, counting the capacity padding each
launch carries). Capacity overflow drops rotate with the slot index so
sustained overload cannot starve a fixed set of streams.

Engines (`HIServerConfig.engine`): "fused" (default, kernel-backed),
"reference" (paper-shaped vmapped `h2t2_step`), "sharded" (fleet sharded
over a device mesh). All consume identical per-stream keys, so the serving
decisions do not depend on the engine choice.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import FleetDecision, HIConfig
from repro.core.policy import H2T2State, effective_local_pred
from repro.serving.batching import compact_offloads, scatter_results
from repro.serving.policy_engine import get_engine


@dataclasses.dataclass(frozen=True)
class HIServerConfig:
    n_streams: int = 8
    hi: HIConfig = HIConfig()
    engine: str = "fused"              # PolicyEngine registry name
    interpret: Optional[bool] = None   # kernel interpret override (fused/sharded)
    # RDL batch capacity per slot; None → n_streams (padded, never drops).
    offload_capacity: Optional[int] = None

    def __post_init__(self):
        if self.offload_capacity is not None and self.offload_capacity < 1:
            raise ValueError(
                f"offload_capacity must be ≥ 1 (got {self.offload_capacity}); "
                "use None for the n_streams default")

    @property
    def capacity(self) -> int:
        return (self.offload_capacity if self.offload_capacity is not None
                else self.n_streams)


class PendingFeedback(NamedTuple):
    """Slot t's offload outcome, waiting to update weights at slot t+1."""

    decision: FleetDecision   # leaves (S,)
    hrs: jnp.ndarray          # (S,) int32 — scattered RDL labels (0 where ~sent)
    sent: jnp.ndarray         # (S,) bool — offloaded AND within capacity
    betas: jnp.ndarray        # (S,)


class HIServerState(NamedTuple):
    policy: H2T2State         # vmapped over streams
    t: jnp.ndarray
    total_loss: jnp.ndarray       # Σ β over samples actually offloaded
    total_offloads: jnp.ndarray   # samples actually served remotely
    total_dropped: jnp.ndarray    # offload decisions dropped by capacity
    rdl_evals: jnp.ndarray        # valid samples evaluated by the RDL
    rdl_batches: jnp.ndarray      # RDL launches (≤ 1 per slot)
    pending: Optional[PendingFeedback]   # None until the first slot completes


class SlotResult(NamedTuple):
    f: jnp.ndarray          # (S,) LDL confidences
    offload: jnp.ndarray    # (S,) bool — the policy's offload decision
    sent: jnp.ndarray       # (S,) bool — decision AND within RDL capacity
    pred: jnp.ndarray       # (S,) final predictions (RDL label where sent)
    loss: jnp.ndarray       # (S,) observable cost (β where sent, else 0)


class HIServer:
    """Orchestrates LDL (edge) and RDL (server) classifiers around H2T2."""

    def __init__(
        self,
        cfg: HIServerConfig,
        ldl: Callable[[jnp.ndarray], jnp.ndarray],   # tokens (S, L) → f (S,)
        rdl: Callable[[jnp.ndarray], jnp.ndarray],   # tokens (C, L) → labels (C,)
    ):
        self.cfg = cfg
        self.ldl = ldl
        self.rdl = rdl
        self.engine = get_engine(cfg.engine, cfg.hi, interpret=cfg.interpret)

    def init_state(self) -> HIServerState:
        zero = jnp.zeros((), jnp.float32)
        izero = jnp.zeros((), jnp.int32)
        return HIServerState(
            policy=self.engine.init(self.cfg.n_streams),
            t=izero, total_loss=zero, total_offloads=zero,
            total_dropped=zero, rdl_evals=izero, rdl_batches=izero,
            pending=None)

    def _apply_pending(self, state: HIServerState) -> H2T2State:
        """Fold the buffered slot-(t-1) RDL results into the policy weights."""
        if state.pending is None:
            return state.policy
        pf = state.pending
        policy, _ = self.engine.feedback(
            state.policy, pf.decision, pf.hrs, pf.betas, sent=pf.sent)
        return policy

    def serve_slot(
        self,
        state: HIServerState,
        tokens: jnp.ndarray,        # (S, L) one sample per stream
        betas: jnp.ndarray,         # (S,)
        key: jax.Array,
    ) -> Tuple[HIServerState, SlotResult]:
        s = self.cfg.n_streams
        cap = self.cfg.capacity
        # Phase 0: delayed feedback from the previous slot's RDL batch.
        policy = self._apply_pending(state)
        # Phase 1: edge inference + offload decisions (label-free).
        fs = self.ldl(tokens)                                # (S,)
        keys = jax.random.split(key, s)
        decision = self.engine.decide(policy, fs, keys)
        # Phase 2: compact ONLY the offloaded samples into one RDL batch.
        # Compaction keeps the first `cap` offloads in order, which would
        # permanently starve high-index streams under sustained overload —
        # when drops are possible, rotate the start index by the slot count
        # so they share the pain. At full capacity rotation cannot change
        # the outcome, so skip its gathers on the hot path.
        if cap < s:
            rot = (jnp.arange(s) + state.t % s) % s
            batch = compact_offloads(tokens[rot], decision.offload[rot], cap)
            batch = batch._replace(src=jnp.where(
                batch.valid, rot[batch.src], -1).astype(jnp.int32))
        else:
            batch = compact_offloads(tokens, decision.offload, cap)
        n_valid = int(jnp.sum(batch.valid))
        if n_valid:
            labels = self.rdl(batch.tokens).astype(jnp.int32)     # (C,)
        else:
            labels = jnp.zeros((cap,), jnp.int32)                 # RDL skipped
        hrs = scatter_results(labels, batch, s, fill=0)
        sent = scatter_results(
            batch.valid.astype(jnp.int32), batch, s, fill=0).astype(bool)
        # Offloads beyond capacity were never sent: fall back to a local
        # prediction (the conditional draw — see `local_fallback_pred`), no β.
        dropped = decision.offload & ~sent
        pred = jnp.where(sent, hrs, effective_local_pred(decision, sent))
        loss = jnp.where(sent, betas, 0.0)

        new_state = HIServerState(
            policy=policy,
            t=state.t + 1,
            total_loss=state.total_loss + jnp.sum(loss),
            total_offloads=state.total_offloads + jnp.sum(sent),
            total_dropped=state.total_dropped + jnp.sum(dropped),
            rdl_evals=state.rdl_evals + n_valid,
            rdl_batches=state.rdl_batches + (1 if n_valid else 0),
            pending=PendingFeedback(decision=decision, hrs=hrs, sent=sent,
                                    betas=betas),
        )
        return new_state, SlotResult(f=fs, offload=decision.offload,
                                     sent=sent, pred=pred, loss=loss)

    def flush(self, state: HIServerState) -> HIServerState:
        """Apply any still-buffered feedback (end of a serving run)."""
        policy = self._apply_pending(state)
        return state._replace(policy=policy, pending=None)

    def run(
        self,
        token_stream: jnp.ndarray,   # (T, S, L)
        betas: jnp.ndarray,          # (T, S)
        key: jax.Array,
    ) -> Tuple[HIServerState, Dict[str, float]]:
        state = self.init_state()
        horizon = token_stream.shape[0]
        for t in range(horizon):
            key, sub = jax.random.split(key)
            state, _ = self.serve_slot(state, token_stream[t], betas[t], sub)
        state = self.flush(state)
        n = horizon * self.cfg.n_streams
        rdl_evals = int(state.rdl_evals)
        # Each launch is capacity-padded, so the remote model also computes
        # the padding rows — report both the sample-level savings and the
        # actual compute rows so neither can be mistaken for the other.
        rdl_rows = int(state.rdl_batches) * self.cfg.capacity
        return state, {
            "avg_offload_cost": float(state.total_loss) / n,
            "offload_rate": float(state.total_offloads) / n,
            "drop_rate": float(state.total_dropped) / n,
            "rdl_evals": float(rdl_evals),
            "rdl_eval_rate": rdl_evals / n,
            "rdl_savings": 1.0 - rdl_evals / n,
            "rdl_batches": float(state.rdl_batches),
            "rdl_compute_rows": float(rdl_rows),
            "rdl_row_savings": 1.0 - rdl_rows / n,
        }
