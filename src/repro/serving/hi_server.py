"""Offload-aware hierarchical-inference server: the paper's system (Fig. 1)
with the remote model paid only for offloaded samples.

Per time slot, for a fleet of edge streams, `serve_slot` runs a two-phase
decide/feedback flow on a `PolicyEngine`:

  1. apply the *previous* slot's RDL results as delayed feedback
     (`engine.feedback`) — the double-buffer: slot t's remote results update
     the expert weights at slot t+1, so edge rounds never block on remote
     inference,
  2. every sample runs the LDL classifier → confidence f_t,
  3. the policy decides offload / local-predict (`engine.decide`) — no label
     is consumed here,
  4. ONLY the offloaded samples are compacted (`compact_offloads`) into one
     fixed-capacity RDL batch; the RDL never sees a non-offloaded sample,
  5. RDL labels scatter back to their source streams (`scatter_results`) and
     are buffered as the next slot's feedback; offloads dropped by capacity
     overflow revert to their local prediction and pay nothing.

The slot's observable cost is β_t per sample actually offloaded; local
misclassification cost is unobservable online (no ground truth at the edge —
use `PolicyEngine.run` for simulation-grade accounting). The run summary
reports the RDL savings versus the old evaluate-everything server two ways:
`rdl_eval_rate` (samples whose labels the remote model produced) and
`rdl_row_savings` (actual compute rows, counting the capacity padding each
launch carries). Capacity overflow drops rotate with the slot index so
sustained overload cannot starve a fixed set of streams.

Engines (`HIServerConfig.engine`): "fused" (default, kernel-backed),
"reference" (paper-shaped vmapped `h2t2_step`), "sharded" (fleet sharded
over a device mesh), "adaptive" (detect → adapt → restart). All consume
identical per-stream keys, so the serving decisions do not depend on the
engine choice. On every engine but "reference", `serve_slot`'s two phases
run the split-phase Pallas kernels (`hedge_decide_pallas` /
`hedge_feedback_pallas`) — kernel on TPU, jnp oracle elsewhere,
`interpret=True` forcing the kernel on CPU — and `run_source` additionally
drives the multi-round kernel in `time_block`-slot chains wherever the
double-buffered feedback permits (see `rounds_eligible`).

Source-driven serving: `run_source` serves a whole `ScenarioSource` horizon
without ever materializing the (S, T) trace — each slot block is emitted on
device, the block's slots run as one `lax.scan` of the identical
decide/compact/feedback flow, and only per-run counters leave the device.
The source plays both classifier roles (fs = LDL confidences, hrs = RDL
labels); its `ys` stay separate so the summary can report ground-truth cost
next to what the policy observes. Peak trace residency is one (S, block)
SlotBatch at any horizon.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import FleetDecision, HIConfig
from repro.core.counter import CounterRNG, check_randomness_mode, seed_from_key
from repro.core.execspec import ExecSpec
from repro.core.policy import (
    H2T2State,
    classification_cost,
    draw_psi_zeta,
    effective_local_pred,
    fleet_rounds_fused,
    source_slot_keys,
)
from repro.data.scenarios import ScenarioSource
from repro.serving.batching import (
    OffloadBatch,
    compact_offloads,
    scatter_results,
)
from repro.serving.policy_engine import get_engine


@dataclasses.dataclass(frozen=True)
class HIServerConfig:
    n_streams: int = 8
    hi: HIConfig = HIConfig()
    engine: str = "fused"              # PolicyEngine registry name
    # Preferred: one ExecSpec carrying all execution knobs (learner,
    # use_kernel, interpret, randomness, stream_block, time_block). When
    # given, the legacy mirror fields below are synced from it; when None,
    # the spec is assembled from the legacy fields (which default to the
    # pre-ExecSpec behavior).
    spec: Optional[ExecSpec] = None
    interpret: Optional[bool] = None   # kernel interpret override
    use_kernel: Optional[bool] = None  # kernel routing override (None = auto)
    # Policy randomness: "pre_draw" (per-stream slot keys, the golden paper
    # path) or "counter" (in-place counter draws at (seed, stream, slot) —
    # no key tree, no materialized ψ/ζ; see `core.counter`).
    randomness: str = "pre_draw"
    # RDL batch capacity per slot; None → n_streams (padded, never drops).
    offload_capacity: Optional[int] = None
    # Multi-round serving: `run_source` drives the multi-round hedge kernel
    # in `time_block`-slot chains wherever the double-buffered feedback
    # cannot diverge from the monolithic H2T2 chain (see `run_source`).
    # None/1 → the slot-by-slot decide/compact/feedback scan.
    time_block: Optional[int] = None

    def __post_init__(self):
        if self.spec is None:
            check_randomness_mode(self.randomness)
            object.__setattr__(self, "spec", ExecSpec(
                use_kernel=self.use_kernel, interpret=self.interpret,
                randomness=self.randomness, time_block=self.time_block))
        else:
            # Keep the legacy mirror fields readable (serve_slot and the
            # serving paths still consult cfg.randomness / cfg.time_block).
            object.__setattr__(self, "interpret", self.spec.interpret)
            object.__setattr__(self, "use_kernel", self.spec.use_kernel)
            object.__setattr__(self, "randomness", self.spec.randomness)
            object.__setattr__(self, "time_block", self.spec.time_block)
        if self.offload_capacity is not None and self.offload_capacity < 1:
            raise ValueError(
                f"offload_capacity must be ≥ 1 (got {self.offload_capacity}); "
                "use None for the n_streams default")
        if self.time_block is not None and self.time_block < 1:
            raise ValueError(
                f"time_block must be ≥ 1 (got {self.time_block}); use None "
                "for slot-by-slot serving")

    @property
    def capacity(self) -> int:
        return (self.offload_capacity if self.offload_capacity is not None
                else self.n_streams)


class PendingFeedback(NamedTuple):
    """Slot t's offload outcome, waiting to update weights at slot t+1."""

    decision: FleetDecision   # leaves (S,)
    hrs: jnp.ndarray          # (S,) int32 — scattered RDL labels (0 where ~sent)
    sent: jnp.ndarray         # (S,) bool — offloaded AND within capacity
    betas: jnp.ndarray        # (S,)


class HIServerState(NamedTuple):
    policy: H2T2State         # vmapped over streams
    t: jnp.ndarray
    total_loss: jnp.ndarray       # Σ β over samples actually offloaded
    total_offloads: jnp.ndarray   # samples actually served remotely
    total_dropped: jnp.ndarray    # offload decisions dropped by capacity
    rdl_evals: jnp.ndarray        # valid samples evaluated by the RDL
    rdl_batches: jnp.ndarray      # RDL launches (≤ 1 per slot)
    pending: Optional[PendingFeedback]   # None until the first slot completes


def rotated_compact(payload: jnp.ndarray, offload: jnp.ndarray,
                    capacity: int, t) -> "OffloadBatch":
    """Compact offloaded rows into one RDL batch, rotating the drop priority.

    Compaction keeps the first `capacity` offloads in order, which would
    permanently starve high-index streams under sustained overload — when
    drops are possible, rotate the start index by the slot count `t` so they
    share the pain. At full capacity rotation cannot change the outcome, so
    skip its gathers on the hot path. Shared by the token-serving
    `serve_slot`, the source-serving scan, and the request plane's
    micro-batcher so all three drop identically.
    """
    s = payload.shape[0]
    if capacity >= s:
        return compact_offloads(payload, offload, capacity)
    rot = (jnp.arange(s) + t % s) % s
    batch = compact_offloads(payload[rot], offload[rot], capacity)
    return batch._replace(src=jnp.where(
        batch.valid, rot[batch.src], -1).astype(jnp.int32))


def _looks_like_prng_key(x) -> bool:
    """Whether `x` is plausibly a JAX PRNG key (typed key array, or the raw
    uint32 (2,) representation) rather than, say, a (T, S) beta matrix."""
    dtype = getattr(x, "dtype", None)
    if dtype is None:
        return False
    if jnp.issubdtype(dtype, jax.dtypes.prng_key):
        return True
    return dtype == jnp.uint32 and getattr(x, "shape", None) == (2,)


class _ServeCounters(NamedTuple):
    """Scalar accumulators of a source-driven serving run (device-resident)."""

    loss: jnp.ndarray          # Σ β over samples actually offloaded
    true_loss: jnp.ndarray     # Σ β·sent + φ(final pred, ground truth)
    offloads: jnp.ndarray      # int32 — samples actually served remotely
    dropped: jnp.ndarray       # int32 — offload decisions dropped by capacity
    rdl_evals: jnp.ndarray     # int32 — valid samples evaluated by the RDL
    rdl_batches: jnp.ndarray   # int32 — RDL launches (≤ 1 per slot)
    correct: jnp.ndarray       # int32 — final predictions matching ground truth


class SlotResult(NamedTuple):
    f: jnp.ndarray          # (S,) LDL confidences
    offload: jnp.ndarray    # (S,) bool — the policy's offload decision
    sent: jnp.ndarray       # (S,) bool — decision AND within RDL capacity
    pred: jnp.ndarray       # (S,) final predictions (RDL label where sent)
    loss: jnp.ndarray       # (S,) observable cost (β where sent, else 0)


class HIServer:
    """Orchestrates LDL (edge) and RDL (server) classifiers around H2T2."""

    def __init__(
        self,
        cfg: HIServerConfig,
        ldl: Callable[[jnp.ndarray], jnp.ndarray],   # tokens (S, L) → f (S,)
        rdl: Callable[[jnp.ndarray], jnp.ndarray],   # tokens (C, L) → labels (C,)
    ):
        self.cfg = cfg
        self.ldl = ldl
        self.rdl = rdl
        self.engine = get_engine(cfg.engine, cfg.hi, spec=cfg.spec)
        self._serve_block = None    # jitted source-serving scan, built lazily
        self._serve_rounds = None   # jitted multi-round block fn, built lazily

    def init_state(self) -> HIServerState:
        zero = jnp.zeros((), jnp.float32)
        izero = jnp.zeros((), jnp.int32)
        return HIServerState(
            policy=self.engine.init(self.cfg.n_streams),
            t=izero, total_loss=zero, total_offloads=zero,
            total_dropped=zero, rdl_evals=izero, rdl_batches=izero,
            pending=None)

    def _apply_pending(self, state: HIServerState) -> H2T2State:
        """Fold the buffered slot-(t-1) RDL results into the policy weights."""
        if state.pending is None:
            return state.policy
        pf = state.pending
        policy, _ = self.engine.feedback(
            state.policy, pf.decision, pf.hrs, pf.betas, sent=pf.sent)
        return policy

    def serve_slot(
        self,
        state: HIServerState,
        tokens: jnp.ndarray,        # (S, L) one sample per stream
        betas: jnp.ndarray,         # (S,)
        key: jax.Array,
    ) -> Tuple[HIServerState, SlotResult]:
        s = self.cfg.n_streams
        cap = self.cfg.capacity
        # Phase 0: delayed feedback from the previous slot's RDL batch.
        policy = self._apply_pending(state)
        # Phase 1: edge inference + offload decisions (label-free).
        fs = self.ldl(tokens)                                # (S,)
        if self.cfg.randomness == "counter":
            # Counter mode consumes the slot key directly as the seed and
            # draws at (seed, stream, slot=t) — no per-stream key split.
            decision = self.engine.decide(policy, fs, key, slot=state.t)
        else:
            keys = jax.random.split(key, s)
            decision = self.engine.decide(policy, fs, keys)
        # Phase 2: compact ONLY the offloaded samples into one RDL batch
        # (rotating the drop priority when capacity can overflow).
        batch = rotated_compact(tokens, decision.offload, cap, state.t)
        n_valid = int(jnp.sum(batch.valid))
        if n_valid:
            labels = self.rdl(batch.tokens).astype(jnp.int32)     # (C,)
        else:
            labels = jnp.zeros((cap,), jnp.int32)                 # RDL skipped
        hrs = scatter_results(labels, batch, s, fill=0)
        sent = scatter_results(
            batch.valid.astype(jnp.int32), batch, s, fill=0).astype(bool)
        # Offloads beyond capacity were never sent: fall back to a local
        # prediction (the conditional draw — see `local_fallback_pred`), no β.
        dropped = decision.offload & ~sent
        pred = jnp.where(sent, hrs, effective_local_pred(decision, sent))
        loss = jnp.where(sent, betas, 0.0)

        new_state = HIServerState(
            policy=policy,
            t=state.t + 1,
            total_loss=state.total_loss + jnp.sum(loss),
            total_offloads=state.total_offloads + jnp.sum(sent),
            total_dropped=state.total_dropped + jnp.sum(dropped),
            rdl_evals=state.rdl_evals + n_valid,
            rdl_batches=state.rdl_batches + (1 if n_valid else 0),
            pending=PendingFeedback(decision=decision, hrs=hrs, sent=sent,
                                    betas=betas),
        )
        return new_state, SlotResult(f=fs, offload=decision.offload,
                                     sent=sent, pred=pred, loss=loss)

    def flush(self, state: HIServerState) -> HIServerState:
        """Apply any still-buffered feedback (end of a serving run)."""
        policy = self._apply_pending(state)
        return state._replace(policy=policy, pending=None)

    def _serve_block_fn(self):
        """The jitted per-block serving scan, built once per server instance
        (jit's own cache handles distinct (S, block) shapes), so repeated
        `run_source` calls never re-trace. Each scanned slot replays
        `serve_slot`'s flow with the source standing in for both
        classifiers."""
        if self._serve_block is not None:
            return self._serve_block
        eng, hi, cap = self.engine, self.cfg.hi, self.cfg.capacity

        def slot(key, carry, xs):
            pol, pending, has_pending, t, acc = carry
            f, hr, y, beta = xs
            s = f.shape[0]
            # Phase 0: previous slot's RDL results (double-buffered).
            pol = jax.lax.cond(
                has_pending,
                lambda p: eng.feedback(p, pending.decision, pending.hrs,
                                       pending.betas, sent=pending.sent)[0],
                lambda p: p, pol)
            # Phase 1: offload decisions, label-free.
            if eng.randomness == "counter":
                dec = eng.decide(pol, f, key, slot=t)
            else:
                dec = eng.decide(pol, f, source_slot_keys(key, t, s))
            # Phase 2: offload-only RDL batch over the remote labels; the
            # per-slot payload is the (S, 1) label column, so compaction,
            # capacity, and rotation behave exactly as with real tokens.
            batch = rotated_compact(hr[:, None], dec.offload, cap, t)
            labels = batch.tokens[:, 0]            # the RDL lookup
            hrs_back = scatter_results(labels, batch, s, fill=0)
            sent = scatter_results(
                batch.valid.astype(jnp.int32), batch, s, fill=0).astype(bool)
            n_valid = jnp.sum(batch.valid.astype(jnp.int32))
            dropped = dec.offload & ~sent
            pred = jnp.where(sent, hrs_back, effective_local_pred(dec, sent))
            loss = jnp.where(sent, beta, 0.0)
            phi_true = classification_cost(hi, pred, y)
            acc = _ServeCounters(
                loss=acc.loss + jnp.sum(loss),
                true_loss=acc.true_loss + jnp.sum(loss + phi_true),
                offloads=acc.offloads + jnp.sum(sent.astype(jnp.int32)),
                dropped=acc.dropped + jnp.sum(dropped.astype(jnp.int32)),
                rdl_evals=acc.rdl_evals + n_valid,
                rdl_batches=acc.rdl_batches + (n_valid > 0).astype(jnp.int32),
                correct=acc.correct + jnp.sum((pred == y).astype(jnp.int32)))
            pending = PendingFeedback(decision=dec, hrs=hrs_back, sent=sent,
                                      betas=beta)
            return (pol, pending, jnp.asarray(True), t + 1, acc), None

        @jax.jit
        def serve_block(pol, pending, has_pending, t0, acc, key, batch):
            tp = lambda a: jnp.swapaxes(a, 0, 1)
            carry, _ = jax.lax.scan(
                lambda c, xs: slot(key, c, xs),
                (pol, pending, has_pending, t0, acc),
                (tp(batch.fs), tp(batch.hrs), tp(batch.ys), tp(batch.betas)))
            return carry

        self._serve_block = serve_block
        return serve_block

    # ----------------------- multi-round serving fast path --------------------

    def rounds_eligible(self, source: ScenarioSource) -> bool:
        """True when `run_source` may serve whole `time_block`-slot chains
        through the multi-round hedge kernel instead of the slot-by-slot
        decide/compact/feedback scan.

        The chain is valid exactly when the double-buffered serving flow
        cannot diverge from the monolithic H2T2 chain: decide at slot t sees
        feedback through t-1 either way, so the two agree as long as (1) no
        offload can be capacity-dropped (`sent` ≡ the offload decision:
        capacity ≥ n_streams), (2) the engine's slot semantics ARE the
        monolithic chain with a block-constant schedule
        (`monolithic_rounds` — fused yes; adaptive updates its detector and
        schedule every slot, sharded splits streams, so both serve
        slot-by-slot), and (3) the source block divides into time blocks.
        """
        tb = self.cfg.time_block or 0
        return (tb > 1
                and getattr(self.engine, "monolithic_rounds", False)
                and self.cfg.capacity >= self.cfg.n_streams
                and source.block % tb == 0)

    def _serve_rounds_fn(self):
        """The jitted multi-round serving block: chains of `time_block` slots
        through `fleet_rounds_fused` (the multi-round Pallas kernel on the
        kernel path), with counters accumulated in the slot path's exact
        addition order so the two paths' summaries match bit-for-bit."""
        if self._serve_rounds is not None:
            return self._serve_rounds
        hi, tb = self.cfg.hi, self.cfg.time_block
        eng = self.engine
        espec = eng._exec_spec()

        @jax.jit
        def serve_rounds_block(pol, t0, acc, key, batch):
            s, block = batch.fs.shape
            n_chunks = block // tb
            blocked = lambda a: jnp.swapaxes(
                a.reshape(s, n_chunks, tb), 0, 1)
            xs = tuple(blocked(a)
                       for a in (batch.fs, batch.hrs, batch.ys, batch.betas))

            def chunk(carry, xs_):
                st, t, acc = carry
                f, hr, y, beta = xs_                          # (S, tb) each
                if eng.randomness == "counter":
                    rng = CounterRNG(seed=seed_from_key(key),
                                     slot=jnp.asarray(t, jnp.int32),
                                     stream_offset=jnp.zeros((), jnp.int32))
                    st, out = fleet_rounds_fused(
                        hi, st, f, None, None, hr, beta, rng=rng, spec=espec)
                else:
                    ts = t + jnp.arange(tb, dtype=jnp.int32)
                    keys = jax.vmap(
                        lambda ti: source_slot_keys(key, ti, s))(ts)
                    psi, zeta = jax.vmap(
                        lambda k: draw_psi_zeta(k, hi.eps))(keys)  # (tb, S)
                    tp = lambda a: jnp.swapaxes(a, 0, 1)
                    st, out = fleet_rounds_fused(
                        hi, st, f, tp(psi), tp(zeta), hr, beta, spec=espec)
                # Serving accounting: β where offloaded (nothing can be
                # dropped on this path), remote label as the prediction.
                obs = jnp.where(out.offload, beta, 0.0)
                phi_true = classification_cost(hi, out.pred, y)
                slot_obs = jnp.sum(obs, axis=0)               # (tb,)
                slot_true = jnp.sum(obs + phi_true, axis=0)
                (loss_acc, true_acc), _ = jax.lax.scan(
                    lambda a, x: ((a[0] + x[0], a[1] + x[1]), None),
                    (acc.loss, acc.true_loss), (slot_obs, slot_true))
                offl = out.offload.astype(jnp.int32)
                acc = _ServeCounters(
                    loss=loss_acc, true_loss=true_acc,
                    offloads=acc.offloads + jnp.sum(offl),
                    dropped=acc.dropped,
                    rdl_evals=acc.rdl_evals + jnp.sum(offl),
                    rdl_batches=acc.rdl_batches + jnp.sum(
                        jnp.any(out.offload, axis=0).astype(jnp.int32)),
                    correct=acc.correct + jnp.sum(
                        (out.pred == y).astype(jnp.int32)))
                return (st, t + tb, acc), None

            (pol, t, acc), _ = jax.lax.scan(chunk, (pol, t0, acc), xs)
            return pol, t, acc

        self._serve_rounds = serve_rounds_block
        return serve_rounds_block

    def _run_source_rounds(
        self, source: ScenarioSource, key: jax.Array,
    ) -> Tuple[HIServerState, Dict[str, float]]:
        """`run_source` served as multi-round kernel chains (see
        `rounds_eligible` for when this is exact). The final slot's feedback
        is applied inside the last chain, which is precisely the slot path's
        end-of-run flush."""
        serve_rounds = self._serve_rounds_fn()
        izero = jnp.zeros((), jnp.int32)
        fzero = jnp.zeros((), jnp.float32)
        pol = self.engine.init(self.cfg.n_streams)
        t, acc, sst = izero, _ServeCounters(fzero, fzero, *([izero] * 5)), \
            source.init_state()
        for blk in range(source.n_blocks):
            sst, batch = source.emit(sst, source.key, blk)
            pol, t, acc = serve_rounds(pol, t, acc, key, batch)
        state = HIServerState(
            policy=pol, t=t,
            total_loss=acc.loss,
            total_offloads=acc.offloads.astype(jnp.float32),
            total_dropped=acc.dropped.astype(jnp.float32),
            rdl_evals=acc.rdl_evals, rdl_batches=acc.rdl_batches,
            pending=None)
        return state, self._source_summary(acc, source.horizon)

    def _source_summary(self, acc: _ServeCounters, horizon: int
                        ) -> Dict[str, float]:
        """The `run_source` summary dict, shared by both serving paths."""
        n = horizon * self.cfg.n_streams
        rdl_evals = int(acc.rdl_evals)
        rdl_rows = int(acc.rdl_batches) * self.cfg.capacity
        return {
            "avg_offload_cost": float(acc.loss) / n,
            "offload_rate": float(acc.offloads) / n,
            "drop_rate": float(acc.dropped) / n,
            "rdl_evals": float(rdl_evals),
            "rdl_eval_rate": rdl_evals / n,
            "rdl_savings": 1.0 - rdl_evals / n,
            "rdl_batches": float(acc.rdl_batches),
            "rdl_compute_rows": float(rdl_rows),
            "rdl_row_savings": 1.0 - rdl_rows / n,
            # Simulation-grade fields a real server could not observe:
            "avg_true_cost": float(acc.true_loss) / n,
            "accuracy": float(acc.correct) / n,
        }

    def run_source(
        self,
        source: ScenarioSource,
        key: jax.Array,
    ) -> Tuple[HIServerState, Dict[str, float]]:
        """Serve a whole `ScenarioSource` horizon, one slot block at a time.

        The flow per slot is exactly `serve_slot`'s — delayed double-buffered
        feedback, offload-only compaction at `capacity`, rotation under
        overflow — but each block runs as a single on-device `lax.scan`, so
        the (S, T) trace is never materialized: the host loop only threads
        the policy state, the pending buffer, and seven scalar counters.
        The source stands in for both classifiers (fs = LDL confidences,
        hrs = the labels the RDL would return); `ys` feed the ground-truth
        summary fields (`avg_true_cost`, `accuracy`) that a real server
        could not observe.

        With `HIServerConfig.time_block > 1` and a configuration where the
        double-buffered flow cannot diverge from the monolithic H2T2 chain
        (`rounds_eligible`), whole `time_block`-slot chains are served
        through the multi-round hedge kernel instead — same decisions, same
        counters, same summary; ineligible configurations silently keep the
        slot-by-slot scan.
        """
        cfg = self.cfg
        s, cap = cfg.n_streams, cfg.capacity
        if key is None:
            raise TypeError("run_source needs a policy `key` (the source "
                            "carries only its own generative key)")
        if source.n_streams != s:
            raise ValueError(
                f"source has {source.n_streams} streams but the server is "
                f"configured for {s}")
        if self.rounds_eligible(source):
            return self._run_source_rounds(source, key)
        eng = self.engine
        izero = jnp.zeros((), jnp.int32)
        fzero = jnp.zeros((), jnp.float32)
        # Neutral pending buffer for the has_pending=False first slot: the
        # scan carry needs a fixed pytree structure, so the "no feedback yet"
        # case is a flag, not a missing leaf.
        pending0 = PendingFeedback(
            decision=FleetDecision(
                i_f=jnp.zeros((s,), jnp.int32),
                offload=jnp.zeros((s,), bool),
                explored=jnp.zeros((s,), bool),
                local_pred=jnp.zeros((s,), jnp.int32),
                q=jnp.zeros((s,)), p=jnp.zeros((s,)), psi=jnp.zeros((s,))),
            hrs=jnp.zeros((s,), jnp.int32),
            sent=jnp.zeros((s,), bool),
            betas=jnp.zeros((s,)))

        serve_block = self._serve_block_fn()
        pol = eng.init(s)
        pending, has_pending = pending0, jnp.asarray(False)
        t, acc, sst = izero, _ServeCounters(fzero, fzero, *([izero] * 5)), \
            source.init_state()
        for blk in range(source.n_blocks):
            # Emit eagerly, scan the block under one (instance-cached) jit:
            # only this (S, block) SlotBatch is ever live.
            sst, batch = source.emit(sst, source.key, blk)
            pol, pending, has_pending, t, acc = serve_block(
                pol, pending, has_pending, t, acc, key, batch)
        if bool(has_pending):                       # final flush
            pol, _ = eng.feedback(pol, pending.decision, pending.hrs,
                                  pending.betas, sent=pending.sent)

        state = HIServerState(
            policy=pol, t=t,
            total_loss=acc.loss,
            total_offloads=acc.offloads.astype(jnp.float32),
            total_dropped=acc.dropped.astype(jnp.float32),
            rdl_evals=acc.rdl_evals, rdl_batches=acc.rdl_batches,
            pending=None)
        return state, self._source_summary(acc, source.horizon)

    def run(
        self,
        token_stream: jnp.ndarray,   # (T, S, L) — or a ScenarioSource
        betas: jnp.ndarray = None,   # (T, S)
        key: jax.Array = None,
    ) -> Tuple[HIServerState, Dict[str, float]]:
        """Serve end to end in either of two explicit forms:

          run(source, key)             — ScenarioSource-driven (key may be
                                         positional or keyword)
          run(tokens, betas, key)      — array-driven replay

        The source form verifies that a positional second argument actually
        looks like a PRNG key instead of silently reinterpreting whatever
        landed in the `betas` slot.
        """
        if isinstance(token_stream, ScenarioSource):
            if betas is not None:
                if key is not None:
                    raise TypeError(
                        "HIServer.run(source, ...) takes no betas — the "
                        "source generates them (got both a second "
                        "positional argument and key=)")
                if not _looks_like_prng_key(betas):
                    raise TypeError(
                        "HIServer.run(source, key) expected a PRNG key as "
                        "the second argument, got "
                        f"{type(betas).__name__} with shape "
                        f"{getattr(betas, 'shape', None)} — the source "
                        "generates its own betas")
                key = betas
            return self.run_source(token_stream, key)
        if betas is None or key is None:
            raise TypeError("HIServer.run(token_stream, betas, key) needs "
                            "betas and key")
        state = self.init_state()
        horizon = token_stream.shape[0]
        counter = self.cfg.randomness == "counter"
        for t in range(horizon):
            if counter:
                # One seed for the whole run; serve_slot draws at slot t.
                sub = key
            else:
                key, sub = jax.random.split(key)
            state, _ = self.serve_slot(state, token_stream[t], betas[t], sub)
        state = self.flush(state)
        n = horizon * self.cfg.n_streams
        rdl_evals = int(state.rdl_evals)
        # Each launch is capacity-padded, so the remote model also computes
        # the padding rows — report both the sample-level savings and the
        # actual compute rows so neither can be mistaken for the other.
        rdl_rows = int(state.rdl_batches) * self.cfg.capacity
        return state, {
            "avg_offload_cost": float(state.total_loss) / n,
            "offload_rate": float(state.total_offloads) / n,
            "drop_rate": float(state.total_dropped) / n,
            "rdl_evals": float(rdl_evals),
            "rdl_eval_rate": rdl_evals / n,
            "rdl_savings": 1.0 - rdl_evals / n,
            "rdl_batches": float(state.rdl_batches),
            "rdl_compute_rows": float(rdl_rows),
            "rdl_row_savings": 1.0 - rdl_rows / n,
        }
