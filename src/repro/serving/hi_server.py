"""Hierarchical-inference server: the paper's system (Fig. 1) end-to-end.

Per time slot, for a fleet of edge streams:
  1. every sample runs the LDL classifier → confidence f_t,
  2. each stream's H2T2 state decides offload / local-predict (vmapped hedge),
  3. offloaded samples are *batched* to the RDL classifier (padded to a fixed
     offload-batch so the step stays jit-shaped),
  4. losses are charged (β_t on offload, δ-weighted misclassification local),
  5. H2T2 weights update from the RDL feedback (Eq. 10 pseudo-loss).

The RDL inference is the ground-truth proxy throughout, exactly as in the
paper's problem setting.

Choosing a `PolicyBackend` (step 2): `backend="fused"` (default) runs the
whole fleet's H2T2 update as one batched `fleet_hedge_step` launch — the
Pallas kernel on TPU, its jnp oracle elsewhere — while `backend="reference"`
keeps the paper-shaped vmapped `h2t2_step`. Both consume the same per-stream
keys and make identical decisions; prefer "fused" everywhere and fall back to
"reference" only when isolating a policy-math question from the kernel path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import HIConfig, h2t2_init
from repro.core.policy import H2T2State, StepOutput
from repro.serving.engine import PolicyBackend, make_policy_step


@dataclasses.dataclass(frozen=True)
class HIServerConfig:
    n_streams: int = 8
    hi: HIConfig = HIConfig()
    backend: PolicyBackend = "fused"
    interpret: Optional[bool] = None   # fused-backend kernel interpret override


class HIServerState(NamedTuple):
    policy: H2T2State       # vmapped over streams
    t: jnp.ndarray
    total_loss: jnp.ndarray
    total_offloads: jnp.ndarray


class SlotResult(NamedTuple):
    f: jnp.ndarray          # (S,) LDL confidences
    offload: jnp.ndarray    # (S,) bool
    pred: jnp.ndarray       # (S,) final predictions
    loss: jnp.ndarray       # (S,)


class HIServer:
    """Orchestrates LDL (edge) and RDL (server) classifiers around H2T2."""

    def __init__(
        self,
        cfg: HIServerConfig,
        ldl: Callable[[jnp.ndarray], jnp.ndarray],   # tokens (S, L) → f (S,)
        rdl: Callable[[jnp.ndarray], jnp.ndarray],   # tokens (S, L) → labels (S,)
    ):
        self.cfg = cfg
        self.ldl = ldl
        self.rdl = rdl
        self._policy_step = make_policy_step(
            cfg.hi, backend=cfg.backend, interpret=cfg.interpret)

    def init_state(self) -> HIServerState:
        policy = jax.vmap(lambda _: h2t2_init(self.cfg.hi))(
            jnp.arange(self.cfg.n_streams))
        zero = jnp.zeros((), jnp.float32)
        return HIServerState(policy=policy, t=jnp.zeros((), jnp.int32),
                             total_loss=zero, total_offloads=zero)

    def serve_slot(
        self,
        state: HIServerState,
        tokens: jnp.ndarray,        # (S, L) one sample per stream
        betas: jnp.ndarray,         # (S,)
        key: jax.Array,
    ) -> Tuple[HIServerState, SlotResult]:
        s = self.cfg.n_streams
        fs = self.ldl(tokens)                                # (S,) edge inference
        # The RDL label is the feedback/ground-truth proxy. We evaluate it for
        # the whole slot batch (simulation); the *policy* only consumes it for
        # offloaded samples — h2t2_step masks internally.
        hrs = self.rdl(tokens).astype(jnp.int32)             # (S,)
        keys = jax.random.split(key, s)
        policy, out = self._policy_step(state.policy, fs, betas, hrs, keys)
        new_state = HIServerState(
            policy=policy,
            t=state.t + 1,
            total_loss=state.total_loss + jnp.sum(out.loss),
            total_offloads=state.total_offloads + jnp.sum(out.offload),
        )
        return new_state, SlotResult(f=fs, offload=out.offload, pred=out.pred,
                                     loss=out.loss)

    def run(
        self,
        token_stream: jnp.ndarray,   # (T, S, L)
        betas: jnp.ndarray,          # (T, S)
        key: jax.Array,
    ) -> Tuple[HIServerState, Dict[str, float]]:
        state = self.init_state()
        horizon = token_stream.shape[0]
        for t in range(horizon):
            key, sub = jax.random.split(key)
            state, _ = self.serve_slot(state, token_stream[t], betas[t], sub)
        n = horizon * self.cfg.n_streams
        return state, {
            "avg_loss": float(state.total_loss) / n,
            "offload_rate": float(state.total_offloads) / n,
        }
