"""Async request plane: the served front half of the system.

Concurrent per-session requests enter through `RequestPlane.submit`
(admission + degradation ladder → slot lease), coalesce in the
`MicroBatcher` into fleet-wide `decide` rounds, route offloads through the
same rotating compaction and delayed feedback as `HIServer`, and price
every offload with a live β from `NetworkEstimator` over measured link
transfers — replacing the generator-supplied β of trace replay end to end.

The offload path is fault-tolerant: any `Link` backend (the deterministic
`SimulatedLink`, a fault-injecting `FaultyLink`, or a future real probe)
sits behind `ResilientSender` — per-send deadlines, capped-backoff retries,
and per-stream circuit breakers — and a send that exhausts its retries
degrades to the conditional local fallback with its feedback slot masked,
so futures never hang and the policy never trains on labels that never
arrived. Everything runs on `VirtualTimeLoop` simulated time under test
and benchmark, so a fixed seed produces the identical summary.
"""
from repro.serving.request_plane.admission import (   # noqa: F401
    REASON_BREAKER_OPEN,
    REASON_NO_SLOT,
    REASON_QUEUE_FULL,
    REASON_RATE_LIMITED,
    REASON_SLO,
    AdmissionConfig,
    AdmissionController,
)
from repro.serving.request_plane.ingress import (     # noqa: F401
    RequestPlane,
    RequestPlaneConfig,
    SessionTable,
    VirtualTimeLoop,
    run_virtual,
    serve_traffic,
)
from repro.serving.request_plane.metrics import (     # noqa: F401
    Counter,
    Gauge,
    Metrics,
    P2Quantile,
    Quantiles,
)
from repro.serving.request_plane.microbatch import (  # noqa: F401
    MicroBatcher,
    PlaneResult,
    Request,
)
from repro.serving.request_plane.netem import (       # noqa: F401
    EstimatorConfig,
    FaultConfig,
    FaultyLink,
    Link,
    LinkConfig,
    LinkError,
    LinkOutage,
    NetworkEstimator,
    SendCorrupted,
    SendDropped,
    SimulatedLink,
)
from repro.serving.request_plane.resilience import (  # noqa: F401
    BREAKER_CLOSED,
    BREAKER_HALF_OPEN,
    BREAKER_OPEN,
    CircuitBreaker,
    ResilienceConfig,
    ResilientSender,
    RetriesExhausted,
    SendTimeout,
)
