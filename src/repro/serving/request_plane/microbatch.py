"""Deadline-based micro-batching: concurrent requests → one fleet decide.

Per flush the batcher replays `HIServer.serve_slot`'s two-phase flow at
request granularity:

  0. apply every *arrived* pending feedback batch, oldest first (the
     double buffer generalized: a batch's remote results update the expert
     weights at the first flush after its last transfer lands, so decide
     rounds never block on the network),
  1. take at most one queued request per stream slot and run ONE device
     `engine.decide` over the whole fleet (inactive slots ride along with
     masked-off decisions — the same (ψ, ζ) key tree as a `ScenarioSource`
     replay via `source_slot_keys`, which is what makes the low-load plane
     bit-compatible with `HIServer.run_source`),
  2. compact only the offloaded requests at `capacity` with the rotating
     drop priority (`rotated_compact`), send each survivor through the
     resilient offload path (`ResilientSender`: deadline, retries with
     backoff, circuit breaker; measured transfer → `NetworkEstimator` →
     next round's β), and complete every request's future: remote label
     where the send succeeded, the conditional local fallback where
     capacity-dropped OR where every retry failed, the local decision
     otherwise.

A flush fires when `max_batch` distinct streams have work OR `max_wait`
elapses after the first queued request — whichever comes first. Streams
not in the batch are frozen exactly: their (η, decay) are masked to
(0, 1), so a partial round leaves their expert weights bit-identical.

Lost-feedback recovery reuses the same freezing: a send that exhausts its
retries resolves the request with the conditional local fallback (a future
never hangs), decrements the batch's `outstanding` count so pending
feedback still drains, and masks that slot's (η, decay) to (0, 1) in its
feedback entry — the request is charged the β its attempts actually spent,
but the policy never trains on a remote label that never arrived.

The batcher is event-loop native but does all device work synchronously
inside the flush callback; only link transfers are awaited.
"""
from __future__ import annotations

import asyncio
import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import (
    H2T2State,
    effective_local_pred,
    fleet_feedback,
    fleet_restart,
    local_fallback_pred,
    source_slot_keys,
)
from repro.core.types import HIConfig
from repro.serving.batching import scatter_results
from repro.serving.hi_server import rotated_compact
from repro.serving.policy_engine import PolicyEngine
from repro.serving.request_plane.metrics import Metrics
from repro.serving.request_plane.netem import NetworkEstimator
from repro.serving.request_plane.resilience import (
    ResilientSender,
    RetriesExhausted,
)


@dataclasses.dataclass
class Request:
    """One in-flight classification request, leased onto stream `stream`."""

    session: int
    stream: int
    f: float                 # LDL confidence (the edge model ran upstream)
    hr: int                  # label the remote model would return
    y: int = -1              # ground truth for accounting; -1 = unknown
    payload_bytes: float = 0.0
    t_arrival: float = 0.0
    future: Optional[asyncio.Future] = None


@dataclasses.dataclass(frozen=True)
class PlaneResult:
    """What a request's future resolves to — always a prediction, never an
    error (denials, capacity drops, and exhausted retries all degrade to
    local-only predictions)."""

    pred: int
    offloaded: bool = False
    dropped: bool = False    # offload decision shed by RDL capacity
    denied: bool = False     # shed by admission before reaching the batcher
    failed: bool = False     # offload sent but every retry failed
    reason: Optional[str] = None
    latency: float = 0.0     # seconds from arrival to completion


class _FeedbackEntry:
    """One flush's delayed feedback, waiting for its transfers to land.

    `eta`/`decay` stay host-side (numpy) until the entry is applied, so a
    transfer that exhausts its retries can still `mask_slot` its stream —
    freezing that slot's weights exactly as an off-batch stream is frozen —
    before the batch reaches `fleet_feedback`.
    """

    __slots__ = ("decision", "hrs", "sent", "betas", "eta", "decay",
                 "outstanding")

    def __init__(self, decision, hrs, sent, betas, eta, decay,
                 outstanding: int):
        self.decision = decision
        self.hrs = hrs
        self.sent = sent
        self.betas = betas       # (S,) np — decision-time β snapshot
        self.eta = eta           # (S,) np — mutable until applied
        self.decay = decay       # (S,) np — mutable until applied
        self.outstanding = outstanding

    def mask_slot(self, slot: int) -> None:
        """Freeze `slot` out of this batch's weight update: (η=0, decay=1)
        make `fleet_feedback` the exact identity for that stream."""
        self.eta[slot] = 0.0
        self.decay[slot] = 1.0


def account_outcome(metrics: Metrics, hi: HIConfig, pred: int, y: int,
                    beta: float) -> None:
    """Shared cost accounting for every completed request (served, dropped,
    or admission-denied): observed cost is β where actually offloaded;
    ground-truth cost adds φ(pred, y) when a label is known."""
    metrics.counter("observed_cost").inc(beta)
    if y >= 0:
        phi = (hi.delta_fp if (pred == 1 and y == 0) else
               hi.delta_fn if (pred == 0 and y == 1) else 0.0)
        metrics.counter("true_cost").inc(beta + phi)
        metrics.counter("labeled_total").inc()
        if pred == y:
            metrics.counter("correct_total").inc()


class MicroBatcher:
    """Coalesces per-stream request queues into fleet decide rounds."""

    def __init__(
        self,
        hi: HIConfig,
        engine: PolicyEngine,
        n_streams: int,
        capacity: int,
        max_batch: int,
        max_wait: float,
        sender: ResilientSender,
        estimator: NetworkEstimator,
        metrics: Metrics,
        key: jax.Array,
        record_rounds: bool = False,
    ):
        self.hi = hi
        self.engine = engine
        self.n_streams = int(n_streams)
        self.capacity = int(capacity)
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait)
        self.sender = sender
        self.estimator = estimator
        self.metrics = metrics
        self.key = key
        self.state = engine.init(n_streams)
        if not isinstance(self.state, H2T2State):
            raise ValueError(
                f"the request plane drives fixed-schedule engines whose "
                f"state is a plain H2T2State; engine {engine.name!r} "
                f"carries {type(self.state).__name__} (partial-round "
                "masking cannot freeze its extra state)")
        espec = engine._exec_spec()
        s, cap = self.n_streams, self.capacity

        # Partial-round feedback: per-stream (η, decay) masked to (0, 1)
        # off-batch, so inactive streams' weights are untouched (decay 1 and
        # zero pseudo-loss make the update the identity, and the log-weight
        # renormalization subtracts an already-zero max).
        self._feedback_fn = jax.jit(
            lambda st, dec, hrs, betas, sent, eta, decay: fleet_feedback(
                hi, st, dec, hrs, betas, sent, eta=eta, decay=decay,
                spec=espec))

        def route(hrs, offload, t):
            # The per-request payload is the (S, 1) remote-label column, so
            # compaction, capacity, and drop rotation behave exactly as in
            # `HIServer.run_source`.
            batch = rotated_compact(hrs[:, None], offload, cap, t)
            hrs_back = scatter_results(batch.tokens[:, 0], batch, s, fill=0)
            sent = scatter_results(
                batch.valid.astype(jnp.int32), batch, s,
                fill=0).astype(bool)
            return hrs_back, sent

        self._route = jax.jit(route)
        self._restart = jax.jit(
            lambda st, mask: fleet_restart(hi, st, mask,
                                           learner=espec.learner))

        self._queues: List[Deque[Request]] = [deque() for _ in range(s)]
        self._n_queued = 0
        self._n_active = 0           # streams with at least one queued request
        self._pending: Deque[_FeedbackEntry] = deque()
        self._inflight = 0           # outstanding link transfers
        self._round = 0
        self._timer = None
        self.stream_sent = np.zeros((s,), np.int64)   # remote serves per slot
        self.record: Optional[List[Dict[str, np.ndarray]]] = (
            [] if record_rounds else None)

    # ------------------------------- ingress side -------------------------------

    @property
    def queue_depth(self) -> int:
        """Requests queued ahead of the next flushes (admission's signal)."""
        return self._n_queued

    def enqueue(self, req: Request) -> asyncio.Future:
        """Queue a request on its stream slot; returns its result future."""
        loop = asyncio.get_running_loop()
        req.future = loop.create_future()
        q = self._queues[req.stream]
        if not q:
            self._n_active += 1
        q.append(req)
        self._n_queued += 1
        self.metrics.gauge("queue_depth").set(self._n_queued)
        if self._n_active >= self.max_batch:
            self._flush()
        elif self._timer is None:
            self._timer = loop.call_at(loop.time() + self.max_wait,
                                       self._timer_fire)
        return req.future

    def restart_stream(self, slot: int) -> None:
        """Wipe one stream's expert weights (session-reclaim hygiene)."""
        mask = jnp.zeros((self.n_streams,), bool).at[slot].set(True)
        self.state = self._restart(self.state, mask)

    # ------------------------------- flush flow ---------------------------------

    def _timer_fire(self):
        self._timer = None
        self._flush()

    def _apply_ready_feedback(self) -> None:
        """Fold every fully-arrived pending batch into the weights, in
        flush order (a stalled older batch holds newer ones back, so
        updates are never applied out of order)."""
        while self._pending and self._pending[0].outstanding == 0:
            e = self._pending.popleft()
            self.state, _ = self._feedback_fn(
                self.state, e.decision, e.hrs, jnp.asarray(e.betas), e.sent,
                jnp.asarray(e.eta), jnp.asarray(e.decay))
            self.metrics.counter("feedback_rounds").inc()

    def _flush(self) -> None:
        loop = asyncio.get_running_loop()
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        self._apply_ready_feedback()
        if self._n_active == 0:
            return
        s = self.n_streams
        t = self._round
        self._round += 1

        active = np.zeros((s,), bool)
        fs = np.full((s,), 0.5, np.float32)     # inert filler off-batch
        hrs = np.zeros((s,), np.int32)
        ys = np.full((s,), -1, np.int32)
        payloads = np.zeros((s,), np.float32)
        reqs: Dict[int, Request] = {}
        for slot in range(s):
            q = self._queues[slot]
            if not q:
                continue
            r = q.popleft()
            self._n_queued -= 1
            active[slot] = True
            fs[slot] = r.f
            hrs[slot] = r.hr
            ys[slot] = r.y
            payloads[slot] = r.payload_bytes
            reqs[slot] = r
        self._n_active = sum(1 for q in self._queues if q)
        self.metrics.gauge("queue_depth").set(self._n_queued)

        # Live β: the estimator prices each stream's offload *now*; this
        # snapshot is both what feedback charges and what the summary
        # accounts, replacing any generator-supplied β end to end.
        betas = self.estimator.beta_vector(payloads)
        if self.engine.randomness == "counter":
            # The flush round index is the counter slot — the same position
            # a `run_source` replay of these rounds would draw at.
            decision = self.engine.decide(self.state, jnp.asarray(fs),
                                          self.key, slot=t)
        else:
            keys = source_slot_keys(self.key, t, s)
            decision = self.engine.decide(self.state, jnp.asarray(fs), keys)
        active_j = jnp.asarray(active)
        decision = decision._replace(
            offload=decision.offload & active_j,
            explored=decision.explored & active_j)
        hrs_back, sent = self._route(jnp.asarray(hrs), decision.offload, t)
        sent_np = np.asarray(sent)
        off_np = np.asarray(decision.offload)
        local_pred = np.asarray(effective_local_pred(decision, sent))
        # The conditional fallback draw for sent slots whose transfer later
        # exhausts its retries (capacity drops get theirs via
        # `effective_local_pred`; this is the same draw, precomputed).
        fallback_pred = np.asarray(local_fallback_pred(decision))

        n_sent = int(sent_np.sum())
        n_drop = int((off_np & ~sent_np).sum())
        self.metrics.counter("rounds_total").inc()
        self.metrics.counter("batched_requests").inc(len(reqs))
        self.metrics.counter("capacity_dropped").inc(n_drop)
        self.metrics.counter("fallback_total").inc(n_drop)

        eta = np.where(active, np.float32(self.hi.eta), np.float32(0.0))
        decay = np.where(active, np.float32(self.hi.decay), np.float32(1.0))
        entry = _FeedbackEntry(
            decision=decision, hrs=hrs_back, sent=sent,
            betas=betas.copy(), eta=eta, decay=decay, outstanding=n_sent)
        self._pending.append(entry)

        if self.record is not None:
            self.record.append({"fs": fs, "hrs": hrs, "ys": ys,
                                "betas": betas.copy(), "active": active})

        for slot, r in reqs.items():
            if sent_np[slot]:
                self.stream_sent[slot] += 1
                loop.create_task(
                    self._transfer(entry, r, float(betas[slot]),
                                   int(fallback_pred[slot])))
            else:
                dropped = bool(off_np[slot])
                self._complete(r, int(local_pred[slot]), offloaded=False,
                               dropped=dropped, beta=0.0)

        # Leftover queued requests wait for the next flush: immediately
        # when a full batch is already waiting, else on a fresh deadline.
        if self._n_active >= self.max_batch:
            loop.call_soon(self._flush)
        elif self._n_active > 0:
            self._timer = loop.call_at(loop.time() + self.max_wait,
                                       self._timer_fire)

    async def _transfer(self, entry: _FeedbackEntry, req: Request,
                        beta: float, fallback_pred: int) -> None:
        """One offload through the resilient path: the sender owns retries,
        timeouts, the breaker, and every estimator observation. A send that
        exhausts its retries degrades to `fallback_pred` (the conditional
        local draw), masks its slot out of the batch's weight update, and
        still decrements `outstanding` — feedback drains, futures resolve.
        """
        self._inflight += 1
        try:
            await self.sender.send(req.stream, req.payload_bytes)
            self.metrics.counter("completed_remote").inc()
            self._complete(req, int(req.hr), offloaded=True, dropped=False,
                           beta=beta)
        except RetriesExhausted as e:
            entry.mask_slot(req.stream)
            self.metrics.counter("retry_exhausted").inc()
            self.metrics.counter("fallback_total").inc()
            # β is charged only where attempts actually hit the link — a
            # breaker fast-fail spent no network budget.
            self._complete(req, fallback_pred, offloaded=False,
                           dropped=False, failed=True,
                           beta=beta if e.attempts > 0 else 0.0)
        finally:
            self._inflight -= 1
            entry.outstanding -= 1

    def _complete(self, req: Request, pred: int, offloaded: bool,
                  dropped: bool, beta: float, failed: bool = False) -> None:
        loop = asyncio.get_running_loop()
        latency = loop.time() - req.t_arrival
        self.metrics.quantiles("latency_ms").observe(latency * 1e3)
        if not offloaded and not dropped and not failed:
            self.metrics.counter("completed_local").inc()
        account_outcome(self.metrics, self.hi, pred, req.y, beta)
        if not req.future.done():
            req.future.set_result(PlaneResult(
                pred=pred, offloaded=offloaded, dropped=dropped,
                failed=failed, latency=latency))

    # ------------------------------- lifecycle ----------------------------------

    @property
    def idle(self) -> bool:
        return not (self._n_queued or self._inflight
                    or any(e.outstanding for e in self._pending))

    async def drain(self) -> None:
        """Wait (in loop time) until every request has completed and every
        transfer has landed, then apply all remaining feedback — the
        request-plane analogue of `HIServer.flush`."""
        while not self.idle:
            await asyncio.sleep(self.max_wait)
        self._apply_ready_feedback()
