"""Fault tolerance for the offload path: deadlines, retries, backoff, and
per-stream circuit breaking — all on the loop clock, so every test is
virtual-time deterministic.

The `Link` protocol (`netem.py`) reports what the wire did; this module
decides what to do about it. `ResilientSender.send` wraps one logical
offload in the full recovery loop:

  attempt     — the raw `link.send`, bounded by a per-attempt `deadline`
                (`asyncio.wait_for`; a straggler past the deadline is
                cancelled and treated as failed).
  retry       — up to `max_retries` re-sends after the first attempt, each
                preceded by capped exponential backoff with deterministic
                seeded jitter (`base·factor^k`, clipped at `cap`, stretched
                by up to `jitter`× a seeded uniform — decorrelating retry
                storms without wall-clock randomness).
  breaker     — a per-stream circuit breaker: CLOSED → OPEN when failures
                run hot (consecutive count OR an EWMA failure rate over a
                threshold), OPEN → HALF_OPEN after `cooldown` seconds of
                loop time, HALF_OPEN admits exactly one probe whose outcome
                closes or re-opens the circuit. An open breaker fails the
                send fast — and the ingress ladder consults
                `breaker_blocking` to deny-to-local before any network
                budget is spent.

Every outcome feeds `NetworkEstimator.observe`: successes as measured RTTs
(`ok=True`), timeouts and drops as tail observations (`ok=False`, the
percentile window only), corrupted responses as real timings whose payload
was garbage (`ok=True` — the wire worked, the bytes didn't). A send that
exhausts every attempt raises `RetriesExhausted`, which carries how many
attempts actually reached the link — the micro-batcher charges β only when
network budget was truly spent (`attempts > 0`).

Metrics emitted (all in the plane summary): `retries_total`,
`send_timeouts`, `send_drops`, `send_outages`, `send_corrupted`,
`send_recovered` (succeeded on a retry), `retry_backoff_s` (cumulative),
`breaker_opens`/`breaker_closes`/`breaker_probes`, and the state gauges
`breaker_{closed,open,half_open}_streams`.
"""
from __future__ import annotations

import asyncio
import dataclasses
import random
from typing import Optional

from repro.serving.request_plane.metrics import Metrics
from repro.serving.request_plane.netem import (
    Link,
    LinkError,
    LinkOutage,
    NetworkEstimator,
    SendCorrupted,
)

#: Circuit-breaker states (the `breaker_{state}_streams` gauge suffixes).
BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half_open"


class SendTimeout(LinkError):
    """A single attempt exceeded the per-send deadline and was cancelled."""


class RetriesExhausted(LinkError):
    """Every attempt of one logical send failed (or the breaker refused to
    try). `attempts` counts sends that actually reached the link — 0 means
    the breaker failed the request fast and no network budget was spent.
    `last_error` is the final attempt's failure (None when `attempts` is 0).
    """

    def __init__(self, stream: int, attempts: int,
                 last_error: Optional[LinkError]):
        detail = ("breaker open, nothing sent" if attempts == 0
                  else f"last error: {last_error}")
        super().__init__(
            f"offload on stream {stream} failed after {attempts} "
            f"attempt(s); {detail}")
        self.stream = int(stream)
        self.attempts = int(attempts)
        self.last_error = last_error


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Retry/timeout/backoff/breaker knobs, all loop-clock driven.

    `deadline=None` disables per-attempt timeouts (safe with the simulated
    doubles, whose failures always surface in finite time; set it for any
    link with stragglers). The defaults are deliberately inert on a healthy
    link: no timeout, backoff only after a failure, breaker only opens on
    real failure runs — so the resilience layer is free when nothing fails.
    """

    deadline: Optional[float] = None   # s per attempt; None → no timeout
    max_retries: int = 2               # re-sends after the first attempt
    backoff_base: float = 0.01         # s, delay before the first retry
    backoff_factor: float = 2.0        # exponential growth per retry
    backoff_cap: float = 0.5           # s, delay ceiling
    backoff_jitter: float = 0.5        # stretch: delay ·= 1 + U[0, jitter]
    seed: int = 0                      # jitter PRNG seed
    breaker_enabled: bool = True
    breaker_consecutive: int = 5       # consecutive failures → OPEN
    breaker_alpha: float = 0.2         # failure-rate EWMA weight
    breaker_threshold: float = 0.7     # EWMA rate → OPEN (after min samples)
    breaker_min_samples: int = 5       # EWMA trips only past this many sends
    breaker_cooldown: float = 1.0      # s OPEN before the half-open probe

    def __post_init__(self):
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError(f"deadline must be positive (got {self.deadline})")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be ≥ 0 (got {self.max_retries})")
        if (self.backoff_base < 0 or self.backoff_cap < 0
                or self.backoff_factor < 1.0 or self.backoff_jitter < 0):
            raise ValueError(
                "backoff needs base ≥ 0, cap ≥ 0, factor ≥ 1, jitter ≥ 0 "
                f"(got base={self.backoff_base}, cap={self.backoff_cap}, "
                f"factor={self.backoff_factor}, jitter={self.backoff_jitter})")
        if self.breaker_consecutive < 1:
            raise ValueError(
                f"breaker_consecutive must be ≥ 1 (got {self.breaker_consecutive})")
        if not 0 < self.breaker_alpha <= 1:
            raise ValueError(
                f"breaker_alpha must lie in (0, 1] (got {self.breaker_alpha})")
        if not 0 < self.breaker_threshold <= 1:
            raise ValueError(
                f"breaker_threshold must lie in (0, 1] "
                f"(got {self.breaker_threshold})")
        if self.breaker_cooldown < 0:
            raise ValueError(
                f"breaker_cooldown must be ≥ 0 (got {self.breaker_cooldown})")


class CircuitBreaker:
    """One stream's failure-driven circuit: CLOSED → OPEN → HALF_OPEN.

    `allow(now)` is the mutating gate (claims the half-open probe);
    `blocking(now)` is the non-mutating view the admission ladder reads.
    `record_success`/`record_failure` return the transition that happened
    (`"opened"`/`"closed"`/None) so the sender can keep gauges exact.
    """

    __slots__ = ("cfg", "state", "consecutive", "rate", "samples",
                 "opened_at", "probing")

    def __init__(self, cfg: ResilienceConfig):
        self.cfg = cfg
        self.state = BREAKER_CLOSED
        self.consecutive = 0
        self.rate = 0.0            # EWMA failure rate
        self.samples = 0
        self.opened_at = 0.0
        self.probing = False

    def blocking(self, now: float) -> bool:
        """Would a send right now be refused? (No state change.)"""
        if not self.cfg.breaker_enabled or self.state == BREAKER_CLOSED:
            return False
        if self.state == BREAKER_OPEN:
            return now - self.opened_at < self.cfg.breaker_cooldown
        return self.probing        # HALF_OPEN: blocked while a probe flies

    def allow(self, now: float) -> bool:
        """Gate one attempt; OPEN past its cooldown becomes HALF_OPEN and
        grants the caller the (single) probe."""
        if not self.cfg.breaker_enabled or self.state == BREAKER_CLOSED:
            return True
        if self.state == BREAKER_OPEN:
            if now - self.opened_at < self.cfg.breaker_cooldown:
                return False
            self.state = BREAKER_HALF_OPEN
            self.probing = True
            return True
        if not self.probing:       # HALF_OPEN, probe slot free
            self.probing = True
            return True
        return False

    def record_success(self) -> Optional[str]:
        self.probing = False
        self.consecutive = 0
        self.samples += 1
        self.rate += self.cfg.breaker_alpha * (0.0 - self.rate)
        if self.state != BREAKER_CLOSED:
            self.state = BREAKER_CLOSED
            self.rate = 0.0        # a closed circuit starts clean
            return "closed"
        return None

    def record_failure(self, now: float) -> Optional[str]:
        self.probing = False
        self.consecutive += 1
        self.samples += 1
        self.rate += self.cfg.breaker_alpha * (1.0 - self.rate)
        if self.state == BREAKER_HALF_OPEN:
            self.state = BREAKER_OPEN
            self.opened_at = now   # failed probe: full cooldown again
            return "opened"
        if self.state == BREAKER_CLOSED and (
                self.consecutive >= self.cfg.breaker_consecutive
                or (self.samples >= self.cfg.breaker_min_samples
                    and self.rate >= self.cfg.breaker_threshold)):
            self.state = BREAKER_OPEN
            self.opened_at = now
            return "opened"
        return None


class ResilientSender:
    """The retry/timeout/backoff/breaker loop around one `Link`, shared by
    every in-flight transfer of the micro-batcher."""

    def __init__(self, link: Link, estimator: NetworkEstimator,
                 metrics: Metrics, cfg: ResilienceConfig, n_streams: int):
        self.link = link
        self.estimator = estimator
        self.metrics = metrics
        self.cfg = cfg
        self.breakers = [CircuitBreaker(cfg) for _ in range(int(n_streams))]
        self._jitter = random.Random(cfg.seed * 7_368_787 + 0x5DEECE66D)
        self._update_breaker_gauges()

    # ------------------------------ breaker views ------------------------------

    def breaker_blocking(self, stream: int, now: float) -> bool:
        """The ingress ladder's view: is this stream's circuit refusing?"""
        return self.breakers[stream].blocking(now)

    def breaker_state(self, stream: int) -> str:
        return self.breakers[stream].state

    def _update_breaker_gauges(self) -> None:
        counts = {BREAKER_CLOSED: 0, BREAKER_OPEN: 0, BREAKER_HALF_OPEN: 0}
        for b in self.breakers:
            counts[b.state] += 1
        for state, n in counts.items():
            self.metrics.gauge(f"breaker_{state}_streams").set(n)

    # ------------------------------ the send loop ------------------------------

    def _backoff(self, retry_index: int) -> float:
        cfg = self.cfg
        delay = min(cfg.backoff_cap,
                    cfg.backoff_base * cfg.backoff_factor ** retry_index)
        if cfg.backoff_jitter > 0.0:
            delay *= 1.0 + cfg.backoff_jitter * self._jitter.random()
        return delay

    async def send(self, stream: int, payload_bytes: float) -> float:
        """One logical offload: returns the successful attempt's measured
        transfer seconds, or raises `RetriesExhausted`."""
        loop = asyncio.get_running_loop()
        cfg = self.cfg
        breaker = self.breakers[stream]
        attempts = 0
        last: Optional[LinkError] = None
        for attempt in range(cfg.max_retries + 1):
            if not breaker.allow(loop.time()):
                break              # open circuit: fail fast, spend nothing
            if breaker.state == BREAKER_HALF_OPEN:
                self.metrics.counter("breaker_probes").inc()
                self._update_breaker_gauges()   # OPEN → HALF_OPEN in allow()
            if attempt > 0:
                self.metrics.counter("retries_total").inc()
            attempts += 1
            t0 = loop.time()
            try:
                if cfg.deadline is not None:
                    await asyncio.wait_for(
                        self.link.send(stream, payload_bytes), cfg.deadline)
                else:
                    await self.link.send(stream, payload_bytes)
            except asyncio.TimeoutError:
                elapsed = loop.time() - t0
                last = SendTimeout(
                    f"attempt {attempt} on stream {stream} exceeded the "
                    f"{cfg.deadline}s deadline", elapsed=elapsed)
                self.metrics.counter("send_timeouts").inc()
                self.estimator.observe(stream, elapsed, payload_bytes,
                                       ok=False)
                self._record_failure(breaker, loop.time())
            except LinkOutage as e:
                last = e           # fast failure: no timing worth recording
                self.metrics.counter("send_outages").inc()
                self._record_failure(breaker, loop.time())
            except SendCorrupted as e:
                last = e           # the wire worked — a real RTT measurement
                self.metrics.counter("send_corrupted").inc()
                self.estimator.observe(stream, e.elapsed, payload_bytes,
                                       ok=True)
                self._record_failure(breaker, loop.time())
            except LinkError as e:  # SendDropped + any transport failure
                last = e
                self.metrics.counter("send_drops").inc()
                self.estimator.observe(
                    stream, max(e.elapsed, loop.time() - t0), payload_bytes,
                    ok=False)
                self._record_failure(breaker, loop.time())
            else:
                measured = loop.time() - t0
                if breaker.record_success() == "closed":
                    self.metrics.counter("breaker_closes").inc()
                    self._update_breaker_gauges()
                self.estimator.observe(stream, measured, payload_bytes,
                                       ok=True)
                if attempt > 0:
                    self.metrics.counter("send_recovered").inc()
                return measured
            if attempt < cfg.max_retries and not breaker.blocking(loop.time()):
                delay = self._backoff(attempt)
                if delay > 0.0:
                    self.metrics.counter("retry_backoff_s").inc(delay)
                    await asyncio.sleep(delay)
        raise RetriesExhausted(stream, attempts, last)

    def _record_failure(self, breaker: CircuitBreaker, now: float) -> None:
        if breaker.record_failure(now) == "opened":
            self.metrics.counter("breaker_opens").inc()
            self._update_breaker_gauges()
