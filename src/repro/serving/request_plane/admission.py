"""Admission control: token-bucket rate limiting, queue-depth shedding, and
the health rungs of the degradation ladder.

The compactor's rotating drop (`serving.hi_server.rotated_compact`) already
bounds the *RDL batch*; admission bounds the *queue in front of the
batcher*, which is what actually blows up tail latency under sustained
overload — an admitted request waits O(queue/throughput) micro-batch rounds
before it is even decided. Denial is graceful degradation, never an error:
the ingress answers a denied request immediately with a local-only fallback
prediction (`RequestPlane`), so callers always get a classification.

Beyond load, the ladder also sheds on predicted *offload-path health*
(`RequestPlane._ladder_deny`): a leased stream whose circuit breaker is
open (`breaker_open`), or whose estimator-predicted p-quantile transfer
time would miss the latency SLO (`slo_miss`, `slo_deadline`/`slo_quantile`
below), is denied to the local fallback *before* any network budget is
spent — the cheap rung of degradation, ahead of retries and fallbacks.

Every denial increments a per-reason counter (`denied_{reason}`) plus the
`denied_total` aggregate, so the overload invariant is checkable exactly:

    requests_total == admitted_total + denied_total
    fallback_total == denied_total + capacity_dropped + retry_exhausted
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving.request_plane.metrics import Metrics

#: Denial reasons (the `denied_{reason}` counter suffixes).
REASON_QUEUE_FULL = "queue_full"
REASON_RATE_LIMITED = "rate_limited"
REASON_NO_SLOT = "no_slot"
REASON_BREAKER_OPEN = "breaker_open"   # stream's offload circuit is open
REASON_SLO = "slo_miss"                # predicted transfer misses the SLO


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Token bucket (`rate` tokens/s, `burst` capacity) + queue-depth cap.

    `rate=None` disables rate limiting; `max_queue=None` disables depth
    shedding (`enabled=False` disables both). The depth cap is the one that
    bounds p99 at saturation: with `max_queue=Q` and per-round service of S
    requests, an admitted request waits at most ~⌈Q/S⌉ + 1 micro-batch
    deadlines before its decide round.

    `slo_deadline` (seconds, None → off) arms the latency-SLO rung of the
    degradation ladder: a request whose leased stream's predicted
    `slo_quantile` transfer time (estimator percentile + payload
    serialization) exceeds the deadline is denied to the local fallback
    before any network budget is spent — the ROADMAP's "deny when the
    estimator's p95 predicts a deadline miss" admission mode.
    """

    rate: Optional[float] = None   # sustained requests/s; None → unlimited
    burst: float = 32.0            # bucket capacity (peak admissions)
    max_queue: Optional[int] = None  # batcher queue-depth cap; None → unbounded
    slo_deadline: Optional[float] = None  # s; None → no latency-SLO rung
    slo_quantile: float = 0.95     # estimator percentile the SLO prices
    enabled: bool = True

    def __post_init__(self):
        if self.rate is not None and self.rate <= 0:
            raise ValueError(f"rate must be positive (got {self.rate})")
        if self.burst <= 0:
            raise ValueError(f"burst must be positive (got {self.burst})")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(
                f"max_queue must be ≥ 1 (got {self.max_queue}); use None "
                "for unbounded")
        if self.slo_deadline is not None and self.slo_deadline <= 0:
            raise ValueError(
                f"slo_deadline must be positive (got {self.slo_deadline}); "
                "use None to disable the SLO rung")
        if not 0.0 < self.slo_quantile < 1.0:
            raise ValueError(
                f"slo_quantile must lie in (0, 1) (got {self.slo_quantile})")


class AdmissionController:
    """Clock-driven admission decisions with per-reason accounting.

    `admit(now, queue_depth)` returns None to admit or a denial-reason
    string; the caller owns the clock (the event loop's time — virtual
    under test), so the controller itself never reads wall time.
    """

    def __init__(self, cfg: AdmissionConfig, metrics: Metrics):
        self.cfg = cfg
        self.metrics = metrics
        self._tokens = float(cfg.burst)
        self._last = None  # type: Optional[float]

    def _refill(self, now: float) -> None:
        if self.cfg.rate is None:
            return
        if self._last is not None and now > self._last:
            self._tokens = min(self.cfg.burst,
                               self._tokens + (now - self._last) * self.cfg.rate)
        self._last = now

    def admit(self, now: float, queue_depth: int) -> Optional[str]:
        """None = cleared to proceed (consumes a token); otherwise the
        denial reason. The ingress owns `admitted_total` — it increments it
        only once the slot lease also succeeds, so a later `no_slot` denial
        is never double-counted as admitted."""
        if not self.cfg.enabled:
            return None
        self._refill(now)
        if (self.cfg.max_queue is not None
                and queue_depth >= self.cfg.max_queue):
            return self.deny(REASON_QUEUE_FULL)
        if self.cfg.rate is not None:
            if self._tokens < 1.0:
                return self.deny(REASON_RATE_LIMITED)
            self._tokens -= 1.0
        return None

    def deny(self, reason: str) -> str:
        """Record a denial (also used by the ingress for `no_slot`)."""
        self.metrics.counter(f"denied_{reason}").inc()
        self.metrics.counter("denied_total").inc()
        return reason
