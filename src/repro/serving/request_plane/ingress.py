"""Session ingress on a virtual clock: requests in, predictions out.

Three layers, composed by `RequestPlane`:

  `VirtualTimeLoop` — an asyncio event loop whose clock is a number we
      advance, not the wall. When no callback is ready it jumps straight to
      the next timer, so a multi-hour traffic trace with thousands of
      `asyncio.sleep`s runs in milliseconds AND deterministically: the same
      seed yields the identical interleaving, hence the identical summary.
      (The tier-1 suite runs entirely on this loop — no wall-clock sleeps.)
  `SessionTable` — maps user sessions onto the fleet's fixed S stream
      slots: free-list lease, LRU reclaim of idle sessions, pin counts so a
      slot with requests in flight is never reassigned under them.
  `RequestPlane` — per-request flow: admission (deny → immediate local
      fallback prediction, never an error) → slot lease → degradation
      ladder (open circuit breaker or predicted latency-SLO miss on the
      leased stream ⇒ deny-to-local before spending network budget) →
      micro-batcher enqueue → await the decide/offload future → release.

`serve_traffic` is the open-loop driver the benchmark and tests share: it
replays a seeded `ArrivalBatch` (`repro.data.traffic`) against a plane on
the virtual clock.
"""
from __future__ import annotations

import asyncio
import dataclasses
import heapq
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.counter import check_randomness_mode
from repro.core.execspec import ExecSpec
from repro.core.types import HIConfig
from repro.serving.policy_engine import get_engine
from repro.serving.request_plane.admission import (
    REASON_BREAKER_OPEN,
    REASON_NO_SLOT,
    REASON_SLO,
    AdmissionConfig,
    AdmissionController,
)
from repro.serving.request_plane.metrics import Metrics
from repro.serving.request_plane.microbatch import (
    MicroBatcher,
    PlaneResult,
    Request,
    account_outcome,
)
from repro.serving.request_plane.netem import (
    EstimatorConfig,
    FaultConfig,
    FaultyLink,
    LinkConfig,
    NetworkEstimator,
    SimulatedLink,
)
from repro.serving.request_plane.resilience import (
    ResilienceConfig,
    ResilientSender,
)


class VirtualTimeLoop(asyncio.SelectorEventLoop):
    """Event loop on simulated time.

    `time()` reads a virtual clock that only moves when the loop would
    otherwise block: with no ready callbacks, `_run_once` advances the
    clock to the earliest scheduled timer, which then fires with a zero
    selector timeout. Callback ordering is untouched asyncio semantics, so
    code under test runs unmodified — `asyncio.sleep`, `loop.call_at`, and
    `loop.time()` all behave, just without the waiting.

    If the loop would block forever (nothing ready, nothing scheduled, not
    stopping) it raises instead: on a virtual clock that state is a
    deadlock, and a loud failure beats a hung test.
    """

    def __init__(self):
        super().__init__()
        self._vt_now = 0.0

    def time(self) -> float:
        return self._vt_now

    def _run_once(self):
        # Drop cancelled timers from the heap head first (mirroring the
        # base loop's bookkeeping) so we never advance to a dead deadline.
        while self._scheduled and self._scheduled[0]._cancelled:
            self._timer_cancelled_count -= 1
            handle = heapq.heappop(self._scheduled)
            handle._scheduled = False
        if not self._ready:
            if self._scheduled:
                when = self._scheduled[0]._when
                if when > self._vt_now:
                    self._vt_now = when
            elif not self._stopping:
                raise RuntimeError(
                    "VirtualTimeLoop has nothing ready and nothing "
                    "scheduled — a real loop would block forever here "
                    "(await on a future nothing will complete?)")
        super()._run_once()


def run_virtual(main) -> object:
    """`asyncio.run` on a fresh `VirtualTimeLoop`. The whole awaited tree
    executes in simulated time; returns the coroutine's result."""
    loop = VirtualTimeLoop()
    try:
        asyncio.set_event_loop(loop)
        return loop.run_until_complete(main)
    finally:
        try:
            loop.run_until_complete(loop.shutdown_asyncgens())
        finally:
            asyncio.set_event_loop(None)
            loop.close()


class SessionTable:
    """Session → stream-slot leases with LRU reclaim.

    The fleet has a fixed S; sessions come and go. A session keeps its slot
    across requests (the H2T2 weights on that slot ARE its learned state);
    when all slots are held, the least-recently-used session with no
    requests in flight is evicted. A fully pinned table refuses the lease
    (`None`) — admission turns that into a `no_slot` denial rather than
    corrupting an active stream.
    """

    def __init__(self, n_slots: int):
        self.n_slots = int(n_slots)
        self._slots: "OrderedDict[int, int]" = OrderedDict()  # session → slot
        self._free: List[int] = list(range(self.n_slots - 1, -1, -1))
        self._pins = [0] * self.n_slots
        self.evictions = 0

    def lease(self, session: int) -> Optional[Tuple[int, bool]]:
        """Pin a slot for one request of `session`.

        Returns (slot, evicted_other_session), or None when every slot is
        pinned by in-flight requests. Callers must `release(slot)` exactly
        once when the request completes.
        """
        evicted = False
        if session in self._slots:
            self._slots.move_to_end(session)
            slot = self._slots[session]
        elif self._free:
            slot = self._free.pop()
            self._slots[session] = slot
        else:
            victim = next((sess for sess, sl in self._slots.items()
                           if self._pins[sl] == 0), None)
            if victim is None:
                return None
            slot = self._slots.pop(victim)
            self._slots[session] = slot
            self.evictions += 1
            evicted = True
        self._pins[slot] += 1
        return slot, evicted

    def release(self, slot: int) -> None:
        self._pins[slot] -= 1
        assert self._pins[slot] >= 0, "unbalanced SessionTable.release"

    def slot_of(self, session: int) -> Optional[int]:
        return self._slots.get(session)


@dataclasses.dataclass(frozen=True)
class RequestPlaneConfig:
    """Everything the plane needs; mirrors `HIServerConfig` where shared."""

    n_streams: int = 8
    hi: HIConfig = dataclasses.field(default_factory=HIConfig)
    engine: str = "fused"
    # Preferred: one ExecSpec for all execution knobs; when given, the
    # legacy mirror fields below are synced from it (when None, it is
    # assembled from them).
    spec: Optional[ExecSpec] = None
    use_kernel: Optional[bool] = None
    interpret: Optional[bool] = None
    randomness: str = "pre_draw"             # "counter" → in-place PRNG draws
    offload_capacity: Optional[int] = None   # RDL batch rows; None → S
    max_batch: Optional[int] = None          # flush at this many streams; None → S
    max_wait: float = 0.05                   # s; flush deadline after first queue
    default_payload_bytes: float = 4096.0
    admission: AdmissionConfig = dataclasses.field(
        default_factory=AdmissionConfig)
    link: LinkConfig = dataclasses.field(default_factory=LinkConfig)
    fault: Optional[FaultConfig] = None   # wrap the link in FaultyLink
    resilience: ResilienceConfig = dataclasses.field(
        default_factory=ResilienceConfig)
    estimator: EstimatorConfig = dataclasses.field(
        default_factory=EstimatorConfig)
    restart_on_reclaim: bool = False   # wipe a slot's weights on session reclaim
    record_rounds: bool = False        # keep per-round arrays (replay parity)

    def __post_init__(self):
        if self.spec is None:
            check_randomness_mode(self.randomness)
            object.__setattr__(self, "spec", ExecSpec(
                use_kernel=self.use_kernel, interpret=self.interpret,
                randomness=self.randomness))
        else:
            object.__setattr__(self, "interpret", self.spec.interpret)
            object.__setattr__(self, "use_kernel", self.spec.use_kernel)
            object.__setattr__(self, "randomness", self.spec.randomness)
        if self.n_streams < 1:
            raise ValueError(f"n_streams must be ≥ 1 (got {self.n_streams})")
        if not (1 <= self.batch_limit <= self.n_streams):
            raise ValueError(
                f"max_batch must lie in [1, n_streams] "
                f"(got {self.max_batch} with n_streams={self.n_streams})")
        if self.capacity < 1:
            raise ValueError(
                f"offload_capacity must be ≥ 1 (got {self.offload_capacity})")
        if self.max_wait <= 0:
            raise ValueError(f"max_wait must be positive (got {self.max_wait})")

    @property
    def capacity(self) -> int:
        return (self.n_streams if self.offload_capacity is None
                else self.offload_capacity)

    @property
    def batch_limit(self) -> int:
        return self.n_streams if self.max_batch is None else self.max_batch


class RequestPlane:
    """The served system: ingress → micro-batch → decide → compact →
    transfer → (delayed) feedback, with admission in front and live β from
    the network estimator closing the loop."""

    def __init__(self, cfg: RequestPlaneConfig, key: Optional[jax.Array] = None):
        self.cfg = cfg
        if key is None:
            key = jax.random.PRNGKey(0)
        self.metrics = Metrics()
        self.admission = AdmissionController(cfg.admission, self.metrics)
        self.sessions = SessionTable(cfg.n_streams)
        self.link = SimulatedLink(cfg.link)
        if cfg.fault is not None:
            self.link = FaultyLink(self.link, cfg.fault)
        self.estimator = NetworkEstimator(cfg.estimator, cfg.n_streams)
        self.sender = ResilientSender(
            self.link, self.estimator, self.metrics, cfg.resilience,
            cfg.n_streams)
        engine = get_engine(cfg.engine, cfg.hi, spec=cfg.spec)
        self.batcher = MicroBatcher(
            hi=cfg.hi, engine=engine, n_streams=cfg.n_streams,
            capacity=cfg.capacity, max_batch=cfg.batch_limit,
            max_wait=cfg.max_wait, sender=self.sender,
            estimator=self.estimator, metrics=self.metrics, key=key,
            record_rounds=cfg.record_rounds)

    def _ladder_deny(self, slot: int, payload_bytes: float,
                     now: float) -> Optional[str]:
        """The health rungs of the degradation ladder, checked after the
        slot lease but before any network budget is spent: an open circuit
        breaker on the leased stream, or an estimator-predicted transfer
        that would miss the latency SLO, denies the request to the local
        fallback immediately."""
        if self.sender.breaker_blocking(slot, now):
            return self.admission.deny(REASON_BREAKER_OPEN)
        slo = self.cfg.admission.slo_deadline
        if slo is not None and self.estimator.predict_transfer(
                slot, payload_bytes,
                q=self.cfg.admission.slo_quantile) > slo:
            return self.admission.deny(REASON_SLO)
        return None

    async def submit(self, session: int, f: float, hr: int, y: int = -1,
                     payload_bytes: Optional[float] = None) -> PlaneResult:
        """Classify one request for `session`. Always resolves to a
        `PlaneResult` — denied or capacity-dropped requests degrade to the
        local-only prediction instead of erroring."""
        loop = asyncio.get_running_loop()
        now = loop.time()
        self.metrics.counter("requests_total").inc()
        payload = float(self.cfg.default_payload_bytes
                        if payload_bytes is None else payload_bytes)
        reason = self.admission.admit(now, self.batcher.queue_depth)
        lease = None
        if reason is None:
            lease = self.sessions.lease(session)
            if lease is None:
                reason = self.admission.deny(REASON_NO_SLOT)
                # The rate token is spent; under a full-pinned table that
                # is the conservative direction (sheds harder, not softer).
            else:
                reason = self._ladder_deny(lease[0], payload, now)
                if reason is not None:
                    self.sessions.release(lease[0])
                    lease = None
        if reason is not None:
            pred = 1 if f >= 0.5 else 0
            self.metrics.counter("fallback_total").inc()
            account_outcome(self.metrics, self.cfg.hi, pred, y, 0.0)
            return PlaneResult(pred=pred, denied=True, reason=reason)
        slot, evicted = lease
        self.metrics.counter("admitted_total").inc()
        if evicted:
            self.metrics.counter("slot_reclaims").inc()
            if self.cfg.restart_on_reclaim:
                self.batcher.restart_stream(slot)
        req = Request(
            session=int(session), stream=slot, f=float(f), hr=int(hr),
            y=int(y), payload_bytes=payload, t_arrival=now)
        try:
            return await self.batcher.enqueue(req)
        finally:
            self.sessions.release(slot)

    async def drain(self) -> None:
        """Finish every queued request, transfer, and feedback round."""
        await self.batcher.drain()

    def summary(self) -> Dict[str, float]:
        """The metrics snapshot plus the derived rates the benchmark rows
        and acceptance checks consume. Deterministic for a fixed seed."""
        snap = self.metrics.snapshot()
        n = max(snap.get("requests_total", 0.0), 1.0)
        labeled = max(snap.get("labeled_total", 0.0), 1.0)
        snap["deny_rate"] = snap.get("denied_total", 0.0) / n
        snap["offload_rate"] = snap.get("completed_remote", 0.0) / n
        snap["drop_rate"] = snap.get("capacity_dropped", 0.0) / n
        snap["fallback_rate"] = snap.get("fallback_total", 0.0) / n
        snap["exhausted_rate"] = snap.get("retry_exhausted", 0.0) / n
        snap["avg_offload_cost"] = snap.get("observed_cost", 0.0) / n
        snap["avg_true_cost"] = snap.get("true_cost", 0.0) / labeled
        snap["accuracy"] = snap.get("correct_total", 0.0) / labeled
        snap["session_evictions"] = float(self.sessions.evictions)
        return snap


async def _drive(plane: RequestPlane, arrivals) -> List[PlaneResult]:
    """Open-loop replay: submit each arrival at its virtual timestamp
    without waiting for earlier completions (they overlap, as in a real
    front-end)."""
    loop = asyncio.get_running_loop()
    gaps = np.asarray(arrivals.gaps, np.float64)
    sessions = np.asarray(arrivals.sessions)
    fs = np.asarray(arrivals.fs, np.float64)
    hrs = np.asarray(arrivals.hrs)
    ys = np.asarray(arrivals.ys)
    payloads = np.asarray(arrivals.payloads, np.float64)
    times = np.cumsum(gaps)
    t0 = loop.time()
    tasks = []
    for i in range(times.shape[0]):
        dt = t0 + times[i] - loop.time()
        if dt > 0:
            await asyncio.sleep(dt)
        tasks.append(loop.create_task(plane.submit(
            session=int(sessions[i]), f=float(fs[i]), hr=int(hrs[i]),
            y=int(ys[i]), payload_bytes=float(payloads[i]))))
    results = await asyncio.gather(*tasks)
    await plane.drain()
    return list(results)


def serve_traffic(
    cfg: RequestPlaneConfig,
    arrivals,                       # ArrivalBatch (repro.data.traffic)
    key: Optional[jax.Array] = None,
) -> Tuple[RequestPlane, List[PlaneResult], Dict[str, float]]:
    """Serve one seeded traffic trace end to end on the virtual clock.

    Returns (plane, per-request results in arrival order, summary). Fully
    deterministic: the trace is seed-threaded, the link is seeded, and the
    loop is virtual — the same inputs produce the identical summary dict.
    """
    plane = RequestPlane(cfg, key)
    results = run_virtual(_drive(plane, arrivals))
    return plane, results, plane.summary()
