"""The transport layer: the `Link` protocol, its deterministic doubles, and
live network estimation (measured transfer times → the per-stream β vector).

Everywhere else in this repo the offloading cost β is *synthesized* by a
`ScenarioSource`; a deployed edge system has to measure it — over a network
that drops, stalls, and garbles. This module closes that loop:

  `Link`            — the transport protocol every backend implements:
                      `send(stream, payload_bytes) -> float` (the measured
                      transfer seconds) plus capability flags. A send may
                      raise a `LinkError` subclass; the resilience layer
                      (`resilience.py`) owns retries and timeouts, the link
                      only reports what the wire did. A real deployment
                      implements this with an aiohttp probe or the actual
                      RDL RPC (ROADMAP follow-up).
  `SimulatedLink`   — the deterministic healthy double: per-stream RTT with
                      jitter, payload/bandwidth serialization, and two-state
                      Markov congestion episodes (the `beta_process`
                      "bursty" dynamics, but happening *to* the transport
                      instead of being handed to the policy). Never raises.
  `FaultyLink`      — a composable fault injector wrapping any `Link`:
                      seeded per-send drops, corrupted responses, Pareto
                      (heavy-tailed) straggler delays, and full outage
                      windows — scheduled on the loop clock or driven by a
                      per-send Markov chain. With every fault knob at zero
                      it is a pure passthrough (no PRNG draws, no time
                      added), so a fault-free wrapped run is bit-identical
                      to the bare link.
  `NetworkEstimator`— rolling per-stream estimation over whatever the link
                      reports: EWMA of the de-payloaded RTT plus a windowed
                      percentile (the SNIPPETS.md `offloadagent.py` recipe:
                      rolling RTT window + a transmit-cost model), converted
                      into the β each stream would pay to offload right now
                      (`beta_vector`, consumed by the micro-batcher every
                      decide round). Failed/timed-out sends fold into the
                      percentile window only (`observe(..., ok=False)`) —
                      they are the tail congestion p95 must price, but
                      their caps are not measured RTTs the EWMA may trust.

β conversion: a predicted transfer of `latency_ref` seconds costs β = 1
(the paper's normalized β ≤ 1); everything scales linearly and clips to
[beta_floor, beta_cap]. The estimator is pure host-side state — tiny S-sized
arrays every flush — so it adds nothing to the device hot path.
"""
from __future__ import annotations

import asyncio
import dataclasses
import random
from collections import deque
from typing import Deque, Dict, List, Optional, Protocol, Tuple, runtime_checkable

import numpy as np


# ------------------------------ link protocol ---------------------------------


class LinkError(RuntimeError):
    """A send failed at the transport. `elapsed` is the loop-seconds the
    sender spent before the failure surfaced (0 for fast failures) — what a
    caller actually observed, and all it may feed the estimator."""

    def __init__(self, msg: str, elapsed: float = 0.0):
        super().__init__(msg)
        self.elapsed = float(elapsed)


class SendDropped(LinkError):
    """The payload (or its response) was lost in flight: the full transfer
    time passed, then the connection reset — no result ever arrived."""


class SendCorrupted(LinkError):
    """A response arrived on time but failed integrity checks. Its timing
    IS a valid RTT measurement; its content is unusable."""


class LinkOutage(LinkError):
    """The remote is unreachable (connection refused): fails fast, before
    any transfer time is spent."""


@runtime_checkable
class Link(Protocol):
    """What the request plane requires of a transport backend.

    `send` transfers `payload_bytes` on `stream`'s connection and returns
    the measured transfer seconds; it may raise a `LinkError` subclass.
    Capability flags let callers reason about a backend without probing it:

      `deterministic` — same seed ⇒ same transfer times and faults (true
          for the simulated doubles; False for any real transport). Tests
          and benchmarks only assert reproducibility when the link says so.
      `lossy` — `send` may raise `LinkError` (True for `FaultyLink` and any
          real transport; the bare `SimulatedLink` never fails).
    """

    deterministic: bool
    lossy: bool

    async def send(self, stream: int, payload_bytes: float) -> float:
        ...


@dataclasses.dataclass(frozen=True)
class LinkConfig:
    """Simulated transport: rtt = base ± jitter (+ congestion), then the
    payload serializes at `bandwidth` bytes/s.

    Congestion is a per-stream two-state Markov chain stepped once per send
    (p_up to enter, p_down to leave, `congested_extra` seconds while in it)
    — the transport-side analogue of the `beta_process` bursty regime. All
    randomness comes from one seeded PRNG per stream, so a virtual-clock
    run is exactly reproducible.
    """

    base_rtt: float = 0.02         # s, uncongested round trip
    jitter: float = 0.004          # s, uniform ±jitter per send
    bandwidth: float = 1.0e6       # bytes/s serialization rate
    congested_extra: float = 0.08  # s added while the stream is congested
    p_up: float = 0.02             # P(uncongested → congested) per send
    p_down: float = 0.2            # P(congested → uncongested) per send
    seed: int = 0

    def __post_init__(self):
        if self.base_rtt < 0 or self.jitter < 0 or self.congested_extra < 0:
            raise ValueError("link delays must be non-negative")
        if self.bandwidth <= 0:
            raise ValueError(f"bandwidth must be positive (got {self.bandwidth})")
        if not (0 <= self.p_up <= 1 and 0 <= self.p_down <= 1):
            raise ValueError("transition probabilities must lie in [0, 1]")


class SimulatedLink:
    """Deterministic simulated transport with per-stream congestion state."""

    deterministic = True
    lossy = False

    def __init__(self, cfg: LinkConfig):
        self.cfg = cfg
        self._rngs: Dict[int, random.Random] = {}
        self._congested: Dict[int, bool] = {}

    def _rng(self, stream: int) -> random.Random:
        rng = self._rngs.get(stream)
        if rng is None:
            # Disjoint deterministic streams: one PRNG per stream slot.
            rng = self._rngs[stream] = random.Random(
                self.cfg.seed * 1_000_003 + stream)
        return rng

    def transfer_time(self, stream: int, payload_bytes: float) -> float:
        """Sample this send's transfer time (steps the congestion chain)."""
        cfg = self.cfg
        rng = self._rng(stream)
        congested = self._congested.get(stream, False)
        u = rng.random()
        congested = (u >= cfg.p_down) if congested else (u < cfg.p_up)
        self._congested[stream] = congested
        rtt = cfg.base_rtt + rng.uniform(-cfg.jitter, cfg.jitter)
        if congested:
            rtt += cfg.congested_extra
        return max(rtt, 0.0) + payload_bytes / cfg.bandwidth

    async def send(self, stream: int, payload_bytes: float) -> float:
        """Transfer `payload_bytes` on `stream`: sleeps the sampled transfer
        time on the running loop's clock and returns it (the "measurement").
        Under `VirtualTimeLoop` the sleep is instantaneous wall-clock."""
        dt = self.transfer_time(stream, payload_bytes)
        await asyncio.sleep(dt)
        return dt


# ------------------------------ fault injection -------------------------------


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Seeded fault model for `FaultyLink`, reproducible on a virtual clock.

    Four independent fault families, each off at its default:

      drops       — with `drop_prob` per send, the transfer's full time
                    passes, then `SendDropped` (response lost in flight).
      corruption  — with `corrupt_prob` per (non-dropped) send, the response
                    arrives on schedule but raises `SendCorrupted`.
      stragglers  — with `straggler_prob` per send, a Pareto-distributed
                    extra delay of `straggler_scale·((1−u)^(−1/shape) − 1)`
                    seconds stretches the transfer: the heavy tail that
                    makes per-send deadlines (not means) the right defense.
      outages     — the remote is unreachable: `LinkOutage` raised fast,
                    before any transfer time. Scheduled `outage_windows`
                    are (start, end) pairs on the loop clock; the Markov
                    mode steps a per-stream chain once per send
                    (`outage_p_enter` to go dark, `outage_p_exit` to come
                    back) — bursty unavailability like the congestion
                    episodes, but fatal instead of slow.

    All randomness comes from one seeded PRNG per stream (disjoint from the
    wrapped link's), so fault traces are exactly reproducible and the
    wrapped link's own draw sequence is never perturbed.
    """

    drop_prob: float = 0.0
    corrupt_prob: float = 0.0
    straggler_prob: float = 0.0
    straggler_scale: float = 0.2       # s, Pareto scale of the extra delay
    straggler_shape: float = 1.5       # Pareto tail index (lower = heavier)
    outage_windows: Tuple[Tuple[float, float], ...] = ()
    outage_p_enter: float = 0.0        # per-send P(reachable → outage)
    outage_p_exit: float = 0.25        # per-send P(outage → reachable)
    seed: int = 0

    def __post_init__(self):
        for name in ("drop_prob", "corrupt_prob", "straggler_prob",
                     "outage_p_enter", "outage_p_exit"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must lie in [0, 1] (got {v})")
        if self.straggler_scale < 0 or self.straggler_shape <= 0:
            raise ValueError("straggler scale must be ≥ 0 and shape > 0")
        windows = tuple((float(a), float(b)) for a, b in self.outage_windows)
        if any(b <= a for a, b in windows):
            raise ValueError(
                f"outage windows must be (start, end) with end > start "
                f"(got {windows})")
        object.__setattr__(self, "outage_windows", windows)

    @property
    def fault_free(self) -> bool:
        """True when every fault family is disabled — `FaultyLink` is then
        a pure passthrough (the parity guarantee)."""
        return (self.drop_prob == 0.0 and self.corrupt_prob == 0.0
                and self.straggler_prob == 0.0 and not self.outage_windows
                and self.outage_p_enter == 0.0)


class FaultyLink:
    """Composable fault injector over any `Link` (see `FaultConfig`).

    Per-send draw order is fixed (outage chain, drop, corrupt, straggler),
    each guarded by its knob so disabled families consume no randomness:
    with `cfg.fault_free` the wrapper forwards the send untouched, which is
    what makes the zero-fault run bit-identical to the bare link.

    `injected` counts faults by family — ground truth for chaos tests
    (the resilience layer's counters must reconcile against it).
    """

    deterministic = True
    lossy = True

    def __init__(self, inner: Link, cfg: FaultConfig):
        self.inner = inner
        self.cfg = cfg
        self._rngs: Dict[int, random.Random] = {}
        self._out: Dict[int, bool] = {}
        self.injected = {"outage": 0, "drop": 0, "corrupt": 0,
                         "straggler": 0}

    def _rng(self, stream: int) -> random.Random:
        rng = self._rngs.get(stream)
        if rng is None:
            rng = self._rngs[stream] = random.Random(
                self.cfg.seed * 2_000_003 + stream)
        return rng

    def in_scheduled_outage(self, now: float) -> bool:
        return any(a <= now < b for a, b in self.cfg.outage_windows)

    async def send(self, stream: int, payload_bytes: float) -> float:
        cfg = self.cfg
        if cfg.fault_free:
            return await self.inner.send(stream, payload_bytes)
        if cfg.outage_windows:
            now = asyncio.get_running_loop().time()
            if self.in_scheduled_outage(now):
                self.injected["outage"] += 1
                raise LinkOutage(f"scheduled outage at t={now:.3f}")
        rng = self._rng(stream)
        if cfg.outage_p_enter > 0.0:
            out = self._out.get(stream, False)
            u = rng.random()
            out = (u >= cfg.outage_p_exit) if out else (u < cfg.outage_p_enter)
            self._out[stream] = out
            if out:
                self.injected["outage"] += 1
                raise LinkOutage(f"markov outage on stream {stream}")
        drop = cfg.drop_prob > 0.0 and rng.random() < cfg.drop_prob
        corrupt = (cfg.corrupt_prob > 0.0
                   and rng.random() < cfg.corrupt_prob and not drop)
        extra = 0.0
        if cfg.straggler_prob > 0.0 and rng.random() < cfg.straggler_prob:
            u = rng.random()
            extra = cfg.straggler_scale * (
                (1.0 - u) ** (-1.0 / cfg.straggler_shape) - 1.0)
            self.injected["straggler"] += 1
        dt = await self.inner.send(stream, payload_bytes)
        if extra > 0.0:
            await asyncio.sleep(extra)
            dt += extra
        if drop:
            self.injected["drop"] += 1
            raise SendDropped(
                f"response lost on stream {stream}", elapsed=dt)
        if corrupt:
            self.injected["corrupt"] += 1
            raise SendCorrupted(
                f"corrupted response on stream {stream}", elapsed=dt)
        return dt


@dataclasses.dataclass(frozen=True)
class EstimatorConfig:
    """Rolling-estimate + β-conversion knobs.

    `bw_hint` is the payload normalizer used to strip the serialization
    term out of a measured transfer (measured − payload/bw_hint ≈ RTT) and
    to add it back when predicting a future transfer. `beta_source`
    selects the predictor: "ewma" (the mean path) or "p95" (the windowed
    percentile — a pessimistic β that prices tail congestion in).
    """

    alpha: float = 0.25            # EWMA weight on the newest sample
    window: int = 64               # rolling window for the percentile
    bw_hint: float = 1.0e6         # bytes/s payload normalizer
    latency_ref: float = 0.25      # transfer seconds that cost β = 1
    beta_floor: float = 0.01
    beta_cap: float = 1.0
    prior_rtt: float = 0.05        # per-stream estimate before any sample
    beta_source: str = "ewma"      # "ewma" | "p95"

    def __post_init__(self):
        if not 0 < self.alpha <= 1:
            raise ValueError(f"alpha must lie in (0, 1] (got {self.alpha})")
        if self.window < 1:
            raise ValueError(f"window must be ≥ 1 (got {self.window})")
        if self.latency_ref <= 0:
            raise ValueError("latency_ref must be positive")
        if not 0 <= self.beta_floor <= self.beta_cap:
            raise ValueError(
                f"need 0 ≤ beta_floor ≤ beta_cap, got "
                f"({self.beta_floor}, {self.beta_cap})")
        if self.beta_source not in ("ewma", "p95"):
            raise ValueError(
                f"unknown beta_source {self.beta_source!r}; "
                "expected 'ewma' or 'p95'")


class NetworkEstimator:
    """Per-stream rolling RTT estimation and the live β vector.

    `observe(stream, seconds, payload_bytes)` folds one measured transfer
    in; `beta_vector(payloads)` prices an offload *now* for every stream.
    Streams with no samples yet sit at `prior_rtt` so cold-start β is
    defined (and conservative rather than free).

    `observe(..., ok=False)` records a failed or timed-out send: the
    elapsed time (the timeout cap, or the time until the reset) enters the
    percentile window — exactly the tail congestion a p95 predictor must
    price — but never the EWMA, which models *measured* RTTs and would be
    silently biased by caps that are lower bounds, not measurements.
    """

    def __init__(self, cfg: EstimatorConfig, n_streams: int):
        self.cfg = cfg
        self.n_streams = int(n_streams)
        self._rtt = np.full((n_streams,), cfg.prior_rtt, np.float64)
        self._seen = np.zeros((n_streams,), bool)
        self._windows: List[Deque[float]] = [
            deque(maxlen=cfg.window) for _ in range(n_streams)]
        self.n_samples = 0
        self.n_failures = 0

    def observe(self, stream: int, seconds: float,
                payload_bytes: float, ok: bool = True) -> None:
        """Fold one transfer observation into stream `stream`'s estimate.

        `ok=False` marks a send that never completed (`seconds` is then the
        elapsed time until the failure surfaced): it inflates the windowed
        percentile but leaves the EWMA untouched.
        """
        cfg = self.cfg
        rtt = max(seconds - payload_bytes / cfg.bw_hint, 0.0)
        if ok:
            if self._seen[stream]:
                self._rtt[stream] += cfg.alpha * (rtt - self._rtt[stream])
            else:
                self._rtt[stream] = rtt      # first sample replaces the prior
                self._seen[stream] = True
        else:
            self.n_failures += 1
        self._windows[stream].append(rtt)
        self.n_samples += 1

    def rtt_estimate(self, stream: int) -> float:
        return float(self._rtt[stream])

    def rtt_percentile(self, q: float,
                       stream: Optional[int] = None) -> float:
        """Windowed RTT percentile — one stream's window, or all pooled.
        Falls back to the EWMA estimate when no samples are windowed."""
        if stream is None:
            pooled = [x for w in self._windows for x in w]
        else:
            pooled = list(self._windows[stream])
        if not pooled:
            return float(np.mean(self._rtt))
        return float(np.percentile(np.asarray(pooled), q * 100.0))

    def predict_transfer(self, stream: int, payload_bytes: float = 0.0,
                         q: float = 0.95) -> float:
        """Pessimistic transfer-time prediction for one stream: the windowed
        q-percentile RTT (EWMA before any windowed sample) plus the payload
        serialization term — what the latency-SLO admission ladder compares
        against its deadline *before* spending network budget."""
        rtt = (self.rtt_percentile(q, stream) if self._windows[stream]
               else float(self._rtt[stream]))
        return rtt + payload_bytes / self.cfg.bw_hint

    def _predict(self, payloads: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        if cfg.beta_source == "p95":
            rtt = np.asarray([
                self.rtt_percentile(0.95, s) if self._windows[s]
                else self._rtt[s]
                for s in range(self.n_streams)])
        else:
            rtt = self._rtt
        return rtt + payloads / cfg.bw_hint

    def beta_vector(self, payloads=None) -> np.ndarray:
        """(S,) float32 — the β each stream would pay to offload now.

        `payloads` is scalar or (S,) expected payload bytes (0 prices the
        bare RTT). This is the vector the micro-batcher snapshots every
        decide round and charges at feedback time.
        """
        payloads = np.broadcast_to(
            np.asarray(0.0 if payloads is None else payloads, np.float64),
            (self.n_streams,))
        beta = self._predict(payloads) / self.cfg.latency_ref
        return np.clip(beta, self.cfg.beta_floor,
                       self.cfg.beta_cap).astype(np.float32)
