"""Live network estimation: measured transfer times → the per-stream β vector.

Everywhere else in this repo the offloading cost β is *synthesized* by a
`ScenarioSource`; a deployed edge system has to measure it. This module
closes that loop with two pieces:

  `SimulatedLink`   — the pluggable transport backend: per-stream RTT with
                      jitter, payload/bandwidth serialization, and two-state
                      Markov congestion episodes (the `beta_process`
                      "bursty" dynamics, but happening *to* the transport
                      instead of being handed to the policy). A real
                      deployment swaps in an aiohttp-probe backend with the
                      same `send(stream, payload_bytes)` coroutine.
  `NetworkEstimator`— rolling per-stream estimation over whatever the link
                      reports: EWMA of the de-payloaded RTT plus a windowed
                      percentile (the SNIPPETS.md `offloadagent.py` recipe:
                      rolling RTT window + a transmit-cost model), converted
                      into the β each stream would pay to offload right now
                      (`beta_vector`, consumed by the micro-batcher every
                      decide round).

β conversion: a predicted transfer of `latency_ref` seconds costs β = 1
(the paper's normalized β ≤ 1); everything scales linearly and clips to
[beta_floor, beta_cap]. The estimator is pure host-side state — tiny S-sized
arrays every flush — so it adds nothing to the device hot path.
"""
from __future__ import annotations

import asyncio
import dataclasses
import random
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class LinkConfig:
    """Simulated transport: rtt = base ± jitter (+ congestion), then the
    payload serializes at `bandwidth` bytes/s.

    Congestion is a per-stream two-state Markov chain stepped once per send
    (p_up to enter, p_down to leave, `congested_extra` seconds while in it)
    — the transport-side analogue of the `beta_process` bursty regime. All
    randomness comes from one seeded PRNG per stream, so a virtual-clock
    run is exactly reproducible.
    """

    base_rtt: float = 0.02         # s, uncongested round trip
    jitter: float = 0.004          # s, uniform ±jitter per send
    bandwidth: float = 1.0e6       # bytes/s serialization rate
    congested_extra: float = 0.08  # s added while the stream is congested
    p_up: float = 0.02             # P(uncongested → congested) per send
    p_down: float = 0.2            # P(congested → uncongested) per send
    seed: int = 0

    def __post_init__(self):
        if self.base_rtt < 0 or self.jitter < 0 or self.congested_extra < 0:
            raise ValueError("link delays must be non-negative")
        if self.bandwidth <= 0:
            raise ValueError(f"bandwidth must be positive (got {self.bandwidth})")
        if not (0 <= self.p_up <= 1 and 0 <= self.p_down <= 1):
            raise ValueError("transition probabilities must lie in [0, 1]")


class SimulatedLink:
    """Deterministic simulated transport with per-stream congestion state."""

    def __init__(self, cfg: LinkConfig):
        self.cfg = cfg
        self._rngs: Dict[int, random.Random] = {}
        self._congested: Dict[int, bool] = {}

    def _rng(self, stream: int) -> random.Random:
        rng = self._rngs.get(stream)
        if rng is None:
            # Disjoint deterministic streams: one PRNG per stream slot.
            rng = self._rngs[stream] = random.Random(
                self.cfg.seed * 1_000_003 + stream)
        return rng

    def transfer_time(self, stream: int, payload_bytes: float) -> float:
        """Sample this send's transfer time (steps the congestion chain)."""
        cfg = self.cfg
        rng = self._rng(stream)
        congested = self._congested.get(stream, False)
        u = rng.random()
        congested = (u >= cfg.p_down) if congested else (u < cfg.p_up)
        self._congested[stream] = congested
        rtt = cfg.base_rtt + rng.uniform(-cfg.jitter, cfg.jitter)
        if congested:
            rtt += cfg.congested_extra
        return max(rtt, 0.0) + payload_bytes / cfg.bandwidth

    async def send(self, stream: int, payload_bytes: float) -> float:
        """Transfer `payload_bytes` on `stream`: sleeps the sampled transfer
        time on the running loop's clock and returns it (the "measurement").
        Under `VirtualTimeLoop` the sleep is instantaneous wall-clock."""
        dt = self.transfer_time(stream, payload_bytes)
        await asyncio.sleep(dt)
        return dt


@dataclasses.dataclass(frozen=True)
class EstimatorConfig:
    """Rolling-estimate + β-conversion knobs.

    `bw_hint` is the payload normalizer used to strip the serialization
    term out of a measured transfer (measured − payload/bw_hint ≈ RTT) and
    to add it back when predicting a future transfer. `beta_source`
    selects the predictor: "ewma" (the mean path) or "p95" (the windowed
    percentile — a pessimistic β that prices tail congestion in).
    """

    alpha: float = 0.25            # EWMA weight on the newest sample
    window: int = 64               # rolling window for the percentile
    bw_hint: float = 1.0e6         # bytes/s payload normalizer
    latency_ref: float = 0.25      # transfer seconds that cost β = 1
    beta_floor: float = 0.01
    beta_cap: float = 1.0
    prior_rtt: float = 0.05        # per-stream estimate before any sample
    beta_source: str = "ewma"      # "ewma" | "p95"

    def __post_init__(self):
        if not 0 < self.alpha <= 1:
            raise ValueError(f"alpha must lie in (0, 1] (got {self.alpha})")
        if self.window < 1:
            raise ValueError(f"window must be ≥ 1 (got {self.window})")
        if self.latency_ref <= 0:
            raise ValueError("latency_ref must be positive")
        if not 0 <= self.beta_floor <= self.beta_cap:
            raise ValueError(
                f"need 0 ≤ beta_floor ≤ beta_cap, got "
                f"({self.beta_floor}, {self.beta_cap})")
        if self.beta_source not in ("ewma", "p95"):
            raise ValueError(
                f"unknown beta_source {self.beta_source!r}; "
                "expected 'ewma' or 'p95'")


class NetworkEstimator:
    """Per-stream rolling RTT estimation and the live β vector.

    `observe(stream, seconds, payload_bytes)` folds one measured transfer
    in; `beta_vector(payloads)` prices an offload *now* for every stream.
    Streams with no samples yet sit at `prior_rtt` so cold-start β is
    defined (and conservative rather than free).
    """

    def __init__(self, cfg: EstimatorConfig, n_streams: int):
        self.cfg = cfg
        self.n_streams = int(n_streams)
        self._rtt = np.full((n_streams,), cfg.prior_rtt, np.float64)
        self._seen = np.zeros((n_streams,), bool)
        self._windows: List[Deque[float]] = [
            deque(maxlen=cfg.window) for _ in range(n_streams)]
        self.n_samples = 0

    def observe(self, stream: int, seconds: float,
                payload_bytes: float) -> None:
        """Fold one measured transfer into stream `stream`'s estimate."""
        cfg = self.cfg
        rtt = max(seconds - payload_bytes / cfg.bw_hint, 0.0)
        if self._seen[stream]:
            self._rtt[stream] += cfg.alpha * (rtt - self._rtt[stream])
        else:
            self._rtt[stream] = rtt          # first sample replaces the prior
            self._seen[stream] = True
        self._windows[stream].append(rtt)
        self.n_samples += 1

    def rtt_estimate(self, stream: int) -> float:
        return float(self._rtt[stream])

    def rtt_percentile(self, q: float,
                       stream: Optional[int] = None) -> float:
        """Windowed RTT percentile — one stream's window, or all pooled.
        Falls back to the EWMA estimate when no samples are windowed."""
        if stream is None:
            pooled = [x for w in self._windows for x in w]
        else:
            pooled = list(self._windows[stream])
        if not pooled:
            return float(np.mean(self._rtt))
        return float(np.percentile(np.asarray(pooled), q * 100.0))

    def _predict(self, payloads: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        if cfg.beta_source == "p95":
            rtt = np.asarray([
                self.rtt_percentile(0.95, s) if self._windows[s]
                else self._rtt[s]
                for s in range(self.n_streams)])
        else:
            rtt = self._rtt
        return rtt + payloads / cfg.bw_hint

    def beta_vector(self, payloads=None) -> np.ndarray:
        """(S,) float32 — the β each stream would pay to offload now.

        `payloads` is scalar or (S,) expected payload bytes (0 prices the
        bare RTT). This is the vector the micro-batcher snapshots every
        decide round and charges at feedback time.
        """
        payloads = np.broadcast_to(
            np.asarray(0.0 if payloads is None else payloads, np.float64),
            (self.n_streams,))
        beta = self._predict(payloads) / self.cfg.latency_ref
        return np.clip(beta, self.cfg.beta_floor,
                       self.cfg.beta_cap).astype(np.float32)
