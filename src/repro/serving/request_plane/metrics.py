"""Lightweight metrics for the request plane: counters, gauges, and
streaming latency quantiles.

The registry is deliberately tiny and dependency-free — the request plane
runs inside an asyncio event loop where a heavyweight metrics client would
dominate the micro-batch cadence. Quantiles use the P² (piecewise-parabolic)
streaming estimator [Jain & Chlamtac 1985]: O(1) memory per tracked
quantile, fully deterministic (no sampling), which keeps the virtual-clock
contract that the same seed produces the identical exported summary.

Everything exports through `Metrics.snapshot()` as one flat name → float
dict, the shape the server summary and the benchmark rows consume.

The metric namespace, by layer (counters unless noted):

  ingress     requests_total, admitted_total, denied_total +
              denied_{reason} per denial reason (`admission.py` — incl. the
              ladder's `breaker_open`/`slo_miss`), fallback_total,
              slot_reclaims
  batcher     rounds_total, batched_requests, feedback_rounds,
              completed_local, completed_remote, capacity_dropped,
              retry_exhausted, queue_depth (gauge), latency_ms (quantiles)
  resilience  retries_total, retry_backoff_s, send_timeouts, send_drops,
              send_outages, send_corrupted, send_recovered,
              breaker_opens/breaker_closes/breaker_probes, and the state
              gauges breaker_{closed,open,half_open}_streams
  accounting  observed_cost, true_cost, labeled_total, correct_total

The conservation identities every run must satisfy exactly (chaos-tested
under injected faults):

  requests_total == admitted_total + denied_total
  admitted_total == completed_local + completed_remote
                    + capacity_dropped + retry_exhausted
  fallback_total == denied_total + capacity_dropped + retry_exhausted
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Tuple


class Counter:
    """Monotone accumulator (counts or cost sums)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Gauge:
    """Last-write-wins instantaneous value (queue depth, β estimate)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class P2Quantile:
    """Streaming quantile via the P² algorithm: five markers whose heights
    track (min, q/2, q, (1+q)/2, max) with parabolic height adjustment.

    Exact for the first five observations (sorted buffer); afterwards O(1)
    per observation. Deterministic — repeated runs over the same sample
    sequence produce bit-identical estimates.
    """

    __slots__ = ("q", "_heights", "_pos", "_count", "_init")

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must lie in (0, 1), got {q}")
        self.q = q
        self._init: List[float] = []
        self._heights: List[float] = []
        self._pos: List[float] = []
        self._count = 0

    def observe(self, x: float) -> None:
        self._count += 1
        if len(self._init) < 5:
            bisect.insort(self._init, float(x))
            if len(self._init) == 5:
                self._heights = list(self._init)
                self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
            return
        h, n = self._heights, self._pos
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and h[k + 1] <= x:
                k += 1
        for i in range(k + 1, 5):
            n[i] += 1.0
        # Desired positions for count N: 1 + (N-1)·(0, q/2, q, (1+q)/2, 1).
        q = self.q
        total = float(self._count)
        desired = (1.0,
                   1.0 + (total - 1.0) * q / 2.0,
                   1.0 + (total - 1.0) * q,
                   1.0 + (total - 1.0) * (1.0 + q) / 2.0,
                   total)
        for i in (1, 2, 3):
            d = desired[i] - n[i]
            if ((d >= 1.0 and n[i + 1] - n[i] > 1.0)
                    or (d <= -1.0 and n[i - 1] - n[i] < -1.0)):
                step = 1.0 if d > 0 else -1.0
                cand = self._parabolic(i, step)
                if not h[i - 1] < cand < h[i + 1]:
                    cand = self._linear(i, step)
                h[i] = cand
                n[i] += step

    def _parabolic(self, i: int, d: float) -> float:
        h, n = self._heights, self._pos
        return h[i] + d / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + d) * (h[i + 1] - h[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - d) * (h[i] - h[i - 1]) / (n[i] - n[i - 1]))

    def _linear(self, i: int, d: float) -> float:
        h, n = self._heights, self._pos
        j = i + int(d)
        return h[i] + d * (h[j] - h[i]) / (n[j] - n[i])

    def value(self) -> float:
        """Current estimate (exact below five samples; 0.0 when empty)."""
        if not self._count:
            return 0.0
        if len(self._init) < 5:
            # Exact interpolated percentile of the sorted prefix.
            xs = self._init
            rank = self.q * (len(xs) - 1)
            lo = int(rank)
            hi = min(lo + 1, len(xs) - 1)
            return xs[lo] + (rank - lo) * (xs[hi] - xs[lo])
        return self._heights[2]


class Quantiles:
    """A set of P² estimators over one observation stream, plus count/sum
    so the snapshot can report a mean next to the percentiles."""

    def __init__(self, qs: Tuple[float, ...] = (0.5, 0.95, 0.99)):
        self.qs = tuple(qs)
        self._est = {q: P2Quantile(q) for q in self.qs}
        self.count = 0
        self.total = 0.0

    def observe(self, x: float) -> None:
        self.count += 1
        self.total += x
        for est in self._est.values():
            est.observe(x)

    def value(self, q: float) -> float:
        return self._est[q].value()

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class Metrics:
    """Name-keyed registry. `counter`/`gauge`/`quantiles` create on first
    use, so instrumentation sites never pre-declare."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._quantiles: Dict[str, Quantiles] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def quantiles(self, name: str,
                  qs: Tuple[float, ...] = (0.5, 0.95, 0.99)) -> Quantiles:
        s = self._quantiles.get(name)
        if s is None:
            s = self._quantiles[name] = Quantiles(qs)
        return s

    def snapshot(self) -> Dict[str, float]:
        """Flatten everything into one name → float dict.

        Quantile streams export `p{XX}_{name}` per tracked quantile plus
        `{name}_mean`/`{name}_count` — the `p50_*`/`p95_*`/`p99_*` prefixes
        are what `benchmarks/check_regression.py` recognizes as
        latency-style metrics.
        """
        out: Dict[str, float] = {}
        for name, c in sorted(self._counters.items()):
            out[name] = c.value
        for name, g in sorted(self._gauges.items()):
            out[name] = g.value
        for name, s in sorted(self._quantiles.items()):
            for q in s.qs:
                out[f"p{int(round(q * 100)):02d}_{name}"] = s.value(q)
            out[f"{name}_mean"] = s.mean()
            out[f"{name}_count"] = float(s.count)
        return out
