"""Offload batching: collect variable offload sets into fixed-shape RDL batches.

JIT-shape-stable: each slot produces a (max_offload, L) padded batch + validity
mask, built with argsort-free compaction (cumsum positions + scatter).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OffloadBatch(NamedTuple):
    tokens: jnp.ndarray     # (C, L) padded
    valid: jnp.ndarray      # (C,) bool
    src: jnp.ndarray        # (C,) int32 — originating stream index (or -1)


def compact_offloads(
    tokens: jnp.ndarray,     # (S, L)
    offload: jnp.ndarray,    # (S,) bool
    capacity: int,
) -> OffloadBatch:
    """Pack the offloaded rows densely into a fixed-capacity batch."""
    s, l = tokens.shape
    pos = jnp.cumsum(offload.astype(jnp.int32)) - 1          # target slot per row
    dest = jnp.where(offload, pos, capacity)                  # overflow → dropped row
    dest = jnp.minimum(dest, capacity)                        # clamp overflow
    out_tokens = jnp.zeros((capacity + 1, l), tokens.dtype)
    out_src = jnp.full((capacity + 1,), -1, jnp.int32)
    out_tokens = out_tokens.at[dest].set(tokens)
    out_src = out_src.at[dest].set(jnp.arange(s, dtype=jnp.int32))
    out_tokens, out_src = out_tokens[:capacity], out_src[:capacity]
    valid = out_src >= 0
    return OffloadBatch(tokens=out_tokens, valid=valid, src=out_src)


def scatter_results(
    results: jnp.ndarray,    # (C,) RDL outputs for the packed batch
    batch: OffloadBatch,
    n_streams: int,
    fill: int = 0,
) -> jnp.ndarray:
    """Route packed RDL outputs back to their source streams."""
    src = jnp.where(batch.valid, batch.src, n_streams)
    padded = jnp.full((n_streams + 1,), fill, results.dtype).at[src].set(
        jnp.where(batch.valid, results, fill))
    return padded[:n_streams]
