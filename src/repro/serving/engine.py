"""Serving engine: jitted prefill / decode steps and greedy generation for
one backbone, plus the LDL/RDL classifier entry point.

The H2T2 policy side of serving lives in `repro.serving.policy_engine`
(`PolicyEngine` protocol + registry: "reference" | "fused" | "sharded")."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import DecodeState, decode_step, prefill
from repro.models.transformer import RunFlags


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_prompt: int = 256
    max_new_tokens: int = 32
    window: Optional[int] = None     # decode attention-window override
    use_flash: bool = False


class Engine:
    """Thin serving wrapper around one backbone: jitted prefill + decode."""

    def __init__(self, cfg: ModelConfig, params: Any, ecfg: EngineConfig = EngineConfig()):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        cap = ecfg.max_prompt + ecfg.max_new_tokens
        flags = RunFlags(mode="prefill", window=ecfg.window, use_flash=ecfg.use_flash)
        dflags = RunFlags(mode="decode", window=ecfg.window)
        self._prefill = jax.jit(
            lambda p, inputs: prefill(p, cfg, inputs, flags=flags, capacity=cap))
        self._decode = jax.jit(
            lambda p, st, tok: decode_step(p, cfg, st, tok, flags=dflags))

    def prefill(self, inputs: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, DecodeState]:
        return self._prefill(self.params, inputs)

    def decode(self, state: DecodeState, token: jnp.ndarray):
        return self._decode(self.params, state, token)

    def generate(
        self, inputs: Dict[str, jnp.ndarray], n_tokens: Optional[int] = None
    ) -> jnp.ndarray:
        """Greedy generation; returns (B, n_tokens) int32."""
        n = n_tokens or self.ecfg.max_new_tokens
        logits, state = self.prefill(inputs)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        out = [tok]
        for _ in range(n - 1):
            logits, state = self.decode(state, tok)
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
        return jnp.concatenate(out, axis=1)


def classifier_fn(
    cfg: ModelConfig, params: Any, head_params: Any,
    flags: RunFlags = RunFlags(mode="prefill"),
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Build a jitted batched classifier: tokens (B, S) → confidence f (B,).

    This is the LDL/RDL entry point for hierarchical inference: backbone
    features pooled by the binary head into the paper's f_t."""
    from repro.models.heads import binary_head, confidence
    from repro.models.layers import apply_norm
    from repro.models import model as model_lib
    from repro.models.transformer import run_blocks_seq

    @jax.jit
    def run(tokens: jnp.ndarray) -> jnp.ndarray:
        x = model_lib._embed_inputs(params, cfg, {"tokens": tokens})
        positions = jnp.arange(x.shape[1])
        x, _, _ = run_blocks_seq(params["blocks"], cfg, x, positions, flags)
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = binary_head(head_params, x)
        return confidence(logits)

    return run
