"""PolicyEngine protocol + registry: one H2T2 serving API from a single
stream to a sharded pod.

Every engine drives the same four entry points, so `run_fleet`-style
simulation, the benchmarks, and the `HIServer` all speak one interface:

  init(n_streams)                  → fleet H2T2State (leaves batched (S,))
  step(state, fs, betas, hrs, keys)→ one slot for the whole fleet
  run(fs, hrs, betas, key)         → whole (S, T) horizon in one call; also
                                     accepts a ScenarioSource as first arg
  run_source(source, key)          → chunked scan over a ScenarioSource:
                                     per-block aggregates, one-block
                                     trace residency (any horizon)
  decide(state, fs, keys) /        → the two-phase serving flow: decide
  feedback(state, decision, …)       offloads first, apply (possibly
                                     delayed) RDL feedback later. Both
                                     phases route through the split-phase
                                     Pallas kernels (hedge_decide_pallas /
                                     hedge_feedback_pallas) on every engine
                                     except "reference" — kernel on TPU,
                                     jnp oracle elsewhere, interpret=True
                                     forcing the kernel on CPU

Randomness comes in one of two engine-wide modes (the `randomness`
constructor option, validated against `core.counter.RANDOMNESS_MODES`):

  "pre_draw" (default) — `keys` is always (S, 2), one PRNGKey per stream,
      consumed through `draw_psi_zeta`, so every engine makes bit-for-bit
      identical decisions for the same keys.
  "counter"  — no key tree and no materialized (ψ, ζ): draws are
      regenerated in place (in-kernel on the kernel path) from the counter
      position (seed, stream, slot). `keys` then carries the run key (or
      its (2,) uint32 `seed_from_key` seed) and `step`/`decide` take a
      `slot` — the absolute round index. All engines share one counter
      contract (`core.counter.psi_zeta_from_counter`), so decisions again
      do not depend on the engine — including "sharded", whose shards
      offset their stream ids by `axis_index * shard_size` to draw exactly
      the global fleet's bits.

Registered engines:

  "reference" — vmapped per-stream `h2t2_step`; the paper-shaped jnp path.
  "fused"     — batched `fleet_hedge_step` (Pallas kernel on TPU, jnp oracle
                elsewhere); `time_block > 1` drives the multi-round kernel.
  "sharded"   — `shard_map`s the fused step over a device mesh with the
                (S,) stream axis sharded, so one fleet spans a pod. Streams
                are padded up to a device-count multiple; validate on CPU
                with XLA_FLAGS=--xla_force_host_platform_device_count=N.
  "adaptive"  — detect → adapt → restart: an online shift detector
                (`core.shift`) watches each stream's per-slot signal, the
                (η, decay) schedule is conditioned on detector state
                (`core.policy.adapt_schedule`), and a confirmed shift
                restarts that stream's expert weights
                (`core.policy.fleet_restart`). With the detector disabled
                it reduces bit-identically to the fixed-schedule policy.

Use `get_engine(name, hi_cfg, **opts)` to resolve a name, or instantiate the
classes directly. `register_engine` adds new backends (e.g. an RPC-remote
policy) without touching any caller.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.counter import CounterRNG, seed_from_key
from repro.core.execspec import UNSET, ExecSpec, resolve_spec
from repro.core.policy import (
    FleetDecision,
    H2T2State,
    SourceRunOutput,
    StepOutput,
    adapt_schedule,
    draw_fleet_randomness,
    draw_psi_zeta,
    fleet_decide,
    fleet_feedback,
    fleet_init,
    fleet_restart,
    fleet_step_fused,
    h2t2_step,
    run_fleet,
    run_fleet_fused,
    run_fleet_source,
)
from repro.core.registry import Registry
from repro.core.shift import ShiftConfig, ShiftState, shift_init, shift_update
from repro.core.types import HIConfig
from repro.data.scenarios import ScenarioSource

ENGINES: Registry = Registry("policy engine")

# Pre-registry-consolidation alias (same underlying dict); existing code
# mutates it for test cleanup.
_REGISTRY = ENGINES._entries

# ExecSpec fields `get_engine` translates out of a legacy opts dict before
# handing the remainder (devices, shift, ...) to the engine constructor.
_EXEC_OPTS = ("interpret", "use_kernel", "randomness", "time_block",
              "stream_block", "learner")


def register_engine(name: str):
    """Class decorator: add a PolicyEngine implementation to the registry."""

    def deco(cls):
        cls.name = name
        ENGINES.add(name, cls)
        return cls

    return deco


def available_engines() -> Tuple[str, ...]:
    return ENGINES.names()


def list_engines() -> Tuple[Tuple[str, str], ...]:
    """(name, one-line description) pairs for `benchmarks.run --list`."""
    return ENGINES.describe()


def get_engine(name: str, hi_cfg: HIConfig, **opts) -> "PolicyEngine":
    """Resolve a registered engine name to a constructed instance.

    Execution knobs ride in `opts` as `spec=ExecSpec(...)`; the loose
    spellings (`use_kernel=...`, `learner=...`, ...) still work but are
    deprecated — they are folded onto the spec here, with the warning
    attributed to the caller of `get_engine`.
    """
    cls = ENGINES.lookup(name)
    spec = opts.pop("spec", None)
    legacy = {k: opts.pop(k) for k in _EXEC_OPTS if k in opts}
    spec = resolve_spec(spec, caller="get_engine", stacklevel=3, **legacy)
    return cls(hi_cfg, spec=spec, **opts)


class PolicyEngine:
    """Base class: shared init/decide/feedback; subclasses supply step/run.

    `decide`/`feedback` exist so a server can split a round around a remote
    call. The base implementations route through the split-phase Pallas
    kernels (`hedge_decide_pallas` / `hedge_feedback_pallas`) under the
    same auto-select as the fused step — kernel on TPU, jnp elsewhere,
    `interpret=True` forcing the kernel on CPU — so the serving hot path
    runs at kernel speed wherever the fused simulation path does.
    Subclasses may override (the reference engine pins the vmapped jnp
    math; the sharded engine runs both phases through its device mesh).
    """

    name = "abstract"

    def __init__(self, hi_cfg: HIConfig,
                 interpret=UNSET,
                 use_kernel=UNSET,
                 randomness=UNSET,
                 *, time_block=UNSET,
                 spec: Optional[ExecSpec] = None):
        # Execution knobs arrive as one `spec=ExecSpec(...)`; the loose
        # kwargs are deprecated shims folded onto it here (warning
        # attributed to the engine's caller, 2 frames above the subclass
        # __init__ that forwarded them).
        spec = resolve_spec(
            spec, caller=type(self).__name__, stacklevel=4,
            interpret=interpret, use_kernel=use_kernel,
            randomness=randomness, time_block=time_block)
        self.hi = hi_cfg
        self.spec = spec
        # Mirror attributes: pre-ExecSpec call sites read these directly.
        self.interpret = spec.interpret
        self.use_kernel = spec.use_kernel
        self.randomness = spec.randomness
        espec = self._exec_spec()

        if spec.randomness == "counter":
            def decide(st, fs, rng):
                return fleet_decide(hi_cfg, st, fs, None, None, rng=rng,
                                    spec=espec)
        else:
            def decide(st, fs, keys):
                psi, zeta = draw_psi_zeta(keys, hi_cfg.eps)
                return fleet_decide(hi_cfg, st, fs, psi, zeta, spec=espec)

        self._decide = jax.jit(decide)
        self._feedback = jax.jit(
            lambda st, dec, hrs, betas, sent:
                fleet_feedback(hi_cfg, st, dec, hrs, betas, sent, spec=espec))

    def _exec_spec(self) -> ExecSpec:
        """The ExecSpec this engine's phases execute under (the reference
        engine pins use_kernel=False here)."""
        return self.spec

    def _kernel_opts(self):
        """(use_kernel, interpret) this engine's decide/feedback split and
        fused steps resolve against (`core.policy._resolve_use_kernel`)."""
        espec = self._exec_spec()
        return espec.use_kernel, espec.interpret

    def _counter_rng(self, key, slot) -> CounterRNG:
        """Counter position for one slot: `key` is the run key (typed, raw
        uint32, or an already-derived (2,) seed), `slot` the absolute round
        index. The fleet's streams always start at global id 0 here — the
        sharded engine re-offsets per shard inside its mesh."""
        if slot is None:
            raise ValueError(
                f"{self.name!r} engine with counter randomness needs `slot` "
                "(the absolute round index)")
        return CounterRNG(seed=seed_from_key(key),
                          slot=jnp.asarray(slot, jnp.int32),
                          stream_offset=jnp.zeros((), jnp.int32))

    def init(self, n_streams: int) -> H2T2State:
        """Fresh fleet state: every leaf batched over (n_streams,)."""
        return fleet_init(self.hi, n_streams, learner=self.spec.learner)

    def step(self, state: H2T2State, fs, betas, hrs, keys, slot=None
             ) -> Tuple[H2T2State, StepOutput]:
        """One slot for the whole fleet (decide + immediate feedback).

        Under counter randomness `keys` is the run key (or (2,) seed) and
        `slot` the absolute round index; under pre_draw `keys` is the (S, 2)
        per-stream slot keys and `slot` is ignored.
        """
        if self.randomness == "counter":
            rng = self._counter_rng(keys, slot)
            return self._step(state, fs, betas, hrs, rng.seed, rng.slot)
        return self._step(state, fs, betas, hrs, keys,
                          jnp.zeros((), jnp.int32))

    def run(self, fs, hrs=None, betas=None, key=None, *, stream_keys=None):
        """Whole horizon in one call: (S, T) arrays OR a `ScenarioSource`.

        With arrays, returns the stacked (S, T) StepOutput and consumes the
        same key tree as `run_fleet`. With a source as the first argument,
        dispatches to `run_source` (chunked scan, per-block aggregates) —
        `key` is then the policy key; the source carries its own.
        """
        if isinstance(fs, ScenarioSource):
            if key is None and betas is None and hrs is not None:
                hrs, key = None, hrs      # the run(source, key) positional form
            if hrs is not None or betas is not None:
                raise TypeError(
                    "engine.run(source, ...) takes no hrs/betas — the source "
                    "generates them")
            return self.run_source(fs, key)
        return self.run_arrays(fs, hrs, betas, key, stream_keys=stream_keys)

    def run_arrays(self, fs, hrs, betas, key=None, *, stream_keys=None
                   ) -> Tuple[H2T2State, StepOutput]:
        """Whole materialized (S, T) horizon; same key tree as `run_fleet`."""
        raise NotImplementedError

    def run_source(self, source: ScenarioSource, key,
                   state: Optional[H2T2State] = None
                   ) -> Tuple[H2T2State, SourceRunOutput]:
        """Chunked run over a `ScenarioSource` on this engine's step path.

        Peak trace residency is one (S, block) SlotBatch; randomness follows
        `source_slot_keys` (pre_draw) or the counter contract at slot t
        (counter), so all engines return identical costs for the same
        source + key + mode.
        """
        return run_fleet_source(self.hi, source, key, state=state,
                                step_fn=self._step, spec=self._exec_spec())

    def decide(self, state: H2T2State, fs, keys, *, slot=None
               ) -> FleetDecision:
        """Phase 1 of a slot: offload decisions, no labels consumed.

        Under counter randomness `keys` is the run key (or (2,) seed) and
        `slot` the absolute round index; under pre_draw `keys` is the (S, 2)
        per-stream slot keys and `slot` is ignored.
        """
        if self.randomness == "counter":
            return self._decide(state, fs, self._counter_rng(keys, slot))
        return self._decide(state, fs, keys)

    def feedback(self, state: H2T2State, decision: FleetDecision,
                 hrs, betas, sent=None) -> Tuple[H2T2State, StepOutput]:
        """Phase 2: charge losses + update weights from (delayed) RDL labels."""
        if sent is None:
            sent = decision.offload
        return self._feedback(state, decision, hrs, betas, sent)


@register_engine("reference")
class ReferenceEngine(PolicyEngine):
    """Vmapped per-stream `h2t2_step` — the paper-shaped jnp path.

    Every phase (step, run, and the serving decide/feedback split) stays on
    the jnp math regardless of backend; `use_kernel`/`interpret` are
    accepted for registry uniformity and ignored. Non-dense learners run
    the same jnp oracles through the fleet ops (there is no per-stream
    `h2t2_step` for them), still pinned to `use_kernel=False`.
    """

    def _exec_spec(self) -> ExecSpec:
        return self.spec.evolve(use_kernel=False, interpret=None)

    def __init__(self, hi_cfg: HIConfig,
                 interpret=UNSET,
                 use_kernel=UNSET,
                 randomness=UNSET,
                 *, spec: Optional[ExecSpec] = None):
        super().__init__(hi_cfg, interpret, use_kernel, randomness, spec=spec)
        espec = self._exec_spec()
        if espec.randomness == "counter":
            # decide + immediate feedback on the jnp math — the counter
            # analogue of `h2t2_step` (same composition the adaptive engine
            # runs, pinned to use_kernel=False).
            def step(st, f, b, hr, seed, t):
                rng = CounterRNG(seed=seed, slot=jnp.asarray(t, jnp.int32),
                                 stream_offset=jnp.zeros((), jnp.int32))
                dec = fleet_decide(hi_cfg, st, f, None, None, rng=rng,
                                   spec=espec)
                return fleet_feedback(hi_cfg, st, dec, hr, b, dec.offload,
                                      spec=espec)

            self._step = jax.jit(step)
        elif espec.learner != "dense":
            def step(st, f, b, hr, k, t):
                psi, zeta = draw_psi_zeta(k, hi_cfg.eps)
                return fleet_step_fused(hi_cfg, st, f, psi, zeta, hr, b,
                                        spec=espec)

            self._step = jax.jit(step)
        else:
            vstep = jax.vmap(
                lambda st, f, b, hr, k: h2t2_step(hi_cfg, st, f, b, hr, k))
            self._step = jax.jit(
                lambda st, f, b, hr, k, t: vstep(st, f, b, hr, k))

    def run_arrays(self, fs, hrs, betas, key=None, *, stream_keys=None):
        espec = self._exec_spec()
        if espec.randomness == "counter":
            if stream_keys is not None:
                raise ValueError("counter randomness is position-keyed; "
                                 "`stream_keys` only applies to pre_draw")
            return run_fleet_fused(self.hi, fs, hrs, betas, key, spec=espec)
        if espec.learner != "dense":
            return run_fleet_fused(self.hi, fs, hrs, betas, key,
                                   stream_keys=stream_keys, spec=espec)
        return run_fleet(self.hi, fs, hrs, betas, key,
                         stream_keys=stream_keys)


@register_engine("fused")
class FusedEngine(PolicyEngine):
    """Batched `fleet_hedge_step`: Pallas kernel on TPU, jnp oracle elsewhere.

    `time_block > 1` makes `run` drive the multi-round kernel
    (`fleet_hedge_rounds`), which keeps the expert grids in VMEM for
    `time_block` rounds per launch; the horizon must divide evenly. The
    default (`time_block=None`) consults the autotune cache
    (`kernels.hedge.autotune`) per run: a cached (G, S, platform) winner
    that divides the horizon is applied, otherwise single-round — any
    geometry produces identical results. `monolithic_rounds` advertises
    that this engine's slot semantics are exactly the monolithic H2T2
    chain, so `HIServer.run_source` may drive whole slot blocks through
    the multi-round kernel when its double-buffered feedback cannot
    diverge from it (fixed schedule, no capacity drops).
    """

    monolithic_rounds = True

    def __init__(self, hi_cfg: HIConfig,
                 interpret=UNSET,
                 use_kernel=UNSET,
                 time_block=UNSET,
                 randomness=UNSET,
                 *, spec: Optional[ExecSpec] = None):
        super().__init__(hi_cfg, interpret, use_kernel, randomness,
                         time_block=time_block, spec=spec)
        self.time_block = self.spec.time_block
        espec = self._exec_spec()

        if espec.randomness == "counter":
            def step(state, fs, betas, hrs, seed, t):
                rng = CounterRNG(seed=seed, slot=jnp.asarray(t, jnp.int32),
                                 stream_offset=jnp.zeros((), jnp.int32))
                return fleet_step_fused(
                    hi_cfg, state, fs, None, None, hrs, betas,
                    rng=rng, spec=espec)
        else:
            def step(state, fs, betas, hrs, keys, t):
                psi, zeta = draw_psi_zeta(keys, hi_cfg.eps)
                return fleet_step_fused(
                    hi_cfg, state, fs, psi, zeta, hrs, betas, spec=espec)

        self._step = jax.jit(step)

    def _resolve_time_block(self, s: int, t: int) -> int:
        """Explicit time_block, else the autotuned winner (per randomness
        mode) when it divides the horizon, else single-round."""
        if self.time_block is not None:
            return self.time_block
        from repro.kernels.hedge import autotune

        rec = autotune.lookup(self.hi.grid, s, randomness=self.randomness)
        if rec:
            tb = int(rec.get("time_block", 1) or 1)
            if tb >= 1 and t % tb == 0:
                return tb
        return 1

    def run_arrays(self, fs, hrs, betas, key=None, *, stream_keys=None):
        tb = self._resolve_time_block(*fs.shape)
        return run_fleet_fused(self.hi, fs, hrs, betas, key,
                               stream_keys=stream_keys,
                               spec=self._exec_spec().evolve(time_block=tb))


@register_engine("sharded")
class ShardedEngine(PolicyEngine):
    """Fleet policy `shard_map`ped over a device mesh, stream axis sharded.

    The fleet's (S,) axis is split across `devices` (default: all visible
    devices). `step`/`run` shard the same `fleet_step_fused` the fused
    engine runs; `decide`/`feedback` (the HIServer serving path) shard
    `fleet_decide`/`fleet_feedback` the same way. There are no cross-stream
    collectives — streams are independent, so the only cost is the pad to a
    device-count multiple. Decisions are bit-for-bit those of the fused
    engine for the same keys.

    Under counter randomness each shard re-offsets its stream ids by
    `axis_index * shard_size` before drawing, so the shards regenerate
    exactly the bits the unsharded fleet would — decisions are invariant to
    the device count (the padding rows draw ids ≥ S and are sliced off).

    On CPU, validate with XLA_FLAGS=--xla_force_host_platform_device_count=N
    (set before importing jax).
    """

    AXIS = "streams"

    def __init__(self, hi_cfg: HIConfig,
                 interpret=UNSET,
                 use_kernel=UNSET,
                 devices: Optional[Sequence[jax.Device]] = None,
                 randomness=UNSET,
                 *, spec: Optional[ExecSpec] = None):
        super().__init__(hi_cfg, interpret, use_kernel, randomness, spec=spec)
        espec = self._exec_spec()
        devs = list(devices) if devices is not None else jax.devices()
        self.mesh = Mesh(np.array(devs), (self.AXIS,))
        self.n_devices = len(devs)

        spec = P(self.AXIS)
        rng_spec = CounterRNG(seed=P(), slot=P(), stream_offset=P())
        unpad = lambda s: lambda tree: jax.tree_util.tree_map(
            lambda a: a[:s], tree)
        axis = self.AXIS

        def local_rng(rng: CounterRNG, local_s: int) -> CounterRNG:
            # Inside the mesh: this shard's streams start at the global id
            # axis_index * shard_size (padding keeps shard sizes equal).
            return rng._replace(
                stream_offset=rng.stream_offset
                + jax.lax.axis_index(axis) * local_s)

        sharded_step = shard_map(
            lambda st, f, psi, zeta, hr, beta: fleet_step_fused(
                hi_cfg, st, f, psi, zeta, hr, beta, spec=espec),
            mesh=self.mesh,
            in_specs=(spec, spec, spec, spec, spec, spec),
            out_specs=(spec, spec),
            check_rep=False,
        )
        self._sharded_step = sharded_step

        sharded_step_counter = shard_map(
            lambda st, f, hr, beta, rng: fleet_step_fused(
                hi_cfg, st, f, None, None, hr, beta,
                rng=local_rng(rng, f.shape[0]), spec=espec),
            mesh=self.mesh,
            in_specs=(spec, spec, spec, spec, rng_spec),
            out_specs=(spec, spec),
            check_rep=False,
        )

        if espec.randomness == "counter":
            def step(state, fs, betas, hrs, seed, t):
                rng = CounterRNG(seed=seed, slot=jnp.asarray(t, jnp.int32),
                                 stream_offset=jnp.zeros((), jnp.int32))
                s = fs.shape[0]
                args = self._pad_tree((state, fs, hrs, betas), s)
                return unpad(s)(sharded_step_counter(*args, rng))
        else:
            def step(state, fs, betas, hrs, keys, t):
                psi, zeta = draw_psi_zeta(keys, hi_cfg.eps)
                s = fs.shape[0]
                args = self._pad_tree((state, fs, psi, zeta, hrs, betas), s)
                return unpad(s)(sharded_step(*args))

        self._step = jax.jit(step)

        def run(fs, hrs, betas, psis, zetas):
            s, t = fs.shape
            state_p, *xs_p = self._pad_tree(
                (fleet_init(hi_cfg, s, learner=espec.learner),
                 fs, psis, zetas, hrs, betas), s)

            def body(st, xs):
                f, psi, zeta, hr, beta = xs
                return sharded_step(st, f, psi, zeta, hr, beta)

            final, outs = jax.lax.scan(body, state_p,
                                       tuple(a.T for a in xs_p))
            # outs leaves are (T, S_pad) → (S, T)
            return (unpad(s)(final), jax.tree_util.tree_map(
                lambda a: jnp.swapaxes(a, 0, 1)[:s], outs))

        self._run = jax.jit(run)

        def run_counter(fs, hrs, betas, seed):
            s, t = fs.shape
            state_p, *xs_p = self._pad_tree(
                (fleet_init(hi_cfg, s, learner=espec.learner),
                 fs, hrs, betas), s)
            slots = jnp.arange(t, dtype=jnp.int32)

            def body(st, xs):
                f, hr, beta, slot = xs
                rng = CounterRNG(seed=seed, slot=slot,
                                 stream_offset=jnp.zeros((), jnp.int32))
                return sharded_step_counter(st, f, hr, beta, rng)

            final, outs = jax.lax.scan(
                body, state_p, tuple(a.T for a in xs_p) + (slots,))
            return (unpad(s)(final), jax.tree_util.tree_map(
                lambda a: jnp.swapaxes(a, 0, 1)[:s], outs))

        self._run_counter = jax.jit(run_counter)

        # The serving split runs through the mesh too — each device runs the
        # decide/feedback *kernels* on its stream shard (same auto-select as
        # everywhere) — so HIServer's phases scale with the fleet like
        # step/run do.
        sharded_decide = shard_map(
            lambda st, fs, psi, zeta: fleet_decide(
                hi_cfg, st, fs, psi, zeta, spec=espec),
            mesh=self.mesh, in_specs=(spec, spec, spec, spec),
            out_specs=spec, check_rep=False)

        sharded_decide_counter = shard_map(
            lambda st, fs, rng: fleet_decide(
                hi_cfg, st, fs, None, None,
                rng=local_rng(rng, fs.shape[0]), spec=espec),
            mesh=self.mesh, in_specs=(spec, spec, rng_spec),
            out_specs=spec, check_rep=False)

        if espec.randomness == "counter":
            def decide(state, fs, rng):
                s = fs.shape[0]
                args = self._pad_tree((state, fs), s)
                return unpad(s)(sharded_decide_counter(*args, rng))
        else:
            def decide(state, fs, keys):
                psi, zeta = draw_psi_zeta(keys, hi_cfg.eps)
                s = fs.shape[0]
                args = self._pad_tree((state, fs, psi, zeta), s)
                return unpad(s)(sharded_decide(*args))

        self._decide = jax.jit(decide)

        sharded_feedback = shard_map(
            lambda st, dec, hrs, betas, sent: fleet_feedback(
                hi_cfg, st, dec, hrs, betas, sent, spec=espec),
            mesh=self.mesh, in_specs=(spec, spec, spec, spec, spec),
            out_specs=(spec, spec), check_rep=False)

        def feedback(state, decision, hrs, betas, sent):
            s = hrs.shape[0]
            args = self._pad_tree((state, decision, hrs, betas, sent), s)
            return unpad(s)(sharded_feedback(*args))

        self._feedback = jax.jit(feedback)

    def _pad_tree(self, tree, s: int):
        """Zero-pad every (S,)-leading leaf up to a device-count multiple.

        Padding rows see an all-zero (but valid) expert grid and inert
        inputs; their outputs are sliced off, so they never affect real
        streams (no step has cross-stream coupling).
        """
        pad = (-s) % self.n_devices
        if pad == 0:
            return tree
        return jax.tree_util.tree_map(
            lambda a: jnp.concatenate(
                [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0), tree)

    def run_arrays(self, fs, hrs, betas, key=None, *, stream_keys=None):
        s, t = fs.shape
        if self.randomness == "counter":
            if stream_keys is not None:
                raise ValueError("counter randomness is position-keyed; "
                                 "`stream_keys` only applies to pre_draw")
            if key is None:
                raise ValueError("counter randomness needs `key`")
            return self._run_counter(fs, hrs, betas, seed_from_key(key))
        psis, zetas = draw_fleet_randomness(self.hi, key, s, t, stream_keys)
        return self._run(fs, hrs, betas, psis, zetas.astype(jnp.int32))


class AdaptiveState(NamedTuple):
    """Fleet policy state + per-stream detector state, threaded as one pytree.

    The passthrough properties expose the inner `H2T2State` fields, so code
    written against a plain fleet state (tests, summaries) can read an
    adaptive state unchanged.
    """

    policy: H2T2State        # leaves batched over (S,)
    shift: ShiftState        # leaves batched over (S,)

    @property
    def log_w(self):
        return self.policy.log_w

    @property
    def t(self):
        return self.policy.t

    @property
    def n_offloads(self):
        return self.policy.n_offloads

    @property
    def n_explores(self):
        return self.policy.n_explores


@register_engine("adaptive")
class AdaptiveEngine(PolicyEngine):
    """Shift-aware policy: detect → adapt → (restart) around the fleet round.

    Per slot the engine (1) conditions the (η, decay) schedule on each
    stream's detector state (`adapt_schedule` — boosted right after a
    confirmed shift, annealing back to the HIConfig values), (2) runs the
    exact reference decide/feedback round with that schedule, (3) folds the
    slot's signal (observed loss, or the quantized confidence) into the
    detector, and (4) if the detector fires and `restart=True`, re-
    initializes the alarmed streams' expert weights while preserving their
    threshold history (`fleet_restart`).

    State is an `AdaptiveState` (policy + detector); `init`/`step`/`run`/
    `run_source` and the serving `decide`/`feedback` split all thread it, so
    `HIServer` drives this engine unchanged. With `shift.detector="none"`
    every decision, loss, and weight update is bit-identical to the
    fixed-schedule engines for the same keys; an enabled-but-alarm-free run
    applies the same schedule values but as traced arrays, which XLA may
    fuse differently (≈1-ulp weight drift over long horizons).

    Serving note: in the `HIServer` flow the observed loss charges the
    scattered remote labels, whose `~sent` rows are fill values — the
    detector still sees level shifts through them, but a real deployment
    may prefer `ShiftConfig(signal="confidence")`, which watches the
    decision-time quantized confidence only.
    """

    def __init__(self, hi_cfg: HIConfig,
                 interpret=UNSET,
                 use_kernel=UNSET,
                 shift: Optional[ShiftConfig] = None,
                 restart: bool = True,
                 randomness=UNSET,
                 *, spec: Optional[ExecSpec] = None):
        super().__init__(hi_cfg, interpret, use_kernel, randomness, spec=spec)
        self.shift_cfg = ShiftConfig() if shift is None else shift
        self.restart = bool(restart)
        scfg = self.shift_cfg
        do_restart = scfg.enabled and self.restart
        espec = self._exec_spec()

        def feedback(state, decision, hrs, betas, sent):
            if scfg.enabled:
                eta, decay = adapt_schedule(hi_cfg, scfg, state.shift)
            else:
                eta = decay = None
            # The per-stream (η, decay) arrays feed the feedback kernel as
            # (S,) VMEM vectors — the adaptive schedule runs at kernel speed.
            policy, out = fleet_feedback(hi_cfg, state.policy, decision, hrs,
                                         betas, sent, eta=eta, decay=decay,
                                         spec=espec)
            if scfg.signal == "confidence":
                x = decision.i_f.astype(hi_cfg.dtype) / hi_cfg.grid
            else:
                x = out.loss
            shift_state, alarm = shift_update(scfg, state.shift, x)
            if do_restart:
                policy = fleet_restart(hi_cfg, policy, alarm,
                                       learner=espec.learner)
            return AdaptiveState(policy=policy, shift=shift_state), out

        self._feedback = jax.jit(feedback)

        if espec.randomness == "counter":
            def decide(state, fs, rng):
                return fleet_decide(hi_cfg, state.policy, fs, None, None,
                                    rng=rng, spec=espec)

            def step(state, fs, betas, hrs, seed, t):
                rng = CounterRNG(seed=seed, slot=jnp.asarray(t, jnp.int32),
                                 stream_offset=jnp.zeros((), jnp.int32))
                decision = fleet_decide(hi_cfg, state.policy, fs, None, None,
                                        rng=rng, spec=espec)
                return feedback(state, decision, hrs, betas, decision.offload)

            def run(state, fs, hrs, betas, seed):
                slots = jnp.arange(fs.shape[1], dtype=jnp.int32)

                def body(st, xs):
                    f, hr, beta, slot = xs
                    return step(st, f, beta, hr, seed, slot)

                tp = lambda a: jnp.swapaxes(a, 0, 1)
                final, outs = jax.lax.scan(
                    body, state, (tp(fs), tp(hrs), tp(betas), slots))
                return final, jax.tree_util.tree_map(tp, outs)
        else:
            def decide(state, fs, keys):
                psi, zeta = draw_psi_zeta(keys, hi_cfg.eps)
                return fleet_decide(hi_cfg, state.policy, fs, psi, zeta,
                                    spec=espec)

            def step(state, fs, betas, hrs, keys, t):
                psi, zeta = draw_psi_zeta(keys, hi_cfg.eps)
                decision = fleet_decide(hi_cfg, state.policy, fs, psi, zeta,
                                        spec=espec)
                return feedback(state, decision, hrs, betas, decision.offload)

            def run(state, fs, hrs, betas, keys_t):
                def body(st, xs):
                    f, hr, beta, keys = xs
                    return step(st, f, beta, hr, keys,
                                jnp.zeros((), jnp.int32))

                tp = lambda a: jnp.swapaxes(a, 0, 1)
                final, outs = jax.lax.scan(
                    body, state, (tp(fs), tp(hrs), tp(betas), tp(keys_t)))
                return final, jax.tree_util.tree_map(tp, outs)

        self._decide = jax.jit(decide)
        self._step = jax.jit(step)
        self._run = jax.jit(run)

    def init(self, n_streams: int) -> AdaptiveState:
        return AdaptiveState(
            policy=fleet_init(self.hi, n_streams, learner=self.spec.learner),
            shift=shift_init(n_streams, self.hi.dtype))

    def run_arrays(self, fs, hrs, betas, key=None, *, stream_keys=None):
        s, t = fs.shape
        if self.randomness == "counter":
            if stream_keys is not None:
                raise ValueError("counter randomness is position-keyed; "
                                 "`stream_keys` only applies to pre_draw")
            if key is None:
                raise ValueError("AdaptiveEngine.run needs `key`")
            return self._run(self.init(s), fs, hrs, betas,
                             seed_from_key(key))
        if stream_keys is None:
            if key is None:
                raise ValueError("AdaptiveEngine.run needs `key` or "
                                 "`stream_keys`")
            stream_keys = jax.random.split(key, s)
        # The run_fleet key tree: stream key → T round keys, so an alarm-free
        # adaptive run is decision-identical to the fixed engines.
        keys_t = jax.vmap(lambda sk: jax.random.split(sk, t))(stream_keys)
        return self._run(self.init(s), fs, hrs, betas, keys_t)

    def run_source(self, source: ScenarioSource, key,
                   state: Optional[AdaptiveState] = None):
        if state is None:
            state = self.init(source.n_streams)
        return run_fleet_source(self.hi, source, key, state=state,
                                step_fn=self._step, spec=self._exec_spec())
