"""Serving layer: backbone engines, the PolicyEngine protocol, and the
offload-aware hierarchical-inference server.

The H2T2 policy is driven through one interface — `PolicyEngine`
(`policy_engine.get_engine("reference" | "fused" | "sharded", hi_cfg)`) —
whether the caller simulates a whole horizon (`engine.run`), steps a fleet
slot-by-slot (`engine.step`), or serves online with delayed remote feedback
(`engine.decide` / `engine.feedback`, the `HIServer` flow). `HIServer` routes
only offloaded samples to the RDL via `compact_offloads`/`scatter_results`
and applies slot t's RDL results as feedback at slot t+1 (double-buffered).

Both the engines and the servers also consume `ScenarioSource`s directly
(`engine.run_source`, `HIServer.run_source`): the workload is pulled one
slot block at a time, so a fleet horizon never materializes on the host.

`request_plane/` is the asynchronous front half: per-session ingress with
admission control, deadline micro-batching into the same decide/compact/
feedback flow, and live β from measured link transfers — see
`repro.serving.request_plane`.
"""
from repro.serving.batching import OffloadBatch, compact_offloads, scatter_results
from repro.serving.engine import Engine, EngineConfig, classifier_fn
from repro.serving.hi_server import (
    HIServer,
    HIServerConfig,
    HIServerState,
    PendingFeedback,
    SlotResult,
    rotated_compact,
)
from repro.serving.policy_engine import (
    AdaptiveEngine,
    AdaptiveState,
    FusedEngine,
    PolicyEngine,
    ReferenceEngine,
    ShardedEngine,
    available_engines,
    get_engine,
    list_engines,
    register_engine,
)

__all__ = [
    "AdaptiveEngine", "AdaptiveState",
    "Engine", "EngineConfig", "FusedEngine", "HIServer", "HIServerConfig",
    "HIServerState", "OffloadBatch", "PendingFeedback", "PolicyEngine",
    "ReferenceEngine", "ShardedEngine", "SlotResult", "available_engines",
    "classifier_fn", "compact_offloads", "get_engine", "list_engines",
    "register_engine", "rotated_compact", "scatter_results",
]
