from repro.serving.batching import OffloadBatch, compact_offloads, scatter_results
from repro.serving.engine import Engine, EngineConfig, classifier_fn
from repro.serving.hi_server import HIServer, HIServerConfig, HIServerState, SlotResult

__all__ = [
    "Engine", "EngineConfig", "HIServer", "HIServerConfig", "HIServerState",
    "OffloadBatch", "SlotResult", "classifier_fn", "compact_offloads",
    "scatter_results",
]
