from repro.serving.batching import OffloadBatch, compact_offloads, scatter_results
from repro.serving.engine import (
    Engine,
    EngineConfig,
    POLICY_BACKENDS,
    PolicyBackend,
    classifier_fn,
    make_policy_step,
)
from repro.serving.hi_server import HIServer, HIServerConfig, HIServerState, SlotResult

__all__ = [
    "Engine", "EngineConfig", "HIServer", "HIServerConfig", "HIServerState",
    "OffloadBatch", "POLICY_BACKENDS", "PolicyBackend", "SlotResult",
    "classifier_fn", "compact_offloads", "make_policy_step", "scatter_results",
]
