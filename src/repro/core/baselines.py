"""Baseline policies from the paper's §5 benchmark suite.

  1. No-offload  — accept the LDL argmax inference as-is.
  2. Full-offload — offload every sample.
  3. HI-single-threshold — the state-of-the-art single-threshold online HI
     policy (Moothedath, Champati, Gross 2024), reimplemented on the same
     quantized grid with the same ε-exploration pseudo-loss machinery so the
     comparison isolates one- vs two-threshold structure.
Offline optima θ† and θ⃗* live in repro.core.offline.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import HIConfig


def _phi(cfg: HIConfig, pred1: jnp.ndarray, h_r: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(
        pred1,
        jnp.where(h_r == 0, cfg.delta_fp, 0.0),
        jnp.where(h_r == 1, cfg.delta_fn, 0.0),
    )


def no_offload_losses(cfg: HIConfig, fs, hrs, betas) -> jnp.ndarray:
    """Per-round loss of the No-offload policy (argmax local inference)."""
    return _phi(cfg, fs >= 0.5, hrs)


def full_offload_losses(cfg: HIConfig, fs, hrs, betas) -> jnp.ndarray:
    return betas


class SingleThresholdState(NamedTuple):
    log_w: jnp.ndarray   # (G+1,) log weights over thresholds θ = k/G, k = 0..G
    t: jnp.ndarray
    n_offloads: jnp.ndarray


class SingleStepOutput(NamedTuple):
    offload: jnp.ndarray
    pred: jnp.ndarray
    loss: jnp.ndarray


def single_threshold_init(cfg: HIConfig) -> SingleThresholdState:
    zero = jnp.zeros((), jnp.int32)
    return SingleThresholdState(
        log_w=jnp.zeros(cfg.grid + 1, cfg.dtype), t=zero, n_offloads=zero
    )


def single_threshold_step(
    cfg: HIConfig,
    state: SingleThresholdState,
    f: jnp.ndarray,
    beta: jnp.ndarray,
    h_r: jnp.ndarray,
    key: jax.Array,
) -> Tuple[SingleThresholdState, SingleStepOutput]:
    """Hedge over single offloading thresholds on confidence c = max(f, 1−f).

    Expert θ offloads iff c < θ; local inference is argmax. Partial feedback is
    handled exactly as H2T2: offloaded rounds reveal h_r; ε-exploration offloads
    unambiguous rounds so the local-side pseudo-loss stays unbiased.
    """
    g = cfg.grid
    conf = jnp.maximum(f, 1.0 - f)
    thetas = jnp.arange(g + 1, dtype=cfg.dtype) / g
    offload_mask = conf < thetas                              # (G+1,)

    log_total = jax.nn.logsumexp(state.log_w)
    masked = jnp.where(offload_mask, state.log_w, -jnp.inf)
    q = jnp.exp(jax.nn.logsumexp(masked) - log_total)         # P(offloading expert)

    k_psi, k_zeta = jax.random.split(key)
    psi = jax.random.uniform(k_psi)
    zeta = jax.random.bernoulli(k_zeta, cfg.eps)
    in_offload = psi <= q
    offload = in_offload | zeta
    explored = zeta & ~in_offload

    pred_local = (f >= 0.5).astype(jnp.int32)
    phi_local = _phi(cfg, pred_local == 1, h_r)
    loss = jnp.where(offload, beta, phi_local)
    pred = jnp.where(offload, h_r.astype(jnp.int32), pred_local)

    # Pseudo-loss per expert, mirroring H2T2 Eq. (10).
    lt = jnp.where(offload & offload_mask, beta, 0.0)
    lt = lt + jnp.where(explored & ~offload_mask, phi_local / cfg.eps, 0.0)
    log_w = state.log_w - cfg.eta * lt
    log_w = log_w - jnp.max(log_w)

    new_state = SingleThresholdState(
        log_w=log_w, t=state.t + 1,
        n_offloads=state.n_offloads + offload.astype(jnp.int32),
    )
    return new_state, SingleStepOutput(offload=offload, pred=pred, loss=loss)


def run_single_threshold(
    cfg: HIConfig, fs, hrs, betas, key
) -> Tuple[SingleThresholdState, SingleStepOutput]:
    keys = jax.random.split(key, fs.shape[0])

    def body(st, xs):
        f, hr, beta, k = xs
        return single_threshold_step(cfg, st, f, beta, hr, k)

    return jax.lax.scan(body, single_threshold_init(cfg), (fs, hrs, betas, keys))
