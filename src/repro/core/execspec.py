"""ExecSpec: one frozen bundle for the kernel-routing knobs.

The hedge path used to thread five loose kwargs (``use_kernel``,
``interpret``, ``randomness``, ``stream_block``, ``time_block``) through
every layer — ``fleet_decide``/``fleet_feedback``, the ops wrappers,
each engine's ``_kernel_opts``, ``HIServerConfig``, and
``RequestPlaneConfig`` — and the learner registry would have made it
six. :class:`ExecSpec` consolidates them into a single frozen (hence
hashable, hence jit-static) dataclass that is passed as ``spec=`` at
every layer.

The old per-call kwargs keep working as thin shims: public entry points
accept them, emit a ``DeprecationWarning`` (outside any jit trace, so
the warning fires on every call rather than once per compile-cache
entry), and map them onto an ``ExecSpec`` via :func:`resolve_spec`.
In-repo code never uses the deprecated spellings — the tier-1 suite
escalates ``DeprecationWarning`` from ``repro.*`` modules to errors.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional

RANDOMNESS_MODES = ("pre_draw", "counter")


class _Unset:
    """Sentinel distinguishing 'kwarg not passed' from an explicit None."""

    _instance: Optional["_Unset"] = None

    def __new__(cls) -> "_Unset":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "<unset>"


UNSET = _Unset()


@dataclasses.dataclass(frozen=True)
class ExecSpec:
    """How a fleet-policy call executes, not what it computes.

    Fields:
      learner: weight-structure name from ``core.learners`` ("dense" is
        the paper's (G, G) grid; "factored" holds two (G,) vectors).
      use_kernel: True forces the Pallas kernel, False forces the jnp
        path, None auto-selects (kernel on TPU, jnp elsewhere).
      interpret: run Pallas in interpret mode (None = auto: interpret
        off-TPU so kernels remain testable on CPU).
      randomness: "pre_draw" (caller materializes (psi, zeta)) or
        "counter" (position-keyed threefry evaluated in-kernel).
      stream_block: kernel stream-block size (None = autotuned default).
      time_block: slots chained per monolithic kernel launch in the
        fused/serving paths (None = engine default).

    Frozen and hashable so it can ride through ``jax.jit`` as a static
    argument; all semantics of each field are owned by the layer that
    consumes it (ops for blocks, engines for time_block).
    """

    learner: str = "dense"
    use_kernel: Optional[bool] = None
    interpret: Optional[bool] = None
    randomness: str = "pre_draw"
    stream_block: Optional[int] = None
    time_block: Optional[int] = None

    def __post_init__(self) -> None:
        if self.randomness not in RANDOMNESS_MODES:
            raise ValueError(
                f"unknown randomness mode {self.randomness!r}; expected one "
                f"of {RANDOMNESS_MODES}"
            )
        if self.stream_block is not None and self.stream_block <= 0:
            raise ValueError("stream_block must be positive when set")
        if self.time_block is not None and self.time_block <= 0:
            raise ValueError("time_block must be positive when set")

    def evolve(self, **overrides: Any) -> "ExecSpec":
        """A copy with the given fields replaced; UNSET/absent = keep."""
        kept = {k: v for k, v in overrides.items() if v is not UNSET}
        return dataclasses.replace(self, **kept) if kept else self


def resolve_spec(
    spec: Optional[ExecSpec],
    *,
    caller: str,
    stacklevel: int = 3,
    **legacy: Any,
) -> ExecSpec:
    """Merge deprecated per-call kwargs onto an ExecSpec, warning once.

    ``legacy`` maps ExecSpec field names to the values of the deprecated
    kwargs; pass :data:`UNSET` (the defaults do) for kwargs the caller
    did not supply. When any legacy kwarg *was* supplied, one
    ``DeprecationWarning`` is emitted naming the kwargs and the caller,
    and the values override the corresponding ``spec`` fields. Must be
    invoked outside jit traces so the warning fires per call.
    """
    base = spec if spec is not None else ExecSpec()
    used: Dict[str, Any] = {
        k: v for k, v in legacy.items() if v is not UNSET
    }
    if not used:
        return base
    names = ", ".join(sorted(used))
    warnings.warn(
        f"{caller}: the per-call kwarg(s) {names} are deprecated; pass "
        f"spec=ExecSpec(...) instead",
        DeprecationWarning,
        stacklevel=stacklevel,
    )
    return base.evolve(**used)
