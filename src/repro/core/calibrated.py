"""Closed-form optimal policies for calibrated local models.

Theorem 1 (binary): with calibrated confidence f = P(h_r = 1 | x),
    predict 1 iff f ≥ δ₁/(δ₁+δ₋₁);
    offload iff β/δ₋₁ ≤ f < 1 − β/δ₁;
    E[l_t] = min{β, δ₁(1−f), δ₋₁ f}.

Theorem 3 (K-class): with calibrated softmax vector f and cost matrix C,
    h* = argmin_k fᵀC_k; offload iff min_k fᵀC_k > β;
    E[l_t] = min{β, min_k fᵀC_k}.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.core.types import HIConfig


class CalibratedDecision(NamedTuple):
    offload: jnp.ndarray       # bool
    pred: jnp.ndarray          # int32 — local prediction if not offloaded
    expected_cost: jnp.ndarray  # float — Bayes expected per-round cost


def optimal_thresholds(cfg: HIConfig, beta: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(θ_l*, θ_u*) of Theorem 1 (Eq. 7). Collapses (no offload region) when
    β ≥ δ₁δ₋₁/(δ₁+δ₋₁), i.e. half the harmonic mean (Remark 1)."""
    theta_l = beta / cfg.delta_fn
    theta_u = 1.0 - beta / cfg.delta_fp
    # When the region is empty the decision threshold is δ₁/(δ₁+δ₋₁) (Eq. 6).
    split = cfg.delta_fp / (cfg.delta_fp + cfg.delta_fn)
    empty = theta_l >= theta_u
    theta_l = jnp.where(empty, split, theta_l)
    theta_u = jnp.where(empty, split, theta_u)
    return theta_l, theta_u


def calibrated_rule(cfg: HIConfig, f: jnp.ndarray, beta: jnp.ndarray) -> CalibratedDecision:
    """Apply Theorem 1 elementwise to confidences f."""
    theta_l, theta_u = optimal_thresholds(cfg, beta)
    offload = (theta_l <= f) & (f < theta_u)
    split = cfg.delta_fp / (cfg.delta_fp + cfg.delta_fn)
    pred = (f >= split).astype(jnp.int32)
    exp_cost = jnp.minimum(beta, jnp.minimum(cfg.delta_fp * (1.0 - f), cfg.delta_fn * f))
    return CalibratedDecision(offload=offload, pred=pred, expected_cost=exp_cost)


def chow_rule(f: jnp.ndarray, beta: jnp.ndarray) -> CalibratedDecision:
    """Chow's rule = Theorem 1 with δ₁ = δ₋₁ = 1 (Remark 1(ii))."""
    cfg = HIConfig(delta_fp=1.0, delta_fn=1.0)
    return calibrated_rule(cfg, f, beta)


def multiclass_rule(
    f: jnp.ndarray,          # (..., K) calibrated softmax
    cost_matrix: jnp.ndarray,  # (K, K), C[i, j] = cost of true i predicted j, C[i,i]=0
    beta: jnp.ndarray,
) -> CalibratedDecision:
    """Theorem 3: h* = argmin_k fᵀC_k, offload iff min_k fᵀC_k > β."""
    # risks[..., j] = Σ_i f_i · C[i, j]
    risks = jnp.einsum("...i,ij->...j", f, cost_matrix)
    pred = jnp.argmin(risks, axis=-1).astype(jnp.int32)
    min_risk = jnp.min(risks, axis=-1)
    offload = min_risk > beta
    exp_cost = jnp.minimum(beta, min_risk)
    return CalibratedDecision(offload=offload, pred=pred, expected_cost=exp_cost)


def multiclass_regions(
    grid: jnp.ndarray,        # (N, K) softmax points on the simplex
    cost_matrix: jnp.ndarray,
    beta: float,
) -> jnp.ndarray:
    """Label each simplex point with its decision region: K for offload, else argmin.

    Used by examples/multiclass_demo.py to reproduce the Fig. 5 region plot.
    """
    d = multiclass_rule(grid, cost_matrix, jnp.asarray(beta))
    k = cost_matrix.shape[0]
    return jnp.where(d.offload, k, d.pred)
