"""Pluggable hedge learners: the weight structure under the H2T2 policy.

The paper's policy maintains a dense (G, G) log-weight grid per stream —
one expert per (lower, upper) threshold pair — so fleet size × G² bounds
both memory residency and the decide-phase region-mass reduce. Following
Chattopadhyay et al. (low-regret *and* low-complexity learners for
hierarchical inference), the two-threshold structure admits far cheaper
learners; this registry makes the weight structure a pluggable choice
threaded through `fleet_decide`/`fleet_feedback` and every engine via
``ExecSpec.learner``.

Each learner owns its state pytree layout (the ``log_w`` leaf of
``H2T2State``), its decide-time region-mass reduce, and its
feedback-time weight update. The numerical ops live next to the dense
kernels (`repro.kernels.hedge.factored` for the factored variant);
this module holds only the structural metadata the policy layer and the
engines need: fresh-weight construction, restart masking, and the
analytic residency accounting the scaling benches report.

Registered learners:
  dense     the paper's (G, G) product grid — bit-identical to the
            pre-registry behavior; O(G²) state and reduce per stream.
  factored  two (G,) per-threshold weight vectors (row 0 = lower, row 1
            = upper) combined as a product distribution at decide time;
            O(G) state and reduce per stream. Feedback updates each axis
            with the pseudo-loss marginalized over the other axis'
            current distribution, so regret tracks dense H2T2 whenever
            the dense posterior is close to a product measure (the
            manuscript scenarios, where one threshold dominates).
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core.registry import Registry

LEARNERS = Registry("learner")


class DenseLearner:
    """Paper H2T2: dense (G, G) log-weight grid, one expert per (l, u) pair."""

    name = "dense"
    description = (
        "dense (G, G) expert grid over (lower, upper) threshold pairs "
        "(the paper's H2T2; O(G^2) state per stream)"
    )

    def fresh_weights(self, cfg) -> jnp.ndarray:
        """Uniform log-weights over the valid l <= u triangle."""
        g = cfg.grid
        iu = jnp.arange(g)
        valid = iu[:, None] <= iu[None, :]
        return jnp.where(valid, 0.0, -jnp.inf).astype(cfg.dtype)

    def fleet_weights(self, cfg, n_streams: int) -> jnp.ndarray:
        return jnp.broadcast_to(
            self.fresh_weights(cfg)[None], (n_streams, cfg.grid, cfg.grid)
        )

    def remask(self, cfg, log_w: jnp.ndarray) -> jnp.ndarray:
        """Re-pin invalid (l > u) cells after a kernel update.

        The Pallas kernels represent -inf with a large negative sentinel;
        restoring the exact -inf keeps the state bit-identical to the jnp
        path.
        """
        g = cfg.grid
        iu = jnp.arange(g)
        valid = iu[:, None] <= iu[None, :]
        return jnp.where(valid[None], log_w, -jnp.inf).astype(cfg.dtype)

    def weight_bytes(self, cfg, n_streams: int) -> int:
        return 4 * n_streams * cfg.grid * cfg.grid

    def state_shape(self, cfg) -> Tuple[int, ...]:
        return (cfg.grid, cfg.grid)


class FactoredLearner:
    """Factored per-threshold learner: two (G,) weight vectors, O(G) state.

    ``log_w`` per stream is (2, G): row 0 the lower-threshold weights,
    row 1 the upper-threshold weights. Region masses come from the
    product distribution (restricted to l <= u via a cumulative-sum
    reduce, so decide stays O(G)); feedback updates each axis against
    the Eq.-10 pseudo-loss marginalized over the other axis.
    """

    name = "factored"
    description = (
        "factored per-threshold learner: two (G,) weight vectors with a "
        "product combine (O(G) state per stream)"
    )

    def fresh_weights(self, cfg) -> jnp.ndarray:
        return jnp.zeros((2, cfg.grid), cfg.dtype)

    def fleet_weights(self, cfg, n_streams: int) -> jnp.ndarray:
        return jnp.zeros((n_streams, 2, cfg.grid), cfg.dtype)

    def remask(self, cfg, log_w: jnp.ndarray) -> jnp.ndarray:
        """No invalid cells to re-pin: every (row, index) weight is live."""
        return log_w.astype(cfg.dtype)

    def weight_bytes(self, cfg, n_streams: int) -> int:
        return 4 * n_streams * 2 * cfg.grid

    def state_shape(self, cfg) -> Tuple[int, ...]:
        return (2, cfg.grid)

    def ops(self):
        """The op module implementing this learner's decide/feedback math."""
        from repro.kernels.hedge import factored

        return factored


LEARNERS.add("dense", DenseLearner())
LEARNERS.add("factored", FactoredLearner())


def register_learner(name: str):
    """Decorator registering a learner *instance factory* under ``name``."""
    return LEARNERS.register(name)


def get_learner(name: str):
    """Look up a learner by name; unknown names list the available ones."""
    return LEARNERS.lookup(name)


def list_learners() -> Tuple[Tuple[str, str], ...]:
    """(name, one-line description) pairs for ``benchmarks.run --list``."""
    return LEARNERS.describe()
