# The paper's primary contribution: H2T2 two-threshold hierarchical-inference
# policy, calibrated-model closed forms, offline optima, and paper baselines.
from repro.core.types import HIConfig, StreamSpec
from repro.core.execspec import UNSET, ExecSpec, resolve_spec
from repro.core.learners import get_learner, list_learners, register_learner
from repro.core.registry import Registry
from repro.core.counter import (
    RANDOMNESS_MODES,
    CounterRNG,
    counter_rng,
    psi_zeta_from_counter,
    seed_from_key,
)
from repro.core.policy import (
    FleetDecision,
    H2T2State,
    SourceRunOutput,
    StepOutput,
    adapt_schedule,
    classification_cost,
    draw_fleet_randomness,
    draw_fleet_slot_randomness,
    draw_psi_zeta,
    effective_local_pred,
    fleet_decide,
    fleet_feedback,
    fleet_init,
    fleet_restart,
    fleet_rounds_fused,
    fleet_step_fused,
    h2t2_init,
    h2t2_step,
    local_fallback_pred,
    pseudo_loss,
    quantize,
    region_masks,
    run_fleet,
    run_fleet_fused,
    run_fleet_source,
    run_stream,
    source_slot_keys,
    true_loss_fleet,
)
from repro.core.shift import (
    COUNTER_CAP,
    ShiftConfig,
    ShiftState,
    detect_shifts,
    shift_init,
    shift_update,
)
from repro.core.calibrated import (
    CalibratedDecision,
    calibrated_rule,
    chow_rule,
    multiclass_regions,
    multiclass_rule,
    optimal_thresholds,
)
from repro.core import baselines, multiclass, offline, regret

__all__ = [
    "COUNTER_CAP",
    "CounterRNG", "RANDOMNESS_MODES",
    "ExecSpec", "Registry", "UNSET",
    "HIConfig", "StreamSpec", "FleetDecision", "H2T2State",
    "ShiftConfig", "ShiftState",
    "SourceRunOutput", "StepOutput", "adapt_schedule", "classification_cost",
    "counter_rng", "detect_shifts",
    "draw_fleet_randomness", "draw_fleet_slot_randomness",
    "draw_psi_zeta", "effective_local_pred",
    "fleet_decide", "fleet_feedback", "fleet_init", "fleet_restart",
    "fleet_rounds_fused", "fleet_step_fused",
    "get_learner", "h2t2_init", "h2t2_step", "list_learners",
    "local_fallback_pred", "pseudo_loss",
    "psi_zeta_from_counter", "quantize", "region_masks", "register_learner",
    "resolve_spec",
    "run_fleet", "run_fleet_fused", "run_fleet_source", "run_stream",
    "seed_from_key", "shift_init", "shift_update",
    "source_slot_keys", "true_loss_fleet",
    "CalibratedDecision", "calibrated_rule", "chow_rule",
    "multiclass_regions", "multiclass_rule", "optimal_thresholds",
    "baselines", "multiclass", "offline", "regret",
]
