# The paper's primary contribution: H2T2 two-threshold hierarchical-inference
# policy, calibrated-model closed forms, offline optima, and paper baselines.
from repro.core.types import HIConfig, StreamSpec
from repro.core.policy import (
    FleetDecision,
    H2T2State,
    SourceRunOutput,
    StepOutput,
    classification_cost,
    draw_fleet_randomness,
    draw_psi_zeta,
    effective_local_pred,
    fleet_decide,
    fleet_feedback,
    fleet_init,
    fleet_step_fused,
    h2t2_init,
    h2t2_step,
    local_fallback_pred,
    pseudo_loss,
    quantize,
    region_masks,
    run_fleet,
    run_fleet_fused,
    run_fleet_source,
    run_stream,
    source_slot_keys,
    true_loss_fleet,
)
from repro.core.calibrated import (
    CalibratedDecision,
    calibrated_rule,
    chow_rule,
    multiclass_regions,
    multiclass_rule,
    optimal_thresholds,
)
from repro.core import baselines, multiclass, offline, regret

__all__ = [
    "HIConfig", "StreamSpec", "FleetDecision", "H2T2State",
    "SourceRunOutput", "StepOutput", "classification_cost",
    "draw_fleet_randomness", "draw_psi_zeta", "effective_local_pred",
    "fleet_decide", "fleet_feedback", "fleet_init", "fleet_step_fused",
    "h2t2_init", "h2t2_step", "local_fallback_pred", "pseudo_loss",
    "quantize", "region_masks",
    "run_fleet", "run_fleet_fused", "run_fleet_source", "run_stream",
    "source_slot_keys", "true_loss_fleet",
    "CalibratedDecision", "calibrated_rule", "chow_rule",
    "multiclass_regions", "multiclass_rule", "optimal_thresholds",
    "baselines", "multiclass", "offline", "regret",
]
