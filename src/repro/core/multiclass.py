"""BEYOND-PAPER: online multiclass HI policy (the paper's §6 open problem).

The paper derives the calibrated K-class rule (Theorem 3: predict
argmin_k fᵀC_k, offload iff min_k fᵀC_k > β) but leaves the *online,
uncalibrated* case open, noting the expert space over (K−2)-simplex
boundaries is combinatorial.

Our compact parametrization: keep the cost-sensitive argmin as the local
prediction (it only needs the model's softmax, no learning), and learn ONE
scalar threshold τ on the *estimated risk* r(f) = min_k fᵀC_k — the quantity
Theorem 3 thresholds at β for calibrated models. For uncalibrated models the
optimal τ shifts away from β; a Hedge over a quantized τ-grid with the same
ε-exploration / importance-weighted pseudo-loss machinery as H2T2 learns it
with partial feedback. |Θ| = 2^b experts regardless of K — compact and
scalable, trading the full boundary family for the risk-scale family (which
contains Theorem 3's rule when calibrated, so the oracle is representable).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import HIConfig


class MCState(NamedTuple):
    log_w: jnp.ndarray     # (G+1,) weights over τ = k·r_max/G
    t: jnp.ndarray
    n_offloads: jnp.ndarray


class MCStepOutput(NamedTuple):
    offload: jnp.ndarray
    pred: jnp.ndarray
    loss: jnp.ndarray


def mc_init(cfg: HIConfig) -> MCState:
    zero = jnp.zeros((), jnp.int32)
    return MCState(log_w=jnp.zeros(cfg.grid + 1, cfg.dtype), t=zero,
                   n_offloads=zero)


def _risk_and_pred(f: jnp.ndarray, cost: jnp.ndarray):
    risks = f @ cost                               # (K,) risk of predicting k
    return jnp.min(risks), jnp.argmin(risks).astype(jnp.int32)


def mc_step(
    cfg: HIConfig,
    state: MCState,
    f: jnp.ndarray,          # (K,) softmax vector
    cost: jnp.ndarray,       # (K, K) cost matrix, C[i, j] = true i predicted j
    beta: jnp.ndarray,
    h_r: jnp.ndarray,        # remote label (used only when offloaded)
    key: jax.Array,
) -> Tuple[MCState, MCStepOutput]:
    g = cfg.grid
    r_max = jnp.max(cost)
    taus = jnp.arange(g + 1, dtype=cfg.dtype) / g * r_max
    risk, pred_local = _risk_and_pred(f, cost)
    offload_mask = risk > taus                     # expert τ offloads iff r > τ

    log_total = jax.nn.logsumexp(state.log_w)
    q = jnp.exp(jax.nn.logsumexp(
        jnp.where(offload_mask, state.log_w, -jnp.inf)) - log_total)

    k_psi, k_zeta = jax.random.split(key)
    psi = jax.random.uniform(k_psi)
    zeta = jax.random.bernoulli(k_zeta, cfg.eps)
    in_off = psi <= q
    offload = in_off | zeta
    explored = zeta & ~in_off

    phi_local = cost[h_r, pred_local]
    loss = jnp.where(offload, beta, phi_local)
    pred = jnp.where(offload, h_r.astype(jnp.int32), pred_local)

    lt = jnp.where(offload & offload_mask, beta, 0.0)
    lt = lt + jnp.where(explored & ~offload_mask, phi_local / cfg.eps, 0.0)
    log_w = cfg.decay * state.log_w - cfg.eta * lt
    log_w = log_w - jnp.max(log_w)

    return (MCState(log_w=log_w, t=state.t + 1,
                    n_offloads=state.n_offloads + offload.astype(jnp.int32)),
            MCStepOutput(offload=offload, pred=pred, loss=loss))


def mc_run_stream(cfg: HIConfig, fs, cost, betas, hrs, key):
    keys = jax.random.split(key, fs.shape[0])

    def body(st, xs):
        f, beta, hr, k = xs
        return mc_step(cfg, st, f, cost, beta, hr, k)

    return jax.lax.scan(body, mc_init(cfg), (fs, betas, hrs, keys))


def mc_offline_best(cfg: HIConfig, fs, cost, betas, hrs) -> jnp.ndarray:
    """Best fixed-τ cumulative loss (the comparator for regret)."""
    g = cfg.grid
    r_max = jnp.max(cost)
    taus = jnp.arange(g + 1, dtype=fs.dtype) / g * r_max
    risks = jnp.min(fs @ cost, axis=-1)                       # (T,)
    preds = jnp.argmin(fs @ cost, axis=-1)
    phi = cost[hrs, preds]
    per = jnp.where(risks[None, :] > taus[:, None], betas[None, :], phi[None, :])
    return jnp.min(jnp.sum(per, axis=-1))


def mc_no_offload_loss(fs, cost, hrs) -> jnp.ndarray:
    preds = jnp.argmin(fs @ cost, axis=-1)
    return jnp.sum(cost[hrs, preds])
