"""Offline-optimal fixed-threshold policies (paper baselines θ† and θ⃗*).

Given a full trace (f_t, h_r_t, β_t) these compute the exact cumulative loss of
EVERY expert on the quantized grid in one vectorized pass, then argmin:

  two-threshold  θ⃗* : experts (l, u), l ≤ u, loss Eq. (3)
  single-threshold θ†: offload iff confidence max(f, 1−f) < θ, else argmax
                       (the rule used by prior single-threshold HI works)
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import quantize
from repro.core.types import HIConfig


class OfflineResult(NamedTuple):
    best_loss: jnp.ndarray     # () cumulative loss of the best expert
    best_expert: jnp.ndarray   # index/tuple of the argmin expert
    losses: jnp.ndarray        # full expert-loss table


def _phi(cfg: HIConfig, pred1: jnp.ndarray, h_r: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(
        pred1,
        jnp.where(h_r == 0, cfg.delta_fp, 0.0),
        jnp.where(h_r == 1, cfg.delta_fn, 0.0),
    )


def two_threshold_losses(
    cfg: HIConfig, fs: jnp.ndarray, hrs: jnp.ndarray, betas: jnp.ndarray
) -> jnp.ndarray:
    """(G, G) cumulative loss L_T(θ⃗) for every grid pair; +inf on invalid l > u."""
    g = cfg.grid
    i_f = quantize(fs, cfg.bits)                     # (T,)
    l = jnp.arange(g)[:, None, None]                 # (G,1,1)
    u = jnp.arange(g)[None, :, None]                 # (1,G,1)
    i = i_f[None, None, :]                           # (1,1,T)
    ambiguous = (l <= i) & (i < u)                   # (G,G,T)
    pred1 = u <= i
    phi = _phi(cfg, pred1, hrs[None, None, :])
    per_round = jnp.where(ambiguous, betas[None, None, :], phi)
    total = jnp.sum(per_round, axis=-1)
    valid = jnp.arange(g)[:, None] <= jnp.arange(g)[None, :]
    return jnp.where(valid, total, jnp.inf)


def best_two_threshold(
    cfg: HIConfig, fs: jnp.ndarray, hrs: jnp.ndarray, betas: jnp.ndarray
) -> OfflineResult:
    losses = two_threshold_losses(cfg, fs, hrs, betas)
    flat = jnp.argmin(losses)
    l_idx, u_idx = flat // cfg.grid, flat % cfg.grid
    return OfflineResult(
        best_loss=losses[l_idx, u_idx],
        best_expert=jnp.stack([l_idx, u_idx]),
        losses=losses,
    )


def single_threshold_losses(
    cfg: HIConfig, fs: jnp.ndarray, hrs: jnp.ndarray, betas: jnp.ndarray
) -> jnp.ndarray:
    """(G+1,) cumulative loss of the single-threshold HI rule per threshold θ=k/G.

    Rule (prior HI works): confidence c = max(f, 1−f); offload iff c < θ;
    otherwise the local prediction is argmax, i.e. 1 iff f ≥ 0.5.
    θ spans 0..1 inclusive (k = 0..G) so θ† can express both naive policies:
    θ=0 → never offload, θ=1 → always offload (c < 1 a.s. for c below 1).
    """
    g = cfg.grid
    conf = jnp.maximum(fs, 1.0 - fs)                 # (T,)
    pred1 = fs >= 0.5
    phi = _phi(cfg, pred1, hrs)
    thetas = jnp.arange(g + 1, dtype=fs.dtype) / g   # (G+1,)
    offload = conf[None, :] < thetas[:, None]        # (G+1, T)
    per_round = jnp.where(offload, betas[None, :], phi[None, :])
    return jnp.sum(per_round, axis=-1)


def best_single_threshold(
    cfg: HIConfig, fs: jnp.ndarray, hrs: jnp.ndarray, betas: jnp.ndarray
) -> OfflineResult:
    losses = single_threshold_losses(cfg, fs, hrs, betas)
    k = jnp.argmin(losses)
    return OfflineResult(best_loss=losses[k], best_expert=k, losses=losses)


def fixed_pair_loss(
    cfg: HIConfig,
    l_idx: int,
    u_idx: int,
    fs: jnp.ndarray,
    hrs: jnp.ndarray,
    betas: jnp.ndarray,
) -> jnp.ndarray:
    """Cumulative loss of one fixed θ⃗ (used by regret evaluation)."""
    i_f = quantize(fs, cfg.bits)
    ambiguous = (l_idx <= i_f) & (i_f < u_idx)
    pred1 = u_idx <= i_f
    phi = _phi(cfg, pred1, hrs)
    return jnp.sum(jnp.where(ambiguous, betas, phi))


def fpr_fnr_cost_surface(
    cfg: HIConfig, fs: jnp.ndarray, hrs: jnp.ndarray, beta: float
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-expert (FPR, FNR, avg cost) surfaces over the (l, u) grid — Fig. 2.

    FPR/FNR here are fractions of all samples, matching Table 2's convention.
    """
    g = cfg.grid
    i_f = quantize(fs, cfg.bits)
    t = fs.shape[0]
    l = jnp.arange(g)[:, None, None]
    u = jnp.arange(g)[None, :, None]
    i = i_f[None, None, :]
    ambiguous = (l <= i) & (i < u)
    pred1 = (u <= i) & ~ambiguous
    pred0 = (i < l) & ~ambiguous
    fp = jnp.sum(pred1 & (hrs[None, None, :] == 0), axis=-1) / t
    fn = jnp.sum(pred0 & (hrs[None, None, :] == 1), axis=-1) / t
    off = jnp.sum(ambiguous, axis=-1) / t
    cost = cfg.delta_fp * fp + cfg.delta_fn * fn + beta * off
    valid = jnp.arange(g)[:, None] <= jnp.arange(g)[None, :]
    inf = jnp.inf
    return (
        jnp.where(valid, fp, inf),
        jnp.where(valid, fn, inf),
        jnp.where(valid, cost, inf),
    )
