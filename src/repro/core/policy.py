"""H2T2 — HI-Hedge with Two Thresholds (paper Algorithm 1).

Experts are threshold tuples θ⃗ = (θ_l, θ_u), θ ∈ {k/G : k = 0..G-1}, θ_l ≤ θ_u,
held as a dense (G, G) log-weight matrix (row = l index, col = u index) with an
upper-triangular validity mask. Confidences are quantized to i_f = ⌊f·G⌋ so that
  region 1 (predict 0):  i_f <  l
  region 2 (ambiguous):  l ≤ i_f < u   → offload
  region 3 (predict 1):  u ≤ i_f
Weights live in log-space; region masses use logsumexp for numerical stability
over long horizons (w ← w·e^{-η·l̃} underflows in linear space by T ~ 1e4).

Everything is jit/vmap friendly: `h2t2_step` is a pure function of (state, sample,
key) and is vmapped over independent edge streams by the serving layer.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.counter import (
    CounterRNG,
    check_randomness_mode,
    psi_zeta_from_counter,
    seed_from_key,
)
from repro.core.execspec import UNSET, ExecSpec, resolve_spec
from repro.core.types import HIConfig


class H2T2State(NamedTuple):
    log_w: jnp.ndarray      # (G, G) float — log expert weights; -inf on invalid cells
    t: jnp.ndarray          # () int32 — rounds seen
    n_offloads: jnp.ndarray  # () int32
    n_explores: jnp.ndarray  # () int32


class StepOutput(NamedTuple):
    offload: jnp.ndarray      # () bool — was the sample offloaded
    pred: jnp.ndarray         # () int32 — final inference (local or remote)
    local_pred: jnp.ndarray   # () int32 — what the local decision would have been
    loss: jnp.ndarray         # () float — incurred loss l_t (β_t if offloaded, φ_t else)
    explored: jnp.ndarray     # () bool — E_t
    q: jnp.ndarray            # () float — region-2 probability mass
    p: jnp.ndarray            # () float — region-3 probability mass


def _valid_mask(g: int, dtype=jnp.float32) -> jnp.ndarray:
    l = jnp.arange(g)[:, None]
    u = jnp.arange(g)[None, :]
    return (l <= u)


def h2t2_init(cfg: HIConfig) -> H2T2State:
    g = cfg.grid
    valid = _valid_mask(g)
    log_w = jnp.where(valid, 0.0, -jnp.inf).astype(cfg.dtype)
    zero = jnp.zeros((), jnp.int32)
    return H2T2State(log_w=log_w, t=zero, n_offloads=zero, n_explores=zero)


def quantize(f: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantize confidence f ∈ [0, 1] to the grid index i_f = ⌊f·G⌋ ∈ {0..G-1}."""
    g = 1 << bits
    return jnp.clip((f * g).astype(jnp.int32), 0, g - 1)


def region_masks(i_f: jnp.ndarray, g: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Boolean masks (G, G) of experts in regions 1/2/3 for quantized conf i_f."""
    l = jnp.arange(g)[:, None]
    u = jnp.arange(g)[None, :]
    valid = l <= u
    r2 = valid & (l <= i_f) & (i_f < u)          # ambiguous → offload
    r3 = valid & (u <= i_f)                       # predict 1
    r1 = valid & (i_f < l)                        # predict 0
    return r1, r2, r3


def _masked_logsumexp(log_w: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    masked = jnp.where(mask, log_w, -jnp.inf)
    m = jnp.max(masked)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    s = jnp.sum(jnp.where(mask, jnp.exp(masked - m_safe), 0.0))
    return jnp.where(s > 0, m_safe + jnp.log(s), -jnp.inf)


def pseudo_loss(
    cfg: HIConfig,
    i_f: jnp.ndarray,
    offloaded: jnp.ndarray,
    explored: jnp.ndarray,
    h_r: jnp.ndarray,
    beta: jnp.ndarray,
) -> jnp.ndarray:
    """Unbiased pseudo-loss l̃_t(θ⃗) for every expert (paper Eq. 10).

    Feedback (h_r) is only available when the sample was offloaded (O_t = 1):
      l̃ = β_t          if O_t = 1 and the expert is ambiguous at i_f,
      l̃ = φ_t(θ⃗)/ε    if E_t = 1 and the expert is unambiguous at i_f,
      l̃ = 0            otherwise.
    φ_t(θ⃗) is the misclassification cost the *expert's own* local prediction
    would incur against the remote label h_r.
    """
    g = cfg.grid
    _, r2, r3 = region_masks(i_f, g)
    # Expert-local prediction: 1 in region 3, 0 in region 1 (region 2 offloads).
    pred1 = r3
    phi = jnp.where(
        pred1, jnp.where(h_r == 0, cfg.delta_fp, 0.0),
        jnp.where(h_r == 1, cfg.delta_fn, 0.0),
    )
    amb_term = jnp.where(offloaded & r2, beta, 0.0)
    una_term = jnp.where(explored & ~r2, phi / cfg.eps, 0.0)
    return amb_term + una_term


class FleetDecision(NamedTuple):
    """Phase-1 output of a fleet round: everything decided *before* any remote
    feedback exists. Leaves are batched (S,) over streams."""

    i_f: jnp.ndarray         # (S,) int32 — quantized confidence at decision time
    offload: jnp.ndarray     # (S,) bool — O_t (region-2 draw OR ζ exploration)
    explored: jnp.ndarray    # (S,) bool — E_t
    local_pred: jnp.ndarray  # (S,) int32 — the local decision (used if not offloaded)
    q: jnp.ndarray           # (S,) float — region-2 probability mass
    p: jnp.ndarray           # (S,) float — region-3 probability mass
    psi: jnp.ndarray         # (S,) float — the ψ draw (for drop fallbacks)


def _decide_one(
    cfg: HIConfig, log_w: jnp.ndarray, f: jnp.ndarray,
    psi: jnp.ndarray, zeta: jnp.ndarray,
) -> FleetDecision:
    """Feedback-free half of Algorithm 1 for one stream (lines 4-20)."""
    g = cfg.grid
    i_f = quantize(f, cfg.bits)
    r1, r2, r3 = region_masks(i_f, g)
    log_total = _masked_logsumexp(log_w, r1 | r2 | r3)
    q = jnp.exp(_masked_logsumexp(log_w, r2) - log_total)
    p = jnp.exp(_masked_logsumexp(log_w, r3) - log_total)
    in_region2 = psi <= q
    zeta = zeta.astype(bool)
    offload = in_region2 | zeta
    explored = zeta & ~in_region2
    local_pred = jnp.where(psi <= q + p, 1, 0).astype(jnp.int32)
    return FleetDecision(i_f=i_f, offload=offload, explored=explored,
                         local_pred=local_pred, q=q, p=p, psi=psi)


def _resolve_use_kernel(use_kernel: Optional[bool],
                        interpret: Optional[bool]) -> bool:
    """The fused-path auto-select: compiled Pallas on TPU, jnp elsewhere —
    unless `interpret=True`, which forces the kernel in interpret mode (the
    correctness-test path on CPU)."""
    if use_kernel is not None:
        return use_kernel
    from repro.kernels.hedge.ops import kernel_available

    return kernel_available() or interpret is True


def fleet_decide(
    cfg: HIConfig,
    state: H2T2State,        # leaves batched over (S,)
    fs: jnp.ndarray,         # (S,)
    psi: Optional[jnp.ndarray],   # (S,) pre-drawn uniforms; None w/ rng
    zeta: Optional[jnp.ndarray],  # (S,) pre-drawn bernoulli(ε); None w/ rng
    *,
    rng: Optional[CounterRNG] = None,   # counter-mode draw position
    use_kernel=UNSET,        # deprecated — pass spec=ExecSpec(...)
    interpret=UNSET,         # deprecated — pass spec=ExecSpec(...)
    spec: Optional[ExecSpec] = None,
) -> FleetDecision:
    """Decide offload/local for a whole fleet without touching any label.

    This is the first half of `h2t2_step`: it reads the expert weights but
    does not update them, so a serving layer can route only the offloaded
    samples to the remote model and apply `fleet_feedback` once (delayed)
    results arrive.

    Randomness comes in one of two ways: pre-drawn (ψ, ζ) operands (the
    golden paper path), or a counter-mode `rng` (seed, slot, stream_offset)
    position with `psi`/`zeta` passed as None — the draws are regenerated
    in place (in-kernel on the kernel path) and the returned
    `FleetDecision.psi` carries the regenerated ψ for the capacity-drop
    fallback.

    `spec` (an :class:`ExecSpec`) routes execution: `spec.use_kernel`
    sends the region reductions through the Pallas decide kernel (the
    default auto-selects like `fleet_step_fused` — kernel on TPU, vmapped
    jnp elsewhere, `interpret=True` forces the kernel for CPU correctness
    runs), and `spec.learner` picks the weight structure (non-dense
    learners always route through the ops layer). Both kernel and jnp
    paths make identical decisions. The loose `use_kernel`/`interpret`
    kwargs are deprecated shims onto the spec.
    """
    spec = resolve_spec(spec, caller="fleet_decide",
                        use_kernel=use_kernel, interpret=interpret)
    uk = _resolve_use_kernel(spec.use_kernel, spec.interpret)
    if rng is not None:
        if psi is not None or zeta is not None:
            raise ValueError("fleet_decide: pass (psi, zeta) OR rng, not both")
        if uk or spec.learner != "dense":
            from repro.kernels.hedge.ops import fleet_hedge_decide

            i_f, off, exp_, lp, q, p, psi_out = fleet_hedge_decide(
                cfg, state.log_w, fs, None, None, rng=rng,
                spec=spec.evolve(use_kernel=uk, randomness="counter"))
            return FleetDecision(i_f=i_f, offload=off.astype(bool),
                                 explored=exp_.astype(bool), local_pred=lp,
                                 q=q, p=p, psi=psi_out)
        sid = rng.stream_offset + jnp.arange(fs.shape[0], dtype=jnp.int32)
        psi, zeta = psi_zeta_from_counter(rng.seed, sid, rng.slot, cfg.eps)
    elif psi is None or zeta is None:
        raise ValueError("fleet_decide needs (psi, zeta) or a counter rng")
    if uk or spec.learner != "dense":
        from repro.kernels.hedge.ops import fleet_hedge_decide

        i_f, off, exp_, lp, q, p = fleet_hedge_decide(
            cfg, state.log_w, fs, psi, zeta.astype(jnp.int32),
            spec=spec.evolve(use_kernel=uk, randomness="pre_draw"))
        return FleetDecision(i_f=i_f, offload=off.astype(bool),
                             explored=exp_.astype(bool), local_pred=lp,
                             q=q, p=p, psi=psi)
    return jax.vmap(lambda lw, f, ps, zt: _decide_one(cfg, lw, f, ps, zt))(
        state.log_w, fs, psi, zeta)


def local_fallback_pred(decision: FleetDecision) -> jnp.ndarray:
    """The local prediction to use when an offload could not be served.

    For ψ ≤ q the sample offloaded via region 2, so `local_pred` (ψ ≤ q+p)
    is deterministically 1 — not a draw from the conditional local-decision
    distribution. Rescale ψ from [0, q) onto the not-offload interval so
    class 1 is chosen with the conditional probability p/(1−q), reusing the
    decision-time randomness. Exploration offloads (ψ > q) already carry the
    correct conditional draw in `local_pred`.
    """
    in_r2 = decision.psi <= decision.q
    r2_pred1 = (decision.psi * (1.0 - decision.q)
                <= decision.p * decision.q)
    return jnp.where(in_r2, r2_pred1,
                     decision.local_pred == 1).astype(jnp.int32)


def effective_local_pred(
    decision: FleetDecision, sent: jnp.ndarray
) -> jnp.ndarray:
    """Local prediction in effect once `sent` is known: capacity-dropped
    offloads use the conditional fallback draw, everyone else keeps
    `local_pred`. Shared by `fleet_feedback` and the HI server so the
    reported predictions always match the weight updates."""
    dropped = decision.offload & ~sent
    return jnp.where(dropped, local_fallback_pred(decision),
                     decision.local_pred)


def fleet_feedback(
    cfg: HIConfig,
    state: H2T2State,        # leaves batched over (S,)
    decision: FleetDecision,
    hrs: jnp.ndarray,        # (S,) remote labels; only consumed where sent/explored
    betas: jnp.ndarray,      # (S,) decision-time offload costs
    sent: Optional[jnp.ndarray] = None,   # (S,) bool — offloads that reached the RDL
    *,
    eta: Optional[jnp.ndarray] = None,    # (S,) or scalar; None → cfg.eta
    decay: Optional[jnp.ndarray] = None,  # (S,) or scalar; None → cfg.decay
    use_kernel=UNSET,        # deprecated — pass spec=ExecSpec(...)
    interpret=UNSET,         # deprecated — pass spec=ExecSpec(...)
    spec: Optional[ExecSpec] = None,
) -> Tuple[H2T2State, StepOutput]:
    """Second half of `h2t2_step`: charge losses and update expert weights.

    `sent` defaults to `decision.offload`; pass the post-compaction mask when
    capacity dropped some offloads — dropped samples revert to a local
    prediction (`local_fallback_pred`, the conditional draw) and contribute
    no pseudo-loss feedback (their h_r was never observed). `hrs` rows where
    `~sent` are only used for the simulation-grade φ accounting in the
    returned `StepOutput.loss`; a real server without ground truth should
    ignore those rows.

    `eta`/`decay` override the config's fixed schedule per stream (the
    adaptive engine passes `adapt_schedule`'s output here); the defaults
    broadcast the HIConfig scalars, which is bit-identical to the fixed
    paper schedule.

    `spec.use_kernel` routes the weight update through the Pallas
    feedback kernel (which takes the post-compaction `sent` mask and the
    per-stream schedule as VMEM vectors); the (S,) loss/prediction
    accounting always stays in jnp. The default auto-selects like
    `fleet_step_fused`; `spec.learner` picks the weight structure
    (non-dense learners always route through the ops layer). The loose
    `use_kernel`/`interpret` kwargs are deprecated shims onto the spec.

    `fleet_decide` + `fleet_feedback` (with full `hrs` and `sent=None`)
    reproduces the vmapped `h2t2_step` exactly — state and outputs.
    """
    spec = resolve_spec(spec, caller="fleet_feedback",
                        use_kernel=use_kernel, interpret=interpret)
    if sent is None:
        sent = decision.offload
    sent = sent.astype(bool)
    explored = decision.explored & sent
    loss, pred = _charge_losses(cfg, sent, effective_local_pred(decision, sent),
                                hrs, betas)
    dtype = state.log_w.dtype
    eta = jnp.broadcast_to(
        jnp.asarray(cfg.eta if eta is None else eta, dtype), sent.shape)
    decay = jnp.broadcast_to(
        jnp.asarray(cfg.decay if decay is None else decay, dtype), sent.shape)

    uk = _resolve_use_kernel(spec.use_kernel, spec.interpret)
    if uk or spec.learner != "dense":
        from repro.core.learners import get_learner
        from repro.kernels.hedge.ops import fleet_hedge_feedback

        new_lw = fleet_hedge_feedback(
            cfg, state.log_w, decision.i_f, sent.astype(jnp.int32),
            explored.astype(jnp.int32), hrs.astype(jnp.int32), betas,
            eta=eta, decay=decay, spec=spec.evolve(use_kernel=uk))
        # The kernel's NEG sentinel → -inf (dense), so kernel- and
        # jnp-updated states are interchangeable representations.
        log_w = get_learner(spec.learner).remask(cfg, new_lw)
    else:
        def one(lw, i_f, off, exp_, hr, beta, eta_s, decay_s):
            lt = pseudo_loss(cfg, i_f, off, exp_, hr, beta)
            new_lw = decay_s * lw - eta_s * lt
            return new_lw - jnp.max(jnp.where(jnp.isfinite(new_lw), new_lw,
                                              -jnp.inf))

        log_w = jax.vmap(one)(
            state.log_w, decision.i_f, sent, explored, hrs, betas, eta, decay)
    new_state = H2T2State(
        log_w=log_w,
        t=state.t + 1,
        n_offloads=state.n_offloads + sent.astype(jnp.int32),
        n_explores=state.n_explores + explored.astype(jnp.int32),
    )
    return new_state, StepOutput(
        offload=sent, pred=pred, local_pred=decision.local_pred, loss=loss,
        explored=explored, q=decision.q, p=decision.p,
    )


# ------------------------ shift-conditioned schedules -------------------------
#
# The fixed (η, decay) schedule is Algorithm 1; under distribution shift the
# accumulated expert evidence is stale, so the adaptive serving policy
# conditions the schedule on detector state (core.shift) and may restart the
# expert weights outright on a confirmed shift. Both pieces are jit-able and
# per-stream, composing with the batched fleet rounds above.


def adapt_schedule(cfg: HIConfig, shift_cfg, shift_state
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-stream (η, decay) conditioned on detector state.

    Right after stream s's last confirmed shift (`since_alarm = 0`) the
    learning rate is boosted to `eta_boost · η` and the weight decay pulled
    to `recovery_decay` (None: left at cfg.decay), so fresh evidence
    dominates; both anneal back to the HIConfig values as
    exp(-since_alarm / recovery). A stream that has never alarmed sits at
    the fixed-schedule *values* exactly (`since_alarm` starts at
    `COUNTER_CAP`, where the boost underflows to 0); note the returned
    arrays are traced, so XLA may fuse the weight update differently than
    with compile-time-constant η/decay (≈1-ulp weight differences — disable
    the detector outright for bit-parity).
    """
    boost = jnp.exp(-shift_state.since_alarm.astype(cfg.dtype)
                    / shift_cfg.recovery)
    eta = cfg.eta * (1.0 + (shift_cfg.eta_boost - 1.0) * boost)
    decay_target = (cfg.decay if shift_cfg.recovery_decay is None
                    else shift_cfg.recovery_decay)
    decay = cfg.decay + (decay_target - cfg.decay) * boost
    return eta, decay


def fleet_restart(cfg: HIConfig, state: H2T2State, mask: jnp.ndarray,
                  learner: str = "dense") -> H2T2State:
    """Re-initialize expert log-weights where `mask` (S,) is set.

    The restart is weights-only: the round/offload/exploration counters —
    the stream's threshold *history* — are preserved, so regret accounting
    and ε/η horizon schedules keep their meaning across a restart. Streams
    outside the mask are untouched. `learner` names the weight structure
    (`core.learners`); the fresh weights match `fleet_init`'s.
    """
    from repro.core.learners import get_learner

    fresh = get_learner(learner).fresh_weights(cfg).astype(state.log_w.dtype)
    mask = mask.astype(bool)
    return state._replace(
        log_w=jnp.where(mask[:, None, None], fresh[None], state.log_w))


def h2t2_step(
    cfg: HIConfig,
    state: H2T2State,
    f: jnp.ndarray,
    beta: jnp.ndarray,
    h_r: jnp.ndarray,
    key: jax.Array,
) -> Tuple[H2T2State, StepOutput]:
    """One round of Algorithm 1: `_decide_one` + the shared feedback math.

    `h_r` is the remote model's label for this sample; the policy only *uses* it
    when the sample is offloaded (masked) — passing it unconditionally keeps the
    step jit-able. The returned loss charges β_t on offload and φ_t otherwise.
    """
    k_psi, k_zeta = jax.random.split(key)
    psi = jax.random.uniform(k_psi)
    zeta = jax.random.bernoulli(k_zeta, cfg.eps)
    dec = _decide_one(cfg, state.log_w, f, psi, zeta)

    # Incurred loss l_t: offload pays β_t; local decision pays φ_t vs h_r proxy.
    loss, pred = _charge_losses(cfg, dec.offload, dec.local_pred, h_r, beta)

    lt = pseudo_loss(cfg, dec.i_f, dec.offload, dec.explored, h_r, beta)
    # decay < 1 = discounted Hedge (beyond-paper): geometric forgetting of
    # accumulated losses, for non-stationary streams. decay = 1 is Alg. 1.
    log_w = cfg.decay * state.log_w - cfg.eta * lt
    # Periodic renormalization keeps log-weights in float range on long horizons.
    log_w = log_w - jnp.max(jnp.where(jnp.isfinite(log_w), log_w, -jnp.inf))

    new_state = H2T2State(
        log_w=log_w,
        t=state.t + 1,
        n_offloads=state.n_offloads + dec.offload.astype(jnp.int32),
        n_explores=state.n_explores + dec.explored.astype(jnp.int32),
    )
    return new_state, StepOutput(
        offload=dec.offload, pred=pred, local_pred=dec.local_pred, loss=loss,
        explored=dec.explored, q=dec.q, p=dec.p,
    )


def run_stream(
    cfg: HIConfig,
    fs: jnp.ndarray,
    hrs: jnp.ndarray,
    betas: jnp.ndarray,
    key: jax.Array,
    state: Optional[H2T2State] = None,
) -> Tuple[H2T2State, StepOutput]:
    """Run H2T2 over a whole (f_t, h_r, β_t) trace with lax.scan.

    Returns the final state and the stacked per-round StepOutput.
    """
    if state is None:
        state = h2t2_init(cfg)
    keys = jax.random.split(key, fs.shape[0])

    def body(st, xs):
        f, hr, beta, k = xs
        st, out = h2t2_step(cfg, st, f, beta, hr, k)
        return st, out

    return jax.lax.scan(body, state, (fs, hrs, betas, keys))


def run_fleet(
    cfg: HIConfig,
    fs: jnp.ndarray,       # (S, T)
    hrs: jnp.ndarray,      # (S, T)
    betas: jnp.ndarray,    # (S, T)
    key: Optional[jax.Array] = None,
    *,
    stream_keys: Optional[jnp.ndarray] = None,
) -> Tuple[H2T2State, StepOutput]:
    """vmap `run_stream` over S independent edge streams.

    Pass `stream_keys` (S, 2) to pin per-stream keys directly (same contract
    as `run_fleet_fused`), otherwise `key` is split into one key per stream.
    """
    if stream_keys is None:
        if key is None:
            raise ValueError("run_fleet needs `key` or `stream_keys`")
        stream_keys = jax.random.split(key, fs.shape[0])
    return jax.vmap(lambda f, h, b, k: run_stream(cfg, f, h, b, k))(
        fs, hrs, betas, stream_keys)


# --------------------------- fused fleet path --------------------------------
#
# The reference path above scans `h2t2_step` per stream and vmaps over the
# fleet. The fused path below pre-draws all (ψ, ζ) randomness for the horizon
# and drives a single lax.scan over time whose body is the batched
# `fleet_hedge_step` (Pallas kernel on TPU, jnp oracle elsewhere). Same
# pytrees in, same pytrees out; the randomness pre-draw mirrors the reference
# key-split tree exactly, so both paths make sample-for-sample identical
# decisions for the same key.


def fleet_init(cfg: HIConfig, n_streams: int,
               learner: str = "dense") -> H2T2State:
    """`h2t2_init` batched over a fleet: every leaf gains a leading (S,) axis.

    `learner` names the weight structure (`core.learners`): "dense" is the
    paper's (S, G, G) grid (bit-identical to the vmapped `h2t2_init`);
    other learners supply their own `log_w` leaf layout (e.g. (S, 2, G)
    for "factored") with the same (S,) counters.
    """
    if learner == "dense":
        return jax.vmap(lambda _: h2t2_init(cfg))(jnp.arange(n_streams))
    from repro.core.learners import get_learner

    zero = jnp.zeros((n_streams,), jnp.int32)
    return H2T2State(
        log_w=get_learner(learner).fleet_weights(cfg, n_streams),
        t=zero, n_offloads=zero, n_explores=zero)


def draw_psi_zeta(keys: jnp.ndarray, eps: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The (ψ, ζ) draws `h2t2_step` makes from a batch of round keys.

    This is THE key-consumption contract: every fused path must draw through
    it (key → split → uniform(k₀), bernoulli(k₁, ε)) so decisions stay
    bit-for-bit identical to the reference `h2t2_step`.
    """
    pz = jax.vmap(jax.random.split)(keys)                # (N, 2, 2)
    psi = jax.vmap(jax.random.uniform)(pz[:, 0])
    zeta = jax.vmap(lambda k: jax.random.bernoulli(k, eps))(pz[:, 1])
    return psi, zeta


def draw_fleet_randomness(
    cfg: HIConfig,
    key: Optional[jax.Array],
    n_streams: int,
    horizon: int,
    stream_keys: Optional[jnp.ndarray] = None,
    *,
    randomness: str = "pre_draw",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-draw the (ψ, ζ) used by every (stream, round), as (S, T) arrays.

    `randomness="pre_draw"` (default) reproduces `run_fleet`'s key tree
    bit-for-bit: key → S stream keys → T round keys each → `draw_psi_zeta`.
    Pass `stream_keys` (S, 2) to pin per-stream keys directly (e.g. one
    PRNGKey per seed).

    `randomness="counter"` materializes the counter contract instead —
    `psi_zeta_from_counter(seed_from_key(key), stream, slot)` over the full
    (S, T) grid. This is the O(S×T) cross-check for the in-kernel counter
    path (which never materializes it); `stream_keys` is invalid here
    because counter draws are position-keyed, not key-tree-keyed.
    """
    check_randomness_mode(randomness)
    if randomness == "counter":
        if stream_keys is not None:
            raise ValueError(
                "counter randomness is position-keyed; `stream_keys` only "
                "applies to pre_draw")
        if key is None:
            raise ValueError("draw_fleet_randomness needs `key`")
        seed = seed_from_key(key)
        sid = jnp.arange(n_streams, dtype=jnp.int32)
        slots = jnp.arange(horizon, dtype=jnp.int32)
        return psi_zeta_from_counter(
            seed, sid[:, None], slots[None, :], cfg.eps)
    if stream_keys is None:
        if key is None:
            raise ValueError("draw_fleet_randomness needs `key` or `stream_keys`")
        stream_keys = jax.random.split(key, n_streams)

    def per_stream(sk):
        return draw_psi_zeta(jax.random.split(sk, horizon), cfg.eps)

    return jax.vmap(per_stream)(stream_keys)


def source_slot_keys(key: jax.Array, t, n_streams: int) -> jnp.ndarray:
    """Per-stream policy keys for absolute slot t of a source-driven run.

    THE key contract for chunked (ScenarioSource) runs, the analogue of
    `draw_fleet_randomness` for horizons that are never materialized:
    stream s's round key at slot t is fold_in(fold_in(key, t), s). Purely
    index-keyed, so every block size — and every engine, all of which
    consume keys through `draw_psi_zeta` — sees identical randomness.
    """
    kt = jax.random.fold_in(key, t)
    return jax.vmap(lambda i: jax.random.fold_in(kt, i))(
        jnp.arange(n_streams))


def draw_fleet_slot_randomness(
    cfg: HIConfig, key: jax.Array, n_streams: int, horizon: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Materialize the slot-keyed contract as (S, T) arrays: slot t draws
    `draw_psi_zeta(source_slot_keys(key, t, S))`.

    This is what every source-driven pre-draw path (`run_fleet_source` and
    all engines' `run_source`) consumes slot-by-slot; materializing it lets
    tests pin the "identical randomness" claim against actual runs without
    replaying a source.
    """

    def per_slot(t):
        return draw_psi_zeta(source_slot_keys(key, t, n_streams), cfg.eps)

    psis, zetas = jax.vmap(per_slot)(jnp.arange(horizon))     # (T, S)
    return psis.T, zetas.T


class SourceRunOutput(NamedTuple):
    """Per-block fleet aggregates of a source-driven run; leaves are
    (S, n_blocks) — O(S·T/block) residency instead of the (S, T) StepOutput.

    `loss` is the policy-observed cost (β on offload, φ against the remote
    label `hrs`); `true_loss` charges ground truth: β per offload PLUS
    φ(final prediction, ys) — under `noisy_rdl` an offloaded sample can pay
    both β and a misclassification, which observed accounting cannot see.
    """

    loss: jnp.ndarray        # (S, n_blocks) Σ observed loss per block
    true_loss: jnp.ndarray   # (S, n_blocks) Σ β·O_t + φ(pred_t, y_t)
    offloads: jnp.ndarray    # (S, n_blocks) int32 offload counts
    explores: jnp.ndarray    # (S, n_blocks) int32 exploration counts
    correct: jnp.ndarray     # (S, n_blocks) int32 count(pred_t == y_t)


def classification_cost(cfg: HIConfig, pred: jnp.ndarray,
                        label: jnp.ndarray) -> jnp.ndarray:
    """φ(pred, label): δ₁ on a false positive, δ₋₁ on a false negative."""
    return jnp.where(
        pred == 1,
        jnp.where(label == 0, cfg.delta_fp, 0.0),
        jnp.where(label == 1, cfg.delta_fn, 0.0),
    )


def true_loss_fleet(cfg: HIConfig, out: StepOutput, ys: jnp.ndarray,
                    betas: jnp.ndarray) -> jnp.ndarray:
    """Ground-truth cost of a fleet slot: β per offload + φ(pred, y)."""
    return (jnp.where(out.offload, betas, 0.0)
            + classification_cost(cfg, out.pred, ys))


def run_fleet_source(
    cfg: HIConfig,
    source,                  # ScenarioSource (duck-typed; keeps core ↛ data)
    key: jax.Array,
    *,
    state: Optional[H2T2State] = None,
    step_fn=None,
    use_kernel=UNSET,        # deprecated — pass spec=ExecSpec(...)
    interpret=UNSET,         # deprecated — pass spec=ExecSpec(...)
    randomness=UNSET,        # deprecated — pass spec=ExecSpec(...)
    spec: Optional[ExecSpec] = None,
) -> Tuple[H2T2State, SourceRunOutput]:
    """Run a fleet over a `ScenarioSource` block-by-block, never holding the
    (S, T) trace: each `lax.scan` block emits one (S, block) SlotBatch and
    reduces it to per-block aggregates on device.

    `step_fn(state, fs, betas, hrs, keys, t) -> (state, StepOutput)` selects
    the execution path (pass a `PolicyEngine._step`); defaults to the fused
    fleet step. Under `randomness="pre_draw"` policy randomness follows
    `source_slot_keys(key, t, S)` (see `draw_fleet_slot_randomness` for the
    materialized form), so every step path produces identical decisions for
    the same `key`. Under `randomness="counter"` the slot keys are never
    built — `keys` carries the (2,) uint32 counter seed
    (`seed_from_key(key)`, constant across slots) and the step draws in
    place at position (seed, stream, slot `t`).
    """
    if key is None:
        raise TypeError("run_fleet_source needs a policy `key` (the source "
                        "carries only its own generative key)")
    spec = resolve_spec(spec, caller="run_fleet_source",
                        use_kernel=use_kernel, interpret=interpret,
                        randomness=randomness)
    s, bsz = source.n_streams, source.block
    counter = spec.randomness == "counter"
    seed = seed_from_key(key) if counter else None
    if step_fn is None:
        if counter:
            def step_fn(st, f, beta, hr, keys, t):
                rng = CounterRNG(seed=keys,
                                 slot=jnp.asarray(t, jnp.int32),
                                 stream_offset=jnp.zeros((), jnp.int32))
                return fleet_step_fused(
                    cfg, st, f, None, None, hr, beta, rng=rng, spec=spec)
        else:
            def step_fn(st, f, beta, hr, keys, t):
                psi, zeta = draw_psi_zeta(keys, cfg.eps)
                return fleet_step_fused(
                    cfg, st, f, psi, zeta, hr, beta, spec=spec)

    if state is None:
        state = fleet_init(cfg, s, learner=spec.learner)
    src_key = source.key

    def slot_body(pst, xs):
        f, hr, y, beta, t = xs
        keys = seed if counter else source_slot_keys(key, t, s)
        pst, out = step_fn(pst, f, beta, hr, keys, t)
        return pst, (out.loss, true_loss_fleet(cfg, out, y, beta),
                     out.offload, out.explored, out.pred == y)

    def block_body(carry, b):
        pst, sst = carry
        sst, batch = source.emit(sst, src_key, b)
        ts = b * bsz + jnp.arange(bsz, dtype=jnp.int32)
        tp = lambda a: jnp.swapaxes(a, 0, 1)
        pst, per = jax.lax.scan(
            slot_body, pst,
            (tp(batch.fs), tp(batch.hrs), tp(batch.ys), tp(batch.betas), ts))
        loss, true, off, exp_, corr = per                     # (block, S)
        return (pst, sst), (
            jnp.sum(loss, 0), jnp.sum(true, 0),
            jnp.sum(off.astype(jnp.int32), 0),
            jnp.sum(exp_.astype(jnp.int32), 0),
            jnp.sum(corr.astype(jnp.int32), 0))

    (final, _), blocks = jax.lax.scan(
        block_body, (state, source.init_state()),
        jnp.arange(source.n_blocks))
    tp = lambda a: jnp.swapaxes(a, 0, 1)                      # → (S, n_blocks)
    return final, SourceRunOutput(*map(tp, blocks))


def _charge_losses(
    cfg: HIConfig, offload: jnp.ndarray, local_pred: jnp.ndarray,
    h_r: jnp.ndarray, beta: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Incurred loss and final prediction from the fused-step decisions."""
    loss = jnp.where(offload, beta,
                     classification_cost(cfg, local_pred, h_r))
    pred = jnp.where(offload, h_r.astype(jnp.int32), local_pred)
    return loss, pred


def fleet_step_fused(
    cfg: HIConfig,
    state: H2T2State,        # leaves batched over (S,)
    f: jnp.ndarray,          # (S,)
    psi: Optional[jnp.ndarray],   # (S,) pre-drawn uniforms; None w/ rng
    zeta: Optional[jnp.ndarray],  # (S,) pre-drawn bernoulli(ε); None w/ rng
    h_r: jnp.ndarray,        # (S,)
    beta: jnp.ndarray,       # (S,)
    use_kernel=UNSET,        # deprecated — pass spec=ExecSpec(...)
    interpret=UNSET,         # deprecated — pass spec=ExecSpec(...)
    *,
    rng: Optional[CounterRNG] = None,     # counter-mode draw position
    eta: Optional[jnp.ndarray] = None,    # (S,) per-stream η; None → cfg.eta
    decay: Optional[jnp.ndarray] = None,  # (S,) per-stream decay
    spec: Optional[ExecSpec] = None,
) -> Tuple[H2T2State, StepOutput]:
    """One fleet round via the fused kernel; mirrors vmapped `h2t2_step`.

    Randomness is either pre-drawn (ψ, ζ) operands or — with `psi=zeta=None`
    and a `rng` counter position — regenerated in place from
    `(seed, stream, slot)`, so nothing randomness-shaped ever sits in HBM.

    `spec.use_kernel=None` auto-selects: compiled Pallas on TPU, jnp oracle
    elsewhere — unless `interpret=True`, which forces the kernel in
    interpret mode (the correctness-test path on CPU); `spec.learner`
    picks the weight structure. `eta`/`decay` override the fixed schedule
    per stream (the kernels take them as (S,) VMEM vectors; the broadcast
    defaults are bit-identical to the paper schedule). The loose
    `use_kernel`/`interpret` kwargs are deprecated shims onto the spec.
    """
    from repro.core.learners import get_learner
    from repro.kernels.hedge.ops import fleet_hedge_step

    spec = resolve_spec(spec, caller="fleet_step_fused",
                        use_kernel=use_kernel, interpret=interpret)
    kspec = spec.evolve(
        use_kernel=_resolve_use_kernel(spec.use_kernel, spec.interpret),
        randomness="counter" if rng is not None else "pre_draw")
    if rng is not None:
        new_lw, off, exp_, lp, q, p = fleet_hedge_step(
            cfg, state.log_w, f, None, None,
            h_r.astype(jnp.int32), beta,
            eta=eta, decay=decay, rng=rng, spec=kspec)
    else:
        new_lw, off, exp_, lp, q, p = fleet_hedge_step(
            cfg, state.log_w, f, psi, zeta.astype(jnp.int32),
            h_r.astype(jnp.int32), beta, eta=eta, decay=decay, spec=kspec)
    offload = off.astype(bool)
    explored = exp_.astype(bool)
    loss, pred = _charge_losses(cfg, offload, lp, h_r, beta)
    # Re-mask invalid cells to -inf so fused state is interchangeable with the
    # reference representation (the dense kernel uses a -1e30 sentinel
    # internally; non-dense learners have no invalid cells).
    log_w = get_learner(spec.learner).remask(cfg, new_lw)
    new_state = H2T2State(
        log_w=log_w,
        t=state.t + 1,
        n_offloads=state.n_offloads + offload.astype(jnp.int32),
        n_explores=state.n_explores + explored.astype(jnp.int32),
    )
    return new_state, StepOutput(
        offload=offload, pred=pred, local_pred=lp, loss=loss,
        explored=explored, q=q, p=p,
    )


def fleet_rounds_fused(
    cfg: HIConfig,
    state: H2T2State,        # leaves batched over (S,)
    f: jnp.ndarray,          # (S, TB)
    psi: Optional[jnp.ndarray],   # (S, TB) pre-drawn uniforms; None w/ rng
    zeta: Optional[jnp.ndarray],  # (S, TB) pre-drawn ζ; None w/ rng
    h_r: jnp.ndarray,        # (S, TB)
    beta: jnp.ndarray,       # (S, TB)
    use_kernel=UNSET,        # deprecated — pass spec=ExecSpec(...)
    interpret=UNSET,         # deprecated — pass spec=ExecSpec(...)
    *,
    rng: Optional[CounterRNG] = None,     # counter position of the block's
                                          # first round; round j draws slot+j
    eta: Optional[jnp.ndarray] = None,    # (S,) per-stream η; None → cfg.eta
    decay: Optional[jnp.ndarray] = None,  # (S,) per-stream decay
    spec: Optional[ExecSpec] = None,
) -> Tuple[H2T2State, StepOutput]:
    """TB rounds for the whole fleet in one multi-round kernel launch.

    Mirrors a TB-long chain of `fleet_step_fused` calls — same state, same
    (S, TB) StepOutput leaves — with the expert grids resident in VMEM for
    the whole block on TPU. With a counter `rng` (and `psi=zeta=None`),
    round j of the block draws at slot `rng.slot + j` in-kernel — peak
    randomness residency O(S×TB) regardless of the horizon. The (η, decay)
    schedule is per-stream but held fixed across the block (a constraint
    the serving layer checks before taking this path for an adaptive
    schedule).
    """
    from repro.core.learners import get_learner
    from repro.kernels.hedge.ops import fleet_hedge_rounds

    spec = resolve_spec(spec, caller="fleet_rounds_fused",
                        use_kernel=use_kernel, interpret=interpret)
    kspec = spec.evolve(
        use_kernel=_resolve_use_kernel(spec.use_kernel, spec.interpret),
        randomness="counter" if rng is not None else "pre_draw")
    if rng is not None:
        new_lw, off, exp_, lp, q, p = fleet_hedge_rounds(
            cfg, state.log_w, f, None, None,
            h_r.astype(jnp.int32), beta,
            eta=eta, decay=decay, rng=rng, spec=kspec)
    else:
        new_lw, off, exp_, lp, q, p = fleet_hedge_rounds(
            cfg, state.log_w, f, psi, zeta.astype(jnp.int32),
            h_r.astype(jnp.int32), beta, eta=eta, decay=decay, spec=kspec)
    offload = off.astype(bool)
    explored = exp_.astype(bool)
    loss, pred = _charge_losses(cfg, offload, lp, h_r, beta)
    new_state = H2T2State(
        log_w=get_learner(spec.learner).remask(cfg, new_lw),
        t=state.t + f.shape[1],
        n_offloads=state.n_offloads + jnp.sum(off, axis=1),
        n_explores=state.n_explores + jnp.sum(exp_, axis=1),
    )
    return new_state, StepOutput(offload=offload, pred=pred, local_pred=lp,
                                 loss=loss, explored=explored, q=q, p=p)


def run_fleet_fused(
    cfg: HIConfig,
    fs: jnp.ndarray,       # (S, T)
    hrs: jnp.ndarray,      # (S, T)
    betas: jnp.ndarray,    # (S, T)
    key: Optional[jax.Array] = None,
    state: Optional[H2T2State] = None,
    *,
    use_kernel=UNSET,        # deprecated — pass spec=ExecSpec(...)
    interpret=UNSET,         # deprecated — pass spec=ExecSpec(...)
    time_block=UNSET,        # deprecated — pass spec=ExecSpec(...)
    stream_keys: Optional[jnp.ndarray] = None,
    randomness=UNSET,        # deprecated — pass spec=ExecSpec(...)
    eta: Optional[jnp.ndarray] = None,    # (S,) per-stream η; None → cfg.eta
    decay: Optional[jnp.ndarray] = None,  # (S,) per-stream decay
    spec: Optional[ExecSpec] = None,
) -> Tuple[H2T2State, StepOutput]:
    """Kernel-backed `run_fleet`: scan over time of the batched fused step.

    Produces the same (H2T2State, StepOutput) pytrees as `run_fleet` — leaves
    batched (S,) / (S, T) — and, for the same `key`, the same decisions.
    `time_block > 1` drives the multi-round kernel (`fleet_hedge_rounds`),
    which keeps the expert grids in VMEM for `time_block` rounds per launch;
    requires T % time_block == 0. `eta`/`decay` thread a per-stream (S,)
    schedule (held fixed over the horizon) through either kernel path.

    `spec.randomness="pre_draw"` (default, the golden path) materializes
    the whole (S, T) (ψ, ζ) block up front. `"counter"` never does: each
    scan step carries only a counter position (seed, slot, offset) and
    the draws are regenerated in place — peak randomness residency
    O(S×time_block). Counter runs are position-keyed off `key` alone;
    `stream_keys` is a pre-draw-only knob. `spec.time_block=None` means 1
    here (the single-round step path). The loose `use_kernel`/`interpret`/
    `time_block`/`randomness` kwargs are deprecated shims onto the spec.
    """
    spec = resolve_spec(spec, caller="run_fleet_fused",
                        use_kernel=use_kernel, interpret=interpret,
                        time_block=time_block, randomness=randomness)
    tb = 1 if spec.time_block is None else spec.time_block
    s, t = fs.shape
    if state is None:
        state = fleet_init(cfg, s, learner=spec.learner)

    if spec.randomness == "counter":
        if stream_keys is not None:
            raise ValueError(
                "counter randomness is position-keyed; `stream_keys` only "
                "applies to pre_draw")
        if key is None:
            raise ValueError("counter randomness needs `key`")
        seed = seed_from_key(key)
        offset = jnp.zeros((), jnp.int32)
        if tb == 1:
            def body(st, xs):
                f, hr, beta, slot = xs
                rng = CounterRNG(seed=seed, slot=slot, stream_offset=offset)
                return fleet_step_fused(
                    cfg, st, f, None, None, hr, beta,
                    rng=rng, eta=eta, decay=decay, spec=spec)

            slots = jnp.arange(t, dtype=jnp.int32)
            final, outs = jax.lax.scan(
                body, state, (fs.T, hrs.T, betas.T, slots))
            return final, jax.tree_util.tree_map(
                lambda a: jnp.swapaxes(a, 0, 1), outs)

        if t % tb:
            raise ValueError(
                f"horizon {t} not divisible by time_block {tb}")
        n_blocks = t // tb
        blocked = lambda a: jnp.swapaxes(
            a.reshape(s, n_blocks, tb), 0, 1)
        xs = tuple(blocked(a) for a in (fs, hrs, betas))
        slot0s = jnp.arange(n_blocks, dtype=jnp.int32) * tb

        def body(st, xs_):
            f, hr, beta, slot0 = xs_
            rng = CounterRNG(seed=seed, slot=slot0, stream_offset=offset)
            return fleet_rounds_fused(
                cfg, st, f, None, None, hr, beta,
                rng=rng, eta=eta, decay=decay, spec=spec)

        final, outs = jax.lax.scan(body, state, xs + (slot0s,))
        unblock = lambda a: jnp.swapaxes(a, 0, 1).reshape(s, t)
        return final, jax.tree_util.tree_map(unblock, outs)

    psis, zetas = draw_fleet_randomness(cfg, key, s, t, stream_keys)

    if tb == 1:
        def body(st, xs):
            f, psi, zeta, hr, beta = xs
            return fleet_step_fused(cfg, st, f, psi, zeta, hr, beta,
                                    eta=eta, decay=decay, spec=spec)

        final, outs = jax.lax.scan(
            body, state, (fs.T, psis.T, zetas.T, hrs.T, betas.T))
        return final, jax.tree_util.tree_map(
            lambda a: jnp.swapaxes(a, 0, 1), outs)

    if t % tb:
        raise ValueError(f"horizon {t} not divisible by time_block {tb}")
    n_blocks = t // tb
    # (S, T) → (n_blocks, S, TB) so scan iterates over time blocks.
    blocked = lambda a: jnp.swapaxes(a.reshape(s, n_blocks, tb), 0, 1)
    xs = tuple(blocked(a) for a in (fs, psis, zetas, hrs, betas))

    def body(st, xs_):
        f, psi, zeta, hr, beta = xs_                     # (S, TB) each
        return fleet_rounds_fused(cfg, st, f, psi, zeta, hr, beta,
                                  eta=eta, decay=decay, spec=spec)

    final, outs = jax.lax.scan(body, state, xs)
    # (n_blocks, S, TB) → (S, T)
    unblock = lambda a: jnp.swapaxes(a, 0, 1).reshape(s, t)
    return final, jax.tree_util.tree_map(unblock, outs)
