"""H2T2 — HI-Hedge with Two Thresholds (paper Algorithm 1).

Experts are threshold tuples θ⃗ = (θ_l, θ_u), θ ∈ {k/G : k = 0..G-1}, θ_l ≤ θ_u,
held as a dense (G, G) log-weight matrix (row = l index, col = u index) with an
upper-triangular validity mask. Confidences are quantized to i_f = ⌊f·G⌋ so that
  region 1 (predict 0):  i_f <  l
  region 2 (ambiguous):  l ≤ i_f < u   → offload
  region 3 (predict 1):  u ≤ i_f
Weights live in log-space; region masses use logsumexp for numerical stability
over long horizons (w ← w·e^{-η·l̃} underflows in linear space by T ~ 1e4).

Everything is jit/vmap friendly: `h2t2_step` is a pure function of (state, sample,
key) and is vmapped over independent edge streams by the serving layer.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import HIConfig


class H2T2State(NamedTuple):
    log_w: jnp.ndarray      # (G, G) float — log expert weights; -inf on invalid cells
    t: jnp.ndarray          # () int32 — rounds seen
    n_offloads: jnp.ndarray  # () int32
    n_explores: jnp.ndarray  # () int32


class StepOutput(NamedTuple):
    offload: jnp.ndarray      # () bool — was the sample offloaded
    pred: jnp.ndarray         # () int32 — final inference (local or remote)
    local_pred: jnp.ndarray   # () int32 — what the local decision would have been
    loss: jnp.ndarray         # () float — incurred loss l_t (β_t if offloaded, φ_t else)
    explored: jnp.ndarray     # () bool — E_t
    q: jnp.ndarray            # () float — region-2 probability mass
    p: jnp.ndarray            # () float — region-3 probability mass


def _valid_mask(g: int, dtype=jnp.float32) -> jnp.ndarray:
    l = jnp.arange(g)[:, None]
    u = jnp.arange(g)[None, :]
    return (l <= u)


def h2t2_init(cfg: HIConfig) -> H2T2State:
    g = cfg.grid
    valid = _valid_mask(g)
    log_w = jnp.where(valid, 0.0, -jnp.inf).astype(cfg.dtype)
    zero = jnp.zeros((), jnp.int32)
    return H2T2State(log_w=log_w, t=zero, n_offloads=zero, n_explores=zero)


def quantize(f: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantize confidence f ∈ [0, 1] to the grid index i_f = ⌊f·G⌋ ∈ {0..G-1}."""
    g = 1 << bits
    return jnp.clip((f * g).astype(jnp.int32), 0, g - 1)


def region_masks(i_f: jnp.ndarray, g: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Boolean masks (G, G) of experts in regions 1/2/3 for quantized conf i_f."""
    l = jnp.arange(g)[:, None]
    u = jnp.arange(g)[None, :]
    valid = l <= u
    r2 = valid & (l <= i_f) & (i_f < u)          # ambiguous → offload
    r3 = valid & (u <= i_f)                       # predict 1
    r1 = valid & (i_f < l)                        # predict 0
    return r1, r2, r3


def _masked_logsumexp(log_w: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    masked = jnp.where(mask, log_w, -jnp.inf)
    m = jnp.max(masked)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    s = jnp.sum(jnp.where(mask, jnp.exp(masked - m_safe), 0.0))
    return jnp.where(s > 0, m_safe + jnp.log(s), -jnp.inf)


def pseudo_loss(
    cfg: HIConfig,
    i_f: jnp.ndarray,
    offloaded: jnp.ndarray,
    explored: jnp.ndarray,
    h_r: jnp.ndarray,
    beta: jnp.ndarray,
) -> jnp.ndarray:
    """Unbiased pseudo-loss l̃_t(θ⃗) for every expert (paper Eq. 10).

    Feedback (h_r) is only available when the sample was offloaded (O_t = 1):
      l̃ = β_t          if O_t = 1 and the expert is ambiguous at i_f,
      l̃ = φ_t(θ⃗)/ε    if E_t = 1 and the expert is unambiguous at i_f,
      l̃ = 0            otherwise.
    φ_t(θ⃗) is the misclassification cost the *expert's own* local prediction
    would incur against the remote label h_r.
    """
    g = cfg.grid
    _, r2, r3 = region_masks(i_f, g)
    # Expert-local prediction: 1 in region 3, 0 in region 1 (region 2 offloads).
    pred1 = r3
    phi = jnp.where(
        pred1, jnp.where(h_r == 0, cfg.delta_fp, 0.0),
        jnp.where(h_r == 1, cfg.delta_fn, 0.0),
    )
    amb_term = jnp.where(offloaded & r2, beta, 0.0)
    una_term = jnp.where(explored & ~r2, phi / cfg.eps, 0.0)
    return amb_term + una_term


def h2t2_step(
    cfg: HIConfig,
    state: H2T2State,
    f: jnp.ndarray,
    beta: jnp.ndarray,
    h_r: jnp.ndarray,
    key: jax.Array,
) -> Tuple[H2T2State, StepOutput]:
    """One round of Algorithm 1.

    `h_r` is the remote model's label for this sample; the policy only *uses* it
    when the sample is offloaded (masked) — passing it unconditionally keeps the
    step jit-able. The returned loss charges β_t on offload and φ_t otherwise.
    """
    g = cfg.grid
    i_f = quantize(f, cfg.bits)
    r1, r2, r3 = region_masks(i_f, g)

    log_total = _masked_logsumexp(state.log_w, r1 | r2 | r3)
    q = jnp.exp(_masked_logsumexp(state.log_w, r2) - log_total)   # P(region 2)
    p = jnp.exp(_masked_logsumexp(state.log_w, r3) - log_total)   # P(region 3)

    k_psi, k_zeta = jax.random.split(key)
    psi = jax.random.uniform(k_psi)
    zeta = jax.random.bernoulli(k_zeta, cfg.eps)

    in_region2 = psi <= q
    offload = in_region2 | zeta
    explored = zeta & ~in_region2                                  # E_t
    local_pred = jnp.where(psi <= q + p, 1, 0).astype(jnp.int32)   # Alg. 1 l.17-20

    # Incurred loss l_t: offload pays β_t; local decision pays φ_t vs h_r proxy.
    phi_local = jnp.where(
        local_pred == 1,
        jnp.where(h_r == 0, cfg.delta_fp, 0.0),
        jnp.where(h_r == 1, cfg.delta_fn, 0.0),
    )
    loss = jnp.where(offload, beta, phi_local)
    pred = jnp.where(offload, h_r.astype(jnp.int32), local_pred)

    lt = pseudo_loss(cfg, i_f, offload, explored, h_r, beta)
    # decay < 1 = discounted Hedge (beyond-paper): geometric forgetting of
    # accumulated losses, for non-stationary streams. decay = 1 is Alg. 1.
    log_w = cfg.decay * state.log_w - cfg.eta * lt
    # Periodic renormalization keeps log-weights in float range on long horizons.
    log_w = log_w - jnp.max(jnp.where(jnp.isfinite(log_w), log_w, -jnp.inf))

    new_state = H2T2State(
        log_w=log_w,
        t=state.t + 1,
        n_offloads=state.n_offloads + offload.astype(jnp.int32),
        n_explores=state.n_explores + explored.astype(jnp.int32),
    )
    return new_state, StepOutput(
        offload=offload, pred=pred, local_pred=local_pred, loss=loss,
        explored=explored, q=q, p=p,
    )


def run_stream(
    cfg: HIConfig,
    fs: jnp.ndarray,
    hrs: jnp.ndarray,
    betas: jnp.ndarray,
    key: jax.Array,
    state: Optional[H2T2State] = None,
) -> Tuple[H2T2State, StepOutput]:
    """Run H2T2 over a whole (f_t, h_r, β_t) trace with lax.scan.

    Returns the final state and the stacked per-round StepOutput.
    """
    if state is None:
        state = h2t2_init(cfg)
    keys = jax.random.split(key, fs.shape[0])

    def body(st, xs):
        f, hr, beta, k = xs
        st, out = h2t2_step(cfg, st, f, beta, hr, k)
        return st, out

    return jax.lax.scan(body, state, (fs, hrs, betas, keys))


def run_fleet(
    cfg: HIConfig,
    fs: jnp.ndarray,       # (S, T)
    hrs: jnp.ndarray,      # (S, T)
    betas: jnp.ndarray,    # (S, T)
    key: jax.Array,
) -> Tuple[H2T2State, StepOutput]:
    """vmap `run_stream` over S independent edge streams."""
    keys = jax.random.split(key, fs.shape[0])
    return jax.vmap(lambda f, h, b, k: run_stream(cfg, f, h, b, k))(fs, hrs, betas, keys)
