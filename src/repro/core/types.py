"""Shared config/state dataclasses for the HI policy layer.

Notation follows the paper:
  f_t        LDL confidence for class 1 (softmax), quantized to b bits.
  delta_fp   δ₁   — normalized false-positive cost.
  delta_fn   δ₋₁  — normalized false-negative cost.
  beta_t     β_t  — normalized offloading cost (β_t ≤ β ≤ 1).
  Θ          expert grid {(θ_l, θ_u) : θ_l ≤ θ_u}, θ ∈ {k/G}, G = 2^b.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class HIConfig:
    """Static configuration of the cost-sensitive HI problem + H2T2 knobs."""

    bits: int = 4                 # confidence quantization b; grid side G = 2^b
    delta_fp: float = 0.7         # δ₁
    delta_fn: float = 1.0         # δ₋₁
    beta_max: float = 1.0         # β — upper bound used in Corollary 1
    eps: float = 0.05             # ε exploration probability
    eta: float = 1.0              # η learning rate (paper's §5 default)
    # BEYOND-PAPER: discount factor on accumulated log-weights (1.0 = paper's
    # H2T2). γ < 1 geometrically forgets old losses — discounted Hedge — which
    # re-adapts faster after distribution shift (see bench_drift).
    decay: float = 1.0
    dtype: jnp.dtype = jnp.float32

    @property
    def grid(self) -> int:
        return 1 << self.bits

    @property
    def n_experts(self) -> int:
        g = self.grid
        return g * (g + 1) // 2   # = 2^{b-1}(2^b + 1)

    def with_horizon(self, horizon: int) -> "HIConfig":
        """Return a copy with the regret-minimizing ε*, η* of Corollary 1."""
        import math

        n = self.n_experts
        beta = max(self.beta_max, 1e-6)
        eps = (math.log(n) / (2.0 * beta * beta * horizon)) ** (1.0 / 3.0)
        eps = min(max(eps, 1e-4), 1.0)
        eta = math.sqrt(2.0 * eps * math.log(n) / horizon)
        return dataclasses.replace(self, eps=eps, eta=eta)


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """A simulated (f_t, h_r) stream calibrated to a dataset/model pair.

    accuracy/fp/fn are fractions of ALL samples (paper Table 2/3 convention:
    accuracy + fp + fn = 1). p1 is the class-1 prior under the RDL proxy labels.
    """

    name: str
    accuracy: float
    fp: float
    fn: float
    p1: float = 0.5
    sigma1: float = 0.25          # confidence spread for h_r = 1 samples
    sigma0: float = 0.25          # confidence spread for h_r = 0 samples
    note: str = ""

    def __post_init__(self):
        total = self.accuracy + self.fp + self.fn
        # The paper's tables round to whole percent (e.g. ResnetDogs 73+15+11=99),
        # so allow rounding slack.
        if abs(total - 1.0) > 0.02:
            raise ValueError(f"{self.name}: accuracy+fp+fn must equal 1, got {total}")
        if not 0.0 < self.p1 < 1.0:
            raise ValueError(f"{self.name}: p1 must lie in (0, 1), got {self.p1}")
        # False negatives are a subset of the class-1 samples (and false
        # positives of the class-0 samples), so their fractions of ALL
        # samples cannot exceed the matching prior.
        if self.fn > self.p1 + 1e-9:
            raise ValueError(
                f"{self.name}: fn={self.fn} exceeds the class-1 prior "
                f"p1={self.p1}; impossible under the Table 2/3 convention")
        if self.fp > (1.0 - self.p1) + 1e-9:
            raise ValueError(
                f"{self.name}: fp={self.fp} exceeds the class-0 prior "
                f"1-p1={1.0 - self.p1}; impossible under the Table 2/3 "
                "convention")
