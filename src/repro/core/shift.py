"""Online distribution-shift detection over per-slot policy streams.

The paper's headline robustness claim is that H2T2 adapts to distribution
shifts and mismatched classifiers; this module supplies the *detection* half
of a detect -> adapt -> restart serving policy. A detector watches one scalar
signal per stream per slot (the observed loss, or the quantized confidence)
and raises a per-stream alarm when the signal's level shifts — the adaptive
`PolicyEngine` then boosts its learning schedule and may restart the expert
weights (`core.policy.fleet_restart`).

Detectors are jit-able pure functions of `(config, state, x)` with state
carried per stream exactly like `H2T2State` — every leaf is batched over
(S,), all updates are elementwise, and `shift_update` composes freely with
`lax.scan` / `vmap` / `shard_map` drivers:

  "cusum" — self-normalizing Page—Hinkley CUSUM over non-overlapping
            `stride`-slot block means: per-slot H2T2 signals are heavy-
            tailed and autocorrelated, so the statistic accumulates one
            normalized increment z = (block_mean - mean)/sd per block —
            independent by construction, so (drift, threshold) behave like
            a textbook CUSUM. The reference `mean` is an EWMA over block
            means and `var` a robust (3σ-clipped) EWMA of the squared block
            deviation, so drift/threshold are in sd units and transfer
            across workloads whose signal scales differ. Two-sided by
            default (a confidence shift can move either way); set
            `two_sided=False` to alarm only on upward (cost-raising)
            shifts of a loss signal.
  "ewma"  — windowed mean-shift: alarm when |fast - slow| exceeds
            `threshold` (here in raw signal units, per slot).
  "none"  — detection disabled; the adaptive engine then reduces exactly
            to the fixed-schedule policy (bit-identical decisions).

On alarm the detector restarts itself (statistics cleared, reference re-seeded
from the current signal) and starts `warmup` slots of suppression so one shift
cannot fire a burst of alarms while the policy re-converges.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

DETECTORS = ("cusum", "ewma", "none")
SIGNALS = ("loss", "confidence")

# `since_alarm` is initialized far in the past so schedules conditioned on it
# (core.policy.adapt_schedule) are exactly at their stationary values until a
# first alarm fires; counters saturate here instead of overflowing int32.
COUNTER_CAP = 1 << 30

# Blocks of growing-window (unclipped) scale estimation before the robust
# clipped EWMA takes over; keep warmup > (SCALE_BLOCKS + 2) · stride.
SCALE_BLOCKS = 8


@dataclasses.dataclass(frozen=True)
class ShiftConfig:
    """Detector + adaptation-schedule knobs (static under jit).

    The defaults are tuned on the calibrated Table 2/3 workloads for the
    quantized-confidence signal (policy-independent, so the policy's own
    learning transients cannot masquerade as drift): zero false alarms over
    stationary horizons of ≥ 20k slots on every manuscript spec, detection
    delay of a few hundred slots on the BreakHis→BreaCh shift (see
    tests/test_shift.py for both properties as executable claims).
    """

    detector: str = "cusum"  # "cusum" | "ewma" | "none"
    signal: str = "confidence"  # what the adaptive engine feeds the detector
    drift: float = 0.6  # CUSUM deadband δ, in sd units of a block mean
    threshold: float = 12.0  # alarm level λ (sd units; raw units for "ewma")
    stride: int = 50  # block length: slots per CUSUM accumulation
    # (threshold/drift trade ARL₀ against delay: e^{2·drift·threshold} blocks
    # between false alarms under an i.i.d.-normal null, ~threshold/(z-drift)
    # blocks of detection delay for a shift of z sd.)
    two_sided: bool = True  # False: only upward (cost-raising) shifts alarm
    mean_rate: float = 0.02  # reference-mean EWMA rate, per block
    fast_rate: float = 0.05  # fast-window EWMA rate, per slot ("ewma" only)
    var_rate: float = 0.05  # deviation-scale EWMA rate, per block
    sd_floor: float = 1e-3  # lower clamp on the tracked deviation scale
    warmup: int = 600  # slots after (re)start before alarms may fire
    # Adaptation schedule (consumed by core.policy.adapt_schedule): right
    # after a confirmed shift the learning rate is multiplied by `eta_boost`
    # and the weight decay pulled toward `recovery_decay`; both anneal back
    # to the HIConfig values with time constant `recovery` slots. Keep the
    # boost mild: the exploration pseudo-loss is φ/ε-scaled, so large η
    # multipliers amplify its variance enough to wreck freshly restarted
    # weights. `recovery_decay=None` leaves the decay untouched — with
    # restarts on there is nothing stale left to forget; set ≈ 0.99 as the
    # soft-adaptation mechanism when running `restart=False`.
    recovery: float = 150.0
    eta_boost: float = 1.5
    recovery_decay: Optional[float] = None

    def __post_init__(self):
        if self.detector not in DETECTORS:
            raise ValueError(
                f"unknown detector {self.detector!r}; expected one of {DETECTORS}"
            )
        if self.signal not in SIGNALS:
            raise ValueError(
                f"unknown signal {self.signal!r}; expected one of {SIGNALS}"
            )
        if self.drift < 0.0 or self.threshold <= 0.0 or self.sd_floor <= 0.0:
            raise ValueError(
                f"need drift ≥ 0, threshold > 0 and sd_floor > 0 "
                f"(got {self.drift}, {self.threshold}, {self.sd_floor})"
            )
        for name in ("mean_rate", "fast_rate", "var_rate"):
            rate = getattr(self, name)
            if not 0.0 < rate <= 1.0:
                raise ValueError(f"{name} must lie in (0, 1] (got {rate})")
        if self.warmup < 0 or self.stride < 1 or self.recovery <= 0.0:
            raise ValueError(
                f"need warmup ≥ 0, stride ≥ 1 and recovery > 0 "
                f"(got {self.warmup}, {self.stride}, {self.recovery})"
            )
        min_warmup = (SCALE_BLOCKS + 2) * self.stride
        if self.detector == "cusum" and self.warmup < min_warmup:
            raise ValueError(
                f"cusum needs warmup ≥ (SCALE_BLOCKS + 2) · stride = "
                f"{min_warmup} (got {self.warmup}): arming before the scale "
                f"estimate has warmed past sd_floor guarantees false alarms"
            )
        if self.eta_boost < 1.0 or (
            self.recovery_decay is not None
            and not 0.0 < self.recovery_decay <= 1.0
        ):
            raise ValueError(
                f"need eta_boost ≥ 1 and recovery_decay in (0, 1] or None "
                f"(got {self.eta_boost}, {self.recovery_decay})"
            )

    @property
    def enabled(self) -> bool:
        return self.detector != "none"


class ShiftState(NamedTuple):
    """Per-stream detector state; every leaf is batched over (S,)."""

    mean: jnp.ndarray  # (S,) float — reference mean (EWMA over block means)
    fast: jnp.ndarray  # (S,) float — fast EWMA (the smoothed recent level)
    var: jnp.ndarray  # (S,) float — robust EWMA of the squared block deviation
    acc: jnp.ndarray  # (S,) float — running sum of the current block
    g_inc: jnp.ndarray  # (S,) float — CUSUM statistic for an upward shift
    g_dec: jnp.ndarray  # (S,) float — CUSUM statistic for a downward shift
    n: jnp.ndarray  # (S,) int32 — slots since the detector (re)started
    since_alarm: jnp.ndarray  # (S,) int32 — slots since the last alarm
    n_alarms: jnp.ndarray  # (S,) int32 — alarms raised so far


def shift_init(n_streams: int, dtype=jnp.float32) -> ShiftState:
    """Fresh detector state for a fleet of `n_streams` streams."""
    fz = jnp.zeros((n_streams,), dtype)
    iz = jnp.zeros((n_streams,), jnp.int32)
    return ShiftState(
        mean=fz,
        fast=fz,
        var=fz,
        acc=fz,
        g_inc=fz,
        g_dec=fz,
        n=iz,
        since_alarm=jnp.full((n_streams,), COUNTER_CAP, jnp.int32),
        n_alarms=iz,
    )


def shift_update(
    cfg: ShiftConfig, state: ShiftState, x: jnp.ndarray
) -> Tuple[ShiftState, jnp.ndarray]:
    """One detector slot: fold signal `x` (S,) in, return (state, alarm (S,)).

    Alarms are edge-triggered: the slot the statistic crosses `threshold`
    raises, the detector restarts (statistics cleared, reference re-seeded
    from `x`), and `warmup` slots must pass before the next alarm can fire.
    With `detector="none"` the state is returned untouched and the alarm
    vector is all-False, so a disabled detector is exactly free.
    """
    if not cfg.enabled:
        return state, jnp.zeros(x.shape, bool)
    x = x.astype(state.mean.dtype)
    first = state.n == 0
    armed = state.n + 1 > cfg.warmup

    if cfg.detector == "cusum":
        fast = state.fast  # only the "ewma" statistic reads the fast EWMA
        # Block-mean accumulation: per-slot H2T2 signals are heavy-tailed
        # and autocorrelated, so the CUSUM folds in one normalized increment
        # per completed `stride`-slot block. Block means of disjoint blocks
        # are independent, so (drift, threshold) behave like a textbook
        # CUSUM, and dividing by the tracked block-deviation scale makes
        # them transfer across workloads whose signal scales differ.
        acc = jnp.where(first, x, state.acc + x)
        boundary = (state.n + 1) % cfg.stride == 0
        first_block = state.n + 1 == cfg.stride
        bm = acc / cfg.stride
        mean = jnp.where(
            boundary,
            jnp.where(first_block, bm,
                      state.mean + cfg.mean_rate * (bm - state.mean)),
            state.mean)
        acc = jnp.where(boundary, 0.0, acc)
        dev = bm - state.mean
        # Robust scale tracking: clip the squared deviation folded into the
        # variance EWMA at (3·sd)², so a genuine level shift cannot inflate
        # its own normalizer faster than the CUSUM accumulates it. While the
        # estimate is cold (first `SCALE_BLOCKS` blocks — inside warmup, so
        # alarms are suppressed anyway) use a growing-window mean of the
        # *unclipped* deviations instead: seeding through the clip would
        # start from sd_floor and take tens of blocks to reach the true
        # scale, leaving an inflated z at arming time.
        k = (state.n + 1) // cfg.stride  # completed blocks incl. this one
        sd_prev = jnp.maximum(
            jnp.sqrt(jnp.maximum(state.var, 0.0)), cfg.sd_floor)
        dev2 = dev * dev
        dev2_clipped = jnp.minimum(dev2, (3.0 * sd_prev) ** 2)
        cold = k <= SCALE_BLOCKS
        var = jnp.where(
            boundary & ~first_block,
            jnp.where(
                cold,
                state.var + (dev2 - state.var)
                / jnp.maximum(k - 1, 1).astype(state.var.dtype),
                state.var + cfg.var_rate * (dev2_clipped - state.var)),
            state.var)
        # Accumulate only once armed: everything a (re)converging policy or
        # a cold scale estimate would contribute during warmup is discarded
        # by construction rather than cleared after the fact.
        take = boundary & armed
        z = dev / sd_prev
        g_inc = jnp.where(
            take, jnp.maximum(0.0, state.g_inc + (z - cfg.drift)),
            state.g_inc)
        g_dec = jnp.where(
            take, jnp.maximum(0.0, state.g_dec + (-z - cfg.drift)),
            state.g_dec)
    else:  # "ewma": windowed mean-shift in raw signal units, per slot
        acc = state.acc  # only the "cusum" statistic accumulates blocks
        fast = jnp.where(
            first, x, state.fast + cfg.fast_rate * (x - state.fast))
        mean = jnp.where(
            first, x, state.mean + cfg.mean_rate * (x - state.mean))
        var = state.var
        g_inc = jnp.maximum(0.0, fast - mean)
        g_dec = jnp.maximum(0.0, mean - fast)
    stat = jnp.maximum(g_inc, g_dec) if cfg.two_sided else g_inc
    alarm = armed & (stat > cfg.threshold)

    cap = jnp.int32(COUNTER_CAP)
    bump = lambda c: jnp.minimum(c + 1, cap)
    new_state = ShiftState(
        mean=jnp.where(alarm, x, mean),
        fast=jnp.where(alarm, x, fast),
        var=jnp.where(alarm, 0.0, var),
        acc=jnp.where(alarm, 0.0, acc),
        g_inc=jnp.where(alarm, 0.0, g_inc),
        g_dec=jnp.where(alarm, 0.0, g_dec),
        n=jnp.where(alarm, 0, bump(state.n)),
        since_alarm=jnp.where(alarm, 0, bump(state.since_alarm)),
        n_alarms=state.n_alarms + alarm.astype(jnp.int32),
    )
    return new_state, alarm


def detect_shifts(
    cfg: ShiftConfig, xs: jnp.ndarray, state: Optional[ShiftState] = None
) -> Tuple[ShiftState, jnp.ndarray]:
    """Scan `shift_update` over a whole (S, T) signal matrix.

    Offline/diagnostic helper (the adaptive engine folds the detector into
    its per-slot feedback instead): returns the final state and the full
    (S, T) boolean alarm raster, e.g. for measuring detection delay.
    """
    if state is None:
        state = shift_init(xs.shape[0], xs.dtype)

    def body(st, x):
        st, alarm = shift_update(cfg, st, x)
        return st, alarm

    final, alarms = jax.lax.scan(body, state, xs.T)
    return final, jnp.swapaxes(alarms, 0, 1)
