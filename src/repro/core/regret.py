"""Regret utilities: Corollary 1 parameters, empirical regret, slope fits."""
from __future__ import annotations

import functools
import math
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import offline, policy
from repro.core.types import HIConfig


def corollary1_params(cfg: HIConfig, horizon: int) -> Tuple[float, float]:
    """(ε*, η*) minimizing the Theorem-2 bound: ε* = (ln|Θ|/2β²T)^{1/3},
    η* = sqrt(2 ε* ln|Θ| / T)."""
    n = cfg.n_experts
    beta = max(cfg.beta_max, 1e-6)
    eps = (math.log(n) / (2.0 * beta * beta * horizon)) ** (1.0 / 3.0)
    eps = min(max(eps, 1e-4), 1.0)
    eta = math.sqrt(2.0 * eps * math.log(n) / horizon)
    return eps, eta


def theorem2_bound(cfg: HIConfig, horizon: int) -> float:
    """R_T ≤ (εβ + η/2ε)·T + ln|Θ|/η."""
    return (
        (cfg.eps * cfg.beta_max + cfg.eta / (2.0 * cfg.eps)) * horizon
        + math.log(cfg.n_experts) / cfg.eta
    )


def empirical_regret(
    cfg: HIConfig,
    fs,
    hrs: Optional[jnp.ndarray] = None,
    betas: Optional[jnp.ndarray] = None,
    key: Optional[jax.Array] = None,
    n_seeds: int = 8,
    run: Optional[Callable] = None,
) -> Dict[str, float]:
    """Mean cumulative H2T2 loss over seeds minus the offline best fixed θ⃗.

    `fs` is either the (T,) confidence trace (with `hrs`/`betas`) or a
    1-stream `ScenarioSource` (duck-typed, keeps core ↛ data), which is
    materialized once — regret against the offline comparator is inherently
    a full-trace metric, and the comparator reads the same remote labels
    the policy's losses charge (`hrs`, not `ys`).

    `run` is a fleet runner `(fs, hrs, betas, key=None, *, stream_keys)` →
    `(state, StepOutput)` — pass a `PolicyEngine.run` bound method to choose
    an engine; defaults to the kernel-backed `run_fleet_fused`. The seed
    batch runs as one fleet (seed i → stream i with the same key
    `run_stream` would consume). Identical losses on every engine.
    """
    if hasattr(fs, "materialize"):                    # ScenarioSource
        if hrs is not None or betas is not None:
            raise TypeError(
                "empirical_regret(source, ...) takes no hrs/betas — the "
                "source generates them")
        if fs.n_streams != 1:
            raise ValueError(
                f"empirical_regret needs a 1-stream source (got "
                f"{fs.n_streams}); regret is a per-stream quantity")
        batch = fs.materialize()
        fs, hrs, betas = batch.fs[0], batch.hrs[0], batch.betas[0]
    if hrs is None or betas is None or key is None:
        raise TypeError("empirical_regret needs hrs/betas/key unless given "
                        "a ScenarioSource")
    if run is None:
        run = functools.partial(policy.run_fleet_fused, cfg)
    keys = jax.random.split(key, n_seeds)
    tile = lambda a: jnp.tile(a[None], (n_seeds, 1))
    _, outs = run(tile(fs), tile(hrs), tile(betas), stream_keys=keys)
    algo = float(jnp.mean(jnp.sum(outs.loss, axis=-1)))
    best = float(offline.best_two_threshold(cfg, fs, hrs, betas).best_loss)
    return {"algo_loss": algo, "best_fixed_loss": best, "regret": algo - best}


def regret_slope(
    horizons: Sequence[int], regrets: Sequence[float]
) -> float:
    """Fit log R_T = a + s·log T, return slope s (sublinear ⇔ s < 1; theory 2/3)."""
    h = np.asarray(horizons, dtype=np.float64)
    r = np.maximum(np.asarray(regrets, dtype=np.float64), 1e-9)
    s, _ = np.polyfit(np.log(h), np.log(r), 1)
    return float(s)
