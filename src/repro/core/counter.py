"""Counter-based (ψ, ζ) randomness: the golden jnp reference.

The H2T2 round consumes exactly two uniforms per stream per slot — ψ (the
threshold draw that picks offload / local-predict) and ζ (the bernoulli(ε)
exploration flag). The pre-draw path materializes them for the whole horizon
as (S, T) arrays; this module defines the *counter* contract that replaces
the tensors with a pure function of position:

    (ψ, ζ)[stream, slot] = mix(seed, stream_id, slot)

where `mix` is the canonical 20-round threefry2x32 block cipher applied to
the (stream_id, slot) counter under the policy key's two uint32 words. The
draw for a given (seed, stream, slot) is a *value*, not a *state* — so any
partition of the fleet into stream blocks, any time blocking, and any
sharding across devices reproduces bit-identical randomness, and nothing is
ever resident beyond the (SB, TB) worklocal draws of the current launch.

Two implementations exist on purpose:

  * this module — plain jnp, the golden reference (and the XLA fallback
    path used when the Pallas kernels are off);
  * `kernels/hedge/kernel.py` — an independent, fully unrolled copy
    evaluated inside the hedge kernels.

`tests/test_counter_rng.py` pins the two against each other bit-for-bit
(uint32 equality, interpret mode) and against the published Random123
known-answer vectors, so a jax/pallas upgrade that changes integer-op
semantics fails loudly instead of silently forking traces.

Counter mode is a deliberately *different* randomness contract from the
pre-draw key tree (`jax.random.split` / `fold_in` chains): the two modes
agree in distribution, not in bits. Pre-draw remains the default and the
golden path for all paper-parity goldens.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# threefry2x32 constants (Salmon et al., "Parallel random numbers: as easy
# as 1, 2, 3", SC'11): 20 rounds = 5 four-round groups with alternating
# rotation schedules, key injection after every group.
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = 0x1BD11BDA


def _as_u32(x) -> jnp.ndarray:
    """Coerce to uint32, wrapping — accepts full-range python ints too."""
    if isinstance(x, int):
        x = x & 0xFFFFFFFF
        return jnp.asarray(x, dtype=jnp.uint32)
    return jnp.asarray(x).astype(jnp.uint32)


def threefry2x32(k0, k1, x0, x1):
    """Canonical 20-round threefry2x32: counter (x0, x1) under key (k0, k1).

    All inputs broadcast against each other as uint32; returns two uint32
    arrays of the broadcast shape. Matches the Random123 known-answer
    vectors (and jax's internal `threefry_2x32`) bit-for-bit.
    """
    k0, k1, x0, x1 = (_as_u32(v) for v in (k0, k1, x0, x1))
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_PARITY))
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x0 = x0 + x1
            x1 = (x1 << r) | (x1 >> (32 - r))
            x1 = x0 ^ x1
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x0, x1


def uniform_from_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Map uint32 bits to float32 uniforms in [0, 1).

    Keeps the top 24 bits so the product is exact in a float32 mantissa —
    the same value is reproducible from the same bits on any backend.
    """
    return (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def seed_from_key(key) -> jnp.ndarray:
    """The (2,) uint32 seed words of a jax PRNG key.

    Accepts both raw `jax.random.PRNGKey` uint32 arrays and new-style typed
    keys; the words double as the threefry key so all counter-mode APIs keep
    taking the same `key` argument as the pre-draw path.
    """
    key = jnp.asarray(key)
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    if key.shape != (2,):
        raise ValueError(
            f"counter mode needs a 2-word (threefry) key, got shape "
            f"{key.shape}")
    return key.astype(jnp.uint32)


def counter_bits(seed: jnp.ndarray, stream_ids, slots):
    """Raw (b0, b1) uint32 draws for (stream, slot) counters under `seed`."""
    seed = jnp.asarray(seed).astype(jnp.uint32)
    return threefry2x32(seed[0], seed[1], stream_ids, slots)


def psi_zeta_from_counter(seed: jnp.ndarray, stream_ids, slots, eps: float):
    """The counter contract: (ψ, ζ) for every (stream_id, slot) pair.

    ψ is uniform on [0, 1) from the first output word; ζ is bernoulli(ε)
    via a float compare on the second (exact for the 24-bit uniforms).
    Returns (psi float32, zeta bool) of the broadcast shape.
    """
    b0, b1 = counter_bits(seed, stream_ids, slots)
    psi = uniform_from_bits(b0)
    zeta = uniform_from_bits(b1) < jnp.float32(eps)
    return psi, zeta


class CounterRNG(NamedTuple):
    """Position of a counter-mode draw: which seed, slot, and stream base.

    A jit-friendly pytree of arrays. `slot` is the time index of the draw
    (the serving slot / round number); `stream_offset` is the global id of
    stream row 0 — nonzero only inside sharded per-device bodies, where it
    restores the fleet-global stream ids that make draws identical to the
    unsharded run.
    """

    seed: jnp.ndarray           # (2,) uint32 — threefry key words
    slot: jnp.ndarray           # () int32
    stream_offset: jnp.ndarray  # () int32

    def at_slot(self, slot) -> "CounterRNG":
        return self._replace(slot=jnp.asarray(slot, jnp.int32))


def counter_rng(key_or_seed, slot, stream_offset=0) -> CounterRNG:
    """Build a `CounterRNG` from a PRNG key (or raw seed words) and a slot."""
    return CounterRNG(
        seed=seed_from_key(key_or_seed),
        slot=jnp.asarray(slot, jnp.int32),
        stream_offset=jnp.asarray(stream_offset, jnp.int32),
    )


RANDOMNESS_MODES = ("pre_draw", "counter")


def check_randomness_mode(randomness: str) -> str:
    if randomness not in RANDOMNESS_MODES:
        raise ValueError(
            f"randomness must be one of {RANDOMNESS_MODES}, "
            f"got {randomness!r}")
    return randomness
