"""One generic name→entry registry shared by engines, scenarios, learners.

Every pluggable family in the repo (policy engines, scenario sources,
hedge learners) used to carry its own copy of the same three functions:
a module-level dict, a ``register_*`` decorator, and a ``get_*`` lookup
with its own flavor of unknown-name error. This module is the single
implementation: construct a :class:`Registry` per family and re-export
thin wrappers so existing call sites keep their names.

Lookup failures raise ``ValueError`` with a uniform message that lists
the available entries, so ``get_engine("fuzed")`` and
``get_learner("fact")`` fail identically and self-document.
"""

from __future__ import annotations

from typing import Callable, Dict, Iterator, Optional, Tuple, TypeVar

T = TypeVar("T")


class Registry:
    """A named collection of pluggable entries with uniform errors.

    ``kind`` is the human-facing family name used in error messages
    ("policy engine", "scenario", "learner").
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: Dict[str, object] = {}

    def register(self, name: str) -> Callable[[T], T]:
        """Decorator: add ``entry`` under ``name`` (last write wins)."""

        def deco(entry: T) -> T:
            self._entries[name] = entry
            return entry

        return deco

    def add(self, name: str, entry: object) -> None:
        """Imperative form of :meth:`register`."""
        self._entries[name] = entry

    def lookup(self, name: str) -> object:
        """Return the entry for ``name`` or raise the uniform error."""
        try:
            return self._entries[name]
        except KeyError:
            raise ValueError(
                f"unknown {self.kind} {name!r}; available: "
                + ", ".join(self.names())
            ) from None

    def names(self) -> Tuple[str, ...]:
        """All registered names, sorted."""
        return tuple(sorted(self._entries))

    def describe(self) -> Tuple[Tuple[str, str], ...]:
        """(name, one-line description) pairs for ``--list`` style output.

        The description is the first line of the entry's docstring (or of
        an explicit ``description`` attribute when the entry carries one).
        """
        rows = []
        for name in self.names():
            entry = self._entries[name]
            doc = getattr(entry, "description", None)
            if not isinstance(doc, str):
                doc = getattr(entry, "__doc__", None) or ""
            rows.append((name, doc.strip().splitlines()[0] if doc.strip() else ""))
        return tuple(rows)

    def __contains__(self, name: object) -> bool:
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(self.names())

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, name: str, default: Optional[object] = None) -> object:
        return self._entries.get(name, default)
