"""Mixture-of-Experts: top-k softmax router + capacity-based dense dispatch.

GShard-style one-hot dispatch/combine einsums: active-expert FLOPs only
(E·C·ff work where E·C ≈ T·top_k·capacity_factor), expert weights shardable
over the mesh 'model' axis (expert-parallel when E % axis == 0, else
per-expert d_ff tensor-parallel). Aux load-balancing loss follows Switch.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init
from repro.utils import constrain


class MoEOutput(NamedTuple):
    y: jnp.ndarray
    aux_loss: jnp.ndarray        # load-balance loss (Switch-style)


def moe_init(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 6)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale = 1.0 / (d ** 0.5)
    p: Params = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(dtype),
        "up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale).astype(dtype),
        "down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) / (f ** 0.5)).astype(dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.d_ff * cfg.n_shared_experts
        p["shared_gate"] = dense_init(ks[4], d, fs, dtype)
        p["shared_up"] = dense_init(ks[4], d, fs, dtype)
        p["shared_down"] = dense_init(ks[5], fs, d, dtype)
    return p


def _router_probs(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    logits = (x.astype(jnp.float32) @ p["router"]["w"])
    return jax.nn.softmax(logits, axis=-1)


def _group_tokens(t: int, target: int = 2048) -> int:
    """Tokens per dispatch group: GShard-style LOCAL dispatch. The one-hot
    dispatch tensor is O(group · E · C) with C ∝ group/E, i.e. quadratic in
    group size — global dispatch at 1M tokens would be TBs."""
    g = min(t, target)
    while t % g:
        g //= 2
    return max(g, 1)


def moe_forward(
    p: Params, cfg: ModelConfig, x: jnp.ndarray, capacity: Optional[int] = None
) -> MoEOutput:
    """x: (B, S, D) → (B, S, D). Tokens over capacity are dropped (residual
    connection passes them through), as in GShard/Switch. Routing/dispatch is
    per token-group; groups shard over the data axis."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    gt = _group_tokens(t)
    ng = t // gt
    xt = x.reshape(ng, gt, d)
    xt = constrain(xt, "batch", None, None)
    probs = _router_probs(p, xt)                          # (G, T, E) fp32

    gate_vals, gate_idx = jax.lax.top_k(probs, k)         # (G, T, k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    if capacity is None:
        # Floor of 4 keeps tiny decode batches drop-free (an expert can absorb
        # the whole group); larger groups get the usual cf-scaled capacity.
        capacity = int(max(4, round(gt * k * cfg.moe_capacity_factor / e)))
        capacity = min(capacity, gt)

    # Position of each (token, slot) within its expert queue, per group.
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)           # (G, T, k, E)
    flat = onehot.reshape(ng, gt * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(ng, gt, k, e)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)                  # (G, T, k)
    keep = pos < capacity
    gate_vals = gate_vals * keep

    # Gather/scatter dispatch (vLLM/modern style) instead of GShard one-hot
    # einsums: the dense dispatch matmul costs g·t·e·c·d FLOPs ≈ 2.5·t_g per
    # token — MORE than the experts themselves at t_g = 2048 (measured
    # 4.4e16 FLOPs + a 10 GiB all-reduce on mixtral prefill_32k). Gathers are
    # group-local, so they never cross the data shards.
    from repro.utils.pjit import axis_size

    ep = e % max(axis_size("expert"), 1) == 0 and axis_size("expert") > 1
    e_ax = "expert" if ep else None
    f_ax = None if ep else "mlp"

    # slot_token[g, e, c] = group-local token index filling expert e's slot c
    # (sentinel gt → zero row). Destinations are unique by construction.
    g_i = jnp.arange(ng, dtype=jnp.int32)[:, None, None]
    slot_token = jnp.full((ng, e, capacity), gt, jnp.int32)
    pos_c = jnp.minimum(pos, capacity - 1)
    t_i = jnp.broadcast_to(jnp.arange(gt, dtype=jnp.int32)[None, :, None],
                           pos.shape)
    slot_token = slot_token.at[
        jnp.broadcast_to(g_i, pos.shape), gate_idx, pos_c
    ].set(jnp.where(keep, t_i, gt), mode="drop")

    xt_pad = jnp.concatenate([xt, jnp.zeros((ng, 1, d), xt.dtype)], axis=1)
    expert_in = jnp.take_along_axis(
        xt_pad, slot_token.reshape(ng, e * capacity)[..., None], axis=1,
    ).reshape(ng, e, capacity, d)
    expert_in = constrain(expert_in, "batch", e_ax, None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["gate"])) * jnp.einsum(
        "gecd,edf->gecf", expert_in, p["up"])
    h = constrain(h, "batch", e_ax, None, f_ax)
    # bf16 accumulation on the row-parallel down-proj keeps the cross-shard
    # partial-sum all-reduce in bf16 (fp32 accumulation doubles the payload
    # of the dominant collective — measured 10 GiB/step on mixtral prefill).
    expert_out = jnp.einsum("gecf,efd->gecd", h.astype(x.dtype), p["down"],
                            preferred_element_type=x.dtype)
    expert_out = constrain(expert_out, "batch", e_ax, None, None)

    # Combine: gather each token's k expert outputs back and gate-sum.
    flat_out = expert_out.reshape(ng, e * capacity, d)
    flat_out = jnp.concatenate([flat_out, jnp.zeros((ng, 1, d), flat_out.dtype)],
                               axis=1)
    slot_of = jnp.where(keep, gate_idx * capacity + pos_c, e * capacity)  # (G,T,k)
    picked = jnp.take_along_axis(
        flat_out, slot_of.reshape(ng, gt * k)[..., None], axis=1,
    ).reshape(ng, gt, k, d)
    y = jnp.sum(picked * gate_vals[..., None].astype(picked.dtype), axis=2)
    y = constrain(y, "batch", None, None).reshape(b, s, d)

    if cfg.n_shared_experts:
        from repro.models.layers import dense

        hs = jax.nn.silu(dense(p["shared_gate"], x)) * dense(p["shared_up"], x)
        y = y + dense(p["shared_down"], hs)

    # Switch aux loss: E · Σ_e fraction_tokens_e · mean_router_prob_e.
    frac = jnp.mean(jnp.sum(onehot, axis=2).astype(jnp.float32), axis=(0, 1))  # (E,)
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac / k * mean_prob)
    return MoEOutput(y=y.astype(x.dtype), aux_loss=aux)
