"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence (per channel):
    r_t = σ(W_r x_t),  i_t = σ(W_i x_t)
    a_t = exp(−c · softplus(Λ) · r_t)            (c = 8)
    h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)
Full sequences use an associative scan (log-depth on TPU); decode is the
plain one-step recurrence. The block wraps the LRU with the Griffin
conv1d(width 4) + GeGLU-style output gate.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense, dense_init
from repro.utils import constrain

_C = 8.0


class LRUCache(NamedTuple):
    h: jnp.ndarray         # (B, W) recurrent state
    conv: jnp.ndarray      # (B, width−1, W) conv tail
    index: jnp.ndarray


def rglru_init(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 7)
    d = cfg.d_model
    w = cfg.lru_width or d
    # Λ init so that a ∈ (0.9, 0.999) at r = 1 (Griffin appendix).
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))
    return {
        "in_x": dense_init(ks[1], d, w, dtype),
        "in_gate": dense_init(ks[2], d, w, dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, w), jnp.float32)
                   * (1.0 / math.sqrt(cfg.conv_width))).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_r": dense_init(ks[4], w, w, dtype),
        "w_i": dense_init(ks[5], w, w, dtype),
        "lambda": lam,
        "out": dense_init(ks[6], w, d, dtype),
    }


def _causal_conv(x, w, b):
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(width))
    return out + b[None, None, :]


def _gates(p: Params, x: jnp.ndarray):
    r = jax.nn.sigmoid(dense(p["w_r"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["w_i"], x).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r          # log a_t ≤ 0
    a2 = jnp.exp(2.0 * log_a)
    gated_x = x.astype(jnp.float32) * i * jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12))
    return log_a, gated_x


def lru_scan(log_a: jnp.ndarray, gx: jnp.ndarray, h0=None) -> jnp.ndarray:
    """Associative scan of h_t = a_t h_{t−1} + gx_t over axis 1 (seq)."""
    if h0 is not None:
        # Fold the carried-in state into the first step.
        first = gx[:, :1] + jnp.exp(log_a[:, :1]) * h0[:, None, :]
        gx = jnp.concatenate([first, gx[:, 1:]], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, b1 * jnp.exp(a2) + b2

    _, h = jax.lax.associative_scan(combine, (log_a, gx), axis=1)
    return h


def rglru_forward(
    p: Params, cfg: ModelConfig, xin: jnp.ndarray
) -> Tuple[jnp.ndarray, LRUCache]:
    """Full-sequence Griffin recurrent block."""
    x = dense(p["in_x"], xin)
    gate = jax.nn.gelu(dense(p["in_gate"], xin))
    conv_in = x
    x = _causal_conv(x, p["conv_w"], p["conv_b"])
    x = constrain(x, "batch", None, "mlp")
    log_a, gx = _gates(p, x)
    h = lru_scan(log_a, gx).astype(xin.dtype)
    out = dense(p["out"], h * gate)
    tail = conv_in[:, -(cfg.conv_width - 1):, :]
    return out, LRUCache(h=h[:, -1, :], conv=tail,
                         index=jnp.asarray(xin.shape[1], jnp.int32))


def make_lru_cache(cfg: ModelConfig, batch: int, dtype) -> LRUCache:
    w = cfg.lru_width or cfg.d_model
    return LRUCache(
        h=jnp.zeros((batch, w), dtype),
        conv=jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def rglru_decode(
    p: Params, cfg: ModelConfig, cache: LRUCache, xin: jnp.ndarray
) -> Tuple[jnp.ndarray, LRUCache]:
    x = dense(p["in_x"], xin)                          # (B,1,W)
    gate = jax.nn.gelu(dense(p["in_gate"], xin))
    window = jnp.concatenate([cache.conv, x], axis=1)  # (B,width,W)
    conv = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    log_a, gx = _gates(p, conv[:, None, :])
    h = jnp.exp(log_a[:, 0]) * cache.h.astype(jnp.float32) + gx[:, 0]
    h = h.astype(xin.dtype)
    out = dense(p["out"], h[:, None, :] * gate)
    return out, LRUCache(h=h, conv=window[:, 1:, :], index=cache.index + 1)
