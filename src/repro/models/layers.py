"""Primitive layers: norms, MLPs, embeddings, rotary. Params are plain dicts."""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None,
               bias: bool = False) -> Params:
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(f"unknown norm {kind!r}")
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def mlp_init(key, d_model: int, d_ff: int, act: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": dense_init(k1, d_model, d_ff, dtype),
        "down": dense_init(k2, d_ff, d_model, dtype),
    }
    if act == "silu":  # gated (SwiGLU)
        p["gate"] = dense_init(k3, d_model, d_ff, dtype)
    return p


def mlp(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    from repro.utils import constrain

    if act == "silu":
        h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    elif act == "gelu":
        h = jax.nn.gelu(dense(p["up"], x))
    else:
        raise ValueError(f"unknown act {act!r}")
    h = constrain(h, "batch", None, "mlp")
    return constrain(dense(p["down"], h), "batch", None, None)


def embedding_init(key, vocab: int, d_model: int, dtype) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                      * (1.0 / math.sqrt(d_model))).astype(dtype)}


def embed(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], tokens, axis=0)


def rotary_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables, (..., head_dim/2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., :, None, :].astype(x.dtype)
    s = sin[..., :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def learned_positions_init(key, max_len: int, d_model: int, dtype) -> Params:
    return {"pos": (jax.random.normal(key, (max_len, d_model), jnp.float32) * 0.02).astype(dtype)}
