"""Task heads for hierarchical inference: a binary (event-detection) head that
turns any backbone into an LDL/RDL classifier emitting the confidence f_t that
repro.core consumes."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense, dense_init


def binary_head_init(key, cfg: ModelConfig, hidden: int = 0) -> Params:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    if hidden:
        return {
            "h": dense_init(k1, d, hidden, cfg.dtype, bias=True),
            "out": dense_init(k2, hidden, 2, cfg.dtype, bias=True),
        }
    return {"out": dense_init(k2, d, 2, cfg.dtype, bias=True)}


def binary_head(p: Params, features: jnp.ndarray) -> jnp.ndarray:
    """features: (B, S, D) → logits (B, 2), pooled at the last position."""
    x = features[:, -1, :]
    if "h" in p:
        x = jax.nn.tanh(dense(p["h"], x))
    return dense(p["out"], x).astype(jnp.float32)


def confidence(logits: jnp.ndarray) -> jnp.ndarray:
    """f_t = softmax(logits)[class 1] — the LDL output the paper thresholds."""
    return jax.nn.softmax(logits, axis=-1)[..., 1]
