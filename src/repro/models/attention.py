"""Attention: GQA/MQA (rotary, optional bias/window) and MLA (DeepSeek-V2).

Caches are fixed-capacity ring buffers so `decode_32k` (capacity = seq_len) and
`long_500k` (capacity = sliding window ⇒ sub-quadratic) share one code path.
Keys are stored post-rotary at their global positions; ring-slot global
positions are reconstructed from the write index for masking.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import Params, apply_rotary, dense, dense_init, rotary_angles
from repro.utils import constrain


class AttnCache(NamedTuple):
    k: jnp.ndarray        # (B, C, Hkv, hd) — post-rotary keys
    v: jnp.ndarray        # (B, C, Hkv, hd)
    index: jnp.ndarray    # () int32 — number of positions written so far


class MLACache(NamedTuple):
    c_kv: jnp.ndarray     # (B, C, kv_lora) — compressed latent
    k_rope: jnp.ndarray   # (B, C, rope_dim) — shared rotary key
    index: jnp.ndarray


# ------------------------------- GQA ----------------------------------------


def gqa_init(key, cfg: ModelConfig, dtype) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "q": dense_init(kq, d, cfg.q_dim, dtype, bias=cfg.qkv_bias),
        "k": dense_init(kk, d, cfg.kv_dim, dtype, bias=cfg.qkv_bias),
        "v": dense_init(kv, d, cfg.kv_dim, dtype, bias=cfg.qkv_bias),
        "o": dense_init(ko, cfg.q_dim, d, dtype),
    }


def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1)


def _sdpa(
    q: jnp.ndarray,              # (B, Sq, H, hd)
    k: jnp.ndarray,              # (B, Sk, Hkv, hd)
    v: jnp.ndarray,              # (B, Sk, Hkv, hd)
    mask: Optional[jnp.ndarray],  # broadcastable to (B, H, Sq, Sk) or (Sq, Sk)
) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, g, hkv, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqgkd,bskd->bgkqs", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bgkqs,bskd->bqgkd", probs, v)
    return ctx.reshape(b, sq, h, hd)


def _sdpa_chunked(
    q: jnp.ndarray,              # (B, S, H, hd)
    k: jnp.ndarray,              # (B, S, Hkv, hd)
    v: jnp.ndarray,              # (B, S, Hkv, hd)
    causal: bool,
    window: Optional[int],
    chunk: int = 1024,
    unroll: bool = False,
) -> jnp.ndarray:
    """Online-softmax attention scanned over KV chunks — O(S·chunk) memory
    instead of O(S²). The XLA-level 'flash' used for long prefill; the Pallas
    kernel is the TPU-optimized variant of the same schedule."""
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    qg = q.reshape(b, s, g, hkv, hd)
    # Sequence-shard the query dim over 'model': head counts like 56 don't
    # divide a 16-way axis, but seq always does — this keeps the (s × chunk)
    # score blocks and fp32 accumulators distributed instead of replicated.
    qg = constrain(qg, "batch", "qseq", None, None, None)
    scale = 1.0 / math.sqrt(hd)
    kc = k.reshape(b, nc, chunk, hkv, hd)
    vc = v.reshape(b, nc, chunk, hkv, hd)
    qpos = jnp.arange(s)

    def _cst(m, l, acc):
        # Keep every carry leaf on the SAME (batch, qseq@model) layout as qg:
        # without this, XLA resolves the scan-carry sharding conflict between
        # the qseq-sharded scores and kv-head-sharded values by FULLY
        # REPLICATING the fp32 accumulator ("involuntary full
        # rematerialization", measured 512 GiB/step on mixtral prefill_32k).
        m = constrain(m, "batch", None, None, "qseq")
        l = constrain(l, "batch", None, None, "qseq")
        acc = constrain(acc, "batch", None, None, "qseq", None)
        return m, l, acc

    def body(carry, inp):
        m, l, acc = carry
        ci, kb, vb = inp
        scores = jnp.einsum("bqgkd,bskd->bgkqs", qg, kb).astype(jnp.float32) * scale
        scores = constrain(scores, "batch", None, None, "qseq", None)
        kpos = ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((s, chunk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bgkqs,bskd->bgkqd", p.astype(vb.dtype), vb).astype(jnp.float32)
        return _cst(m_new, l, acc), None

    init = _cst(
        jnp.full((b, g, hkv, s), -1e30, jnp.float32),
        jnp.zeros((b, g, hkv, s), jnp.float32),
        jnp.zeros((b, g, hkv, s, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        body, init, (jnp.arange(nc), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
        unroll=nc if unroll else 1)
    out = acc / jnp.maximum(l, 1e-38)[..., None]
    # (b, g, kv, s, d) → (b, s, g, kv, d) → (b, s, h, d)
    return jnp.moveaxis(out, 3, 1).reshape(b, s, h, hd).astype(q.dtype)


def _sdpa_window_blocked(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    window: int, chunk: int, unroll: bool = False,
) -> jnp.ndarray:
    """Sliding-window attention, q-chunk blocked: each query chunk attends a
    SLICED kv span of length L = window+chunk instead of the whole sequence —
    score traffic s·L vs s·s (5.3× less for mixtral's 4096 window at 32k), and
    no online-softmax carries (the full receptive field is in-block)."""
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    l_span = min(s, ((window + chunk + chunk - 1) // chunk) * chunk)
    nq = s // chunk
    qg = q.reshape(b, s, g, hkv, hd)
    qg = constrain(qg, "batch", "qseq", None, None, None)
    scale = 1.0 / math.sqrt(hd)

    def body(_, qc):
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qc * chunk, chunk, axis=1)
        start = jnp.clip(qc * chunk + chunk - l_span, 0, s - l_span)
        k_blk = jax.lax.dynamic_slice_in_dim(k, start, l_span, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, start, l_span, axis=1)
        scores = jnp.einsum("bqgkd,bskd->bgkqs", q_blk, k_blk
                            ).astype(jnp.float32) * scale
        qpos = qc * chunk + jnp.arange(chunk)
        kpos = start + jnp.arange(l_span)
        mask = (kpos[None, :] <= qpos[:, None]) & (
            kpos[None, :] > qpos[:, None] - window)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        ctx = jnp.einsum("bgkqs,bskd->bqgkd", probs, v_blk)
        return None, ctx

    _, blocks = jax.lax.scan(body, None, jnp.arange(nq),
                             unroll=nq if unroll else 1)
    # (nq, b, chunk, g, kv, hd) → (b, s, h, hd)
    out = jnp.moveaxis(blocks, 0, 1).reshape(b, s, g, hkv, hd)
    return out.reshape(b, s, h, hd).astype(q.dtype)


def causal_mask(sq: int, sk: int, window: Optional[int], offset: int = 0) -> jnp.ndarray:
    """(sq, sk) mask; query i attends key j iff j ≤ i+offset (and within window)."""
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def gqa_forward(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                 # (B, S, D)
    positions: jnp.ndarray,         # (S,)
    causal: bool = True,
    window: Optional[int] = None,
    kv_source: Optional[jnp.ndarray] = None,   # cross-attention memory (B, Sk, D)
    use_flash: bool = False,
    cache_capacity: Optional[int] = None,
    attn_impl: str = "naive",                  # naive | chunked
    chunk: int = 1024,
    unroll: bool = False,
) -> Tuple[jnp.ndarray, AttnCache]:
    """Full-sequence attention (train / prefill). Returns output and a cache
    holding the (post-rotary) K/V of this sequence."""
    q = _split_heads(dense(p["q"], x), cfg.n_heads)
    src = x if kv_source is None else kv_source
    k = _split_heads(dense(p["k"], src), cfg.n_kv_heads)
    v = _split_heads(dense(p["v"], src), cfg.n_kv_heads)
    if cfg.rope_theta > 0 and kv_source is None:
        cos, sin = rotary_angles(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    mask = None
    if causal and kv_source is None:
        mask = causal_mask(x.shape[1], src.shape[1], window)
    if use_flash and kv_source is None:
        from repro.kernels.flash_attention import ops as flash_ops

        ctx = flash_ops.flash_attention(
            q, k, v, causal=causal, window=window)
    elif attn_impl == "chunked" and kv_source is None:
        if causal and window is not None and window + chunk < x.shape[1]:
            ctx = _sdpa_window_blocked(q, k, v, window=window, chunk=chunk,
                                       unroll=unroll)
        else:
            ctx = _sdpa_chunked(q, k, v, causal=causal, window=window,
                                chunk=chunk, unroll=unroll)
    else:
        ctx = _sdpa(q, k, v, mask)
    ctx = constrain(ctx, "batch", None, "heads", None)
    out = constrain(
        dense(p["o"], ctx.reshape(x.shape[0], x.shape[1], -1)),
        "batch", None, None)
    s = src.shape[1]
    if cache_capacity is not None and cache_capacity > s:
        pad = ((0, 0), (0, cache_capacity - s), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    cache = AttnCache(k=k, v=v, index=jnp.asarray(s, jnp.int32))
    return out, cache


def make_cache(cfg: ModelConfig, batch: int, capacity: int, dtype) -> AttnCache:
    shape = (batch, capacity, cfg.n_kv_heads, cfg.head_dim)
    return AttnCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        index=jnp.zeros((), jnp.int32),
    )


def ring_positions(index: jnp.ndarray, capacity: int) -> jnp.ndarray:
    """Global position held by each ring slot after `index` writes; -1 if empty.

    Slot j holds the largest p < index with p ≡ j (mod capacity).
    """
    j = jnp.arange(capacity)
    last = index - 1
    p = last - ((last - j) % capacity)
    return jnp.where((index > 0) & (p >= 0), p, -1)


def gqa_decode(
    p: Params,
    cfg: ModelConfig,
    cache: AttnCache,
    x: jnp.ndarray,                 # (B, 1, D) — one new token
    window: Optional[int] = None,
) -> Tuple[jnp.ndarray, AttnCache]:
    b = x.shape[0]
    capacity = cache.k.shape[1]
    pos = cache.index                                  # scalar global position
    q = _split_heads(dense(p["q"], x), cfg.n_heads)
    k = _split_heads(dense(p["k"], x), cfg.n_kv_heads)
    v = _split_heads(dense(p["v"], x), cfg.n_kv_heads)
    if cfg.rope_theta > 0:
        cos, sin = rotary_angles(pos[None], cfg.head_dim, cfg.rope_theta)
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)
    # Decode keeps K/V head_dim-sharded over 'model' (the cache layout):
    # contracting the sharded head_dim yields a partial-sum all-reduce on the
    # tiny score tensor instead of all-gathering the whole cache
    # (measured 2 GiB/layer → 116 MB/layer on deepseek-coder decode_32k).
    q = constrain(q, "batch", None, None, "head_dim")
    k = constrain(k, "batch", None, None, "head_dim")
    v = constrain(v, "batch", None, None, "head_dim")
    slot = pos % capacity
    new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
    new_index = pos + 1
    kpos = ring_positions(new_index, capacity)         # (C,)
    valid = kpos >= 0
    if window is not None:
        valid &= kpos > pos - window
    mask = valid[None, None, None, None, :]            # (1,1,1,1,C) over (b,g,kv,q,s)
    ctx = _sdpa(q, new_k, new_v, mask)
    out = dense(p["o"], ctx.reshape(b, 1, -1))
    return out, AttnCache(k=new_k, v=new_v, index=new_index)


def gqa_cross_decode(
    p: Params, cfg: ModelConfig, mem_cache: AttnCache, x: jnp.ndarray
) -> jnp.ndarray:
    """Cross-attention for decode: attend the fixed encoder memory cache."""
    q = _split_heads(dense(p["q"], x), cfg.n_heads)
    ctx = _sdpa(q, mem_cache.k, mem_cache.v, None)
    return dense(p["o"], ctx.reshape(x.shape[0], 1, -1))


# ------------------------------- MLA ----------------------------------------


def mla_init(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 8)
    d, h = cfg.d_model, cfg.n_heads
    nope, rope, vd, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    p: Params = {
        "dkv": dense_init(ks[0], d, r, dtype),
        "kv_norm": layers.norm_init(r, "rmsnorm", dtype),
        "uk": dense_init(ks[1], r, h * nope, dtype),
        "uv": dense_init(ks[2], r, h * vd, dtype),
        "kr": dense_init(ks[3], d, rope, dtype),
        "o": dense_init(ks[4], h * vd, d, dtype),
    }
    if cfg.q_lora_rank:
        p["dq"] = dense_init(ks[5], d, cfg.q_lora_rank, dtype)
        p["q_norm"] = layers.norm_init(cfg.q_lora_rank, "rmsnorm", dtype)
        p["uq"] = dense_init(ks[6], cfg.q_lora_rank, h * (nope + rope), dtype)
    else:
        p["uq"] = dense_init(ks[6], d, h * (nope + rope), dtype)
    return p


def _mla_q(p: Params, cfg: ModelConfig, x, positions):
    b, s, _ = x.shape
    h, nope, rope = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        cq = layers.apply_norm(p["q_norm"], dense(p["dq"], x), "rmsnorm")
        q = dense(p["uq"], cq)
    else:
        q = dense(p["uq"], x)
    q = q.reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rotary_angles(positions, rope, cfg.rope_theta)
    q_rope = apply_rotary(q_rope, cos, sin)
    return q_nope, q_rope


def mla_forward(
    p: Params, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray,
    window: Optional[int] = None, cache_capacity: Optional[int] = None,
    attn_impl: str = "naive", chunk: int = 1024, unroll: bool = False,
) -> Tuple[jnp.ndarray, MLACache]:
    """Train/prefill MLA with a causal mask; caches (c_kv, k_rope)."""
    b, s, _ = x.shape
    h, nope, rope, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv = layers.apply_norm(p["kv_norm"], dense(p["dkv"], x), "rmsnorm")
    cos, sin = rotary_angles(positions, rope, cfg.rope_theta)
    k_rope = apply_rotary(dense(p["kr"], x)[:, :, None, :], cos, sin)[:, :, 0, :]
    scale = 1.0 / math.sqrt(nope + rope)
    if attn_impl == "chunked":
        ctx = _mla_chunked(p, cfg, q_nope, q_rope, c_kv, k_rope, window=window,
                           chunk=chunk, unroll=unroll, scale=scale)
    else:
        k_nope = dense(p["uk"], c_kv).reshape(b, s, h, nope)
        val = dense(p["uv"], c_kv).reshape(b, s, h, vd)
        scores = (
            jnp.einsum("bqhn,bshn->bhqs", q_nope, k_nope)
            + jnp.einsum("bqhr,bsr->bhqs", q_rope, k_rope)
        ).astype(jnp.float32) * scale
        mask = causal_mask(s, s, window)
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhqs,bshv->bqhv", probs, val)
    out = dense(p["o"], ctx.reshape(b, s, h * vd))
    ck, kr = c_kv, k_rope
    if cache_capacity is not None and cache_capacity > s:
        pad = ((0, 0), (0, cache_capacity - s), (0, 0))
        ck, kr = jnp.pad(ck, pad), jnp.pad(kr, pad)
    return out, MLACache(c_kv=ck, k_rope=kr, index=jnp.asarray(s, jnp.int32))


def _mla_chunked(
    p: Params, cfg: ModelConfig, q_nope, q_rope, c_kv, k_rope,
    window: Optional[int], chunk: int, unroll: bool, scale: float,
) -> jnp.ndarray:
    """Online-softmax MLA scanned over latent-cache chunks; per-head K/V are
    decompressed one chunk at a time (O(S·chunk) memory)."""
    b, s, h = q_nope.shape[0], q_nope.shape[1], cfg.n_heads
    nope, rope, vd, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    q_nope = constrain(q_nope, "batch", "qseq", None, None)
    q_rope = constrain(q_rope, "batch", "qseq", None, None)
    ckc = c_kv.reshape(b, nc, chunk, r)
    krc = k_rope.reshape(b, nc, chunk, rope)
    qpos = jnp.arange(s)

    def body(carry, inp):
        m, l, acc = carry
        ci, ck, kr = inp
        k_nope = dense(p["uk"], ck).reshape(b, chunk, h, nope)
        val = dense(p["uv"], ck).reshape(b, chunk, h, vd)
        scores = (
            jnp.einsum("bqhn,bshn->bhqs", q_nope, k_nope)
            + jnp.einsum("bqhr,bsr->bhqs", q_rope, kr)
        ).astype(jnp.float32) * scale
        kpos = ci * chunk + jnp.arange(chunk)
        mask = kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None], scores, -1e30)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        pr = jnp.exp(scores - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(pr, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqs,bshv->bhqv", pr.astype(val.dtype), val).astype(jnp.float32)
        return (m_new, l, acc), None

    init = (
        jnp.full((b, h, s), -1e30, jnp.float32),
        jnp.zeros((b, h, s), jnp.float32),
        jnp.zeros((b, h, s, vd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        body, init, (jnp.arange(nc), jnp.moveaxis(ckc, 1, 0), jnp.moveaxis(krc, 1, 0)),
        unroll=nc if unroll else 1)
    out = acc / jnp.maximum(l, 1e-38)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(c_kv.dtype)     # (b, s, h, vd)


def make_mla_cache(cfg: ModelConfig, batch: int, capacity: int, dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, capacity, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, capacity, cfg.qk_rope_dim), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def mla_decode(
    p: Params, cfg: ModelConfig, cache: MLACache, x: jnp.ndarray,
    window: Optional[int] = None,
) -> Tuple[jnp.ndarray, MLACache]:
    """Absorbed-matrix decode: attention runs directly on the compressed latent
    cache (scores via q·W_uk·c_kv), never materializing per-head K/V — the
    memory win MLA was designed for, adapted to a ring cache."""
    b = x.shape[0]
    h, nope, rope, vd, r = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                            cfg.v_head_dim, cfg.kv_lora_rank)
    capacity = cache.c_kv.shape[1]
    pos = cache.index
    q_nope, q_rope = _mla_q(p, cfg, x, pos[None])        # (B,1,H,·)
    c_new = layers.apply_norm(p["kv_norm"], dense(p["dkv"], x), "rmsnorm")
    cos, sin = rotary_angles(pos[None], rope, cfg.rope_theta)
    kr_new = apply_rotary(dense(p["kr"], x)[:, :, None, :], cos, sin)[:, :, 0, :]
    slot = pos % capacity
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_new, slot, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, kr_new, slot, axis=1)
    new_index = pos + 1
    # Absorb W_uk into q: q_abs (B,H,r) = q_nope · W_uk(r→h,nope)
    w_uk = p["uk"]["w"].reshape(r, h, nope)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk)
    scale = 1.0 / math.sqrt(nope + rope)
    scores = (
        jnp.einsum("bhr,bsr->bhs", q_abs, c_kv)
        + jnp.einsum("bhp,bsp->bhs", q_rope[:, 0], k_rope)
    ).astype(jnp.float32) * scale
    kpos = ring_positions(new_index, capacity)
    valid = kpos >= 0
    if window is not None:
        valid &= kpos > pos - window
    scores = jnp.where(valid[None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_c = jnp.einsum("bhs,bsr->bhr", probs, c_kv)      # context in latent space
    w_uv = p["uv"]["w"].reshape(r, h, vd)
    ctx = jnp.einsum("bhr,rhv->bhv", ctx_c, w_uv)
    out = dense(p["o"], ctx.reshape(b, 1, h * vd))
    return out, MLACache(c_kv=c_kv, k_rope=k_rope, index=new_index)
