"""Top-level model API: init / forward / prefill / decode for every family.

Inputs are a dict:
    tokens  (B, S)  int32           — always present (decoder tokens)
    patches (B, P, D) dtype         — vlm only (stub frontend embeddings)
    frames  (B, F, D) dtype         — encdec only (stub conv/mel frontend)
The VLM prefix occupies the first `n_patches` positions of the declared
seq_len, so `tokens` carries seq_len − n_patches text positions.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, transformer
from repro.models.layers import (
    Params,
    apply_norm,
    dense,
    dense_init,
    embed,
    embedding_init,
    learned_positions_init,
    norm_init,
)
from repro.models.transformer import RunFlags
from repro.utils import constrain

MAX_LEARNED_POS = 4096  # whisper-style learned positions table size


class DecodeState(NamedTuple):
    caches: Any                               # per-layer cache pytree
    memory: Optional[attention.AttnCache]     # encoder / cross-attn K/V (encdec)


def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 8)
    dtype = cfg.dtype
    p: Params = {
        "embed": embedding_init(ks[0], cfg.vocab_padded, cfg.d_model, dtype),
        "blocks": transformer.init_blocks(ks[1], cfg, dtype),
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_padded, dtype)
    if cfg.rope_theta == 0:
        p["pos"] = learned_positions_init(ks[3], MAX_LEARNED_POS, cfg.d_model, dtype)
    if cfg.family == "vlm":
        p["projector"] = dense_init(ks[4], cfg.d_model, cfg.d_model, dtype)
    if cfg.family == "encdec":
        p["enc_pos"] = learned_positions_init(ks[5], cfg.n_frames, cfg.d_model, dtype)
        import dataclasses

        enc_plain = dataclasses.replace(
            cfg, family="dense", n_layers=cfg.n_encoder_layers,
            n_dense_layers=0, pattern=("attn",))
        p["encoder"] = transformer.init_blocks(ks[6], enc_plain, dtype)
        p["enc_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["xkv"] = attention.gqa_init(ks[7], cfg, dtype)  # unused q/o kept for shape parity
    return p


def _logits(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = apply_norm(p["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = x @ p["embed"]["table"].T
    else:
        logits = dense(p["lm_head"], x)
    return constrain(logits, "batch", None, "vocab")


def _encode(p: Params, cfg: ModelConfig, frames: jnp.ndarray,
            flags: RunFlags, unroll: bool) -> attention.AttnCache:
    """Encoder stack over stub frame embeddings → cross-attention K/V memory."""
    import dataclasses

    enc_cfg = dataclasses.replace(
        cfg, family="dense", n_layers=cfg.n_encoder_layers,
        n_dense_layers=0, pattern=("attn",))
    x = frames + p["enc_pos"]["pos"][None, : frames.shape[1], :]
    positions = jnp.arange(frames.shape[1])
    # Bidirectional: reuse run_blocks_seq but disable causal masking by calling
    # blocks with a full window; encoder layers have no cross-attn params.
    x, _, _ = transformer.run_blocks_seq(
        p["encoder"], enc_cfg, x, positions,
        dataclasses.replace(flags, mode="encode"), memory=None, unroll=unroll)
    x = apply_norm(p["enc_norm"], x, cfg.norm)
    k = attention._split_heads(dense(p["xkv"]["k"], x), cfg.n_kv_heads)
    v = attention._split_heads(dense(p["xkv"]["v"], x), cfg.n_kv_heads)
    return attention.AttnCache(k=k, v=v, index=jnp.asarray(x.shape[1], jnp.int32))


def _embed_inputs(p: Params, cfg: ModelConfig, inputs: Dict[str, jnp.ndarray]):
    x = embed(p["embed"], inputs["tokens"])
    if cfg.family == "vlm" and "patches" in inputs:
        patches = dense(p["projector"], inputs["patches"].astype(cfg.dtype))
        x = jnp.concatenate([patches, x], axis=1)
    if cfg.rope_theta == 0 and "pos" in p:
        s = x.shape[1]
        x = x + p["pos"]["pos"][None, (jnp.arange(s) % MAX_LEARNED_POS), :]
    return x


def forward(
    p: Params, cfg: ModelConfig, inputs: Dict[str, jnp.ndarray],
    flags: RunFlags = RunFlags(), unroll: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward (training). Returns (logits, aux_loss)."""
    memory = None
    if cfg.family == "encdec":
        memory = _encode(p, cfg, inputs["frames"].astype(cfg.dtype), flags, unroll)
    x = _embed_inputs(p, cfg, inputs)
    x = constrain(x, "batch", None, None)
    positions = jnp.arange(x.shape[1])
    x, _, aux = transformer.run_blocks_seq(
        p["blocks"], cfg, x, positions, flags, memory=memory, unroll=unroll)
    return _logits(p, cfg, x), aux


def prefill(
    p: Params, cfg: ModelConfig, inputs: Dict[str, jnp.ndarray],
    flags: RunFlags = RunFlags(), unroll: bool = False,
    capacity: Optional[int] = None,
) -> Tuple[jnp.ndarray, DecodeState]:
    """Process a prompt, returning last-position logits and the decode state.

    `capacity` pads attention caches beyond the prompt length so subsequent
    decode steps append instead of wrapping the ring.
    """
    import dataclasses as _dc

    if capacity is not None:
        flags = _dc.replace(flags, cache_capacity=capacity)
    memory = None
    if cfg.family == "encdec":
        memory = _encode(p, cfg, inputs["frames"].astype(cfg.dtype), flags, unroll)
    x = _embed_inputs(p, cfg, inputs)
    positions = jnp.arange(x.shape[1])
    x, caches, _ = transformer.run_blocks_seq(
        p["blocks"], cfg, x, positions, flags, memory=memory, unroll=unroll,
        collect_caches=True)
    return _logits(p, cfg, x[:, -1:, :]), DecodeState(caches=caches, memory=memory)


def init_decode_state(
    cfg: ModelConfig, batch: int, capacity: int,
    memory_len: Optional[int] = None,
) -> DecodeState:
    caches = transformer.init_block_caches(cfg, batch, capacity, cfg.dtype)
    memory = None
    if cfg.family == "encdec":
        mlen = memory_len or cfg.n_frames
        memory = attention.AttnCache(
            k=jnp.zeros((batch, mlen, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
            v=jnp.zeros((batch, mlen, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
            index=jnp.asarray(mlen, jnp.int32),
        )
    return DecodeState(caches=caches, memory=memory)


def decode_step(
    p: Params, cfg: ModelConfig, state: DecodeState, token: jnp.ndarray,
    flags: RunFlags = RunFlags(mode="decode"), unroll: bool = False,
) -> Tuple[jnp.ndarray, DecodeState]:
    """One decode step: token (B, 1) int32 → (logits (B, 1, V), new state)."""
    x = embed(p["embed"], token)
    if cfg.rope_theta == 0 and "pos" in p:
        # Use the cache index of the first attention layer as the position.
        pos = _first_cache_index(state.caches)
        x = x + p["pos"]["pos"][None, (pos % MAX_LEARNED_POS)[None], :]
    x = constrain(x, "batch", None, None)
    x, new_caches = transformer.run_blocks_decode(
        p["blocks"], cfg, state.caches, x, flags, memory=state.memory,
        unroll=unroll)
    return _logits(p, cfg, x), DecodeState(caches=new_caches, memory=state.memory)


def _first_cache_index(caches) -> jnp.ndarray:
    for seg in ("lead", "body", "tail"):
        for layer in caches[seg] if isinstance(caches[seg], list) else [caches[seg]]:
            if not layer:
                continue
            for v in layer.values():
                idx = v.index
                return idx[0] if idx.ndim else idx
    return jnp.zeros((), jnp.int32)


# --------------------------- parameter counting -------------------------------


def param_count(p: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(p))


def total_param_count(cfg: ModelConfig) -> int:
    """Total STORED params (all experts), from the abstract param tree."""
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))


import numpy as np  # noqa: E402  (used by total_param_count)


def active_param_count(cfg: ModelConfig) -> int:
    """Approximate active params per token (MoE: top_k + shared experts only)."""
    total = 0
    d = cfg.d_model
    for kind in cfg.layer_kinds:
        if kind in ("attn", "moe"):
            if cfg.use_mla:
                r = cfg.kv_lora_rank
                total += d * r + r * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
                total += d * cfg.qk_rope_dim
                if cfg.q_lora_rank:
                    total += d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * (
                        cfg.qk_nope_dim + cfg.qk_rope_dim)
                else:
                    total += d * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                total += cfg.n_heads * cfg.v_head_dim * d
            else:
                total += d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
        if kind == "moe":
            mult = 3 if cfg.act == "silu" else 2
            total += cfg.top_k * mult * d * cfg.d_ff
            total += cfg.n_shared_experts * mult * d * cfg.d_ff
        elif kind == "attn":
            mult = 3 if cfg.act == "silu" else 2
            total += mult * d * cfg.d_ff
        elif kind == "ssm":
            inner = cfg.ssm_inner
            total += d * (2 * inner + 2 * cfg.ssm_groups * cfg.ssm_state
                          + cfg.ssm_heads) + inner * d
        elif kind == "rec":
            w = cfg.lru_width or d
            total += 2 * d * w + 2 * w * w + w * d
            mult = 3 if cfg.act == "silu" else 2
            total += mult * d * cfg.d_ff
    total += cfg.vocab_padded * d * (1 if cfg.tie_embeddings else 2)
    return total
