"""Generic backbone: pattern-repeated blocks with scan-over-groups.

A config's depth is laid out as
    [lead: n_dense_layers explicit layers] +
    [body: (n // |pattern|) groups of the repeated pattern, ONE lax.scan] +
    [tail: n % |pattern| explicit layers]
so heterogeneous stacks (recurrentgemma's rec/rec/attn, deepseek-v2's leading
dense layer) compile to a single compact HLO loop. `unroll=True` replays the
scan body per group — used by the roofline dry-run because XLA's
cost_analysis does not multiply FLOPs through `while` loops.

Block kinds:
  attn — [norm → GQA/MLA → +res] [norm → MLP → +res]      (dense/vlm/encdec)
  moe  — [norm → GQA/MLA → +res] [norm → MoE → +res]
  ssm  — [norm → Mamba2 → +res]
  rec  — [norm → RG-LRU → +res] [norm → MLP → +res]
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, moe as moe_lib, rglru, ssm as ssm_lib
from repro.models.layers import (
    Params,
    apply_norm,
    dense,
    mlp,
    mlp_init,
    norm_init,
)


# --------------------------- depth plan --------------------------------------


class DepthPlan(NamedTuple):
    lead: Tuple[str, ...]          # explicit leading layer kinds
    pattern: Tuple[str, ...]       # repeated unit
    n_groups: int                  # scanned repetitions of the unit
    tail: Tuple[str, ...]          # explicit trailing layer kinds


def depth_plan(cfg: ModelConfig) -> DepthPlan:
    kinds = list(cfg.layer_kinds)
    lead = tuple(kinds[: cfg.n_dense_layers])
    body = kinds[cfg.n_dense_layers:]
    unit = cfg.pattern
    n_groups = len(body) // len(unit)
    tail = tuple(body[n_groups * len(unit):])
    return DepthPlan(lead=lead, pattern=unit, n_groups=n_groups, tail=tail)


# --------------------------- layer init --------------------------------------


def _layer_init(key, cfg: ModelConfig, kind: str, dense_mlp: bool, dtype) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": norm_init(cfg.d_model, cfg.norm, dtype)}
    if kind in ("attn", "moe"):
        if cfg.use_mla:
            p["attn"] = attention.mla_init(ks[0], cfg, dtype)
        else:
            p["attn"] = attention.gqa_init(ks[0], cfg, dtype)
        p["norm2"] = norm_init(cfg.d_model, cfg.norm, dtype)
        if kind == "moe" and not dense_mlp:
            p["moe"] = moe_lib.moe_init(ks[1], cfg, dtype)
        else:
            ff = cfg.dense_ff if (dense_mlp and cfg.dense_ff) else cfg.d_ff
            p["mlp"] = mlp_init(ks[1], cfg.d_model, ff, cfg.act, dtype)
        if cfg.family in ("encdec",):
            p["norm_x"] = norm_init(cfg.d_model, cfg.norm, dtype)
            p["xattn"] = attention.gqa_init(ks[2], cfg, dtype)
    elif kind == "ssm":
        p["ssm"] = ssm_lib.ssm_init(ks[0], cfg, dtype)
    elif kind == "rec":
        p["rec"] = rglru_init_wrap(ks[0], cfg, dtype)
        p["norm2"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    return p


def rglru_init_wrap(key, cfg, dtype):
    return rglru.rglru_init(key, cfg, dtype)


# --------------------------- caches ------------------------------------------


def _layer_cache(cfg: ModelConfig, kind: str, batch: int, capacity: int, dtype):
    if kind in ("attn", "moe"):
        if cfg.use_mla:
            c = {"attn": attention.make_mla_cache(cfg, batch, capacity, dtype)}
        else:
            cap = capacity
            if kind == "attn" and cfg.sliding_window:
                cap = min(capacity, cfg.sliding_window)
            c = {"attn": attention.make_cache(cfg, batch, cap, dtype)}
        return c
    if kind == "ssm":
        return {"ssm": ssm_lib.make_ssm_cache(cfg, batch, dtype)}
    if kind == "rec":
        return {"rec": rglru.make_lru_cache(cfg, batch, dtype)}
    raise ValueError(kind)


# --------------------------- block application --------------------------------


@dataclasses.dataclass(frozen=True)
class RunFlags:
    mode: str = "train"            # train | prefill | decode | encode
    window: Optional[int] = None   # runtime attention-window override (long_500k)
    cache_capacity: Optional[int] = None  # pad prefill caches for later decode
    attn_impl: str = "naive"       # naive | chunked (XLA online-softmax)
    attn_chunk: int = 1024
    unroll_chunks: bool = False    # unroll kv-chunk scans (roofline accuracy)
    use_flash: bool = False
    use_ssd_kernel: bool = False
    ssd_chunk: int = 128
    remat: bool = False            # checkpoint each scanned group (train memory)


def _attn_window(cfg: ModelConfig, flags: RunFlags) -> Optional[int]:
    if flags.window is not None:
        return (min(cfg.sliding_window, flags.window)
                if cfg.sliding_window else flags.window)
    return cfg.sliding_window


def _apply_block_seq(
    p: Params, cfg: ModelConfig, kind: str, x: jnp.ndarray, positions: jnp.ndarray,
    flags: RunFlags, memory: Optional[attention.AttnCache] = None,
):
    """Full-sequence application. Returns (x, cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    cache: Dict[str, Any] = {}
    if kind in ("attn", "moe"):
        h = apply_norm(p["norm1"], x, cfg.norm)
        w = _attn_window(cfg, flags)
        causal = flags.mode != "encode"   # whisper encoder is bidirectional
        cap = flags.cache_capacity
        if cap is not None and cfg.sliding_window and not cfg.use_mla:
            cap = min(cap, max(cfg.sliding_window, h.shape[1]))
        if cfg.use_mla:
            a, c = attention.mla_forward(
                p["attn"], cfg, h, positions, window=w, cache_capacity=cap,
                attn_impl=flags.attn_impl, chunk=flags.attn_chunk,
                unroll=flags.unroll_chunks)
        else:
            a, c = attention.gqa_forward(
                p["attn"], cfg, h, positions, causal=causal, window=w,
                use_flash=flags.use_flash, cache_capacity=cap,
                attn_impl=flags.attn_impl, chunk=flags.attn_chunk,
                unroll=flags.unroll_chunks)
        x = x + a
        cache["attn"] = c
        if "xattn" in p and memory is not None:
            h = apply_norm(p["norm_x"], x, cfg.norm)
            a, _ = _cross_full(p["xattn"], cfg, h, memory)
            x = x + a
        h = apply_norm(p["norm2"], x, cfg.norm)
        if "moe" in p:
            out = moe_lib.moe_forward(p["moe"], cfg, h)
            x = x + out.y
            aux = aux + out.aux_loss
        else:
            x = x + mlp(p["mlp"], h, cfg.act)
    elif kind == "ssm":
        h = apply_norm(p["norm1"], x, cfg.norm)
        y, c = ssm_lib.ssm_forward(p["ssm"], cfg, h, chunk=flags.ssd_chunk,
                                   use_kernel=flags.use_ssd_kernel)
        x = x + y
        cache["ssm"] = c
    elif kind == "rec":
        h = apply_norm(p["norm1"], x, cfg.norm)
        y, c = rglru.rglru_forward(p["rec"], cfg, h)
        x = x + y
        cache["rec"] = c
        h = apply_norm(p["norm2"], x, cfg.norm)
        x = x + mlp(p["mlp"], h, cfg.act)
    else:
        raise ValueError(kind)
    return x, cache, aux


def _cross_full(p: Params, cfg: ModelConfig, h: jnp.ndarray,
                memory: attention.AttnCache):
    """Full-sequence cross-attention against precomputed encoder K/V."""
    q = attention._split_heads(dense(p["q"], h), cfg.n_heads)
    ctx = attention._sdpa(q, memory.k, memory.v, None)
    return dense(p["o"], ctx.reshape(h.shape[0], h.shape[1], -1)), None


def _apply_block_decode(
    p: Params, cfg: ModelConfig, kind: str, cache: Dict[str, Any], x: jnp.ndarray,
    flags: RunFlags, memory: Optional[attention.AttnCache] = None,
):
    """Single-token application with cache update."""
    new_cache: Dict[str, Any] = {}
    if kind in ("attn", "moe"):
        h = apply_norm(p["norm1"], x, cfg.norm)
        w = _attn_window(cfg, flags)
        if cfg.use_mla:
            a, c = attention.mla_decode(p["attn"], cfg, cache["attn"], h, window=w)
        else:
            a, c = attention.gqa_decode(p["attn"], cfg, cache["attn"], h, window=w)
        x = x + a
        new_cache["attn"] = c
        if "xattn" in p and memory is not None:
            h = apply_norm(p["norm_x"], x, cfg.norm)
            x = x + attention.gqa_cross_decode(p["xattn"], cfg, memory, h)
        h = apply_norm(p["norm2"], x, cfg.norm)
        if "moe" in p:
            out = moe_lib.moe_forward(p["moe"], cfg, h)
            x = x + out.y
        else:
            x = x + mlp(p["mlp"], h, cfg.act)
    elif kind == "ssm":
        h = apply_norm(p["norm1"], x, cfg.norm)
        y, c = ssm_lib.ssm_decode(p["ssm"], cfg, cache["ssm"], h)
        x = x + y
        new_cache["ssm"] = c
    elif kind == "rec":
        h = apply_norm(p["norm1"], x, cfg.norm)
        y, c = rglru.rglru_decode(p["rec"], cfg, cache["rec"], h)
        x = x + y
        new_cache["rec"] = c
        h = apply_norm(p["norm2"], x, cfg.norm)
        x = x + mlp(p["mlp"], h, cfg.act)
    else:
        raise ValueError(kind)
    return x, new_cache


# --------------------------- stacked init / run ------------------------------


def _stack_params(per_layer: List[Params]) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)


def init_blocks(key, cfg: ModelConfig, dtype) -> Params:
    plan = depth_plan(cfg)
    keys = iter(jax.random.split(key, cfg.n_layers + 4))
    lead = [_layer_init(next(keys), cfg, k, dense_mlp=True, dtype=dtype)
            for k in plan.lead]
    body: List[Params] = []
    for pos, kind in enumerate(plan.pattern):
        groups = [_layer_init(next(keys), cfg, kind, dense_mlp=False, dtype=dtype)
                  for _ in range(plan.n_groups)]
        body.append(_stack_params(groups) if groups else {})
    tail = [_layer_init(next(keys), cfg, k, dense_mlp=False, dtype=dtype)
            for k in plan.tail]
    return {"lead": lead, "body": body, "tail": tail}


def init_block_caches(cfg: ModelConfig, batch: int, capacity: int, dtype):
    plan = depth_plan(cfg)
    lead = [_layer_cache(cfg, k, batch, capacity, dtype) for k in plan.lead]
    body = []
    for kind in plan.pattern:
        per = [_layer_cache(cfg, kind, batch, capacity, dtype)
               for _ in range(plan.n_groups)]
        body.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per) if per else {})
    tail = [_layer_cache(cfg, k, batch, capacity, dtype) for k in plan.tail]
    return {"lead": lead, "body": body, "tail": tail}


def run_blocks_seq(
    blocks: Params, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray,
    flags: RunFlags, memory=None, unroll: bool = False, collect_caches: bool = False,
):
    """Apply the full depth to a sequence. Returns (x, caches, aux_loss)."""
    plan = depth_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    caches = {"lead": [], "body": [], "tail": []}

    for p, kind in zip(blocks["lead"], plan.lead):
        x, c, aux = _apply_block_seq(p, cfg, kind, x, positions, flags, memory)
        aux_total += aux
        caches["lead"].append(c)

    if plan.n_groups:
        def group_body(carry, group_params):
            xc, aux_c = carry
            cs = []
            for pos, kind in enumerate(plan.pattern):
                xc, c, aux = _apply_block_seq(
                    group_params[pos], cfg, kind, xc, positions, flags, memory)
                aux_c = aux_c + aux
                cs.append(c)
            return (xc, aux_c), tuple(cs)

        if flags.remat:
            group_body = jax.checkpoint(group_body)

        (x, aux_total), stacked = jax.lax.scan(
            group_body, (x, aux_total), tuple(blocks["body"]),
            unroll=plan.n_groups if unroll else 1)
        caches["body"] = list(stacked)

    for p, kind in zip(blocks["tail"], plan.tail):
        x, c, aux = _apply_block_seq(p, cfg, kind, x, positions, flags, memory)
        aux_total += aux
        caches["tail"].append(c)

    return x, (caches if collect_caches else None), aux_total


def run_blocks_decode(
    blocks: Params, cfg: ModelConfig, caches, x: jnp.ndarray, flags: RunFlags,
    memory=None, unroll: bool = False,
):
    plan = depth_plan(cfg)
    new_caches = {"lead": [], "body": [], "tail": []}

    for p, c, kind in zip(blocks["lead"], caches["lead"], plan.lead):
        x, nc = _apply_block_decode(p, cfg, kind, c, x, flags, memory)
        new_caches["lead"].append(nc)

    if plan.n_groups:
        def group_body(xc, scanned):
            group_params, group_caches = scanned
            ncs = []
            for pos, kind in enumerate(plan.pattern):
                xc, nc = _apply_block_decode(
                    group_params[pos], cfg, kind, group_caches[pos], xc, flags, memory)
                ncs.append(nc)
            return xc, tuple(ncs)

        x, stacked = jax.lax.scan(
            group_body, x, (tuple(blocks["body"]), tuple(caches["body"])),
            unroll=plan.n_groups if unroll else 1)
        new_caches["body"] = list(stacked)

    for p, c, kind in zip(blocks["tail"], caches["tail"], plan.tail):
        x, nc = _apply_block_decode(p, cfg, kind, c, x, flags, memory)
        new_caches["tail"].append(nc)

    return x, new_caches
