from repro.models.model import (
    DecodeState,
    active_param_count,
    decode_step,
    forward,
    init_decode_state,
    init_params,
    param_count,
    prefill,
)
from repro.models.transformer import RunFlags, depth_plan

__all__ = [
    "DecodeState", "RunFlags", "active_param_count", "decode_step", "depth_plan",
    "forward", "init_decode_state", "init_params", "param_count", "prefill",
]
