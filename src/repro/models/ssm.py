"""Mamba2 block — SSD (state-space duality) chunked scan + recurrent decode.

Forward follows the "minimal SSD" algorithm of arXiv:2405.21060 §6: the
sequence is split into chunks; within-chunk interactions use the quadratic
(attention-like, MXU-friendly) form, across-chunk state is carried by an
exact associative recurrence. Decode maintains the (H, P, N) state directly.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense, dense_init
from repro.utils import constrain


class SSMCache(NamedTuple):
    state: jnp.ndarray      # (B, H, P, N) — SSM state
    conv: jnp.ndarray       # (B, W-1, conv_dim) — temporal-conv tail
    index: jnp.ndarray


def ssm_init(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    inner = cfg.ssm_inner
    h = cfg.ssm_heads
    n = cfg.ssm_state
    g = cfg.ssm_groups
    conv_dim = inner + 2 * g * n
    # in_proj emits [x (inner), z (inner), B (g·n), C (g·n), dt (h)].
    return {
        "in_proj": dense_init(ks[0], d, 2 * inner + 2 * g * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_dim), jnp.float32)
                   * (1.0 / math.sqrt(cfg.conv_width))).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((inner,), dtype),
        "out_proj": dense_init(ks[2], inner, d, dtype),
    }


def _split_proj(cfg: ModelConfig, proj: jnp.ndarray):
    inner, g, n, h = cfg.ssm_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    x = proj[..., :inner]
    z = proj[..., inner:2 * inner]
    b = proj[..., 2 * inner:2 * inner + g * n]
    c = proj[..., 2 * inner + g * n:2 * inner + 2 * g * n]
    dt = proj[..., 2 * inner + 2 * g * n:]
    return x, z, b, c, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along seq: x (B,S,C), w (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return out + b[None, None, :]


def _gated_rmsnorm(x: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    xf = (x * jax.nn.silu(z)).astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_scan(
    x: jnp.ndarray,       # (B, S, H, P)
    dt: jnp.ndarray,      # (B, S, H) — post-softplus
    a: jnp.ndarray,       # (H,) — positive decay rates (state uses exp(-dt·a))
    b: jnp.ndarray,       # (B, S, G, N)
    c: jnp.ndarray,       # (B, S, G, N)
    chunk: int = 128,
    initial_state: Optional[jnp.ndarray] = None,
    use_kernel: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    if use_kernel:
        from repro.kernels.ssd import ops as ssd_ops

        return ssd_ops.ssd(x, dt, a, b, c, chunk=chunk, initial_state=initial_state)
    return ssd_reference(x, dt, a, b, c, chunk=chunk, initial_state=initial_state)


def ssd_reference(x, dt, a, b, c, chunk=128, initial_state=None):
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk
    rep = h // g

    # log decay per step: Δlog = -dt·a  (a > 0)
    dlog = -dt * a[None, None, :]                       # (B,S,H)
    xr = x.reshape(bs, nc, chunk, h, p)
    dtr = dt.reshape(bs, nc, chunk, h)
    dlogr = dlog.reshape(bs, nc, chunk, h)
    br = jnp.repeat(b.reshape(bs, nc, chunk, g, n), rep, axis=3)   # (B,NC,Q,H,N)
    cr = jnp.repeat(c.reshape(bs, nc, chunk, g, n), rep, axis=3)

    cum = jnp.cumsum(dlogr, axis=2)                     # (B,NC,Q,H)
    # Within-chunk "attention" matrix L[i,j] = exp(cum_i − cum_j)·(i ≥ j)
    li = cum[:, :, :, None, :]                          # query i
    lj = cum[:, :, None, :, :]                          # key j
    seg = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(causal[None, None, :, :, None], seg, 0.0)

    scores = jnp.einsum("bzqhn,bzkhn->bzqkh", cr, br) * seg
    y_diag = jnp.einsum("bzqkh,bzkh,bzkhp->bzqhp", scores, dtr, xr)

    # Chunk-final states: S_z = Σ_j exp(cum_Q − cum_j)·dt_j·B_j⊗x_j
    decay_to_end = jnp.exp(jnp.clip(cum[:, :, -1:, :] - cum, -60.0, 0.0))  # (B,NC,Q,H)
    chunk_states = jnp.einsum(
        "bzkh,bzkh,bzkhn,bzkhp->bzhpn", decay_to_end, dtr, br, xr)
    chunk_decay = jnp.exp(jnp.clip(cum[:, :, -1, :], -60.0, 0.0))           # (B,NC,H)

    # Inter-chunk recurrence (sequential over NC chunks).
    def body(carry, inp):
        st = carry                                      # (B,H,P,N)
        s_z, d_z = inp                                  # (B,H,P,N), (B,H)
        new = st * d_z[:, :, None, None] + s_z.astype(jnp.float32)
        return new, st                                  # emit state ENTERING chunk

    init = (jnp.zeros((bs, h, p, n), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))
    final, entering = jax.lax.scan(
        body,
        init,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    entering = jnp.moveaxis(entering, 0, 1)             # (B,NC,H,P,N)

    # Contribution of the entering state to each position.
    decay_in = jnp.exp(jnp.clip(cum, -60.0, 0.0))       # exp(cum_i)
    y_off = jnp.einsum("bzqhn,bzqh,bzhpn->bzqhp", cr, decay_in, entering)
    y = (y_diag + y_off).reshape(bs, s, h, p).astype(x.dtype)
    return y, final.astype(x.dtype)


def ssm_forward(
    p: Params, cfg: ModelConfig, xin: jnp.ndarray, chunk: int = 128,
    use_kernel: bool = False,
) -> Tuple[jnp.ndarray, SSMCache]:
    """Full-sequence Mamba2 block: in_proj → conv → SSD → gated norm → out."""
    bsz, s, _ = xin.shape
    inner, g, n, h, pd = (cfg.ssm_inner, cfg.ssm_groups, cfg.ssm_state,
                          cfg.ssm_heads, cfg.ssm_head_dim)
    chunk = min(chunk, s)
    while s % chunk:       # largest power-of-two-ish divisor ≤ requested chunk
        chunk //= 2
    proj = dense(p["in_proj"], xin)
    x, z, b, c, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([x, b, c], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    x = conv_out[..., :inner].reshape(bsz, s, h, pd)
    b = conv_out[..., inner:inner + g * n].reshape(bsz, s, g, n)
    c = conv_out[..., inner + g * n:].reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    a = jnp.exp(p["a_log"])
    x = constrain(x, "batch", None, "heads", None)
    y, final = ssd_scan(x, dt, a, b, c, chunk=chunk, use_kernel=use_kernel)
    y = y + x * p["d_skip"][None, None, :, None].astype(y.dtype)
    y = _gated_rmsnorm(y.reshape(bsz, s, inner), z, p["norm_scale"])
    out = dense(p["out_proj"], y)
    tail = conv_in[:, -(cfg.conv_width - 1):, :]
    return out, SSMCache(state=final, conv=tail, index=jnp.asarray(s, jnp.int32))


def make_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    conv_dim = cfg.ssm_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return SSMCache(
        state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def ssm_decode(
    p: Params, cfg: ModelConfig, cache: SSMCache, xin: jnp.ndarray
) -> Tuple[jnp.ndarray, SSMCache]:
    """One-token recurrent step: h ← exp(−dt·a)·h + dt·x⊗B ; y = C·h + D·x."""
    bsz = xin.shape[0]
    inner, g, n, h, pd = (cfg.ssm_inner, cfg.ssm_groups, cfg.ssm_state,
                          cfg.ssm_heads, cfg.ssm_head_dim)
    proj = dense(p["in_proj"], xin)                    # (B,1,·)
    x, z, b, c, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([x, b, c], axis=-1)      # (B,1,conv_dim)
    window = jnp.concatenate([cache.conv, conv_in], axis=1)  # (B,W,conv_dim)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    x = conv_out[:, :inner].reshape(bsz, h, pd)
    b = conv_out[:, inner:inner + g * n].reshape(bsz, g, n)
    c = conv_out[:, inner + g * n:].reshape(bsz, g, n)
    rep = h // g
    b = jnp.repeat(b, rep, axis=1)                     # (B,H,N)
    c = jnp.repeat(c, rep, axis=1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])  # (B,H)
    a = jnp.exp(p["a_log"])
    decay = jnp.exp(-dt * a[None, :]).astype(x.dtype)  # (B,H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt.astype(x.dtype), x, b)
    state = cache.state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, c)
    y = y + x * p["d_skip"][None, :, None].astype(y.dtype)
    y = _gated_rmsnorm(y.reshape(bsz, 1, inner), z, p["norm_scale"])
    out = dense(p["out_proj"], y)
    return out, SSMCache(state=state, conv=window[:, 1:, :], index=cache.index + 1)
