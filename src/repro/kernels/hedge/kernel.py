"""Fused H2T2 hedge kernels (monolithic step, multi-round, and the serving
decide/feedback split) as Pallas TPU kernels.

One program instance processes a block of SB streams, each owning the full
(G, G) expert log-weight grid resident in VMEM. Per stream the monolithic
step kernel

  1. reduces the three region log-masses (masked max + exp-sum),
  2. applies the pre-drawn randomness (ψ, ζ) to form the offload / explore /
     local-prediction decisions,
  3. applies the Eq.-10 pseudo-loss update to the log-weights,
  4. renormalizes by the updated max (long-horizon stability),

all in a single VMEM round-trip — the sequential per-sample CPU loop of the
paper's implementation becomes one bandwidth-bound fleet update. The expert
grid is dense (G×G) with an l ≤ u validity mask, so every reduction is a
regular 8×128-lane VPU op; region membership is integer comparison against
the quantized confidence index (no gathers).

The serving split mirrors `core.policy.fleet_decide`/`fleet_feedback`:
`hedge_decide_kernel` runs phases 1–2 only (region log-mass reduce + ψ/ζ
decisions, no weight write), `hedge_feedback_kernel` runs phases 3–4 with
the post-compaction `sent` mask — so an `HIServer` that routes offloads to a
remote model and applies results one slot later runs both halves of the
round at kernel speed.

The (η, decay) schedule arrives as per-stream (SB,) VMEM vectors on every
kernel (the adaptive serving policy anneals them per stream after a shift);
broadcasting the HIConfig scalars reproduces the fixed paper schedule
bit-for-bit — v·x with v = broadcast(c) is elementwise identical to c·x.

Grid: (S_pad // SB,). Block shapes: log_w (SB, G, G); per-stream vectors
(SB,). A fleet whose stream count is not a multiple of `stream_block`
(primes included) is zero-padded up to one and the outputs sliced back —
never degraded to an S-wide grid of SB=1 launches. VMEM footprint ≈
2 · SB·G²·4 B (e.g. SB=8, b=8 ⇒ 4 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-only hardware PRNG (no CPU interpret lowering; see counter docs)
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover - pallas without the TPU extension
    pltpu = None

NEG = -1e30

# ---------------------------------------------------------------------------
# Counter-mode randomness: the kernel-native twin of
# `repro.core.counter.threefry2x32`. Implemented independently (fully
# unrolled round ladder) on purpose — `tests/test_counter_rng.py` pins the
# two implementations bit-for-bit against each other and against the
# Random123 known-answer vectors, so integer-semantics drift in a jax or
# pallas upgrade fails loudly instead of silently forking traces.
# ---------------------------------------------------------------------------

# 20 rounds of threefry2x32: the two 4-rotation schedules, alternating.
_TF_ROT = (13, 15, 26, 6, 17, 29, 16, 24) * 3
_TF_PARITY = 0x1BD11BDA
_U24_SCALE = 1.0 / (1 << 24)


def _tf2x32(k0, k1, c0, c1):
    """threefry2x32(counter=(c0, c1), key=(k0, k1)) — all uint32."""
    keys = (k0, k1, k0 ^ k1 ^ jnp.uint32(_TF_PARITY))
    x0 = c0 + k0
    x1 = c1 + k1
    for r in range(20):
        rot = _TF_ROT[r]
        x0 = x0 + x1
        x1 = ((x1 << rot) | (x1 >> (32 - rot))) ^ x0
        if (r + 1) % 4 == 0:
            j = (r + 1) // 4
            x0 = x0 + keys[j % 3]
            x1 = x1 + keys[(j + 1) % 3] + jnp.uint32(j)
    return x0, x1


def _counter_psi_zeta(seed0, seed1, sid, slot, eps: float):
    """The in-kernel counter contract: (ψ, ζ) from (stream, slot) position.

    Mirrors `repro.core.counter.psi_zeta_from_counter` exactly: top 24 bits
    of each output word as a float32 uniform (exact in the mantissa), ζ via
    a float compare against ε.
    """
    b0, b1 = _tf2x32(seed0, seed1,
                     sid.astype(jnp.uint32), slot.astype(jnp.uint32))
    psi = (b0 >> 8).astype(jnp.float32) * jnp.float32(_U24_SCALE)
    u1 = (b1 >> 8).astype(jnp.float32) * jnp.float32(_U24_SCALE)
    zeta = (u1 < jnp.float32(eps)).astype(jnp.int32)
    return psi, zeta


def _rng_words(rng_ref):
    """Unpack the (4,) int32 rng vector: seed words, slot, stream offset."""
    vals = rng_ref[...]
    seed0 = jax.lax.bitcast_convert_type(vals[0], jnp.uint32)
    seed1 = jax.lax.bitcast_convert_type(vals[1], jnp.uint32)
    return seed0, seed1, vals[2], vals[3]


def _block_stream_ids(offset, stream_block: int):
    """Global stream ids of this program's (SB,) block rows."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (stream_block, 1), 0)[:, 0]
    return offset + pl.program_id(0) * stream_block + iota


def pack_counter_rng(rng) -> jnp.ndarray:
    """Pack a `CounterRNG`-like (seed, slot, stream_offset) into the (4,)
    int32 vector the counter kernels take (seed words bitcast, not
    converted, so the full uint32 range survives)."""
    seed, slot, offset = rng[0], rng[1], rng[2]
    seed_i = jax.lax.bitcast_convert_type(
        jnp.asarray(seed).astype(jnp.uint32), jnp.int32)
    return jnp.stack([
        seed_i[0], seed_i[1],
        jnp.asarray(slot, jnp.int32).reshape(()),
        jnp.asarray(offset, jnp.int32).reshape(()),
    ])


def _region_logsum(logw, mask):
    masked = jnp.where(mask, logw, NEG)
    m = jnp.max(masked, axis=(-2, -1), keepdims=True)
    m = jnp.maximum(m, NEG)  # guard all-masked
    s = jnp.sum(jnp.where(mask, jnp.exp(masked - m), 0.0), axis=(-2, -1))
    return m[..., 0, 0] + jnp.log(jnp.maximum(s, 1e-38))


def _decide_body(logw, i_f, psi, zeta, l_idx, u_idx, valid):
    """Label-free half of the round over a (SB, G, G) block: region masses +
    the (offload, explored, local_pred) decisions. Shared by the decide-only
    kernel and `_round_body` so the split stays step-for-step identical to
    the monolithic kernels."""
    i_b = i_f[:, None, None]
    r2 = valid & (l_idx <= i_b) & (i_b < u_idx)          # ambiguous → offload
    r3 = valid & (u_idx <= i_b)                          # predict 1
    # region 1 (predict 0) is valid & ~r2 & ~r3; never materialized.

    log_s2 = _region_logsum(logw, r2)
    log_s3 = _region_logsum(logw, r3)
    log_tot = _region_logsum(logw, valid)
    q = jnp.exp(log_s2 - log_tot)
    p = jnp.exp(log_s3 - log_tot)

    in_r2 = psi <= q
    offload = in_r2 | (zeta != 0)
    explored = (zeta != 0) & ~in_r2
    local_pred = (psi <= q + p).astype(jnp.int32)
    return r2, r3, offload, explored, local_pred, q, p


def _feedback_body(logw, i_f, sent, explored, h_r, beta, eta, decay,
                   l_idx, u_idx, valid, *, eps, delta_fp, delta_fn):
    """Eq.-10 pseudo-loss update over a (SB, G, G) block.

    `sent` is the offload mask that actually reached the remote model (the
    post-compaction mask in serving; the raw offload decision in
    simulation), `explored` the already-`sent`-masked exploration flag.
    η/decay are (SB,) per-stream vectors.
    """
    i_b = i_f[:, None, None]
    r2 = valid & (l_idx <= i_b) & (i_b < u_idx)
    r3 = valid & (u_idx <= i_b)
    pred1 = r3
    phi = jnp.where(pred1,
                    jnp.where(h_r[:, None, None] == 0, delta_fp, 0.0),
                    jnp.where(h_r[:, None, None] == 1, delta_fn, 0.0))
    lt = jnp.where(sent[:, None, None] & r2, beta[:, None, None], 0.0)
    lt = lt + jnp.where(explored[:, None, None] & valid & ~r2, phi / eps, 0.0)
    # decay < 1 = discounted Hedge (see HIConfig.decay); decay = 1 is Alg. 1.
    new_logw = decay[:, None, None] * logw - eta[:, None, None] * lt
    new_max = jnp.max(jnp.where(valid, new_logw, NEG), axis=(-2, -1),
                      keepdims=True)
    return jnp.where(valid, new_logw - new_max, NEG)


def _round_body(logw, i_f, psi, zeta, h_r, beta, eta, decay,
                l_idx, u_idx, valid, *, eps, delta_fp, delta_fn):
    """One full H2T2 round over a (SB, G, G) block; composition of the decide
    and feedback bodies (with `sent` = the raw offload decision), shared by
    the single-round and multi-round kernels so all four stay
    step-for-step identical."""
    _, _, offload, explored, local_pred, q, p = _decide_body(
        logw, i_f, psi, zeta, l_idx, u_idx, valid)
    new_logw = _feedback_body(
        logw, i_f, offload, explored, h_r, beta, eta, decay,
        l_idx, u_idx, valid, eps=eps, delta_fp=delta_fp, delta_fn=delta_fn)
    return new_logw, offload, explored, local_pred, q, p


def _grid_iota(g: int):
    l_idx = jax.lax.broadcasted_iota(jnp.int32, (1, g, g), 1)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, (1, g, g), 2)
    return l_idx, u_idx, l_idx <= u_idx


def hedge_step_kernel(
    # inputs
    log_w_ref, i_f_ref, psi_ref, zeta_ref, h_r_ref, beta_ref, eta_ref,
    decay_ref,
    # outputs
    new_log_w_ref, offload_ref, explored_ref, local_pred_ref, q_ref, p_ref,
    *, grid_side: int, eps: float, delta_fp: float, delta_fn: float,
):
    logw = log_w_ref[...].astype(jnp.float32)            # (SB, G, G)
    l_idx, u_idx, valid = _grid_iota(grid_side)
    new_logw, offload, explored, local_pred, q, p = _round_body(
        logw, i_f_ref[...], psi_ref[...], zeta_ref[...], h_r_ref[...],
        beta_ref[...], eta_ref[...], decay_ref[...], l_idx, u_idx, valid,
        eps=eps, delta_fp=delta_fp, delta_fn=delta_fn)

    new_log_w_ref[...] = new_logw.astype(new_log_w_ref.dtype)
    offload_ref[...] = offload.astype(jnp.int32)
    explored_ref[...] = explored.astype(jnp.int32)
    local_pred_ref[...] = local_pred
    q_ref[...] = q.astype(jnp.float32)
    p_ref[...] = p.astype(jnp.float32)


def hedge_rounds_kernel(
    # inputs
    log_w_ref, i_f_ref, psi_ref, zeta_ref, h_r_ref, beta_ref, eta_ref,
    decay_ref,
    # outputs
    new_log_w_ref, offload_ref, explored_ref, local_pred_ref, q_ref, p_ref,
    *, grid_side: int, n_rounds: int, eps: float,
    delta_fp: float, delta_fn: float,
):
    """Time-blocked variant: TB sequential H2T2 rounds per kernel invocation.

    The (SB, G, G) log-weight block stays resident in VMEM across all TB
    rounds — one HBM round-trip amortized over the whole time block, instead
    of one per round. Per-round inputs/outputs are (SB, TB) and indexed with
    a static (unrolled) round index, so there are no dynamic stores. The
    per-stream (η, decay) vectors apply to every round in the block, so the
    fast path is valid whenever the schedule is constant across the block
    (fixed schedules always; adaptive schedules only between detector
    updates).
    """
    logw = log_w_ref[...].astype(jnp.float32)            # (SB, G, G)
    l_idx, u_idx, valid = _grid_iota(grid_side)
    eta = eta_ref[...]
    decay = decay_ref[...]

    for t in range(n_rounds):                            # static unroll
        logw, offload, explored, local_pred, q, p = _round_body(
            logw, i_f_ref[:, t], psi_ref[:, t], zeta_ref[:, t], h_r_ref[:, t],
            beta_ref[:, t], eta, decay, l_idx, u_idx, valid,
            eps=eps, delta_fp=delta_fp, delta_fn=delta_fn)
        offload_ref[:, t] = offload.astype(jnp.int32)
        explored_ref[:, t] = explored.astype(jnp.int32)
        local_pred_ref[:, t] = local_pred
        q_ref[:, t] = q.astype(jnp.float32)
        p_ref[:, t] = p.astype(jnp.float32)

    new_log_w_ref[...] = logw.astype(new_log_w_ref.dtype)


def hedge_decide_kernel(
    # inputs
    log_w_ref, i_f_ref, psi_ref, zeta_ref,
    # outputs
    offload_ref, explored_ref, local_pred_ref, q_ref, p_ref,
    *, grid_side: int,
):
    """Serving phase 1: region log-mass reduce + ψ/ζ decisions. Reads the
    expert grid, never writes it — the weight update waits for the (delayed)
    remote labels in `hedge_feedback_kernel`."""
    logw = log_w_ref[...].astype(jnp.float32)            # (SB, G, G)
    l_idx, u_idx, valid = _grid_iota(grid_side)
    _, _, offload, explored, local_pred, q, p = _decide_body(
        logw, i_f_ref[...], psi_ref[...], zeta_ref[...], l_idx, u_idx, valid)
    offload_ref[...] = offload.astype(jnp.int32)
    explored_ref[...] = explored.astype(jnp.int32)
    local_pred_ref[...] = local_pred
    q_ref[...] = q.astype(jnp.float32)
    p_ref[...] = p.astype(jnp.float32)


def hedge_feedback_kernel(
    # inputs
    log_w_ref, i_f_ref, sent_ref, explored_ref, h_r_ref, beta_ref, eta_ref,
    decay_ref,
    # outputs
    new_log_w_ref,
    *, grid_side: int, eps: float, delta_fp: float, delta_fn: float,
):
    """Serving phase 2: the Eq.-10 weight update under the post-compaction
    `sent` mask and the per-stream (η, decay) schedule. The cheap (S,) loss
    and prediction accounting stays in jnp (`core.policy.fleet_feedback`) —
    only the (S, G, G) weight traffic runs here."""
    logw = log_w_ref[...].astype(jnp.float32)            # (SB, G, G)
    l_idx, u_idx, valid = _grid_iota(grid_side)
    new_logw = _feedback_body(
        logw, i_f_ref[...], sent_ref[...] != 0, explored_ref[...] != 0,
        h_r_ref[...], beta_ref[...], eta_ref[...], decay_ref[...],
        l_idx, u_idx, valid, eps=eps, delta_fp=delta_fp, delta_fn=delta_fn)
    new_log_w_ref[...] = new_logw.astype(new_log_w_ref.dtype)


def hedge_step_counter_kernel(
    # inputs
    log_w_ref, i_f_ref, rng_ref, h_r_ref, beta_ref, eta_ref, decay_ref,
    # outputs
    new_log_w_ref, offload_ref, explored_ref, local_pred_ref, q_ref, p_ref,
    *, grid_side: int, stream_block: int, eps: float,
    delta_fp: float, delta_fn: float,
):
    """Counter-mode monolithic step: (ψ, ζ) regenerated in-register from the
    (stream, slot) position — no randomness inputs, no randomness in HBM."""
    logw = log_w_ref[...].astype(jnp.float32)            # (SB, G, G)
    l_idx, u_idx, valid = _grid_iota(grid_side)
    seed0, seed1, slot, offset = _rng_words(rng_ref)
    sid = _block_stream_ids(offset, stream_block)
    psi, zeta = _counter_psi_zeta(seed0, seed1, sid, slot, eps)
    new_logw, offload, explored, local_pred, q, p = _round_body(
        logw, i_f_ref[...], psi, zeta, h_r_ref[...],
        beta_ref[...], eta_ref[...], decay_ref[...], l_idx, u_idx, valid,
        eps=eps, delta_fp=delta_fp, delta_fn=delta_fn)

    new_log_w_ref[...] = new_logw.astype(new_log_w_ref.dtype)
    offload_ref[...] = offload.astype(jnp.int32)
    explored_ref[...] = explored.astype(jnp.int32)
    local_pred_ref[...] = local_pred
    q_ref[...] = q.astype(jnp.float32)
    p_ref[...] = p.astype(jnp.float32)


def hedge_rounds_counter_kernel(
    # inputs
    log_w_ref, i_f_ref, rng_ref, h_r_ref, beta_ref, eta_ref, decay_ref,
    # outputs
    new_log_w_ref, offload_ref, explored_ref, local_pred_ref, q_ref, p_ref,
    *, grid_side: int, n_rounds: int, stream_block: int, eps: float,
    delta_fp: float, delta_fn: float,
):
    """Counter-mode time-blocked rounds: round t draws at slot₀ + t, so a
    TB-chain reproduces the per-slot draws of any other chunking exactly —
    the whole horizon's randomness never exists outside registers."""
    logw = log_w_ref[...].astype(jnp.float32)            # (SB, G, G)
    l_idx, u_idx, valid = _grid_iota(grid_side)
    eta = eta_ref[...]
    decay = decay_ref[...]
    seed0, seed1, slot0, offset = _rng_words(rng_ref)
    sid = _block_stream_ids(offset, stream_block)

    for t in range(n_rounds):                            # static unroll
        psi, zeta = _counter_psi_zeta(seed0, seed1, sid, slot0 + t, eps)
        logw, offload, explored, local_pred, q, p = _round_body(
            logw, i_f_ref[:, t], psi, zeta, h_r_ref[:, t],
            beta_ref[:, t], eta, decay, l_idx, u_idx, valid,
            eps=eps, delta_fp=delta_fp, delta_fn=delta_fn)
        offload_ref[:, t] = offload.astype(jnp.int32)
        explored_ref[:, t] = explored.astype(jnp.int32)
        local_pred_ref[:, t] = local_pred
        q_ref[:, t] = q.astype(jnp.float32)
        p_ref[:, t] = p.astype(jnp.float32)

    new_log_w_ref[...] = logw.astype(new_log_w_ref.dtype)


def hedge_decide_counter_kernel(
    # inputs
    log_w_ref, i_f_ref, rng_ref,
    # outputs
    offload_ref, explored_ref, local_pred_ref, q_ref, p_ref, psi_ref,
    *, grid_side: int, stream_block: int, eps: float,
):
    """Counter-mode serving decide. Additionally *outputs* the ψ draw: the
    serving layer reuses it for the capacity-drop local fallback
    (`FleetDecision.psi`), which pre-draw mode gets from the caller."""
    logw = log_w_ref[...].astype(jnp.float32)            # (SB, G, G)
    l_idx, u_idx, valid = _grid_iota(grid_side)
    seed0, seed1, slot, offset = _rng_words(rng_ref)
    sid = _block_stream_ids(offset, stream_block)
    psi, zeta = _counter_psi_zeta(seed0, seed1, sid, slot, eps)
    _, _, offload, explored, local_pred, q, p = _decide_body(
        logw, i_f_ref[...], psi, zeta, l_idx, u_idx, valid)
    offload_ref[...] = offload.astype(jnp.int32)
    explored_ref[...] = explored.astype(jnp.int32)
    local_pred_ref[...] = local_pred
    q_ref[...] = q.astype(jnp.float32)
    p_ref[...] = p.astype(jnp.float32)
    psi_ref[...] = psi.astype(jnp.float32)


def _counter_draw_kernel(
    rng_ref, b0_ref, b1_ref, psi_ref, zeta_ref,
    *, stream_block: int, eps: float, hw_bits: bool,
):
    """Raw counter draws for one slot — the bit-compat test surface.

    `hw_bits=True` swaps the portable threefry mixing for the TPU hardware
    generator (`pltpu.prng_seed`/`prng_random_bits` seeded per (stream
    block, slot)). That path has no CPU interpret lowering, is NOT
    bit-compatible with the counter contract, and its draws depend on the
    stream_block partition — it exists only for on-TPU throughput
    experiments (see ROADMAP's TPU-validation item).
    """
    vals = rng_ref[...]
    if hw_bits:
        if pltpu is None:  # pragma: no cover
            raise NotImplementedError("pltpu unavailable")
        pltpu.prng_seed(vals[0], vals[1], vals[2], pl.program_id(0))
        b0 = pltpu.prng_random_bits((stream_block,)).astype(jnp.uint32)
        b1 = pltpu.prng_random_bits((stream_block,)).astype(jnp.uint32)
        psi = (b0 >> 8).astype(jnp.float32) * jnp.float32(_U24_SCALE)
        u1 = (b1 >> 8).astype(jnp.float32) * jnp.float32(_U24_SCALE)
        zeta = (u1 < jnp.float32(eps)).astype(jnp.int32)
    else:
        seed0, seed1, slot, offset = _rng_words(rng_ref)
        sid = _block_stream_ids(offset, stream_block)
        b0, b1 = _tf2x32(seed0, seed1,
                         sid.astype(jnp.uint32), slot.astype(jnp.uint32))
        psi, zeta = _counter_psi_zeta(seed0, seed1, sid, slot, eps)
    b0_ref[...] = b0
    b1_ref[...] = b1
    psi_ref[...] = psi
    zeta_ref[...] = zeta


def counter_draw_pallas(
    rng,                     # (seed (2,) uint32, slot (), stream_offset ())
    n_streams: int,
    *,
    eps: float,
    stream_block: int = 8,
    interpret: bool = True,
    hw_bits: bool = False,
):
    """Kernel-native counter draws for one slot: (b0, b1, ψ, ζ), each (S,).

    The debug/test wrapper behind the pinned bit-compat suite: raw uint32
    words straight out of the in-kernel mixing, compared bit-for-bit
    against `repro.core.counter.counter_bits`/`psi_zeta_from_counter`.
    """
    s = int(n_streams)
    sb, s_pad, _ = _block_streams(s, stream_block)
    grid = (s_pad // sb,)
    kern = functools.partial(
        _counter_draw_kernel, stream_block=sb, eps=eps, hw_bits=hw_bits)
    vec = lambda: pl.BlockSpec((sb,), lambda i: (i,))
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=(vec(), vec(), vec(), vec()),
        out_shape=(
            jax.ShapeDtypeStruct((s_pad,), jnp.uint32),
            jax.ShapeDtypeStruct((s_pad,), jnp.uint32),
            jax.ShapeDtypeStruct((s_pad,), jnp.float32),
            jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        ),
        interpret=interpret,
    )(pack_counter_rng(rng))
    return tuple(o[:s] for o in out)


def _block_streams(s: int, stream_block: int):
    """Resolve the (SB, S_pad, grid) launch geometry for an S-stream fleet.

    SB never exceeds S; when S is not a multiple of SB (odd or prime fleet
    sizes included) the stream axis is zero-padded up to one — outputs for
    the padding rows are sliced off by the wrappers. This replaces the old
    largest-divisor fallback, which degraded a prime fleet to SB=1 and an
    S-wide grid of tiny launches.
    """
    sb = max(1, min(int(stream_block), s))
    pad = (-s) % sb
    return sb, s + pad, pad


def _pad_streams(pad: int, *arrays):
    """Zero-pad the leading stream axis of every array by `pad` rows.

    Padding rows carry inert inputs (all-zero — but structurally valid —
    expert grids, i_f = 0, ψ = 0, …); nothing in a hedge kernel couples
    streams, so they can never affect a real stream's outputs, which is why
    slicing (rather than masking arithmetic) is enough on the way out.
    """
    if pad == 0:
        return arrays
    return tuple(
        jnp.concatenate(
            [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0)
        for a in arrays)


def _sched_vec(val, s: int) -> jnp.ndarray:
    """Broadcast a scalar-or-(S,) schedule value to an (S,) float32 vector."""
    return jnp.broadcast_to(jnp.asarray(val, jnp.float32), (s,))


def hedge_step_pallas(
    log_w: jnp.ndarray,      # (S, G, G) float32
    i_f: jnp.ndarray,        # (S,) int32
    psi: jnp.ndarray,        # (S,) float32
    zeta: jnp.ndarray,       # (S,) int32
    h_r: jnp.ndarray,        # (S,) int32
    beta: jnp.ndarray,       # (S,) float32
    eta,                     # scalar or (S,) float32 — per-stream η
    decay,                   # scalar or (S,) float32 — per-stream decay
    *,
    eps: float, delta_fp: float, delta_fn: float,
    stream_block: int = 8,
    interpret: bool = True,
):
    s, g, _ = log_w.shape
    sb, s_pad, pad = _block_streams(s, stream_block)
    grid = (s_pad // sb,)
    kern = functools.partial(
        hedge_step_kernel, grid_side=g, eps=eps,
        delta_fp=delta_fp, delta_fn=delta_fn)
    vec = lambda: pl.BlockSpec((sb,), lambda i: (i,))
    out_shapes = (
        jax.ShapeDtypeStruct((s_pad, g, g), jnp.float32),
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.float32),
        jax.ShapeDtypeStruct((s_pad,), jnp.float32),
    )
    args = _pad_streams(pad, log_w, i_f, psi, zeta, h_r, beta,
                        _sched_vec(eta, s), _sched_vec(decay, s))
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            vec(), vec(), vec(), vec(), vec(), vec(), vec(),
        ],
        out_specs=(
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            vec(), vec(), vec(), vec(), vec(),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(*args)
    return tuple(o[:s] for o in out)


def hedge_rounds_pallas(
    log_w: jnp.ndarray,      # (S, G, G) float32
    i_f: jnp.ndarray,        # (S, TB) int32
    psi: jnp.ndarray,        # (S, TB) float32
    zeta: jnp.ndarray,       # (S, TB) int32
    h_r: jnp.ndarray,        # (S, TB) int32
    beta: jnp.ndarray,       # (S, TB) float32
    eta,                     # scalar or (S,) float32 — per-stream η
    decay,                   # scalar or (S,) float32 — per-stream decay
    *,
    eps: float, delta_fp: float, delta_fn: float,
    stream_block: int = 8,
    interpret: bool = True,
):
    """TB sequential rounds for the whole fleet in one kernel launch.

    Matches a TB-long chain of `hedge_step_pallas` calls step-for-step, but
    keeps each stream's expert grid in VMEM across the block.
    """
    s, g, _ = log_w.shape
    tb = i_f.shape[1]
    sb, s_pad, pad = _block_streams(s, stream_block)
    grid = (s_pad // sb,)
    kern = functools.partial(
        hedge_rounds_kernel, grid_side=g, n_rounds=tb, eps=eps,
        delta_fp=delta_fp, delta_fn=delta_fn)
    vec = lambda: pl.BlockSpec((sb,), lambda i: (i,))
    mat = lambda: pl.BlockSpec((sb, tb), lambda i: (i, 0))
    out_shapes = (
        jax.ShapeDtypeStruct((s_pad, g, g), jnp.float32),
        jax.ShapeDtypeStruct((s_pad, tb), jnp.int32),
        jax.ShapeDtypeStruct((s_pad, tb), jnp.int32),
        jax.ShapeDtypeStruct((s_pad, tb), jnp.int32),
        jax.ShapeDtypeStruct((s_pad, tb), jnp.float32),
        jax.ShapeDtypeStruct((s_pad, tb), jnp.float32),
    )
    args = _pad_streams(pad, log_w, i_f, psi, zeta, h_r, beta,
                        _sched_vec(eta, s), _sched_vec(decay, s))
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            mat(), mat(), mat(), mat(), mat(), vec(), vec(),
        ],
        out_specs=(
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            mat(), mat(), mat(), mat(), mat(),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(*args)
    return tuple(o[:s] for o in out)


def hedge_decide_pallas(
    log_w: jnp.ndarray,      # (S, G, G) float32
    i_f: jnp.ndarray,        # (S,) int32
    psi: jnp.ndarray,        # (S,) float32
    zeta: jnp.ndarray,       # (S,) int32
    *,
    stream_block: int = 8,
    interpret: bool = True,
):
    """Serving phase 1 for the fleet: (offload, explored, local_pred, q, p),
    no weight write."""
    s, g, _ = log_w.shape
    sb, s_pad, pad = _block_streams(s, stream_block)
    grid = (s_pad // sb,)
    kern = functools.partial(hedge_decide_kernel, grid_side=g)
    vec = lambda: pl.BlockSpec((sb,), lambda i: (i,))
    out_shapes = (
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.float32),
        jax.ShapeDtypeStruct((s_pad,), jnp.float32),
    )
    args = _pad_streams(pad, log_w, i_f, psi, zeta)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            vec(), vec(), vec(),
        ],
        out_specs=(vec(), vec(), vec(), vec(), vec()),
        out_shape=out_shapes,
        interpret=interpret,
    )(*args)
    return tuple(o[:s] for o in out)


def hedge_feedback_pallas(
    log_w: jnp.ndarray,      # (S, G, G) float32
    i_f: jnp.ndarray,        # (S,) int32 — decision-time quantized confidence
    sent: jnp.ndarray,       # (S,) int32 — offloads that reached the RDL
    explored: jnp.ndarray,   # (S,) int32 — exploration flag, already ∧ sent
    h_r: jnp.ndarray,        # (S,) int32
    beta: jnp.ndarray,       # (S,) float32
    eta,                     # scalar or (S,) float32 — per-stream η
    decay,                   # scalar or (S,) float32 — per-stream decay
    *,
    eps: float, delta_fp: float, delta_fn: float,
    stream_block: int = 8,
    interpret: bool = True,
):
    """Serving phase 2 for the fleet: the Eq.-10 weight update only."""
    s, g, _ = log_w.shape
    sb, s_pad, pad = _block_streams(s, stream_block)
    grid = (s_pad // sb,)
    kern = functools.partial(
        hedge_feedback_kernel, grid_side=g, eps=eps,
        delta_fp=delta_fp, delta_fn=delta_fn)
    vec = lambda: pl.BlockSpec((sb,), lambda i: (i,))
    args = _pad_streams(pad, log_w, i_f, sent, explored, h_r, beta,
                        _sched_vec(eta, s), _sched_vec(decay, s))
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            vec(), vec(), vec(), vec(), vec(), vec(), vec(),
        ],
        out_specs=pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, g, g), jnp.float32),
        interpret=interpret,
    )(*args)
    return out[:s]


def _rng_spec():
    return pl.BlockSpec((4,), lambda i: (0,))


def hedge_step_counter_pallas(
    log_w: jnp.ndarray,      # (S, G, G) float32
    i_f: jnp.ndarray,        # (S,) int32
    rng,                     # (seed (2,) uint32, slot (), stream_offset ())
    h_r: jnp.ndarray,        # (S,) int32
    beta: jnp.ndarray,       # (S,) float32
    eta,                     # scalar or (S,) float32 — per-stream η
    decay,                   # scalar or (S,) float32 — per-stream decay
    *,
    eps: float, delta_fp: float, delta_fn: float,
    stream_block: int = 8,
    interpret: bool = True,
):
    """Counter-mode `hedge_step_pallas`: no (ψ, ζ) inputs — the draws are
    regenerated from (stream, slot) position inside the kernel."""
    s, g, _ = log_w.shape
    sb, s_pad, pad = _block_streams(s, stream_block)
    grid = (s_pad // sb,)
    kern = functools.partial(
        hedge_step_counter_kernel, grid_side=g, stream_block=sb, eps=eps,
        delta_fp=delta_fp, delta_fn=delta_fn)
    vec = lambda: pl.BlockSpec((sb,), lambda i: (i,))
    out_shapes = (
        jax.ShapeDtypeStruct((s_pad, g, g), jnp.float32),
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.float32),
        jax.ShapeDtypeStruct((s_pad,), jnp.float32),
    )
    padded = _pad_streams(pad, log_w, i_f, h_r, beta,
                          _sched_vec(eta, s), _sched_vec(decay, s))
    args = padded[:2] + (pack_counter_rng(rng),) + padded[2:]
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            vec(), _rng_spec(), vec(), vec(), vec(), vec(),
        ],
        out_specs=(
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            vec(), vec(), vec(), vec(), vec(),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(*args)
    return tuple(o[:s] for o in out)


def hedge_rounds_counter_pallas(
    log_w: jnp.ndarray,      # (S, G, G) float32
    i_f: jnp.ndarray,        # (S, TB) int32
    rng,                     # (seed, slot₀, stream_offset) — round t at slot₀+t
    h_r: jnp.ndarray,        # (S, TB) int32
    beta: jnp.ndarray,       # (S, TB) float32
    eta,                     # scalar or (S,) float32 — per-stream η
    decay,                   # scalar or (S,) float32 — per-stream decay
    *,
    eps: float, delta_fp: float, delta_fn: float,
    stream_block: int = 8,
    interpret: bool = True,
):
    """Counter-mode `hedge_rounds_pallas`: TB rounds, zero randomness HBM
    traffic — peak randomness residency is the (SB,) in-register draw."""
    s, g, _ = log_w.shape
    tb = i_f.shape[1]
    sb, s_pad, pad = _block_streams(s, stream_block)
    grid = (s_pad // sb,)
    kern = functools.partial(
        hedge_rounds_counter_kernel, grid_side=g, n_rounds=tb,
        stream_block=sb, eps=eps, delta_fp=delta_fp, delta_fn=delta_fn)
    vec = lambda: pl.BlockSpec((sb,), lambda i: (i,))
    mat = lambda: pl.BlockSpec((sb, tb), lambda i: (i, 0))
    out_shapes = (
        jax.ShapeDtypeStruct((s_pad, g, g), jnp.float32),
        jax.ShapeDtypeStruct((s_pad, tb), jnp.int32),
        jax.ShapeDtypeStruct((s_pad, tb), jnp.int32),
        jax.ShapeDtypeStruct((s_pad, tb), jnp.int32),
        jax.ShapeDtypeStruct((s_pad, tb), jnp.float32),
        jax.ShapeDtypeStruct((s_pad, tb), jnp.float32),
    )
    padded = _pad_streams(pad, log_w, i_f, h_r, beta,
                          _sched_vec(eta, s), _sched_vec(decay, s))
    args = padded[:2] + (pack_counter_rng(rng),) + padded[2:]
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            mat(), _rng_spec(), mat(), mat(), vec(), vec(),
        ],
        out_specs=(
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            mat(), mat(), mat(), mat(), mat(),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(*args)
    return tuple(o[:s] for o in out)


def hedge_decide_counter_pallas(
    log_w: jnp.ndarray,      # (S, G, G) float32
    i_f: jnp.ndarray,        # (S,) int32
    rng,                     # (seed (2,) uint32, slot (), stream_offset ())
    *,
    eps: float,
    stream_block: int = 8,
    interpret: bool = True,
):
    """Counter-mode serving decide: (offload, explored, local_pred, q, p, ψ).

    ψ is an *output* here (serving reuses it for the capacity-drop local
    fallback) — the one draw that outlives the kernel, (S,) not (S, T).
    """
    s, g, _ = log_w.shape
    sb, s_pad, pad = _block_streams(s, stream_block)
    grid = (s_pad // sb,)
    kern = functools.partial(
        hedge_decide_counter_kernel, grid_side=g, stream_block=sb, eps=eps)
    vec = lambda: pl.BlockSpec((sb,), lambda i: (i,))
    out_shapes = (
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.float32),
        jax.ShapeDtypeStruct((s_pad,), jnp.float32),
        jax.ShapeDtypeStruct((s_pad,), jnp.float32),
    )
    padded = _pad_streams(pad, log_w, i_f)
    args = padded + (pack_counter_rng(rng),)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            vec(), _rng_spec(),
        ],
        out_specs=(vec(), vec(), vec(), vec(), vec(), vec()),
        out_shape=out_shapes,
        interpret=interpret,
    )(*args)
    return tuple(o[:s] for o in out)
