"""Fused H2T2 hedge step as a Pallas TPU kernel.

One program instance processes a block of SB streams, each owning the full
(G, G) expert log-weight grid resident in VMEM. Per stream the kernel

  1. reduces the three region log-masses (masked max + exp-sum),
  2. applies the pre-drawn randomness (ψ, ζ) to form the offload / explore /
     local-prediction decisions,
  3. applies the Eq.-10 pseudo-loss update to the log-weights,
  4. renormalizes by the updated max (long-horizon stability),

all in a single VMEM round-trip — the sequential per-sample CPU loop of the
paper's implementation becomes one bandwidth-bound fleet update. The expert
grid is dense (G×G) with an l ≤ u validity mask, so every reduction is a
regular 8×128-lane VPU op; region membership is integer comparison against
the quantized confidence index (no gathers).

Grid: (S // SB,). Block shapes: log_w (SB, G, G); per-stream scalars (SB,).
VMEM footprint ≈ 2 · SB·G²·4 B (e.g. SB=8, b=8 ⇒ 4 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _region_logsum(logw, mask):
    masked = jnp.where(mask, logw, NEG)
    m = jnp.max(masked, axis=(-2, -1), keepdims=True)
    m = jnp.maximum(m, NEG)  # guard all-masked
    s = jnp.sum(jnp.where(mask, jnp.exp(masked - m), 0.0), axis=(-2, -1))
    return m[..., 0, 0] + jnp.log(jnp.maximum(s, 1e-38))


def _round_body(logw, i_f, psi, zeta, h_r, beta, l_idx, u_idx, valid,
                *, eta, eps, delta_fp, delta_fn, decay):
    """One H2T2 round over a (SB, G, G) block; shared by the single-round and
    multi-round kernels so the two stay step-for-step identical."""
    i_b = i_f[:, None, None]
    r2 = valid & (l_idx <= i_b) & (i_b < u_idx)          # ambiguous → offload
    r3 = valid & (u_idx <= i_b)                          # predict 1
    # region 1 (predict 0) is valid & ~r2 & ~r3; never materialized.

    log_s2 = _region_logsum(logw, r2)
    log_s3 = _region_logsum(logw, r3)
    log_tot = _region_logsum(logw, valid)
    q = jnp.exp(log_s2 - log_tot)
    p = jnp.exp(log_s3 - log_tot)

    in_r2 = psi <= q
    offload = in_r2 | (zeta != 0)
    explored = (zeta != 0) & ~in_r2
    local_pred = (psi <= q + p).astype(jnp.int32)

    # Eq. 10 pseudo-loss per expert.
    pred1 = r3
    phi = jnp.where(pred1,
                    jnp.where(h_r[:, None, None] == 0, delta_fp, 0.0),
                    jnp.where(h_r[:, None, None] == 1, delta_fn, 0.0))
    lt = jnp.where(offload[:, None, None] & r2, beta[:, None, None], 0.0)
    lt = lt + jnp.where(explored[:, None, None] & valid & ~r2, phi / eps, 0.0)
    # decay < 1 = discounted Hedge (see HIConfig.decay); decay = 1 is Alg. 1.
    new_logw = decay * logw - eta * lt
    new_max = jnp.max(jnp.where(valid, new_logw, NEG), axis=(-2, -1), keepdims=True)
    new_logw = jnp.where(valid, new_logw - new_max, NEG)
    return new_logw, offload, explored, local_pred, q, p


def hedge_step_kernel(
    # inputs
    log_w_ref, i_f_ref, psi_ref, zeta_ref, h_r_ref, beta_ref,
    # outputs
    new_log_w_ref, offload_ref, explored_ref, local_pred_ref, q_ref, p_ref,
    *, grid_side: int, eta: float, eps: float, delta_fp: float, delta_fn: float,
    decay: float = 1.0,
):
    g = grid_side
    logw = log_w_ref[...].astype(jnp.float32)            # (SB, G, G)

    l_idx = jax.lax.broadcasted_iota(jnp.int32, (1, g, g), 1)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, (1, g, g), 2)
    valid = l_idx <= u_idx
    new_logw, offload, explored, local_pred, q, p = _round_body(
        logw, i_f_ref[...], psi_ref[...], zeta_ref[...], h_r_ref[...],
        beta_ref[...], l_idx, u_idx, valid,
        eta=eta, eps=eps, delta_fp=delta_fp, delta_fn=delta_fn, decay=decay)

    new_log_w_ref[...] = new_logw.astype(new_log_w_ref.dtype)
    offload_ref[...] = offload.astype(jnp.int32)
    explored_ref[...] = explored.astype(jnp.int32)
    local_pred_ref[...] = local_pred
    q_ref[...] = q.astype(jnp.float32)
    p_ref[...] = p.astype(jnp.float32)


def hedge_rounds_kernel(
    # inputs
    log_w_ref, i_f_ref, psi_ref, zeta_ref, h_r_ref, beta_ref,
    # outputs
    new_log_w_ref, offload_ref, explored_ref, local_pred_ref, q_ref, p_ref,
    *, grid_side: int, n_rounds: int, eta: float, eps: float,
    delta_fp: float, delta_fn: float, decay: float = 1.0,
):
    """Time-blocked variant: TB sequential H2T2 rounds per kernel invocation.

    The (SB, G, G) log-weight block stays resident in VMEM across all TB
    rounds — one HBM round-trip amortized over the whole time block, instead
    of one per round. Per-round inputs/outputs are (SB, TB) and indexed with
    a static (unrolled) round index, so there are no dynamic stores.
    """
    g = grid_side
    logw = log_w_ref[...].astype(jnp.float32)            # (SB, G, G)
    l_idx = jax.lax.broadcasted_iota(jnp.int32, (1, g, g), 1)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, (1, g, g), 2)
    valid = l_idx <= u_idx

    for t in range(n_rounds):                            # static unroll
        logw, offload, explored, local_pred, q, p = _round_body(
            logw, i_f_ref[:, t], psi_ref[:, t], zeta_ref[:, t], h_r_ref[:, t],
            beta_ref[:, t], l_idx, u_idx, valid,
            eta=eta, eps=eps, delta_fp=delta_fp, delta_fn=delta_fn, decay=decay)
        offload_ref[:, t] = offload.astype(jnp.int32)
        explored_ref[:, t] = explored.astype(jnp.int32)
        local_pred_ref[:, t] = local_pred
        q_ref[:, t] = q.astype(jnp.float32)
        p_ref[:, t] = p.astype(jnp.float32)

    new_log_w_ref[...] = logw.astype(new_log_w_ref.dtype)


def _stream_block(s: int, stream_block: int) -> int:
    sb = min(stream_block, s)
    while s % sb:
        sb -= 1
    return sb


def hedge_step_pallas(
    log_w: jnp.ndarray,      # (S, G, G) float32
    i_f: jnp.ndarray,        # (S,) int32
    psi: jnp.ndarray,        # (S,) float32
    zeta: jnp.ndarray,       # (S,) int32
    h_r: jnp.ndarray,        # (S,) int32
    beta: jnp.ndarray,       # (S,) float32
    *,
    eta: float, eps: float, delta_fp: float, delta_fn: float,
    decay: float = 1.0,
    stream_block: int = 8,
    interpret: bool = True,
):
    s, g, _ = log_w.shape
    sb = _stream_block(s, stream_block)
    grid = (s // sb,)
    kern = functools.partial(
        hedge_step_kernel, grid_side=g, eta=eta, eps=eps,
        delta_fp=delta_fp, delta_fn=delta_fn, decay=decay)
    vec = lambda: pl.BlockSpec((sb,), lambda i: (i,))
    out_shapes = (
        jax.ShapeDtypeStruct((s, g, g), jnp.float32),
        jax.ShapeDtypeStruct((s,), jnp.int32),
        jax.ShapeDtypeStruct((s,), jnp.int32),
        jax.ShapeDtypeStruct((s,), jnp.int32),
        jax.ShapeDtypeStruct((s,), jnp.float32),
        jax.ShapeDtypeStruct((s,), jnp.float32),
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            vec(), vec(), vec(), vec(), vec(),
        ],
        out_specs=(
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            vec(), vec(), vec(), vec(), vec(),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(log_w, i_f, psi, zeta, h_r, beta)


def hedge_rounds_pallas(
    log_w: jnp.ndarray,      # (S, G, G) float32
    i_f: jnp.ndarray,        # (S, TB) int32
    psi: jnp.ndarray,        # (S, TB) float32
    zeta: jnp.ndarray,       # (S, TB) int32
    h_r: jnp.ndarray,        # (S, TB) int32
    beta: jnp.ndarray,       # (S, TB) float32
    *,
    eta: float, eps: float, delta_fp: float, delta_fn: float,
    decay: float = 1.0,
    stream_block: int = 8,
    interpret: bool = True,
):
    """TB sequential rounds for the whole fleet in one kernel launch.

    Matches a TB-long chain of `hedge_step_pallas` calls step-for-step, but
    keeps each stream's expert grid in VMEM across the block.
    """
    s, g, _ = log_w.shape
    tb = i_f.shape[1]
    sb = _stream_block(s, stream_block)
    grid = (s // sb,)
    kern = functools.partial(
        hedge_rounds_kernel, grid_side=g, n_rounds=tb, eta=eta, eps=eps,
        delta_fp=delta_fp, delta_fn=delta_fn, decay=decay)
    mat = lambda: pl.BlockSpec((sb, tb), lambda i: (i, 0))
    out_shapes = (
        jax.ShapeDtypeStruct((s, g, g), jnp.float32),
        jax.ShapeDtypeStruct((s, tb), jnp.int32),
        jax.ShapeDtypeStruct((s, tb), jnp.int32),
        jax.ShapeDtypeStruct((s, tb), jnp.int32),
        jax.ShapeDtypeStruct((s, tb), jnp.float32),
        jax.ShapeDtypeStruct((s, tb), jnp.float32),
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            mat(), mat(), mat(), mat(), mat(),
        ],
        out_specs=(
            pl.BlockSpec((sb, g, g), lambda i: (i, 0, 0)),
            mat(), mat(), mat(), mat(), mat(),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(log_w, i_f, psi, zeta, h_r, beta)
