"""Pure-jnp oracle for the fused hedge step (mirrors repro.core.policy with
externally supplied randomness)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def hedge_step_ref(
    log_w: jnp.ndarray, i_f: jnp.ndarray, psi: jnp.ndarray, zeta: jnp.ndarray,
    h_r: jnp.ndarray, beta: jnp.ndarray,
    *, eta: float, eps: float, delta_fp: float, delta_fn: float,
    decay: float = 1.0,
):
    s, g, _ = log_w.shape
    l_idx = jnp.arange(g)[None, :, None]
    u_idx = jnp.arange(g)[None, None, :]
    valid = l_idx <= u_idx
    i_b = i_f[:, None, None]
    r2 = valid & (l_idx <= i_b) & (i_b < u_idx)
    r3 = valid & (u_idx <= i_b)

    def logsum(mask):
        masked = jnp.where(mask, log_w, NEG)
        m = jnp.maximum(jnp.max(masked, axis=(-2, -1), keepdims=True), NEG)
        ssum = jnp.sum(jnp.where(mask, jnp.exp(masked - m), 0.0), axis=(-2, -1))
        return m[..., 0, 0] + jnp.log(jnp.maximum(ssum, 1e-38))

    log_tot = logsum(valid)
    q = jnp.exp(logsum(r2) - log_tot)
    p = jnp.exp(logsum(r3) - log_tot)
    in_r2 = psi <= q
    offload = in_r2 | (zeta != 0)
    explored = (zeta != 0) & ~in_r2
    local_pred = (psi <= q + p).astype(jnp.int32)

    phi = jnp.where(r3,
                    jnp.where(h_r[:, None, None] == 0, delta_fp, 0.0),
                    jnp.where(h_r[:, None, None] == 1, delta_fn, 0.0))
    lt = jnp.where(offload[:, None, None] & r2, beta[:, None, None], 0.0)
    lt = lt + jnp.where(explored[:, None, None] & valid & ~r2, phi / eps, 0.0)
    new = decay * log_w - eta * lt
    new_max = jnp.max(jnp.where(valid, new, NEG), axis=(-2, -1), keepdims=True)
    new = jnp.where(valid, new - new_max, NEG)
    return (new.astype(jnp.float32), offload.astype(jnp.int32),
            explored.astype(jnp.int32), local_pred,
            q.astype(jnp.float32), p.astype(jnp.float32))


def hedge_rounds_ref(
    log_w: jnp.ndarray,      # (S, G, G)
    i_f: jnp.ndarray,        # (S, TB)
    psi: jnp.ndarray,        # (S, TB)
    zeta: jnp.ndarray,       # (S, TB)
    h_r: jnp.ndarray,        # (S, TB)
    beta: jnp.ndarray,       # (S, TB)
    *, eta: float, eps: float, delta_fp: float, delta_fn: float,
    decay: float = 1.0,
):
    """Oracle for the time-blocked kernel: scan `hedge_step_ref` over TB rounds."""

    def body(lw, xs):
        new, off, exp_, lp, q, p = hedge_step_ref(
            lw, *xs, eta=eta, eps=eps, delta_fp=delta_fp, delta_fn=delta_fn,
            decay=decay)
        return new, (off, exp_, lp, q, p)

    xs = tuple(a.T for a in (i_f, psi, zeta, h_r, beta))         # time-major
    final, outs = jax.lax.scan(body, log_w.astype(jnp.float32), xs)
    off, exp_, lp, q, p = (o.T for o in outs)                    # back to (S, TB)
    return final, off, exp_, lp, q, p
