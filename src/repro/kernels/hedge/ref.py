"""Pure-jnp oracles for the fused hedge kernels (mirrors repro.core.policy
with externally supplied randomness).

Every oracle accepts the (η, decay) schedule as a scalar OR a per-stream
(S,) vector, exactly like the Pallas kernels — broadcasting a scalar is
elementwise identical to the static-scalar math, so the fixed paper
schedule stays bit-for-bit reproducible through either form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.counter import psi_zeta_from_counter

NEG = -1e30


def _counter_draws(rng, s: int, slot_shift, eps: float):
    """(ψ, ζ) for streams [offset, offset+S) at slot + slot_shift, via the
    golden counter contract (`repro.core.counter`)."""
    seed, slot, offset = rng[0], rng[1], rng[2]
    sid = jnp.asarray(offset, jnp.int32) + jnp.arange(s, dtype=jnp.int32)
    psi, zeta = psi_zeta_from_counter(
        seed, sid, jnp.asarray(slot, jnp.int32) + slot_shift, eps)
    return psi, zeta.astype(jnp.int32)


def _sched_col(val, s: int) -> jnp.ndarray:
    """Schedule value as an (S, 1, 1) float32 column for (S, G, G) updates."""
    return jnp.broadcast_to(
        jnp.asarray(val, jnp.float32), (s,))[:, None, None]


def _regions(i_f: jnp.ndarray, g: int):
    l_idx = jnp.arange(g)[None, :, None]
    u_idx = jnp.arange(g)[None, None, :]
    valid = l_idx <= u_idx
    i_b = i_f[:, None, None]
    r2 = valid & (l_idx <= i_b) & (i_b < u_idx)
    r3 = valid & (u_idx <= i_b)
    return valid, r2, r3


def _logsum(log_w, mask):
    masked = jnp.where(mask, log_w, NEG)
    m = jnp.maximum(jnp.max(masked, axis=(-2, -1), keepdims=True), NEG)
    ssum = jnp.sum(jnp.where(mask, jnp.exp(masked - m), 0.0), axis=(-2, -1))
    return m[..., 0, 0] + jnp.log(jnp.maximum(ssum, 1e-38))


def hedge_decide_ref(
    log_w: jnp.ndarray, i_f: jnp.ndarray, psi: jnp.ndarray, zeta: jnp.ndarray,
):
    """Oracle for the decide-only kernel: region masses + decisions, no
    weight write. Returns (offload, explored, local_pred, q, p)."""
    g = log_w.shape[1]
    valid, r2, r3 = _regions(i_f, g)
    log_tot = _logsum(log_w, valid)
    q = jnp.exp(_logsum(log_w, r2) - log_tot)
    p = jnp.exp(_logsum(log_w, r3) - log_tot)
    in_r2 = psi <= q
    offload = in_r2 | (zeta != 0)
    explored = (zeta != 0) & ~in_r2
    local_pred = (psi <= q + p).astype(jnp.int32)
    return (offload.astype(jnp.int32), explored.astype(jnp.int32), local_pred,
            q.astype(jnp.float32), p.astype(jnp.float32))


def hedge_feedback_ref(
    log_w: jnp.ndarray, i_f: jnp.ndarray, sent: jnp.ndarray,
    explored: jnp.ndarray, h_r: jnp.ndarray, beta: jnp.ndarray,
    eta, decay,
    *, eps: float, delta_fp: float, delta_fn: float,
):
    """Oracle for the feedback-only kernel: the Eq.-10 weight update under a
    `sent` mask and per-stream (η, decay). Returns the renormalized
    log-weights (NEG sentinel on invalid cells)."""
    s, g, _ = log_w.shape
    valid, r2, r3 = _regions(i_f, g)
    sent_b = (sent != 0)[:, None, None]
    explored_b = (explored != 0)[:, None, None]
    phi = jnp.where(r3,
                    jnp.where(h_r[:, None, None] == 0, delta_fp, 0.0),
                    jnp.where(h_r[:, None, None] == 1, delta_fn, 0.0))
    lt = jnp.where(sent_b & r2, beta[:, None, None], 0.0)
    lt = lt + jnp.where(explored_b & valid & ~r2, phi / eps, 0.0)
    new = _sched_col(decay, s) * log_w - _sched_col(eta, s) * lt
    new_max = jnp.max(jnp.where(valid, new, NEG), axis=(-2, -1), keepdims=True)
    return jnp.where(valid, new - new_max, NEG).astype(jnp.float32)


def hedge_step_ref(
    log_w: jnp.ndarray, i_f: jnp.ndarray, psi: jnp.ndarray, zeta: jnp.ndarray,
    h_r: jnp.ndarray, beta: jnp.ndarray,
    *, eta, eps: float, delta_fp: float, delta_fn: float,
    decay=1.0,
):
    off, exp_, local_pred, q, p = hedge_decide_ref(log_w, i_f, psi, zeta)
    new = hedge_feedback_ref(
        log_w, i_f, off, exp_, h_r, beta, eta, decay,
        eps=eps, delta_fp=delta_fp, delta_fn=delta_fn)
    return new, off, exp_, local_pred, q, p


def hedge_rounds_ref(
    log_w: jnp.ndarray,      # (S, G, G)
    i_f: jnp.ndarray,        # (S, TB)
    psi: jnp.ndarray,        # (S, TB)
    zeta: jnp.ndarray,       # (S, TB)
    h_r: jnp.ndarray,        # (S, TB)
    beta: jnp.ndarray,       # (S, TB)
    *, eta, eps: float, delta_fp: float, delta_fn: float,
    decay=1.0,
):
    """Oracle for the time-blocked kernel: scan `hedge_step_ref` over TB
    rounds with the (per-stream) schedule held fixed across the block."""

    def body(lw, xs):
        new, off, exp_, lp, q, p = hedge_step_ref(
            lw, *xs, eta=eta, eps=eps, delta_fp=delta_fp, delta_fn=delta_fn,
            decay=decay)
        return new, (off, exp_, lp, q, p)

    xs = tuple(a.T for a in (i_f, psi, zeta, h_r, beta))         # time-major
    final, outs = jax.lax.scan(body, log_w.astype(jnp.float32), xs)
    off, exp_, lp, q, p = (o.T for o in outs)                    # back to (S, TB)
    return final, off, exp_, lp, q, p


def hedge_step_counter_ref(
    log_w: jnp.ndarray, i_f: jnp.ndarray, rng, h_r: jnp.ndarray,
    beta: jnp.ndarray,
    *, eta, eps: float, delta_fp: float, delta_fn: float, decay=1.0,
):
    """Counter-mode oracle: draws (ψ, ζ) from (stream, slot) position via
    the golden counter contract, then runs the pre-draw step oracle."""
    psi, zeta = _counter_draws(rng, log_w.shape[0], 0, eps)
    return hedge_step_ref(
        log_w, i_f, psi, zeta, h_r, beta,
        eta=eta, eps=eps, delta_fp=delta_fp, delta_fn=delta_fn, decay=decay)


def hedge_rounds_counter_ref(
    log_w: jnp.ndarray, i_f: jnp.ndarray, rng, h_r: jnp.ndarray,
    beta: jnp.ndarray,
    *, eta, eps: float, delta_fp: float, delta_fn: float, decay=1.0,
):
    """Counter-mode rounds oracle: round t of the block draws at slot₀ + t.

    The (S, TB) draws here are worklocal to the call — the XLA fallback's
    peak randomness residency, matching the kernel's O(S×TB) contract.
    """
    tb = i_f.shape[1]
    seed, slot0, offset = rng[0], rng[1], rng[2]
    sid = jnp.asarray(offset, jnp.int32) + jnp.arange(
        log_w.shape[0], dtype=jnp.int32)
    slots = jnp.asarray(slot0, jnp.int32) + jnp.arange(tb, dtype=jnp.int32)
    psi, zeta = psi_zeta_from_counter(
        seed, sid[:, None], slots[None, :], eps)
    return hedge_rounds_ref(
        log_w, i_f, psi, zeta.astype(jnp.int32), h_r, beta,
        eta=eta, eps=eps, delta_fp=delta_fp, delta_fn=delta_fn, decay=decay)


def hedge_decide_counter_ref(log_w: jnp.ndarray, i_f: jnp.ndarray, rng,
                             *, eps: float):
    """Counter-mode decide oracle; appends the ψ draw (serving reuses it
    for the capacity-drop local fallback), mirroring the counter kernel."""
    psi, zeta = _counter_draws(rng, log_w.shape[0], 0, eps)
    return hedge_decide_ref(log_w, i_f, psi, zeta) + (psi,)
