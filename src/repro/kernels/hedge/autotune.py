"""(stream_block × time_block) autotuner for the hedge kernel family, with a
persistent per-(G, S, platform) JSON cache under `results/`.

The multi-round kernel's launch geometry has two knobs: SB (streams per
program instance — VMEM residency per launch) and TB (sequential rounds per
launch — HBM round-trips amortized per weight block). The best pair depends
on the expert-grid side G, the fleet size S, and the backend (CPU interpret
timings are NOT predictive for TPU — which is exactly why the cache is
keyed by platform and ships per-platform entries).

Workflow:

    # sweep and persist (CI nightly runs the --quick variant):
    PYTHONPATH=src python -m benchmarks.run --only kernels --autotune

    # consult (what ops.py does automatically when stream_block=None):
    from repro.kernels.hedge import autotune
    autotune.best_blocks(g=16, s=64)     # -> (stream_block, time_block)

Cache location: `results/hedge_autotune.json` at the repo root, overridable
via $REPRO_HEDGE_AUTOTUNE_CACHE (tests point it at a tmpdir). Lookups are
mtime-invalidated, so a rewritten cache is re-read on the next lookup —
but note the ops consult it at jit TRACE time: (cfg, shape) combinations a
process has already traced keep their launch geometry until new shapes
arrive or the process restarts.
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

DEFAULT_STREAM_BLOCK = 8
DEFAULT_TIME_BLOCK = 8
_ENV_VAR = "REPRO_HEDGE_AUTOTUNE_CACHE"


def cache_path() -> str:
    env = os.environ.get(_ENV_VAR)
    if env:
        return env
    root = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", "..", ".."))
    return os.path.join(root, "results", "hedge_autotune.json")


def _entry_key(g: int, s: int, platform: str,
               randomness: str = "pre_draw") -> str:
    """Cache key: platform/G<grid>/S<streams>/<randomness>.

    Keyed by randomness mode because the two modes have different kernel
    bodies (counter mode trades the (SB, TB) ψ/ζ HBM reads for 20 rounds of
    in-register mixing per draw) — a counter-mode winner must not be applied
    to pre-draw runs, and vice versa. `lookup` still falls back to the
    pre-mode legacy key (no suffix) for pre_draw, so committed caches keep
    working.
    """
    return f"{platform}/G{g}/S{s}/{randomness}"


def _legacy_entry_key(g: int, s: int, platform: str) -> str:
    """Pre-randomness-mode key shape; consulted as a pre_draw fallback."""
    return f"{platform}/G{g}/S{s}"


@functools.lru_cache(maxsize=None)
def _load(path: str, mtime: float) -> Dict[str, dict]:
    # mtime participates in the cache key purely to invalidate on rewrite.
    del mtime
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError):
        return {}
    entries = doc.get("entries", {})
    return entries if isinstance(entries, dict) else {}


def load_cache(path: Optional[str] = None) -> Dict[str, dict]:
    """The cache's entries dict ({} when the file is missing/corrupt)."""
    path = cache_path() if path is None else path
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        return {}
    return _load(path, mtime)


def lookup(g: int, s: int, platform: Optional[str] = None,
           path: Optional[str] = None,
           randomness: str = "pre_draw") -> Optional[dict]:
    """The cached best-(SB, TB) record for (G, S, platform, mode), or None.

    pre_draw lookups fall back to the legacy (mode-less) key so caches
    written before randomness modes existed stay valid; counter-mode
    lookups never do — legacy winners were measured on pre-draw kernels.
    """
    platform = jax.default_backend() if platform is None else platform
    cache = load_cache(path)
    rec = cache.get(_entry_key(g, s, platform, randomness))
    if rec is None and randomness == "pre_draw":
        rec = cache.get(_legacy_entry_key(g, s, platform))
    return rec


def best_blocks(g: int, s: int, platform: Optional[str] = None,
                randomness: str = "pre_draw") -> Tuple[int, int]:
    """(stream_block, time_block) — cached winner, or the static defaults.

    Tolerant of partial entries (hand-edited or older-format caches): a
    missing field falls back to its default rather than crashing the
    serving hot path over an advisory performance cache.
    """
    rec = lookup(g, s, platform, randomness=randomness)
    if rec is None:
        return DEFAULT_STREAM_BLOCK, DEFAULT_TIME_BLOCK
    try:
        return (int(rec.get("stream_block", DEFAULT_STREAM_BLOCK)),
                int(rec.get("time_block", DEFAULT_TIME_BLOCK)))
    except (TypeError, ValueError):
        return DEFAULT_STREAM_BLOCK, DEFAULT_TIME_BLOCK


def best_stream_block(g: int, s: int, platform: Optional[str] = None,
                      randomness: str = "pre_draw") -> int:
    return best_blocks(g, s, platform, randomness)[0]


def best_time_block(g: int, s: int, platform: Optional[str] = None,
                    randomness: str = "pre_draw") -> int:
    return best_blocks(g, s, platform, randomness)[1]


def _measure_rounds_us(cfg, s: int, sb: int, tb: int, interpret: bool,
                       reps: int, randomness: str = "pre_draw") -> float:
    """µs per H2T2 round of one multi-round launch chain at (SB, TB)."""
    from repro.core.counter import counter_rng
    from repro.core.execspec import ExecSpec
    from repro.kernels.hedge.ops import fleet_hedge_rounds

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    logw = jnp.where(
        jnp.arange(cfg.grid)[:, None] <= jnp.arange(cfg.grid)[None, :],
        0.0, -1e30)[None].repeat(s, 0).astype(jnp.float32)
    data = (jax.random.uniform(ks[0], (s, tb)),
            jax.random.bernoulli(ks[3], 0.5, (s, tb)).astype(jnp.int32),
            jax.random.uniform(ks[4], (s, tb), maxval=0.6))
    if randomness == "counter":
        kw = dict(rng=counter_rng(jax.random.PRNGKey(0), 0))
        args = (logw, data[0], None, None) + data[1:]
    else:
        kw = {}
        args = (logw, data[0],
                jax.random.uniform(ks[1], (s, tb)),
                jax.random.bernoulli(ks[2], cfg.eps,
                                     (s, tb)).astype(jnp.int32)) + data[1:]

    spec = ExecSpec(use_kernel=True, interpret=interpret,
                    stream_block=sb, randomness=randomness)

    def fn():
        return fleet_hedge_rounds(cfg, *args, spec=spec, **kw)

    jax.block_until_ready(fn())                       # compile outside timing
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps / tb * 1e6


def sweep(
    grids: Sequence[int] = (8, 16),
    streams: Sequence[int] = (16, 64),
    stream_blocks: Sequence[int] = (1, 2, 4, 8, 16),
    time_blocks: Sequence[int] = (1, 2, 4, 8, 16),
    *,
    reps: int = 3,
    interpret: Optional[bool] = None,
    path: Optional[str] = None,
    write: bool = True,
    randomness: str = "pre_draw",
) -> Dict[str, dict]:
    """Time every (SB ≤ S) × TB pair per (G, S); persist the winners.

    Returns the new entries (keyed like the cache). With `write=True`
    (default) they are merged into the JSON cache at `path`, preserving
    other platforms' entries.
    """
    import math

    from repro.core.types import HIConfig
    from repro.kernels.hedge.ops import _interpret_default

    platform = jax.default_backend()
    interp = _interpret_default() if interpret is None else interpret
    entries: Dict[str, dict] = {}
    for g in grids:
        cfg = HIConfig(bits=int(math.log2(g)))
        assert cfg.grid == g, f"grid {g} must be a power of two"
        for s in streams:
            best = None
            measured = {}
            # The kernels cap SB at S anyway, so clamp (and dedupe) rather
            # than dropping candidates — stream_blocks larger than a small
            # fleet must not leave the sweep empty.
            for sb in sorted({min(b, s) for b in stream_blocks}):
                for tb in time_blocks:
                    us = _measure_rounds_us(cfg, s, sb, tb, interp, reps,
                                            randomness)
                    measured[f"sb{sb}_tb{tb}"] = round(us, 3)
                    if best is None or us < best[0]:
                        best = (us, sb, tb)
            us, sb, tb = best
            entries[_entry_key(g, s, platform, randomness)] = {
                "stream_block": sb,
                "time_block": tb,
                "us_per_round": round(us, 3),
                "interpret": bool(interp),
                "randomness": randomness,
                "measured": measured,
            }
    if write:
        write_cache(entries, path)
    return entries


def write_cache(entries: Dict[str, dict], path: Optional[str] = None) -> str:
    """Merge `entries` into the JSON cache (other keys preserved)."""
    path = cache_path() if path is None else path
    merged = dict(load_cache(path))
    merged.update(entries)
    doc = {
        "format": "hedge-autotune-v1",
        "note": ("best (stream_block, time_block) per platform/G<grid>/"
                 "S<streams>/<randomness>; legacy mode-less keys are read "
                 "as pre_draw. interpret-mode (CPU) timings are not "
                 "predictive for TPU — entries are consulted per-platform "
                 "only. Refresh: benchmarks.run --only kernels --autotune"),
        "entries": {k: merged[k] for k in sorted(merged)},
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")
    os.replace(tmp, path)
    return path


def rows(entries: Dict[str, dict]) -> List[str]:
    """Benchmark-harness CSV rows for a sweep's entries (timings only — the
    regression gate never compares `*_us` metrics)."""
    out = []
    for key in sorted(entries):
        rec = entries[key]
        name = "hedge_autotune_" + key.replace("/", "_")
        out.append(
            f"{name},{rec['us_per_round']:.1f},"
            f"stream_block={rec['stream_block']};"
            f"time_block={rec['time_block']};"
            f"best_us={rec['us_per_round']:.3f}")
    return out
