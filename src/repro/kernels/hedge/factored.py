"""Factored per-threshold hedge learner: O(S·G) state, O(G) reduces.

The dense kernels keep a (G, G) expert grid per stream; this module is
the reduced-complexity alternative the learner registry
(`repro.core.learners`) exposes as ``learner="factored"``. Per stream
the state is (2, G): row 0 holds log-weights over the *lower* threshold
index, row 1 over the *upper* index. Region masses come from the
product distribution restricted to the valid l ≤ u triangle — all three
are O(G) via one cumulative sum over the lower axis:

    total = Σ_u wu[u] · cl[u]            cl[u] = Σ_{l ≤ u} wl[l]
    r3    = Σ_{u ≤ i_f} wu[u] · cl[u]    (predict-1 mass)
    r2    = cl[i_f] · Σ_{u > i_f} wu[u]  (ambiguous mass)

so `q = r2/total` and `p = r3/total` feed the exact dense decision
rules (offload iff ψ ≤ q or ζ; local_pred = [ψ ≤ q+p]).

Feedback updates each axis against the Eq.-10 pseudo-loss with the
*other* axis marginalized under its current distribution: a lower index
l ≤ i_f sits in r2 with probability P(u > i_f) (→ β on offload) and in
r3 otherwise (→ δ_fp/ε on exploration), etc. Each (G,) row is
decay/η-updated and renormalized by its own max, exactly like the dense
grid.

Layout mirrors `ref.py` + `kernel.py`: `*_ref` functions are the jnp
oracles (the XLA fallback), `*_pallas` the Pallas launches over
(SB, 2, G) stream blocks, with counter-randomness twins that draw
(ψ, ζ) in-kernel from the same position-keyed threefry contract as the
dense kernels — so switching learners never changes the draws. The
kernel bodies call the same `_decide_core`/`_feedback_core` as the
oracles, which is what makes interpret-mode runs bit-identical to the
refs. Exported names follow the uniform learner-ops protocol
`repro.kernels.hedge.ops` dispatches on: `step_ref`, `rounds_ref`,
`decide_ref`, `feedback_ref`, their `*_counter_ref` twins, and the
matching `*_pallas` set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.counter import psi_zeta_from_counter
from repro.kernels.hedge.kernel import (
    _block_stream_ids,
    _block_streams,
    _counter_psi_zeta,
    _pad_streams,
    _rng_spec,
    _rng_words,
    _sched_vec,
    pack_counter_rng,
)
from repro.kernels.hedge.ref import _counter_draws

TINY = 1e-38


def _axis_idx(g: int):
    """(1, G) int32 iota over the threshold axis (2-D for TPU lowering)."""
    return jax.lax.broadcasted_iota(jnp.int32, (1, g), 1)


def _axis_weights(lv):
    """Max-shifted weights of one (S, G) log-weight row (safe exp)."""
    return jnp.exp(lv - jnp.max(lv, axis=-1, keepdims=True))


def _decide_core(log_w, i_f, psi, zeta, g: int):
    """Region masses + decisions for an (S, 2, G) block; shared verbatim by
    the jnp oracle and the Pallas kernel bodies (interpret-mode
    bit-identity)."""
    wl = _axis_weights(log_w[:, 0, :].astype(jnp.float32))
    wu = _axis_weights(log_w[:, 1, :].astype(jnp.float32))
    cl = jnp.cumsum(wl, axis=-1)                   # cl[u] = Σ_{l<=u} wl[l]
    idx = _axis_idx(g)
    i_b = i_f[:, None]
    le = idx <= i_b
    s_tot = jnp.sum(wu * cl, axis=-1)
    s_r3 = jnp.sum(jnp.where(le, wu * cl, 0.0), axis=-1)
    cl_if = jnp.sum(jnp.where(idx == i_b, cl, 0.0), axis=-1)
    wu_gt = jnp.sum(jnp.where(le, 0.0, wu), axis=-1)
    tot = jnp.maximum(s_tot, TINY)
    q = cl_if * wu_gt / tot
    p = s_r3 / tot
    in_r2 = psi <= q
    offload = in_r2 | (zeta != 0)
    explored = (zeta != 0) & ~in_r2
    local_pred = (psi <= q + p).astype(jnp.int32)
    return (offload.astype(jnp.int32), explored.astype(jnp.int32), local_pred,
            q.astype(jnp.float32), p.astype(jnp.float32))


def _feedback_core(log_w, i_f, sent, explored, h_r, beta, eta, decay, g: int,
                   *, eps: float, delta_fp: float, delta_fn: float):
    """Per-axis Eq.-10 update with the other axis marginalized; (η, decay)
    arrive as (S,) vectors. Returns the renormalized (S, 2, G) state."""
    lv_l = log_w[:, 0, :].astype(jnp.float32)
    lv_u = log_w[:, 1, :].astype(jnp.float32)
    wl = _axis_weights(lv_l)
    wu = _axis_weights(lv_u)
    cl = jnp.cumsum(wl, axis=-1)
    cu = jnp.cumsum(wu, axis=-1)
    idx = _axis_idx(g)
    i_b = i_f[:, None]
    at = idx == i_b
    cl_if = jnp.sum(jnp.where(at, cl, 0.0), axis=-1)
    cu_if = jnp.sum(jnp.where(at, cu, 0.0), axis=-1)
    sum_l = jnp.maximum(jnp.sum(wl, axis=-1), TINY)
    sum_u = jnp.maximum(jnp.sum(wu, axis=-1), TINY)
    p_l_le = cl_if / sum_l                         # P(l <= i_f)
    p_u_gt = (sum_u - cu_if) / sum_u               # P(u >  i_f)
    phi_fp = jnp.where(h_r == 0, delta_fp, 0.0).astype(jnp.float32)
    phi_fn = jnp.where(h_r == 1, delta_fn, 0.0).astype(jnp.float32)
    sent_f = (sent != 0).astype(jnp.float32)
    expl_f = (explored != 0).astype(jnp.float32) * jnp.float32(1.0 / eps)
    beta_f = beta.astype(jnp.float32)
    # Lower axis: l <= i_f is ambiguous w.p. P(u > i_f), predict-1 otherwise;
    # l > i_f is always predict-0 (r1) on the valid triangle.
    amb_l = sent_f * beta_f * p_u_gt + expl_f * phi_fp * (1.0 - p_u_gt)
    lt_l = jnp.where(idx <= i_b, amb_l[:, None], (expl_f * phi_fn)[:, None])
    # Upper axis: u > i_f is ambiguous w.p. P(l <= i_f), predict-0 otherwise;
    # u <= i_f is always predict-1 (r3).
    amb_u = sent_f * beta_f * p_l_le + expl_f * phi_fn * (1.0 - p_l_le)
    lt_u = jnp.where(idx > i_b, amb_u[:, None], (expl_f * phi_fp)[:, None])
    new_l = decay[:, None] * lv_l - eta[:, None] * lt_l
    new_u = decay[:, None] * lv_u - eta[:, None] * lt_u
    new_l = new_l - jnp.max(new_l, axis=-1, keepdims=True)
    new_u = new_u - jnp.max(new_u, axis=-1, keepdims=True)
    return jnp.stack([new_l, new_u], axis=1).astype(jnp.float32)


# ---------------------------------------------------------------------------
# jnp oracles (mirror ref.py's signatures exactly)
# ---------------------------------------------------------------------------


def decide_ref(log_w, i_f, psi, zeta):
    """(offload, explored, local_pred, q, p) from an (S, 2, G) state."""
    return _decide_core(log_w, i_f, psi, zeta, log_w.shape[-1])


def feedback_ref(log_w, i_f, sent, explored, h_r, beta, eta, decay,
                 *, eps: float, delta_fp: float, delta_fn: float):
    s, _, g = log_w.shape
    return _feedback_core(
        log_w, i_f, sent, explored, h_r, beta,
        _sched_vec(eta, s), _sched_vec(decay, s), g,
        eps=eps, delta_fp=delta_fp, delta_fn=delta_fn)


def step_ref(log_w, i_f, psi, zeta, h_r, beta,
             *, eta, eps: float, delta_fp: float, delta_fn: float, decay=1.0):
    off, exp_, lp, q, p = decide_ref(log_w, i_f, psi, zeta)
    new = feedback_ref(
        log_w, i_f, off, exp_, h_r, beta, eta, decay,
        eps=eps, delta_fp=delta_fp, delta_fn=delta_fn)
    return new, off, exp_, lp, q, p


def rounds_ref(log_w, i_f, psi, zeta, h_r, beta,
               *, eta, eps: float, delta_fp: float, delta_fn: float,
               decay=1.0):
    """Scan `step_ref` over the (S, TB) block, schedule held fixed."""

    def body(lw, xs):
        new, off, exp_, lp, q, p = step_ref(
            lw, *xs, eta=eta, eps=eps, delta_fp=delta_fp, delta_fn=delta_fn,
            decay=decay)
        return new, (off, exp_, lp, q, p)

    xs = tuple(a.T for a in (i_f, psi, zeta, h_r, beta))         # time-major
    final, outs = jax.lax.scan(body, log_w.astype(jnp.float32), xs)
    off, exp_, lp, q, p = (o.T for o in outs)                    # back to (S, TB)
    return final, off, exp_, lp, q, p


def step_counter_ref(log_w, i_f, rng, h_r, beta,
                     *, eta, eps: float, delta_fp: float, delta_fn: float,
                     decay=1.0):
    psi, zeta = _counter_draws(rng, log_w.shape[0], 0, eps)
    return step_ref(
        log_w, i_f, psi, zeta, h_r, beta,
        eta=eta, eps=eps, delta_fp=delta_fp, delta_fn=delta_fn, decay=decay)


def rounds_counter_ref(log_w, i_f, rng, h_r, beta,
                       *, eta, eps: float, delta_fp: float, delta_fn: float,
                       decay=1.0):
    tb = i_f.shape[1]
    seed, slot0, offset = rng[0], rng[1], rng[2]
    sid = jnp.asarray(offset, jnp.int32) + jnp.arange(
        log_w.shape[0], dtype=jnp.int32)
    slots = jnp.asarray(slot0, jnp.int32) + jnp.arange(tb, dtype=jnp.int32)
    psi, zeta = psi_zeta_from_counter(seed, sid[:, None], slots[None, :], eps)
    return rounds_ref(
        log_w, i_f, psi, zeta.astype(jnp.int32), h_r, beta,
        eta=eta, eps=eps, delta_fp=delta_fp, delta_fn=delta_fn, decay=decay)


def decide_counter_ref(log_w, i_f, rng, *, eps: float):
    """Counter-mode decide oracle; appends the ψ draw like the dense one."""
    psi, zeta = _counter_draws(rng, log_w.shape[0], 0, eps)
    return decide_ref(log_w, i_f, psi, zeta) + (psi,)


# ---------------------------------------------------------------------------
# Pallas kernels: the factored decide/feedback pair (+ counter twins)
# ---------------------------------------------------------------------------


def decide_kernel(log_w_ref, i_f_ref, psi_ref, zeta_ref,
                  offload_ref, explored_ref, local_pred_ref, q_ref, p_ref,
                  *, grid_side: int):
    off, exp_, lp, q, p = _decide_core(
        log_w_ref[...], i_f_ref[...], psi_ref[...], zeta_ref[...], grid_side)
    offload_ref[...] = off
    explored_ref[...] = exp_
    local_pred_ref[...] = lp
    q_ref[...] = q
    p_ref[...] = p


def decide_counter_kernel(log_w_ref, i_f_ref, rng_ref,
                          offload_ref, explored_ref, local_pred_ref,
                          q_ref, p_ref, psi_ref,
                          *, grid_side: int, stream_block: int, eps: float):
    seed0, seed1, slot, offset = _rng_words(rng_ref)
    sid = _block_stream_ids(offset, stream_block)
    psi, zeta = _counter_psi_zeta(seed0, seed1, sid, slot, eps)
    off, exp_, lp, q, p = _decide_core(
        log_w_ref[...], i_f_ref[...], psi, zeta, grid_side)
    offload_ref[...] = off
    explored_ref[...] = exp_
    local_pred_ref[...] = lp
    q_ref[...] = q
    p_ref[...] = p
    psi_ref[...] = psi.astype(jnp.float32)


def feedback_kernel(log_w_ref, i_f_ref, sent_ref, explored_ref, h_r_ref,
                    beta_ref, eta_ref, decay_ref, out_ref,
                    *, grid_side: int, eps: float, delta_fp: float,
                    delta_fn: float):
    out_ref[...] = _feedback_core(
        log_w_ref[...], i_f_ref[...], sent_ref[...], explored_ref[...],
        h_r_ref[...], beta_ref[...], eta_ref[...], decay_ref[...], grid_side,
        eps=eps, delta_fp=delta_fp, delta_fn=delta_fn)


def _state_spec(sb: int, g: int):
    return pl.BlockSpec((sb, 2, g), lambda i: (i, 0, 0))


def decide_pallas(log_w, i_f, psi, zeta, *,
                  stream_block: int = 8, interpret: bool = True):
    """Factored serving decide: (offload, explored, local_pred, q, p)."""
    s, _, g = log_w.shape
    sb, s_pad, pad = _block_streams(s, stream_block)
    kern = functools.partial(decide_kernel, grid_side=g)
    vec = lambda: pl.BlockSpec((sb,), lambda i: (i,))
    out_shapes = (
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.float32),
        jax.ShapeDtypeStruct((s_pad,), jnp.float32),
    )
    args = _pad_streams(pad, log_w, i_f, psi, zeta)
    out = pl.pallas_call(
        kern,
        grid=(s_pad // sb,),
        in_specs=[_state_spec(sb, g), vec(), vec(), vec()],
        out_specs=(vec(), vec(), vec(), vec(), vec()),
        out_shape=out_shapes,
        interpret=interpret,
    )(*args)
    return tuple(o[:s] for o in out)


def decide_counter_pallas(log_w, i_f, rng, *, eps: float,
                          stream_block: int = 8, interpret: bool = True):
    """Counter-mode factored decide; appends the in-kernel ψ draw."""
    s, _, g = log_w.shape
    sb, s_pad, pad = _block_streams(s, stream_block)
    kern = functools.partial(
        decide_counter_kernel, grid_side=g, stream_block=sb, eps=eps)
    vec = lambda: pl.BlockSpec((sb,), lambda i: (i,))
    out_shapes = (
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        jax.ShapeDtypeStruct((s_pad,), jnp.float32),
        jax.ShapeDtypeStruct((s_pad,), jnp.float32),
        jax.ShapeDtypeStruct((s_pad,), jnp.float32),
    )
    padded = _pad_streams(pad, log_w, i_f)
    args = padded + (pack_counter_rng(rng),)
    out = pl.pallas_call(
        kern,
        grid=(s_pad // sb,),
        in_specs=[_state_spec(sb, g), vec(), _rng_spec()],
        out_specs=(vec(), vec(), vec(), vec(), vec(), vec()),
        out_shape=out_shapes,
        interpret=interpret,
    )(*args)
    return tuple(o[:s] for o in out)


def feedback_pallas(log_w, i_f, sent, explored, h_r, beta, eta, decay, *,
                    eps: float, delta_fp: float, delta_fn: float,
                    stream_block: int = 8, interpret: bool = True):
    """Factored serving feedback: the per-axis Eq.-10 update only."""
    s, _, g = log_w.shape
    sb, s_pad, pad = _block_streams(s, stream_block)
    kern = functools.partial(
        feedback_kernel, grid_side=g, eps=eps,
        delta_fp=delta_fp, delta_fn=delta_fn)
    vec = lambda: pl.BlockSpec((sb,), lambda i: (i,))
    args = _pad_streams(pad, log_w, i_f, sent, explored, h_r, beta,
                        _sched_vec(eta, s), _sched_vec(decay, s))
    out = pl.pallas_call(
        kern,
        grid=(s_pad // sb,),
        in_specs=[_state_spec(sb, g),
                  vec(), vec(), vec(), vec(), vec(), vec(), vec()],
        out_specs=_state_spec(sb, g),
        out_shape=jax.ShapeDtypeStruct((s_pad, 2, g), jnp.float32),
        interpret=interpret,
    )(*args)
    return out[:s]


def step_pallas(log_w, i_f, psi, zeta, h_r, beta, eta, decay, *,
                eps: float, delta_fp: float, delta_fn: float,
                stream_block: int = 8, interpret: bool = True):
    """One factored round = the decide kernel + the feedback kernel (the
    state is O(S·G), so there is no fused-grid win to chase)."""
    off, exp_, lp, q, p = decide_pallas(
        log_w, i_f, psi, zeta, stream_block=stream_block, interpret=interpret)
    new = feedback_pallas(
        log_w, i_f, off, exp_, h_r, beta, eta, decay,
        eps=eps, delta_fp=delta_fp, delta_fn=delta_fn,
        stream_block=stream_block, interpret=interpret)
    return new, off, exp_, lp, q, p


def rounds_pallas(log_w, i_f, psi, zeta, h_r, beta, eta, decay, *,
                  eps: float, delta_fp: float, delta_fn: float,
                  stream_block: int = 8, interpret: bool = True):
    """TB sequential factored rounds: scan of the kernel-pair step."""

    def body(lw, xs):
        new, off, exp_, lp, q, p = step_pallas(
            lw, *xs, eta, decay, eps=eps, delta_fp=delta_fp,
            delta_fn=delta_fn, stream_block=stream_block, interpret=interpret)
        return new, (off, exp_, lp, q, p)

    xs = tuple(a.T for a in (i_f, psi, zeta, h_r, beta))
    final, outs = jax.lax.scan(body, log_w.astype(jnp.float32), xs)
    off, exp_, lp, q, p = (o.T for o in outs)
    return final, off, exp_, lp, q, p


def step_counter_pallas(log_w, i_f, rng, h_r, beta, eta, decay, *,
                        eps: float, delta_fp: float, delta_fn: float,
                        stream_block: int = 8, interpret: bool = True):
    """Counter-mode factored round: in-kernel draws in decide, then the
    feedback kernel on the resulting masks."""
    off, exp_, lp, q, p, _psi = decide_counter_pallas(
        log_w, i_f, rng, eps=eps, stream_block=stream_block,
        interpret=interpret)
    new = feedback_pallas(
        log_w, i_f, off, exp_, h_r, beta, eta, decay,
        eps=eps, delta_fp=delta_fp, delta_fn=delta_fn,
        stream_block=stream_block, interpret=interpret)
    return new, off, exp_, lp, q, p


def rounds_counter_pallas(log_w, i_f, rng, h_r, beta, eta, decay, *,
                          eps: float, delta_fp: float, delta_fn: float,
                          stream_block: int = 8, interpret: bool = True):
    """TB counter-mode rounds: round t draws at slot₀ + t, never holding
    more than the (S,) working set of one slot's randomness."""
    seed, slot0, offset = rng[0], rng[1], rng[2]

    def body(lw, xs):
        t, i_f_t, h_r_t, beta_t = xs
        rng_t = (seed, jnp.asarray(slot0, jnp.int32) + t, offset)
        new, off, exp_, lp, q, p = step_counter_pallas(
            lw, i_f_t, rng_t, h_r_t, beta_t, eta, decay,
            eps=eps, delta_fp=delta_fp, delta_fn=delta_fn,
            stream_block=stream_block, interpret=interpret)
        return new, (off, exp_, lp, q, p)

    tb = i_f.shape[1]
    xs = (jnp.arange(tb, dtype=jnp.int32),
          i_f.T, h_r.T, beta.T)
    final, outs = jax.lax.scan(body, log_w.astype(jnp.float32), xs)
    off, exp_, lp, q, p = (o.T for o in outs)
    return final, off, exp_, lp, q, p
