"""jit'd public wrappers for the fused hedge kernels: the monolithic
single-/multi-round steps and the serving decide/feedback split.

Every op takes the (η, decay) schedule as optional per-stream (S,) arrays
(None → the HIConfig scalars, broadcast — bit-identical to the fixed paper
schedule) and a `stream_block` override (None → consult the persistent
autotune cache, `kernels.hedge.autotune`, falling back to its static
default).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.types import HIConfig
from repro.kernels.hedge import autotune
from repro.kernels.hedge.kernel import (
    hedge_decide_pallas,
    hedge_feedback_pallas,
    hedge_rounds_pallas,
    hedge_step_pallas,
)
from repro.kernels.hedge.ref import (
    hedge_decide_ref,
    hedge_feedback_ref,
    hedge_rounds_ref,
    hedge_step_ref,
)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def kernel_available() -> bool:
    """True when the compiled Pallas kernel (not interpret mode) can run."""
    return jax.default_backend() == "tpu"


def _loss_kw(cfg: HIConfig) -> dict:
    return dict(eps=cfg.eps, delta_fp=cfg.delta_fp, delta_fn=cfg.delta_fn)


def _sched(cfg: HIConfig, eta, decay):
    """Resolve the schedule: HIConfig scalars where not overridden."""
    return (cfg.eta if eta is None else eta,
            cfg.decay if decay is None else decay)


def _stream_block(stream_block, g: int, s: int) -> int:
    """Static launch geometry: explicit override, else the autotune cache.

    Called at trace time (shapes are concrete), so the cache lookup is pure
    Python and free at execution time — which also means a (cfg, shape)
    combo this process already traced keeps its geometry even if the cache
    file is rewritten (jit never re-traces identical static args).
    """
    if stream_block is not None:
        return int(stream_block)
    return autotune.best_stream_block(g, s)


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel", "interpret",
                                             "stream_block"))
def fleet_hedge_step(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G)
    f: jnp.ndarray,          # (S,) confidences in [0, 1]
    psi: jnp.ndarray,        # (S,) uniforms
    zeta: jnp.ndarray,       # (S,) bernoulli(ε) draws
    h_r: jnp.ndarray,        # (S,) remote labels
    beta: jnp.ndarray,       # (S,) offload costs
    use_kernel: bool = True,
    interpret: bool = None,
    eta: jnp.ndarray = None,     # (S,) per-stream η; None → cfg.eta
    decay: jnp.ndarray = None,   # (S,) per-stream decay; None → cfg.decay
    stream_block: int = None,    # None → autotune cache default
):
    """One H2T2 round for a whole fleet of streams."""
    g = cfg.grid
    i_f = jnp.clip((f * g).astype(jnp.int32), 0, g - 1)
    eta, decay = _sched(cfg, eta, decay)
    if use_kernel:
        interp = _interpret_default() if interpret is None else interpret
        return hedge_step_pallas(
            log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
            zeta.astype(jnp.int32), h_r.astype(jnp.int32),
            beta.astype(jnp.float32), eta, decay, interpret=interp,
            stream_block=_stream_block(stream_block, g, log_w.shape[0]),
            **_loss_kw(cfg))
    return hedge_step_ref(
        log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
        zeta.astype(jnp.int32), h_r.astype(jnp.int32),
        beta.astype(jnp.float32), eta=eta, decay=decay, **_loss_kw(cfg))


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel", "interpret",
                                             "stream_block"))
def fleet_hedge_rounds(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G)
    f: jnp.ndarray,          # (S, TB) confidences in [0, 1]
    psi: jnp.ndarray,        # (S, TB) uniforms
    zeta: jnp.ndarray,       # (S, TB) bernoulli(ε) draws
    h_r: jnp.ndarray,        # (S, TB) remote labels
    beta: jnp.ndarray,       # (S, TB) offload costs
    use_kernel: bool = True,
    interpret: bool = None,
    eta: jnp.ndarray = None,     # (S,) per-stream η; None → cfg.eta
    decay: jnp.ndarray = None,   # (S,) per-stream decay; None → cfg.decay
    stream_block: int = None,    # None → autotune cache default
):
    """TB sequential H2T2 rounds for a whole fleet in one launch.

    Step-for-step identical to TB chained `fleet_hedge_step` calls (with the
    schedule held fixed across the block); on TPU the expert grids stay in
    VMEM for the whole time block.
    """
    g = cfg.grid
    i_f = jnp.clip((f * g).astype(jnp.int32), 0, g - 1)
    eta, decay = _sched(cfg, eta, decay)
    if use_kernel:
        interp = _interpret_default() if interpret is None else interpret
        return hedge_rounds_pallas(
            log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
            zeta.astype(jnp.int32), h_r.astype(jnp.int32),
            beta.astype(jnp.float32), eta, decay, interpret=interp,
            stream_block=_stream_block(stream_block, g, log_w.shape[0]),
            **_loss_kw(cfg))
    return hedge_rounds_ref(
        log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
        zeta.astype(jnp.int32), h_r.astype(jnp.int32),
        beta.astype(jnp.float32), eta=eta, decay=decay, **_loss_kw(cfg))


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel", "interpret",
                                             "stream_block"))
def fleet_hedge_decide(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G)
    f: jnp.ndarray,          # (S,) confidences in [0, 1]
    psi: jnp.ndarray,        # (S,) uniforms
    zeta: jnp.ndarray,       # (S,) bernoulli(ε) draws
    use_kernel: bool = True,
    interpret: bool = None,
    stream_block: int = None,    # None → autotune cache default
):
    """Serving phase 1 for the fleet: quantize + region masses + decisions.

    Returns (i_f, offload, explored, local_pred, q, p) — everything
    `core.policy.FleetDecision` needs except the caller-held ψ. No weight
    write: feedback waits for the (delayed, possibly capacity-dropped)
    remote labels in `fleet_hedge_feedback`.
    """
    g = cfg.grid
    i_f = jnp.clip((f * g).astype(jnp.int32), 0, g - 1)
    if use_kernel:
        interp = _interpret_default() if interpret is None else interpret
        out = hedge_decide_pallas(
            log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
            zeta.astype(jnp.int32), interpret=interp,
            stream_block=_stream_block(stream_block, g, log_w.shape[0]))
    else:
        out = hedge_decide_ref(
            log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
            zeta.astype(jnp.int32))
    return (i_f,) + tuple(out)


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel", "interpret",
                                             "stream_block"))
def fleet_hedge_feedback(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G)
    i_f: jnp.ndarray,        # (S,) decision-time quantized confidence
    sent: jnp.ndarray,       # (S,) offloads that reached the RDL
    explored: jnp.ndarray,   # (S,) exploration flag, already ∧ sent
    h_r: jnp.ndarray,        # (S,) remote labels
    beta: jnp.ndarray,       # (S,) decision-time offload costs
    use_kernel: bool = True,
    interpret: bool = None,
    eta: jnp.ndarray = None,     # (S,) per-stream η; None → cfg.eta
    decay: jnp.ndarray = None,   # (S,) per-stream decay; None → cfg.decay
    stream_block: int = None,    # None → autotune cache default
):
    """Serving phase 2 for the fleet: the Eq.-10 weight update only.

    The cheap (S,) loss/prediction accounting lives in
    `core.policy.fleet_feedback`, which routes its (S, G, G) weight traffic
    here when `use_kernel` resolves true.
    """
    g = cfg.grid
    eta, decay = _sched(cfg, eta, decay)
    if use_kernel:
        interp = _interpret_default() if interpret is None else interpret
        return hedge_feedback_pallas(
            log_w.astype(jnp.float32), i_f.astype(jnp.int32),
            sent.astype(jnp.int32), explored.astype(jnp.int32),
            h_r.astype(jnp.int32), beta.astype(jnp.float32), eta, decay,
            interpret=interp,
            stream_block=_stream_block(stream_block, g, log_w.shape[0]),
            **_loss_kw(cfg))
    return hedge_feedback_ref(
        log_w.astype(jnp.float32), i_f.astype(jnp.int32),
        sent.astype(jnp.int32), explored.astype(jnp.int32),
        h_r.astype(jnp.int32), beta.astype(jnp.float32), eta, decay,
        **_loss_kw(cfg))
