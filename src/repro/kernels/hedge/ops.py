"""jit'd public wrapper for the fused hedge kernel."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import HIConfig
from repro.kernels.hedge.kernel import hedge_step_pallas
from repro.kernels.hedge.ref import hedge_step_ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel", "interpret"))
def fleet_hedge_step(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G)
    f: jnp.ndarray,          # (S,) confidences in [0, 1]
    psi: jnp.ndarray,        # (S,) uniforms
    zeta: jnp.ndarray,       # (S,) bernoulli(ε) draws
    h_r: jnp.ndarray,        # (S,) remote labels
    beta: jnp.ndarray,       # (S,) offload costs
    use_kernel: bool = True,
    interpret: bool = None,
):
    """One H2T2 round for a whole fleet of streams."""
    g = cfg.grid
    i_f = jnp.clip((f * g).astype(jnp.int32), 0, g - 1)
    kw = dict(eta=cfg.eta, eps=cfg.eps, delta_fp=cfg.delta_fp, delta_fn=cfg.delta_fn)
    if use_kernel:
        interp = _interpret_default() if interpret is None else interpret
        return hedge_step_pallas(
            log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
            zeta.astype(jnp.int32), h_r.astype(jnp.int32),
            beta.astype(jnp.float32), interpret=interp, **kw)
    return hedge_step_ref(
        log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
        zeta.astype(jnp.int32), h_r.astype(jnp.int32),
        beta.astype(jnp.float32), **kw)
