"""jit'd public wrappers for the fused hedge kernels: the monolithic
single-/multi-round steps and the serving decide/feedback split.

Every op takes the (η, decay) schedule as optional per-stream (S,) arrays
(None → the HIConfig scalars, broadcast — bit-identical to the fixed paper
schedule) and a `stream_block` override (None → consult the persistent
autotune cache, `kernels.hedge.autotune`, falling back to its static
default).

The randomness-consuming ops (step/rounds/decide) additionally take
`randomness="pre_draw" | "counter"`: pre_draw (default, the golden paper
path) ships (ψ, ζ) as operands; counter mode takes an `rng`
(seed, slot, stream_offset) position instead and regenerates the draws
in-kernel via the threefry counter contract (`repro.core.counter`) — zero
randomness tensors in memory. The autotune cache is consulted per mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.counter import check_randomness_mode
from repro.core.types import HIConfig
from repro.kernels.hedge import autotune
from repro.kernels.hedge.kernel import (
    hedge_decide_counter_pallas,
    hedge_decide_pallas,
    hedge_feedback_pallas,
    hedge_rounds_counter_pallas,
    hedge_rounds_pallas,
    hedge_step_counter_pallas,
    hedge_step_pallas,
)
from repro.kernels.hedge.ref import (
    hedge_decide_counter_ref,
    hedge_decide_ref,
    hedge_feedback_ref,
    hedge_rounds_counter_ref,
    hedge_rounds_ref,
    hedge_step_counter_ref,
    hedge_step_ref,
)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def kernel_available() -> bool:
    """True when the compiled Pallas kernel (not interpret mode) can run."""
    return jax.default_backend() == "tpu"


def _loss_kw(cfg: HIConfig) -> dict:
    return dict(eps=cfg.eps, delta_fp=cfg.delta_fp, delta_fn=cfg.delta_fn)


def _sched(cfg: HIConfig, eta, decay):
    """Resolve the schedule: HIConfig scalars where not overridden."""
    return (cfg.eta if eta is None else eta,
            cfg.decay if decay is None else decay)


def _stream_block(stream_block, g: int, s: int,
                  randomness: str = "pre_draw") -> int:
    """Static launch geometry: explicit override, else the autotune cache.

    Called at trace time (shapes are concrete), so the cache lookup is pure
    Python and free at execution time — which also means a (cfg, shape)
    combo this process already traced keeps its geometry even if the cache
    file is rewritten (jit never re-traces identical static args). The
    cache is consulted per randomness mode — counter kernels have different
    arithmetic intensity, so their winners are tuned separately.
    """
    if stream_block is not None:
        return int(stream_block)
    return autotune.best_stream_block(g, s, randomness=randomness)


def _check_randomness(randomness: str, psi, zeta, rng) -> None:
    """Trace-time validation of the (mode, operands) pairing."""
    check_randomness_mode(randomness)
    if randomness == "counter":
        if rng is None:
            raise ValueError("randomness='counter' needs an rng "
                             "(seed, slot, stream_offset) triple")
        if psi is not None or zeta is not None:
            raise ValueError("randomness='counter' regenerates (psi, zeta) "
                             "in place — pass them as None")
    elif rng is not None:
        raise ValueError("rng is only meaningful with randomness='counter'")


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel", "interpret",
                                             "stream_block", "randomness"))
def fleet_hedge_step(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G)
    f: jnp.ndarray,          # (S,) confidences in [0, 1]
    psi: jnp.ndarray,        # (S,) uniforms; None in counter mode
    zeta: jnp.ndarray,       # (S,) bernoulli(ε) draws; None in counter mode
    h_r: jnp.ndarray,        # (S,) remote labels
    beta: jnp.ndarray,       # (S,) offload costs
    use_kernel: bool = True,
    interpret: bool = None,
    eta: jnp.ndarray = None,     # (S,) per-stream η; None → cfg.eta
    decay: jnp.ndarray = None,   # (S,) per-stream decay; None → cfg.decay
    stream_block: int = None,    # None → autotune cache default
    randomness: str = "pre_draw",
    rng=None,                    # (seed, slot, stream_offset) — counter mode
):
    """One H2T2 round for a whole fleet of streams.

    With `randomness="counter"` the (ψ, ζ) draws are regenerated from the
    `rng` position instead of passed in — no randomness operands at all.
    """
    _check_randomness(randomness, psi, zeta, rng)
    g = cfg.grid
    i_f = jnp.clip((f * g).astype(jnp.int32), 0, g - 1)
    eta, decay = _sched(cfg, eta, decay)
    sb = _stream_block(stream_block, g, log_w.shape[0], randomness)
    if use_kernel:
        interp = _interpret_default() if interpret is None else interpret
        if randomness == "counter":
            return hedge_step_counter_pallas(
                log_w.astype(jnp.float32), i_f, rng, h_r.astype(jnp.int32),
                beta.astype(jnp.float32), eta, decay, interpret=interp,
                stream_block=sb, **_loss_kw(cfg))
        return hedge_step_pallas(
            log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
            zeta.astype(jnp.int32), h_r.astype(jnp.int32),
            beta.astype(jnp.float32), eta, decay, interpret=interp,
            stream_block=sb, **_loss_kw(cfg))
    if randomness == "counter":
        return hedge_step_counter_ref(
            log_w.astype(jnp.float32), i_f, rng, h_r.astype(jnp.int32),
            beta.astype(jnp.float32), eta=eta, decay=decay, **_loss_kw(cfg))
    return hedge_step_ref(
        log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
        zeta.astype(jnp.int32), h_r.astype(jnp.int32),
        beta.astype(jnp.float32), eta=eta, decay=decay, **_loss_kw(cfg))


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel", "interpret",
                                             "stream_block", "randomness"))
def fleet_hedge_rounds(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G)
    f: jnp.ndarray,          # (S, TB) confidences in [0, 1]
    psi: jnp.ndarray,        # (S, TB) uniforms; None in counter mode
    zeta: jnp.ndarray,       # (S, TB) bernoulli(ε); None in counter mode
    h_r: jnp.ndarray,        # (S, TB) remote labels
    beta: jnp.ndarray,       # (S, TB) offload costs
    use_kernel: bool = True,
    interpret: bool = None,
    eta: jnp.ndarray = None,     # (S,) per-stream η; None → cfg.eta
    decay: jnp.ndarray = None,   # (S,) per-stream decay; None → cfg.decay
    stream_block: int = None,    # None → autotune cache default
    randomness: str = "pre_draw",
    rng=None,                    # (seed, slot₀, stream_offset) — counter mode
):
    """TB sequential H2T2 rounds for a whole fleet in one launch.

    Step-for-step identical to TB chained `fleet_hedge_step` calls (with the
    schedule held fixed across the block); on TPU the expert grids stay in
    VMEM for the whole time block. Counter mode draws round t of the block
    at slot₀ + t — the chain reproduces any other chunking bit-for-bit and
    ships zero randomness operands.
    """
    _check_randomness(randomness, psi, zeta, rng)
    g = cfg.grid
    i_f = jnp.clip((f * g).astype(jnp.int32), 0, g - 1)
    eta, decay = _sched(cfg, eta, decay)
    sb = _stream_block(stream_block, g, log_w.shape[0], randomness)
    if use_kernel:
        interp = _interpret_default() if interpret is None else interpret
        if randomness == "counter":
            return hedge_rounds_counter_pallas(
                log_w.astype(jnp.float32), i_f, rng, h_r.astype(jnp.int32),
                beta.astype(jnp.float32), eta, decay, interpret=interp,
                stream_block=sb, **_loss_kw(cfg))
        return hedge_rounds_pallas(
            log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
            zeta.astype(jnp.int32), h_r.astype(jnp.int32),
            beta.astype(jnp.float32), eta, decay, interpret=interp,
            stream_block=sb, **_loss_kw(cfg))
    if randomness == "counter":
        return hedge_rounds_counter_ref(
            log_w.astype(jnp.float32), i_f, rng, h_r.astype(jnp.int32),
            beta.astype(jnp.float32), eta=eta, decay=decay, **_loss_kw(cfg))
    return hedge_rounds_ref(
        log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
        zeta.astype(jnp.int32), h_r.astype(jnp.int32),
        beta.astype(jnp.float32), eta=eta, decay=decay, **_loss_kw(cfg))


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel", "interpret",
                                             "stream_block", "randomness"))
def fleet_hedge_decide(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G)
    f: jnp.ndarray,          # (S,) confidences in [0, 1]
    psi: jnp.ndarray,        # (S,) uniforms; None in counter mode
    zeta: jnp.ndarray,       # (S,) bernoulli(ε) draws; None in counter mode
    use_kernel: bool = True,
    interpret: bool = None,
    stream_block: int = None,    # None → autotune cache default
    randomness: str = "pre_draw",
    rng=None,                    # (seed, slot, stream_offset) — counter mode
):
    """Serving phase 1 for the fleet: quantize + region masses + decisions.

    Returns (i_f, offload, explored, local_pred, q, p) — everything
    `core.policy.FleetDecision` needs except the caller-held ψ. In counter
    mode ψ is regenerated in place and *returned* as a seventh element
    (serving reuses it for the capacity-drop local fallback). No weight
    write: feedback waits for the (delayed, possibly capacity-dropped)
    remote labels in `fleet_hedge_feedback`.
    """
    _check_randomness(randomness, psi, zeta, rng)
    g = cfg.grid
    i_f = jnp.clip((f * g).astype(jnp.int32), 0, g - 1)
    sb = _stream_block(stream_block, g, log_w.shape[0], randomness)
    if use_kernel:
        interp = _interpret_default() if interpret is None else interpret
        if randomness == "counter":
            out = hedge_decide_counter_pallas(
                log_w.astype(jnp.float32), i_f, rng, eps=cfg.eps,
                interpret=interp, stream_block=sb)
        else:
            out = hedge_decide_pallas(
                log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
                zeta.astype(jnp.int32), interpret=interp, stream_block=sb)
    elif randomness == "counter":
        out = hedge_decide_counter_ref(
            log_w.astype(jnp.float32), i_f, rng, eps=cfg.eps)
    else:
        out = hedge_decide_ref(
            log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
            zeta.astype(jnp.int32))
    return (i_f,) + tuple(out)


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel", "interpret",
                                             "stream_block"))
def fleet_hedge_feedback(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G)
    i_f: jnp.ndarray,        # (S,) decision-time quantized confidence
    sent: jnp.ndarray,       # (S,) offloads that reached the RDL
    explored: jnp.ndarray,   # (S,) exploration flag, already ∧ sent
    h_r: jnp.ndarray,        # (S,) remote labels
    beta: jnp.ndarray,       # (S,) decision-time offload costs
    use_kernel: bool = True,
    interpret: bool = None,
    eta: jnp.ndarray = None,     # (S,) per-stream η; None → cfg.eta
    decay: jnp.ndarray = None,   # (S,) per-stream decay; None → cfg.decay
    stream_block: int = None,    # None → autotune cache default
):
    """Serving phase 2 for the fleet: the Eq.-10 weight update only.

    The cheap (S,) loss/prediction accounting lives in
    `core.policy.fleet_feedback`, which routes its (S, G, G) weight traffic
    here when `use_kernel` resolves true.
    """
    g = cfg.grid
    eta, decay = _sched(cfg, eta, decay)
    if use_kernel:
        interp = _interpret_default() if interpret is None else interpret
        return hedge_feedback_pallas(
            log_w.astype(jnp.float32), i_f.astype(jnp.int32),
            sent.astype(jnp.int32), explored.astype(jnp.int32),
            h_r.astype(jnp.int32), beta.astype(jnp.float32), eta, decay,
            interpret=interp,
            stream_block=_stream_block(stream_block, g, log_w.shape[0]),
            **_loss_kw(cfg))
    return hedge_feedback_ref(
        log_w.astype(jnp.float32), i_f.astype(jnp.int32),
        sent.astype(jnp.int32), explored.astype(jnp.int32),
        h_r.astype(jnp.int32), beta.astype(jnp.float32), eta, decay,
        **_loss_kw(cfg))
