"""Public wrappers for the fused hedge kernels: the monolithic
single-/multi-round steps and the serving decide/feedback split.

Every op routes on a single frozen :class:`repro.core.ExecSpec` passed
as ``spec=`` — learner choice, kernel-vs-jnp, interpret mode, stream
block, randomness mode all live there. The old loose kwargs
(``use_kernel``, ``interpret``, ``stream_block``, ``randomness``) keep
working as deprecated shims that emit a ``DeprecationWarning`` and map
onto the spec; since the shim resolution happens in a plain-Python
wrapper *outside* the jit boundary, the warning fires per call while
the jitted impl still sees one hashable static spec.

``spec.use_kernel=None`` auto-selects (the Pallas kernel on TPU, the
jnp oracle elsewhere — unless ``interpret=True`` explicitly asks for
the interpret-mode kernel). ``spec.learner`` picks the weight
structure: ``"dense"`` dispatches to the paper's (G, G) kernels in
`ref.py`/`kernel.py` bit-identically; any other name resolves through
`repro.core.learners` to a module exporting the same op protocol (see
:class:`LearnerFns`; `factored.py` is the (2, G) per-threshold
instance).

Every op takes the (η, decay) schedule as optional per-stream (S,)
arrays (None → the HIConfig scalars, broadcast — bit-identical to the
fixed paper schedule); ``spec.stream_block=None`` consults the
persistent autotune cache (`kernels.hedge.autotune`).

The randomness-consuming ops (step/rounds/decide) honor
``spec.randomness``: ``"pre_draw"`` (default, the golden paper path)
ships (ψ, ζ) as operands; ``"counter"`` takes an `rng`
(seed, slot, stream_offset) position instead and regenerates the draws
in-kernel via the threefry counter contract (`repro.core.counter`) —
zero randomness tensors in memory, and the draws are position-keyed so
they are identical across learners.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.counter import check_randomness_mode
from repro.core.execspec import UNSET, ExecSpec, resolve_spec
from repro.core.learners import get_learner
from repro.core.types import HIConfig
from repro.kernels.hedge import autotune
from repro.kernels.hedge.kernel import (
    hedge_decide_counter_pallas,
    hedge_decide_pallas,
    hedge_feedback_pallas,
    hedge_rounds_counter_pallas,
    hedge_rounds_pallas,
    hedge_step_counter_pallas,
    hedge_step_pallas,
)
from repro.kernels.hedge.ref import (
    hedge_decide_counter_ref,
    hedge_decide_ref,
    hedge_feedback_ref,
    hedge_rounds_counter_ref,
    hedge_rounds_ref,
    hedge_step_counter_ref,
    hedge_step_ref,
)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def kernel_available() -> bool:
    """True when the compiled Pallas kernel (not interpret mode) can run."""
    return jax.default_backend() == "tpu"


class LearnerFns(NamedTuple):
    """The op protocol a learner's kernel module exports.

    The dense entries are assembled from `ref.py`/`kernel.py`; any other
    registered learner's ``ops()`` module must export exactly these
    names with the same signatures (`factored.py` is the model)."""

    step_ref: Callable
    rounds_ref: Callable
    decide_ref: Callable
    feedback_ref: Callable
    step_counter_ref: Callable
    rounds_counter_ref: Callable
    decide_counter_ref: Callable
    step_pallas: Callable
    rounds_pallas: Callable
    decide_pallas: Callable
    feedback_pallas: Callable
    step_counter_pallas: Callable
    rounds_counter_pallas: Callable
    decide_counter_pallas: Callable


_DENSE_FNS = LearnerFns(
    step_ref=hedge_step_ref,
    rounds_ref=hedge_rounds_ref,
    decide_ref=hedge_decide_ref,
    feedback_ref=hedge_feedback_ref,
    step_counter_ref=hedge_step_counter_ref,
    rounds_counter_ref=hedge_rounds_counter_ref,
    decide_counter_ref=hedge_decide_counter_ref,
    step_pallas=hedge_step_pallas,
    rounds_pallas=hedge_rounds_pallas,
    decide_pallas=hedge_decide_pallas,
    feedback_pallas=hedge_feedback_pallas,
    step_counter_pallas=hedge_step_counter_pallas,
    rounds_counter_pallas=hedge_rounds_counter_pallas,
    decide_counter_pallas=hedge_decide_counter_pallas,
)


@functools.lru_cache(maxsize=None)
def _learner_fns(name: str) -> LearnerFns:
    if name == "dense":
        return _DENSE_FNS
    mod = get_learner(name).ops()
    return LearnerFns(**{f: getattr(mod, f) for f in LearnerFns._fields})


def _loss_kw(cfg: HIConfig) -> dict:
    return dict(eps=cfg.eps, delta_fp=cfg.delta_fp, delta_fn=cfg.delta_fn)


def _sched(cfg: HIConfig, eta, decay):
    """Resolve the schedule: HIConfig scalars where not overridden."""
    return (cfg.eta if eta is None else eta,
            cfg.decay if decay is None else decay)


def _stream_block(stream_block, g: int, s: int,
                  randomness: str = "pre_draw") -> int:
    """Static launch geometry: explicit override, else the autotune cache.

    Called at trace time (shapes are concrete), so the cache lookup is pure
    Python and free at execution time — which also means a (cfg, shape)
    combo this process already traced keeps its geometry even if the cache
    file is rewritten (jit never re-traces identical static args). The
    cache is consulted per randomness mode — counter kernels have different
    arithmetic intensity, so their winners are tuned separately.
    """
    if stream_block is not None:
        return int(stream_block)
    return autotune.best_stream_block(g, s, randomness=randomness)


def _use_kernel(spec: ExecSpec) -> bool:
    """Resolve spec.use_kernel=None: kernel where it compiles (TPU), or
    where interpret mode was explicitly requested; jnp oracle elsewhere."""
    if spec.use_kernel is None:
        return kernel_available() or spec.interpret is True
    return bool(spec.use_kernel)


def _interpret(spec: ExecSpec) -> bool:
    return _interpret_default() if spec.interpret is None else spec.interpret


def _check_randomness(randomness: str, psi, zeta, rng) -> None:
    """Trace-time validation of the (mode, operands) pairing."""
    check_randomness_mode(randomness)
    if randomness == "counter":
        if rng is None:
            raise ValueError("randomness='counter' needs an rng "
                             "(seed, slot, stream_offset) triple")
        if psi is not None or zeta is not None:
            raise ValueError("randomness='counter' regenerates (psi, zeta) "
                             "in place — pass them as None")
    elif rng is not None:
        raise ValueError("rng is only meaningful with randomness='counter'")


@functools.partial(jax.jit, static_argnames=("cfg", "spec"))
def _fleet_hedge_step(cfg, log_w, f, psi, zeta, h_r, beta, eta, decay, rng,
                      *, spec: ExecSpec):
    _check_randomness(spec.randomness, psi, zeta, rng)
    fns = _learner_fns(spec.learner)
    g = cfg.grid
    i_f = jnp.clip((f * g).astype(jnp.int32), 0, g - 1)
    eta, decay = _sched(cfg, eta, decay)
    sb = _stream_block(spec.stream_block, g, log_w.shape[0], spec.randomness)
    if _use_kernel(spec):
        interp = _interpret(spec)
        if spec.randomness == "counter":
            return fns.step_counter_pallas(
                log_w.astype(jnp.float32), i_f, rng, h_r.astype(jnp.int32),
                beta.astype(jnp.float32), eta, decay, interpret=interp,
                stream_block=sb, **_loss_kw(cfg))
        return fns.step_pallas(
            log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
            zeta.astype(jnp.int32), h_r.astype(jnp.int32),
            beta.astype(jnp.float32), eta, decay, interpret=interp,
            stream_block=sb, **_loss_kw(cfg))
    if spec.randomness == "counter":
        return fns.step_counter_ref(
            log_w.astype(jnp.float32), i_f, rng, h_r.astype(jnp.int32),
            beta.astype(jnp.float32), eta=eta, decay=decay, **_loss_kw(cfg))
    return fns.step_ref(
        log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
        zeta.astype(jnp.int32), h_r.astype(jnp.int32),
        beta.astype(jnp.float32), eta=eta, decay=decay, **_loss_kw(cfg))


def fleet_hedge_step(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G) dense / learner state pytree leaf
    f: jnp.ndarray,          # (S,) confidences in [0, 1]
    psi: jnp.ndarray,        # (S,) uniforms; None in counter mode
    zeta: jnp.ndarray,       # (S,) bernoulli(ε) draws; None in counter mode
    h_r: jnp.ndarray,        # (S,) remote labels
    beta: jnp.ndarray,       # (S,) offload costs
    use_kernel=UNSET,        # deprecated — pass spec=ExecSpec(...)
    interpret=UNSET,         # deprecated — pass spec=ExecSpec(...)
    eta: jnp.ndarray = None,     # (S,) per-stream η; None → cfg.eta
    decay: jnp.ndarray = None,   # (S,) per-stream decay; None → cfg.decay
    stream_block=UNSET,      # deprecated — pass spec=ExecSpec(...)
    randomness=UNSET,        # deprecated — pass spec=ExecSpec(...)
    rng=None,                # (seed, slot, stream_offset) — counter mode
    spec: ExecSpec = None,
):
    """One H2T2 round for a whole fleet of streams.

    With ``spec.randomness="counter"`` the (ψ, ζ) draws are regenerated
    from the `rng` position instead of passed in — no randomness operands
    at all.
    """
    spec = resolve_spec(spec, caller="fleet_hedge_step",
                        use_kernel=use_kernel, interpret=interpret,
                        stream_block=stream_block, randomness=randomness)
    return _fleet_hedge_step(cfg, log_w, f, psi, zeta, h_r, beta, eta, decay,
                             rng, spec=spec)


@functools.partial(jax.jit, static_argnames=("cfg", "spec"))
def _fleet_hedge_rounds(cfg, log_w, f, psi, zeta, h_r, beta, eta, decay, rng,
                        *, spec: ExecSpec):
    _check_randomness(spec.randomness, psi, zeta, rng)
    fns = _learner_fns(spec.learner)
    g = cfg.grid
    i_f = jnp.clip((f * g).astype(jnp.int32), 0, g - 1)
    eta, decay = _sched(cfg, eta, decay)
    sb = _stream_block(spec.stream_block, g, log_w.shape[0], spec.randomness)
    if _use_kernel(spec):
        interp = _interpret(spec)
        if spec.randomness == "counter":
            return fns.rounds_counter_pallas(
                log_w.astype(jnp.float32), i_f, rng, h_r.astype(jnp.int32),
                beta.astype(jnp.float32), eta, decay, interpret=interp,
                stream_block=sb, **_loss_kw(cfg))
        return fns.rounds_pallas(
            log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
            zeta.astype(jnp.int32), h_r.astype(jnp.int32),
            beta.astype(jnp.float32), eta, decay, interpret=interp,
            stream_block=sb, **_loss_kw(cfg))
    if spec.randomness == "counter":
        return fns.rounds_counter_ref(
            log_w.astype(jnp.float32), i_f, rng, h_r.astype(jnp.int32),
            beta.astype(jnp.float32), eta=eta, decay=decay, **_loss_kw(cfg))
    return fns.rounds_ref(
        log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
        zeta.astype(jnp.int32), h_r.astype(jnp.int32),
        beta.astype(jnp.float32), eta=eta, decay=decay, **_loss_kw(cfg))


def fleet_hedge_rounds(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G) dense / learner state pytree leaf
    f: jnp.ndarray,          # (S, TB) confidences in [0, 1]
    psi: jnp.ndarray,        # (S, TB) uniforms; None in counter mode
    zeta: jnp.ndarray,       # (S, TB) bernoulli(ε); None in counter mode
    h_r: jnp.ndarray,        # (S, TB) remote labels
    beta: jnp.ndarray,       # (S, TB) offload costs
    use_kernel=UNSET,        # deprecated — pass spec=ExecSpec(...)
    interpret=UNSET,         # deprecated — pass spec=ExecSpec(...)
    eta: jnp.ndarray = None,     # (S,) per-stream η; None → cfg.eta
    decay: jnp.ndarray = None,   # (S,) per-stream decay; None → cfg.decay
    stream_block=UNSET,      # deprecated — pass spec=ExecSpec(...)
    randomness=UNSET,        # deprecated — pass spec=ExecSpec(...)
    rng=None,                # (seed, slot₀, stream_offset) — counter mode
    spec: ExecSpec = None,
):
    """TB sequential H2T2 rounds for a whole fleet in one launch.

    Step-for-step identical to TB chained `fleet_hedge_step` calls (with the
    schedule held fixed across the block); on TPU the expert state stays in
    VMEM for the whole time block. Counter mode draws round t of the block
    at slot₀ + t — the chain reproduces any other chunking bit-for-bit and
    ships zero randomness operands.
    """
    spec = resolve_spec(spec, caller="fleet_hedge_rounds",
                        use_kernel=use_kernel, interpret=interpret,
                        stream_block=stream_block, randomness=randomness)
    return _fleet_hedge_rounds(cfg, log_w, f, psi, zeta, h_r, beta, eta,
                               decay, rng, spec=spec)


@functools.partial(jax.jit, static_argnames=("cfg", "spec"))
def _fleet_hedge_decide(cfg, log_w, f, psi, zeta, rng, *, spec: ExecSpec):
    _check_randomness(spec.randomness, psi, zeta, rng)
    fns = _learner_fns(spec.learner)
    g = cfg.grid
    i_f = jnp.clip((f * g).astype(jnp.int32), 0, g - 1)
    sb = _stream_block(spec.stream_block, g, log_w.shape[0], spec.randomness)
    if _use_kernel(spec):
        interp = _interpret(spec)
        if spec.randomness == "counter":
            out = fns.decide_counter_pallas(
                log_w.astype(jnp.float32), i_f, rng, eps=cfg.eps,
                interpret=interp, stream_block=sb)
        else:
            out = fns.decide_pallas(
                log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
                zeta.astype(jnp.int32), interpret=interp, stream_block=sb)
    elif spec.randomness == "counter":
        out = fns.decide_counter_ref(
            log_w.astype(jnp.float32), i_f, rng, eps=cfg.eps)
    else:
        out = fns.decide_ref(
            log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
            zeta.astype(jnp.int32))
    return (i_f,) + tuple(out)


def fleet_hedge_decide(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G) dense / learner state pytree leaf
    f: jnp.ndarray,          # (S,) confidences in [0, 1]
    psi: jnp.ndarray,        # (S,) uniforms; None in counter mode
    zeta: jnp.ndarray,       # (S,) bernoulli(ε) draws; None in counter mode
    use_kernel=UNSET,        # deprecated — pass spec=ExecSpec(...)
    interpret=UNSET,         # deprecated — pass spec=ExecSpec(...)
    stream_block=UNSET,      # deprecated — pass spec=ExecSpec(...)
    randomness=UNSET,        # deprecated — pass spec=ExecSpec(...)
    rng=None,                # (seed, slot, stream_offset) — counter mode
    spec: ExecSpec = None,
):
    """Serving phase 1 for the fleet: quantize + region masses + decisions.

    Returns (i_f, offload, explored, local_pred, q, p) — everything
    `core.policy.FleetDecision` needs except the caller-held ψ. In counter
    mode ψ is regenerated in place and *returned* as a seventh element
    (serving reuses it for the capacity-drop local fallback). No weight
    write: feedback waits for the (delayed, possibly capacity-dropped)
    remote labels in `fleet_hedge_feedback`.
    """
    spec = resolve_spec(spec, caller="fleet_hedge_decide",
                        use_kernel=use_kernel, interpret=interpret,
                        stream_block=stream_block, randomness=randomness)
    return _fleet_hedge_decide(cfg, log_w, f, psi, zeta, rng, spec=spec)


@functools.partial(jax.jit, static_argnames=("cfg", "spec"))
def _fleet_hedge_feedback(cfg, log_w, i_f, sent, explored, h_r, beta, eta,
                          decay, *, spec: ExecSpec):
    fns = _learner_fns(spec.learner)
    g = cfg.grid
    eta, decay = _sched(cfg, eta, decay)
    if _use_kernel(spec):
        return fns.feedback_pallas(
            log_w.astype(jnp.float32), i_f.astype(jnp.int32),
            sent.astype(jnp.int32), explored.astype(jnp.int32),
            h_r.astype(jnp.int32), beta.astype(jnp.float32), eta, decay,
            interpret=_interpret(spec),
            stream_block=_stream_block(
                spec.stream_block, g, log_w.shape[0]),
            **_loss_kw(cfg))
    return fns.feedback_ref(
        log_w.astype(jnp.float32), i_f.astype(jnp.int32),
        sent.astype(jnp.int32), explored.astype(jnp.int32),
        h_r.astype(jnp.int32), beta.astype(jnp.float32), eta, decay,
        **_loss_kw(cfg))


def fleet_hedge_feedback(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G) dense / learner state pytree leaf
    i_f: jnp.ndarray,        # (S,) decision-time quantized confidence
    sent: jnp.ndarray,       # (S,) offloads that reached the RDL
    explored: jnp.ndarray,   # (S,) exploration flag, already ∧ sent
    h_r: jnp.ndarray,        # (S,) remote labels
    beta: jnp.ndarray,       # (S,) decision-time offload costs
    use_kernel=UNSET,        # deprecated — pass spec=ExecSpec(...)
    interpret=UNSET,         # deprecated — pass spec=ExecSpec(...)
    eta: jnp.ndarray = None,     # (S,) per-stream η; None → cfg.eta
    decay: jnp.ndarray = None,   # (S,) per-stream decay; None → cfg.decay
    stream_block=UNSET,      # deprecated — pass spec=ExecSpec(...)
    spec: ExecSpec = None,
):
    """Serving phase 2 for the fleet: the Eq.-10 weight update only.

    The cheap (S,) loss/prediction accounting lives in
    `core.policy.fleet_feedback`, which routes its weight traffic here
    when the spec's kernel routing resolves true.
    """
    spec = resolve_spec(spec, caller="fleet_hedge_feedback",
                        use_kernel=use_kernel, interpret=interpret,
                        stream_block=stream_block)
    return _fleet_hedge_feedback(cfg, log_w, i_f, sent, explored, h_r, beta,
                                 eta, decay, spec=spec)
