"""jit'd public wrappers for the fused hedge kernels (single- and multi-round)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.types import HIConfig
from repro.kernels.hedge.kernel import hedge_rounds_pallas, hedge_step_pallas
from repro.kernels.hedge.ref import hedge_rounds_ref, hedge_step_ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def kernel_available() -> bool:
    """True when the compiled Pallas kernel (not interpret mode) can run."""
    return jax.default_backend() == "tpu"


def _cfg_kw(cfg: HIConfig) -> dict:
    return dict(eta=cfg.eta, eps=cfg.eps, delta_fp=cfg.delta_fp,
                delta_fn=cfg.delta_fn, decay=cfg.decay)


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel", "interpret"))
def fleet_hedge_step(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G)
    f: jnp.ndarray,          # (S,) confidences in [0, 1]
    psi: jnp.ndarray,        # (S,) uniforms
    zeta: jnp.ndarray,       # (S,) bernoulli(ε) draws
    h_r: jnp.ndarray,        # (S,) remote labels
    beta: jnp.ndarray,       # (S,) offload costs
    use_kernel: bool = True,
    interpret: bool = None,
):
    """One H2T2 round for a whole fleet of streams."""
    g = cfg.grid
    i_f = jnp.clip((f * g).astype(jnp.int32), 0, g - 1)
    kw = _cfg_kw(cfg)
    if use_kernel:
        interp = _interpret_default() if interpret is None else interpret
        return hedge_step_pallas(
            log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
            zeta.astype(jnp.int32), h_r.astype(jnp.int32),
            beta.astype(jnp.float32), interpret=interp, **kw)
    return hedge_step_ref(
        log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
        zeta.astype(jnp.int32), h_r.astype(jnp.int32),
        beta.astype(jnp.float32), **kw)


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel", "interpret"))
def fleet_hedge_rounds(
    cfg: HIConfig,
    log_w: jnp.ndarray,      # (S, G, G)
    f: jnp.ndarray,          # (S, TB) confidences in [0, 1]
    psi: jnp.ndarray,        # (S, TB) uniforms
    zeta: jnp.ndarray,       # (S, TB) bernoulli(ε) draws
    h_r: jnp.ndarray,        # (S, TB) remote labels
    beta: jnp.ndarray,       # (S, TB) offload costs
    use_kernel: bool = True,
    interpret: bool = None,
):
    """TB sequential H2T2 rounds for a whole fleet in one launch.

    Step-for-step identical to TB chained `fleet_hedge_step` calls; on TPU the
    expert grids stay in VMEM for the whole time block.
    """
    g = cfg.grid
    i_f = jnp.clip((f * g).astype(jnp.int32), 0, g - 1)
    kw = _cfg_kw(cfg)
    if use_kernel:
        interp = _interpret_default() if interpret is None else interpret
        return hedge_rounds_pallas(
            log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
            zeta.astype(jnp.int32), h_r.astype(jnp.int32),
            beta.astype(jnp.float32), interpret=interp, **kw)
    return hedge_rounds_ref(
        log_w.astype(jnp.float32), i_f, psi.astype(jnp.float32),
        zeta.astype(jnp.int32), h_r.astype(jnp.int32),
        beta.astype(jnp.float32), **kw)
