"""jit'd wrapper; picks interpret mode automatically off-TPU."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    interp = (jax.default_backend() != "tpu") if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interp)
