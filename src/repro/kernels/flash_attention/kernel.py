"""Blockwise (flash) attention Pallas kernel: causal GQA with optional
sliding window.

TPU adaptation: q/k tiles are MXU-aligned (block_q × head_dim, block_k ×
head_dim, head_dim a multiple of 128 preferred); the online-softmax running
max/sum live in VMEM scratch; the KV loop is the innermost grid dimension so
the accumulator persists across KV steps. Fully-masked KV blocks (beyond the
causal frontier or the sliding window) are skipped via `pl.when`.

Grid: (batch, q_heads, Sq/block_q, Sk/block_k).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref,                 # (bq, d), (bk, d), (bk, d)
    o_ref,                                # (bq, d)
    m_scr, l_scr, acc_scr,                # scratch: (bq, 1), (bq, 1), (bq, d)
    *, scale: float, causal: bool, window, block_q: int, block_k: int,
    seq_k: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        s = q @ k.T                                       # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG)

        m_prev = m_scr[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v_ref[0, 0].astype(jnp.float32)
        m_scr[...] = m_new

    if causal or window is not None:
        # Skip blocks that are fully masked.
        q_end = q_start + block_q - 1
        visible = True
        if causal:
            visible = k_start <= q_end
        if window is not None:
            visible = visible & (k_start + block_k - 1 > q_start - window)

        @pl.when(visible)
        def _():
            compute()
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-38)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,          # (B, Sq, H, D)
    k: jnp.ndarray,          # (B, Sk, Hkv, D)
    v: jnp.ndarray,          # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window=None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    sq_pad = math.ceil(sq / block_q) * block_q
    sk_pad = math.ceil(sk / block_k) * block_k
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))

    # Layout (B, H, S, D) so blocks are contiguous per (batch, head).
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, h, sq_pad // block_q, sk_pad // block_k)
    kern = functools.partial(
        _attn_kernel, scale=1.0 / math.sqrt(d), causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_k=sk)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            # q head h uses kv head h % hkv (matches models.attention._sdpa's
            # (g, hkv) reshape convention).
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, n=hkv: (bi, hi % n, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, n=hkv: (bi, hi % n, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :sq, :, :]
