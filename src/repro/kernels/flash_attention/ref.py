"""Pure-jnp oracle: exact softmax attention with GQA + causal/window masks."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,          # (B, Sq, H, D)
    k: jnp.ndarray,          # (B, Sk, Hkv, D)
    v: jnp.ndarray,          # (B, Sk, Hkv, D)
    causal: bool = True,
    window: Optional[int] = None,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    qg = q.reshape(b, sq, g, hkv, d)
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bqgkd,bskd->bgkqs", qg, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bgkqs,bskd->bqgkd", probs, v)
    return ctx.reshape(b, sq, h, d)
