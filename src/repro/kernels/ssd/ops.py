"""jit'd wrapper for the SSD kernel."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
    c: jnp.ndarray, chunk: int = 128, initial_state=None,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if initial_state is not None:
        raise NotImplementedError("kernel path starts from zero state; "
                                  "use ssd_reference for seeded scans")
    interp = (jax.default_backend() != "tpu") if interpret is None else interpret
    return ssd_pallas(x, dt, a, b, c, chunk=chunk, interpret=interp)
