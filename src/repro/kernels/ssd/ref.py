"""Oracle for the SSD kernel: the models.ssm chunked reference (itself
validated against the sequential recurrence in tests)."""
from repro.models.ssm import ssd_reference


def ssd_ref(x, dt, a, b, c, chunk=128, initial_state=None):
    return ssd_reference(x, dt, a, b, c, chunk=chunk, initial_state=initial_state)


def ssd_sequential(x, dt, a, b, c):
    """Exact step-by-step recurrence h ← e^{−dt·a} h + dt·x⊗B; y = C·h."""
    import jax
    import jax.numpy as jnp

    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    bh = jnp.repeat(b, rep, axis=2)
    ch = jnp.repeat(c, rep, axis=2)

    def step(state, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(-dtt * a[None, :])[..., None, None]   # (B,H,1,1)
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dtt, xt, bt)
        state = state * decay + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    init = jnp.zeros((bs, h, p, n), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          bh.transpose(1, 0, 2, 3).astype(jnp.float32),
          ch.transpose(1, 0, 2, 3).astype(jnp.float32))
    state, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3), state
