"""Mamba2 SSD chunk-scan Pallas kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060 §6): the sequential
recurrence over chunks becomes the innermost grid dimension with the SSM
state (P × N) carried in VMEM scratch; the within-chunk quadratic part
(C·Bᵀ ⊙ decay) runs on the MXU per (batch·head, chunk) tile.

Grid: (B·H, S/chunk). Blocks per program: x (chunk, P), dt/decays (chunk,),
b/c (chunk, N). VMEM ≈ chunk·(P+2N)·4 B + P·N·4 B.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,      # (1, chunk, P)
    dt_ref,     # (1, chunk)
    dlog_ref,   # (1, chunk)  — per-step log decay (−dt·a), precomputed
    b_ref,      # (1, chunk, N)
    c_ref,      # (1, chunk, N)
    y_ref,      # (1, chunk, P)
    state_ref,  # (1, P, N) — final state output (written on last chunk)
    state_scr,  # VMEM scratch (P, N)
    *, chunk: int,
):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    dlog = dlog_ref[0].astype(jnp.float32)    # (Q,)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0].astype(jnp.float32)          # (Q, N)

    cum = jnp.cumsum(dlog)                    # (Q,)
    # Within-chunk quadratic term.
    li = cum[:, None]
    lj = cum[None, :]
    seg = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))
    causal = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    seg = jnp.where(causal, seg, 0.0)
    scores = (c @ b.T) * seg                  # (Q, Q)
    y = (scores * dt[None, :]) @ x            # (Q, P)

    # Entering-state contribution: y += (C_q · state) · exp(cum_q)
    decay_in = jnp.exp(jnp.clip(cum, -60.0, 0.0))
    st = state_scr[...]                       # (P, N)
    y = y + (c @ st.T) * decay_in[:, None]

    # State update: state ← state·exp(cum_Q) + Σ_j exp(cum_Q−cum_j)·dt_j·x_j⊗B_j
    decay_to_end = jnp.exp(jnp.clip(cum[-1] - cum, -60.0, 0.0))
    weighted_x = x * (dt * decay_to_end)[:, None]   # (Q, P)
    new_state = st * jnp.exp(jnp.clip(cum[-1], -60.0, 0.0)) + weighted_x.T @ b
    state_scr[...] = new_state

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        state_ref[0] = state_scr[...].astype(state_ref.dtype)


def ssd_pallas(
    x: jnp.ndarray,       # (B, S, H, P)
    dt: jnp.ndarray,      # (B, S, H) — post-softplus
    a: jnp.ndarray,       # (H,) positive decay rates
    b: jnp.ndarray,       # (B, S, G, N)
    c: jnp.ndarray,       # (B, S, G, N)
    chunk: int = 128,
    interpret: bool = True,
):
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk

    # Flatten (B, H) into the leading grid dim; broadcast groups to heads.
    xt = x.transpose(0, 2, 1, 3).reshape(bs * h, s, p)
    dtt = dt.transpose(0, 2, 1).reshape(bs * h, s)
    dlog = (-dt * a[None, None, :]).transpose(0, 2, 1).reshape(bs * h, s)
    bh = jnp.repeat(b, rep, axis=2).transpose(0, 2, 1, 3).reshape(bs * h, s, n)
    ch = jnp.repeat(c, rep, axis=2).transpose(0, 2, 1, 3).reshape(bs * h, s, n)

    kern = functools.partial(_ssd_kernel, chunk=chunk)
    y, state = pl.pallas_call(
        kern,
        grid=(bs * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, p, n), lambda i, j: (i, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bs * h, s, p), x.dtype),
            jax.ShapeDtypeStruct((bs * h, p, n), x.dtype),
        ),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, dlog, bh, ch)
    y = y.reshape(bs, h, s, p).transpose(0, 2, 1, 3)
    state = state.reshape(bs, h, p, n)
    return y, state
