# Pallas TPU kernels for the compute hot-spots (validated in interpret mode
# on CPU, targeted at TPU v5e):
#   hedge           — fused H2T2 fleet step (the paper's core loop)
#   flash_attention — blockwise causal/windowed GQA attention
#   ssd             — Mamba2 state-space-duality chunk scan
from repro.kernels.hedge import ops as hedge_ops
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.ssd import ops as ssd_ops

__all__ = ["hedge_ops", "flash_ops", "ssd_ops"]
