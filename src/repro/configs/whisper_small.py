"""whisper-small — encoder-decoder with conv/mel frontend STUB: input_specs()
provides precomputed frame embeddings [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,                  # decoder layers
    n_encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51_865,
    pattern=("attn",),
    n_frames=1500,
    norm="layernorm",
    act="gelu",
    rope_theta=0.0,               # whisper uses learned positions, not rope
    source="arXiv:2212.04356 (Whisper-small)",
)
