"""recurrentgemma-2b — hybrid RG-LRU + local attention, 2 recurrent : 1 attn
pattern [arXiv:2402.19427, Griffin/RecurrentGemma]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,                 # MQA
    head_dim=256,
    d_ff=7680,
    vocab=256_000,
    pattern=("rec", "rec", "attn"),
    sliding_window=2048,          # local attention window
    lru_width=2560,
    rope_theta=10_000.0,
    act="gelu",
    source="arXiv:2402.19427 (RecurrentGemma-2B)",
)
