"""deepseek-v2-236b — MoE 160 routed experts top-6 + 2 shared, MLA attention
with kv_lora=512 [arXiv:2405.04434]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,               # MLA: per-head keys decompressed from latent
    head_dim=192,                 # qk_nope(128) + qk_rope(64)
    d_ff=1536,                    # per routed expert
    vocab=102_400,
    pattern=("moe",),
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    n_dense_layers=1,             # first layer uses a dense MLP
    dense_ff=12_288,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    source="arXiv:2405.04434 (DeepSeek-V2 236B)",
)
