"""The paper's own hierarchical pair, expressed in this framework:
a lightweight edge LDL (MobileNet-class capacity) and a server RDL.
Both are small decoder backbones with binary heads; the paper's policy layer
(repro.core) is model-agnostic, so these stand in for the MobileNet/ResNet
pairs of Table 2 when running end-to-end serving examples."""
from repro.configs.base import ModelConfig

LDL_CONFIG = ModelConfig(
    name="paper-ldl",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=1024,
    vocab=512,
    pattern=("attn",),
    source="paper §5: MobileNet-class edge LDL stand-in",
)

RDL_CONFIG = ModelConfig(
    name="paper-rdl",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    head_dim=64,
    d_ff=3072,
    vocab=512,
    pattern=("attn",),
    source="paper §5: remote RDL stand-in",
)
