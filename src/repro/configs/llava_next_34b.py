"""llava-next-34b — VLM: Yi-34B language decoder consuming anyres patch
embeddings from a stub vision frontend [hf:llava-hf/llava-v1.6]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20_480,
    vocab=64_000,
    pattern=("attn",),
    rope_theta=5_000_000.0,
    n_patches=2880,               # anyres: (4 tiles + 1 base) x 576 patches (stub)
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (34B variant dims per Yi-34B)",
)
