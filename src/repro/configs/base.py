"""ModelConfig — one dataclass describing every supported backbone family.

Families: dense (GQA/MQA attention + MLP), moe (routed experts), ssm (Mamba2
SSD), hybrid (RG-LRU recurrent + local attention), encdec (whisper-style),
vlm (dense decoder consuming patch embeddings), audio (= encdec).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


def pad_to(x: int, multiple: int = 256) -> int:
    return ((x + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # Block pattern, repeated through depth ('attn', 'rec', 'ssm', 'moe').
    pattern: Tuple[str, ...] = ("attn",)
    # Attention extras.
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None      # persistent SWA (mixtral, rg local attn)
    long_context_window: int = 8192           # window used only for long_500k decode
    # MoE.
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    dense_ff: Optional[int] = None            # d_ff of any leading dense MLP layers
    n_dense_layers: int = 0                   # leading layers that use dense MLP
    # MLA (deepseek-v2).
    use_mla: bool = False
    kv_lora_rank: int = 512
    q_lora_rank: int = 0                      # 0 ⇒ direct q projection
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # SSM (mamba2).
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    # RG-LRU (recurrentgemma).
    lru_width: Optional[int] = None
    # Encoder-decoder (whisper) / frontends.
    n_encoder_layers: int = 0
    n_frames: int = 1500                      # encoder positions (stub frontend)
    n_patches: int = 0                        # VLM prefix patch embeddings (stub)
    # Misc.
    norm: str = "rmsnorm"                     # rmsnorm | layernorm
    act: str = "silu"                         # silu (swiglu) | gelu
    tie_embeddings: bool = False
    dtype: jnp.dtype = jnp.bfloat16
    source: str = ""                          # citation

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/LM head shard
        16-way on the model axis (MaxText-style padding; loss masks the tail)."""
        return pad_to(self.vocab)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kinds through the depth, repeating the pattern."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def reduced(self, **overrides) -> "ModelConfig":
        """CPU-smoke-sized variant of the same family (≤2 layers, d_model ≤ 512,
        ≤4 experts), preserving the block pattern and divisibility structure."""
        small = dict(
            n_layers=max(2, len(self.pattern)),
            d_model=256,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=64,
            d_ff=512,
            vocab=512,
            n_frames=64,
            n_patches=min(self.n_patches, 16),
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else None,
            long_context_window=64,
        )
        if self.n_experts:
            small.update(n_experts=4, top_k=min(self.top_k, 2), d_ff=128,
                         n_shared_experts=min(self.n_shared_experts, 1),
                         dense_ff=256 if self.dense_ff else None,
                         n_dense_layers=min(self.n_dense_layers, 1))
        if self.use_mla:
            small.update(kv_lora_rank=64, q_lora_rank=64 if self.q_lora_rank else 0,
                         qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32, head_dim=48)
        if self.family == "ssm":
            small.update(ssm_state=16, ssm_head_dim=32)
        if self.family in ("encdec", "audio"):
            small.update(n_encoder_layers=2)
        if self.family == "hybrid":
            small.update(lru_width=256)
        small.update(overrides)
        return dataclasses.replace(self, name=self.name + "-smoke", **small)
