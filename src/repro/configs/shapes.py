"""The four assigned input shapes.

Decode shapes lower `serve_step` (one new token against a KV cache of seq_len);
train_4k lowers `train_step`; prefill_32k lowers `prefill_step`.
long_500k decodes against a sliding-window cache (window = cfg.long_context_window,
or the family's native recurrent state) — see DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    s.name: s
    for s in [
        InputShape("train_4k", seq_len=4_096, global_batch=256, kind="train"),
        InputShape("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
        InputShape("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
        InputShape("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
    ]
}


def get_shape(name: str) -> InputShape:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; choose from {sorted(SHAPES)}")
