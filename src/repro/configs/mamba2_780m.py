"""mamba2-780m — attention-free SSM with state-space duality (SSD)
[arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,                    # attention-free
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,                       # no separate MLP; the Mamba2 block is the mixer
    vocab=50_280,
    pattern=("ssm",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    conv_width=4,
    norm="rmsnorm",
    source="arXiv:2405.21060 (Mamba2-780m)",
)
