"""Config registry: `get_config(arch)` / `--arch <id>`."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, pad_to
from repro.configs.shapes import SHAPES, InputShape, get_shape

from repro.configs.recurrentgemma_2b import CONFIG as _recurrentgemma_2b
from repro.configs.mamba2_780m import CONFIG as _mamba2_780m
from repro.configs.deepseek_coder_33b import CONFIG as _deepseek_coder_33b
from repro.configs.llava_next_34b import CONFIG as _llava_next_34b
from repro.configs.whisper_small import CONFIG as _whisper_small
from repro.configs.deepseek_v2_236b import CONFIG as _deepseek_v2_236b
from repro.configs.mixtral_8x7b import CONFIG as _mixtral_8x7b
from repro.configs.granite_3_2b import CONFIG as _granite_3_2b
from repro.configs.yi_34b import CONFIG as _yi_34b
from repro.configs.qwen2_1_5b import CONFIG as _qwen2_1_5b
from repro.configs.paper_hi import LDL_CONFIG, RDL_CONFIG

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _recurrentgemma_2b,
        _mamba2_780m,
        _deepseek_coder_33b,
        _llava_next_34b,
        _whisper_small,
        _deepseek_v2_236b,
        _mixtral_8x7b,
        _granite_3_2b,
        _yi_34b,
        _qwen2_1_5b,
        LDL_CONFIG,
        RDL_CONFIG,
    ]
}

ASSIGNED = [
    "recurrentgemma-2b", "mamba2-780m", "deepseek-coder-33b", "llava-next-34b",
    "whisper-small", "deepseek-v2-236b", "mixtral-8x7b", "granite-3-2b",
    "yi-34b", "qwen2-1.5b",
]


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")


__all__ = [
    "ARCHS", "ASSIGNED", "ModelConfig", "InputShape", "SHAPES",
    "get_config", "get_shape", "pad_to", "LDL_CONFIG", "RDL_CONFIG",
]
