"""Randomized invariants of the H2T2 policy (Algorithm 1).

Hypothesis-free satellite of test_policy_properties: over randomized
(f, h_r, β) traces the policy must keep its probability masses coherent,
its region masks a partition, its log-weights finite over long horizons,
and (with decay=1) follow the paper's linear-space Hedge update
step-for-step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HIConfig,
    draw_fleet_randomness,
    quantize,
    region_masks,
    run_stream,
)


def _trace(key, t, beta_max=0.6):
    ks = jax.random.split(key, 3)
    fs = jax.random.uniform(ks[0], (t,))
    hrs = jax.random.bernoulli(ks[1], 0.5, (t,)).astype(jnp.int32)
    betas = jax.random.uniform(ks[2], (t,), minval=0.05, maxval=beta_max)
    return fs, hrs, betas


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_q_plus_p_bounded(seed):
    """Region masses are probabilities: q, p ∈ [0, 1] and q + p ≤ 1."""
    cfg = HIConfig(bits=4, eps=0.1, eta=1.0)
    fs, hrs, betas = _trace(jax.random.PRNGKey(seed), 500)
    _, out = run_stream(cfg, fs, hrs, betas, jax.random.PRNGKey(100 + seed))
    q, p = np.asarray(out.q), np.asarray(out.p)
    assert np.all(q >= 0) and np.all(q <= 1 + 1e-6)
    assert np.all(p >= 0) and np.all(p <= 1 + 1e-6)
    assert np.all(q + p <= 1 + 1e-5)


@pytest.mark.parametrize("bits", [2, 3, 4, 5, 6])
def test_region_masks_partition_valid_grid(bits):
    """For every quantized confidence, regions 1/2/3 partition {l ≤ u}."""
    g = 1 << bits
    valid = np.arange(g)[:, None] <= np.arange(g)[None, :]
    for i_f in range(g):
        r1, r2, r3 = map(np.asarray, region_masks(jnp.asarray(i_f), g))
        assert not np.any(r1 & r2) and not np.any(r2 & r3) and not np.any(r1 & r3)
        assert np.array_equal(r1 | r2 | r3, valid)
        assert not np.any((r1 | r2 | r3) & ~valid)


def test_log_weights_finite_after_1e4_rounds():
    """Long-horizon stability: valid log-weights stay finite (and
    renormalized to max ≈ 0) after 10⁴ rounds; invalid cells stay -inf."""
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    fs, hrs, betas = _trace(jax.random.PRNGKey(3), 10_000)
    st, out = run_stream(cfg, fs, hrs, betas, jax.random.PRNGKey(4))
    g = cfg.grid
    lw = np.asarray(st.log_w)
    valid = np.arange(g)[:, None] <= np.arange(g)[None, :]
    assert np.all(np.isfinite(lw[valid]))
    assert np.max(lw[valid]) <= 1e-5
    assert np.all(np.isneginf(lw[~valid]))
    assert np.all(np.isfinite(np.asarray(out.loss)))


def test_decay_one_reproduces_algorithm1_step_for_step():
    """decay=1.0 (the paper's H2T2) must match a plain linear-space
    implementation of Algorithm 1 — same q/p, same decisions, same weight
    distribution — on every round."""
    cfg = HIConfig(bits=3, eps=0.1, eta=1.0, delta_fp=0.7, delta_fn=1.0,
                   decay=1.0)
    g = cfg.grid
    t = 64
    fs, hrs, betas = _trace(jax.random.PRNGKey(5), t)
    key = jax.random.PRNGKey(6)
    _, out = run_stream(cfg, fs, hrs, betas, key)

    # Same (ψ, ζ) draws run_stream consumed (stream_keys pins the key tree).
    psis, zetas = draw_fleet_randomness(cfg, None, 1, t, stream_keys=key[None])
    psis, zetas = np.asarray(psis[0]), np.asarray(zetas[0])

    l = np.arange(g)[:, None]
    u = np.arange(g)[None, :]
    valid = l <= u
    w = np.where(valid, 1.0, 0.0)                        # uniform over experts
    for step in range(t):
        i_f = min(int(float(fs[step]) * g), g - 1)
        r2 = valid & (l <= i_f) & (i_f < u)
        r3 = valid & (u <= i_f)
        total = w.sum()
        q = w[r2].sum() / total
        p = w[r3].sum() / total
        np.testing.assert_allclose(float(out.q[step]), q, atol=1e-5)
        np.testing.assert_allclose(float(out.p[step]), p, atol=1e-5)

        psi, zeta = psis[step], bool(zetas[step])
        in_r2 = psi <= q
        offload = in_r2 or zeta
        explored = zeta and not in_r2
        local_pred = int(psi <= q + p)
        assert bool(out.offload[step]) == offload
        assert bool(out.explored[step]) == explored
        assert int(out.local_pred[step]) == local_pred

        # Eq. 10 pseudo-loss and the multiplicative Hedge update.
        h_r, beta = int(hrs[step]), float(betas[step])
        phi = np.where(r3, cfg.delta_fp if h_r == 0 else 0.0,
                       cfg.delta_fn if h_r == 1 else 0.0)
        lt = np.where(offload & r2, beta, 0.0)
        lt = lt + np.where(explored & valid & ~r2, phi / cfg.eps, 0.0)
        w = w * np.exp(-cfg.eta * lt)
        w = np.where(valid, w / w.max(), 0.0)            # renormalization

    st, _ = run_stream(cfg, fs, hrs, betas, key)
    w_policy = np.where(valid, np.exp(np.asarray(st.log_w, np.float64)), 0.0)
    np.testing.assert_allclose(w_policy / w_policy.sum(), w / w.sum(),
                               atol=1e-4)
