"""Seeded arrival-process generation (`repro.data.traffic`): the
ScenarioSource bit-identity contract restated for asynchronous traffic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.traffic import ArrivalBatch, TrafficProcess


def _materialized(process, chunk, **kw):
    tp = TrafficProcess(
        process=process,
        rate=200.0,
        n_arrivals=256,
        n_sessions=8,
        chunk=chunk,
        key=jax.random.PRNGKey(42),
        **kw,
    )
    return tp, tp.materialize()


@pytest.mark.parametrize("process", ["poisson", "mmpp"])
def test_chunk_invariance_bit_identity(process):
    """The emitted timeline is bit-identical for ANY chunk size — the
    stateful MMPP regime carry included."""
    _, whole = _materialized(process, chunk=None)
    for chunk in (1, 32, 128):
        _, chunked = _materialized(process, chunk=chunk)
        for leaf_w, leaf_c in zip(whole, chunked):
            assert np.array_equal(np.asarray(leaf_w), np.asarray(leaf_c))


@pytest.mark.parametrize("process", ["poisson", "mmpp"])
def test_seed_determinism(process):
    _, a = _materialized(process, chunk=64)
    _, b = _materialized(process, chunk=64)
    for leaf_a, leaf_b in zip(a, b):
        assert np.array_equal(np.asarray(leaf_a), np.asarray(leaf_b))
    other = TrafficProcess(
        process=process, rate=200.0, n_arrivals=256, key=jax.random.PRNGKey(1)
    ).materialize()
    assert not np.array_equal(np.asarray(a.gaps), np.asarray(other.gaps))


def test_poisson_rate_and_field_sanity():
    tp, arr = _materialized("poisson", chunk=None)
    assert isinstance(arr, ArrivalBatch)
    gaps = np.asarray(arr.gaps)
    assert gaps.shape == (256,) and np.all(gaps > 0)
    # Mean interarrival ≈ 1/rate (CLT slack: ±40% is > 6 sigma at N=256).
    assert abs(gaps.mean() - 1.0 / tp.rate) < 0.4 / tp.rate
    sessions = np.asarray(arr.sessions)
    assert sessions.min() >= 0 and sessions.max() < 8
    fs = np.asarray(arr.fs)
    assert np.all((fs > 0.0) & (fs < 1.0))
    assert set(np.unique(np.asarray(arr.ys))) <= {0, 1}
    payloads = np.asarray(arr.payloads)
    assert np.all(payloads >= 4096.0 * 0.5) and np.all(payloads <= 4096.0 * 1.5)


def test_mmpp_bursts_raise_arrival_rate():
    """Burst episodes shorten gaps: the MMPP mean rate must sit strictly
    between the calm rate and the burst rate."""
    tp = TrafficProcess(
        process="mmpp",
        rate=50.0,
        burst_rate=500.0,
        p_burst=0.2,
        p_calm=0.2,
        n_arrivals=2048,
        key=jax.random.PRNGKey(0),
    )
    gaps = np.asarray(tp.materialize().gaps)
    mean_rate = 1.0 / gaps.mean()
    assert 60.0 < mean_rate < 450.0


def test_clean_rdl_labels_match_ground_truth():
    _, arr = _materialized("poisson", chunk=None)
    assert np.array_equal(np.asarray(arr.hrs), np.asarray(arr.ys))
    _, noisy = _materialized("poisson", chunk=None, rdl_fn=0.4, rdl_fp=0.4)
    assert not np.array_equal(np.asarray(noisy.hrs), np.asarray(noisy.ys))
    # The flips perturb only hrs: the rest of the timeline is unchanged.
    assert np.array_equal(np.asarray(arr.ys), np.asarray(noisy.ys))
    assert np.array_equal(np.asarray(arr.gaps), np.asarray(noisy.gaps))


def test_emit_leaves_are_chunk_shaped():
    tp = TrafficProcess(process="mmpp", rate=100.0, n_arrivals=64, chunk=16)
    state = tp.init_state()
    state, batch = tp.emit(state, tp.key, 0)
    for leaf in batch:
        assert leaf.shape == (16,)
    assert state.dtype == jnp.int32


@pytest.mark.parametrize(
    "kw",
    [
        {"process": "weibull"},
        {"rate": 0.0},
        {"n_arrivals": 100, "chunk": 32},
        {"n_sessions": 0},
        {"payload_jitter": 1.5},
        {"rdl_fn": 1.0},
        {"burst_rate": -1.0},
    ],
)
def test_validation(kw):
    with pytest.raises(ValueError):
        TrafficProcess(**{"rate": 100.0, "n_arrivals": 64, **kw})
