"""The bench-regression CI gate: row parsing (`benchmarks.run`), tolerance
comparison (`benchmarks.check_regression`), and the committed baseline."""

import json
import os

from benchmarks.check_regression import compare, untracked
from benchmarks.run import parse_row, rows_to_report

BASELINE = os.path.join(
    os.path.dirname(__file__), "..", "results", "bench_baseline.json"
)


def _report(**metrics_by_name):
    return {
        "meta": {},
        "benchmarks": {
            name: {"us_per_call": 100.0, "metrics": dict(metrics)}
            for name, metrics in metrics_by_name.items()
        },
    }


# -------------------------------- row parsing ---------------------------------


def test_parse_row_comma_separated_metrics():
    name, rec = parse_row(
        "scenario_stationary,123,cost=0.2052,true_cost=0.2052,offload_rate=0.407"
    )
    assert name == "scenario_stationary"
    assert rec["us_per_call"] == 123.0
    assert rec["metrics"] == {
        "cost": 0.2052,
        "true_cost": 0.2052,
        "offload_rate": 0.407,
    }


def test_parse_row_semicolon_and_string_values():
    name, rec = parse_row(
        "hedge_fleet_G16_S64_T2048_fused,42,us_per_round=0.02;engine=fused"
    )
    assert name == "hedge_fleet_G16_S64_T2048_fused"
    assert rec["metrics"]["us_per_round"] == 0.02
    assert rec["metrics"]["engine"] == "fused"


def test_parse_row_error_and_malformed():
    _, rec = parse_row("fig4,0,ERROR")
    assert rec.get("error")
    _, rec = parse_row("just-a-name")
    assert rec.get("error")


def test_rows_to_report_shape():
    report = rows_to_report(
        ["a,1,x=2", "b,3,y=4"], meta={"quick": True}
    )
    assert report["meta"] == {"quick": True}
    assert set(report["benchmarks"]) == {"a", "b"}
    assert report["benchmarks"]["a"]["metrics"]["x"] == 2.0


# ------------------------------- the tolerance gate ---------------------------


def test_compare_passes_within_tolerance():
    base = _report(bench={"cost": 1.0, "rate": 0.5})
    cur = _report(bench={"cost": 1.08, "rate": 0.52})
    assert compare(cur, base, tolerance=0.10) == []


def test_compare_fails_outside_tolerance():
    base = _report(bench={"cost": 1.0})
    cur = _report(bench={"cost": 1.2})
    failures = compare(cur, base, tolerance=0.10)
    assert len(failures) == 1 and "bench.cost" in failures[0]


def test_compare_absolute_floor_for_tiny_metrics():
    base = _report(bench={"drop_rate": 0.0})
    assert compare(_report(bench={"drop_rate": 0.01}), base) == []
    failures = compare(_report(bench={"drop_rate": 4.0}), base)
    assert len(failures) == 1


def test_compare_skips_discrete_restart_counts():
    """Alarm counts flip by whole units on ulp-level drift; they are
    excluded from the float gate (the cost metrics gate the behavior)."""
    base = _report(bench={"restarts": 4.0, "cost": 1.0})
    assert compare(_report(bench={"restarts": 5.0, "cost": 1.0}), base) == []


def test_compare_flags_missing_benchmark_and_metric():
    base = _report(a={"cost": 1.0}, b={"cost": 1.0})
    cur = _report(a={"other": 1.0})
    failures = compare(cur, base)
    assert any("a.cost" in f for f in failures)
    assert any(f.startswith("b:") for f in failures)


def test_compare_skips_strings_and_timing():
    base = _report(bench={"engine": "fused", "cost": 1.0})
    cur = {
        "meta": {},
        "benchmarks": {
            "bench": {
                "us_per_call": 9e9,  # timing never gated
                "metrics": {"engine": "reference", "cost": 1.0},
            }
        },
    }
    assert compare(cur, base) == []


def test_compare_skips_latency_percentiles():
    """p50/p95/p99 latency metrics are environment-shaped, never gated —
    while behavioral rates in the same row still gate (via the absolute
    floor when the baseline sits at zero, e.g. deny_rate below capacity)."""
    base = _report(
        rp={"p50_latency_ms": 20.0, "p99_latency_ms": 90.0, "deny_rate": 0.0}
    )
    cur = _report(
        rp={"p50_latency_ms": 55.0, "p99_latency_ms": 400.0, "deny_rate": 0.0}
    )
    assert compare(cur, base) == []
    bad = _report(
        rp={"p50_latency_ms": 20.0, "p99_latency_ms": 90.0, "deny_rate": 0.5}
    )
    failures = compare(bad, base)
    assert len(failures) == 1 and "deny_rate" in failures[0]


def test_compare_skips_bytes_metrics():
    """Memory-footprint metrics (`rand_bytes_peak` in the long-horizon
    kernel rows) are informational: they move whenever block sizes retune,
    while behavioral metrics in the same row keep gating."""
    base = _report(lh={"rand_bytes_peak": 8192.0, "cost": 1.0})
    cur = _report(lh={"rand_bytes_peak": 32768.0, "cost": 1.0})
    assert compare(cur, base) == []
    bad = _report(lh={"rand_bytes_peak": 8192.0, "cost": 2.0})
    failures = compare(bad, base)
    assert len(failures) == 1 and "lh.cost" in failures[0]


def test_compare_flags_errored_run():
    base = _report(bench={"cost": 1.0})
    cur = {"meta": {}, "benchmarks": {"bench": {"error": True, "metrics": {}}}}
    failures = compare(cur, base)
    assert failures and "errored" in failures[0]


def test_compare_flags_errored_baseline_record():
    base = {"meta": {}, "benchmarks": {"bench": {"error": True, "metrics": {}}}}
    cur = _report(bench={"cost": 1.0})
    failures = compare(cur, base)
    assert failures and "refresh the baseline" in failures[0]


def test_untracked_reports_new_benchmarks():
    base = _report(a={"cost": 1.0})
    cur = _report(a={"cost": 1.0}, b={"cost": 2.0})
    assert untracked(cur, base) == ["b"]
    assert untracked(base, base) == []


# ------------------------------ committed baseline ----------------------------


def test_committed_baseline_is_valid_and_covers_gated_modules():
    with open(BASELINE) as fh:
        baseline = json.load(fh)
    benches = baseline["benchmarks"]
    assert len(benches) >= 10
    # The gated CI subset: drift, scenarios, the three adaptive arms, the
    # request-plane load sweep, and the kernel rows (both randomness modes).
    for required in (
        "drift_h2t2_paper",
        "scenario_stationary",
        "adaptive_drift_ood_fixed",
        "adaptive_drift_ood_adaptive",
        "adaptive_drift_ood_oracle",
        "request_plane_poisson_x1",
        "request_plane_mmpp_x1",
        "hedge_fleet_G16_S16_T256_fused_counter",
        "hedge_longhorizon_S4_T51200_pre_draw",
        "hedge_longhorizon_S4_T51200_counter",
    ):
        assert required in benches, required
        metrics = benches[required]["metrics"]
        assert any(
            isinstance(v, (int, float)) for v in metrics.values()
        ), required
    # A baseline compares clean against itself.
    assert compare(baseline, baseline) == []
    # The headline result is pinned in the baseline itself: adaptive beats
    # fixed under OOD drift.
    fixed = benches["adaptive_drift_ood_fixed"]["metrics"]["true_cost"]
    adaptive = benches["adaptive_drift_ood_adaptive"]["metrics"]["true_cost"]
    assert adaptive < fixed
