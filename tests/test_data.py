"""Stream simulators: calibration matches Table 2/3; drift traces behave."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import StreamSpec
from repro.data import DATASETS, calibrate, dataset_trace, drift_trace, empirical_confusion


def test_stream_spec_rejects_bad_p1():
    with pytest.raises(ValueError, match="p1"):
        StreamSpec("bad", accuracy=0.7, fp=0.1, fn=0.2, p1=0.0)
    with pytest.raises(ValueError, match="p1"):
        StreamSpec("bad", accuracy=0.7, fp=0.1, fn=0.2, p1=1.0)
    with pytest.raises(ValueError, match="p1"):
        StreamSpec("bad", accuracy=0.7, fp=0.1, fn=0.2, p1=-0.3)


def test_stream_spec_rejects_fn_above_prior():
    # fn is a fraction of ALL samples; it cannot exceed the class-1 prior.
    with pytest.raises(ValueError, match="fn"):
        StreamSpec("bad", accuracy=0.5, fp=0.1, fn=0.4, p1=0.3)
    # Boundary fn == p1 is legal (every class-1 sample misclassified).
    StreamSpec("edge", accuracy=0.5, fp=0.1, fn=0.4, p1=0.4)


def test_stream_spec_rejects_fp_above_class0_prior():
    # Mirrored bound: fp cannot exceed the class-0 prior 1 - p1.
    with pytest.raises(ValueError, match="fp"):
        StreamSpec("bad", accuracy=0.5, fp=0.4, fn=0.1, p1=0.7)
    StreamSpec("edge", accuracy=0.5, fp=0.3, fn=0.2, p1=0.7)


def test_stream_spec_rejects_bad_confusion_total():
    with pytest.raises(ValueError, match="accuracy"):
        StreamSpec("bad", accuracy=0.5, fp=0.1, fn=0.1)


def test_stream_spec_accepts_all_paper_tables():
    for spec in DATASETS.values():       # construction re-runs __post_init__
        StreamSpec(spec.name, accuracy=spec.accuracy, fp=spec.fp,
                   fn=spec.fn, p1=spec.p1)


@pytest.mark.parametrize("name", sorted(DATASETS))
def test_stream_matches_table_statistics(name):
    spec = DATASETS[name]
    tr = dataset_trace(name, 40_000, jax.random.PRNGKey(0), beta=0.3)
    acc, fp, fn = empirical_confusion(tr)
    assert abs(fp - spec.fp) < 0.015, (name, fp, spec.fp)
    assert abs(fn - spec.fn) < 0.015, (name, fn, spec.fn)
    assert bool(jnp.all((tr.fs > 0) & (tr.fs < 1)))


def test_calibration_solver_consistency():
    for name, spec in DATASETS.items():
        params = calibrate(spec)
        assert 0 < params["p1"] < 1
        assert np.isfinite(params["mu1"]) and np.isfinite(params["mu0"])


def test_beta_modes():
    tr_fixed = dataset_trace("phishing", 500, jax.random.PRNGKey(1), beta=0.4)
    assert abs(float(jnp.min(tr_fixed.betas)) - 0.4) < 1e-6
    assert float(jnp.min(tr_fixed.betas)) == float(jnp.max(tr_fixed.betas))
    tr_rand = dataset_trace("phishing", 500, jax.random.PRNGKey(1), beta=0.4,
                            beta_mode="uniform")
    assert float(jnp.max(tr_rand.betas)) <= 0.4
    assert float(jnp.std(tr_rand.betas)) > 0.05


def test_drift_trace_changes_distribution():
    tr = drift_trace("breakhis", "breach", 20_000, jax.random.PRNGKey(2))
    first = empirical_confusion(type(tr)(tr.fs[:10_000], tr.hrs[:10_000],
                                         tr.betas[:10_000]))
    second = empirical_confusion(type(tr)(tr.fs[10_000:], tr.hrs[10_000:],
                                          tr.betas[10_000:]))
    assert first[0] > second[0] + 0.15   # accuracy collapses post-shift


def test_multistream_shapes():
    tr = dataset_trace("chest", 100, jax.random.PRNGKey(3), n_streams=4)
    assert tr.fs.shape == (4, 100) and tr.hrs.shape == (4, 100)
