"""Stream simulators: calibration matches Table 2/3; drift traces behave."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DATASETS, calibrate, dataset_trace, drift_trace, empirical_confusion


@pytest.mark.parametrize("name", sorted(DATASETS))
def test_stream_matches_table_statistics(name):
    spec = DATASETS[name]
    tr = dataset_trace(name, 40_000, jax.random.PRNGKey(0), beta=0.3)
    acc, fp, fn = empirical_confusion(tr)
    assert abs(fp - spec.fp) < 0.015, (name, fp, spec.fp)
    assert abs(fn - spec.fn) < 0.015, (name, fn, spec.fn)
    assert bool(jnp.all((tr.fs > 0) & (tr.fs < 1)))


def test_calibration_solver_consistency():
    for name, spec in DATASETS.items():
        params = calibrate(spec)
        assert 0 < params["p1"] < 1
        assert np.isfinite(params["mu1"]) and np.isfinite(params["mu0"])


def test_beta_modes():
    tr_fixed = dataset_trace("phishing", 500, jax.random.PRNGKey(1), beta=0.4)
    assert abs(float(jnp.min(tr_fixed.betas)) - 0.4) < 1e-6
    assert float(jnp.min(tr_fixed.betas)) == float(jnp.max(tr_fixed.betas))
    tr_rand = dataset_trace("phishing", 500, jax.random.PRNGKey(1), beta=0.4,
                            beta_mode="uniform")
    assert float(jnp.max(tr_rand.betas)) <= 0.4
    assert float(jnp.std(tr_rand.betas)) > 0.05


def test_drift_trace_changes_distribution():
    tr = drift_trace("breakhis", "breach", 20_000, jax.random.PRNGKey(2))
    first = empirical_confusion(type(tr)(tr.fs[:10_000], tr.hrs[:10_000],
                                         tr.betas[:10_000]))
    second = empirical_confusion(type(tr)(tr.fs[10_000:], tr.hrs[10_000:],
                                          tr.betas[10_000:]))
    assert first[0] > second[0] + 0.15   # accuracy collapses post-shift


def test_multistream_shapes():
    tr = dataset_trace("chest", 100, jax.random.PRNGKey(3), n_streams=4)
    assert tr.fs.shape == (4, 100) and tr.hrs.shape == (4, 100)
