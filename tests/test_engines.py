"""PolicyEngine protocol + registry: resolution, cross-engine parity, the
decide/feedback split, and the sharded (device-mesh) engine.

The sharded tests use however many devices are visible; CI runs the whole
suite a second time under XLA_FLAGS=--xla_force_host_platform_device_count=8
so the multi-device path (including S not divisible by the device count) is
exercised on every push. A `slow`-marked subprocess test forces 8 devices
locally too.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HIConfig,
    draw_psi_zeta,
    fleet_decide,
    fleet_feedback,
    fleet_init,
    h2t2_step,
    local_fallback_pred,
)
from repro.serving import (
    AdaptiveEngine,
    FusedEngine,
    PolicyEngine,
    ReferenceEngine,
    ShardedEngine,
    available_engines,
    get_engine,
    register_engine,
)


from conftest import fleet_trace as _fleet_trace


def _assert_outputs_equal(a, b, atol=1e-5):
    assert np.array_equal(np.asarray(a.offload), np.asarray(b.offload))
    assert np.array_equal(np.asarray(a.pred), np.asarray(b.pred))
    assert np.array_equal(np.asarray(a.local_pred), np.asarray(b.local_pred))
    assert np.array_equal(np.asarray(a.explored), np.asarray(b.explored))
    np.testing.assert_allclose(np.asarray(a.loss), np.asarray(b.loss),
                               atol=atol)


def _assert_states_close(a, b, atol=1e-4):
    valid = np.isfinite(np.asarray(a.log_w))
    np.testing.assert_allclose(np.asarray(b.log_w)[valid],
                               np.asarray(a.log_w)[valid], atol=atol)
    assert np.array_equal(np.asarray(a.n_offloads), np.asarray(b.n_offloads))
    assert np.array_equal(np.asarray(a.t), np.asarray(b.t))


# --------------------------------- registry -----------------------------------


def test_registry_resolves_all_engines():
    assert set(available_engines()) >= {"reference", "fused", "sharded",
                                        "adaptive"}
    cfg = HIConfig(bits=3)
    assert isinstance(get_engine("reference", cfg), ReferenceEngine)
    assert isinstance(get_engine("fused", cfg), FusedEngine)
    assert isinstance(get_engine("sharded", cfg), ShardedEngine)
    assert isinstance(get_engine("adaptive", cfg), AdaptiveEngine)


def test_registry_unknown_engine_raises():
    with pytest.raises(ValueError, match="engine"):
        get_engine("warp-drive", HIConfig())


def test_register_engine_extends_registry():
    @register_engine("_test_dummy")
    class Dummy(ReferenceEngine):
        pass

    try:
        assert "_test_dummy" in available_engines()
        assert isinstance(get_engine("_test_dummy", HIConfig(bits=2)), Dummy)
    finally:
        from repro.serving import policy_engine
        del policy_engine._REGISTRY["_test_dummy"]


# --------------------------- cross-engine parity ------------------------------


def test_reference_vs_fused_step_identical():
    """The acceptance bar: reference and fused make decision-for-decision
    identical slot steps for the same per-stream keys."""
    cfg = HIConfig(bits=4, eps=0.1, eta=1.0)
    s = 8
    ref = get_engine("reference", cfg)
    fus = get_engine("fused", cfg)
    state = ref.init(s)
    key = jax.random.PRNGKey(23)
    for t in range(5):
        key, k1, k2 = jax.random.split(key, 3)
        fs = jax.random.uniform(k1, (s,))
        hrs = jax.random.bernoulli(k2, 0.5, (s,)).astype(jnp.int32)
        betas = jnp.full((s,), 0.25)
        keys = jax.random.split(jax.random.fold_in(key, t), s)
        s_ref, o_ref = ref.step(state, fs, betas, hrs, keys)
        s_fus, o_fus = fus.step(state, fs, betas, hrs, keys)
        _assert_outputs_equal(o_ref, o_fus, atol=1e-6)
        _assert_states_close(s_ref, s_fus, atol=1e-5)
        state = s_fus


@pytest.mark.parametrize("name", ["reference", "fused", "sharded"])
def test_engine_run_matches_reference_run(name):
    cfg = HIConfig(bits=3, eps=0.1, eta=0.9)
    fs, hrs, betas = _fleet_trace(jax.random.PRNGKey(1), 8, 96)
    key = jax.random.PRNGKey(11)
    st_ref, o_ref = get_engine("reference", cfg).run(fs, hrs, betas, key)
    st_eng, o_eng = get_engine(name, cfg).run(fs, hrs, betas, key)
    _assert_outputs_equal(o_ref, o_eng)
    _assert_states_close(st_ref, st_eng)


def test_engine_run_stream_keys_pins_randomness():
    cfg = HIConfig(bits=3, eps=0.05)
    fs, hrs, betas = _fleet_trace(jax.random.PRNGKey(2), 4, 48)
    key = jax.random.PRNGKey(3)
    stream_keys = jax.random.split(key, 4)
    for name in available_engines():
        _, via_key = get_engine(name, cfg).run(fs, hrs, betas, key)
        _, via_sk = get_engine(name, cfg).run(fs, hrs, betas,
                                              stream_keys=stream_keys)
        assert np.array_equal(np.asarray(via_key.offload),
                              np.asarray(via_sk.offload)), name


def test_fused_time_block_through_engine():
    cfg = HIConfig(bits=4, eps=0.1, eta=1.0)
    fs, hrs, betas = _fleet_trace(jax.random.PRNGKey(3), 8, 64)
    key = jax.random.PRNGKey(17)
    _, o1 = get_engine("fused", cfg).run(fs, hrs, betas, key)
    _, o8 = get_engine("fused", cfg, interpret=True,
                       time_block=8).run(fs, hrs, betas, key)
    assert np.array_equal(np.asarray(o1.offload), np.asarray(o8.offload))
    np.testing.assert_allclose(np.asarray(o1.loss), np.asarray(o8.loss),
                               atol=1e-5)


# ---------------------------- decide/feedback split ---------------------------


def test_decide_plus_feedback_equals_h2t2_step():
    """fleet_decide ∘ fleet_feedback (full labels, immediate) reproduces the
    vmapped `h2t2_step` exactly — states and every output leaf."""
    cfg = HIConfig(bits=3, eps=0.1, eta=0.9, decay=0.97)
    s = 8
    state = fleet_init(cfg, s)
    key = jax.random.PRNGKey(0)
    step = jax.vmap(lambda st, f, b, hr, k: h2t2_step(cfg, st, f, b, hr, k))
    for t in range(10):
        key, k1, k2, k3 = jax.random.split(key, 4)
        fs = jax.random.uniform(k1, (s,))
        hrs = jax.random.bernoulli(k2, 0.5, (s,)).astype(jnp.int32)
        betas = jnp.full((s,), 0.3)
        keys = jax.random.split(k3, s)
        st_ref, o_ref = step(state, fs, betas, hrs, keys)
        psi, zeta = draw_psi_zeta(keys, cfg.eps)
        dec = fleet_decide(cfg, state, fs, psi, zeta)
        st_df, o_df = fleet_feedback(cfg, state, dec, hrs, betas)
        _assert_outputs_equal(o_ref, o_df, atol=1e-6)
        np.testing.assert_allclose(np.asarray(o_ref.q), np.asarray(o_df.q),
                                   atol=1e-6)
        _assert_states_close(st_ref, st_df, atol=1e-6)
        state = st_ref


def test_engine_decide_feedback_matches_step():
    """Every engine's decide+feedback composition equals its own step."""
    cfg = HIConfig(bits=4, eps=0.1, eta=1.0)
    s = 6
    fs = jax.random.uniform(jax.random.PRNGKey(1), (s,))
    hrs = jax.random.bernoulli(jax.random.PRNGKey(2), 0.5, (s,)).astype(jnp.int32)
    betas = jnp.full((s,), 0.3)
    keys = jax.random.split(jax.random.PRNGKey(4), s)
    for name in available_engines():
        eng = get_engine(name, cfg)
        state = eng.init(s)
        st_step, o_step = eng.step(state, fs, betas, hrs, keys)
        dec = eng.decide(state, fs, keys)
        st_df, o_df = eng.feedback(state, dec, hrs, betas)
        assert np.array_equal(np.asarray(o_step.offload),
                              np.asarray(o_df.offload)), name
        _assert_states_close(st_step, st_df, atol=1e-5)


def test_feedback_sent_mask_drops_capacity_overflow():
    """Offloads masked out of `sent` revert to local: no β, no weight update
    from their (unobserved) label."""
    cfg = HIConfig(bits=3, eps=0.0, eta=1.0)   # ε=0: offload ⇔ region-2 draw
    s = 4
    state = fleet_init(cfg, s)
    fs = jnp.full((s,), 0.5)
    psi = jnp.zeros((s,))                       # ψ=0 ≤ q → all offload
    zeta = jnp.zeros((s,), bool)
    dec = fleet_decide(cfg, state, fs, psi, zeta)
    assert bool(jnp.all(dec.offload))
    hrs = jnp.ones((s,), jnp.int32)
    betas = jnp.full((s,), 0.4)
    sent = jnp.asarray([True, True, False, False])
    st, out = fleet_feedback(cfg, state, dec, hrs, betas, sent=sent)
    assert np.array_equal(np.asarray(out.offload), np.asarray(sent))
    # Dropped streams fall back to the conditional local draw (NOT the raw
    # local_pred, which is deterministically 1 for a region-2 offload)...
    assert np.array_equal(np.asarray(out.pred[2:]),
                          np.asarray(local_fallback_pred(dec)[2:]))
    # ...pay φ not β, and contribute no offload count.
    assert np.array_equal(np.asarray(st.n_offloads), [1, 1, 0, 0])
    # Sent streams' experts got the β pseudo-loss; dropped streams' did not.
    assert not np.allclose(np.asarray(st.log_w[0]), np.asarray(st.log_w[2]),
                           atol=1e-6)


# ------------------------------ sharded engine --------------------------------


@pytest.mark.parametrize("s", [8, 12, 3])
def test_sharded_matches_fused_any_stream_count(s):
    """Sharded ≡ fused for S divisible and NOT divisible by the device count
    (padding path), on however many devices this process sees."""
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    fs, hrs, betas = _fleet_trace(jax.random.PRNGKey(s), s, 64)
    key = jax.random.PRNGKey(7)
    st_f, o_f = get_engine("fused", cfg).run(fs, hrs, betas, key)
    st_s, o_s = get_engine("sharded", cfg).run(fs, hrs, betas, key)
    _assert_outputs_equal(o_f, o_s)
    _assert_states_close(st_f, st_s)


def test_sharded_step_matches_fused_step():
    cfg = HIConfig(bits=3, eps=0.1)
    s = 5
    fus = get_engine("fused", cfg)
    shd = get_engine("sharded", cfg)
    state = shd.init(s)
    fs = jax.random.uniform(jax.random.PRNGKey(0), (s,))
    hrs = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (s,)).astype(jnp.int32)
    betas = jnp.full((s,), 0.2)
    keys = jax.random.split(jax.random.PRNGKey(2), s)
    s_f, o_f = fus.step(state, fs, betas, hrs, keys)
    s_s, o_s = shd.step(state, fs, betas, hrs, keys)
    _assert_outputs_equal(o_f, o_s)
    _assert_states_close(s_f, s_s)
    assert o_s.offload.shape == (s,)
    assert s_s.log_w.shape == s_f.log_w.shape


def test_sharded_mesh_spans_all_devices():
    eng = get_engine("sharded", HIConfig(bits=2))
    assert eng.n_devices == len(jax.devices())
    assert eng.mesh.shape == {"streams": eng.n_devices}


@pytest.mark.slow
def test_sharded_parity_under_8_fake_devices_subprocess():
    """Force 8 host devices in a clean interpreter and re-check parity with a
    stream count that does not divide evenly (S=12 over 8 devices)."""
    code = """
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from repro.core import HIConfig
from repro.serving import get_engine
cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
ks = jax.random.split(jax.random.PRNGKey(0), 3)
s, t = 12, 64
fs = jax.random.uniform(ks[0], (s, t))
hrs = jax.random.bernoulli(ks[1], 0.5, (s, t)).astype(jnp.int32)
betas = jnp.full((s, t), 0.3)
key = jax.random.PRNGKey(7)
_, o_f = get_engine("fused", cfg).run(fs, hrs, betas, key)
_, o_s = get_engine("sharded", cfg).run(fs, hrs, betas, key)
assert np.array_equal(np.asarray(o_f.offload), np.asarray(o_s.offload))
np.testing.assert_allclose(np.asarray(o_f.loss), np.asarray(o_s.loss), atol=1e-5)
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
