import os
import sys

# Tests run on the single real CPU device; ONLY dryrun.py forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# Repo root, so tests can drive the benchmark harness (`import benchmarks`).
sys.path.insert(1, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module", autouse=True)
def _clear_jax_caches_between_modules():
    """Release compiled executables at module boundaries: the accumulated
    live-executable load of the full suite can segfault XLA:CPU's compiler
    late in the run (jax 0.4.37), and no module needs another's jit cache."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def fleet_trace(key, s, t, beta=0.3):
    """Random (fs, hrs, betas) fleet trace shared by the engine/fleet suites."""
    ks = jax.random.split(key, 3)
    fs = jax.random.uniform(ks[0], (s, t))
    hrs = jax.random.bernoulli(ks[1], 0.5, (s, t)).astype(jnp.int32)
    betas = jnp.full((s, t), beta)
    return fs, hrs, betas
