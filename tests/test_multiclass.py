"""Multiclass online HI policy (beyond-paper §6 extension)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HIConfig
from repro.core.multiclass import (
    mc_init,
    mc_no_offload_loss,
    mc_offline_best,
    mc_run_stream,
    mc_step,
)


def _stream(key, t, k=3, miscal=0.0, scale=2.0):
    """Synthetic K-class stream: true label y, softmax = noisy one-hot with
    optional miscalibration (temperature distortion)."""
    ky, kn = jax.random.split(key)
    y = jax.random.randint(ky, (t,), 0, k)
    logits = scale * jax.nn.one_hot(y, k) + jax.random.normal(kn, (t, k))
    logits = logits * (1.0 - miscal)
    return jax.nn.softmax(logits, axis=-1), y


COST = jnp.asarray([[0.0, 0.7, 0.9],
                    [1.0, 0.0, 0.6],
                    [0.8, 0.5, 0.0]])


def test_mc_step_shapes():
    cfg = HIConfig(bits=4, eps=0.1)
    st = mc_init(cfg)
    f = jnp.asarray([0.2, 0.5, 0.3])
    st2, out = mc_step(cfg, st, f, COST, jnp.asarray(0.3), jnp.asarray(1),
                       jax.random.PRNGKey(0))
    assert st2.log_w.shape == (cfg.grid + 1,)
    assert out.pred.shape == () and out.loss.shape == ()


def test_mc_learns_vs_naive():
    """Online τ-policy beats no-offload on an ambiguous stream (weak local
    model, cheap offload) and lands within 40% of the offline-best fixed τ."""
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    fs, hrs = _stream(jax.random.PRNGKey(0), 6000, miscal=0.5, scale=1.2)
    betas = jnp.full((6000,), 0.15)
    _, out = mc_run_stream(cfg, fs, COST, betas, hrs, jax.random.PRNGKey(1))
    algo = float(jnp.sum(out.loss))
    no = float(mc_no_offload_loss(fs, COST, hrs))
    best = float(mc_offline_best(cfg, fs, COST, betas, hrs))
    assert algo < no
    assert algo <= 1.40 * best, (algo, best, no)


def test_mc_matches_theorem3_when_calibrated():
    """With a calibrated stream, the learned τ should sit near β: the offline
    best fixed τ's decision rule agrees with Theorem 3's β-threshold on most
    rounds."""
    cfg = HIConfig(bits=5, eps=0.05, eta=1.0)
    key = jax.random.PRNGKey(2)
    t, k = 8000, 3
    # Calibrated: draw f on the simplex, then y | f ~ Categorical(f).
    f_raw = jax.random.dirichlet(key, jnp.ones(k), (t,))
    y = jax.random.categorical(jax.random.fold_in(key, 1), jnp.log(f_raw))
    beta = 0.25
    betas = jnp.full((t,), beta)
    best = float(mc_offline_best(cfg, f_raw, COST, betas, y))
    # Theorem-3 oracle loss on the same trace.
    risks = jnp.min(f_raw @ COST, axis=-1)
    preds = jnp.argmin(f_raw @ COST, axis=-1)
    phi = COST[y, preds]
    thm3 = float(jnp.sum(jnp.where(risks > beta, beta, phi)))
    assert best <= thm3 * 1.02 + 1e-3   # grid contains (≈) the oracle rule


def test_mc_exploration_keeps_offloading():
    cfg = HIConfig(bits=3, eps=0.2)
    fs, hrs = _stream(jax.random.PRNGKey(3), 500)
    betas = jnp.full((500,), 0.9)   # offload almost never worth it
    st, out = mc_run_stream(cfg, fs, COST, betas, hrs, jax.random.PRNGKey(4))
    rate = float(jnp.mean(out.offload))
    assert 0.05 < rate < 0.6        # ε-exploration keeps feedback flowing
