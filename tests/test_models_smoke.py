"""Per-arch smoke tests: REDUCED variant of each assigned family, one forward
+ one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED
from repro.models import forward, init_params, param_count
from repro.training import AdamWConfig, TrainState, build_train_step, init_opt_state

B, S = 2, 32


def _inputs(cfg, key, with_labels=True):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)
    inputs = {"tokens": toks}
    if cfg.family == "vlm":
        inputs["patches"] = 0.1 * jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), cfg.dtype)
    if cfg.family == "encdec":
        inputs["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.n_frames, cfg.d_model), cfg.dtype)
    if with_labels:
        inputs["labels"] = jnp.roll(toks, -1, axis=1)
        inputs["mask"] = jnp.ones((B, S), jnp.float32)
    return inputs


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward_shapes_and_finite(arch, rng):
    cfg = ARCHS[arch].reduced()
    assert cfg.d_model <= 512 and (not cfg.n_experts or cfg.n_experts <= 4)
    params = init_params(rng, cfg)
    logits, aux = forward(params, cfg, _inputs(cfg, rng, with_labels=False))
    seq = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, seq, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_one_train_step(arch, rng):
    cfg = ARCHS[arch].reduced()
    params = init_params(rng, cfg)
    state = TrainState(params=params, opt=init_opt_state(params))
    step = build_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1))
    state2, metrics = step(state, _inputs(cfg, rng))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # Params actually moved.
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, state2.params)
    assert max(jax.tree.leaves(moved)) > 0


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the exact assigned dimensions."""
    expect = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        c = ARCHS[arch]
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
            == (L, d, h, kv, ff, v), arch
        assert c.source, f"{arch} missing citation"


def test_moe_and_special_fields():
    dsv2 = ARCHS["deepseek-v2-236b"]
    assert dsv2.n_experts == 160 and dsv2.top_k == 6 and dsv2.n_shared_experts == 2
    assert dsv2.use_mla and dsv2.kv_lora_rank == 512
    mix = ARCHS["mixtral-8x7b"]
    assert mix.n_experts == 8 and mix.top_k == 2 and mix.sliding_window == 4096
    m2 = ARCHS["mamba2-780m"]
    assert m2.ssm_state == 128
    qw = ARCHS["qwen2-1.5b"]
    assert qw.qkv_bias
    rg = ARCHS["recurrentgemma-2b"]
    assert rg.pattern == ("rec", "rec", "attn") and rg.sliding_window == 2048


def test_param_count_sanity():
    """Full config param counts land near the nameplate sizes."""
    from repro.models.model import active_param_count

    for arch, lo, hi in [
        ("qwen2-1.5b", 1.2e9, 2.2e9),
        ("granite-3-2b", 2.0e9, 3.6e9),
        ("yi-34b", 30e9, 40e9),
        ("deepseek-coder-33b", 30e9, 40e9),
        ("mamba2-780m", 0.6e9, 1.1e9),
        ("recurrentgemma-2b", 2.0e9, 3.6e9),
    ]:
        n = active_param_count(ARCHS[arch])
        assert lo < n < hi, f"{arch}: {n:.2e}"
