"""Public-API pins: export surface, ExecSpec shims, and registry identity.

Three guarantees this suite freezes:

  1. The export surface of `repro.core` / `repro.serving` — additions are
     deliberate (update the pin), removals are breaking.
  2. The deprecated per-call kwargs (`use_kernel=`, `interpret=`,
     `randomness=`, `time_block=`, and the engine-opt spellings) still
     work, produce BIT-IDENTICAL results to their `spec=ExecSpec(...)`
     equivalents, and emit exactly one DeprecationWarning per resolved
     call. In-repo code never warns: pytest.ini escalates
     DeprecationWarning from `repro`/`benchmarks` modules to errors, so
     the shims are only exercised here, from test modules.
  3. Routing `learner="dense"` through the registry is the identity: the
     registry-spec path reproduces the default path bit-for-bit on every
     engine and both randomness modes.
"""
import importlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core
import repro.serving
from conftest import fleet_trace as _fleet_trace
from repro.core import ExecSpec, HIConfig, run_fleet_fused
from repro.serving import HIServerConfig, get_engine
from repro.serving.request_plane import RequestPlaneConfig

CORE_EXPORTS = frozenset({
    "COUNTER_CAP", "CounterRNG", "RANDOMNESS_MODES",
    "ExecSpec", "Registry", "UNSET",
    "HIConfig", "StreamSpec", "FleetDecision", "H2T2State",
    "ShiftConfig", "ShiftState",
    "SourceRunOutput", "StepOutput", "adapt_schedule", "classification_cost",
    "counter_rng", "detect_shifts",
    "draw_fleet_randomness", "draw_fleet_slot_randomness",
    "draw_psi_zeta", "effective_local_pred",
    "fleet_decide", "fleet_feedback", "fleet_init", "fleet_restart",
    "fleet_rounds_fused", "fleet_step_fused",
    "get_learner", "h2t2_init", "h2t2_step", "list_learners",
    "local_fallback_pred", "pseudo_loss",
    "psi_zeta_from_counter", "quantize", "region_masks", "register_learner",
    "resolve_spec",
    "run_fleet", "run_fleet_fused", "run_fleet_source", "run_stream",
    "seed_from_key", "shift_init", "shift_update",
    "source_slot_keys", "true_loss_fleet",
    "CalibratedDecision", "calibrated_rule", "chow_rule",
    "multiclass_regions", "multiclass_rule", "optimal_thresholds",
    "baselines", "multiclass", "offline", "regret",
})

SERVING_EXPORTS = frozenset({
    "AdaptiveEngine", "AdaptiveState",
    "Engine", "EngineConfig", "FusedEngine", "HIServer", "HIServerConfig",
    "HIServerState", "OffloadBatch", "PendingFeedback", "PolicyEngine",
    "ReferenceEngine", "ShardedEngine", "SlotResult", "available_engines",
    "classifier_fn", "compact_offloads", "get_engine", "list_engines",
    "register_engine", "rotated_compact", "scatter_results",
})


def _tree_equal(a, b):
    fa, fb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def _deprecations(record):
    return [w for w in record if issubclass(w.category, DeprecationWarning)]


# ------------------------------ export surface --------------------------------


def test_core_export_surface_is_pinned():
    assert set(repro.core.__all__) == CORE_EXPORTS
    for name in CORE_EXPORTS:
        assert getattr(repro.core, name, None) is not None, name


def test_serving_export_surface_is_pinned():
    assert set(repro.serving.__all__) == SERVING_EXPORTS
    for name in SERVING_EXPORTS:
        assert getattr(repro.serving, name, None) is not None, name


def test_streams_module_is_a_warning_shim():
    sys.modules.pop("repro.data.streams", None)
    with pytest.warns(DeprecationWarning, match="repro.data.streams"):
        import repro.data.streams as streams
        importlib.reload(streams)
    # The shim's names are the scenarios module's objects, not copies.
    import repro.data.scenarios as scenarios
    assert streams.sample_trace is scenarios.sample_trace
    assert streams.Trace is scenarios.Trace


# --------------------------- deprecated kwarg shims ---------------------------


def test_run_fleet_fused_kwargs_warn_once_and_match_spec():
    cfg = HIConfig(bits=3, eps=0.1, eta=1.0)
    fs, hrs, betas = _fleet_trace(jax.random.PRNGKey(0), 4, 64)
    key = jax.random.PRNGKey(7)
    spec = ExecSpec(use_kernel=True, interpret=True, time_block=4)
    want = run_fleet_fused(cfg, fs, hrs, betas, key, spec=spec)
    with pytest.warns(DeprecationWarning, match="spec=ExecSpec") as record:
        got = run_fleet_fused(cfg, fs, hrs, betas, key,
                              use_kernel=True, interpret=True, time_block=4)
    assert len(_deprecations(record)) == 1
    _tree_equal(want, got)


def test_fleet_decide_kwargs_warn_once_and_match_spec():
    from repro.core import fleet_decide, fleet_init

    cfg = HIConfig(bits=3, eps=0.1)
    state = fleet_init(cfg, 8)
    key = jax.random.PRNGKey(1)
    fs = jax.random.uniform(key, (8,))
    psi = jax.random.uniform(jax.random.fold_in(key, 1), (8,))
    zeta = jnp.zeros((8,), jnp.int32)
    want = fleet_decide(cfg, state, fs, psi, zeta,
                        spec=ExecSpec(use_kernel=False))
    with pytest.warns(DeprecationWarning, match="fleet_decide") as record:
        got = fleet_decide(cfg, state, fs, psi, zeta, use_kernel=False)
    assert len(_deprecations(record)) == 1
    _tree_equal(want, got)


def test_get_engine_legacy_opts_warn_once_and_match_spec():
    cfg = HIConfig(bits=3, eps=0.1, eta=1.0)
    fs, hrs, betas = _fleet_trace(jax.random.PRNGKey(2), 4, 64)
    key = jax.random.PRNGKey(11)
    spec = ExecSpec(randomness="counter")
    want = get_engine("fused", cfg, spec=spec).run(fs, hrs, betas, key)
    with pytest.warns(DeprecationWarning, match="get_engine") as record:
        eng = get_engine("fused", cfg, randomness="counter")
    assert len(_deprecations(record)) == 1
    assert eng.spec == spec
    _tree_equal(want, eng.run(fs, hrs, betas, key))


def test_engine_constructor_kwargs_warn_once():
    from repro.serving import FusedEngine

    cfg = HIConfig(bits=3)
    with pytest.warns(DeprecationWarning, match="FusedEngine") as record:
        eng = FusedEngine(cfg, use_kernel=False)
    assert len(_deprecations(record)) == 1
    assert eng.spec == ExecSpec(use_kernel=False)


def test_spec_only_paths_do_not_warn():
    import warnings

    cfg = HIConfig(bits=3, eps=0.1, eta=1.0)
    fs, hrs, betas = _fleet_trace(jax.random.PRNGKey(3), 2, 32)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng = get_engine("fused", cfg, spec=ExecSpec())
        eng.run(fs, hrs, betas, jax.random.PRNGKey(1))
        run_fleet_fused(cfg, fs, hrs, betas, jax.random.PRNGKey(1),
                        spec=ExecSpec())


def test_configs_sync_legacy_fields_into_spec():
    cfg = HIConfig(bits=3)
    hs = HIServerConfig(hi=cfg, n_streams=4, randomness="counter",
                        use_kernel=False)
    assert hs.spec == ExecSpec(use_kernel=False, randomness="counter")
    rp = RequestPlaneConfig(hi=cfg, n_streams=4, randomness="counter")
    assert rp.spec.randomness == "counter"
    # And the spec-first spelling keeps the mirror attributes coherent.
    hs2 = HIServerConfig(hi=cfg, n_streams=4,
                         spec=ExecSpec(randomness="counter", time_block=4))
    assert hs2.randomness == "counter" and hs2.time_block == 4


# --------------------------- dense registry identity --------------------------


@pytest.mark.parametrize("engine", ["reference", "fused", "sharded",
                                    "adaptive"])
@pytest.mark.parametrize("randomness", ["pre_draw", "counter"])
def test_dense_registry_path_is_identity(engine, randomness):
    """learner='dense' through the registry == the pre-registry default."""
    cfg = HIConfig(bits=3, eps=0.1, eta=1.0)
    fs, hrs, betas = _fleet_trace(jax.random.PRNGKey(4), 4, 64)
    key = jax.random.PRNGKey(13)
    default = get_engine(engine, cfg,
                         spec=ExecSpec(randomness=randomness))
    named = get_engine(engine, cfg,
                       spec=ExecSpec(learner="dense", randomness=randomness))
    _tree_equal(default.run(fs, hrs, betas, key),
                named.run(fs, hrs, betas, key))
