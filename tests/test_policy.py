"""Unit tests for the H2T2 policy (Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    HIConfig,
    h2t2_init,
    h2t2_step,
    pseudo_loss,
    quantize,
    region_masks,
    run_stream,
)


CFG = HIConfig(bits=4, delta_fp=0.7, delta_fn=1.0, eps=0.1, eta=1.0)


def test_expert_count_formula():
    # |Θ| = 2^{b-1}(2^b + 1)
    for b in (2, 3, 4, 6, 8):
        cfg = HIConfig(bits=b)
        assert cfg.n_experts == 2 ** (b - 1) * (2**b + 1)


def test_init_uniform_weights():
    st = h2t2_init(CFG)
    g = CFG.grid
    valid = np.tril(np.ones((g, g)), -1) == 0  # l <= u upper triangle inc. diag
    lw = np.asarray(st.log_w)
    assert np.all(lw[valid.T == False] == 0) or True  # noqa: E712 — see below
    l = np.arange(g)[:, None]
    u = np.arange(g)[None, :]
    assert np.all(lw[l <= u] == 0.0)
    assert np.all(np.isneginf(lw[l > u]))


def test_regions_partition_experts():
    g = CFG.grid
    for i_f in range(g):
        r1, r2, r3 = region_masks(jnp.asarray(i_f), g)
        r1, r2, r3 = map(np.asarray, (r1, r2, r3))
        valid = np.arange(g)[:, None] <= np.arange(g)[None, :]
        # Disjoint and exhaustive over valid experts.
        assert not np.any(r1 & r2) and not np.any(r2 & r3) and not np.any(r1 & r3)
        assert np.array_equal(r1 | r2 | r3, valid)


def test_quantize_bounds():
    g = CFG.grid
    q = quantize(jnp.asarray([0.0, 0.9999, 1.0, 0.5]), CFG.bits)
    assert q[0] == 0 and q[1] == g - 1 and q[2] == g - 1 and q[3] == g // 2


def test_offload_probability_matches_region_mass():
    """With uniform weights, q_t must equal (# region-2 experts)/|Θ|."""
    st = h2t2_init(CFG)
    f = jnp.asarray(0.5)
    _, out = h2t2_step(CFG, st, f, jnp.asarray(0.3), jnp.asarray(1),
                       jax.random.PRNGKey(0))
    g = CFG.grid
    i_f = int(quantize(f, CFG.bits))
    r1, r2, r3 = region_masks(jnp.asarray(i_f), g)
    expect_q = float(jnp.sum(r2)) / CFG.n_experts
    expect_p = float(jnp.sum(r3)) / CFG.n_experts
    assert abs(float(out.q) - expect_q) < 1e-5
    assert abs(float(out.p) - expect_p) < 1e-5


def test_pseudo_loss_zero_without_offload():
    lt = pseudo_loss(CFG, jnp.asarray(5), jnp.asarray(False), jnp.asarray(False),
                     jnp.asarray(1), jnp.asarray(0.3))
    assert float(jnp.max(jnp.abs(lt))) == 0.0


def test_pseudo_loss_ambiguous_get_beta_on_offload():
    i_f = jnp.asarray(7)
    lt = pseudo_loss(CFG, i_f, jnp.asarray(True), jnp.asarray(False),
                     jnp.asarray(1), jnp.asarray(0.25))
    _, r2, _ = region_masks(i_f, CFG.grid)
    lt, r2 = np.asarray(lt), np.asarray(r2)
    assert np.allclose(lt[r2], 0.25)
    assert np.allclose(lt[~r2], 0.0)


def test_pseudo_loss_exploration_scales_phi_by_eps():
    i_f = jnp.asarray(3)
    h_r = jnp.asarray(0)
    lt = pseudo_loss(CFG, i_f, jnp.asarray(True), jnp.asarray(True),
                     h_r, jnp.asarray(0.25))
    r1, r2, r3 = region_masks(i_f, CFG.grid)
    lt = np.asarray(lt)
    # h_r=0: experts predicting 1 (region 3) are FPs → δ₁/ε; region 1 correct → 0.
    assert np.allclose(lt[np.asarray(r3)], CFG.delta_fp / CFG.eps)
    assert np.allclose(lt[np.asarray(r1)], 0.0)
    assert np.allclose(lt[np.asarray(r2)], 0.25)


def test_weights_only_decrease_and_stay_normalized():
    key = jax.random.PRNGKey(1)
    fs = jax.random.uniform(key, (200,))
    hrs = jax.random.bernoulli(key, 0.5, (200,)).astype(jnp.int32)
    betas = jnp.full((200,), 0.3)
    st, _ = run_stream(CFG, fs, hrs, betas, key)
    lw = np.asarray(st.log_w)
    g = CFG.grid
    l = np.arange(g)[:, None]
    u = np.arange(g)[None, :]
    assert np.max(lw[l <= u]) <= 1e-6          # renormalized: max ≈ 0
    assert np.all(np.isneginf(lw[l > u]))      # invalid stay dead
    assert np.all(np.isfinite(lw[l <= u]))


def test_deterministic_given_key():
    key = jax.random.PRNGKey(2)
    fs = jax.random.uniform(key, (50,))
    hrs = jax.random.bernoulli(key, 0.5, (50,)).astype(jnp.int32)
    betas = jnp.full((50,), 0.3)
    _, o1 = run_stream(CFG, fs, hrs, betas, jax.random.PRNGKey(7))
    _, o2 = run_stream(CFG, fs, hrs, betas, jax.random.PRNGKey(7))
    assert np.array_equal(np.asarray(o1.loss), np.asarray(o2.loss))


def test_loss_charged_correctly():
    """Offloaded rounds pay β; local rounds pay φ against h_r."""
    key = jax.random.PRNGKey(3)
    fs = jax.random.uniform(key, (300,))
    hrs = jax.random.bernoulli(key, 0.5, (300,)).astype(jnp.int32)
    betas = jnp.full((300,), 0.4)
    _, out = run_stream(CFG, fs, hrs, betas, key)
    loss = np.asarray(out.loss)
    off = np.asarray(out.offload)
    pred = np.asarray(out.local_pred)
    hr = np.asarray(hrs)
    assert np.allclose(loss[off], 0.4)
    local = ~off
    expect = np.where(pred[local] == 1,
                      np.where(hr[local] == 0, CFG.delta_fp, 0.0),
                      np.where(hr[local] == 1, CFG.delta_fn, 0.0))
    assert np.allclose(loss[local], expect)


def test_corollary1_params():
    cfg = HIConfig(bits=4).with_horizon(10_000)
    import math

    n = cfg.n_experts
    eps_expect = (math.log(n) / (2 * 1.0 * 10_000)) ** (1 / 3)
    assert abs(cfg.eps - eps_expect) < 1e-9
    assert abs(cfg.eta - math.sqrt(2 * cfg.eps * math.log(n) / 10_000)) < 1e-9
