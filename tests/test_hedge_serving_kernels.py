"""Serving-hot-path kernel coverage: the decide/feedback split kernels,
per-stream (η, decay) schedule vectors, stream-axis zero-padding for
non-divisible fleet sizes, the (SB × TB) autotune cache, and the HIServer
multi-round serving fast path.

The load-bearing bar: with `interpret=True` the Pallas kernels and the jnp
paths must make BIT-identical decisions (offload/explore/predict and every
integer counter, asserted with array_equal throughout), and their weight
states must agree to float32-ulp level. The weights themselves are compared
with tight allclose rather than array_equal because the update
`decay·w − η·l̃` may or may not be FMA-fused depending on whether the
schedule is a compile-time constant or a traced (S,) vector — XLA's choice,
≈1-2 ulp, the same caveat `AdaptiveEngine` documents. On this platform the
serving-level parities (serve_slot, run_source fast path, adaptive engine)
are in fact bit-identical end to end.
"""
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HIConfig,
    draw_fleet_randomness,
    draw_psi_zeta,
    fleet_decide,
    fleet_feedback,
    fleet_init,
    run_fleet_fused,
)
from repro.kernels.hedge import autotune
from repro.kernels.hedge.ops import (
    fleet_hedge_decide,
    fleet_hedge_feedback,
    fleet_hedge_rounds,
    fleet_hedge_step,
)
from repro.serving import HIServer, HIServerConfig, available_engines, get_engine

from conftest import fleet_trace as _fleet_trace


def _rand_logw(key, s, g):
    l = jnp.arange(g)[:, None]
    u = jnp.arange(g)[None, :]
    lw = jax.random.normal(key, (s, g, g))
    return jnp.where(l <= u, lw - jnp.max(lw), -jnp.inf).astype(jnp.float32)


def _slot_inputs(key, s, eps=0.1):
    ks = jax.random.split(key, 4)
    fs = jax.random.uniform(ks[0], (s,))
    hrs = jax.random.bernoulli(ks[1], 0.5, (s,)).astype(jnp.int32)
    betas = jax.random.uniform(ks[2], (s,), maxval=0.6)
    psi, zeta = draw_psi_zeta(jax.random.split(ks[3], s), eps)
    return fs, hrs, betas, psi, zeta


def _assert_trees_equal(a, b, msg=""):
    for name, x, y in zip(a._fields, a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y)), (msg, name)


def _assert_logw_close(a, b, msg="", atol=2e-5):
    """Weight grids equal to ulp level (see module docstring), -inf aligned."""
    a, b = np.asarray(a), np.asarray(b)
    valid = np.isfinite(a)
    assert np.array_equal(valid, np.isfinite(b)), msg
    np.testing.assert_allclose(b[valid], a[valid], atol=atol, err_msg=str(msg))


# ----------------------- decide/feedback split kernels ------------------------


def _assert_decisions_equal(a, b, msg=""):
    """FleetDecision parity: every decision bit-identical, region masses to
    float tolerance (reduction fusion may differ across graph contexts)."""
    for name in ("i_f", "offload", "explored", "local_pred"):
        assert np.array_equal(np.asarray(getattr(a, name)),
                              np.asarray(getattr(b, name))), (msg, name)
    for name in ("q", "p", "psi"):
        np.testing.assert_allclose(np.asarray(getattr(b, name)),
                                   np.asarray(getattr(a, name)), atol=1e-6,
                                   err_msg=f"{msg} {name}")


@pytest.mark.parametrize("bits", [3, 4, 5])          # G ∈ {8, 16, 32}
def test_decide_kernel_matches_jnp(bits):
    """The decide kernel (interpret) makes BIT-identical decisions to the
    vmapped jnp `fleet_decide` (on this platform the q/p masses match
    bit-for-bit too; the assert allows reduction-fusion ulps)."""
    cfg = HIConfig(bits=bits, eps=0.1, eta=1.0)
    s = 9                                           # not a stream_block multiple
    state = fleet_init(cfg, s)._replace(
        log_w=_rand_logw(jax.random.PRNGKey(bits), s, cfg.grid))
    fs, _, _, psi, zeta = _slot_inputs(jax.random.PRNGKey(7 + bits), s)
    ref = fleet_decide(cfg, state, fs, psi, zeta, use_kernel=False)
    ker = fleet_decide(cfg, state, fs, psi, zeta, use_kernel=True,
                       interpret=True)
    _assert_decisions_equal(ref, ker)


@pytest.mark.parametrize("bits", [3, 4, 5])
def test_feedback_kernel_per_stream_schedule_golden(bits):
    """Feedback kernel vs the jnp `fleet_feedback` under a per-stream
    (η, decay) schedule AND a capacity-dropping `sent` mask: states and
    outputs bit-identical."""
    cfg = HIConfig(bits=bits, eps=0.07, eta=0.9, decay=0.95)
    s = 8
    ks = jax.random.split(jax.random.PRNGKey(40 + bits), 3)
    state = fleet_init(cfg, s)._replace(log_w=_rand_logw(ks[0], s, cfg.grid))
    fs, hrs, betas, psi, zeta = _slot_inputs(ks[1], s)
    dec = fleet_decide(cfg, state, fs, psi, zeta, use_kernel=False)
    # Drop every other offload, as a capacity-limited server would.
    sent = dec.offload & (jnp.arange(s) % 2 == 0)
    eta = jax.random.uniform(ks[2], (s,), minval=0.3, maxval=2.0)
    decay = jnp.linspace(0.9, 1.0, s)
    st_ref, out_ref = fleet_feedback(cfg, state, dec, hrs, betas, sent=sent,
                                     eta=eta, decay=decay, use_kernel=False)
    st_ker, out_ker = fleet_feedback(cfg, state, dec, hrs, betas, sent=sent,
                                     eta=eta, decay=decay, use_kernel=True,
                                     interpret=True)
    _assert_trees_equal(out_ref, out_ker)
    assert np.array_equal(np.asarray(st_ref.t), np.asarray(st_ker.t))
    assert np.array_equal(np.asarray(st_ref.n_offloads),
                          np.asarray(st_ker.n_offloads))
    assert np.array_equal(np.asarray(st_ref.n_explores),
                          np.asarray(st_ker.n_explores))
    _assert_logw_close(st_ref.log_w, st_ker.log_w)


def test_schedule_scalar_broadcast_identity():
    """Broadcasting the HIConfig scalars into the kernels' (S,) schedule
    vectors reproduces the fixed-schedule results: every decision, q/p
    mass, and derived output bit-for-bit; the weight grid to ulp level
    (the broadcast is elementwise-identical math, but a traced vector
    operand can change XLA's FMA fusion of decay·w − η·l̃ — the
    compile-time-constant caveat `AdaptiveEngine` documents)."""
    cfg = HIConfig(bits=4, eps=0.1, eta=0.8, decay=0.97)
    s = 8
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    logw = _rand_logw(ks[0], s, cfg.grid)
    fs, hrs, betas, psi, zeta = _slot_inputs(ks[1], s)
    zeta = zeta.astype(jnp.int32)
    vec = lambda v: jnp.full((s,), v, jnp.float32)
    for uk in (True, False):
        default = fleet_hedge_step(cfg, logw, fs, psi, zeta, hrs, betas,
                                   use_kernel=uk, interpret=True)
        explicit = fleet_hedge_step(cfg, logw, fs, psi, zeta, hrs, betas,
                                    use_kernel=uk, interpret=True,
                                    eta=vec(cfg.eta), decay=vec(cfg.decay))
        _assert_logw_close(default[0], explicit[0], msg=uk)
        for a, b in zip(default[1:], explicit[1:]):
            assert np.array_equal(np.asarray(a), np.asarray(b)), uk


def test_split_kernels_compose_to_monolithic_kernel():
    """decide-kernel + feedback-kernel (sent = the raw offload decision)
    reproduces the monolithic step kernel bit-for-bit."""
    cfg = HIConfig(bits=4, eps=0.1, eta=1.0, decay=0.98)
    s = 8
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    logw = _rand_logw(ks[0], s, cfg.grid)
    fs, hrs, betas, psi, zeta = _slot_inputs(ks[1], s)
    zeta = zeta.astype(jnp.int32)
    new_lw, off, exp_, lp, q, p = fleet_hedge_step(
        cfg, logw, fs, psi, zeta, hrs, betas, use_kernel=True, interpret=True)
    i_f, off2, exp2, lp2, q2, p2 = fleet_hedge_decide(
        cfg, logw, fs, psi, zeta, use_kernel=True, interpret=True)
    lw2 = fleet_hedge_feedback(
        cfg, logw, i_f, off2, exp2, hrs, betas, use_kernel=True,
        interpret=True)
    for a, b in zip((off, exp_, lp), (off2, exp2, lp2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip((q, p), (q2, p2)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-6)
    _assert_logw_close(new_lw, lw2)


def test_engine_split_equals_step_with_kernels_all_engines():
    """Every registered engine's decide+feedback composition equals its own
    step with the split kernels forced (interpret mode) — state included.
    Under CI's 8-fake-device matrix job this also covers the kernels inside
    the sharded engine's shard_map."""
    cfg = HIConfig(bits=4, eps=0.1, eta=1.0)
    s = 6
    fs, hrs, betas, _, _ = _slot_inputs(jax.random.PRNGKey(9), s)
    keys = jax.random.split(jax.random.PRNGKey(10), s)
    for name in available_engines():
        eng = get_engine(name, cfg, interpret=True)
        state = eng.init(s)
        st_step, o_step = eng.step(state, fs, betas, hrs, keys)
        dec = eng.decide(state, fs, keys)
        st_df, o_df = eng.feedback(state, dec, hrs, betas)
        assert np.array_equal(np.asarray(o_step.offload),
                              np.asarray(o_df.offload)), name
        assert np.array_equal(np.asarray(o_step.pred),
                              np.asarray(o_df.pred)), name
        _assert_logw_close(st_step.log_w, st_df.log_w, msg=name)


def test_kernel_vs_jnp_engine_cross_parity():
    """fused(interpret kernel) and reference(jnp) engines serve bit-identical
    decide/feedback rounds for the same keys — the serving layer can mix
    their states freely."""
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    s = 8
    ker = get_engine("fused", cfg, interpret=True)
    ref = get_engine("reference", cfg)
    st_k, st_r = ker.init(s), ref.init(s)
    key = jax.random.PRNGKey(2)
    for t in range(4):
        key, k1, k2 = jax.random.split(key, 3)
        fs = jax.random.uniform(k1, (s,))
        hrs = jax.random.bernoulli(k2, 0.5, (s,)).astype(jnp.int32)
        betas = jnp.full((s,), 0.3)
        keys = jax.random.split(jax.random.fold_in(key, t), s)
        dec_k = ker.decide(st_k, fs, keys)
        dec_r = ref.decide(st_r, fs, keys)
        _assert_decisions_equal(dec_r, dec_k, msg=t)
        sent = dec_k.offload & (jnp.arange(s) < s - 1)   # drop the last stream
        st_k, o_k = ker.feedback(st_k, dec_k, hrs, betas, sent=sent)
        st_r, o_r = ref.feedback(st_r, dec_r, hrs, betas, sent=sent)
        assert np.array_equal(np.asarray(o_k.pred), np.asarray(o_r.pred))
        _assert_logw_close(st_r.log_w, st_k.log_w, msg=t)


# -------------------- stream-axis zero-padding (satellite) --------------------


@pytest.mark.parametrize("s", [1, 3, 5, 7, 13])
def test_stream_padding_any_fleet_size(s):
    """Prime/odd fleet sizes run at full stream_block via zero-padding (not
    the old SB=1 divisor fallback) and still match the jnp oracle exactly —
    single-round, multi-round, and the split kernels."""
    cfg = HIConfig(bits=3, eps=0.1, eta=1.0, decay=0.96)
    g = cfg.grid
    ks = jax.random.split(jax.random.PRNGKey(s), 2)
    logw = _rand_logw(ks[0], s, g)
    fs, hrs, betas, psi, zeta = _slot_inputs(ks[1], s)
    zeta = zeta.astype(jnp.int32)
    def check(kernel_out, ref_out):
        new_k, *rest_k = kernel_out
        new_r, *rest_r = ref_out
        _assert_logw_close(new_r, new_k, msg=s)
        for a, b in zip(rest_k, rest_r):
            if np.asarray(a).dtype == np.int32:
                assert np.array_equal(np.asarray(a), np.asarray(b)), s
            else:                                        # q/p region masses
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-6)
            assert np.asarray(a).shape[0] == s           # padding sliced off

    check(fleet_hedge_step(cfg, logw, fs, psi, zeta, hrs, betas,
                           use_kernel=True, interpret=True, stream_block=8),
          fleet_hedge_step(cfg, logw, fs, psi, zeta, hrs, betas,
                           use_kernel=False))

    tb = 4
    tile = lambda a: jnp.tile(a[:, None], (1, tb))
    check(fleet_hedge_rounds(cfg, logw, tile(fs), tile(psi), tile(zeta),
                             tile(hrs), tile(betas), use_kernel=True,
                             interpret=True, stream_block=8),
          fleet_hedge_rounds(cfg, logw, tile(fs), tile(psi), tile(zeta),
                             tile(hrs), tile(betas), use_kernel=False))


def test_block_streams_geometry():
    """The launch geometry never exceeds S, pads to an SB multiple, and no
    longer falls back to SB=1 on primes."""
    from repro.kernels.hedge.kernel import _block_streams

    assert _block_streams(16, 8) == (8, 16, 0)
    assert _block_streams(13, 8) == (8, 16, 3)           # prime: pad, not SB=1
    assert _block_streams(5, 8) == (5, 5, 0)             # SB capped at S
    assert _block_streams(3, 8) == (3, 3, 0)
    assert _block_streams(96, 8) == (8, 96, 0)


# ---------------------- per-stream schedules, fleet paths ---------------------


def test_run_fleet_fused_vector_schedule_matches_feedback_chain():
    """`run_fleet_fused(eta=…, decay=…)` (both kernel time_block paths) ==
    a decide/feedback chain with the same per-stream schedule."""
    cfg = HIConfig(bits=3, eps=0.1, eta=1.0)
    s, t = 6, 32
    fs, hrs, betas = _fleet_trace(jax.random.PRNGKey(0), s, t)
    key = jax.random.PRNGKey(4)
    eta = jnp.linspace(0.5, 1.5, s)
    decay = jnp.linspace(0.92, 1.0, s)

    state = fleet_init(cfg, s)
    psis, zetas = draw_fleet_randomness(cfg, key, s, t)
    chain = []
    for ti in range(t):
        dec = fleet_decide(cfg, state, fs[:, ti], psis[:, ti], zetas[:, ti],
                           use_kernel=False)
        state, out = fleet_feedback(cfg, state, dec, hrs[:, ti], betas[:, ti],
                                    eta=eta, decay=decay, use_kernel=False)
        chain.append(out.offload)
    chain = jnp.stack(chain, axis=1)

    for tb in (1, 8):
        st, out = run_fleet_fused(cfg, fs, hrs, betas, key, use_kernel=True,
                                  interpret=True, time_block=tb,
                                  eta=eta, decay=decay)
        assert np.array_equal(np.asarray(out.offload), np.asarray(chain)), tb
        _assert_logw_close(state.log_w, st.log_w, msg=tb, atol=1e-4)


def test_adaptive_engine_kernel_bit_parity_across_shifts():
    """The adaptive engine with kernels forced (interpret) is bit-identical
    to its jnp path over the pinned drift scenario — through detector
    alarms, per-stream schedule boosts, and weight restarts."""
    from repro.data.scenarios import get_scenario

    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    key = jax.random.PRNGKey(11)
    mk = lambda: get_scenario(
        "piecewise", n_streams=4, horizon=2000, block=500,
        key=jax.random.PRNGKey(0), beta=0.3,
        segments=((0, "breakhis"), (1000, "xract")))
    st_j, out_j = get_engine("adaptive", cfg).run_source(mk(), key)
    st_k, out_k = get_engine("adaptive", cfg,
                             interpret=True).run_source(mk(), key)
    for name in ("offloads", "explores", "correct"):
        assert np.array_equal(np.asarray(getattr(out_j, name)),
                              np.asarray(getattr(out_k, name))), name
    for name in ("loss", "true_loss"):
        np.testing.assert_allclose(np.asarray(getattr(out_k, name)),
                                   np.asarray(getattr(out_j, name)),
                                   atol=1e-3, err_msg=name)
    assert int(jnp.sum(st_j.shift.n_alarms)) > 0         # shifts were detected
    assert np.array_equal(np.asarray(st_j.shift.n_alarms),
                          np.asarray(st_k.shift.n_alarms))
    _assert_logw_close(st_j.policy.log_w, st_k.policy.log_w, atol=1e-4)


# ------------------------- HIServer serving fast path -------------------------


def _stationary_source(s=4, horizon=512, block=64):
    from repro.data.scenarios import get_scenario

    return get_scenario("stationary", n_streams=s, horizon=horizon,
                        block=block, key=jax.random.PRNGKey(0), beta=0.3)


def test_hiserver_serve_slot_runs_kernels_bit_identical():
    """`serve_slot` with the kernel-backed fused engine (interpret) ==
    the reference jnp engine, slot for slot — results and state."""
    cfg = HIConfig(bits=3, eps=0.1, eta=1.0)
    s = 5
    mk = lambda engine, interpret=None: HIServer(
        HIServerConfig(n_streams=s, hi=cfg, engine=engine,
                       interpret=interpret, offload_capacity=3),
        ldl=lambda tok: jax.nn.sigmoid(jnp.mean(tok, axis=-1)),
        rdl=lambda tok: (jnp.mean(tok, axis=-1) > 0).astype(jnp.int32))
    srv_k, srv_r = mk("fused", interpret=True), mk("reference")
    st_k, st_r = srv_k.init_state(), srv_r.init_state()
    key = jax.random.PRNGKey(0)
    for t in range(6):
        key, k1, k2 = jax.random.split(key, 3)
        tokens = jax.random.normal(k1, (s, 8))
        betas = jnp.full((s,), 0.3)
        st_k, res_k = srv_k.serve_slot(st_k, tokens, betas, k2)
        st_r, res_r = srv_r.serve_slot(st_r, tokens, betas, k2)
        _assert_trees_equal(res_k, res_r, msg=t)
    _assert_logw_close(st_r.policy.log_w, st_k.policy.log_w)


def test_hiserver_rounds_fast_path_matches_slot_path():
    """`run_source` through the multi-round kernel (time_block) produces the
    slot path's summaries, counters, and final weights bit-for-bit."""
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    s = 4
    dummy = lambda x: x
    slot = HIServer(HIServerConfig(n_streams=s, hi=cfg, engine="fused",
                                   interpret=True), dummy, dummy)
    fast = HIServer(HIServerConfig(n_streams=s, hi=cfg, engine="fused",
                                   interpret=True, time_block=8),
                    dummy, dummy)
    assert not slot.rounds_eligible(_stationary_source(s))
    assert fast.rounds_eligible(_stationary_source(s))
    key = jax.random.PRNGKey(11)
    st1, sum1 = slot.run_source(_stationary_source(s), key)
    st2, sum2 = fast.run_source(_stationary_source(s), key)
    # Bit-identical summaries on this platform; the assert allows summation
    # fusion ulps on the two float fields (everything else is count-derived).
    assert set(sum1) == set(sum2)
    for k in sum1:
        assert math.isclose(sum1[k], sum2[k], rel_tol=1e-6, abs_tol=1e-9), k
    for k in ("offload_rate", "rdl_evals", "rdl_batches", "drop_rate",
              "accuracy"):
        assert sum1[k] == sum2[k], k
    assert int(st1.t) == int(st2.t) == 512
    _assert_logw_close(st1.policy.log_w, st2.policy.log_w)
    assert np.array_equal(np.asarray(st1.policy.n_offloads),
                          np.asarray(st2.policy.n_offloads))
    assert np.array_equal(np.asarray(st1.policy.n_explores),
                          np.asarray(st2.policy.n_explores))


def test_hiserver_rounds_eligibility_gates():
    """The fast path declines exactly the configurations whose double-
    buffered feedback could diverge from the monolithic chain."""
    cfg = HIConfig(bits=3)
    dummy = lambda x: x
    src = _stationary_source(4)
    mk = lambda **kw: HIServer(
        HIServerConfig(n_streams=4, hi=cfg, **kw), dummy, dummy)
    assert mk(engine="fused", time_block=8).rounds_eligible(src)
    # Capacity drops possible → sent ≠ offload → slot path.
    assert not mk(engine="fused", time_block=8,
                  offload_capacity=2).rounds_eligible(src)
    # Per-slot detector/schedule updates → slot path.
    assert not mk(engine="adaptive", time_block=8).rounds_eligible(src)
    # Block must divide into time blocks.
    assert not mk(engine="fused", time_block=7).rounds_eligible(src)
    with pytest.raises(ValueError, match="time_block"):
        HIServerConfig(n_streams=4, hi=cfg, time_block=0)


# ------------------------------ autotune cache --------------------------------


def test_autotune_sweep_persists_and_lookup(tmp_path, monkeypatch):
    path = str(tmp_path / "hedge_autotune.json")
    monkeypatch.setenv("REPRO_HEDGE_AUTOTUNE_CACHE", path)
    backend = jax.default_backend()
    entries = autotune.sweep(grids=(8,), streams=(4,), stream_blocks=(1, 4),
                             time_blocks=(1, 2), reps=1)
    assert set(entries) == {f"{backend}/G8/S4/pre_draw"}
    rec = autotune.lookup(8, 4)
    assert rec is not None and os.path.exists(path)
    assert rec["stream_block"] in (1, 4) and rec["time_block"] in (1, 2)
    assert rec["randomness"] == "pre_draw"
    assert set(rec["measured"]) == {"sb1_tb1", "sb1_tb2", "sb4_tb1", "sb4_tb2"}
    # Unknown shapes fall back to the static defaults.
    assert autotune.best_blocks(8, 999) == (
        autotune.DEFAULT_STREAM_BLOCK, autotune.DEFAULT_TIME_BLOCK)
    # A rewrite is picked up (mtime invalidation, no process restart).
    entries[f"{backend}/G8/S4/pre_draw"]["stream_block"] = 2
    autotune.write_cache(entries, path)
    assert autotune.best_stream_block(8, 4) == 2
    # Other platforms' entries survive a merge; legacy mode-less keys are
    # read as pre_draw winners...
    autotune.write_cache({"tpu/G8/S4": {"stream_block": 16, "time_block": 32,
                                        "us_per_round": 1.0}}, path)
    assert autotune.best_blocks(8, 4, platform="tpu") == (16, 32)
    assert autotune.best_stream_block(8, 4) == 2
    # ...but never as counter-mode winners (measured on a different kernel
    # body), and a counter entry never shadows the pre_draw lookup.
    assert autotune.lookup(8, 4, platform="tpu", randomness="counter") is None
    assert autotune.best_blocks(8, 4, platform="tpu",
                                randomness="counter") == (
        autotune.DEFAULT_STREAM_BLOCK, autotune.DEFAULT_TIME_BLOCK)
    autotune.write_cache({"tpu/G8/S4/counter": {"stream_block": 2,
                                                "time_block": 4}}, path)
    assert autotune.best_blocks(8, 4, platform="tpu",
                                randomness="counter") == (2, 4)
    assert autotune.best_blocks(8, 4, platform="tpu") == (16, 32)
    # A counter-mode sweep measures the counter kernel and writes its own key.
    centries = autotune.sweep(grids=(8,), streams=(4,), stream_blocks=(4,),
                              time_blocks=(1,), reps=1, randomness="counter")
    assert set(centries) == {f"{backend}/G8/S4/counter"}
    assert autotune.lookup(8, 4, randomness="counter")["randomness"] == \
        "counter"
    assert autotune.best_stream_block(8, 4) == 2     # pre_draw untouched
    # Partial entries (hand-edited caches) degrade field-by-field, not crash.
    autotune.write_cache({"tpu/G8/S2": {"stream_block": 16}}, path)
    assert autotune.best_blocks(8, 2, platform="tpu") == (
        16, autotune.DEFAULT_TIME_BLOCK)


def test_ops_defaults_consult_autotune_cache(tmp_path, monkeypatch):
    """`ops` resolves stream_block=None through the cache at trace time, and
    the chosen geometry never changes results (pad + slice)."""
    from repro.kernels.hedge.ops import _stream_block

    path = str(tmp_path / "cache.json")
    monkeypatch.setenv("REPRO_HEDGE_AUTOTUNE_CACHE", path)
    assert _stream_block(None, 8, 5) == autotune.DEFAULT_STREAM_BLOCK
    assert _stream_block(3, 8, 5) == 3                   # explicit wins
    autotune.write_cache(
        {f"{jax.default_backend()}/G8/S5": {
            "stream_block": 3, "time_block": 4, "us_per_round": 1.0}}, path)
    assert _stream_block(None, 8, 5) == 3

    cfg = HIConfig(bits=3, eps=0.1)
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    logw = _rand_logw(ks[0], 5, cfg.grid)
    fs, hrs, betas, psi, zeta = _slot_inputs(ks[1], 5)
    zeta = zeta.astype(jnp.int32)
    for sb in (None, 1, 2, 8):                           # geometry-invariant
        out = fleet_hedge_step(cfg, logw, fs, psi, zeta, hrs, betas,
                               use_kernel=True, interpret=True,
                               stream_block=sb)
        ref = fleet_hedge_step(cfg, logw, fs, psi, zeta, hrs, betas,
                               use_kernel=False)
        for a, b in zip(out, ref):
            assert np.array_equal(np.asarray(a), np.asarray(b)), sb


def test_fused_engine_default_time_block_consults_cache(tmp_path, monkeypatch):
    """FusedEngine(time_block=None) applies the cached TB winner when it
    divides the horizon, single-round otherwise; an explicit value wins."""
    path = str(tmp_path / "cache.json")
    monkeypatch.setenv("REPRO_HEDGE_AUTOTUNE_CACHE", path)
    cfg = HIConfig(bits=3)
    eng = get_engine("fused", cfg)
    assert eng._resolve_time_block(s=4, t=96) == 1       # no cache → 1
    autotune.write_cache(
        {f"{jax.default_backend()}/G8/S4": {
            "stream_block": 4, "time_block": 8, "us_per_round": 1.0}}, path)
    assert eng._resolve_time_block(s=4, t=96) == 8       # winner divides 96
    assert eng._resolve_time_block(s=4, t=97) == 1       # 97 % 8 → fallback
    assert eng._resolve_time_block(s=5, t=96) == 1       # no S=5 entry
    assert get_engine("fused", cfg,
                      time_block=2)._resolve_time_block(s=4, t=96) == 2


# ------------------------------- multi-device ---------------------------------


@pytest.mark.slow
def test_sharded_split_kernels_under_8_fake_devices_subprocess():
    """Force 8 host devices in a clean interpreter: the sharded engine's
    decide/feedback split with kernels forced (interpret inside shard_map)
    still equals its own step, with S=11 not dividing the device count."""
    code = """
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from repro.core import HIConfig
from repro.serving import get_engine
cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
s = 11
eng = get_engine("sharded", cfg, interpret=True)
state = eng.init(s)
ks = jax.random.split(jax.random.PRNGKey(0), 3)
fs = jax.random.uniform(ks[0], (s,))
hrs = jax.random.bernoulli(ks[1], 0.5, (s,)).astype(jnp.int32)
betas = jnp.full((s,), 0.3)
keys = jax.random.split(ks[2], s)
st_step, o_step = eng.step(state, fs, betas, hrs, keys)
dec = eng.decide(state, fs, keys)
st_df, o_df = eng.feedback(state, dec, hrs, betas)
assert np.array_equal(np.asarray(o_step.offload), np.asarray(o_df.offload))
lw_s, lw_d = np.asarray(st_step.log_w), np.asarray(st_df.log_w)
valid = np.isfinite(lw_s)
assert np.array_equal(lw_s[valid], lw_d[valid])
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
