"""ScenarioSource registry: resolution, chunk-invariance, statistical
fidelity of every scenario, cross-engine cost identity from a source, and
streamed (one-block-residency) serving at T ≥ 100k.

The load-bearing acceptance tests:
  * `test_stationary_chunked_bit_identical_across_block_sizes` — the same
    key yields the SAME trace whatever the block size, so chunked runs and
    the materialized `sample_trace` shim agree bit-for-bit.
  * `test_engines_identical_costs_from_source` — reference/fused/sharded
    produce identical costs when driven from the same source + policy key.
  * `test_hi_server_streams_100k_horizon_one_block_residency` — `HIServer`
    serves T = 100_000 slots from a source while only ever emitting
    (S, block) chunks, classifiers untouched.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HIConfig, run_fleet_source
from repro.data import DATASETS, Trace, empirical_confusion, sample_trace
from repro.data.scenarios import (
    BetaProcessSource,
    HeteroFleetSource,
    NoisyRDLSource,
    PiecewiseSource,
    ScenarioSource,
    StationarySource,
    available_scenarios,
    get_scenario,
    register_scenario,
)
from repro.serving import HIServer, HIServerConfig, get_engine


def _eager_blocks(src, key=None):
    """Concatenate emit() calls one block at a time (the serving pull)."""
    key = src.key if key is None else key
    st, outs = src.init_state(), []
    for b in range(src.n_blocks):
        st, batch = src.emit(st, key, b)
        outs.append(batch)
    return jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=1), *outs)


def _segment(batch, lo, hi):
    return Trace(batch.fs[:, lo:hi], batch.hrs[:, lo:hi],
                 batch.betas[:, lo:hi])


# --------------------------------- registry -----------------------------------


def test_registry_exposes_at_least_five_scenarios():
    names = set(available_scenarios())
    assert names >= {"stationary", "piecewise", "beta_process", "noisy_rdl",
                     "hetero_fleet"}
    assert len(names) >= 5
    src = get_scenario("stationary", n_streams=2, horizon=64, block=32,
                       key=jax.random.PRNGKey(0))
    assert isinstance(src, StationarySource)
    assert src.n_blocks == 2


def test_synthetic_only_excludes_data_backed_sources():
    """Generic sweeps (bench_scenarios) construct every name from
    (n_streams, horizon, key) alone — replay needs arrays and must be
    filtered out, while every synthetic source must actually construct."""
    synthetic = available_scenarios(synthetic_only=True)
    assert "replay" in available_scenarios()
    assert "replay" not in synthetic
    for name in synthetic:
        src = get_scenario(name, n_streams=2, horizon=32, block=16,
                           key=jax.random.PRNGKey(0))
        assert src.n_blocks == 2


def test_get_scenario_unknown_raises():
    with pytest.raises(ValueError, match="scenario"):
        get_scenario("warp-drive")


def test_register_scenario_extends_registry():
    @register_scenario("_test_dummy")
    class Dummy(StationarySource):
        pass

    try:
        assert "_test_dummy" in available_scenarios()
        assert isinstance(get_scenario("_test_dummy", horizon=8), Dummy)
    finally:
        from repro.data import scenarios
        del scenarios._SCENARIOS["_test_dummy"]


def test_source_validates_geometry():
    with pytest.raises(ValueError, match="block"):
        StationarySource(horizon=100, block=33)
    with pytest.raises(ValueError, match="n_streams"):
        StationarySource(n_streams=0, horizon=8)
    with pytest.raises(ValueError, match="beta_mode"):
        StationarySource(horizon=8, beta_mode="bursty")   # stationary: no Markov β


# ------------------------------ chunk invariance ------------------------------


def test_stationary_chunked_bit_identical_across_block_sizes():
    """Same key ⇒ identical trace whatever the chunking: per-slot keying
    makes `materialize` independent of the block size, bit-for-bit."""
    kw = dict(spec="breakhis", n_streams=3, horizon=96,
              key=jax.random.PRNGKey(5), beta=0.3, beta_mode="uniform")
    full = StationarySource(**kw).materialize()
    for blk in (8, 32, 48):
        got = StationarySource(block=blk, **kw).materialize()
        for name, a, b in zip(full._fields, full, got):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (blk, name)


def test_eager_emit_matches_materialize():
    """Pulling blocks one `emit` at a time (the serving path) agrees with the
    scanned materialization: random bits exactly, floats to XLA fusion noise."""
    src = StationarySource(spec="phishing", n_streams=2, horizon=64, block=16,
                           key=jax.random.PRNGKey(1), beta_mode="uniform")
    full = StationarySource(spec="phishing", n_streams=2, horizon=64,
                            key=jax.random.PRNGKey(1), beta_mode="uniform"
                            ).materialize()
    got = _eager_blocks(src)
    assert np.array_equal(np.asarray(full.hrs), np.asarray(got.hrs))
    assert np.array_equal(np.asarray(full.ys), np.asarray(got.ys))
    np.testing.assert_allclose(np.asarray(full.fs), np.asarray(got.fs),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(full.betas), np.asarray(got.betas),
                               atol=1e-6)


def test_sample_trace_shim_is_materialized_stationary():
    """`sample_trace` is now literally StationarySource.materialize()."""
    tr = sample_trace(DATASETS["phishing"], 128, jax.random.PRNGKey(1),
                      beta=0.25)
    m = StationarySource(spec="phishing", horizon=128,
                         key=jax.random.PRNGKey(1), beta=0.25).materialize()
    assert np.array_equal(np.asarray(tr.fs), np.asarray(m.fs[0]))
    assert np.array_equal(np.asarray(tr.hrs), np.asarray(m.hrs[0]))
    assert np.array_equal(np.asarray(tr.betas), np.asarray(m.betas[0]))


def test_bursty_state_carries_across_blocks():
    """The Markov β regime is generator state: chunked emission must continue
    it across block boundaries, not restart it — traces stay bit-identical
    between one-block and 8-block chunkings."""
    kw = dict(spec="synthetic", n_streams=4, horizon=256,
              key=jax.random.PRNGKey(2), beta=0.4, beta_mode="bursty")
    full = BetaProcessSource(**kw).materialize()
    got = BetaProcessSource(block=32, **kw).materialize()
    for name, a, b in zip(full._fields, full, got):
        assert np.array_equal(np.asarray(a), np.asarray(b)), name
    vals = np.unique(np.asarray(full.betas))
    np.testing.assert_allclose(vals, [0.05, 0.4], atol=1e-6)


# --------------------------- scenario statistics ------------------------------


def test_piecewise_segments_match_source_specs():
    """Pre-/post-switch segments reproduce their own specs' confusion stats."""
    switch = 10_000
    src = PiecewiseSource(segments=((0, "breakhis"), (switch, "breach")),
                          n_streams=2, horizon=20_000, block=5_000,
                          key=jax.random.PRNGKey(3))
    b = src.materialize()
    for lo, hi, name in [(0, switch, "breakhis"), (switch, 20_000, "breach")]:
        spec = DATASETS[name]
        _, fp, fn = empirical_confusion(_segment(b, lo, hi))
        assert abs(fp - spec.fp) < 0.02, (name, fp, spec.fp)
        assert abs(fn - spec.fn) < 0.02, (name, fn, spec.fn)


def test_piecewise_accepts_many_segments():
    src = PiecewiseSource(
        segments=((0, "breakhis"), (300, "chest"), (700, "breach")),
        horizon=1000, block=250, key=jax.random.PRNGKey(8))
    b = src.materialize()
    assert b.fs.shape == (1, 1000)
    with pytest.raises(ValueError, match="start"):
        PiecewiseSource(segments=((5, "breakhis"),), horizon=100)
    with pytest.raises(ValueError, match="increase"):
        PiecewiseSource(segments=((0, "breakhis"), (50, "chest"), (50, "breach")),
                        horizon=100)
    with pytest.raises(ValueError, match="horizon"):
        PiecewiseSource(segments=((0, "breakhis"), (100, "chest")), horizon=100)


def test_noisy_rdl_noise_rates_match_rdl_spec():
    """The mismatched-classifier feedback flips labels at exactly the RDL
    spec's conditional error rates; ground truth stays in `ys`."""
    src = NoisyRDLSource(spec="synthetic", rdl_fn=0.12, rdl_fp=0.07,
                         n_streams=2, horizon=20_000,
                         key=jax.random.PRNGKey(4))
    b = src.materialize()
    ys, hrs = np.asarray(b.ys), np.asarray(b.hrs)
    fn_rate = ((hrs == 0) & (ys == 1)).sum() / (ys == 1).sum()
    fp_rate = ((hrs == 1) & (ys == 0)).sum() / (ys == 0).sum()
    assert abs(fn_rate - 0.12) < 0.015, fn_rate
    assert abs(fp_rate - 0.07) < 0.015, fp_rate
    # Confidences are generated from the TRUE label, not the noisy feedback.
    _, fp, fn = empirical_confusion(Trace(b.fs, b.ys, b.betas))
    spec = DATASETS["synthetic"]
    assert abs(fp - spec.fp) < 0.02 and abs(fn - spec.fn) < 0.02


def test_noisy_rdl_rates_from_spec_table():
    src = NoisyRDLSource(rdl_spec="chest", horizon=8)
    spec = DATASETS["chest"]
    assert src.rdl_fn == pytest.approx(spec.fn / spec.p1)
    assert src.rdl_fp == pytest.approx(spec.fp / (1.0 - spec.p1))


def test_hetero_fleet_per_stream_stats():
    src = HeteroFleetSource(specs=("breakhis", "chest"), horizon=30_000,
                            key=jax.random.PRNGKey(3))
    assert src.n_streams == 2
    b = src.materialize()
    for i, name in enumerate(("breakhis", "chest")):
        spec = DATASETS[name]
        _, fp, fn = empirical_confusion(_segment(b, 0, 30_000)._replace(
            fs=b.fs[i], hrs=b.hrs[i]))
        assert abs(fp - spec.fp) < 0.02, (name, fp)
        assert abs(fn - spec.fn) < 0.02, (name, fn)
    with pytest.raises(ValueError, match="n_streams"):
        HeteroFleetSource(specs=("breakhis",), n_streams=3, horizon=8)


def test_beta_process_sinusoidal_and_uniform():
    sin = get_scenario("beta_process", beta_mode="sinusoidal", n_streams=2,
                       horizon=1024, key=jax.random.PRNGKey(1), beta=0.5,
                       beta_lo=0.1, period=256).materialize()
    bs = np.asarray(sin.betas)
    assert bs.min() >= 0.1 - 1e-6 and bs.max() <= 0.5 + 1e-6
    assert bs.std() > 0.05                      # actually sweeps
    assert np.allclose(bs[0], bs[1])            # network-wide congestion
    uni = get_scenario("beta_process", beta_mode="uniform", horizon=512,
                       key=jax.random.PRNGKey(1), beta=0.4).materialize()
    ub = np.asarray(uni.betas)
    assert ub.max() <= 0.4 and ub.std() > 0.05


# --------------------------- source-driven running ----------------------------


@pytest.mark.parametrize("name", ["reference", "fused", "sharded"])
def test_engines_identical_costs_from_source(name):
    """Acceptance: every engine produces identical costs when driven from the
    same source and policy key."""
    cfg = HIConfig(bits=3, eps=0.1, eta=1.0)
    mk = lambda: get_scenario("piecewise", n_streams=6, horizon=192, block=48,
                              key=jax.random.PRNGKey(6))
    key = jax.random.PRNGKey(9)
    _, ref = get_engine("reference", cfg).run_source(mk(), key)
    st, out = get_engine(name, cfg).run_source(mk(), key)
    assert np.array_equal(np.asarray(ref.offloads), np.asarray(out.offloads))
    assert np.array_equal(np.asarray(ref.explores), np.asarray(out.explores))
    assert np.array_equal(np.asarray(ref.correct), np.asarray(out.correct))
    np.testing.assert_allclose(np.asarray(ref.loss), np.asarray(out.loss),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ref.true_loss),
                               np.asarray(out.true_loss), atol=1e-5)
    assert out.loss.shape == (6, 4)             # (S, n_blocks) aggregates
    assert int(st.t[0]) == 192


def test_engine_run_dispatches_source():
    cfg = HIConfig(bits=3, eps=0.05)
    src = get_scenario("stationary", n_streams=4, horizon=128, block=32,
                       key=jax.random.PRNGKey(2))
    eng = get_engine("fused", cfg)
    _, via_run = eng.run(src, key=jax.random.PRNGKey(5))
    _, via_rs = eng.run_source(src, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(np.asarray(via_run.loss),
                                  np.asarray(via_rs.loss))
    with pytest.raises(TypeError, match="hrs"):
        eng.run(src, jnp.zeros((4, 128)), key=jax.random.PRNGKey(5))


def test_run_fleet_source_matches_fused_engine():
    cfg = HIConfig(bits=4, eps=0.1, eta=1.0)
    src = get_scenario("noisy_rdl", n_streams=3, horizon=96, block=24,
                       key=jax.random.PRNGKey(4), rdl_fn=0.3, rdl_fp=0.3)
    key = jax.random.PRNGKey(7)
    _, a = run_fleet_source(cfg, src, key)
    _, b = get_engine("fused", cfg).run_source(src, key)
    np.testing.assert_allclose(np.asarray(a.loss), np.asarray(b.loss),
                               atol=1e-6)
    # Under heavy RDL noise the ground-truth cost must exceed what the
    # policy observes: offloads pay β AND the remote model's mistakes.
    assert float(jnp.sum(a.true_loss)) > float(jnp.sum(a.loss))


def test_source_run_block_size_invariant_costs():
    """The policy key contract is per-(slot, stream), so chunking the same
    scenario differently cannot change the run."""
    cfg = HIConfig(bits=3, eps=0.1)
    key = jax.random.PRNGKey(11)
    totals = []
    for blk in (16, 64, 256):
        src = get_scenario("stationary", n_streams=4, horizon=256, block=blk,
                           key=jax.random.PRNGKey(1))
        _, out = get_engine("fused", cfg).run_source(src, key)
        totals.append(float(jnp.sum(out.loss)))
    np.testing.assert_allclose(totals[0], totals[1:], rtol=1e-6)


def test_empirical_regret_accepts_source():
    from repro.core import regret

    cfg = HIConfig(bits=3, eps=0.1, eta=0.5)
    src = get_scenario("stationary", n_streams=1, horizon=2000,
                       key=jax.random.PRNGKey(0))
    res = regret.empirical_regret(cfg, src, key=jax.random.PRNGKey(1),
                                  n_seeds=2)
    assert set(res) == {"algo_loss", "best_fixed_loss", "regret"}
    assert res["algo_loss"] >= res["best_fixed_loss"] - 1e-3
    with pytest.raises(ValueError, match="1-stream"):
        regret.empirical_regret(
            cfg, get_scenario("stationary", n_streams=2, horizon=64),
            key=jax.random.PRNGKey(1))


# ------------------------------ streamed serving ------------------------------


class _RecordingSource(StationarySource):
    """Asserts nothing bigger than one (S, block) chunk is ever emitted."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.emitted_shapes = []

    def emit(self, state, key, slot):
        state, batch = super().emit(state, key, slot)
        self.emitted_shapes.append(
            tuple(tuple(leaf.shape) for leaf in batch))
        return state, batch


def _no_classifier(tokens):
    raise AssertionError("source-driven serving must not invoke a classifier")


def test_hi_server_streams_100k_horizon_one_block_residency():
    """Acceptance: serve T = 100_000 slots from a ScenarioSource; the trace
    exists only as (S, block) chunks (block = 1000 ≪ T) and the LDL/RDL
    callables are never touched."""
    s, block, horizon = 4, 1000, 100_000
    hi = HIConfig(bits=3, eps=0.05)
    server = HIServer(HIServerConfig(n_streams=s, hi=hi, engine="fused"),
                      _no_classifier, _no_classifier)
    src = _RecordingSource(spec="breakhis", n_streams=s, horizon=horizon,
                           block=block, key=jax.random.PRNGKey(0), beta=0.25)
    state, summary = server.run_source(src, jax.random.PRNGKey(1))
    assert int(state.t) == horizon
    # Every emitted chunk — including while tracing — is exactly (S, block).
    assert src.emitted_shapes
    assert all(shape == (s, block) for shapes in src.emitted_shapes
               for shape in shapes)
    n = horizon * s
    assert 0.01 < summary["offload_rate"] < 1.0
    assert summary["rdl_evals"] == float(state.total_offloads)
    assert abs(summary["avg_offload_cost"]
               - 0.25 * summary["offload_rate"]) < 1e-5
    assert summary["rdl_savings"] == 1.0 - summary["rdl_evals"] / n
    assert 0.0 < summary["accuracy"] < 1.0
    assert summary["avg_true_cost"] >= summary["avg_offload_cost"]


def test_hi_server_source_capacity_and_rotation():
    """Capacity-limited source serving drops overflow (no β) and still
    reports honest row accounting, exactly like the token path."""
    s = 6
    server = HIServer(
        HIServerConfig(n_streams=s, hi=HIConfig(bits=3, eps=0.4),
                       engine="fused", offload_capacity=2),
        _no_classifier, _no_classifier)
    src = get_scenario("stationary", n_streams=s, horizon=256, block=64,
                       key=jax.random.PRNGKey(5), beta=0.05)
    state, summary = server.run_source(src, jax.random.PRNGKey(2))
    assert summary["drop_rate"] > 0.0
    assert summary["rdl_compute_rows"] == summary["rdl_batches"] * 2
    assert summary["rdl_evals"] <= 2 * 256
    assert float(state.total_dropped) > 0


def test_hi_server_run_dispatches_source():
    server = HIServer(HIServerConfig(n_streams=2, hi=HIConfig(bits=2)),
                      _no_classifier, _no_classifier)
    src = get_scenario("stationary", n_streams=2, horizon=64, block=32,
                       key=jax.random.PRNGKey(0))
    state, summary = server.run(src, key=jax.random.PRNGKey(1))
    assert int(state.t) == 64
    with pytest.raises(ValueError, match="streams"):
        server.run_source(
            get_scenario("stationary", n_streams=3, horizon=32),
            jax.random.PRNGKey(1))
