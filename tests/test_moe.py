"""MoE dispatch invariants (gather/scatter path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS
from repro.models.moe import _group_tokens, moe_forward, moe_init


def _cfg(e=4, k=2, d=32, f=64, cf=8.0):
    return dataclasses.replace(
        ARCHS["mixtral-8x7b"].reduced(),
        n_experts=e, top_k=k, d_model=d, d_ff=f, moe_capacity_factor=cf,
        n_shared_experts=0)


def test_group_tokens_divides():
    for t in (1, 2, 128, 1024, 4096, 2**20, 96):
        g = _group_tokens(t)
        assert t % g == 0 and g <= 2048


def test_moe_output_shape_and_finite(rng):
    cfg = _cfg()
    p = moe_init(rng, cfg, jnp.float32)
    x = jax.random.normal(rng, (2, 16, cfg.d_model))
    out = moe_forward(p, cfg, x)
    assert out.y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out.y)))
    assert float(out.aux_loss) > 0


def test_moe_matches_dense_expert_eval_when_dropfree(rng):
    """With capacity ≥ tokens, gather dispatch must equal explicitly routing
    every token through its top-k experts (brute force)."""
    cfg = _cfg(cf=64.0)
    p = moe_init(rng, cfg, jnp.float32)
    x = jax.random.normal(rng, (1, 8, cfg.d_model))
    out = moe_forward(p, cfg, x)

    # Brute force: per token, evaluate its top-k experts directly.
    xt = x.reshape(-1, cfg.d_model)
    probs = jax.nn.softmax(xt @ p["router"]["w"], axis=-1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / jnp.sum(gv, -1, keepdims=True)
    ys = []
    for t in range(xt.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.top_k):
            e = int(gi[t, j])
            h = jax.nn.silu(xt[t] @ p["gate"][e]) * (xt[t] @ p["up"][e])
            acc += gv[t, j] * (h @ p["down"][e])
        ys.append(acc)
    brute = jnp.stack(ys).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(out.y), np.asarray(brute),
                               rtol=2e-2, atol=2e-3)


def test_moe_capacity_drops_fall_back_to_zero(rng):
    """With capacity 4 (floor) and many tokens, dropped tokens contribute 0
    (residual passthrough at the block level)."""
    cfg = _cfg(e=2, k=1, cf=1e-9)
    p = moe_init(rng, cfg, jnp.float32)
    x = jax.random.normal(rng, (1, 64, cfg.d_model))
    out = moe_forward(p, cfg, x)
    # Some rows should be exactly zero (dropped).
    norms = jnp.linalg.norm(out.y.reshape(-1, cfg.d_model), axis=-1)
    assert int(jnp.sum(norms == 0)) > 0
    assert bool(jnp.all(jnp.isfinite(out.y)))


@given(seed=st.integers(0, 2**31 - 1), tokens=st.sampled_from([4, 16, 64]))
@settings(max_examples=10, deadline=None)
def test_moe_gate_weights_sum_bounded(seed, tokens):
    """Output magnitude is bounded by the max single-expert output (convex
    gate combination property)."""
    cfg = _cfg(cf=64.0)
    key = jax.random.PRNGKey(seed)
    p = moe_init(key, cfg, jnp.float32)
    x = 0.5 * jax.random.normal(key, (1, tokens, cfg.d_model))
    out = moe_forward(p, cfg, x)
    per_expert = []
    xt = x.reshape(-1, cfg.d_model)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xt @ p["gate"][e]) * (xt @ p["up"][e])
        per_expert.append(h @ p["down"][e])
    stack = jnp.stack(per_expert)                      # (E, T, D)
    max_norm = jnp.max(jnp.linalg.norm(stack, axis=-1))
    out_norm = jnp.max(jnp.linalg.norm(out.y.reshape(-1, cfg.d_model), axis=-1))
    assert float(out_norm) <= float(max_norm) * (1 + 1e-3)


def test_shared_experts_added(rng):
    cfg = dataclasses.replace(_cfg(), n_shared_experts=1)
    p = moe_init(rng, cfg, jnp.float32)
    x = jax.random.normal(rng, (1, 8, cfg.d_model))
    out_with = moe_forward(p, cfg, x)
    p2 = dict(p)
    p2["shared_down"] = {"w": jnp.zeros_like(p["shared_down"]["w"])}
    out_without = moe_forward(p2, cfg, x)
    assert float(jnp.max(jnp.abs(out_with.y - out_without.y))) > 1e-6
