"""Shift detection (core.shift), shift-conditioned schedules and restarts
(core.policy), and the `adaptive` PolicyEngine end to end.

The statistical claims of the adaptive layer are pinned as executable
tests: zero false alarms on stationary workloads over T = 20k slots,
bounded detection delay after a `piecewise` segment boundary, bit-exact
reduction to the fixed-schedule policy when the detector is disabled, and
lower cumulative ground-truth cost than fixed-η H2T2 under OOD drift (the
acceptance bar, at reduced horizon).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    COUNTER_CAP,
    HIConfig,
    ShiftConfig,
    adapt_schedule,
    detect_shifts,
    fleet_init,
    fleet_restart,
    shift_init,
    shift_update,
)
from repro.core.policy import quantize
from repro.data.scenarios import get_scenario
from repro.serving import (
    AdaptiveEngine,
    AdaptiveState,
    HIServer,
    HIServerConfig,
    get_engine,
)

CFG = HIConfig(bits=4, eps=0.05, eta=1.0)


def _conf_signal(fs, bits=4):
    """The quantized-confidence signal the adaptive engine feeds its
    detector (i_f / G)."""
    return quantize(fs, bits).astype(jnp.float32) / (1 << bits)


def _piecewise(spec_b, horizon=4000, n_streams=4, block=500):
    return get_scenario(
        "piecewise",
        segments=((0, "breakhis"), (horizon // 2, spec_b)),
        n_streams=n_streams,
        horizon=horizon,
        block=block,
        key=jax.random.PRNGKey(0),
        beta=0.3,
    )


# ------------------------------- configuration --------------------------------


def test_shift_config_validation():
    with pytest.raises(ValueError, match="detector"):
        ShiftConfig(detector="psychic")
    with pytest.raises(ValueError, match="signal"):
        ShiftConfig(signal="vibes")
    with pytest.raises(ValueError, match="threshold"):
        ShiftConfig(threshold=0.0)
    with pytest.raises(ValueError, match="mean_rate"):
        ShiftConfig(mean_rate=0.0)
    with pytest.raises(ValueError, match="stride"):
        ShiftConfig(stride=0)
    with pytest.raises(ValueError, match="eta_boost"):
        ShiftConfig(eta_boost=0.5)
    with pytest.raises(ValueError, match="recovery_decay"):
        ShiftConfig(recovery_decay=1.5)
    # Arming a cusum before its scale estimate has warmed guarantees false
    # alarms; the config refuses outright.
    with pytest.raises(ValueError, match="warmup"):
        ShiftConfig(warmup=100)
    ShiftConfig(detector="ewma", warmup=100)  # per-slot detector: fine
    assert not ShiftConfig(detector="none").enabled
    assert ShiftConfig().enabled


def test_detector_none_is_free():
    scfg = ShiftConfig(detector="none")
    state = shift_init(3)
    new_state, alarm = shift_update(scfg, state, jnp.ones((3,)))
    assert new_state is state
    assert not bool(jnp.any(alarm))


# --------------------------------- detection ----------------------------------


def test_cusum_detects_synthetic_step():
    """A clean +0.3 level step on low noise alarms within a few blocks."""
    scfg = ShiftConfig()
    s, t, t_shift = 3, 3000, 1500
    noise = 0.03 * jax.random.normal(jax.random.PRNGKey(0), (s, t))
    level = jnp.where(jnp.arange(t)[None, :] < t_shift, 0.3, 0.6)
    final, alarms = detect_shifts(scfg, level + noise)
    alarms = np.asarray(alarms)
    assert alarms[:, :t_shift].sum() == 0
    for i in range(s):
        fired = np.argwhere(alarms[i]).ravel()
        assert len(fired) >= 1
        assert t_shift < fired[0] <= t_shift + 300
    assert np.all(np.asarray(final.n_alarms) >= 1)


def test_cusum_one_sided_ignores_downward_step():
    scfg = ShiftConfig(two_sided=False)
    s, t = 2, 3000
    noise = 0.03 * jax.random.normal(jax.random.PRNGKey(1), (s, t))
    down = jnp.where(jnp.arange(t)[None, :] < 1500, 0.6, 0.3)
    _, alarms = detect_shifts(scfg, down + noise)
    assert int(np.asarray(alarms).sum()) == 0
    _, alarms_up = detect_shifts(scfg, -(down - 0.9) + noise)
    assert int(np.asarray(alarms_up).sum()) >= s


def test_no_false_alarms_stationary_20k():
    """The headline null claim: on every tested stationary workload the
    default detector raises zero alarms over T = 20k slots, so the adaptive
    engine never restarts a healthy fleet."""
    for i, spec in enumerate(["synthetic", "chest", "breach"]):
        src = get_scenario(
            "stationary",
            spec=spec,
            n_streams=8,
            horizon=20_000,
            key=jax.random.PRNGKey(2 + i),
        )
        xs = _conf_signal(src.materialize().fs)
        final, alarms = detect_shifts(ShiftConfig(), xs)
        assert int(np.asarray(alarms).sum()) == 0, spec
        assert np.all(np.asarray(final.n_alarms) == 0), spec


@pytest.mark.parametrize("spec_b,max_delay", [("xract", 800), ("breach", 1200)])
def test_detection_delay_bounded_piecewise(spec_b, max_delay):
    """Every stream alarms within a bounded window after the segment
    boundary, and never before it."""
    src = _piecewise(spec_b)
    xs = _conf_signal(src.materialize().fs)
    _, alarms = detect_shifts(ShiftConfig(), xs)
    alarms = np.asarray(alarms)
    t_shift = 2000
    assert alarms[:, :t_shift].sum() == 0
    for i in range(alarms.shape[0]):
        fired = np.argwhere(alarms[i]).ravel()
        assert len(fired) >= 1, f"stream {i} never detected the shift"
        assert t_shift < fired[0] <= t_shift + max_delay, (i, fired[0])


# ------------------------- schedules and restarts -----------------------------


def test_adapt_schedule_boost_and_anneal():
    scfg = ShiftConfig(eta_boost=3.0, recovery_decay=0.97, recovery=100.0)
    cfg = HIConfig(eta=0.5, decay=1.0)
    state = shift_init(2)
    # Never alarmed: exactly the fixed schedule.
    eta, decay = adapt_schedule(cfg, scfg, state)
    np.testing.assert_array_equal(np.asarray(eta), np.float32(0.5))
    np.testing.assert_array_equal(np.asarray(decay), np.float32(1.0))
    # Right after an alarm: full boost on the alarmed stream only.
    state = state._replace(since_alarm=jnp.asarray([0, COUNTER_CAP], jnp.int32))
    eta, decay = adapt_schedule(cfg, scfg, state)
    np.testing.assert_allclose(np.asarray(eta), [1.5, 0.5], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(decay), [0.97, 1.0], rtol=1e-6)
    # recovery_decay=None leaves the decay untouched even at full boost.
    eta, decay = adapt_schedule(cfg, ShiftConfig(eta_boost=3.0), state)
    np.testing.assert_array_equal(np.asarray(decay), np.float32(1.0))
    # The boost anneals monotonically.
    state = state._replace(since_alarm=jnp.asarray([100, 300], jnp.int32))
    eta, _ = adapt_schedule(cfg, scfg, state)
    assert 0.5 < float(eta[1]) < float(eta[0]) < 1.5


def test_fleet_restart_masked_and_preserves_history():
    cfg = HIConfig(bits=3)
    state = fleet_init(cfg, 3)
    state = state._replace(
        log_w=state.log_w - 2.0,
        t=jnp.full((3,), 7, jnp.int32),
        n_offloads=jnp.asarray([1, 2, 3], jnp.int32),
    )
    fresh = fleet_init(cfg, 3)
    out = fleet_restart(cfg, state, jnp.asarray([True, False, True]))
    # Restarted streams get the fresh grid back (valid cells at 0, rest -inf).
    np.testing.assert_array_equal(np.asarray(out.log_w[0]), np.asarray(fresh.log_w[0]))
    np.testing.assert_array_equal(np.asarray(out.log_w[2]), np.asarray(fresh.log_w[2]))
    # Unmasked streams keep their weights; every counter is preserved.
    np.testing.assert_array_equal(np.asarray(out.log_w[1]), np.asarray(state.log_w[1]))
    np.testing.assert_array_equal(np.asarray(out.t), np.asarray(state.t))
    np.testing.assert_array_equal(
        np.asarray(out.n_offloads), np.asarray(state.n_offloads)
    )


# ----------------------------- adaptive engine --------------------------------


def test_adaptive_engine_registered_with_state_views():
    eng = get_engine("adaptive", CFG)
    assert isinstance(eng, AdaptiveEngine)
    state = eng.init(5)
    assert isinstance(state, AdaptiveState)
    np.testing.assert_array_equal(
        np.asarray(state.log_w), np.asarray(state.policy.log_w)
    )
    assert state.t is state.policy.t
    assert state.n_offloads is state.policy.n_offloads
    assert state.n_explores is state.policy.n_explores
    assert np.asarray(state.shift.n_alarms).shape == (5,)


def test_adaptive_disabled_is_bitwise_reference():
    """With the detector off the adaptive engine IS the reference policy:
    same decisions, losses, and weights, bit for bit."""
    cfg = HIConfig(bits=3, eps=0.1, eta=0.9, decay=0.99)
    s, t = 4, 96
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    fs = jax.random.uniform(ks[0], (s, t))
    hrs = jax.random.bernoulli(ks[1], 0.5, (s, t)).astype(jnp.int32)
    betas = jnp.full((s, t), 0.3)
    key = jax.random.PRNGKey(11)
    st_ref, o_ref = get_engine("reference", cfg).run(fs, hrs, betas, key)
    eng = get_engine("adaptive", cfg, shift=ShiftConfig(detector="none"))
    st_ad, o_ad = eng.run(fs, hrs, betas, key)
    np.testing.assert_array_equal(np.asarray(o_ref.offload), np.asarray(o_ad.offload))
    np.testing.assert_array_equal(np.asarray(o_ref.pred), np.asarray(o_ad.pred))
    np.testing.assert_array_equal(np.asarray(o_ref.loss), np.asarray(o_ad.loss))
    np.testing.assert_array_equal(
        np.asarray(st_ref.log_w), np.asarray(st_ad.policy.log_w)
    )
    assert int(jnp.sum(st_ad.shift.n_alarms)) == 0


def test_adaptive_stationary_no_alarms_matches_fixed():
    """On a stationary source the enabled detector never fires, so the
    adaptive run follows the fixed schedule (same decisions; weights may
    differ by float-fusion ulps)."""
    src = lambda: get_scenario(
        "stationary", n_streams=4, horizon=2000, block=500, key=jax.random.PRNGKey(7)
    )
    key = jax.random.PRNGKey(9)
    _, o_fix = get_engine("fused", CFG).run_source(src(), key)
    st_ad, o_ad = get_engine("adaptive", CFG).run_source(src(), key)
    assert int(jnp.sum(st_ad.shift.n_alarms)) == 0
    np.testing.assert_array_equal(
        np.asarray(o_fix.offloads), np.asarray(o_ad.offloads)
    )
    np.testing.assert_allclose(
        np.asarray(o_fix.loss), np.asarray(o_ad.loss), rtol=1e-4
    )


def test_adaptive_beats_fixed_on_ood_drift():
    """ACCEPTANCE: under piecewise OOD drift the adaptive engine achieves
    lower cumulative ground-truth cost than fixed-η H2T2, and it got there
    by actually restarting."""
    key = jax.random.PRNGKey(11)
    _, o_fix = get_engine("fused", CFG).run_source(_piecewise("xract"), key)
    st_ad, o_ad = get_engine("adaptive", CFG).run_source(_piecewise("xract"), key)
    fixed_cost = float(jnp.sum(o_fix.true_loss))
    adaptive_cost = float(jnp.sum(o_ad.true_loss))
    assert adaptive_cost < 0.95 * fixed_cost, (adaptive_cost, fixed_cost)
    assert np.all(np.asarray(st_ad.shift.n_alarms) >= 1)


def test_oracle_restart_run_reproduces_fixed_without_restarts():
    """bench_adaptive's oracle runner on zero restart slots is decision-
    identical to the chunked fixed-engine run — the paired-randomness
    contract the whole bench rests on."""
    from benchmarks.bench_adaptive import oracle_restart_run

    src = lambda: get_scenario(
        "stationary", n_streams=3, horizon=512, block=128, key=jax.random.PRNGKey(4)
    )
    key = jax.random.PRNGKey(11)
    _, out = get_engine("fused", CFG).run_source(src(), key)
    loss, true, off = oracle_restart_run(CFG, src(), key, ())
    np.testing.assert_allclose(
        np.asarray(loss).reshape(3, 4, 128).sum(-1),
        np.asarray(out.loss),
        atol=1e-4,
    )
    np.testing.assert_array_equal(
        np.asarray(off).reshape(3, 4, 128).sum(-1).astype(np.int32),
        np.asarray(out.offloads),
    )


def test_hi_server_serves_adaptive_engine():
    """HIServer drives the adaptive engine through the decide/feedback split
    unchanged — composite state, capacity, and summary all intact."""
    cfg = HIServerConfig(
        n_streams=4,
        hi=HIConfig(bits=3, eps=0.1),
        engine="adaptive",
        offload_capacity=2,
    )
    srv = HIServer(cfg, ldl=None, rdl=None)
    src = get_scenario(
        "piecewise", n_streams=4, horizon=256, block=64, key=jax.random.PRNGKey(3)
    )
    state, summary = srv.run_source(src, jax.random.PRNGKey(5))
    assert isinstance(state.policy, AdaptiveState)
    assert int(state.t) == 256
    assert 0.0 <= summary["offload_rate"] <= 1.0
    assert summary["rdl_compute_rows"] <= 2 * 256
