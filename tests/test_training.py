"""Training substrate: loss decreases, grad-accum equivalence, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LDL_CONFIG
from repro.data import synthetic_batch
from repro.models import init_params
from repro.training import (
    AdamWConfig,
    TrainState,
    build_train_step,
    checkpoint,
    init_opt_state,
)


def _state(cfg, key):
    params = init_params(key, cfg)
    return TrainState(params=params, opt=init_opt_state(params))


def test_loss_decreases_over_steps(rng):
    cfg = LDL_CONFIG.reduced(vocab=128, n_layers=2)
    state = _state(cfg, rng)
    step = jax.jit(build_train_step(cfg, AdamWConfig(lr=3e-3, warmup_steps=5,
                                                     total_steps=100)))
    losses = []
    key = rng
    for i in range(30):
        key, sub = jax.random.split(key)
        b = synthetic_batch(sub, batch=8, seq=32, vocab=cfg.vocab)
        state, metrics = step(state, b._asdict())
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_grad_accum_matches_single_batch(rng):
    """microbatches=2 ≡ microbatches=1 (same data, same update)."""
    cfg = LDL_CONFIG.reduced(vocab=64, n_layers=2)
    state0 = _state(cfg, rng)
    b = synthetic_batch(rng, batch=8, seq=16, vocab=cfg.vocab)._asdict()
    s1, m1 = build_train_step(cfg, AdamWConfig(lr=1e-3))(state0, b)
    s2, m2 = build_train_step(cfg, AdamWConfig(lr=1e-3), microbatches=2)(state0, b)
    d = jax.tree.map(
        lambda a, c: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - c.astype(jnp.float32)))),
        s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 5e-2   # bf16 params; update magnitudes ~lr


def test_lr_schedule_shape():
    from repro.training.optimizer import schedule

    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    warm = [float(schedule(cfg, jnp.asarray(s))) for s in range(11)]
    assert warm[0] == 0.0 and abs(warm[10] - 1.0) < 1e-6
    assert all(b >= a - 1e-9 for a, b in zip(warm, warm[1:]))
    end = float(schedule(cfg, jnp.asarray(100)))
    assert abs(end - 0.1) < 1e-6


def test_grad_clip_bounds_update():
    from repro.training.optimizer import apply_updates

    cfg = AdamWConfig(lr=1e-2, grad_clip=0.5, weight_decay=0.0)
    params = {"w": jnp.ones((4, 4), jnp.float32)}
    grads = {"w": jnp.full((4, 4), 100.0)}
    state = init_opt_state(params)
    _, _, metrics = apply_updates(cfg, params, grads, state)
    assert float(metrics["grad_norm"]) > 0.5   # raw norm reported pre-clip


def test_checkpoint_roundtrip(rng):
    cfg = LDL_CONFIG.reduced(vocab=64, n_layers=2)
    state = _state(cfg, rng)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        checkpoint.save(path, state)
        restored = checkpoint.restore(path, jax.tree.map(jnp.zeros_like, state))
        diff = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - b.astype(jnp.float32)))),
            state, restored)
        assert max(jax.tree.leaves(diff)) == 0.0


def test_checkpoint_shape_mismatch_raises(rng):
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        checkpoint.save(path, {"a": jnp.zeros((3,))})
        with pytest.raises((ValueError, KeyError)):
            checkpoint.restore(path, {"a": jnp.zeros((4,))})
