"""Tentpole coverage: the kernel-backed fleet engine vs the reference path.

`run_fleet_fused` pre-draws the (ψ, ζ) randomness with the exact key tree of
`run_fleet`, so the two must agree decision-for-decision — not just in
distribution — on any trace. The multi-round (time-blocked) kernel must match
a chain of single-round steps, and the serving policy engines must be
interchangeable.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HIConfig, fleet_init, run_fleet, run_fleet_fused
from repro.kernels.hedge.ops import fleet_hedge_rounds, fleet_hedge_step
from repro.serving import get_engine


from conftest import fleet_trace as _fleet_trace


def _rand_logw(key, s, g):
    l = jnp.arange(g)[:, None]
    u = jnp.arange(g)[None, :]
    lw = jax.random.normal(key, (s, g, g))
    return jnp.where(l <= u, lw - jnp.max(lw), -1e30).astype(jnp.float32)


# ------------------------- fused-vs-reference parity --------------------------


def test_run_fleet_fused_matches_run_fleet_64x2048():
    """Acceptance-scale parity: identical offload/pred/loss sequences on a
    64-stream × 2048-round trace, Pallas kernel in interpret mode."""
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    fs, hrs, betas = _fleet_trace(jax.random.PRNGKey(0), 64, 2048)
    key = jax.random.PRNGKey(7)
    st_ref, out_ref = run_fleet(cfg, fs, hrs, betas, key)
    st_fus, out_fus = run_fleet_fused(cfg, fs, hrs, betas, key,
                                      use_kernel=True, interpret=True)
    assert np.array_equal(np.asarray(out_ref.offload), np.asarray(out_fus.offload))
    assert np.array_equal(np.asarray(out_ref.pred), np.asarray(out_fus.pred))
    np.testing.assert_allclose(np.asarray(out_ref.loss), np.asarray(out_fus.loss),
                               atol=1e-5)
    assert np.array_equal(np.asarray(st_ref.t), np.asarray(st_fus.t))
    assert np.array_equal(np.asarray(st_ref.n_offloads),
                          np.asarray(st_fus.n_offloads))
    valid = np.isfinite(np.asarray(st_ref.log_w))
    np.testing.assert_allclose(np.asarray(st_fus.log_w)[valid],
                               np.asarray(st_ref.log_w)[valid], atol=1e-4)
    assert np.all(np.isneginf(np.asarray(st_fus.log_w)[~valid]))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_run_fleet_fused_small_parity(use_kernel):
    """Both fused engines (jnp oracle, interpret kernel) match the reference
    on a small trace, including q/p masses and exploration flags."""
    cfg = HIConfig(bits=3, eps=0.1, eta=0.9)
    fs, hrs, betas = _fleet_trace(jax.random.PRNGKey(1), 8, 128)
    key = jax.random.PRNGKey(11)
    _, out_ref = run_fleet(cfg, fs, hrs, betas, key)
    _, out_fus = run_fleet_fused(cfg, fs, hrs, betas, key,
                                 use_kernel=use_kernel,
                                 interpret=True if use_kernel else None)
    assert np.array_equal(np.asarray(out_ref.offload), np.asarray(out_fus.offload))
    assert np.array_equal(np.asarray(out_ref.explored), np.asarray(out_fus.explored))
    assert np.array_equal(np.asarray(out_ref.local_pred),
                          np.asarray(out_fus.local_pred))
    np.testing.assert_allclose(np.asarray(out_ref.q), np.asarray(out_fus.q),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(out_ref.p), np.asarray(out_fus.p),
                               atol=1e-5)


def test_run_fleet_fused_decay_matches_reference():
    """Discounted Hedge (decay < 1) goes through the kernel path too."""
    cfg = HIConfig(bits=3, eps=0.1, eta=1.0, decay=0.97)
    fs, hrs, betas = _fleet_trace(jax.random.PRNGKey(2), 4, 96)
    key = jax.random.PRNGKey(13)
    st_ref, out_ref = run_fleet(cfg, fs, hrs, betas, key)
    st_fus, out_fus = run_fleet_fused(cfg, fs, hrs, betas, key,
                                      use_kernel=True, interpret=True)
    assert np.array_equal(np.asarray(out_ref.offload), np.asarray(out_fus.offload))
    np.testing.assert_allclose(np.asarray(out_ref.loss), np.asarray(out_fus.loss),
                               atol=1e-5)
    valid = np.isfinite(np.asarray(st_ref.log_w))
    np.testing.assert_allclose(np.asarray(st_fus.log_w)[valid],
                               np.asarray(st_ref.log_w)[valid], atol=1e-4)


def test_time_blocked_path_matches_per_round_path():
    """time_block > 1 (multi-round kernel) ≡ time_block = 1, same key."""
    cfg = HIConfig(bits=4, eps=0.1, eta=1.0)
    fs, hrs, betas = _fleet_trace(jax.random.PRNGKey(3), 8, 64)
    key = jax.random.PRNGKey(17)
    st1, out1 = run_fleet_fused(cfg, fs, hrs, betas, key,
                                use_kernel=True, interpret=True, time_block=1)
    st8, out8 = run_fleet_fused(cfg, fs, hrs, betas, key,
                                use_kernel=True, interpret=True, time_block=8)
    for a, b in zip(out1, out8):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), atol=1e-5)
    assert np.array_equal(np.asarray(st1.n_offloads), np.asarray(st8.n_offloads))
    valid = np.isfinite(np.asarray(st1.log_w))
    np.testing.assert_allclose(np.asarray(st8.log_w)[valid],
                               np.asarray(st1.log_w)[valid], atol=1e-4)


def test_time_block_must_divide_horizon():
    cfg = HIConfig(bits=2)
    fs, hrs, betas = _fleet_trace(jax.random.PRNGKey(4), 2, 10)
    with pytest.raises(ValueError, match="time_block"):
        run_fleet_fused(cfg, fs, hrs, betas, jax.random.PRNGKey(0),
                        time_block=4)


# ----------------------- kernel golden tests (G sweep) ------------------------


@pytest.mark.parametrize("bits", [3, 4, 5])          # G ∈ {8, 16, 32}
def test_step_kernel_golden_vs_ref(bits):
    cfg = HIConfig(bits=bits, eps=0.07, eta=0.9, decay=0.95)
    g = cfg.grid
    s = 8
    ks = jax.random.split(jax.random.PRNGKey(bits), 6)
    logw = _rand_logw(ks[0], s, g)
    f = jax.random.uniform(ks[1], (s,))
    psi = jax.random.uniform(ks[2], (s,))
    zeta = jax.random.bernoulli(ks[3], 0.3, (s,)).astype(jnp.int32)
    hr = jax.random.bernoulli(ks[4], 0.5, (s,)).astype(jnp.int32)
    beta = jax.random.uniform(ks[5], (s,), maxval=0.6)
    outk = fleet_hedge_step(cfg, logw, f, psi, zeta, hr, beta, use_kernel=True)
    outr = fleet_hedge_step(cfg, logw, f, psi, zeta, hr, beta, use_kernel=False)
    for a, b in zip(outk, outr):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), atol=1e-5)


@pytest.mark.parametrize("bits", [3, 4, 5])          # G ∈ {8, 16, 32}
def test_rounds_kernel_golden_vs_ref_and_chained_steps(bits):
    """Multi-round kernel == scan of the jnp oracle == chained single steps."""
    cfg = HIConfig(bits=bits, eps=0.1, eta=1.0)
    g = cfg.grid
    s, tb = 4, 8
    ks = jax.random.split(jax.random.PRNGKey(100 + bits), 6)
    logw = _rand_logw(ks[0], s, g)
    f = jax.random.uniform(ks[1], (s, tb))
    psi = jax.random.uniform(ks[2], (s, tb))
    zeta = jax.random.bernoulli(ks[3], 0.2, (s, tb)).astype(jnp.int32)
    hr = jax.random.bernoulli(ks[4], 0.5, (s, tb)).astype(jnp.int32)
    beta = jax.random.uniform(ks[5], (s, tb), maxval=0.6)

    outk = fleet_hedge_rounds(cfg, logw, f, psi, zeta, hr, beta,
                              use_kernel=True, interpret=True)
    outr = fleet_hedge_rounds(cfg, logw, f, psi, zeta, hr, beta,
                              use_kernel=False)
    for a, b in zip(outk, outr):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), atol=1e-5)

    # Chain the single-round step and compare round outputs + final weights.
    lw = logw
    for t in range(tb):
        lw, off, exp_, lp, q, p = fleet_hedge_step(
            cfg, lw, f[:, t], psi[:, t], zeta[:, t], hr[:, t], beta[:, t],
            use_kernel=True, interpret=True)
        assert np.array_equal(np.asarray(off), np.asarray(outk[1][:, t]))
        assert np.array_equal(np.asarray(lp), np.asarray(outk[3][:, t]))
        np.testing.assert_allclose(np.asarray(q), np.asarray(outk[4][:, t]),
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(lw), np.asarray(outk[0]), atol=1e-4)


# --------------------------- serving policy engines ---------------------------


def test_policy_engines_interchangeable():
    """get_engine("reference") and ("fused") give identical slot decisions
    and states for identical per-stream keys (cross-engine state handoff)."""
    cfg = HIConfig(bits=4, eps=0.1, eta=1.0)
    s = 8
    state = fleet_init(cfg, s)
    ref = get_engine("reference", cfg)
    fus = get_engine("fused", cfg)
    key = jax.random.PRNGKey(23)
    for t in range(5):
        key, k1, k2 = jax.random.split(key, 3)
        fs = jax.random.uniform(k1, (s,))
        hrs = jax.random.bernoulli(k2, 0.5, (s,)).astype(jnp.int32)
        betas = jnp.full((s,), 0.25)
        keys = jax.random.split(jax.random.fold_in(key, t), s)
        s_ref, o_ref = ref.step(state, fs, betas, hrs, keys)
        s_fus, o_fus = fus.step(state, fs, betas, hrs, keys)
        assert np.array_equal(np.asarray(o_ref.offload), np.asarray(o_fus.offload))
        assert np.array_equal(np.asarray(o_ref.pred), np.asarray(o_fus.pred))
        np.testing.assert_allclose(np.asarray(o_ref.loss),
                                   np.asarray(o_fus.loss), atol=1e-6)
        valid = np.isfinite(np.asarray(s_ref.log_w))
        np.testing.assert_allclose(np.asarray(s_fus.log_w)[valid],
                                   np.asarray(s_ref.log_w)[valid], atol=1e-5)
        state = s_fus
