"""Sharding-rule unit tests (pure CPU — no device mesh needed beyond 1)."""
import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_shape
from repro.launch import sharding


class FakeMesh:
    """Duck-typed mesh: only .shape is consulted by the spec builders."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.size = 1
        for v in axes.values():
            self.size *= v


MESH = FakeMesh(data=16, model=16)


def _specs(arch, strategy="2d"):
    from repro.launch.builders import abstract_params

    shapes = abstract_params(ARCHS[arch])
    return sharding.param_specs(shapes, MESH, strategy), shapes


def _walk(specs, shapes, path=""):
    if isinstance(specs, dict):
        for k in specs:
            yield from _walk(specs[k], shapes[k], f"{path}/{k}")
    elif isinstance(specs, (list, tuple)) and not isinstance(specs, P):
        for i, (s, sh) in enumerate(zip(specs, shapes)):
            yield from _walk(s, sh, f"{path}/{i}")
    else:
        yield path, specs, shapes


def test_no_duplicate_axes_any_arch():
    for arch in ARCHS:
        specs, shapes = _specs(arch)
        for path, spec, shape in _walk(specs, shapes):
            used = []
            for e in spec:
                if isinstance(e, (tuple, list)):
                    used.extend(e)
                elif e is not None:
                    used.append(e)
            assert len(used) == len(set(used)), (arch, path, spec)


def test_divisibility_every_spec():
    for arch in ARCHS:
        specs, shapes = _specs(arch)
        for path, spec, shape in _walk(specs, shapes):
            for dim, e in zip(shape.shape, tuple(spec) + (None,) * 8):
                n = 1
                for ax in (e if isinstance(e, (tuple, list)) else [e]):
                    if ax is not None:
                        n *= MESH.shape[ax]
                assert dim % n == 0, (arch, path, spec, shape.shape)


def test_vocab_weights_model_only():
    specs, shapes = _specs("qwen2-1.5b")
    for path, spec, shape in _walk(specs, shapes):
        if "/embed/" in path:
            assert spec[0] == "model" and spec[1] is None, (path, spec)


def test_row_parallel_projections():
    specs, shapes = _specs("yi-34b", strategy="tp")
    seen = 0
    for path, spec, shape in _walk(specs, shapes):
        if path.endswith(("/down/w", "/o/w")):
            # Stacked body weights: (groups, row@model, col)
            assert "model" in tuple(spec), (path, spec)
            assert spec[-1] is None or spec[-1] != "model" or True
            seen += 1
    assert seen >= 2


def test_mixtral_expert_hybrid_sharding():
    specs, shapes = _specs("mixtral-8x7b", strategy="2d")
    found = 0
    for path, spec, shape in _walk(specs, shapes):
        if path.endswith(("/moe/gate", "/moe/up", "/moe/down")):
            flat = [a for e in spec if e is not None
                    for a in (e if isinstance(e, (tuple, list)) else [e])]
            assert "model" in flat, (path, spec)
            assert "data" in flat, (path, spec)   # hybrid TP+ZeRO storage
            found += 1
    assert found >= 3


def test_opt_state_specs_add_data_axis():
    specs, shapes = _specs("yi-34b", strategy="tp")
    mv = sharding.opt_state_specs(specs, shapes, MESH)
    improved = 0
    for (p1, s1, sh), (p2, s2, _) in zip(_walk(specs, shapes), _walk(mv, shapes)):
        flat1 = {a for e in s1 if e is not None
                 for a in (e if isinstance(e, (tuple, list)) else [e])}
        flat2 = {a for e in s2 if e is not None
                 for a in (e if isinstance(e, (tuple, list)) else [e])}
        assert flat1 <= flat2
        if "data" in flat2 - flat1:
            improved += 1
    assert improved > 10   # most big weights gain a data shard


def test_batch_spec_fallbacks():
    m1 = FakeMesh(data=16, model=16)
    assert sharding.batch_spec(m1, 256) == P("data", None)
    assert sharding.batch_spec(m1, 1) == P(None, None)
    m2 = FakeMesh(pod=2, data=16, model=16)
    assert sharding.batch_spec(m2, 256) == P(("pod", "data"), None)
    assert sharding.batch_spec(m2, 16) == P("data", None)


def test_default_strategy_uses_total_params():
    from repro.launch.builders import default_strategy

    mesh = FakeMesh(data=16, model=16)
    dec = get_shape("decode_32k")
    tr = get_shape("train_4k")
    assert default_strategy(ARCHS["qwen2-1.5b"], dec, mesh) == "tp"
    assert default_strategy(ARCHS["deepseek-v2-236b"], dec, mesh) == "2d"
    assert default_strategy(ARCHS["qwen2-1.5b"], tr, mesh) == "2d"
