"""Recurrent blocks: RG-LRU scan vs step recurrence; SSD seeded-state decode
chain; discounted-hedge policy behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import HIConfig, run_stream
from repro.models.rglru import lru_scan


def test_lru_associative_scan_matches_loop(rng):
    b, s, w = 2, 33, 8
    log_a = -jax.nn.softplus(jax.random.normal(rng, (b, s, w)))
    gx = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, w))
    h_scan = lru_scan(log_a, gx)
    h = jnp.zeros((b, w))
    outs = []
    for t in range(s):
        h = jnp.exp(log_a[:, t]) * h + gx[:, t]
        outs.append(h)
    h_loop = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h_loop),
                               rtol=1e-5, atol=1e-5)


def test_lru_scan_with_initial_state(rng):
    b, s, w = 1, 16, 4
    log_a = -jax.nn.softplus(jax.random.normal(rng, (b, s, w)))
    gx = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, w))
    h0 = jax.random.normal(jax.random.fold_in(rng, 2), (b, w))
    h_seeded = lru_scan(log_a, gx, h0=h0)
    # Equivalent: prepend a step that produces h0 exactly.
    h = h0
    outs = []
    for t in range(s):
        h = jnp.exp(log_a[:, t]) * h + gx[:, t]
        outs.append(h)
    np.testing.assert_allclose(np.asarray(h_seeded),
                               np.asarray(jnp.stack(outs, 1)),
                               rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_lru_decay_bounded(seed):
    """|h_t| ≤ max|gx| / (1 − max a) — geometric-series stability bound."""
    key = jax.random.PRNGKey(seed)
    b, s, w = 1, 64, 4
    log_a = -jax.nn.softplus(jax.random.normal(key, (b, s, w))) - 0.1
    gx = jax.random.normal(jax.random.fold_in(key, 1), (b, s, w))
    h = lru_scan(log_a, gx)
    a_max = float(jnp.exp(jnp.max(log_a)))
    bound = float(jnp.max(jnp.abs(gx))) / (1 - a_max)
    assert float(jnp.max(jnp.abs(h))) <= bound + 1e-3


def test_discounted_hedge_still_learns():
    """decay < 1 (beyond-paper) must not break convergence on a stationary
    stream: cost stays within 10% of the vanilla policy."""
    from repro.data import dataset_trace

    tr = dataset_trace("breakhis", 6000, jax.random.PRNGKey(0), beta=0.3)
    costs = {}
    for decay in (1.0, 0.999):
        cfg = HIConfig(bits=4, eps=0.05, eta=1.0, decay=decay)
        _, out = run_stream(cfg, tr.fs, tr.hrs, tr.betas, jax.random.PRNGKey(1))
        costs[decay] = float(jnp.mean(out.loss))
    assert costs[0.999] <= costs[1.0] * 1.10, costs


def test_discounted_hedge_keeps_invalid_cells_dead():
    cfg = HIConfig(bits=3, decay=0.99)
    tr_key = jax.random.PRNGKey(2)
    fs = jax.random.uniform(tr_key, (200,))
    hrs = jax.random.bernoulli(tr_key, 0.5, (200,)).astype(jnp.int32)
    betas = jnp.full((200,), 0.3)
    st_, _ = run_stream(cfg, fs, hrs, betas, tr_key)
    g = cfg.grid
    l = np.arange(g)[:, None]
    u = np.arange(g)[None, :]
    lw = np.asarray(st_.log_w)
    assert np.all(np.isneginf(lw[l > u]) | (lw[l > u] < -1e20))
    assert np.all(np.isfinite(lw[l <= u]))
