"""Property-based tests (hypothesis) for the paper's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import HIConfig, calibrated_rule, multiclass_rule, optimal_thresholds
from repro.core.policy import pseudo_loss, quantize, region_masks

SETTINGS = dict(max_examples=40, deadline=None)


@given(
    f=st.floats(0.0, 0.999),
    beta=st.floats(0.01, 0.99),
    h_r=st.integers(0, 1),
    eps=st.floats(0.01, 0.5),
)
@settings(**SETTINGS)
def test_pseudo_loss_unbiased(f, beta, h_r, eps):
    """Lemma 1: E_ζ[l̃_t(θ)] = l_t(θ) for every expert, any f/β/h_r.

    E splits on the two feedback events: exploration (prob ε, only fires when
    the chosen expert is unambiguous) and region-2 offload. For a FIXED expert
    θ the pseudo-loss expectation over ζ must equal its true loss
    l_t(θ) = β if ambiguous else φ.
    """
    cfg = HIConfig(bits=4, eps=eps)
    i_f = quantize(jnp.asarray(f), cfg.bits)
    r1, r2, r3 = region_masks(i_f, cfg.grid)

    # Case the chosen expert is ambiguous: O=1 always, E=0 (ζ can be 1 but
    # E_t requires f outside the chosen expert's band).
    lt_off = pseudo_loss(cfg, i_f, jnp.asarray(True), jnp.asarray(False),
                         jnp.asarray(h_r), jnp.asarray(beta))
    # Case unambiguous: with prob ε, O=E=1; else no feedback.
    lt_exp = pseudo_loss(cfg, i_f, jnp.asarray(True), jnp.asarray(True),
                         jnp.asarray(h_r), jnp.asarray(beta))
    lt_none = pseudo_loss(cfg, i_f, jnp.asarray(False), jnp.asarray(False),
                          jnp.asarray(h_r), jnp.asarray(beta))

    expected_amb = beta
    phi = np.where(np.asarray(r3),
                   (cfg.delta_fp if h_r == 0 else 0.0),
                   (cfg.delta_fn if h_r == 1 else 0.0))
    # Ambiguous experts: every feedback event charges them β (they would have
    # offloaded): E[l̃] over the two branches must equal β whenever O=1 paths
    # fire with total prob 1 for ambiguous-chosen rounds.
    assert np.allclose(np.asarray(lt_off)[np.asarray(r2)], expected_amb, atol=1e-6)
    # Unambiguous experts under exploration: ε · φ/ε = φ.
    est = eps * np.asarray(lt_exp) + (1 - eps) * np.asarray(lt_none)
    unamb = np.asarray(r1 | r3)
    assert np.allclose(est[unamb], phi[unamb], atol=1e-5)


@given(beta=st.floats(0.0, 1.0), dfp=st.floats(0.05, 1.0), dfn=st.floats(0.05, 1.0))
@settings(**SETTINGS)
def test_theorem1_no_offload_above_harmonic_mean(beta, dfp, dfn):
    """Remark 1(i): offload region empty iff β ≥ δ₁δ₋₁/(δ₁+δ₋₁)."""
    cfg = HIConfig(delta_fp=dfp, delta_fn=dfn)
    tl, tu = optimal_thresholds(cfg, jnp.asarray(beta))
    hm_half = dfp * dfn / (dfp + dfn)
    if beta >= hm_half + 1e-9:
        assert float(tl) == float(tu)          # collapsed: never offload
    elif beta < hm_half - 1e-9:
        assert float(tl) < float(tu)


@given(f=st.floats(0.001, 0.999), beta=st.floats(0.01, 0.99))
@settings(**SETTINGS)
def test_theorem1_cost_is_min_of_three(f, beta):
    cfg = HIConfig(delta_fp=0.7, delta_fn=1.0)
    d = calibrated_rule(cfg, jnp.asarray(f), jnp.asarray(beta))
    expect = min(beta, 0.7 * (1 - f), 1.0 * f)
    assert abs(float(d.expected_cost) - expect) < 1e-6
    # Decision consistency: offload iff β is NOT the argmin ≥ both error costs.
    if float(d.offload):
        assert beta <= min(0.7 * (1 - f), f) + 1e-6


@given(
    k=st.integers(2, 5),
    beta=st.floats(0.01, 0.99),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_theorem3_reduces_to_binary_and_dominates(k, beta, seed):
    """Theorem 3 expected cost = min(β, min_k fᵀC_k) ≤ cost of any fixed k."""
    key = jax.random.PRNGKey(seed)
    kf, kc = jax.random.split(key)
    f = jax.nn.softmax(jax.random.normal(kf, (k,)))
    c = jax.random.uniform(kc, (k, k))
    c = c * (1 - jnp.eye(k))
    d = multiclass_rule(f, c, jnp.asarray(beta))
    risks = np.asarray(f @ np.asarray(c))
    assert abs(float(d.expected_cost) - min(beta, risks.min())) < 1e-5
    assert int(d.pred) == int(risks.argmin())


@given(f=st.floats(0.0, 0.999), beta=st.floats(0.01, 0.45))
@settings(**SETTINGS)
def test_theorem1_matches_chow_when_symmetric(f, beta):
    """Remark 1(ii): δ₁=δ₋₁=1 ⇒ offload iff β < min(f, 1−f) (Chow's rule).

    The exact boundary β == min(f, 1−f) is cost-indifferent (Eq. 7 includes
    the lower edge), so it is excluded.
    """
    if abs(beta - min(f, 1 - f)) < 1e-6:
        return
    cfg = HIConfig(delta_fp=1.0, delta_fn=1.0)
    d = calibrated_rule(cfg, jnp.asarray(f), jnp.asarray(beta))
    assert bool(d.offload) == bool(beta < min(f, 1 - f))
    assert int(d.pred) == int(f >= 0.5)


@given(
    seed=st.integers(0, 2**31 - 1),
    t=st.integers(50, 300),
    beta=st.floats(0.05, 0.55),
)
@settings(max_examples=10, deadline=None)
def test_offline_two_threshold_dominates_single(seed, t, beta):
    """θ⃗* ≤ θ† ≤ naive policies on any trace (two thresholds subsume one)."""
    from repro.core import baselines, offline

    cfg = HIConfig(bits=4)
    key = jax.random.PRNGKey(seed)
    fs = jax.random.uniform(key, (t,))
    hrs = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (t,)).astype(jnp.int32)
    betas = jnp.full((t,), beta)
    two = float(offline.best_two_threshold(cfg, fs, hrs, betas).best_loss)
    one_losses = offline.single_threshold_losses(cfg, fs, hrs, betas)
    # θ=1 (always-offload) is excluded: the paper's quantized pair grid
    # {k/G : k < G} cannot express θ_u = 1, so full-offload has no
    # two-threshold counterpart (|Θ| = 2^{b−1}(2^b+1) counts G values only).
    one = float(jnp.min(one_losses[:-1]))
    no = float(jnp.sum(baselines.no_offload_losses(cfg, fs, hrs, betas)))
    assert two <= one + 1e-4
    assert one <= no + 1e-4              # θ=0 is the no-offload policy
