"""Serving integration: HI server end-to-end with tiny LDL/RDL backbones."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LDL_CONFIG, RDL_CONFIG
from repro.core import HIConfig
from repro.models import init_params
from repro.models.heads import binary_head_init
from repro.serving import (
    HIServer,
    HIServerConfig,
    classifier_fn,
    compact_offloads,
    scatter_results,
)


def test_compact_and_scatter_roundtrip():
    tokens = jnp.arange(6 * 4).reshape(6, 4).astype(jnp.int32)
    offload = jnp.asarray([True, False, True, True, False, True])
    batch = compact_offloads(tokens, offload, capacity=4)
    assert batch.tokens.shape == (4, 4)
    assert np.array_equal(np.asarray(batch.src), [0, 2, 3, 5])
    assert bool(jnp.all(batch.valid))
    results = jnp.asarray([10, 20, 30, 40], jnp.int32)
    routed = scatter_results(results, batch, n_streams=6, fill=-1)
    assert np.array_equal(np.asarray(routed), [10, -1, 20, 30, -1, 40])


def test_compact_overflow_drops_tail():
    tokens = jnp.zeros((5, 3), jnp.int32)
    offload = jnp.ones((5,), bool)
    batch = compact_offloads(tokens, offload, capacity=3)
    assert int(jnp.sum(batch.valid)) == 3


def test_hi_server_end_to_end(rng):
    """Tiny LDL/RDL transformers + H2T2 router: loss accounting consistent,
    offload rate sane, and cheaper than full-offload at moderate β."""
    n_streams, horizon, seq = 8, 60, 16
    ldl_cfg = LDL_CONFIG.reduced(vocab=64)
    rdl_cfg = RDL_CONFIG.reduced(vocab=64)
    kp, kh, kt = jax.random.split(rng, 3)
    ldl_params = init_params(kp, ldl_cfg)
    ldl_head = binary_head_init(kp, ldl_cfg)
    ldl = classifier_fn(ldl_cfg, ldl_params, ldl_head)

    def rdl(tokens):
        # Remote model = ground-truth proxy (paper's setting): label by parity.
        return (jnp.sum(tokens == 7, axis=-1) % 2).astype(jnp.int32)

    hi = HIConfig(bits=4, eps=0.1, eta=1.0)
    server = HIServer(HIServerConfig(n_streams=n_streams, hi=hi), ldl, rdl)
    tokens = jax.random.randint(kt, (horizon, n_streams, seq), 0, 64, jnp.int32)
    betas = jnp.full((horizon, n_streams), 0.2)
    state, summary = server.run(tokens, betas, jax.random.PRNGKey(5))
    assert 0.0 <= summary["offload_rate"] <= 1.0
    assert summary["avg_loss"] <= 1.0
    assert int(state.t) == horizon
    # Untrained LDL ≈ random vs parity labels: H2T2 should not do worse than
    # always paying max(FP, FN) cost, and exploration keeps offloads > 0.
    assert summary["offload_rate"] > 0.01
    assert summary["avg_loss"] <= 1.0


def test_engine_generate(rng):
    from repro.serving import Engine, EngineConfig

    cfg = LDL_CONFIG.reduced(vocab=64)
    params = init_params(rng, cfg)
    eng = Engine(cfg, params, EngineConfig(max_prompt=16, max_new_tokens=4))
    toks = jax.random.randint(rng, (2, 12), 0, 64, jnp.int32)
    out = eng.generate({"tokens": toks}, n_tokens=4)
    assert out.shape == (2, 4)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_padded)))
