"""Serving integration: the offload-aware HI server end-to-end with tiny
LDL/RDL backbones, plus batching-compaction coverage.

The load-bearing acceptance test here is `test_rdl_called_only_on_offloads`:
the RDL must never be invoked on non-offloaded samples — invocations (padded
capacity allowed) must match the offloaded-sample count.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LDL_CONFIG
from repro.core import HIConfig
from repro.models import init_params
from repro.models.heads import binary_head_init
from repro.serving import (
    HIServer,
    HIServerConfig,
    classifier_fn,
    compact_offloads,
    scatter_results,
)


# ------------------------------ offload batching ------------------------------


def test_compact_and_scatter_roundtrip():
    tokens = jnp.arange(6 * 4).reshape(6, 4).astype(jnp.int32)
    offload = jnp.asarray([True, False, True, True, False, True])
    batch = compact_offloads(tokens, offload, capacity=4)
    assert batch.tokens.shape == (4, 4)
    assert np.array_equal(np.asarray(batch.src), [0, 2, 3, 5])
    assert bool(jnp.all(batch.valid))
    results = jnp.asarray([10, 20, 30, 40], jnp.int32)
    routed = scatter_results(results, batch, n_streams=6, fill=-1)
    assert np.array_equal(np.asarray(routed), [10, -1, 20, 30, -1, 40])


def test_compact_overflow_drops_tail_deterministically():
    """Overflow beyond capacity always drops the HIGHEST stream indices —
    compaction is in stream order, so the kept set is a deterministic prefix."""
    tokens = jnp.arange(6 * 3).reshape(6, 3).astype(jnp.int32)
    offload = jnp.asarray([True, False, True, True, True, True])   # 5 offloads
    batch = compact_offloads(tokens, offload, capacity=3)
    assert int(jnp.sum(batch.valid)) == 3
    # Kept: streams 0, 2, 3 (first three offloads); dropped: 4 and 5.
    assert np.array_equal(np.asarray(batch.src), [0, 2, 3])
    assert np.array_equal(np.asarray(batch.tokens), np.asarray(tokens)[[0, 2, 3]])
    # Repeated calls agree bit-for-bit.
    again = compact_offloads(tokens, offload, capacity=3)
    assert np.array_equal(np.asarray(batch.src), np.asarray(again.src))


def test_compact_scatter_restores_per_stream_order():
    """Scatter routes each packed result back to exactly its source stream,
    whatever the offload pattern."""
    key = jax.random.PRNGKey(0)
    for trial in range(5):
        key, k1 = jax.random.split(key)
        offload = jax.random.bernoulli(k1, 0.5, (9,))
        tokens = (jnp.arange(9)[:, None] * jnp.ones((1, 2))).astype(jnp.int32)
        batch = compact_offloads(tokens, offload, capacity=9)
        # RDL result = 100 + source stream id (recoverable from the tokens).
        results = jnp.where(batch.valid, 100 + batch.tokens[:, 0], -7)
        routed = scatter_results(results, batch, n_streams=9, fill=-1)
        expect = np.where(np.asarray(offload), 100 + np.arange(9), -1)
        assert np.array_equal(np.asarray(routed), expect)


def test_compact_offloads_jit_shape_stable():
    """Output shapes depend only on capacity, never on the offload count, so
    the op stays jit-compilable with a single trace."""
    traces = []

    @jax.jit
    def compact4(tokens, offload):
        traces.append(1)
        return compact_offloads(tokens, offload, capacity=4)

    tokens = jnp.zeros((7, 3), jnp.int32)
    for n_off in (0, 2, 7):
        offload = jnp.arange(7) < n_off
        batch = compact4(tokens, offload)
        assert batch.tokens.shape == (4, 3)
        assert batch.valid.shape == (4,)
        assert batch.src.shape == (4,)
        assert int(jnp.sum(batch.valid)) == min(n_off, 4)
    assert len(traces) == 1, "retriggered trace ⇒ shape depends on data"


# ------------------------------ the HI server ---------------------------------


def _tiny_server(n_streams, engine="fused", capacity=None, eps=0.1):
    ldl_cfg = LDL_CONFIG.reduced(vocab=64)
    kp = jax.random.PRNGKey(0)
    ldl_params = init_params(kp, ldl_cfg)
    ldl_head = binary_head_init(kp, ldl_cfg)
    ldl = classifier_fn(ldl_cfg, ldl_params, ldl_head)

    calls = []

    def rdl(tokens):
        calls.append(int(tokens.shape[0]))
        return (jnp.sum(tokens == 7, axis=-1) % 2).astype(jnp.int32)

    hi = HIConfig(bits=4, eps=eps, eta=1.0)
    cfg = HIServerConfig(n_streams=n_streams, hi=hi, engine=engine,
                         offload_capacity=capacity)
    return HIServer(cfg, ldl, rdl), calls


def test_hi_server_end_to_end(rng):
    """Tiny LDL transformer + H2T2 router with offload-only RDL batching:
    cost accounting consistent, offload rate sane, savings reported."""
    n_streams, horizon, seq = 8, 60, 16
    server, calls = _tiny_server(n_streams)
    kt = jax.random.split(rng, 1)[0]
    tokens = jax.random.randint(kt, (horizon, n_streams, seq), 0, 64, jnp.int32)
    betas = jnp.full((horizon, n_streams), 0.2)
    state, summary = server.run(tokens, betas, jax.random.PRNGKey(5))
    n = horizon * n_streams
    assert int(state.t) == horizon
    assert state.pending is None            # run() flushes the double buffer
    assert 0.0 <= summary["offload_rate"] <= 1.0
    # Exploration keeps offloads alive even for an untrained LDL.
    assert summary["offload_rate"] > 0.01
    # Observable cost is β per offloaded sample.
    assert abs(summary["avg_offload_cost"]
               - 0.2 * summary["offload_rate"]) < 1e-6
    # The whole point: the RDL evaluated only the offloaded samples.
    assert summary["rdl_evals"] == float(state.total_offloads)
    assert summary["rdl_savings"] == 1.0 - summary["rdl_evals"] / n
    assert summary["rdl_batches"] <= horizon
    # Row accounting includes the capacity padding of every launch.
    assert summary["rdl_compute_rows"] == summary["rdl_batches"] * n_streams
    assert summary["rdl_row_savings"] <= summary["rdl_savings"]


@pytest.mark.parametrize("engine", ["reference", "fused", "sharded"])
def test_rdl_called_only_on_offloads(engine):
    """Acceptance: RDL invocations == offloaded-sample count (padded capacity
    allowed) — the server never evaluates the RDL on non-offloaded samples."""
    n_streams, horizon, seq = 8, 25, 12
    server, calls = _tiny_server(n_streams, engine=engine)
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (horizon, n_streams, seq), 0, 64, jnp.int32)
    betas = jnp.full((horizon, n_streams), 0.25)
    state = server.init_state()
    total_sent = 0
    for t in range(horizon):
        state, slot = server.serve_slot(
            state, tokens[t], betas[t], jax.random.fold_in(jax.random.PRNGKey(9), t))
        total_sent += int(jnp.sum(slot.sent))
    # Each RDL call is exactly one capacity-padded batch — never the raw slot.
    assert all(c == server.cfg.capacity for c in calls)
    # Valid rows across all calls == offloaded samples; padding is the only
    # slack, and it is bounded by capacity per launch.
    assert int(state.rdl_evals) == total_sent
    assert sum(calls) <= int(state.rdl_batches) * server.cfg.capacity
    assert int(state.rdl_batches) == len(calls)
    # Strictly fewer samples than evaluate-everything (untrained LDL won't
    # offload 100% at β=0.25 with ε=0.1).
    assert total_sent < horizon * n_streams


def test_hi_server_capacity_overflow_reverts_to_local():
    """With a tiny RDL capacity, overflowing offloads are dropped, pay no β,
    and keep their local prediction."""
    n_streams, horizon, seq = 8, 15, 12
    cap = 2
    server, calls = _tiny_server(n_streams, capacity=cap, eps=0.3)
    tokens = jax.random.randint(jax.random.PRNGKey(2),
                                (horizon, n_streams, seq), 0, 64, jnp.int32)
    betas = jnp.full((n_streams,), 0.1)    # cheap offloads → lots of them
    state = server.init_state()
    saw_drop = False
    for t in range(horizon):
        state, slot = server.serve_slot(
            state, tokens[t], betas, jax.random.fold_in(jax.random.PRNGKey(3), t))
        dropped = np.asarray(slot.offload & ~slot.sent)
        if dropped.any():
            saw_drop = True
            assert np.all(np.asarray(slot.loss)[dropped] == 0.0)
        assert int(jnp.sum(slot.sent)) <= cap
        assert all(c == cap for c in calls)
    assert saw_drop, "capacity=2 with ε=0.3 should overflow at least once"
    assert float(state.total_dropped) > 0


def test_hi_server_overflow_drops_rotate_across_streams():
    """Sustained overload must not starve a fixed set of streams: the drop
    priority rotates with the slot index, so service spreads over the fleet."""
    n_streams, horizon = 8, 16
    server, _ = _tiny_server(n_streams, capacity=1, eps=0.5)
    tokens = jax.random.randint(jax.random.PRNGKey(6),
                                (horizon, n_streams, 12), 0, 64, jnp.int32)
    betas = jnp.full((n_streams,), 0.05)   # cheap → near-constant offloading
    state = server.init_state()
    served = set()
    for t in range(horizon):
        state, slot = server.serve_slot(
            state, tokens[t], betas, jax.random.fold_in(jax.random.PRNGKey(8), t))
        served |= set(np.flatnonzero(np.asarray(slot.sent)).tolist())
    # With capacity 1 and a fixed prefix policy only ~1 stream would ever be
    # served; rotation must reach most of the fleet across 16 slots.
    assert len(served) >= n_streams // 2, served


def test_hi_server_config_rejects_nonpositive_capacity():
    with pytest.raises(ValueError, match="offload_capacity"):
        HIServerConfig(n_streams=4, offload_capacity=0)
    with pytest.raises(ValueError, match="offload_capacity"):
        HIServerConfig(n_streams=4, offload_capacity=-1)
    assert HIServerConfig(n_streams=4).capacity == 4
    assert HIServerConfig(n_streams=4, offload_capacity=2).capacity == 2


def test_hi_server_delayed_feedback_double_buffer():
    """Slot t's RDL labels update the policy at slot t+1: after slot 1 the
    weights reflect slot 0's feedback, and flush() applies the last slot."""
    n_streams = 4
    server, _ = _tiny_server(n_streams, eps=0.5)   # lots of offloads
    tokens = jax.random.randint(jax.random.PRNGKey(4), (3, n_streams, 12),
                                0, 64, jnp.int32)
    betas = jnp.full((n_streams,), 0.2)
    state = server.init_state()
    w0 = np.asarray(state.policy.log_w).copy()
    state, _ = server.serve_slot(state, tokens[0], betas, jax.random.PRNGKey(0))
    # Decide phase alone must not move the weights.
    assert np.array_equal(np.asarray(state.policy.log_w), w0)
    assert state.pending is not None
    state, _ = server.serve_slot(state, tokens[1], betas, jax.random.PRNGKey(1))
    w2 = np.asarray(state.policy.log_w)
    # Slot 0's feedback has now been applied (some stream offloaded at ε=0.5).
    assert not np.array_equal(w2, w0)
    flushed = server.flush(state)
    assert flushed.pending is None
    assert not np.array_equal(np.asarray(flushed.policy.log_w), w2)


def test_engine_generate(rng):
    from repro.serving import Engine, EngineConfig

    cfg = LDL_CONFIG.reduced(vocab=64)
    params = init_params(rng, cfg)
    eng = Engine(cfg, params, EngineConfig(max_prompt=16, max_new_tokens=4))
    toks = jax.random.randint(rng, (2, 12), 0, 64, jnp.int32)
    out = eng.generate({"tokens": toks}, n_tokens=4)
    assert out.shape == (2, 4)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_padded)))


def test_run_dispatch_source_forms():
    """`run(source, key)` works positionally and by keyword; anything that
    is not a PRNG key in the positional slot raises instead of being
    silently reinterpreted."""
    import pytest

    from repro.data import get_scenario

    n_streams = 2
    server, _ = _tiny_server(n_streams)
    src = get_scenario("stationary", n_streams=n_streams, horizon=8,
                       block=4, key=jax.random.PRNGKey(3))
    k = jax.random.PRNGKey(9)
    _, by_kw = server.run(src, key=k)
    _, by_pos = server.run(src, k)
    assert by_kw == by_pos
    with pytest.raises(TypeError, match="expected a PRNG key"):
        server.run(src, jnp.zeros((8, n_streams)))   # a beta matrix
    with pytest.raises(TypeError, match="takes no betas"):
        server.run(src, jnp.zeros((8, n_streams)), key=k)


def test_run_array_form_requires_betas_and_key():
    import pytest

    server, _ = _tiny_server(2)
    tokens = jnp.zeros((2, 2, 8), jnp.int32)
    with pytest.raises(TypeError, match="needs betas and key"):
        server.run(tokens, jnp.zeros((2, 2)))
