"""Fault tolerance of the offload path: fault injection, retry/timeout/
backoff, circuit breaking, lost-feedback recovery, and the degradation
ladder — all on the virtual clock, so chaos is exactly reproducible.

The conservation chaos test is hypothesis-driven where hypothesis is
installed (seeded fault schedules via `derandomize=True`) and falls back to
a fixed seed sweep otherwise — either way the invariants are asserted on
deterministic virtual-clock runs.
"""

import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.data.traffic import TrafficProcess
from repro.serving.request_plane import (
    BREAKER_CLOSED,
    BREAKER_HALF_OPEN,
    BREAKER_OPEN,
    AdmissionConfig,
    CircuitBreaker,
    EstimatorConfig,
    FaultConfig,
    FaultyLink,
    Link,
    LinkConfig,
    LinkOutage,
    Metrics,
    NetworkEstimator,
    RequestPlaneConfig,
    ResilienceConfig,
    ResilientSender,
    RetriesExhausted,
    SendCorrupted,
    SendDropped,
    SendTimeout,
    SimulatedLink,
    run_virtual,
    serve_traffic,
)

K = jax.random.PRNGKey


# ------------------------------ circuit breaker -------------------------------


def test_breaker_opens_on_consecutive_failures_then_probes_closed():
    cfg = ResilienceConfig(breaker_consecutive=3, breaker_cooldown=2.0)
    b = CircuitBreaker(cfg)
    assert b.state == BREAKER_CLOSED and not b.blocking(0.0)
    assert b.record_failure(0.0) is None
    assert b.record_failure(0.1) is None
    assert b.record_failure(0.2) == "opened"
    assert b.state == BREAKER_OPEN and b.blocking(0.3)
    assert not b.allow(1.0)                    # cooldown not elapsed
    assert b.allow(2.5)                        # OPEN → HALF_OPEN, probe claimed
    assert b.state == BREAKER_HALF_OPEN
    assert not b.allow(2.5)                    # only one probe at a time
    assert b.record_success() == "closed"
    assert b.state == BREAKER_CLOSED and b.rate == 0.0   # closes clean


def test_breaker_failed_probe_reopens_with_full_cooldown():
    cfg = ResilienceConfig(breaker_consecutive=2, breaker_cooldown=1.0)
    b = CircuitBreaker(cfg)
    b.record_failure(0.0)
    b.record_failure(0.0)
    assert b.state == BREAKER_OPEN
    assert b.allow(1.5)                        # half-open probe
    assert b.record_failure(1.5) == "opened"   # probe failed
    assert b.state == BREAKER_OPEN and b.opened_at == 1.5
    assert not b.allow(2.4) and b.allow(2.6)


def test_breaker_ewma_rate_trip_and_disabled_never_blocks():
    cfg = ResilienceConfig(breaker_consecutive=100, breaker_alpha=0.5,
                           breaker_threshold=0.6, breaker_min_samples=3)
    b = CircuitBreaker(cfg)
    # Consecutive stays far below 100; the EWMA failure rate trips instead,
    # but only once min_samples is reached.
    assert b.record_failure(0.0) is None       # rate 0.5, 1 sample
    assert b.record_failure(0.1) is None       # rate 0.75, 2 samples
    assert b.record_failure(0.2) == "opened"   # rate 0.875 ≥ 0.6, 3 samples
    off = CircuitBreaker(ResilienceConfig(breaker_enabled=False))
    for _ in range(20):
        off.record_failure(0.0)
    assert not off.blocking(0.0) and off.allow(0.0)


# ------------------------------ backoff ---------------------------------------


def _sender(res_cfg, link=None, n_streams=1, metrics=None):
    return ResilientSender(
        link if link is not None else SimulatedLink(LinkConfig()),
        NetworkEstimator(EstimatorConfig(), n_streams),
        metrics if metrics is not None else Metrics(), res_cfg, n_streams)


def test_backoff_is_seeded_capped_and_jitter_bounded():
    cfg = ResilienceConfig(seed=3, backoff_base=0.1, backoff_factor=2.0,
                           backoff_cap=0.3, backoff_jitter=0.5)
    seq = lambda c: [_sender(c)._backoff(k) for k in range(6)]
    a = seq(cfg)
    assert a == seq(cfg)                                   # same seed, same jitter
    assert seq(dataclasses.replace(cfg, seed=4)) != a
    for k, d in enumerate(a):
        raw = min(0.3, 0.1 * 2.0 ** k)                     # capped exponential
        assert raw <= d <= raw * 1.5                       # jitter stretch only
    plain = dataclasses.replace(cfg, backoff_jitter=0.0)
    assert seq(plain) == [0.1, 0.2, 0.3, 0.3, 0.3, 0.3]


def test_resilience_and_fault_config_validation():
    for bad in (dict(deadline=0.0), dict(max_retries=-1),
                dict(backoff_factor=0.5), dict(breaker_threshold=0.0),
                dict(breaker_consecutive=0), dict(breaker_cooldown=-1.0)):
        with pytest.raises(ValueError):
            ResilienceConfig(**bad)
    for bad in (dict(drop_prob=1.5), dict(outage_p_enter=-0.1),
                dict(straggler_shape=0.0),
                dict(outage_windows=((2.0, 1.0),))):
        with pytest.raises(ValueError):
            FaultConfig(**bad)


# ------------------------------ faulty link -----------------------------------


def _collect_sends(link, n, stream=0, payload=0.0):
    """Drive `n` sends under the virtual clock; tag each outcome."""

    async def main():
        out = []
        for _ in range(n):
            try:
                out.append(("ok", await link.send(stream, payload)))
            except LinkOutage:
                out.append(("outage", 0.0))
            except SendDropped as e:
                out.append(("drop", e.elapsed))
            except SendCorrupted as e:
                out.append(("corrupt", e.elapsed))
        return out

    return run_virtual(main())


def test_link_protocol_and_capability_flags():
    bare = SimulatedLink(LinkConfig())
    faulty = FaultyLink(bare, FaultConfig(drop_prob=0.1))
    assert isinstance(bare, Link) and isinstance(faulty, Link)
    assert bare.deterministic and not bare.lossy
    assert faulty.deterministic and faulty.lossy


def test_faulty_link_traces_are_seeded_and_counted():
    fc = FaultConfig(drop_prob=0.3, corrupt_prob=0.2, straggler_prob=0.2,
                     straggler_scale=0.05, outage_p_enter=0.1, seed=11)
    mk = lambda c=fc: FaultyLink(SimulatedLink(LinkConfig(seed=2)), c)
    a = _collect_sends(mk(), 60)
    assert a == _collect_sends(mk(), 60)                   # same seed, same trace
    assert {"ok", "drop", "corrupt", "outage"} <= {k for k, _ in a}
    assert _collect_sends(mk(dataclasses.replace(fc, seed=12)), 60) != a
    # `injected` is ground truth for what actually surfaced.
    link = mk()
    trace = _collect_sends(link, 60)
    for fam in ("drop", "corrupt", "outage"):
        assert link.injected[fam] == sum(1 for k, _ in trace if k == fam)
    assert link.injected["straggler"] > 0


def test_zero_fault_wrapper_is_pure_passthrough():
    assert FaultConfig().fault_free
    bare = _collect_sends(SimulatedLink(LinkConfig(seed=4)), 30)
    wrapped_link = FaultyLink(SimulatedLink(LinkConfig(seed=4)), FaultConfig())
    assert _collect_sends(wrapped_link, 30) == bare
    assert wrapped_link._rngs == {}            # no fault PRNG ever materialized


def test_scheduled_outage_windows_follow_the_loop_clock():
    link = FaultyLink(
        SimulatedLink(LinkConfig(base_rtt=0.01, jitter=0.0,
                                 congested_extra=0.0, p_up=0.0)),
        FaultConfig(outage_windows=((1.0, 2.0),)))
    assert link.in_scheduled_outage(1.5) and not link.in_scheduled_outage(2.0)

    async def main():
        loop = asyncio.get_running_loop()
        log = []
        while loop.time() < 3.0:
            t0 = loop.time()
            try:
                await link.send(0, 0.0)
                log.append((t0, "ok"))
            except LinkOutage:
                log.append((t0, "outage"))
                await asyncio.sleep(0.05)
        return log

    log = run_virtual(main())
    assert any(kind == "outage" for _, kind in log)
    for t0, kind in log:
        assert kind == ("outage" if 1.0 <= t0 < 2.0 else "ok")
    assert link.injected["outage"] == sum(1 for _, k in log if k == "outage")


# ------------------------------ estimator ok flag -----------------------------


def test_estimator_failures_feed_tail_window_not_ewma():
    est = NetworkEstimator(EstimatorConfig(alpha=0.5, window=8,
                                           prior_rtt=0.05), 2)
    est.observe(0, 0.02, 0.0)
    assert est.rtt_estimate(0) == pytest.approx(0.02)
    for _ in range(3):
        est.observe(0, 0.25, 0.0, ok=False)    # timeout caps, not RTTs
    assert est.rtt_estimate(0) == pytest.approx(0.02)      # EWMA untouched
    assert est.n_failures == 3 and est.n_samples == 4
    assert est.rtt_percentile(0.95, 0) > 0.2               # window inflated
    # The SLO rung's prediction: windowed percentile + payload term.
    assert est.predict_transfer(0, payload_bytes=1.0e4, q=0.95) == \
        pytest.approx(est.rtt_percentile(0.95, 0) + 0.01)
    # A cold stream predicts from its EWMA prior.
    assert est.predict_transfer(1) == pytest.approx(0.05)


# ------------------------------ resilient sender ------------------------------


class _ScriptLink:
    """Scripted transport for sender unit tests: each entry is ("ok", dt),
    ("drop", dt), ("outage",), or ("hang", dt)."""

    deterministic = True
    lossy = True

    def __init__(self, script):
        self.script = list(script)
        self.sent = 0

    async def send(self, stream, payload_bytes):
        step = self.script[self.sent] if self.sent < len(self.script) \
            else ("ok", 0.01)
        self.sent += 1
        kind = step[0]
        if kind == "outage":
            raise LinkOutage("scripted outage")
        await asyncio.sleep(step[1])
        if kind == "drop":
            raise SendDropped("scripted drop", elapsed=step[1])
        return step[1]


def test_sender_retries_through_drops_and_recovers():
    m = Metrics()
    sender = _sender(ResilienceConfig(max_retries=2, backoff_base=0.01,
                                      backoff_jitter=0.0),
                     link=_ScriptLink([("drop", 0.02), ("drop", 0.02),
                                       ("ok", 0.03)]), metrics=m)
    measured = run_virtual(sender.send(0, 0.0))
    assert measured == pytest.approx(0.03)
    snap = m.snapshot()
    assert snap["retries_total"] == 2.0 and snap["send_drops"] == 2.0
    assert snap["send_recovered"] == 1.0
    assert snap["retry_backoff_s"] == pytest.approx(0.01 + 0.02)
    assert sender.estimator.n_failures == 2 and sender.estimator.n_samples == 3


def test_sender_deadline_timeouts_exhaust_and_observe_caps():
    m = Metrics()
    sender = _sender(ResilienceConfig(deadline=0.05, max_retries=2,
                                      backoff_base=0.01, backoff_jitter=0.0),
                     link=_ScriptLink([("hang", 1.0)] * 3), metrics=m)
    with pytest.raises(RetriesExhausted) as exc:
        run_virtual(sender.send(0, 0.0))
    assert exc.value.attempts == 3
    assert isinstance(exc.value.last_error, SendTimeout)
    assert m.snapshot()["send_timeouts"] == 3.0
    # Each cap entered the percentile window as a failure observation.
    assert sender.estimator.n_failures == 3
    assert sender.estimator.rtt_percentile(0.95, 0) == pytest.approx(
        0.05, abs=1e-6)


def test_sender_breaker_fast_fails_then_probe_closes():
    m = Metrics()
    link = _ScriptLink([("outage",), ("outage",), ("ok", 0.02)])
    sender = _sender(ResilienceConfig(max_retries=0, breaker_consecutive=2,
                                      breaker_cooldown=0.5), link=link,
                     metrics=m)

    async def main():
        for _ in range(2):                     # two real failures → OPEN
            with pytest.raises(RetriesExhausted):
                await sender.send(0, 0.0)
        assert sender.breaker_state(0) == BREAKER_OPEN
        assert sender.breaker_blocking(0, asyncio.get_running_loop().time())
        # Open circuit: fail fast, nothing reaches the link.
        with pytest.raises(RetriesExhausted) as exc:
            await sender.send(0, 0.0)
        assert exc.value.attempts == 0 and exc.value.last_error is None
        assert link.sent == 2
        await asyncio.sleep(0.6)               # past the cooldown
        return await sender.send(0, 0.0)       # the half-open probe

    assert run_virtual(main()) == pytest.approx(0.02)
    assert sender.breaker_state(0) == BREAKER_CLOSED
    snap = m.snapshot()
    assert snap["send_outages"] == 2.0 and snap["breaker_opens"] == 1.0
    assert snap["breaker_probes"] == 1.0 and snap["breaker_closes"] == 1.0
    assert snap["breaker_closed_streams"] == 1.0
    assert snap["breaker_open_streams"] == 0.0


# ------------------------------ the plane under faults ------------------------


def _plane_cfg(s=8, mw=0.02, **kw):
    return RequestPlaneConfig(
        n_streams=s, max_wait=mw, offload_capacity=s // 2,
        admission=AdmissionConfig(max_queue=4 * s), **kw)


def _load(s, mw, n, key=3):
    """Offered load 1.0: arrival rate matched to one fleet round per
    `max_wait` deadline."""
    return TrafficProcess(process="poisson", rate=s / mw, n_arrivals=n,
                          n_sessions=s, key=K(key)).materialize()


def _assert_conservation(summary, n_requests):
    g = lambda k: summary.get(k, 0.0)
    assert g("requests_total") == float(n_requests)
    assert g("requests_total") == g("admitted_total") + g("denied_total")
    assert g("admitted_total") == (g("completed_local") + g("completed_remote")
                                   + g("capacity_dropped")
                                   + g("retry_exhausted"))
    assert g("fallback_total") == (g("denied_total") + g("capacity_dropped")
                                   + g("retry_exhausted"))
    assert g("admitted_total") == g("latency_ms_count")


def test_zero_fault_plane_summary_is_bit_identical():
    """The parity guarantee end to end: a `FaultyLink` with every knob at
    zero yields the exact summary of the bare `SimulatedLink` run."""
    arr = _load(8, 0.02, 200)
    cfg = _plane_cfg(resilience=ResilienceConfig(deadline=0.25))
    clean = serve_traffic(cfg, arr, K(5))[2]
    wrapped = serve_traffic(
        dataclasses.replace(cfg, fault=FaultConfig()), arr, K(5))[2]
    assert wrapped == clean


def _chaos_invariants(seed):
    """One seeded chaos run: randomized drop/corrupt/straggler/outage
    schedule + randomized resilience knobs; every future must resolve and
    the accounting must balance exactly."""
    rng = np.random.default_rng(seed)
    fault = FaultConfig(
        drop_prob=float(rng.uniform(0.0, 0.4)),
        corrupt_prob=float(rng.uniform(0.0, 0.2)),
        straggler_prob=float(rng.uniform(0.0, 0.3)),
        straggler_scale=0.1,
        outage_p_enter=float(rng.uniform(0.0, 0.08)),
        outage_p_exit=float(rng.uniform(0.2, 0.6)),
        outage_windows=((0.4, 0.6),) if rng.random() < 0.5 else (),
        seed=int(rng.integers(0, 2 ** 31)))
    res = ResilienceConfig(
        deadline=float(rng.uniform(0.08, 0.3)),
        max_retries=int(rng.integers(0, 4)),
        breaker_consecutive=int(rng.integers(2, 6)),
        breaker_cooldown=float(rng.uniform(0.1, 1.0)),
        seed=seed)
    s, mw, n = 6, 0.02, 220
    cfg = _plane_cfg(s=s, mw=mw, fault=fault, resilience=res)
    plane, results, summary = serve_traffic(cfg, _load(s, mw, n, key=seed + 1),
                                            K(seed))
    # No hung futures, no leaked in-flight work, feedback fully drained.
    assert len(results) == n and all(r.pred in (0, 1) for r in results)
    assert plane.batcher.idle and plane.batcher._inflight == 0
    assert not plane.batcher._pending
    _assert_conservation(summary, n)
    # The sender's failure counters reconcile with the injector's ground
    # truth (stragglers excluded: they are delays, not failures — and a
    # straggler cancelled by the deadline surfaces as a timeout instead).
    g = lambda k: summary.get(k, 0.0)
    inj = plane.link.injected
    assert g("send_outages") == float(inj["outage"])
    assert g("send_drops") == float(inj["drop"])
    assert g("send_corrupted") == float(inj["corrupt"])


try:
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(0, 2 ** 16 - 1))
    @settings(max_examples=6, deadline=None, derandomize=True)
    def test_chaos_conservation_under_random_faults(seed):
        _chaos_invariants(seed)
except ImportError:                            # fixed sweep, same invariants

    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
    def test_chaos_conservation_under_random_faults(seed):
        _chaos_invariants(seed)


def test_retry_exhaustion_degrades_to_fallback_and_drains_feedback():
    """Every send drops: all offloads exhaust their retries, yet every
    future resolves (failed, local fallback), feedback drains with the dead
    slots masked out, and β is still charged for the budget actually
    spent."""
    s, mw, n = 4, 0.02, 80
    cfg = _plane_cfg(s=s, mw=mw, fault=FaultConfig(drop_prob=1.0, seed=3),
                     resilience=ResilienceConfig(max_retries=1,
                                                 breaker_enabled=False))
    plane, results, summary = serve_traffic(cfg, _load(s, mw, n, key=2), K(2))
    g = lambda k: summary.get(k, 0.0)
    assert g("completed_remote") == 0.0 and g("retry_exhausted") > 0
    assert g("retry_exhausted") == float(sum(r.failed for r in results))
    for r in results:
        assert r.pred in (0, 1)
        if r.failed:
            assert not r.offloaded and not r.dropped
    # max_retries=1 → exactly two dropped attempts per exhausted send.
    assert g("send_drops") == 2.0 * g("retry_exhausted")
    assert g("observed_cost") > 0.0            # β charged: attempts > 0
    _assert_conservation(summary, n)
    # Feedback never wedged on the lost labels.
    assert plane.batcher.idle and not plane.batcher._pending
    assert g("feedback_rounds") == g("rounds_total")


def test_slo_rung_denies_before_spending_network_budget():
    """With the estimator still at its cold-start prior (0.05 s) and an SLO
    of 0.01 s, every request is denied at the ladder before any send."""
    s = 4
    cfg = RequestPlaneConfig(
        n_streams=s, max_wait=0.02,
        admission=AdmissionConfig(slo_deadline=0.01, slo_quantile=0.9))
    plane, results, summary = serve_traffic(cfg, _load(s, 0.02, 40, key=6),
                                            K(1))
    assert summary.get("denied_slo_miss", 0.0) == 40.0
    assert all(r.denied and r.reason == "slo_miss" and r.pred in (0, 1)
               for r in results)
    assert plane.estimator.n_samples == 0      # the link was never touched
    _assert_conservation(summary, 40)


def test_breaker_rung_denies_and_gauges_track_states():
    """Sustained harsh faults open per-stream breakers; once open, the
    ladder denies at ingress (`breaker_open`) instead of burning retries."""
    s, mw, n = 8, 0.02, 300
    cfg = _plane_cfg(
        s=s, mw=mw,
        fault=FaultConfig(drop_prob=0.6, outage_p_enter=0.10,
                          outage_p_exit=0.15, seed=2),
        resilience=ResilienceConfig(deadline=0.25, max_retries=1,
                                    breaker_consecutive=3,
                                    breaker_cooldown=0.5))
    plane, results, summary = serve_traffic(cfg, _load(s, mw, n), K(5))
    g = lambda k: summary.get(k, 0.0)
    assert len(results) == n and all(r.pred in (0, 1) for r in results)
    assert g("denied_breaker_open") > 0 and g("breaker_opens") > 0
    assert g("breaker_probes") > 0             # half-open probes happened
    _assert_conservation(summary, n)
    # The state gauges partition the fleet.
    assert (g("breaker_closed_streams") + g("breaker_open_streams")
            + g("breaker_half_open_streams")) == float(s)
    assert summary["exhausted_rate"] >= 0.0 and summary["fallback_rate"] > 0.0


def test_acceptance_faulty_run_stays_within_25pct_of_clean_cost():
    """The PR's acceptance bar: 10% drops plus a bursty outage (scheduled
    burst + Markov episodes) at offered load 1.0 — the plane completes with
    zero hung futures, exact conservation, and cumulative true cost within
    25% of the fault-free run."""
    s, mw, n = 8, 0.02, 400
    arr = _load(s, mw, n)
    base = _plane_cfg(s=s, mw=mw,
                      resilience=ResilienceConfig(deadline=0.25,
                                                  max_retries=2,
                                                  breaker_consecutive=3,
                                                  breaker_cooldown=0.1))
    _, clean_results, clean = serve_traffic(base, arr, K(5))
    faulty_cfg = dataclasses.replace(
        base, fault=FaultConfig(drop_prob=0.10,
                                outage_windows=((0.1, 0.2),),
                                outage_p_enter=0.02, outage_p_exit=0.25,
                                seed=7))
    plane, results, faulty = serve_traffic(faulty_cfg, arr, K(5))
    g = lambda k: faulty.get(k, 0.0)
    assert len(results) == n and all(r.pred in (0, 1) for r in results)
    assert plane.batcher.idle and not plane.batcher._pending
    # Faults really fired and the recovery path really ran.
    assert g("send_drops") > 0 and g("send_outages") > 0
    assert g("retries_total") > 0 and g("send_recovered") > 0
    _assert_conservation(faulty, n)
    # Degradation, not collapse: cumulative ground-truth cost within 25%.
    assert faulty["true_cost"] == pytest.approx(clean["true_cost"], rel=0.25)
    assert faulty["labeled_total"] == clean["labeled_total"]
