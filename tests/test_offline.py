"""Offline optima: vectorized grid losses match brute-force simulation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HIConfig, offline


CFG = HIConfig(bits=3, delta_fp=0.7, delta_fn=1.0)


def _brute_force_pair_loss(cfg, l_idx, u_idx, fs, hrs, betas):
    total = 0.0
    g = cfg.grid
    for f, hr, b in zip(np.asarray(fs), np.asarray(hrs), np.asarray(betas)):
        i = min(int(f * g), g - 1)
        if l_idx <= i < u_idx:
            total += float(b)
        elif i >= u_idx:
            total += cfg.delta_fp if hr == 0 else 0.0
        else:
            total += cfg.delta_fn if hr == 1 else 0.0
    return total


def test_two_threshold_losses_match_brute_force():
    key = jax.random.PRNGKey(0)
    fs = jax.random.uniform(key, (200,))
    hrs = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.6, (200,)).astype(jnp.int32)
    betas = jax.random.uniform(jax.random.fold_in(key, 2), (200,), maxval=0.5)
    table = np.asarray(offline.two_threshold_losses(CFG, fs, hrs, betas))
    g = CFG.grid
    for l in range(0, g, 3):
        for u in range(l, g, 3):
            expect = _brute_force_pair_loss(CFG, l, u, fs, hrs, betas)
            assert abs(table[l, u] - expect) < 1e-3, (l, u)


def test_invalid_cells_are_inf():
    fs = jnp.asarray([0.5]); hrs = jnp.asarray([1]); betas = jnp.asarray([0.3])
    table = np.asarray(offline.two_threshold_losses(CFG, fs, hrs, betas))
    g = CFG.grid
    l = np.arange(g)[:, None]
    u = np.arange(g)[None, :]
    assert np.all(np.isinf(table[l > u]))
    assert np.all(np.isfinite(table[l <= u]))


def test_single_threshold_extremes_are_naive_policies():
    from repro.core import baselines

    key = jax.random.PRNGKey(1)
    fs = jax.random.uniform(key, (300,), minval=0.01, maxval=0.99)
    hrs = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (300,)).astype(jnp.int32)
    betas = jnp.full((300,), 0.35)
    losses = np.asarray(offline.single_threshold_losses(CFG, fs, hrs, betas))
    no = float(jnp.sum(baselines.no_offload_losses(CFG, fs, hrs, betas)))
    full = float(jnp.sum(baselines.full_offload_losses(CFG, fs, hrs, betas)))
    assert abs(losses[0] - no) < 1e-3          # θ=0 never offloads
    assert abs(losses[-1] - full) < 1e-3       # θ=1 always offloads (conf < 1)


def test_fpr_fnr_surface_consistency():
    key = jax.random.PRNGKey(2)
    fs = jax.random.uniform(key, (500,))
    hrs = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.5, (500,)).astype(jnp.int32)
    fp, fn, cost = offline.fpr_fnr_cost_surface(CFG, fs, hrs, beta=0.3)
    fp, fn, cost = map(np.asarray, (fp, fn, cost))
    g = CFG.grid
    valid = np.arange(g)[:, None] <= np.arange(g)[None, :]
    # cost = δ₁·FPR + δ₋₁·FNR + β·offload_rate ≥ δ-weighted errors alone.
    assert np.all(cost[valid] >= 0.7 * fp[valid] + 1.0 * fn[valid] - 1e-6)
    # Widest band (0, G−1): predict-0 impossible (i_f < 0 never), predict-1
    # only in the top quantization bin (θ_u = 1 is outside the grid).
    assert fn[0, g - 1] == 0
    assert fp[0, g - 1] < 0.15


def test_fixed_pair_loss_matches_table():
    key = jax.random.PRNGKey(4)
    fs = jax.random.uniform(key, (100,))
    hrs = jax.random.bernoulli(jax.random.fold_in(key, 5), 0.5, (100,)).astype(jnp.int32)
    betas = jnp.full((100,), 0.2)
    table = np.asarray(offline.two_threshold_losses(CFG, fs, hrs, betas))
    for l, u in [(0, 0), (2, 5), (3, 3), (0, CFG.grid - 1)]:
        v = float(offline.fixed_pair_loss(CFG, l, u, fs, hrs, betas))
        assert abs(v - table[l, u]) < 1e-4
