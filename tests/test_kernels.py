"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import HIConfig
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hedge.ops import fleet_hedge_step
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref, ssd_sequential


# ------------------------------- hedge ---------------------------------------


@pytest.mark.parametrize("bits", [2, 3, 4, 6])
@pytest.mark.parametrize("n_streams", [1, 5, 16])
def test_hedge_kernel_matches_ref(bits, n_streams):
    cfg = HIConfig(bits=bits, eps=0.07, eta=0.9)
    g = cfg.grid
    key = jax.random.PRNGKey(bits * 100 + n_streams)
    ks = jax.random.split(key, 6)
    l = jnp.arange(g)[:, None]
    u = jnp.arange(g)[None, :]
    logw = jnp.where(l <= u, jax.random.normal(ks[0], (n_streams, g, g)),
                     -1e30).astype(jnp.float32)
    f = jax.random.uniform(ks[1], (n_streams,))
    psi = jax.random.uniform(ks[2], (n_streams,))
    zeta = jax.random.bernoulli(ks[3], 0.2, (n_streams,)).astype(jnp.int32)
    hr = jax.random.bernoulli(ks[4], 0.5, (n_streams,)).astype(jnp.int32)
    beta = jax.random.uniform(ks[5], (n_streams,), maxval=0.6)
    outk = fleet_hedge_step(cfg, logw, f, psi, zeta, hr, beta, use_kernel=True)
    outr = fleet_hedge_step(cfg, logw, f, psi, zeta, hr, beta, use_kernel=False)
    for a, b in zip(outk, outr):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), atol=1e-5)


def test_hedge_kernel_matches_policy_module():
    """The fused kernel agrees with repro.core.policy.h2t2_step decisions when
    fed the same uniform/bernoulli draws."""
    from repro.core.policy import h2t2_init, region_masks, quantize, pseudo_loss

    cfg = HIConfig(bits=4, eps=0.1, eta=1.0)
    st = h2t2_init(cfg)
    f = jnp.asarray([0.55])
    psi, zeta = jnp.asarray([0.2]), jnp.asarray([0], jnp.int32)
    hr, beta = jnp.asarray([1], jnp.int32), jnp.asarray([0.3])
    new_lw, off, exp, pred, q, p = fleet_hedge_step(
        cfg, st.log_w[None], f, psi, zeta, hr, beta, use_kernel=True)
    i_f = quantize(f[0], cfg.bits)
    _, r2, r3 = region_masks(i_f, cfg.grid)
    q_expect = float(jnp.sum(r2)) / cfg.n_experts
    assert abs(float(q[0]) - q_expect) < 1e-5
    assert bool(off[0]) == (0.2 <= q_expect)
    lt = pseudo_loss(cfg, i_f, off[0] == 1, exp[0] == 1, hr[0], beta[0])
    manual = st.log_w - cfg.eta * lt
    manual = jnp.where(jnp.isfinite(st.log_w),
                       manual - jnp.max(jnp.where(jnp.isfinite(manual), manual,
                                                  -jnp.inf)), -1e30)
    valid = jnp.isfinite(st.log_w)
    np.testing.assert_allclose(np.asarray(new_lw[0])[np.asarray(valid)],
                               np.asarray(manual)[np.asarray(valid)], atol=1e-5)


# ------------------------------- flash ---------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,hkv,d,causal,window,bq,bk",
    [
        (2, 128, 4, 2, 64, True, None, 64, 64),
        (1, 100, 4, 4, 32, True, None, 32, 32),     # pad path
        (2, 256, 8, 2, 64, True, 64, 64, 64),       # sliding window
        (1, 64, 2, 1, 128, False, None, 32, 32),    # bidirectional MQA
        (1, 64, 6, 3, 16, True, 24, 16, 16),        # narrow head_dim
    ],
)
def test_flash_matches_ref(dtype, b, s, h, hkv, d, causal, window, bq, bk):
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    atol = 2e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_flash_block_shape_invariance():
    """Output independent of BlockSpec tiling choices."""
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    outs = [
        np.asarray(flash_attention(q, k, v, block_q=bq, block_k=bk))
        for bq, bk in [(32, 32), (64, 32), (32, 64), (128, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5)


# ------------------------------- ssd ------------------------------------------


@pytest.mark.parametrize(
    "b,s,h,p,g,n,chunk",
    [
        (2, 64, 4, 16, 1, 8, 16),
        (1, 128, 8, 32, 2, 16, 32),
        (2, 96, 4, 16, 1, 8, 32),      # chunk auto-halves to divide 96
        (1, 32, 2, 8, 1, 4, 32),
    ],
)
def test_ssd_kernel_matches_sequential(b, s, h, p, g, n, chunk):
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    x = 0.5 * jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = jnp.exp(0.3 * jax.random.normal(ks[2], (h,)))
    bb = 0.5 * jax.random.normal(ks[3], (b, s, g, n))
    cc = 0.5 * jax.random.normal(ks[4], (b, s, g, n))
    yk, stk = ssd(x, dt, a, bb, cc, chunk=chunk)
    yr, str_ = ssd_ref(x, dt, a, bb, cc, chunk=chunk)
    ys, sts = ssd_sequential(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(ys), atol=1e-4)
    np.testing.assert_allclose(np.asarray(stk), np.asarray(sts), atol=1e-4)


def test_ssd_chunk_invariance():
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 5)
    b, s, h, p, g, n = 1, 64, 2, 8, 1, 4
    x = 0.5 * jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = jnp.exp(0.3 * jax.random.normal(ks[2], (h,)))
    bb = 0.5 * jax.random.normal(ks[3], (b, s, g, n))
    cc = 0.5 * jax.random.normal(ks[4], (b, s, g, n))
    y8, _ = ssd(x, dt, a, bb, cc, chunk=8)
    y16, _ = ssd(x, dt, a, bb, cc, chunk=16)
    y64, _ = ssd(x, dt, a, bb, cc, chunk=64)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), atol=1e-5)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y64), atol=1e-5)


def test_model_ssd_kernel_flag_equivalence():
    """mamba2 block with use_ssd_kernel=True ≡ pure-jnp path."""
    from repro.configs import ARCHS
    from repro.models import forward, init_params
    from repro.models.transformer import RunFlags

    cfg = ARCHS["mamba2-780m"].reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab,
                              jnp.int32)
    l1, _ = forward(params, cfg, {"tokens": toks},
                    flags=RunFlags(use_ssd_kernel=False))
    l2, _ = forward(params, cfg, {"tokens": toks},
                    flags=RunFlags(use_ssd_kernel=True))
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=0.06)
