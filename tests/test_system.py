"""End-to-end behaviour tests for the paper's system: calibrated rules,
H2T2 vs baselines on every dataset, and launch-layer plumbing."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HIConfig,
    baselines,
    calibrated_rule,
    multiclass_regions,
    multiclass_rule,
    offline,
    run_stream,
)
from repro.data import dataset_trace


def test_calibrated_rule_optimal_among_threshold_policies():
    """On a calibrated synthetic stream, Theorem 1's rule achieves (near) the
    best expected cost among ALL two-threshold policies."""
    cfg = HIConfig(bits=6, delta_fp=0.7, delta_fn=1.0)
    key = jax.random.PRNGKey(0)
    # Calibrated stream: f ~ U(0,1), h_r | f ~ Bernoulli(f).
    fs = jax.random.uniform(key, (30_000,))
    hrs = jax.random.bernoulli(jax.random.fold_in(key, 1), fs).astype(jnp.int32)
    beta = 0.25
    betas = jnp.full_like(fs, beta)
    d = calibrated_rule(cfg, fs, jnp.asarray(beta))
    incurred = jnp.where(
        d.offload, beta,
        jnp.where(d.pred == 1,
                  jnp.where(hrs == 0, cfg.delta_fp, 0.0),
                  jnp.where(hrs == 1, cfg.delta_fn, 0.0)))
    thm1 = float(jnp.mean(incurred))
    best = offline.best_two_threshold(cfg, fs, hrs, betas)
    grid_best = float(best.best_loss) / fs.shape[0]
    assert thm1 <= grid_best * 1.03 + 1e-3, (thm1, grid_best)


@pytest.mark.parametrize("name", ["breakhis", "chest", "synthetic", "breach"])
def test_h2t2_competitive_on_dataset(name):
    """H2T2 ends within 30% of the offline two-threshold optimum and below
    the worst naive policy on each dataset (β = 0.3, T = 6000)."""
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    tr = dataset_trace(name, 6000, jax.random.PRNGKey(0), beta=0.3)
    _, out = run_stream(cfg, tr.fs, tr.hrs, tr.betas, jax.random.PRNGKey(1))
    h2t2 = float(jnp.sum(out.loss))
    two = float(offline.best_two_threshold(cfg, tr.fs, tr.hrs, tr.betas).best_loss)
    no = float(jnp.sum(baselines.no_offload_losses(cfg, tr.fs, tr.hrs, tr.betas)))
    full = float(jnp.sum(baselines.full_offload_losses(cfg, tr.fs, tr.hrs, tr.betas)))
    assert h2t2 <= max(no, full)
    # 45% envelope: single-seed online run incl. exploration cost εβT; the
    # imbalanced chest stream (p1 = 0.8) sits highest of the four.
    assert h2t2 <= 1.45 * two, (name, h2t2, two)


def test_h2t2_beats_single_threshold_hedge_under_asymmetry():
    """The paper's core claim, averaged over seeds on BreakHis at β=0.3."""
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    tr = dataset_trace("breakhis", 8000, jax.random.PRNGKey(10), beta=0.3)
    h_losses, s_losses = [], []
    for seed in range(4):
        _, o = run_stream(cfg, tr.fs, tr.hrs, tr.betas, jax.random.PRNGKey(seed))
        h_losses.append(float(jnp.sum(o.loss)))
        _, so = baselines.run_single_threshold(
            cfg, tr.fs, tr.hrs, tr.betas, jax.random.PRNGKey(100 + seed))
        s_losses.append(float(jnp.sum(so.loss)))
    assert np.mean(h_losses) < np.mean(s_losses), (h_losses, s_losses)


def test_multiclass_regions_structure():
    """K=3 calibrated rule yields K+1 = 4 regions on the simplex (Fig. 5)."""
    k = 3
    key = jax.random.PRNGKey(0)
    c = jax.random.uniform(key, (k, k), minval=0.3, maxval=1.0)
    c = c * (1 - jnp.eye(k))
    pts = []
    for i in range(0, 21):
        for j in range(0, 21 - i):
            pts.append((i / 20, j / 20, (20 - i - j) / 20))
    grid = jnp.asarray(pts)
    labels = np.asarray(multiclass_regions(grid, c, beta=0.2))
    present = set(labels.tolist())
    assert present == {0, 1, 2, 3}, present   # 3 classes + offload region
    # Vertices are confidently classified, never offloaded.
    for v in range(k):
        vertex = jnp.zeros((k,)).at[v].set(1.0)
        d = multiclass_rule(vertex, c, jnp.asarray(0.2))
        assert not bool(d.offload) and int(d.pred) == v


@pytest.mark.slow
def test_dryrun_entry_point_smoke():
    """The dry-run CLI itself (512 host devices) on the smallest arch/shape."""
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-1.5b",
         "--shape", "decode_32k", "--mesh", "single"],
        capture_output=True, text=True, timeout=900, env=env, cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    import json

    rec = json.loads(proc.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["n_devices"] == 256
