"""Attention implementation equivalences: naive vs chunked vs window-blocked
vs Pallas flash, plus decode ring-buffer positions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ref import attention_ref
from repro.models.attention import (
    _sdpa,
    _sdpa_chunked,
    _sdpa_window_blocked,
    ring_positions,
)


def _qkv(key, b, s, h, hkv, d):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (b, s, h, d)),
            jax.random.normal(ks[1], (b, s, hkv, d)),
            jax.random.normal(ks[2], (b, s, hkv, d)))


@pytest.mark.parametrize("chunk", [16, 64, 256])
def test_chunked_equals_naive_causal(chunk, rng):
    q, k, v = _qkv(rng, 2, 256, 4, 2, 32)
    ref = attention_ref(q, k, v, causal=True)
    out = _sdpa_chunked(q, k, v, causal=True, window=None, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("window,chunk", [(32, 16), (64, 64), (100, 32)])
def test_window_blocked_equals_oracle(window, chunk, rng):
    q, k, v = _qkv(rng, 1, 256, 4, 4, 16)
    ref = attention_ref(q, k, v, causal=True, window=window)
    out = _sdpa_window_blocked(q, k, v, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@given(
    s=st.integers(8, 128),
    window=st.integers(4, 64),
    chunk=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_window_blocked_property(s, window, chunk, seed):
    """Property: q-blocked sliding-window attention ≡ masked dense attention
    for arbitrary (seq, window, block) combinations."""
    s = (s // 8) * 8
    if s < 16 or window + chunk >= s:
        return
    key = jax.random.PRNGKey(seed)
    q, k, v = _qkv(key, 1, s, 2, 1, 8)
    ref = attention_ref(q, k, v, causal=True, window=window)
    out = _sdpa_window_blocked(q, k, v, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)


def test_flash_kernel_vs_chunked_vs_naive(rng):
    from repro.kernels.flash_attention.ops import flash_attention

    q, k, v = _qkv(rng, 1, 128, 4, 2, 64)
    a = attention_ref(q, k, v, causal=True)
    b = _sdpa_chunked(q, k, v, causal=True, window=None, chunk=32)
    c = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=2e-5)
    np.testing.assert_allclose(np.asarray(c), np.asarray(a), atol=2e-5)


def test_ring_positions_math():
    # Before wrap: slot j holds position j.
    p = np.asarray(ring_positions(jnp.asarray(3), 8))
    assert p.tolist() == [0, 1, 2, -1, -1, -1, -1, -1]
    # After wrap at capacity 4, index 6: slots hold [4, 5, 2, 3].
    p = np.asarray(ring_positions(jnp.asarray(6), 4))
    assert p.tolist() == [4, 5, 2, 3]
    # Exactly at capacity.
    p = np.asarray(ring_positions(jnp.asarray(4), 4))
    assert p.tolist() == [0, 1, 2, 3]
    # Empty cache.
    p = np.asarray(ring_positions(jnp.asarray(0), 4))
    assert p.tolist() == [-1, -1, -1, -1]


@given(index=st.integers(0, 300), capacity=st.sampled_from([4, 16, 64]))
@settings(max_examples=30, deadline=None)
def test_ring_positions_property(index, capacity):
    """Each slot holds the largest p < index with p ≡ slot (mod capacity);
    all valid positions are within the last `capacity` writes."""
    p = np.asarray(ring_positions(jnp.asarray(index), capacity))
    for j, pj in enumerate(p):
        if pj < 0:
            assert index <= j
        else:
            assert pj % capacity == j
            assert index - capacity <= pj < index
