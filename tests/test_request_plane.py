"""Async request plane: virtual clock, micro-batching, admission, live β,
and parity with the offline serving path.

No pytest-asyncio: every coroutine runs synchronously through
`run_virtual`, on simulated time — the suite performs no wall-clock sleeps.
"""

import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HIConfig
from repro.data import ReplaySource
from repro.data.traffic import ArrivalBatch, TrafficProcess
from repro.serving import HIServer, HIServerConfig
from repro.serving.request_plane import (
    AdmissionConfig,
    AdmissionController,
    EstimatorConfig,
    LinkConfig,
    Metrics,
    NetworkEstimator,
    P2Quantile,
    RequestPlane,
    RequestPlaneConfig,
    SessionTable,
    SimulatedLink,
    run_virtual,
    serve_traffic,
)

K = jax.random.PRNGKey


# ------------------------------ virtual clock --------------------------------


def test_virtual_clock_advances_without_wall_time():
    async def main():
        loop = asyncio.get_running_loop()
        t0 = loop.time()
        await asyncio.sleep(1800.0)            # half an hour of virtual time
        await asyncio.gather(asyncio.sleep(5.0), asyncio.sleep(9.0))
        return loop.time() - t0

    wall0 = time.monotonic()
    elapsed = run_virtual(main())
    assert time.monotonic() - wall0 < 5.0      # no real sleeping happened
    assert elapsed == pytest.approx(1809.0)


def test_virtual_clock_interleaving_is_deterministic():
    async def main():
        log = []

        async def worker(name, delay, repeats):
            for i in range(repeats):
                await asyncio.sleep(delay)
                log.append((name, i, asyncio.get_running_loop().time()))

        await asyncio.gather(worker("a", 0.3, 7), worker("b", 0.7, 3),
                             worker("c", 0.21, 10))
        return log

    assert run_virtual(main()) == run_virtual(main())


def test_virtual_clock_deadlock_raises_instead_of_hanging():
    async def main():
        await asyncio.get_running_loop().create_future()   # never resolves

    with pytest.raises(RuntimeError, match="nothing ready"):
        run_virtual(main())


# ------------------------------ metrics --------------------------------------


def test_p2_quantile_exact_for_small_samples():
    est = P2Quantile(0.5)
    for x in (5.0, 1.0, 9.0):
        est.observe(x)
    assert est.value() == 5.0                   # exact median of {1, 5, 9}


def test_p2_quantile_tracks_numpy_percentiles():
    rng = np.random.default_rng(7)
    xs = rng.normal(10.0, 2.0, 5000)
    for q in (0.5, 0.95, 0.99):
        est = P2Quantile(q)
        for x in xs:
            est.observe(float(x))
        assert est.value() == pytest.approx(
            np.percentile(xs, q * 100.0), abs=0.25)


def test_metrics_snapshot_shape():
    m = Metrics()
    m.counter("served").inc(3)
    m.gauge("depth").set(7)
    for x in (1.0, 2.0, 3.0):
        m.quantiles("latency_ms").observe(x)
    snap = m.snapshot()
    assert snap["served"] == 3.0 and snap["depth"] == 7.0
    assert {"p50_latency_ms", "p95_latency_ms", "p99_latency_ms",
            "latency_ms_mean", "latency_ms_count"} <= set(snap)
    assert snap["p50_latency_ms"] == 2.0 and snap["latency_ms_count"] == 3.0


# ------------------------------ admission ------------------------------------


def test_token_bucket_denies_then_refills():
    m = Metrics()
    ctl = AdmissionController(AdmissionConfig(rate=1.0, burst=2.0), m)
    assert ctl.admit(0.0, 0) is None
    assert ctl.admit(0.0, 0) is None
    assert ctl.admit(0.0, 0) == "rate_limited"          # bucket empty
    assert ctl.admit(1.5, 0) is None                    # 1.5 tokens refilled
    assert ctl.admit(100.0, 0) is None                  # refill caps at burst
    assert ctl.admit(100.0, 0) is None
    assert ctl.admit(100.0, 0) == "rate_limited"
    snap = m.snapshot()
    assert snap["denied_rate_limited"] == 2.0 == snap["denied_total"]


def test_queue_depth_cap_and_disabled_mode():
    m = Metrics()
    ctl = AdmissionController(AdmissionConfig(max_queue=4), m)
    assert ctl.admit(0.0, 3) is None
    assert ctl.admit(0.0, 4) == "queue_full"
    off = AdmissionController(AdmissionConfig(enabled=False, max_queue=1), m)
    assert off.admit(0.0, 10 ** 6) is None


def test_admission_config_validation():
    with pytest.raises(ValueError):
        AdmissionConfig(rate=-1.0)
    with pytest.raises(ValueError):
        AdmissionConfig(burst=0.0)
    with pytest.raises(ValueError):
        AdmissionConfig(max_queue=0)


# ------------------------------ session table --------------------------------


def test_session_table_lease_lru_and_pins():
    tab = SessionTable(2)
    s0, ev0 = tab.lease(100)
    s1, ev1 = tab.lease(200)
    assert {s0, s1} == {0, 1} and not ev0 and not ev1
    # Both pinned: a third session cannot lease.
    assert tab.lease(300) is None
    tab.release(s0)
    tab.release(s1)
    # Same session re-leases its own slot (no eviction).
    again, ev = tab.lease(100)
    assert again == s0 and not ev
    tab.release(again)
    # 100 was just used, so 200 is now the LRU victim.
    s3, ev = tab.lease(300)
    assert ev and s3 == s1 and tab.slot_of(200) is None
    assert tab.slot_of(100) == s0 and tab.evictions == 1


# ------------------------------ netem ----------------------------------------


def test_simulated_link_is_seeded_and_nonnegative():
    cfg = LinkConfig(base_rtt=0.02, jitter=0.01, seed=5)
    a_link, b_link = SimulatedLink(cfg), SimulatedLink(cfg)
    a = [a_link.transfer_time(0, 1000.0) for _ in range(20)]
    b = [b_link.transfer_time(0, 1000.0) for _ in range(20)]
    assert a == b and all(dt >= 1000.0 / cfg.bandwidth for dt in a)
    # Distinct streams draw from disjoint PRNGs.
    assert b_link.transfer_time(1, 1000.0) != a[0]


def test_estimator_converges_and_prices_beta():
    cfg = EstimatorConfig(alpha=0.5, window=8, bw_hint=1.0e6,
                          latency_ref=0.1, prior_rtt=0.05)
    est = NetworkEstimator(cfg, 2)
    # Cold start: β from the prior RTT, not zero.
    assert est.beta_vector()[0] == pytest.approx(0.5)
    for _ in range(12):
        est.observe(0, 0.02 + 0.001, 1000.0)   # payload term stripped
    assert est.rtt_estimate(0) == pytest.approx(0.02, abs=1e-6)
    beta = est.beta_vector()
    assert beta[0] == pytest.approx(0.2, abs=1e-4)
    assert beta[1] == pytest.approx(0.5)       # untouched stream keeps prior
    assert beta.dtype == np.float32
    # Payload adds the serialization term: 0.02 + 0.01 s → β 0.3.
    assert est.beta_vector(10_000.0)[0] == pytest.approx(0.3, abs=1e-4)


def test_estimator_percentile_source_prices_tail():
    cfg = EstimatorConfig(alpha=0.2, window=16, latency_ref=0.1,
                          beta_source="p95")
    est = NetworkEstimator(cfg, 1)
    for _ in range(15):
        est.observe(0, 0.01, 0.0)
    est.observe(0, 0.09, 0.0)                  # one congestion spike
    # p95 of [0.01×15, 0.09] interpolates to 0.03 — far above the 0.01 mode.
    assert est.rtt_percentile(0.95, 0) == pytest.approx(0.03)
    assert est.beta_vector()[0] > NetworkEstimator(
        EstimatorConfig(latency_ref=0.1), 1).cfg.beta_floor
    with pytest.raises(ValueError):
        EstimatorConfig(beta_source="median")
    with pytest.raises(ValueError):
        EstimatorConfig(beta_floor=0.5, beta_cap=0.1)


# ------------------------------ the plane ------------------------------------


def _traffic(rate, n, key=3, process="poisson", **kw):
    return TrafficProcess(process=process, rate=rate, n_arrivals=n,
                          n_sessions=8, key=K(key), **kw).materialize()


def test_same_seed_identical_summary():
    cfg = RequestPlaneConfig(n_streams=8, max_wait=0.02, offload_capacity=4,
                             admission=AdmissionConfig(max_queue=16))
    arr = _traffic(300.0, 200, process="mmpp")
    s1 = serve_traffic(cfg, arr, K(7))[2]
    s2 = serve_traffic(cfg, arr, K(7))[2]
    assert s1 == s2


def test_flush_on_max_batch_is_immediate():
    cfg = RequestPlaneConfig(n_streams=4, max_wait=10.0)

    async def main():
        plane = RequestPlane(cfg, K(0))
        results = await asyncio.gather(*[
            asyncio.ensure_future(plane.submit(i, 0.6, 1, y=1))
            for i in range(4)])
        await plane.drain()
        return plane, results

    plane, results = run_virtual(main())
    summary = plane.summary()
    # All four streams queued → one full flush, no 10 s deadline waited.
    assert summary["rounds_total"] == 1.0
    assert summary["requests_total"] == 4.0 == summary["admitted_total"]
    for r in results:
        assert r.pred in (0, 1) and not r.denied
        if not r.offloaded:
            assert r.latency == 0.0            # decided at the arrival instant
        else:
            assert 0.0 < r.latency < 10.0      # link time only


def test_flush_on_deadline_when_batch_incomplete():
    cfg = RequestPlaneConfig(n_streams=4, max_wait=0.25)

    async def main():
        plane = RequestPlane(cfg, K(0))
        r = await plane.submit(0, 0.9, 1, y=1)
        await plane.drain()
        return plane, r

    plane, r = run_virtual(main())
    assert plane.summary()["rounds_total"] == 1.0
    assert r.latency >= 0.25                   # waited out the deadline


def test_denials_degrade_to_fallback_predictions():
    cfg = RequestPlaneConfig(
        n_streams=8, max_wait=0.02,
        admission=AdmissionConfig(rate=10.0, burst=2.0))
    arr = _traffic(2000.0, 150)
    plane, results, summary = serve_traffic(cfg, arr, K(1))
    assert summary["denied_total"] > 0
    fs = np.asarray(arr.fs)
    for f, r in zip(fs, results):
        assert r.pred in (0, 1)                # never an error
        if r.denied:
            assert r.reason in ("rate_limited", "queue_full", "no_slot")
            assert r.pred == int(f >= 0.5)     # the local-only fallback
    assert summary["requests_total"] == \
        summary["admitted_total"] + summary["denied_total"]
    assert summary["fallback_total"] == \
        summary["denied_total"] + summary["capacity_dropped"]


def test_no_slot_denial_while_stream_pinned():
    cfg = RequestPlaneConfig(
        n_streams=1, hi=HIConfig(eps=1.0),      # ε=1 → every decide offloads
        max_wait=0.01,
        link=LinkConfig(base_rtt=0.5, jitter=0.0, congested_extra=0.0))

    async def main():
        plane = RequestPlane(cfg, K(0))
        first = asyncio.ensure_future(plane.submit(0, 0.5, 1, y=1))
        await asyncio.sleep(0.05)              # first is mid-transfer (0.5 s)
        second = await plane.submit(1, 0.9, 1, y=1)
        r1 = await first
        await plane.drain()
        return plane, r1, second

    plane, r1, r2 = run_virtual(main())
    assert r1.offloaded and r1.latency >= 0.5
    assert r2.denied and r2.reason == "no_slot" and r2.pred == 1
    assert plane.summary()["denied_no_slot"] == 1.0


def test_session_eviction_reclaims_lru_slot():
    cfg = RequestPlaneConfig(n_streams=2, max_batch=1, max_wait=0.01,
                             restart_on_reclaim=True)

    async def main():
        plane = RequestPlane(cfg, K(0))
        for session in (10, 11, 12, 13, 10):   # 4 sessions on 2 slots
            await plane.submit(session, 0.7, 1, y=1)
        await plane.drain()
        return plane

    plane = run_virtual(main())
    summary = plane.summary()
    assert summary["session_evictions"] >= 2.0
    assert summary["slot_reclaims"] == summary["session_evictions"]


# --------------------- parity with the offline serving path -------------------


def _lockstep_arrivals(s, rounds, period):
    """One request per stream per round, rounds `period` seconds apart —
    the synchronous slot structure of the offline server, as traffic."""
    n = s * rounds
    gaps = np.zeros((n,), np.float32)
    gaps[::s] = period
    gaps[0] = 0.0
    rng = np.random.default_rng(11)
    ys = rng.integers(0, 2, n).astype(np.int32)
    fs = np.where(ys == 1, rng.uniform(0.55, 0.95, n),
                  rng.uniform(0.05, 0.45, n)).astype(np.float32)
    return ArrivalBatch(
        gaps=gaps, sessions=np.tile(np.arange(s, dtype=np.int32), rounds),
        fs=fs, hrs=ys, ys=ys, payloads=np.full((n,), 4096.0, np.float32))


def test_low_load_parity_with_hi_server_replay():
    """At low load (full rounds, no drops, transfers done before the next
    round) the plane's decide/compact/feedback flow is op-for-op the
    offline `HIServer.run_source` — replaying the plane's recorded rounds
    with the same policy key must reproduce its offloads and cost."""
    s, rounds = 4, 24
    hi = HIConfig(eps=0.3)
    cfg = RequestPlaneConfig(n_streams=s, hi=hi, max_wait=0.2,
                             record_rounds=True)
    plane, results, summary = serve_traffic(
        cfg, _lockstep_arrivals(s, rounds, period=1.0), K(7))
    rec = plane.batcher.record
    assert len(rec) == rounds
    assert all(bool(np.all(r["active"])) for r in rec)
    assert summary["drop_rate"] == 0.0 and summary["deny_rate"] == 0.0

    stack = lambda name: np.stack([r[name] for r in rec], axis=1)  # (S, T)
    src = ReplaySource(fs=stack("fs"), hrs=stack("hrs"), ys=stack("ys"),
                       betas=stack("betas"))
    server = HIServer(HIServerConfig(n_streams=s, hi=hi), ldl=None, rdl=None)
    _, replay = server.run_source(src, K(7))

    assert summary["offload_rate"] == replay["offload_rate"]
    assert summary["avg_offload_cost"] == pytest.approx(
        replay["avg_offload_cost"], abs=1e-5)
    assert summary["avg_true_cost"] == pytest.approx(
        replay["avg_true_cost"], abs=1e-5)
    assert summary["accuracy"] == replay["accuracy"]


def test_counter_mode_low_load_parity_and_determinism():
    """`randomness="counter"` through the whole plane: the flush round
    index is the counter slot, so the low-load replay parity with a
    counter-mode `HIServer.run_source` holds exactly as in pre-draw mode —
    with zero key-tree splits and no (ψ, ζ) tensors anywhere."""
    s, rounds = 4, 16
    hi = HIConfig(eps=0.3)
    cfg = RequestPlaneConfig(n_streams=s, hi=hi, max_wait=0.2,
                             record_rounds=True, randomness="counter")
    plane, results, summary = serve_traffic(
        cfg, _lockstep_arrivals(s, rounds, period=1.0), K(7))
    assert summary["drop_rate"] == 0.0 and summary["deny_rate"] == 0.0
    rec = plane.batcher.record
    assert len(rec) == rounds

    stack = lambda name: np.stack([r[name] for r in rec], axis=1)  # (S, T)
    src = ReplaySource(fs=stack("fs"), hrs=stack("hrs"), ys=stack("ys"),
                       betas=stack("betas"))
    server = HIServer(
        HIServerConfig(n_streams=s, hi=hi, randomness="counter"),
        ldl=None, rdl=None)
    _, replay = server.run_source(src, K(7))
    assert summary["offload_rate"] == replay["offload_rate"]
    assert summary["avg_offload_cost"] == pytest.approx(
        replay["avg_offload_cost"], abs=1e-5)
    assert summary["avg_true_cost"] == pytest.approx(
        replay["avg_true_cost"], abs=1e-5)

    # Deterministic for a fixed seed, and a different contract from the
    # pre-draw key tree under the same key.
    again = serve_traffic(
        cfg, _lockstep_arrivals(s, rounds, period=1.0), K(7))[2]
    assert again == summary
    pre = serve_traffic(
        RequestPlaneConfig(n_streams=s, hi=hi, max_wait=0.2),
        _lockstep_arrivals(s, rounds, period=1.0), K(7))[2]
    assert pre["offload_rate"] != summary["offload_rate"]
    with pytest.raises(ValueError, match="randomness"):
        RequestPlaneConfig(n_streams=s, randomness="bogus")


def test_replay_source_round_trips_and_validates():
    trace = ReplaySource(fs=np.full((2, 8), 0.5, np.float32),
                         hrs=np.zeros((2, 8), np.int32),
                         ys=np.zeros((2, 8), np.int32),
                         betas=np.full((2, 8), 0.3, np.float32),
                         block=4)
    out = trace.materialize()
    assert out.fs.shape == (2, 8)
    assert bool(jnp.all(out.betas == 0.3))
    with pytest.raises(ValueError, match="share one"):
        ReplaySource(fs=np.zeros((2, 8)), hrs=np.zeros((2, 4)),
                     ys=np.zeros((2, 8)), betas=np.zeros((2, 8)))


# ------------------------------ sustained overload ----------------------------


def test_sustained_overload_fairness_and_exact_accounting():
    """Queue saturated for many rounds: admission + rotating drops shed
    load, yet no stream is starved of remote service, every future
    resolves, and the shed accounting balances exactly."""
    s = 6
    cfg = RequestPlaneConfig(
        n_streams=s, hi=HIConfig(eps=0.5), max_wait=0.02,
        offload_capacity=2,
        admission=AdmissionConfig(max_queue=2 * s))
    n = 600
    arr = TrafficProcess(process="poisson", rate=1200.0, n_arrivals=n,
                         n_sessions=s, key=K(9)).materialize()
    plane, results, summary = serve_traffic(cfg, arr, K(2))

    assert len(results) == n and all(r.pred in (0, 1) for r in results)
    assert summary["denied_total"] > 0 and summary["capacity_dropped"] > 0
    # Exact shed accounting: every request is admitted or denied, every
    # fallback is a denial or a capacity drop, every admitted request
    # completes exactly once (and had its latency observed).
    assert summary["requests_total"] == \
        summary["admitted_total"] + summary["denied_total"]
    assert summary["fallback_total"] == \
        summary["denied_total"] + summary["capacity_dropped"] \
        + summary.get("retry_exhausted", 0.0)
    assert summary["admitted_total"] == summary["latency_ms_count"]
    assert summary["admitted_total"] == (summary["completed_local"]
                                         + summary["completed_remote"]
                                         + summary["capacity_dropped"]
                                         + summary.get("retry_exhausted", 0.0))
    # Rotating compaction shares the RDL: no stream starves.
    assert plane.batcher.stream_sent.min() >= 1
    # Queue-depth admission bounds tail latency at saturation.
    assert summary["p99_latency_ms"] < 500.0


# ------------------------------ config ----------------------------------------


def test_plane_config_validation():
    with pytest.raises(ValueError, match="max_batch"):
        RequestPlaneConfig(n_streams=4, max_batch=5)
    with pytest.raises(ValueError, match="max_wait"):
        RequestPlaneConfig(max_wait=0.0)
    with pytest.raises(ValueError, match="offload_capacity"):
        RequestPlaneConfig(offload_capacity=0)
    with pytest.raises(ValueError, match="adaptive|H2T2State"):
        run_virtual(_submit_once(RequestPlaneConfig(engine="adaptive")))


async def _submit_once(cfg):
    plane = RequestPlane(cfg, K(0))
    await plane.submit(0, 0.5, 1)
    await plane.drain()
