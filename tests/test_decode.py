"""Decode-path integration: prefill + decode_step ≡ full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED
from repro.models import decode_step, forward, init_decode_state, init_params, prefill
from repro.models.transformer import RunFlags

B, S = 2, 48

TOL = {"default": 0.08}


def _inputs(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)
    inputs = {"tokens": toks}
    if cfg.family == "vlm":
        inputs["patches"] = 0.1 * jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), cfg.dtype)
    if cfg.family == "encdec":
        inputs["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.n_frames, cfg.d_model), cfg.dtype)
    return inputs


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_forward(arch, rng):
    import dataclasses

    cfg = ARCHS[arch].reduced()
    if cfg.n_experts:
        # Capacity-based MoE drops differ between batch shapes; give the
        # router enough capacity that neither path drops tokens.
        cfg = dataclasses.replace(cfg, moe_capacity_factor=64.0)
    params = init_params(rng, cfg)
    inputs = _inputs(cfg, rng)
    toks = inputs["tokens"]
    full, _ = forward(params, cfg, inputs)

    pre = dict(inputs)
    pre["tokens"] = toks[:, : S - 1]
    extra = cfg.n_patches if cfg.family == "vlm" else 0
    _, st = prefill(params, cfg, pre, capacity=S + extra + 8)
    logits, _ = decode_step(params, cfg, st, toks[:, S - 1 : S])

    a = np.asarray(logits[:, 0, :], np.float32)
    b = np.asarray(full[:, -1, :], np.float32)
    assert np.max(np.abs(a - b)) < TOL["default"], np.max(np.abs(a - b))


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-780m", "recurrentgemma-2b"])
def test_multi_step_decode_stays_finite(arch, rng):
    cfg = ARCHS[arch].reduced()
    params = init_params(rng, cfg)
    inputs = _inputs(cfg, rng)
    _, st = prefill(params, cfg, inputs, capacity=S + 16)
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(8):
        logits, st = decode_step(params, cfg, st, tok)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)


def test_ring_cache_eviction_matches_window():
    """Sliding-window ring cache: decode with capacity=window equals decode
    with a big cache when attention is windowed."""
    import dataclasses

    cfg = ARCHS["qwen2-1.5b"].reduced()
    win = 16
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab, jnp.int32)
    flags = RunFlags(mode="decode", window=win)

    def run(capacity):
        st = init_decode_state(cfg, B, capacity)
        outs = []
        for t in range(S):
            logits, st = decode_step(params, cfg, st, toks[:, t : t + 1], flags=flags)
            outs.append(np.asarray(logits[:, 0, :], np.float32))
        return np.stack(outs)

    small = run(win)        # ring wraps constantly
    big = run(S + 1)        # never wraps
    # The ring layout rotates key order, so the bf16 attention reduction can
    # differ by one ulp (2^-6 at logit magnitude ~2-4) on isolated steps.
    assert np.max(np.abs(small - big)) <= 0.02


def test_cold_decode_from_empty_cache(rng):
    """Decoding from a fresh cache (no prefill) works and is causal-correct
    vs forward over the same prefix."""
    cfg = ARCHS["granite-3-2b"].reduced()
    params = init_params(rng, cfg)
    toks = jax.random.randint(rng, (B, 8), 0, cfg.vocab, jnp.int32)
    st = init_decode_state(cfg, B, 16)
    outs = []
    for t in range(8):
        logits, st = decode_step(params, cfg, st, toks[:, t : t + 1])
        outs.append(np.asarray(logits[:, 0, :], np.float32))
    full, _ = forward(params, cfg, {"tokens": toks})
    assert np.max(np.abs(np.stack(outs, 1) - np.asarray(full, np.float32))) < 0.08
