"""Pinned contract suite for counter-based (ψ, ζ) randomness.

Three layers of pinning, least to most integrated:

  1. The threefry2x32 primitive against the published Random123
     known-answer vectors (and jax's own `threefry_2x32`), so a jax
     upgrade that changes integer-op semantics fails loudly.
  2. The in-kernel counter generator (`counter_draw_pallas`, interpret
     mode) against the golden jnp `psi_zeta_from_counter` — raw uint32
     words compared with array_equal, for every tested (S, stream_block).
  3. Position-invariance properties: the draw at (seed, stream, slot) is a
     value, not a state, so ANY partition of the fleet into stream blocks
     / time blocks / device shards reproduces bit-identical randomness —
     asserted over S ∈ {1, 5, 13, 64} × TB ∈ {1, 8, 64} and, in the slow
     suite, across 8 fake devices in a subprocess.

Plus the serving integration: every PolicyEngine, the HIServer, and the
request plane accept `randomness="counter"` and agree bit-for-bit with
each other (counter mode is a *different* contract from pre_draw — the
two modes agree in distribution, never in bits).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CounterRNG,
    HIConfig,
    counter_rng,
    draw_fleet_randomness,
    draw_fleet_slot_randomness,
    draw_psi_zeta,
    fleet_decide,
    fleet_feedback,
    fleet_init,
    psi_zeta_from_counter,
    run_fleet_fused,
    seed_from_key,
)
from repro.core.counter import (
    RANDOMNESS_MODES,
    check_randomness_mode,
    counter_bits,
    threefry2x32,
    uniform_from_bits,
)
from repro.core.policy import run_fleet_source, source_slot_keys
from repro.kernels.hedge.kernel import counter_draw_pallas
from repro.serving import HIServer, HIServerConfig, get_engine

CFG = HIConfig(bits=4, eps=0.05, eta=1.0)


# ------------------------- layer 1: the primitive -----------------------------


def test_threefry_known_answer_vectors():
    """Random123 KATs for threefry2x32 (20 rounds), key words first."""
    vectors = [
        ((0, 0), (0, 0), (0x6B200159, 0x99BA4EFE)),
        ((0xFFFFFFFF, 0xFFFFFFFF), (0xFFFFFFFF, 0xFFFFFFFF),
         (0x1CB996FC, 0xBB002BE7)),
        ((0x13198A2E, 0x03707344), (0x243F6A88, 0x85A308D3),
         (0xC4923A9C, 0x483DF7A0)),
        ((123, 456), (7, 9), (0x79F35382, 0x623FEF17)),
    ]
    for (k0, k1), (x0, x1), (e0, e1) in vectors:
        b0, b1 = threefry2x32(k0, k1, x0, x1)
        assert (int(b0), int(b1)) == (e0, e1), (hex(k0), hex(x0))


def test_threefry_matches_jax_internal():
    """Our portable mixing is bit-identical to jax's `threefry_2x32` (the
    PRNGKey impl) on random key/counter words."""
    from jax._src.prng import threefry_2x32

    words = jax.random.bits(jax.random.PRNGKey(0), (32, 4), jnp.uint32)
    ours = threefry2x32(words[:, 0], words[:, 1], words[:, 2], words[:, 3])
    theirs = threefry_2x32(words[:, :2].T, words[:, 2:].T)
    assert np.array_equal(np.asarray(ours[0]), np.asarray(theirs[0]))
    assert np.array_equal(np.asarray(ours[1]), np.asarray(theirs[1]))


def test_uniform_from_bits_is_exact_24bit():
    bits = jnp.asarray([0, 0xFF, 0x100, 0xFFFFFFFF], jnp.uint32)
    u = uniform_from_bits(bits)
    # Top 24 bits only: the low byte never matters, the top value is
    # (2^24 - 1)/2^24 < 1, and every value is exact in a float32 mantissa.
    assert u[0] == 0.0 and u[1] == 0.0
    assert float(u[2]) == 1.0 / (1 << 24)
    assert float(u[3]) == (1 - 2**-24) and float(u[3]) < 1.0


def test_seed_from_key_accepts_both_key_styles():
    raw = jax.random.PRNGKey(42)                       # (2,) uint32
    typed = jax.random.key(42)                         # typed scalar key
    s1, s2 = seed_from_key(raw), seed_from_key(typed)
    assert s1.shape == (2,) and s1.dtype == jnp.uint32
    assert np.array_equal(np.asarray(s1), np.asarray(s2))
    # Raw (2,) word arrays pass through; jit-traced keys work too.
    assert np.array_equal(np.asarray(seed_from_key(s1)), np.asarray(s1))
    assert np.array_equal(
        np.asarray(jax.jit(seed_from_key)(raw)), np.asarray(s1))
    with pytest.raises(ValueError, match="2-word"):
        seed_from_key(jnp.zeros((3,), jnp.uint32))


def test_psi_zeta_contract_and_broadcast():
    seed = seed_from_key(jax.random.PRNGKey(7))
    sid = jnp.arange(5, dtype=jnp.int32)
    b0, b1 = counter_bits(seed, sid, 3)
    psi, zeta = psi_zeta_from_counter(seed, sid, 3, 0.25)
    assert np.array_equal(np.asarray(psi), np.asarray(uniform_from_bits(b0)))
    assert np.array_equal(
        np.asarray(zeta), np.asarray(uniform_from_bits(b1)) < 0.25)
    assert psi.dtype == jnp.float32 and zeta.dtype == jnp.bool_
    # (S, 1) × (1, T) broadcasting gives the full grid, row/col consistent
    # with the scalar-slot draws.
    slots = jnp.arange(4, dtype=jnp.int32)
    pg, zg = psi_zeta_from_counter(seed, sid[:, None], slots[None, :], 0.25)
    assert pg.shape == zg.shape == (5, 4)
    p3, z3 = psi_zeta_from_counter(seed, sid, slots[3], 0.25)
    assert np.array_equal(np.asarray(pg[:, 3]), np.asarray(p3))
    assert np.array_equal(np.asarray(zg[:, 3]), np.asarray(z3))


def test_randomness_mode_validation():
    assert RANDOMNESS_MODES == ("pre_draw", "counter")
    for mode in RANDOMNESS_MODES:
        assert check_randomness_mode(mode) == mode
    with pytest.raises(ValueError, match="randomness"):
        check_randomness_mode("hybrid")


# ------------------------ layer 2: in-kernel bit-compat -----------------------


@pytest.mark.parametrize("s", [1, 5, 13, 64])
@pytest.mark.parametrize("sb", [1, 8])
def test_counter_draw_pallas_bit_compat(s, sb):
    """The unrolled in-kernel threefry twin returns the SAME uint32 words as
    the golden jnp reference — for every stream-block geometry, including
    non-divisible fleets (padding rows draw ids ≥ S and are sliced off)."""
    eps = 0.3
    rng = counter_rng(jax.random.PRNGKey(11), slot=9, stream_offset=2)
    b0k, b1k, psik, zetak = counter_draw_pallas(
        rng, s, eps=eps, stream_block=sb, interpret=True)
    sid = 2 + jnp.arange(s, dtype=jnp.int32)
    b0, b1 = counter_bits(rng.seed, sid, rng.slot)
    psi, zeta = psi_zeta_from_counter(rng.seed, sid, rng.slot, eps)
    assert np.array_equal(np.asarray(b0k), np.asarray(b0))
    assert np.array_equal(np.asarray(b1k), np.asarray(b1))
    assert np.array_equal(np.asarray(psik), np.asarray(psi))
    assert np.array_equal(np.asarray(zetak), np.asarray(zeta).astype(np.int32))


def test_hw_bits_has_no_cpu_lowering():
    """The TPU hardware-PRNG variant is an on-TPU throughput experiment
    only: no CPU interpret lowering exists, and the portable path must stay
    the default (hw_bits=False) everywhere bit-compat matters."""
    rng = counter_rng(jax.random.PRNGKey(0), 0)
    with pytest.raises(NotImplementedError, match="prng_seed"):
        counter_draw_pallas(rng, 4, eps=0.1, hw_bits=True, interpret=True)


# ---------------------- layer 3: partition invariance -------------------------


@pytest.mark.parametrize("s", [1, 5, 13, 64])
@pytest.mark.parametrize("tb", [1, 8, 64])
def test_counter_draws_partition_invariant(s, tb):
    """Assembling the (S, T) draw grid from ANY (stream_block × time_block)
    tiling — each tile drawn independently through its (stream_offset,
    slot) position — is bit-identical to the one-shot materialization."""
    t = 64
    eps = 0.1
    seed = seed_from_key(jax.random.PRNGKey(3))
    sid = jnp.arange(s, dtype=jnp.int32)
    slots = jnp.arange(t, dtype=jnp.int32)
    full_p, full_z = psi_zeta_from_counter(
        seed, sid[:, None], slots[None, :], eps)

    tiled_p = np.zeros((s, t), np.float32)
    tiled_z = np.zeros((s, t), bool)
    for s0 in range(0, s, 5):                    # uneven stream partition
        rows = min(5, s - s0)
        for t0 in range(0, t, tb):
            # Each tile only knows its offsets — exactly what a sharded
            # per-device block or a multi-round kernel launch sees.
            tsid = s0 + jnp.arange(rows, dtype=jnp.int32)
            tslots = t0 + jnp.arange(tb, dtype=jnp.int32)
            p, z = psi_zeta_from_counter(
                seed, tsid[:, None], tslots[None, :], eps)
            tiled_p[s0:s0 + rows, t0:t0 + tb] = np.asarray(p)
            tiled_z[s0:s0 + rows, t0:t0 + tb] = np.asarray(z)
    assert np.array_equal(tiled_p, np.asarray(full_p))
    assert np.array_equal(tiled_z, np.asarray(full_z))


def test_run_fleet_fused_counter_blocking_invariance():
    """Counter-mode fleet runs are invariant to time blocking and to the
    kernel/jnp path switch: tb ∈ {1, 8, 64} and interpret-mode kernels all
    make bit-identical decisions."""
    s, t = 5, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    fs = jax.random.uniform(ks[0], (s, t))
    hrs = jax.random.bernoulli(ks[1], 0.5, (s, t)).astype(jnp.int32)
    betas = jnp.full((s, t), 0.3)
    key = jax.random.PRNGKey(5)
    ref = run_fleet_fused(CFG, fs, hrs, betas, key, use_kernel=False,
                          randomness="counter")
    for kwargs in ({"time_block": 8}, {"time_block": 64},
                   {"use_kernel": True, "interpret": True},
                   {"use_kernel": True, "interpret": True, "time_block": 8}):
        st, out = run_fleet_fused(CFG, fs, hrs, betas, key,
                                  randomness="counter",
                                  **{"use_kernel": False, **kwargs})
        for a, b in ((out.offload, ref[1].offload),
                     (out.explored, ref[1].explored),
                     (out.pred, ref[1].pred)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), kwargs
        np.testing.assert_allclose(np.asarray(out.loss),
                                   np.asarray(ref[1].loss),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(st.log_w),
                                   np.asarray(ref[0].log_w),
                                   rtol=1e-5, atol=1e-6)


def test_counter_run_matches_materialized_crosscheck():
    """The zero-materialization counter run consumes exactly the draws the
    O(S×T) `draw_fleet_randomness(randomness="counter")` cross-check
    materializes — pinned through the returned per-round ψ."""
    s, t = 4, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    fs = jax.random.uniform(ks[0], (s, t))
    hrs = jax.random.bernoulli(ks[1], 0.5, (s, t)).astype(jnp.int32)
    betas = jnp.full((s, t), 0.3)
    key = jax.random.PRNGKey(9)
    psi, zeta = draw_fleet_randomness(CFG, key, s, t, randomness="counter")
    sid = jnp.arange(s, dtype=jnp.int32)
    slots = jnp.arange(t, dtype=jnp.int32)
    pref, zref = psi_zeta_from_counter(
        seed_from_key(key), sid[:, None], slots[None, :], CFG.eps)
    assert np.array_equal(np.asarray(psi), np.asarray(pref))
    assert np.array_equal(np.asarray(zeta), np.asarray(zref))
    _, out = run_fleet_fused(CFG, fs, hrs, betas, key, use_kernel=False,
                             randomness="counter")
    # Replaying the materialized draws through explicit (ψ, ζ) decide /
    # feedback reproduces the counter run's decisions bit-for-bit.
    state = fleet_init(CFG, s)
    offl = []
    for i in range(t):
        dec = fleet_decide(CFG, state, fs[:, i], psi[:, i], zeta[:, i])
        offl.append(np.asarray(dec.offload))
        state, _ = fleet_feedback(CFG, state, dec, hrs[:, i], betas[:, i],
                                  dec.offload)
    assert np.array_equal(np.stack(offl, 1), np.asarray(out.offload))
    # The two modes are different contracts: same key, different bits.
    pre_psi, _ = draw_fleet_randomness(CFG, key, s, t)
    assert not np.array_equal(np.asarray(pre_psi), np.asarray(psi))


def test_counter_mode_argument_validation():
    s, t = 3, 8
    key = jax.random.PRNGKey(0)
    stream_keys = jax.random.split(key, s)
    with pytest.raises(ValueError, match="stream_keys"):
        draw_fleet_randomness(CFG, key, s, t, stream_keys=stream_keys,
                              randomness="counter")
    with pytest.raises(ValueError, match="key"):
        draw_fleet_randomness(CFG, None, s, t, randomness="counter")
    fs = jnp.full((s, t), 0.5)
    hrs = jnp.zeros((s, t), jnp.int32)
    betas = jnp.full((s, t), 0.3)
    with pytest.raises(ValueError, match="stream_keys"):
        run_fleet_fused(CFG, fs, hrs, betas, key, stream_keys=stream_keys,
                        randomness="counter")
    state = fleet_init(CFG, s)
    rng = counter_rng(key, 0)
    psi = jnp.full((s,), 0.5)
    zeta = jnp.zeros((s,), bool)
    with pytest.raises(ValueError, match="rng"):
        fleet_decide(CFG, state, fs[:, 0], psi, zeta, rng=rng)
    with pytest.raises(ValueError, match="rng"):
        fleet_decide(CFG, state, fs[:, 0], None, None)


# -------------------- slot-randomness contract (pre_draw) ---------------------


def test_slot_randomness_contract_pins_source_runs():
    """`draw_fleet_slot_randomness` IS the source-driven key contract in
    materialized form: column t equals `draw_psi_zeta(source_slot_keys)`,
    and feeding those columns through explicit (ψ, ζ) decide/feedback
    replays a `run_fleet_source`-keyed round bit-for-bit."""
    s, horizon = 6, 5
    key = jax.random.PRNGKey(3)
    psis, zetas = draw_fleet_slot_randomness(CFG, key, s, horizon)
    assert psis.shape == zetas.shape == (s, horizon)
    state = fleet_init(CFG, s)
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    fs = jax.random.uniform(ks[0], (s,))
    hrs = jax.random.bernoulli(ks[1], 0.5, (s,)).astype(jnp.int32)
    betas = jnp.full((s,), 0.3)
    for t in range(horizon):
        psi, zeta = draw_psi_zeta(source_slot_keys(key, t, s), CFG.eps)
        assert np.array_equal(np.asarray(psi), np.asarray(psis[:, t]))
        assert np.array_equal(np.asarray(zeta), np.asarray(zetas[:, t]))
        dec = fleet_decide(CFG, state, fs, psis[:, t], zetas[:, t])
        dec_k = fleet_decide(CFG, state, fs, psi, zeta)
        assert np.array_equal(np.asarray(dec.offload), np.asarray(dec_k.offload))
        state, _ = fleet_feedback(CFG, state, dec, hrs, betas, dec.offload)


# ----------------------- serving integration (engines) ------------------------


def _fleet_trace(s, t, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 2)
    fs = jax.random.uniform(ks[0], (s, t))
    hrs = jax.random.bernoulli(ks[1], 0.5, (s, t)).astype(jnp.int32)
    betas = jnp.full((s, t), 0.3)
    return fs, hrs, betas


def test_engines_counter_parity():
    """Every PolicyEngine under `randomness="counter"` makes bit-identical
    decisions, and each engine's whole-run path equals its own
    step-by-step decide/feedback loop at the same slots."""
    s, t = 4, 24
    fs, hrs, betas = _fleet_trace(s, t)
    key = jax.random.PRNGKey(2)
    outs = {}
    for name in ("reference", "fused", "sharded", "adaptive"):
        eng = get_engine(name, CFG, randomness="counter")
        assert eng.randomness == "counter"
        outs[name] = eng.run(fs, hrs, betas, key)[1]
    ref = outs["reference"]
    for name, out in outs.items():
        assert np.array_equal(np.asarray(out.offload),
                              np.asarray(ref.offload)), name
        assert np.array_equal(np.asarray(out.pred), np.asarray(ref.pred)), name
        np.testing.assert_allclose(np.asarray(out.loss), np.asarray(ref.loss),
                                   rtol=1e-5, atol=1e-6, err_msg=name)
    # decide(slot=...) is the same draw the run consumed at that slot.
    eng = get_engine("fused", CFG, randomness="counter")
    state = eng.init(s)
    dec = eng.decide(state, fs[:, 0], key, slot=0)
    assert np.array_equal(np.asarray(dec.offload), np.asarray(ref.offload[:, 0]))
    # Without a slot the counter position is ambiguous — loud error.
    with pytest.raises(ValueError, match="slot"):
        eng.decide(state, fs[:, 0], key)
    with pytest.raises(ValueError, match="randomness"):
        get_engine("fused", CFG, randomness="bogus")


def test_engine_run_source_counter_parity():
    """Source-driven counter runs agree across engines (no (S, T) arrays,
    no per-slot key trees — one seed, position-keyed draws)."""
    from repro.data.scenarios import StationarySource

    s = 4
    src = StationarySource(n_streams=s, horizon=36, block=12,
                           key=jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(4)
    totals = {}
    for name in ("reference", "fused", "adaptive"):
        eng = get_engine(name, CFG, randomness="counter")
        _, out = eng.run_source(src, key)
        totals[name] = float(np.asarray(out.loss).sum())
    assert totals["fused"] == pytest.approx(totals["reference"], rel=1e-6)
    assert totals["adaptive"] == pytest.approx(totals["reference"], rel=1e-6)
    # And differs from the pre_draw contract under the same key (different
    # randomness, same distribution).
    _, pre = get_engine("fused", CFG).run_source(src, key)
    assert float(np.asarray(pre.loss).sum()) != totals["fused"]


def test_hi_server_counter_smoke():
    """HIServer end to end in counter mode: the multi-round fast path, the
    slot-by-slot path, and both engines agree on totals."""
    from repro.data.scenarios import StationarySource

    s = 4
    key = jax.random.PRNGKey(6)
    mk = lambda **kw: HIServer(HIServerConfig(
        n_streams=s, hi=CFG, randomness="counter", **kw),
        ldl=None, rdl=None)
    src = lambda: StationarySource(n_streams=s, horizon=48, block=12,
                                   key=jax.random.PRNGKey(1))
    fused, _ = mk(engine="fused").run_source(src(), key)
    fused_tb, _ = mk(engine="fused", time_block=12).run_source(src(), key)
    ref, _ = mk(engine="reference").run_source(src(), key)
    assert float(fused.total_loss) == pytest.approx(
        float(ref.total_loss), rel=1e-6)
    assert float(fused_tb.total_loss) == pytest.approx(
        float(ref.total_loss), rel=1e-6)
    with pytest.raises(ValueError, match="randomness"):
        HIServerConfig(n_streams=s, hi=CFG, randomness="bogus")


# ------------------------------ sharded (slow) --------------------------------


@pytest.mark.slow
def test_sharded_counter_bits_under_8_fake_devices_subprocess():
    """8 fake host devices in a clean interpreter: the sharded engine's
    counter-mode run is bit-identical to the single-device fused run — the
    per-device stream_offset re-derives fleet-global draw positions, so
    sharding is invisible in the bits (S=11 not dividing 8 exercises the
    padded shard)."""
    code = """
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from repro.core import HIConfig
from repro.serving import get_engine
cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
s, t = 11, 24
ks = jax.random.split(jax.random.PRNGKey(0), 2)
fs = jax.random.uniform(ks[0], (s, t))
hrs = jax.random.bernoulli(ks[1], 0.5, (s, t)).astype(jnp.int32)
betas = jnp.full((s, t), 0.3)
key = jax.random.PRNGKey(5)
sh = get_engine("sharded", cfg, randomness="counter")
fu = get_engine("fused", cfg, randomness="counter")
st_s, out_s = sh.run(fs, hrs, betas, key)
st_f, out_f = fu.run(fs, hrs, betas, key)
assert np.array_equal(np.asarray(out_s.offload), np.asarray(out_f.offload))
assert np.array_equal(np.asarray(out_s.explored), np.asarray(out_f.explored))
assert np.array_equal(np.asarray(out_s.pred), np.asarray(out_f.pred))
np.testing.assert_allclose(np.asarray(st_s.log_w), np.asarray(st_f.log_w),
                           rtol=1e-5, atol=1e-6)
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["JAX_PLATFORMS"] = "cpu"
    env.setdefault("PYTHONPATH", "src")
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stderr
    assert "OK" in res.stdout
