"""Regret behaviour: sublinear growth (Theorem 2 / Corollary 1) + bound sanity."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import HIConfig, offline, run_stream
from repro.core.regret import corollary1_params, empirical_regret, regret_slope, theorem2_bound
from repro.data import dataset_trace


def test_theorem2_bound_positive_and_scales():
    cfg = HIConfig(bits=4, eps=0.1, eta=0.1)
    b1 = theorem2_bound(cfg, 1000)
    b2 = theorem2_bound(cfg, 4000)
    assert 0 < b1 < b2


def test_corollary1_regret_rate_is_two_thirds():
    """With ε*, η*, the bound itself grows ~T^{2/3}."""
    import math

    rates = []
    for t in (10_000, 80_000):
        cfg = HIConfig(bits=4)
        eps, eta = corollary1_params(cfg, t)
        cfg2 = HIConfig(bits=4, eps=eps, eta=eta)
        rates.append(theorem2_bound(cfg2, t))
    slope = math.log(rates[1] / rates[0]) / math.log(8.0)
    assert 0.6 < slope < 0.75, slope


@pytest.mark.slow
def test_empirical_regret_sublinear():
    """Empirical regret slope (log R vs log T) well below linear on BreakHis."""
    horizons = [500, 2000, 8000]
    regrets = []
    for t in horizons:
        cfg = HIConfig(bits=4).with_horizon(t)
        tr = dataset_trace("breakhis", t, jax.random.PRNGKey(0), beta=0.3)
        r = empirical_regret(cfg, tr.fs, tr.hrs, tr.betas,
                             jax.random.PRNGKey(1), n_seeds=6)
        regrets.append(max(r["regret"], 1.0))
    slope = regret_slope(horizons, regrets)
    assert slope < 0.95, (horizons, regrets, slope)


def test_h2t2_beats_naive_on_transitional_beta():
    """The paper's headline: in the transitional β region H2T2 < single-naive."""
    from repro.core import baselines

    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    tr = dataset_trace("breakhis", 8000, jax.random.PRNGKey(0), beta=0.25)
    _, out = run_stream(cfg, tr.fs, tr.hrs, tr.betas, jax.random.PRNGKey(1))
    h2t2 = float(jnp.sum(out.loss))
    no = float(jnp.sum(baselines.no_offload_losses(cfg, tr.fs, tr.hrs, tr.betas)))
    full = float(jnp.sum(baselines.full_offload_losses(cfg, tr.fs, tr.hrs, tr.betas)))
    best_fixed = float(offline.best_two_threshold(cfg, tr.fs, tr.hrs, tr.betas).best_loss)
    assert h2t2 < no and h2t2 < full
    assert h2t2 < 1.25 * best_fixed   # converges near the offline optimum


def test_ood_gain():
    """BreaCh (OOD, 38% FN): H2T2 must strongly beat the no-offload policy."""
    from repro.core import baselines

    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    tr = dataset_trace("breach", 8000, jax.random.PRNGKey(2), beta=0.3)
    _, out = run_stream(cfg, tr.fs, tr.hrs, tr.betas, jax.random.PRNGKey(3))
    h2t2 = float(jnp.mean(out.loss))
    no = float(jnp.mean(baselines.no_offload_losses(cfg, tr.fs, tr.hrs, tr.betas)))
    assert h2t2 < 0.8 * no
