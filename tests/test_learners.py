"""Learner registry: the factored learner's ref/kernel parity and plumbing.

The factored learner has no dense golden to match — its CONTRACT is that the
jnp reference math and the Pallas (interpret) kernels are bit-identical
under jit for every phase (decide / feedback / fused rounds, pre-draw and
counter randomness), that results are invariant to kernel chunking
(stream_block / time_block), and that the structural plumbing (fresh
weights, restart masking, residency accounting) matches the registry
metadata.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import fleet_trace as _fleet_trace
from repro.core import (
    ExecSpec,
    HIConfig,
    counter_rng,
    fleet_decide,
    fleet_feedback,
    fleet_init,
    fleet_restart,
    get_learner,
    list_learners,
    run_fleet_fused,
)
from repro.core.policy import draw_fleet_randomness

JNP = ExecSpec(learner="factored", use_kernel=False)
KER = ExecSpec(learner="factored", use_kernel=True, interpret=True)


def _factored_state(key, s, g=8, rounds=0):
    """A factored fleet state, optionally advanced by a few warmup rounds."""
    cfg = HIConfig(bits=int(np.log2(g)), eps=0.1, eta=1.0)
    state = fleet_init(cfg, s, learner="factored")
    if rounds:
        fs, hrs, betas = _fleet_trace(key, s, rounds)
        state, _ = run_fleet_fused(cfg, fs, hrs, betas, key, spec=JNP)
    return cfg, state


def _tree_equal(a, b):
    fa, fb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), (x, y)


# ----------------------------- registry metadata ------------------------------


def test_registry_lists_both_learners():
    names = [n for n, _ in list_learners()]
    assert names == ["dense", "factored"]


def test_unknown_learner_error_is_uniform():
    with pytest.raises(ValueError, match="unknown learner 'fact'; available"):
        get_learner("fact")


def test_state_shapes_and_residency():
    cfg = HIConfig(bits=4)
    g = cfg.grid
    dense, factored = get_learner("dense"), get_learner("factored")
    assert dense.state_shape(cfg) == (g, g)
    assert factored.state_shape(cfg) == (2, g)
    assert dense.weight_bytes(cfg, 100) == 4 * 100 * g * g
    assert factored.weight_bytes(cfg, 100) == 4 * 100 * 2 * g
    assert fleet_init(cfg, 3, learner="factored").log_w.shape == (3, 2, g)


def test_factored_restart_resets_masked_streams():
    key = jax.random.PRNGKey(0)
    cfg, state = _factored_state(key, 4, rounds=32)
    assert float(jnp.abs(state.log_w).max()) > 0.0
    mask = jnp.array([True, False, True, False])
    fresh = fleet_restart(cfg, state, mask, learner="factored")
    assert np.all(np.asarray(fresh.log_w[mask]) == 0.0)
    assert np.array_equal(np.asarray(fresh.log_w[~mask]),
                          np.asarray(state.log_w[~mask]))
    # Restarts are weights-only: counters (the stream's history) persist.
    assert np.array_equal(np.asarray(fresh.t), np.asarray(state.t))


# -------------------------- ref vs kernel bit-identity ------------------------


def test_factored_decide_kernel_matches_ref():
    key = jax.random.PRNGKey(1)
    cfg, state = _factored_state(key, 16, rounds=64)
    fs = jax.random.uniform(jax.random.fold_in(key, 1), (16,))
    psi = jax.random.uniform(jax.random.fold_in(key, 2), (16,))
    zeta = jax.random.bernoulli(
        jax.random.fold_in(key, 3), cfg.eps, (16,)).astype(jnp.int32)
    d_ref = fleet_decide(cfg, state, fs, psi, zeta, spec=JNP)
    d_ker = fleet_decide(cfg, state, fs, psi, zeta, spec=KER)
    _tree_equal(d_ref, d_ker)


def test_factored_feedback_kernel_matches_ref():
    key = jax.random.PRNGKey(2)
    cfg, state = _factored_state(key, 16, rounds=64)
    fs = jax.random.uniform(jax.random.fold_in(key, 1), (16,))
    psi = jax.random.uniform(jax.random.fold_in(key, 2), (16,))
    zeta = jax.random.bernoulli(
        jax.random.fold_in(key, 3), cfg.eps, (16,)).astype(jnp.int32)
    hrs = jax.random.bernoulli(
        jax.random.fold_in(key, 4), 0.5, (16,)).astype(jnp.int32)
    betas = jnp.full((16,), 0.3)
    dec = fleet_decide(cfg, state, fs, psi, zeta, spec=JNP)
    sent = dec.offload
    st_ref, out_ref = fleet_feedback(cfg, state, dec, hrs, betas, sent,
                                     spec=JNP)
    st_ker, out_ker = fleet_feedback(cfg, state, dec, hrs, betas, sent,
                                     spec=KER)
    _tree_equal(st_ref, st_ker)
    _tree_equal(out_ref, out_ker)


@pytest.mark.parametrize("randomness", ["pre_draw", "counter"])
def test_factored_fused_run_kernel_matches_ref(randomness):
    """Whole-horizon fused runs agree bit-for-bit across the jnp and
    interpret-kernel paths under both randomness modes."""
    key = jax.random.PRNGKey(3)
    cfg = HIConfig(bits=3, eps=0.1, eta=1.0, decay=0.97)
    fs, hrs, betas = _fleet_trace(key, 8, 128)
    base = ExecSpec(learner="factored", randomness=randomness)
    st_ref, out_ref = run_fleet_fused(
        cfg, fs, hrs, betas, key, spec=base.evolve(use_kernel=False))
    st_ker, out_ker = run_fleet_fused(
        cfg, fs, hrs, betas, key,
        spec=base.evolve(use_kernel=True, interpret=True))
    _tree_equal(st_ref, st_ker)
    _tree_equal(out_ref, out_ker)


@pytest.mark.parametrize("time_block", [1, 4, 16])
def test_factored_time_block_invariance(time_block):
    """Chunking the horizon into multi-round kernel launches is a pure
    performance knob: results match the per-round chain exactly."""
    key = jax.random.PRNGKey(4)
    cfg = HIConfig(bits=3, eps=0.1, eta=1.0)
    fs, hrs, betas = _fleet_trace(key, 4, 64)
    ref = run_fleet_fused(cfg, fs, hrs, betas, key,
                          spec=KER.evolve(time_block=1))
    got = run_fleet_fused(cfg, fs, hrs, betas, key,
                          spec=KER.evolve(time_block=time_block))
    _tree_equal(ref, got)


@pytest.mark.parametrize("stream_block", [1, 3, 16])
def test_factored_stream_block_invariance(stream_block):
    key = jax.random.PRNGKey(5)
    cfg = HIConfig(bits=3, eps=0.1, eta=1.0)
    fs, hrs, betas = _fleet_trace(key, 8, 64)
    ref = run_fleet_fused(cfg, fs, hrs, betas, key, spec=KER)
    got = run_fleet_fused(cfg, fs, hrs, betas, key,
                          spec=KER.evolve(stream_block=stream_block))
    _tree_equal(ref, got)


def test_factored_counter_decide_kernel_matches_ref():
    key = jax.random.PRNGKey(6)
    cfg, state = _factored_state(key, 16, rounds=32)
    fs = jax.random.uniform(jax.random.fold_in(key, 1), (16,))
    rng = counter_rng(key, slot=7)
    spec = ExecSpec(learner="factored", randomness="counter")
    d_ref = fleet_decide(cfg, state, fs, None, None, rng=rng,
                         spec=spec.evolve(use_kernel=False))
    d_ker = fleet_decide(cfg, state, fs, None, None, rng=rng,
                         spec=spec.evolve(use_kernel=True, interpret=True))
    _tree_equal(d_ref, d_ker)


# ------------------------------ behavior sanity -------------------------------


def test_factored_learns_on_separable_stream():
    """On a cleanly separable confidence stream the factored fleet should
    stop offloading almost entirely once the thresholds are learned."""
    key = jax.random.PRNGKey(8)
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    t = 2048
    ys = jax.random.bernoulli(key, 0.5, (1, t)).astype(jnp.int32)
    fs = jnp.where(ys == 1, 0.9, 0.1) + 0.05 * jax.random.uniform(
        jax.random.fold_in(key, 1), (1, t)) - 0.025
    betas = jnp.full((1, t), 0.3)
    _, out = run_fleet_fused(cfg, fs, ys, betas, key, spec=JNP)
    late = np.asarray(out.offload)[:, t // 2:]
    assert late.mean() < 2.5 * cfg.eps


def test_factored_randomness_is_learner_independent():
    """Both learners consume the identical ψ/ζ stream for the same key, so
    exploration flags coincide wherever both policies are in region 2/3 the
    same way — spot-check by comparing the ψ draw surfaces directly."""
    key = jax.random.PRNGKey(9)
    cfg = HIConfig(bits=3, eps=0.1)
    psis_d, zetas_d = draw_fleet_randomness(cfg, key, 4, 32, None)
    psis_f, zetas_f = draw_fleet_randomness(cfg, key, 4, 32, None)
    assert np.array_equal(np.asarray(psis_d), np.asarray(psis_f))
    assert np.array_equal(np.asarray(zetas_d), np.asarray(zetas_f))
