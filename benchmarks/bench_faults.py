"""BEYOND-PAPER: fault-tolerance benchmark — the degradation ladder under
injected link faults.

Each row serves the SAME seeded offered-load-1.0 Poisson trace through the
full async plane, with `FaultyLink` injecting a different fault mix and the
resilience layer (per-send deadline, capped-backoff retries, per-stream
circuit breakers) absorbing it. Everything runs on the virtual clock, so
fault schedules are exactly reproducible and wall-clock time measures only
host+device compute.

The grid walks the degradation ladder:

    clean                fault-free reference (FaultyLink in passthrough)
    drop10 / drop30      10% / 30% per-send response loss → retries
    outage20             Markov outages at ~20% duty (p 0.05 in, 0.2 out)
                         → breakers open, ladder denies at ingress
    drop10_outage_retry0 drops + outages with retries disabled — every
                         failure immediately degrades to the local fallback
    drop10_outage_retry4 same faults, deeper retry budget — spend latency
                         to recover offloads instead

Reported per row: mean ground-truth cost, offload/deny/fallback/exhausted
rates, and p99 latency (ms, virtual time). The regression gate treats
`p99_*` as informational; the cost/rate columns gate.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax

from repro.data.traffic import TrafficProcess
from repro.serving.request_plane import (
    AdmissionConfig,
    FaultConfig,
    RequestPlaneConfig,
    ResilienceConfig,
    serve_traffic,
)

N_STREAMS = 8
MAX_WAIT = 0.02           # s — micro-batch flush deadline

#: (row suffix, fault mix, retry budget) — the drop × outage × retry grid.
GRID = (
    ("clean", FaultConfig(), 2),
    ("drop10", FaultConfig(drop_prob=0.10, seed=7), 2),
    ("drop30", FaultConfig(drop_prob=0.30, seed=7), 2),
    ("outage20", FaultConfig(outage_p_enter=0.05, outage_p_exit=0.2,
                             seed=7), 2),
    ("drop10_outage_retry0",
     FaultConfig(drop_prob=0.10, outage_p_enter=0.05, outage_p_exit=0.2,
                 seed=7), 0),
    ("drop10_outage_retry4",
     FaultConfig(drop_prob=0.10, outage_p_enter=0.05, outage_p_exit=0.2,
                 seed=7), 4),
)


def _plane_cfg(engine: str, fault: Optional[FaultConfig],
               max_retries: int) -> RequestPlaneConfig:
    return RequestPlaneConfig(
        n_streams=N_STREAMS,
        engine=engine,
        max_wait=MAX_WAIT,
        offload_capacity=N_STREAMS // 2,
        admission=AdmissionConfig(max_queue=4 * N_STREAMS),
        fault=fault,
        resilience=ResilienceConfig(deadline=0.25, max_retries=max_retries,
                                    breaker_consecutive=3,
                                    breaker_cooldown=0.1),
    )


def _serve_row(name: str, cfg: RequestPlaneConfig,
               traffic: TrafficProcess) -> str:
    arrivals = traffic.materialize()
    t0 = time.perf_counter()
    _, _, summary = serve_traffic(cfg, arrivals, jax.random.PRNGKey(11))
    us = (time.perf_counter() - t0) * 1e6 / traffic.n_arrivals
    return (f"{name},{us:.0f},"
            f"true_cost={summary['avg_true_cost']:.4f},"
            f"offload_rate={summary['offload_rate']:.3f},"
            f"deny_rate={summary['deny_rate']:.3f},"
            f"fallback_rate={summary['fallback_rate']:.3f},"
            f"exhausted_rate={summary['exhausted_rate']:.3f},"
            f"p99_latency_ms={summary['p99_latency_ms']:.2f}")


def run(quick: bool = False, engine: str = "fused") -> List[str]:
    rows = []
    n_arrivals = 512 if quick else 4096
    traffic = TrafficProcess(
        process="poisson", rate=N_STREAMS / MAX_WAIT,   # offered load 1.0
        n_arrivals=n_arrivals, n_sessions=N_STREAMS,
        key=jax.random.PRNGKey(5))
    for suffix, fault, max_retries in GRID:
        rows.append(_serve_row(f"faults_{suffix}",
                               _plane_cfg(engine, fault, max_retries),
                               traffic))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
