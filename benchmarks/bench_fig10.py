"""Fig. 10: average cost AND runtime vs quantization bits b.

|Θ| = 2^{b−1}(2^b+1); runtime measured per H2T2 round (jit-compiled, CPU).
Also benchmarks the fused Pallas hedge kernel — single-round (interpret mode)
and the time-blocked multi-round variant — against the vmapped jnp path at
each b; the kernel is the TPU fleet-serving variant."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import avg_costs_all_policies, engine_cached, timed
from repro.core import ExecSpec, HIConfig
from repro.data import dataset_trace
from repro.kernels.hedge.ops import fleet_hedge_rounds, fleet_hedge_step


def run(quick: bool = False, engine: str = "fused") -> List[str]:
    rows = []
    horizon = 1000 if quick else 5000
    bits_list = [2, 4] if quick else [2, 3, 4, 5, 6]
    for b in bits_list:
        cfg = HIConfig(bits=b, eps=0.05, eta=1.0)
        t0 = time.perf_counter()
        costs = avg_costs_all_policies("breakhis", beta=0.3, horizon=horizon,
                                       bits=b, seeds=2, engine=engine)
        wall = time.perf_counter() - t0
        # Per-round policy-update latency of the selected engine (jit'd scan).
        tr = dataset_trace("breakhis", horizon, jax.random.PRNGKey(0), beta=0.3)
        eng = engine_cached(engine, cfg)
        f = jax.jit(lambda: eng.run(tr.fs[None], tr.hrs[None], tr.betas[None],
                                    jax.random.PRNGKey(1))[1].loss)
        us_round = timed(f) / horizon
        rows.append(
            f"fig10_bits{b}_cost,{us_round:.2f},"
            f"h2t2={costs['h2t2']:.4f};n_experts={cfg.n_experts};"
            f"wall_s={wall:.1f};engine={engine}")
    # Fleet hedge kernel vs jnp reference (batched streams, one round + a
    # TB=8 time block through the multi-round kernel).
    for b in bits_list:
        cfg = HIConfig(bits=b)
        g = cfg.grid
        s = 16 if quick else 64
        tb = 8
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 6)
        l = jnp.arange(g)[:, None]
        u = jnp.arange(g)[None, :]
        logw = jnp.where(l <= u, 0.0, -1e30)[None].repeat(s, 0).astype(jnp.float32)
        args = (logw, jax.random.uniform(ks[1], (s,)), jax.random.uniform(ks[2], (s,)),
                jnp.zeros((s,), jnp.int32), jnp.ones((s,), jnp.int32),
                jnp.full((s,), 0.3))
        ker, ref = ExecSpec(use_kernel=True), ExecSpec(use_kernel=False)
        us_k = timed(lambda *a: fleet_hedge_step(cfg, *a, spec=ker), *args)
        us_r = timed(lambda *a: fleet_hedge_step(cfg, *a, spec=ref), *args)
        rargs = (logw,
                 jax.random.uniform(ks[1], (s, tb)),
                 jax.random.uniform(ks[2], (s, tb)),
                 jnp.zeros((s, tb), jnp.int32), jnp.ones((s, tb), jnp.int32),
                 jnp.full((s, tb), 0.3))
        us_rounds = timed(
            lambda *a: fleet_hedge_rounds(cfg, *a, spec=ker), *rargs)
        rows.append(f"fig10_bits{b}_hedge_kernel,{us_k:.1f},"
                    f"jnp_ref_us={us_r:.1f};rounds_tb{tb}_us={us_rounds:.1f};"
                    f"streams={s};interpret=True")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
