"""Shared benchmark plumbing: policies run over calibrated dataset traces.

Every H2T2-running helper takes an `engine` name resolved through the
`PolicyEngine` registry ("fused" default; "reference" | "fused" | "sharded").
All engines consume identical randomness and produce identical costs — the
switch only changes which execution path the perf trajectory measures.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import HIConfig, baselines, offline
from repro.data import dataset_trace
from repro.serving.policy_engine import get_engine


@functools.lru_cache(maxsize=None)
def engine_cached(name: str, cfg: HIConfig):
    """Memoized engine construction: engines carry per-instance jit caches,
    so benchmark sweeps must reuse one instance per (name, cfg) or every
    point recompiles (worst on the sharded engine's shard_map scan)."""
    return get_engine(name, cfg)

MANUSCRIPT_DATASETS = ["breakhis", "chest", "phishing", "synthetic", "breach"]
APPENDIX_DATASETS = ["chestxray", "resnetdogs", "logisticdogs", "xract"]


def h2t2_seed_losses(
    cfg: HIConfig, fs, hrs, betas, seeds: int, seed0: int = 0,
    engine: str = "fused",
) -> List[float]:
    """Cumulative H2T2 loss for PRNGKey(seed0)..PRNGKey(seed0+seeds-1).

    All seeds run as one fleet (seed i → stream i, the same key tree the
    per-seed `run_stream` calls would consume) on the chosen engine.
    """
    tile = lambda a: jnp.tile(a[None], (seeds, 1))
    stream_keys = jnp.stack(
        [jax.random.PRNGKey(seed0 + s) for s in range(seeds)])
    _, o = engine_cached(engine, cfg).run(
        tile(fs), tile(hrs), tile(betas), stream_keys=stream_keys)
    return [float(x) for x in jnp.sum(o.loss, axis=-1)]


def avg_costs_all_policies(
    name: str, beta: float, horizon: int = 10_000,
    delta_fp: float = 0.7, delta_fn: float = 1.0,
    bits: int = 4, eta: float = 1.0, eps: float = 0.05,
    seeds: int = 3, seed0: int = 0, engine: str = "fused",
) -> Dict[str, float]:
    """Average per-round cost of the paper's six §5 policies on one dataset."""
    cfg = HIConfig(bits=bits, delta_fp=delta_fp, delta_fn=delta_fn,
                   eps=eps, eta=eta)
    tr = dataset_trace(name, horizon, jax.random.PRNGKey(seed0 + 99), beta=beta)
    t = horizon

    h2t2 = [l / t for l in h2t2_seed_losses(cfg, tr.fs, tr.hrs, tr.betas,
                                            seeds, engine=engine)]
    single = []
    for s in range(seeds):
        _, so = baselines.run_single_threshold(
            cfg, tr.fs, tr.hrs, tr.betas, jax.random.PRNGKey(1000 + s))
        single.append(float(jnp.sum(so.loss)) / t)

    return {
        "no_offload": float(jnp.sum(
            baselines.no_offload_losses(cfg, tr.fs, tr.hrs, tr.betas))) / t,
        "full_offload": float(jnp.sum(
            baselines.full_offload_losses(cfg, tr.fs, tr.hrs, tr.betas))) / t,
        "hi_single": sum(single) / len(single),
        "offline_single": float(offline.best_single_threshold(
            cfg, tr.fs, tr.hrs, tr.betas).best_loss) / t,
        "offline_two": float(offline.best_two_threshold(
            cfg, tr.fs, tr.hrs, tr.betas).best_loss) / t,
        "h2t2": sum(h2t2) / len(h2t2),
    }


def timed(fn, *args, reps: int = 5) -> float:
    """us per call after warmup (jit compile excluded)."""
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6
