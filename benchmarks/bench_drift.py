"""BEYOND-PAPER: distribution-shift adaptation — vanilla H2T2 (paper Alg. 1)
vs discounted H2T2 (decay < 1) on a BreakHis→BreaCh mid-stream domain shift.

The paper demonstrates OOD robustness on stationary OOD streams (Fig. 4e);
here the stream CHANGES regime at T/2 and we measure post-shift cost."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core import HIConfig, run_stream
from repro.data import drift_trace


def run(quick: bool = False) -> List[str]:
    rows = []
    horizon = 4000 if quick else 20_000
    half = horizon // 2
    tr = drift_trace("breakhis", "breach", horizon, jax.random.PRNGKey(0),
                     beta=0.3)
    for decay, label in [(1.0, "paper"), (0.999, "decay0.999"),
                         (0.995, "decay0.995")]:
        cfg = HIConfig(bits=4, eps=0.05, eta=1.0, decay=decay)
        t0 = time.perf_counter()
        post = []
        for seed in range(2 if quick else 4):
            _, out = run_stream(cfg, tr.fs, tr.hrs, tr.betas,
                                jax.random.PRNGKey(seed))
            post.append(float(jnp.mean(out.loss[half:])))
        us = (time.perf_counter() - t0) * 1e6
        rows.append(f"drift_h2t2_{label},{us:.0f},"
                    f"post_shift_cost={sum(post)/len(post):.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
