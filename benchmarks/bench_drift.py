"""BEYOND-PAPER: distribution-shift adaptation — vanilla H2T2 (paper Alg. 1)
vs discounted H2T2 (decay < 1) on a BreakHis→BreaCh mid-stream domain shift.

The paper demonstrates OOD robustness on stationary OOD streams (Fig. 4e);
here the stream CHANGES regime at T/2 (the `piecewise` scenario's simplest
schedule) and we measure post-shift cost. All seeds run as ONE fleet on the
chosen `PolicyEngine` (seed i → stream i, the same key tree the per-seed
`run_stream` calls would consume), so `--engine` picks the execution path
the timing measures."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import engine_cached
from repro.core import HIConfig
from repro.data.scenarios import PiecewiseSource


def run(quick: bool = False, engine: str = "fused") -> List[str]:
    rows = []
    horizon = 4000 if quick else 20_000
    half = horizon // 2
    seeds = 2 if quick else 4
    src = PiecewiseSource(segments=((0, "breakhis"), (half, "breach")),
                          horizon=horizon, key=jax.random.PRNGKey(0),
                          beta=0.3)
    tr = src.materialize()                                   # (1, T) leaves
    tile = lambda a: jnp.tile(a, (seeds, 1))
    stream_keys = jnp.stack([jax.random.PRNGKey(s) for s in range(seeds)])
    for decay, label in [(1.0, "paper"), (0.999, "decay0.999"),
                         (0.995, "decay0.995")]:
        cfg = HIConfig(bits=4, eps=0.05, eta=1.0, decay=decay)
        eng = engine_cached(engine, cfg)
        t0 = time.perf_counter()
        _, out = eng.run(tile(tr.fs), tile(tr.hrs), tile(tr.betas),
                         stream_keys=stream_keys)
        jax.block_until_ready(out.loss)
        us = (time.perf_counter() - t0) * 1e6
        post = float(jnp.mean(out.loss[:, half:]))
        rows.append(f"drift_h2t2_{label},{us:.0f},"
                    f"post_shift_cost={post:.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
