"""BEYOND-PAPER: closed-loop request-plane benchmark — offered-load sweep.

Each row serves one seeded open-loop traffic trace through the FULL async
plane (ingress admission → session slot lease → deadline micro-batch →
fleet decide → rotating compaction → simulated link transfer → live-β
estimation → delayed feedback) on the virtual clock, so the sweep is
deterministic and wall-clock time measures only host+device compute.

Offered load is expressed relative to the plane's nominal service
capacity S/max_wait (every stream slot served once per flush deadline):
x0.25/x0.5 sit well under capacity (deny rate ≈ 0), x1 is at it, x2 is
sustained overload where queue-depth admission bounds p99 latency by
shedding to local-only fallbacks. A final row drives bursty MMPP arrivals
at nominal x1 to exercise admission under load spikes.

Reported per row: mean observed serving cost (β on actual offloads),
offload/deny/drop rates, and p50/p95/p99 request latency (ms, virtual
time). Latency percentiles are environment-shaped; the regression gate
treats `p50_*`/`p95_*`/`p99_*` as informational (see check_regression.py).
"""
from __future__ import annotations

import time
from typing import List

import jax

from repro.data.traffic import TrafficProcess
from repro.serving.request_plane import (
    AdmissionConfig,
    RequestPlaneConfig,
    serve_traffic,
)

N_STREAMS = 8
MAX_WAIT = 0.02           # s — micro-batch flush deadline
LOADS = (0.25, 0.5, 1.0, 2.0)


def _plane_cfg(engine: str) -> RequestPlaneConfig:
    return RequestPlaneConfig(
        n_streams=N_STREAMS,
        engine=engine,
        max_wait=MAX_WAIT,
        offload_capacity=N_STREAMS // 2,
        admission=AdmissionConfig(max_queue=4 * N_STREAMS),
    )


def _serve_row(name: str, cfg: RequestPlaneConfig,
               traffic: TrafficProcess) -> str:
    arrivals = traffic.materialize()
    t0 = time.perf_counter()
    _, _, summary = serve_traffic(cfg, arrivals, jax.random.PRNGKey(11))
    us = (time.perf_counter() - t0) * 1e6 / traffic.n_arrivals
    return (f"{name},{us:.0f},"
            f"served_cost={summary['avg_offload_cost']:.4f},"
            f"true_cost={summary['avg_true_cost']:.4f},"
            f"offload_rate={summary['offload_rate']:.3f},"
            f"deny_rate={summary['deny_rate']:.3f},"
            f"drop_rate={summary['drop_rate']:.3f},"
            f"p50_latency_ms={summary['p50_latency_ms']:.2f},"
            f"p95_latency_ms={summary['p95_latency_ms']:.2f},"
            f"p99_latency_ms={summary['p99_latency_ms']:.2f}")


def run(quick: bool = False, engine: str = "fused") -> List[str]:
    rows = []
    n_arrivals = 512 if quick else 4096
    service_rate = N_STREAMS / MAX_WAIT      # nominal plane capacity, req/s
    cfg = _plane_cfg(engine)
    for x in LOADS:
        traffic = TrafficProcess(
            process="poisson", rate=x * service_rate,
            n_arrivals=n_arrivals, n_sessions=N_STREAMS,
            key=jax.random.PRNGKey(5))
        rows.append(_serve_row(f"request_plane_poisson_x{x:g}", cfg, traffic))
    traffic = TrafficProcess(
        process="mmpp", rate=service_rate, burst_rate=4.0 * service_rate,
        n_arrivals=n_arrivals, n_sessions=N_STREAMS,
        key=jax.random.PRNGKey(5))
    rows.append(_serve_row("request_plane_mmpp_x1", cfg, traffic))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
