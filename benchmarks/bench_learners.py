"""BEYOND-PAPER: learner-registry benchmarks — regret parity and scaling.

Two row families:

  learner_* / learner_parity_*
      Dense (G, G) H2T2 vs the factored two-vector learner on the
      manuscript regret workloads, identical traces and randomness (the
      ψ/ζ draws are learner-independent). `cost_gap_rel` is the relative
      cumulative-true-cost gap factored − dense; the paper-parity claim
      is |gap| ≤ 5% on these stationary workloads.

  learner_scaling_*
      The sharded engine pushed up the stream axis with the factored
      learner + counter randomness: O(S·G) weight residency and no
      materialized (S, T) randomness, which is what makes S ≥ 10⁶
      streams feasible at all (dense pre-draw would hold S·G² weights
      AND S·T ψ/ζ draws). Timing (`wall_s`) and residency (`*_bytes`)
      metrics are informational for the regression gate; the behavioral
      cost/offload metrics gate.

The committed million-stream curve in `results/factored_scaling.json`
comes from the module's CLI:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.bench_learners --scaling

(the harness's `--only learners` rows stop at a CI-sized smoke sweep).
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

from repro.core import HIConfig
from repro.core.execspec import ExecSpec
from repro.core.learners import get_learner
from repro.data import dataset_trace, get_scenario
from repro.serving.policy_engine import get_engine


@functools.lru_cache(maxsize=None)
def _engine(name: str, cfg: HIConfig, spec: ExecSpec):
    # Same motivation as benchmarks.common.engine_cached: engines carry
    # per-instance jit caches, and the sweep must reuse one instance per
    # (name, cfg, spec) or every point recompiles.
    return get_engine(name, cfg, spec=spec)


def parity_rows(quick: bool, engine: str) -> List[str]:
    """Factored vs dense cumulative true cost on the manuscript workloads."""
    rows = []
    horizon = 2000 if quick else 8000
    seeds = 2 if quick else 3
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    datasets = ("breakhis",) if quick else ("breakhis", "phishing")
    for name in datasets:
        tr = dataset_trace(name, horizon, jax.random.PRNGKey(99), beta=0.3)
        tile = lambda a: jnp.tile(a[None], (seeds, 1))
        stream_keys = jnp.stack(
            [jax.random.PRNGKey(s) for s in range(seeds)])
        costs: Dict[str, float] = {}
        for learner in ("dense", "factored"):
            eng = _engine(engine, cfg, ExecSpec(learner=learner))
            t0 = time.perf_counter()
            _, out = eng.run(tile(tr.fs), tile(tr.hrs), tile(tr.betas),
                             stream_keys=stream_keys)
            jax.block_until_ready(out.loss)
            us = (time.perf_counter() - t0) * 1e6
            costs[learner] = float(jnp.mean(jnp.sum(out.loss, axis=-1)))
            rows.append(
                f"learner_{learner}_{name},{us:.0f},"
                f"cost={costs[learner] / horizon:.4f},"
                f"offload_rate="
                f"{float(jnp.mean(out.offload.astype(jnp.float32))):.3f}")
        gap = (costs["factored"] - costs["dense"]) / max(costs["dense"], 1e-9)
        rows.append(
            f"learner_parity_{name},0,"
            f"cost_dense={costs['dense'] / horizon:.4f},"
            f"cost_factored={costs['factored'] / horizon:.4f},"
            f"cost_gap_rel={gap:.4f}")
    return rows


def scaling_point(s: int, *, horizon: int, block: int, cfg: HIConfig,
                  engine: str = "sharded") -> Dict[str, float]:
    """One factored + counter-RNG scaling measurement at fleet size `s`."""
    spec = ExecSpec(learner="factored", randomness="counter")
    eng = _engine(engine, cfg, spec)
    src = get_scenario("stationary", spec="synthetic", n_streams=s,
                       horizon=horizon, block=block,
                       key=jax.random.PRNGKey(5), beta=0.3)
    t0 = time.perf_counter()
    _, out = eng.run_source(src, jax.random.PRNGKey(17))
    jax.block_until_ready(out.loss)
    wall = time.perf_counter() - t0
    n = s * horizon
    return {
        "streams": s,
        "horizon": horizon,
        "wall_s": wall,
        "us_per_stream_round": wall / n * 1e6,
        "cost": float(jnp.sum(out.loss)) / n,
        "offload_rate": float(jnp.sum(out.offloads)) / n,
        "weight_bytes_peak": get_learner("factored").weight_bytes(cfg, s),
        "dense_weight_bytes_equiv": get_learner("dense").weight_bytes(cfg, s),
    }


def scaling_rows(quick: bool) -> List[str]:
    """CI-sized smoke sweep (the full 10⁶-stream curve is the CLI's job)."""
    rows = []
    streams: Sequence[int] = (1 << 10, 1 << 12) if quick \
        else (1 << 12, 1 << 14, 1 << 16)
    horizon, block = (32, 8) if quick else (64, 16)
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    for s in streams:
        rec = scaling_point(s, horizon=horizon, block=block, cfg=cfg)
        rows.append(
            f"learner_scaling_s{s},{rec['wall_s'] * 1e6:.0f},"
            f"streams={s},wall_s={rec['wall_s']:.3f},"
            f"us_per_stream_round={rec['us_per_stream_round']:.3f},"
            f"cost={rec['cost']:.4f},offload_rate={rec['offload_rate']:.3f},"
            f"weight_bytes_peak={rec['weight_bytes_peak']}")
    return rows


def run(quick: bool = False, engine: str = "fused") -> List[str]:
    return parity_rows(quick, engine) + scaling_rows(quick)


def scaling_sweep(streams: Sequence[int], horizon: int = 64,
                  block: int = 16) -> Dict[str, object]:
    """The committed scaling artifact: streams vs wall-clock / residency."""
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    points = []
    for s in streams:
        rec = scaling_point(s, horizon=horizon, block=block, cfg=cfg)
        print(f"S={s:>9}: wall_s={rec['wall_s']:.2f} "
              f"us/stream-round={rec['us_per_stream_round']:.3f} "
              f"weights={rec['weight_bytes_peak'] / 2**20:.1f} MiB "
              f"(dense equiv {rec['dense_weight_bytes_equiv'] / 2**20:.1f})")
        points.append(rec)
    return {
        "format": "factored-scaling-v1",
        "note": ("factored learner + counter randomness on the sharded "
                 "engine (stationary synthetic source, chunked run_source); "
                 "weight_bytes_peak is the analytic O(S*G) factored "
                 "residency, dense_weight_bytes_equiv the O(S*G^2) grid a "
                 "dense fleet of the same size would hold. Wall-clock is "
                 "machine-dependent (CPU interpret-free jnp path unless on "
                 "TPU); the shape of the curve, not its level, is the "
                 "claim."),
        "config": {"bits": 4, "eps": 0.05, "eta": 1.0, "horizon": horizon,
                   "block": block, "engine": "sharded",
                   "learner": "factored", "randomness": "counter",
                   "n_devices": jax.device_count(),
                   "backend": jax.default_backend()},
        "points": points,
    }


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scaling", action="store_true",
                    help="run the full scaling sweep (up to 2^20 streams) "
                         "and write results/factored_scaling.json")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "results", "factored_scaling.json"))
    args = ap.parse_args()
    if not args.scaling:
        print("\n".join(run()))
        return 0
    doc = scaling_sweep((1 << 14, 1 << 16, 1 << 18, 1 << 20))
    out = os.path.abspath(args.out)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
