"""Fig. 2: FPR vs FNR and average cost, single- vs two-threshold policies
(BreakHis + Synthetic, δ₁=0.7, δ₋₁=1, β=0.3)."""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.core import HIConfig, offline
from repro.data import dataset_trace


def run(quick: bool = False) -> List[str]:
    rows = []
    cfg = HIConfig(bits=4, delta_fp=0.7, delta_fn=1.0)
    horizon = 2000 if quick else 10_000
    for name in ("breakhis", "synthetic"):
        t0 = time.perf_counter()
        tr = dataset_trace(name, horizon, jax.random.PRNGKey(0), beta=0.3)
        fp, fn, cost = offline.fpr_fnr_cost_surface(cfg, tr.fs, tr.hrs, beta=0.3)
        cost = np.asarray(cost)
        fp, fn = np.asarray(fp), np.asarray(fn)
        # Best two-threshold point.
        best2 = np.unravel_index(np.argmin(cost), cost.shape)
        # Best single-threshold point = symmetric band (G−k, k).
        g = cfg.grid
        singles = [(g - k, k) for k in range(g // 2 + 1, g)] + [(0, 0)]
        best1 = min(singles, key=lambda lu: cost[lu])
        us = (time.perf_counter() - t0) * 1e6
        rows.append(
            f"fig2_{name}_two_threshold,{us:.0f},"
            f"fpr={fp[best2]:.3f};fnr={fn[best2]:.3f};cost={cost[best2]:.4f}")
        rows.append(
            f"fig2_{name}_single_threshold,{us:.0f},"
            f"fpr={fp[best1]:.3f};fnr={fn[best1]:.3f};cost={cost[best1]:.4f}")
        assert cost[best2] <= cost[best1] + 1e-6
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
