"""Fig. 9: average cost vs learning rate η (β=0.4, δ₁=0.7, δ₋₁=1).

Shows the paper's point that the bound-optimal η* (Corollary 1) is not the
empirical minimum, and η = 1 is a good default."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import avg_costs_all_policies
from repro.core import HIConfig
from repro.core.regret import corollary1_params


def run(quick: bool = False, engine: str = "fused") -> List[str]:
    rows = []
    horizon = 2000 if quick else 10_000
    etas = [0.01, 0.1, 1.0, 4.0] if quick else [0.003, 0.01, 0.05, 0.2, 0.5, 1.0, 2.0, 8.0]
    eta_star = corollary1_params(HIConfig(bits=4), horizon)[1]
    etas = sorted(set(etas + [round(eta_star, 4)]))
    for name in (["breakhis"] if quick else ["breakhis", "chest"]):
        for eta in etas:
            t0 = time.perf_counter()
            costs = avg_costs_all_policies(
                name, beta=0.4, horizon=horizon, eta=eta, seeds=2,
                engine=engine)
            us = (time.perf_counter() - t0) * 1e6
            star = " (eta*)" if abs(eta - eta_star) < 1e-3 else ""
            rows.append(f"fig9_{name}_eta{eta:g}{star},{us:.0f},"
                        f"h2t2={costs['h2t2']:.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
