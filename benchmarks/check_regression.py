"""Gate benchmark results against a committed baseline.

    python benchmarks/check_regression.py current.json results/bench_baseline.json
    python benchmarks/check_regression.py current.json results/bench_baseline.json --update

Both files are `benchmarks.run --json` documents. Every numeric metric in
the baseline must be reproduced by the current run within a relative
tolerance (default ±10%, with a small absolute floor so near-zero metrics
don't demand infinite precision). Timing is machine-dependent and never
compared — neither `us_per_call` nor derived metrics named like timings
(`us_*`/`*_us`, `wall_s`, `*speedup*`, `*gflops*` throughputs; see
`is_timing_metric`). Latency percentiles (`p50_*`/`p95_*`/`p99_*`; see
`is_latency_metric`) are likewise informational: the request-plane rows
report them in simulated link time, which is configuration-shaped rather
than behavioral. Memory-footprint metrics (`*_bytes`/`bytes_*`; see
`is_bytes_metric`) are informational too — they change whenever a kernel
legitimately retunes its working set, and the behavioral metrics alongside
them gate the results the bytes buy. Rates with a zero
baseline (e.g. `deny_rate` below capacity) are still gated, via the
absolute floor. Benchmarks present in the current run but
missing from the baseline are reported informationally — commit a refreshed
baseline (`--update`) to start tracking them.

Stdlib-only on purpose: the gate can run without jax installed.
"""

from __future__ import annotations

import argparse
import json
import math
import shutil
import sys
from typing import Dict, List

DEFAULT_TOLERANCE = 0.10
ABS_FLOOR = 0.02
# Discrete event counts (how often the shift detector fired) flip by whole
# units on ulp-level numeric drift, so a ±10% float gate on them is pure
# noise; the cost/rate metrics gate the behavior they produce. The autotune
# rows' launch-geometry winners (stream_block/time_block) are derived purely
# from machine-dependent timings and never affect results, so they are
# advisory too.
SKIP_METRICS = frozenset({"restarts", "stream_block", "time_block"})


def is_timing_metric(key: str) -> bool:
    """Machine-dependent timing metrics, never gated (like `us_per_call`).

    Benchmarks name them with a `us_`/`_us` microsecond affix, a `wall_s`
    second counter, a `speedup` ratio of two timings, or a `gflops`
    throughput (flops over a measured time) — so kernel/serving latency
    rows can live in the tracked baseline while only their deterministic
    cost metrics gate.
    """
    return (
        key.endswith("_us")
        or key.startswith("us_")
        or key == "wall_s"
        or "speedup" in key
        or "gflops" in key
    )


def is_latency_metric(key: str) -> bool:
    """Streaming latency percentiles, never gated.

    The request-plane benchmark exports `p50_*`/`p95_*`/`p99_*` quantiles of
    simulated request latency; they shift with any retuning of the link or
    deadline configuration without implying a behavioral regression, so the
    gate tracks them informationally and gates the cost/rate metrics
    alongside them instead.
    """
    return key.startswith(("p50_", "p95_", "p99_"))


def is_bytes_metric(key: str) -> bool:
    """Memory-footprint metrics, never gated.

    The kernel benchmarks export analytic peak residencies (e.g. the
    long-horizon rows' `rand_bytes_peak`: O(S·T) materialized randomness
    under pre_draw vs O(S·time_block) under counter draws). They move with
    any legitimate retuning of block sizes or horizons, so the gate tracks
    them informationally and gates the behavioral metrics instead.
    """
    return (
        key.endswith("_bytes")
        or key.startswith("bytes_")
        or "_bytes_" in key
    )


def compare(
    current: Dict,
    baseline: Dict,
    tolerance: float = DEFAULT_TOLERANCE,
    abs_floor: float = ABS_FLOOR,
) -> List[str]:
    """Return a list of human-readable failures (empty = gate passes)."""
    failures = []
    cur = current.get("benchmarks", {})
    base = baseline.get("benchmarks", {})
    for name, brec in sorted(base.items()):
        if brec.get("error"):
            failures.append(
                f"{name}: baseline record is errored — refresh the baseline"
            )
            continue
        crec = cur.get(name)
        if crec is None:
            failures.append(f"{name}: missing from current results")
            continue
        if crec.get("error"):
            failures.append(f"{name}: current run errored")
            continue
        for key, bval in sorted(brec.get("metrics", {}).items()):
            if (key in SKIP_METRICS or is_timing_metric(key)
                    or is_latency_metric(key) or is_bytes_metric(key)):
                continue
            if not isinstance(bval, (int, float)) or isinstance(bval, bool):
                continue
            cval = crec.get("metrics", {}).get(key)
            if not isinstance(cval, (int, float)) or isinstance(cval, bool):
                failures.append(f"{name}.{key}: missing from current results")
                continue
            limit = max(tolerance * abs(bval), abs_floor)
            if not math.isfinite(cval) or abs(cval - bval) > limit:
                failures.append(
                    f"{name}.{key}: {cval:.6g} deviates from baseline "
                    f"{bval:.6g} by more than ±{limit:.6g}"
                )
    return failures


def untracked(current: Dict, baseline: Dict) -> List[str]:
    """Benchmark names in the current run the baseline doesn't cover."""
    base = baseline.get("benchmarks", {})
    return sorted(n for n in current.get("benchmarks", {}) if n not in base)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh `benchmarks.run --json` output")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=DEFAULT_TOLERANCE,
        help=f"relative tolerance per metric (default {DEFAULT_TOLERANCE})",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="overwrite the baseline with the current results and exit 0",
    )
    args = ap.parse_args()

    with open(args.current) as fh:
        current = json.load(fh)

    if args.update:
        errored = sorted(
            n
            for n, rec in current.get("benchmarks", {}).items()
            if rec.get("error")
        )
        if errored:
            print(
                "refusing to update the baseline from an errored run: "
                + ", ".join(errored)
            )
            return 1
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline updated: {args.baseline}")
        return 0

    with open(args.baseline) as fh:
        baseline = json.load(fh)

    failures = compare(current, baseline, tolerance=args.tolerance)
    extra = untracked(current, baseline)
    if extra:
        print(
            "note: benchmarks not in the baseline (run with --update to "
            "track): " + ", ".join(extra)
        )
    if failures:
        print(f"REGRESSION GATE FAILED ({len(failures)} deviation(s)):")
        for f in failures:
            print(f"  {f}")
        return 1
    n = len(baseline.get("benchmarks", {}))
    print(f"regression gate passed: {n} benchmark(s) within "
          f"±{args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
