"""Theorem 2 / Corollary 1: empirical regret growth vs horizon + fitted slope
(theory: T^{2/3} ⇒ slope ≈ 0.67; sublinear ⇔ slope < 1)."""
from __future__ import annotations

import time
from typing import List

import jax

from benchmarks.common import engine_cached
from repro.core import HIConfig
from repro.core.regret import empirical_regret, regret_slope, theorem2_bound
from repro.data import dataset_trace


def run(quick: bool = False, engine: str = "fused") -> List[str]:
    rows = []
    horizons = [500, 2000] if quick else [500, 2000, 8000, 32000]
    regrets = []
    for t in horizons:
        cfg = HIConfig(bits=4).with_horizon(t)
        tr = dataset_trace("breakhis", t, jax.random.PRNGKey(0), beta=0.3)
        t0 = time.perf_counter()
        r = empirical_regret(cfg, tr.fs, tr.hrs, tr.betas, jax.random.PRNGKey(1),
                             n_seeds=2 if quick else 6,
                             run=engine_cached(engine, cfg).run)
        us = (time.perf_counter() - t0) * 1e6
        bound = theorem2_bound(cfg, t)
        regrets.append(max(r["regret"], 1e-6))
        rows.append(f"regret_T{t},{us:.0f},"
                    f"empirical={r['regret']:.1f};bound={bound:.1f};"
                    f"algo={r['algo_loss']:.1f};best_fixed={r['best_fixed_loss']:.1f};"
                    f"engine={engine}")
    slope = regret_slope(horizons, regrets)
    rows.append(f"regret_slope,0,slope={slope:.3f};sublinear={slope < 1.0}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
