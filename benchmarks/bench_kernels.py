"""Kernel microbenchmarks (CPU: interpret-mode correctness path; timings are
for the jnp reference oracles, which are the XLA fallbacks on TPU too).

The hedge-fleet section times the full H2T2 simulation engine under every
registered `PolicyEngine` ("reference" vmapped scan, "fused" kernel-backed
scan — including the time-blocked multi-round variant — and "sharded" when
more than one device is visible) so the perf trajectory tracks the paths
serving actually runs, in both randomness modes ("pre_draw" key-tree draws
and "counter" in-kernel draws). The long-horizon section runs the fused
engine at T≈10⁶ in both modes and reports `rand_bytes_peak` — the analytic
peak residency of the (ψ, ζ) randomness: O(S·T) materialized for pre_draw
vs O(S·time_block) for counter. The serving-split section times
`engine.decide` / `engine.feedback` — the exact two phases
`HIServer.serve_slot` runs — per engine. All timing metrics use `*_us`
keys, which the regression gate never compares (`check_regression.py`
timing policy); byte metrics are likewise informational.

`run(autotune=True)` (the `benchmarks.run --only kernels --autotune` path)
additionally sweeps the hedge kernel's (stream_block × time_block) launch
geometry and persists the per-(G, S, platform) winners to
`results/hedge_autotune.json` — the cache `repro.kernels.hedge.ops`
consults for its launch defaults."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.core import ExecSpec, HIConfig
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hedge import autotune as hedge_autotune
from repro.kernels.ssd.ref import ssd_ref
from repro.serving.policy_engine import get_engine


def _hedge_fleet_rows(quick: bool) -> List[str]:
    rows = []
    shapes = [(4, 16, 256)] if quick else [(4, 64, 1024), (5, 128, 1024)]
    for bits, s, t in shapes:                            # (bits, streams, rounds)
        cfg = HIConfig(bits=bits, eps=0.05, eta=1.0)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        fs = jax.random.uniform(ks[0], (s, t))
        hrs = jax.random.bernoulli(ks[1], 0.5, (s, t)).astype(jnp.int32)
        betas = jnp.full((s, t), 0.3)
        key = jax.random.PRNGKey(1)
        engines = {
            "reference": get_engine("reference", cfg),
            "fused": get_engine("fused", cfg),
            "fused_tb8": get_engine("fused", cfg, spec=ExecSpec(time_block=8)),
            "fused_counter": get_engine(
                "fused", cfg, spec=ExecSpec(randomness="counter")),
            "fused_tb8_counter": get_engine(
                "fused", cfg,
                spec=ExecSpec(time_block=8, randomness="counter")),
        }
        if len(jax.devices()) > 1:
            engines["sharded"] = get_engine("sharded", cfg)
        for name, eng in engines.items():
            fn = jax.jit(lambda k, e=eng: e.run(fs, hrs, betas, k)[1].loss)
            us = timed(fn, key, reps=3)
            rows.append(
                f"hedge_fleet_G{cfg.grid}_S{s}_T{t}_{name},{us:.0f},"
                f"us_per_round={us / t:.2f};engine={name}")
    return rows


def _long_horizon_rows(quick: bool) -> List[str]:
    """Randomness residency at serving horizons: pre_draw materializes the
    full (S, T) (ψ, ζ) tensor up front, counter mode never holds more than
    the running (S, time_block) working set. `rand_bytes_peak` is that peak
    analytically (8 bytes per draw: ψ f32 + ζ widened to i32 as the kernel
    consumes it) — byte metrics are informational in the regression gate,
    like the `*_us` timings alongside them."""
    rows = []
    s, tb = 4, 256
    t = 51_200 if quick else 1_048_576
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    fs = jax.random.uniform(ks[0], (s, t))
    hrs = jax.random.bernoulli(ks[1], 0.5, (s, t)).astype(jnp.int32)
    betas = jnp.full((s, t), 0.3)
    key = jax.random.PRNGKey(1)
    for mode in ("pre_draw", "counter"):
        eng = get_engine(
            "fused", cfg, spec=ExecSpec(time_block=tb, randomness=mode))
        fn = jax.jit(lambda k, e=eng: e.run(fs, hrs, betas, k)[1].loss)
        us = timed(fn, key, reps=1)
        draws = s * t if mode == "pre_draw" else s * tb
        rows.append(
            f"hedge_longhorizon_S{s}_T{t}_{mode},{us:.0f},"
            f"us_per_round={us / t:.3f};rand_bytes_peak={draws * 8};"
            f"randomness={mode}")
    return rows


def _serving_split_rows(quick: bool) -> List[str]:
    """Per-phase serving timings: decide / feedback on the production path
    (kernel on TPU, jnp elsewhere) for each engine the HIServer can drive."""
    rows = []
    shapes = [(4, 16)] if quick else [(4, 64), (4, 256)]
    for bits, s in shapes:
        cfg = HIConfig(bits=bits, eps=0.05, eta=1.0)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        fs = jax.random.uniform(ks[0], (s,))
        hrs = jax.random.bernoulli(ks[1], 0.5, (s,)).astype(jnp.int32)
        betas = jnp.full((s,), 0.3)
        keys = jax.random.split(ks[2], s)
        for name in ("reference", "fused", "adaptive"):
            eng = get_engine(name, cfg)
            state = eng.init(s)
            dec = eng.decide(state, fs, keys)
            us_d = timed(lambda keys_: eng.decide(state, fs, keys_), keys)
            us_f = timed(
                lambda hrs_: eng.feedback(state, dec, hrs_, betas)[0].log_w,
                hrs)
            rows.append(
                f"hedge_serving_G{cfg.grid}_S{s}_{name},{us_d + us_f:.0f},"
                f"decide_us={us_d:.1f};feedback_us={us_f:.1f};engine={name}")
    return rows


def _autotune_rows(quick: bool) -> List[str]:
    """Sweep (SB × TB) and persist the winners (see kernels.hedge.autotune)."""
    entries = hedge_autotune.sweep(
        grids=(8,) if quick else (8, 16),
        streams=(8,) if quick else (16, 64),
        stream_blocks=(1, 4, 8) if quick else (1, 2, 4, 8, 16),
        time_blocks=(1, 8) if quick else (1, 2, 4, 8, 16),
        reps=2 if quick else 3)
    rows = hedge_autotune.rows(entries)
    rows.append(f"hedge_autotune_cache,0,path={hedge_autotune.cache_path()}")
    return rows


def run(quick: bool = False, autotune: bool = False) -> List[str]:
    rows = _hedge_fleet_rows(quick)
    rows += _long_horizon_rows(quick)
    rows += _serving_split_rows(quick)
    if autotune:
        rows += _autotune_rows(quick)
    key = jax.random.PRNGKey(0)
    # Attention oracle at serving-ish shapes.
    for (b, s, h, hkv, d) in ([(1, 256, 4, 2, 64)] if quick
                              else [(1, 256, 4, 2, 64), (2, 1024, 8, 2, 64)]):
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.bfloat16)
        f = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
        us = timed(f, q, k, v)
        flops = 4 * b * s * s * h * d / 2
        rows.append(f"attn_ref_b{b}_s{s}_h{h},{us:.0f},gflops_eff={flops/us/1e3:.1f}")
    # SSD oracle.
    for (b, s, h, p, n) in ([(1, 512, 4, 32, 16)] if quick
                            else [(1, 512, 4, 32, 16), (2, 2048, 8, 64, 64)]):
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.3
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        bb = jax.random.normal(ks[3], (b, s, 1, n)) * 0.3
        cc = jax.random.normal(ks[4], (b, s, 1, n)) * 0.3
        f = jax.jit(lambda *args: ssd_ref(*args, chunk=128)[0])
        us = timed(f, x, dt, a, bb, cc)
        rows.append(f"ssd_ref_b{b}_s{s}_h{h},{us:.0f},chunk=128")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
