"""Kernel microbenchmarks (CPU: interpret-mode correctness path; timings are
for the jnp reference oracles, which are the XLA fallbacks on TPU too)."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ref import ssd_ref


def run(quick: bool = False) -> List[str]:
    rows = []
    key = jax.random.PRNGKey(0)
    # Attention oracle at serving-ish shapes.
    for (b, s, h, hkv, d) in ([(1, 256, 4, 2, 64)] if quick
                              else [(1, 256, 4, 2, 64), (2, 1024, 8, 2, 64)]):
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.bfloat16)
        f = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
        us = timed(f, q, k, v)
        flops = 4 * b * s * s * h * d / 2
        rows.append(f"attn_ref_b{b}_s{s}_h{h},{us:.0f},gflops_eff={flops/us/1e3:.1f}")
    # SSD oracle.
    for (b, s, h, p, n) in ([(1, 512, 4, 32, 16)] if quick
                            else [(1, 512, 4, 32, 16), (2, 2048, 8, 64, 64)]):
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.3
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        bb = jax.random.normal(ks[3], (b, s, 1, n)) * 0.3
        cc = jax.random.normal(ks[4], (b, s, 1, n)) * 0.3
        f = jax.jit(lambda *args: ssd_ref(*args, chunk=128)[0])
        us = timed(f, x, dt, a, bb, cc)
        rows.append(f"ssd_ref_b{b}_s{s}_h{h},{us:.0f},chunk=128")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
