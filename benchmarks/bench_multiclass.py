"""BEYOND-PAPER (§6 open problem): online multiclass HI via a learned risk
threshold τ — cost vs β on a synthetic 3-class stream, vs naive policies and
the offline-best fixed τ (which contains Theorem 3's rule when calibrated)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core import HIConfig
from repro.core.multiclass import mc_no_offload_loss, mc_offline_best, mc_run_stream

COST = jnp.asarray([[0.0, 0.7, 0.9],
                    [1.0, 0.0, 0.6],
                    [0.8, 0.5, 0.0]])


def run(quick: bool = False) -> List[str]:
    rows = []
    t = 2000 if quick else 10_000
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    key = jax.random.PRNGKey(0)
    ky, kn = jax.random.split(key)
    y = jax.random.randint(ky, (t,), 0, 3)
    logits = 1.4 * jax.nn.one_hot(y, 3) + jax.random.normal(kn, (t, 3))
    fs = jax.nn.softmax(logits, axis=-1)
    for beta in ([0.2, 0.4] if quick else [0.1, 0.2, 0.3, 0.4, 0.5]):
        betas = jnp.full((t,), beta)
        t0 = time.perf_counter()
        _, out = mc_run_stream(cfg, fs, COST, betas, y, jax.random.PRNGKey(1))
        us = (time.perf_counter() - t0) * 1e6
        algo = float(jnp.sum(out.loss)) / t
        no = float(mc_no_offload_loss(fs, COST, y)) / t
        best = float(mc_offline_best(cfg, fs, COST, betas, y)) / t
        rows.append(f"multiclass_beta{beta:g},{us:.0f},"
                    f"mc_h2t2={algo:.4f};no_offload={no:.4f};"
                    f"full_offload={beta:.4f};offline_tau={best:.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
