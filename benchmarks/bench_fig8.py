"""Fig. 8: average cost vs cost-asymmetry ratio δ₁/δ₋₁ ∈ (1/10, 10), β=0.4.

The paper's claim: the two-threshold gain over single-threshold GROWS with
asymmetry and vanishes near δ₁/δ₋₁ = 1."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import avg_costs_all_policies


def run(quick: bool = False, engine: str = "fused") -> List[str]:
    rows = []
    ratios = [0.1, 0.5, 1.0, 2.0, 10.0] if quick else \
        [0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0]
    horizon = 2000 if quick else 10_000
    for name in (["breakhis"] if quick else ["breakhis", "chest", "breach"]):
        for r in ratios:
            # Normalize so max(δ₁, δ₋₁) = 1 (paper's normalization).
            dfp, dfn = (1.0, 1.0 / r) if r > 1 else (r, 1.0)
            t0 = time.perf_counter()
            costs = avg_costs_all_policies(
                name, beta=0.4, horizon=horizon, delta_fp=dfp, delta_fn=dfn,
                seeds=2, engine=engine)
            us = (time.perf_counter() - t0) * 1e6
            rows.append(
                f"fig8_{name}_ratio{r:g},{us:.0f},"
                f"h2t2={costs['h2t2']:.4f};hi_single={costs['hi_single']:.4f};"
                f"offline_two={costs['offline_two']:.4f};"
                f"offline_single={costs['offline_single']:.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
