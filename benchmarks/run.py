"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig4,...]
                                            [--json out.json]

Prints ``name,us_per_call,derived`` CSV rows (stdout) — one row per measured
configuration, matching the paper's artifacts:

    fig2    FPR/FNR/cost of single- vs two-threshold optima
    fig4    avg cost vs β, six policies × nine datasets (+ Fig. 6/7 via flags)
    fig8    avg cost vs asymmetry δ₁/δ₋₁
    fig9    avg cost vs learning rate η
    fig10   cost + runtime vs quantization bits (+ hedge-kernel microbench)
    regret  Theorem-2 empirical regret growth + slope
    kernels attention/SSD oracle microbenchmarks
    drift   BEYOND-PAPER: discounted-hedge adaptation under mid-stream shift
    request_plane BEYOND-PAPER: async request plane offered-load sweep
              (ingress → micro-batch → decide → compact → feedback with
              live-β estimation, virtual-clock deterministic)
    multiclass BEYOND-PAPER: online K-class HI via learned risk threshold (paper §6)
    scenarios BEYOND-PAPER: cost/regret across the ScenarioSource registry
              (chunked engine runs; --scenario restricts the sweep)
    adaptive BEYOND-PAPER: fixed vs shift-aware adaptive vs oracle-restart
              policies under drift / β dynamics / RDL noise
    learners BEYOND-PAPER: learner-registry rows — factored vs dense H2T2
              regret parity on manuscript workloads, plus the factored
              + counter-RNG million-stream scaling smoke
    faults  BEYOND-PAPER: degradation-ladder sweep under injected link
              faults (drop × outage × retry-budget grid through
              FaultyLink + ResilientSender, virtual-clock deterministic)

``--list`` prints every registered policy engine, workload scenario, and
hedge learner with its one-line description, then exits.

``--json out.json`` additionally writes the rows as machine-readable
per-benchmark records (see `parse_row`); `benchmarks/check_regression.py`
gates CI on such a file against `results/bench_baseline.json`.
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import traceback
from typing import Dict, Tuple

from benchmarks import (
    bench_adaptive,
    bench_drift,
    bench_faults,
    bench_multiclass,
    bench_fig2,
    bench_fig4,
    bench_fig8,
    bench_fig9,
    bench_fig10,
    bench_kernels,
    bench_learners,
    bench_regret,
    bench_request_plane,
    bench_scenarios,
)

MODULES = {
    "fig2": bench_fig2,
    "fig4": bench_fig4,
    "fig8": bench_fig8,
    "fig9": bench_fig9,
    "fig10": bench_fig10,
    "regret": bench_regret,
    "kernels": bench_kernels,
    "drift": bench_drift,
    "multiclass": bench_multiclass,
    "scenarios": bench_scenarios,
    "adaptive": bench_adaptive,
    "request_plane": bench_request_plane,
    "learners": bench_learners,
    "faults": bench_faults,
}


def parse_row(row: str) -> Tuple[str, Dict[str, object]]:
    """Parse one ``name,us_per_call,derived`` row into (name, record).

    The derived field is a `,`- or `;`-separated list of ``key=value``
    items; numeric values parse to floats, anything else stays a string
    (regression gating only compares the numeric ones). Malformed or ERROR
    rows yield a record with ``"error": True``.
    """
    parts = row.split(",")
    name = parts[0]
    record: Dict[str, object] = {"metrics": {}}
    try:
        record["us_per_call"] = float(parts[1])
    except (IndexError, ValueError):
        record["error"] = True
        return name, record
    derived = ",".join(parts[2:])
    if derived == "ERROR":
        record["error"] = True
        return name, record
    for item in derived.replace(";", ",").split(","):
        if "=" not in item:
            continue
        k, v = item.split("=", 1)
        try:
            record["metrics"][k] = float(v)
        except ValueError:
            record["metrics"][k] = v
    return name, record


def rows_to_report(rows, meta: Dict[str, object]) -> Dict[str, object]:
    """Assemble parsed rows into the --json / baseline document shape."""
    benchmarks: Dict[str, object] = {}
    for row in rows:
        name, record = parse_row(row)
        benchmarks[name] = record
    return {"meta": meta, "benchmarks": benchmarks}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced horizons/sweeps (CI-sized)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of " + ",".join(MODULES))
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write per-benchmark metrics as JSON "
                         "(the regression-gate input)")
    from repro.data.scenarios import available_scenarios
    from repro.serving.policy_engine import available_engines

    ap.add_argument("--list", action="store_true",
                    help="list registered policy engines, scenarios, and "
                         "learners with descriptions, then exit")
    ap.add_argument("--engine", default="fused",
                    choices=available_engines(),
                    help="H2T2 PolicyEngine for modules that run the fleet")
    ap.add_argument("--scenario", default="",
                    help="comma-separated ScenarioSource subset for "
                         "scenario-aware modules; choose from "
                         + ",".join(available_scenarios()))
    ap.add_argument("--autotune", action="store_true",
                    help="with `--only kernels`: sweep the hedge kernel's "
                         "(stream_block × time_block) launch geometry and "
                         "persist the per-(G, S, platform) winners to "
                         "results/hedge_autotune.json (consulted by "
                         "repro.kernels.hedge.ops defaults)")
    args = ap.parse_args()
    if args.list:
        from repro.core.learners import list_learners
        from repro.data.scenarios import list_scenarios
        from repro.serving.policy_engine import list_engines

        for kind, entries in (("engines", list_engines()),
                              ("scenarios", list_scenarios()),
                              ("learners", list_learners())):
            print(f"{kind}:")
            for name, desc in entries:
                print(f"  {name:14s} {desc}")
        return 0
    names = [n for n in args.only.split(",") if n] or list(MODULES)
    print("name,us_per_call,derived")
    all_rows = []
    failed = False
    for name in names:
        kwargs = {"quick": args.quick}
        params = inspect.signature(MODULES[name].run).parameters
        if "engine" in params:
            kwargs["engine"] = args.engine
        if "scenario" in params:
            kwargs["scenario"] = args.scenario
        if "autotune" in params:
            kwargs["autotune"] = args.autotune
        try:
            for row in MODULES[name].run(**kwargs):
                print(row)
                all_rows.append(row)
                sys.stdout.flush()
        except Exception:  # noqa: BLE001
            failed = True
            print(f"{name},0,ERROR")
            all_rows.append(f"{name},0,ERROR")
            traceback.print_exc()
    if args.json:
        report = rows_to_report(all_rows, meta={
            "quick": args.quick, "engine": args.engine, "only": names,
        })
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
