"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig4,...]

Prints ``name,us_per_call,derived`` CSV rows (stdout) — one row per measured
configuration, matching the paper's artifacts:

    fig2    FPR/FNR/cost of single- vs two-threshold optima
    fig4    avg cost vs β, six policies × nine datasets (+ Fig. 6/7 via flags)
    fig8    avg cost vs asymmetry δ₁/δ₋₁
    fig9    avg cost vs learning rate η
    fig10   cost + runtime vs quantization bits (+ hedge-kernel microbench)
    regret  Theorem-2 empirical regret growth + slope
    kernels attention/SSD oracle microbenchmarks
    drift   BEYOND-PAPER: discounted-hedge adaptation under mid-stream shift
    multiclass BEYOND-PAPER: online K-class HI via learned risk threshold (paper §6)
    scenarios BEYOND-PAPER: cost/regret across the ScenarioSource registry
              (chunked engine runs; --scenario restricts the sweep)
"""
from __future__ import annotations

import argparse
import inspect
import sys
import traceback

from benchmarks import (
    bench_drift,
    bench_multiclass,
    bench_fig2,
    bench_fig4,
    bench_fig8,
    bench_fig9,
    bench_fig10,
    bench_kernels,
    bench_regret,
    bench_scenarios,
)

MODULES = {
    "fig2": bench_fig2,
    "fig4": bench_fig4,
    "fig8": bench_fig8,
    "fig9": bench_fig9,
    "fig10": bench_fig10,
    "regret": bench_regret,
    "kernels": bench_kernels,
    "drift": bench_drift,
    "multiclass": bench_multiclass,
    "scenarios": bench_scenarios,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced horizons/sweeps (CI-sized)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of " + ",".join(MODULES))
    from repro.data.scenarios import available_scenarios
    from repro.serving.policy_engine import available_engines

    ap.add_argument("--engine", default="fused",
                    choices=available_engines(),
                    help="H2T2 PolicyEngine for modules that run the fleet")
    ap.add_argument("--scenario", default="",
                    help="comma-separated ScenarioSource subset for "
                         "scenario-aware modules; choose from "
                         + ",".join(available_scenarios()))
    args = ap.parse_args()
    names = [n for n in args.only.split(",") if n] or list(MODULES)
    print("name,us_per_call,derived")
    failed = False
    for name in names:
        kwargs = {"quick": args.quick}
        params = inspect.signature(MODULES[name].run).parameters
        if "engine" in params:
            kwargs["engine"] = args.engine
        if "scenario" in params:
            kwargs["scenario"] = args.scenario
        try:
            for row in MODULES[name].run(**kwargs):
                print(row)
                sys.stdout.flush()
        except Exception:  # noqa: BLE001
            failed = True
            print(f"{name},0,ERROR")
            traceback.print_exc()
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
