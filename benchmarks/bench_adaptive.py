"""BEYOND-PAPER: shift-aware adaptive serving policies.

Three arms run over the same workload with identical policy randomness
(`source_slot_keys`), so the comparison is paired sample-for-sample:

  fixed    — the chosen engine with the paper's fixed (η, decay) schedule.
  adaptive — the `adaptive` PolicyEngine: per-stream CUSUM shift detection
             over the quantized-confidence stream, schedule boost, and a
             weight restart on confirmed shift (`--engine` is ignored for
             this arm; the detector composes with the reference round).
  oracle   — fixed schedule, but the expert weights are re-initialized
             (`fleet_restart`) exactly at the true shift slots the scenario
             was built with; the unbeatable restart baseline. On scenarios
             with no step shift it has no restart slots and reproduces the
             fixed arm.

Per scenario the rows report observed cost, ground-truth cost, offload
rate, post-shift ground-truth cost (second half of the horizon), and the
restart count, e.g. how often the detector actually fired.
"""

from __future__ import annotations

import time
from typing import List, Sequence

import jax
import jax.numpy as jnp

from benchmarks.common import engine_cached
from repro.core import HIConfig
from repro.core.policy import (
    draw_psi_zeta,
    fleet_init,
    fleet_restart,
    fleet_step_fused,
    source_slot_keys,
    true_loss_fleet,
)
from repro.data.scenarios import get_scenario
from repro.serving import HIServer, HIServerConfig, get_engine

POLICY_KEY = 11


def oracle_restart_run(cfg: HIConfig, source, key, restart_slots: Sequence[int]):
    """Run the fused fleet step over `source` with oracle weight restarts.

    The trace is materialized once and scanned segment-by-segment;
    `fleet_restart` re-initializes every stream's expert weights at each
    slot in `restart_slots`. Policy keys follow `source_slot_keys`, so with
    no restart slots this reproduces the chunked `run_source` runs
    decision-for-decision. Returns per-slot (S, T) arrays
    (loss, true_loss, offload).
    """
    tr = source.materialize()
    s, t = tr.fs.shape
    state = fleet_init(cfg, s)
    bounds = [0, *sorted(int(r) for r in restart_slots), t]

    @jax.jit
    def seg(state, fs, hrs, ys, betas, t0):
        ts = t0 + jnp.arange(fs.shape[1], dtype=jnp.int32)
        tp = lambda a: jnp.swapaxes(a, 0, 1)

        def body(st, xs):
            f, hr, y, beta, ti = xs
            psi, zeta = draw_psi_zeta(source_slot_keys(key, ti, s), cfg.eps)
            st, out = fleet_step_fused(cfg, st, f, psi, zeta, hr, beta)
            return st, (out.loss, true_loss_fleet(cfg, out, y, beta), out.offload)

        state, per = jax.lax.scan(
            body, state, (tp(fs), tp(hrs), tp(ys), tp(betas), ts)
        )
        loss, true, off = per
        return state, (tp(loss), tp(true), tp(off))

    parts = []
    for a, b in zip(bounds, bounds[1:]):
        if a > 0:
            state = fleet_restart(cfg, state, jnp.ones((s,), bool))
        sl = lambda arr: arr[:, a:b]
        state, per = seg(
            state, sl(tr.fs), sl(tr.hrs), sl(tr.ys), sl(tr.betas), jnp.int32(a)
        )
        parts.append(per)
    cat = lambda i: jnp.concatenate([p[i] for p in parts], axis=1)
    return cat(0), cat(1), cat(2)


def _scenarios(quick: bool):
    horizon = 4000 if quick else 20_000
    block = 500 if quick else 1000
    n_streams = 4 if quick else 8
    half = horizon // 2
    mk = lambda name, **kw: (
        lambda: get_scenario(
            name,
            n_streams=n_streams,
            horizon=horizon,
            block=block,
            key=jax.random.PRNGKey(0),
            beta=0.3,
            **kw,
        )
    )
    return horizon, n_streams, {
        # Mild shift: the stale experts stay serviceable, so this measures
        # the adaptive layer's overhead when restarting barely pays.
        "drift_mild": (
            mk("piecewise", segments=((0, "breakhis"), (half, "breach"))),
            (half,),
        ),
        # OOD shift (paper Table 3's xract mismatch): stale experts are
        # badly wrong and restarts dominate.
        "drift_ood": (
            mk("piecewise", segments=((0, "breakhis"), (half, "xract"))),
            (half,),
        ),
        # No step shift: these measure false-restart overhead under network
        # -cost dynamics and remote-label noise.
        "beta_process": (mk("beta_process"), ()),
        "noisy_rdl": (mk("noisy_rdl", rdl_fn=0.3, rdl_fp=0.3), ()),
    }


def _serving_rows(quick: bool) -> List[str]:
    """Fused-vs-reference `HIServer.run_source` serving cost + speedup.

    Three arms serve the same OOD-drift workload end-to-end through the
    HIServer (double-buffered decide/compact/feedback): the paper-shaped
    reference engine, the fused engine (kernel path on TPU, batched jnp
    elsewhere), and the fused engine with `time_block=8` multi-round
    serving. All three make identical decisions, so the cost metrics are
    arm-independent (and CI-gated); `speedup_vs_reference` and `*_us` are
    timing metrics the gate never compares.
    """
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    horizon = 2000 if quick else 10_000
    # Must divide into time_block=8 chains or the fused_tb8 arm silently
    # falls back to the slot path (`rounds_eligible`, asserted below).
    block = 400 if quick else 1000
    n_streams = 4 if quick else 8
    half = horizon // 2
    mk = lambda: get_scenario(
        "piecewise",
        n_streams=n_streams,
        horizon=horizon,
        block=block,
        key=jax.random.PRNGKey(0),
        beta=0.3,
        segments=((0, "breakhis"), (half, "xract")),
    )
    key = jax.random.PRNGKey(POLICY_KEY)
    dummy = lambda tokens: tokens
    arms = (
        ("reference", dict(engine="reference")),
        ("fused", dict(engine="fused")),
        ("fused_tb8", dict(engine="fused", time_block=8)),
    )
    rows, ref_us = [], None
    for arm, opts in arms:
        server = HIServer(
            HIServerConfig(n_streams=n_streams, hi=cfg, **opts), dummy, dummy
        )
        if arm == "fused_tb8" and not server.rounds_eligible(mk()):
            # A bare assert would vanish under -O and let the row silently
            # time the slot path while claiming the multi-round kernel.
            raise ValueError(
                "fused_tb8 arm fell back to the slot path — block/horizon "
                "no longer divide time_block=8"
            )
        server.run_source(mk(), key)  # warm the jit caches
        t0 = time.perf_counter()
        _, summary = server.run_source(mk(), key)
        us = (time.perf_counter() - t0) * 1e6
        ref_us = us if ref_us is None else ref_us
        # Named serving_* (not adaptive_*): these arms benchmark the fixed-
        # schedule HIServer engines, not the adaptive policy.
        rows.append(
            f"serving_{arm},{us:.0f},"
            f"cost={summary['avg_offload_cost']:.4f},"
            f"true_cost={summary['avg_true_cost']:.4f},"
            f"offload_rate={summary['offload_rate']:.3f},"
            f"rdl_savings={summary['rdl_savings']:.3f},"
            f"speedup_vs_reference={ref_us / us:.2f}"
        )
    return rows


def run(quick: bool = False, engine: str = "fused", scenario: str = "") -> List[str]:
    rows = []
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    horizon, n_streams, scenarios = _scenarios(quick)
    names = [n for n in scenario.split(",") if n] or list(scenarios)
    key = jax.random.PRNGKey(POLICY_KEY)

    for name in names:
        maker, restart_slots = scenarios[name]
        n = n_streams * horizon

        def report(arm, us, cost, true_cost, offloads, post_true, restarts):
            rows.append(
                f"adaptive_{name}_{arm},{us:.0f},"
                f"cost={cost / n:.4f},true_cost={true_cost / n:.4f},"
                f"offload_rate={offloads / n:.3f},"
                f"post_true_cost={post_true / (n / 2):.4f},"
                f"restarts={restarts}"
            )

        for arm in ("fixed", "adaptive"):
            eng = (
                get_engine("adaptive", cfg)
                if arm == "adaptive"
                else engine_cached(engine, cfg)
            )
            src = maker()
            t0 = time.perf_counter()
            state, out = eng.run_source(src, key)
            jax.block_until_ready(out.loss)
            us = (time.perf_counter() - t0) * 1e6
            half_blocks = out.loss.shape[1] // 2
            restarts = (
                int(jnp.sum(state.shift.n_alarms)) if arm == "adaptive" else 0
            )
            report(
                arm,
                us,
                float(jnp.sum(out.loss)),
                float(jnp.sum(out.true_loss)),
                float(jnp.sum(out.offloads)),
                float(jnp.sum(out.true_loss[:, half_blocks:])),
                restarts,
            )

        t0 = time.perf_counter()
        loss, true, off = oracle_restart_run(cfg, maker(), key, restart_slots)
        jax.block_until_ready(loss)
        us = (time.perf_counter() - t0) * 1e6
        report(
            "oracle",
            us,
            float(jnp.sum(loss)),
            float(jnp.sum(true)),
            float(jnp.sum(off)),
            float(jnp.sum(true[:, horizon // 2 :])),
            len(restart_slots) * n_streams,
        )
    if not scenario:  # full-module runs only, like the gate
        rows += _serving_rows(quick)
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
