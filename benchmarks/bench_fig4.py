"""Fig. 4 / Fig. 6 / Fig. 7: average cost vs β for the six §5 policies on
every dataset (manuscript + appendix). --delta1 0.25 reproduces Fig. 7."""
from __future__ import annotations

import argparse
import time
from typing import List

from benchmarks.common import APPENDIX_DATASETS, MANUSCRIPT_DATASETS, avg_costs_all_policies

POLICIES = ["no_offload", "full_offload", "hi_single", "offline_single",
            "offline_two", "h2t2"]


def run(quick: bool = False, delta_fp: float = 0.7,
        datasets=None, betas=None, engine: str = "fused") -> List[str]:
    rows = []
    datasets = datasets or (MANUSCRIPT_DATASETS if quick
                            else MANUSCRIPT_DATASETS + APPENDIX_DATASETS)
    betas = betas or ([0.2, 0.4] if quick else [0.1, 0.2, 0.3, 0.4, 0.5, 0.6])
    horizon = 2000 if quick else 10_000
    seeds = 2 if quick else 3
    for name in datasets:
        for beta in betas:
            t0 = time.perf_counter()
            costs = avg_costs_all_policies(
                name, beta, horizon=horizon, delta_fp=delta_fp, seeds=seeds,
                engine=engine)
            us = (time.perf_counter() - t0) * 1e6
            derived = ";".join(f"{p}={costs[p]:.4f}" for p in POLICIES)
            rows.append(f"fig4_{name}_beta{beta:g},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--delta1", type=float, default=0.7)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("\n".join(run(quick=args.quick, delta_fp=args.delta1)))
