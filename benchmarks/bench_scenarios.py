"""BEYOND-PAPER: cost/regret sweep across every registered workload scenario.

One fleet per scenario runs CHUNKED through the chosen `PolicyEngine`
(`engine.run_source` — the (S, T) trace is never materialized), reporting
the policy-observed average cost (β on offload, φ against the remote
label), the ground-truth average cost (φ against `ys` — these diverge under
`noisy_rdl`, where an offloaded sample can pay β *and* a misclassification),
and the offload rate. A second pass reports Theorem-2-style empirical
regret per scenario on a 1-stream source (the offline comparator needs the
materialized trace, so horizons stay modest there).

`--scenario a,b` restricts the sweep; default is every registered scenario.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import engine_cached
from repro.core import HIConfig, regret
from repro.data.scenarios import available_scenarios, get_scenario


def run(quick: bool = False, engine: str = "fused",
        scenario: str = "") -> List[str]:
    rows = []
    names = [n for n in scenario.split(",") if n] or sorted(
        available_scenarios(synthetic_only=True))
    horizon = 2048 if quick else 16_384
    block = 256 if quick else 1024
    n_streams = 8
    cfg = HIConfig(bits=4, eps=0.05, eta=1.0)
    eng = engine_cached(engine, cfg)
    for name in names:
        src = get_scenario(name, n_streams=n_streams, horizon=horizon,
                           block=block, key=jax.random.PRNGKey(7), beta=0.3)
        t0 = time.perf_counter()
        _, out = eng.run_source(src, jax.random.PRNGKey(11))
        jax.block_until_ready(out.loss)
        us = (time.perf_counter() - t0) * 1e6
        n = n_streams * horizon
        rows.append(
            f"scenario_{name},{us:.0f},"
            f"cost={float(jnp.sum(out.loss)) / n:.4f},"
            f"true_cost={float(jnp.sum(out.true_loss)) / n:.4f},"
            f"offload_rate={float(jnp.sum(out.offloads)) / n:.3f}")

    reg_horizon = 2000 if quick else 8000
    reg_cfg = cfg.with_horizon(reg_horizon)
    reg_eng = engine_cached(engine, reg_cfg)
    for name in names:
        src1 = get_scenario(name, n_streams=1, horizon=reg_horizon,
                            block=reg_horizon, key=jax.random.PRNGKey(7),
                            beta=0.3)
        t0 = time.perf_counter()
        res = regret.empirical_regret(reg_cfg, src1,
                                      key=jax.random.PRNGKey(3),
                                      n_seeds=2 if quick else 4,
                                      run=reg_eng.run)
        us = (time.perf_counter() - t0) * 1e6
        rows.append(f"scenario_{name}_regret,{us:.0f},"
                    f"regret={res['regret']:.1f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
