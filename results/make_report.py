"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the sweep JSONLs."""
import json
import sys


def load(path):
    out = []
    for line in open(path):
        line = line.strip()
        if line.startswith("{"):
            out.append(json.loads(line))
    return out


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | ok | peak GB/dev | fits 16G | compile s | collectives (full-depth count) |",
            "|---|---|---|---|---|---|---|---|"]
    for d in recs:
        m = d.get("memory") or {}
        c = d.get("collectives_fulldepth") or {}
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            f"{'✅' if d['ok'] else '❌ ' + d.get('error', '')[:60]} | "
            f"{m.get('peak_bytes', 0)/2**30:.1f} | "
            f"{'yes' if d.get('fits_hbm') else 'no'} | "
            f"{d.get('compile_seconds', '')} | {int(c.get('count', 0))} |")
    return "\n".join(rows)


def fmt_s(x):
    return f"{x:.3g}"


def roofline_table(recs):
    rows = ["| arch | shape | compute s | memory s | collective s | dominant | "
            "MODEL_FLOPS | useful ratio | peak GB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for d in recs:
        r = d.get("roofline")
        if not r:
            continue
        t = r["terms_seconds"]
        rows.append(
            f"| {d['arch']} | {d['shape']} | {fmt_s(t['compute'])} | "
            f"{fmt_s(t['memory'])} | {fmt_s(t['collective'])} | "
            f"**{r['dominant']}** | {r['model_flops']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{(r.get('memory') or {}).get('peak_bytes', 0)/2**30:.1f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "both"
    suffix = sys.argv[2] if len(sys.argv) > 2 else ""   # e.g. "_optimized"
    if which in ("dryrun", "both"):
        path = f"results/dryrun_sweep{suffix or '_final'}.jsonl"
        try:
            print(dryrun_table(load(path)))
        except FileNotFoundError:
            print(dryrun_table(load("results/dryrun_sweep.jsonl")))
        print()
    if which in ("roofline", "both"):
        try:
            print(roofline_table(load(f"results/roofline_sweep{suffix}.jsonl")))
        except FileNotFoundError:
            print("(roofline sweep not found)")
