"""Top-collective profiler: lower one (arch, shape, strategy) with unrolled
depth-2, group collective ops by (kind, shape), print descending total bytes."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import re
from collections import defaultdict
import jax
from repro.configs import get_config, get_shape
from repro.launch import builders
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import _SHAPE_RE, _shape_bytes, _COLL_KINDS

ap = argparse.ArgumentParser()
ap.add_argument("--arch", required=True)
ap.add_argument("--shape", required=True)
ap.add_argument("--strategy", default="auto")
ap.add_argument("--groups", type=int, default=2)
ap.add_argument("--top", type=int, default=20)
args = ap.parse_args()

cfg = builders.override_groups(get_config(args.arch), args.groups)
shape = get_shape(args.shape)
mesh = make_production_mesh()
fn, fargs, shard = builders.build_dryrun_step(cfg, shape, mesh, strategy=args.strategy, unroll=True, microbatches=1)
with mesh:
    compiled = jax.jit(fn, in_shardings=shard).lower(*fargs).compile()
agg = defaultdict(lambda: [0, 0.0])
for line in compiled.as_text().splitlines():
    s = line.strip()
    kind = None
    for k in _COLL_KINDS:
        if f" {k}(" in s or f"= {k}(" in s or f"{k}-start(" in s:
            kind = k; break
    if kind is None: continue
    shapes = _SHAPE_RE.findall(s)
    if not shapes: continue
    dt, dims = max(shapes, key=lambda x: _shape_bytes(*x))
    payload = _shape_bytes(dt, dims) * (2 if kind == "all-reduce" else 1)
    key = (kind, f"{dt}[{dims}]")
    agg[key][0] += 1
    agg[key][1] += payload
total = sum(v[1] for v in agg.values())
print(f"total collective bytes/device (depth-{args.groups}): {total/2**30:.2f} GiB")
for (kind, shp), (cnt, byt) in sorted(agg.items(), key=lambda kv: -kv[1][1])[:args.top]:
    print(f"{byt/2**30:9.3f} GiB  x{cnt:4d}  {kind:20s} {shp}")
ca = compiled.cost_analysis()
print("flops/dev %.3e  bytes/dev %.3e" % (ca.get("flops",0), ca.get("bytes accessed",0)))
